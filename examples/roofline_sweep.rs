//! Roofline sweep (paper Fig. 4): conv2d 3×3 over input sizes,
//! Quark-8-lane (2-bit bit-serial) vs Ara-4-lane (int8) — the two designs
//! occupy the same 1.09 mm² die and power budget (Table II), so raw GOPS is
//! the fair comparison.
//!
//! ```sh
//! cargo run --release --offline --example roofline_sweep
//! ```

use quark::report::fig4;

fn main() {
    let fig = fig4::generate(&[4, 8, 16, 32, 56]);
    println!("{}", fig.markdown());

    // ASCII roofline, log-log-ish.
    println!("roofline sketch (log AI → attainable GOPS):");
    for roof in &fig.roofs {
        println!("\n{} (peak {:.0} GOPS, BW {:.0} GB/s, ridge {:.1} ops/B)", roof.name, roof.peak_gops, roof.mem_gbs, roof.ridge());
        let mut ai = 0.125f64;
        while ai <= 512.0 {
            let g = roof.attainable(ai);
            let bar = "#".repeat(((g / roof.peak_gops) * 50.0) as usize);
            println!("  {:>7.2} ops/B | {bar} {:.0}", ai, g);
            ai *= 4.0;
        }
    }
    println!("\nmeasured points:");
    for p in &fig.points {
        println!("  {:<22} AI {:>6.2}  {:>7.1} GOPS  ({:.0}% of roof)", p.label, p.ai, p.gops, p.efficiency * 100.0);
    }
}
