//! Quickstart: the bit-serial pipeline on a simulated Quark core, end to end.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! 1. quantize a small weight/activation matrix to 2-bit codes,
//! 2. pack the weights offline (the host's job, as in the paper),
//! 3. run the bit-serial GEMM on Quark — `vbitpack` packs activations at
//!    runtime, `vand`+`vpopcnt`+`vshacc` compute paper Eq. (1),
//! 4. compare cycles against the same GEMM on Ara with int8.

use quark::arch::MachineConfig;
use quark::kernels::bitpack::setup_index_vector;
use quark::kernels::matmul::{gemm_codes_golden, matmul_bitserial, matmul_int8};
use quark::kernels::requantize::RqBuf;
use quark::quant::{pack_weight_planes, quantize_activations, quantize_weights_unsigned};
use quark::sim::Sim;

fn main() {
    let (m, k, n) = (16, 256, 64);

    // --- 1. quantize real-valued tensors to 2-bit codes -------------------
    let wf: Vec<f32> = (0..k * n).map(|i| ((i * 37 % 100) as f32 / 50.0) - 1.0).collect();
    let af: Vec<f32> = (0..m * k).map(|i| (i * 13 % 100) as f32 / 100.0).collect();
    let (w_codes, wq) = quantize_weights_unsigned(&wf, 2);
    let (a_codes, aq) = quantize_activations(&af, 2);
    println!("weights → 2-bit affine codes (alpha={:.4}, beta={:.4})", wq.alpha, wq.beta);
    println!("acts    → 2-bit unsigned codes (scale={:.4})", aq.scale);

    // --- 2. Quark: bit-serial GEMM ----------------------------------------
    let mut quark = Sim::new(MachineConfig::quark(4));
    let idx = setup_index_vector(&mut quark);
    let wpk = pack_weight_planes(&w_codes, k, n, 2, quark.cfg.vlen_bits / 64);
    let a_addr = quark.alloc((m * k) as u64);
    quark.write_bytes(a_addr, &a_codes);
    let w_addr = quark.alloc(wpk.byte_len() as u64);
    for (i, &word) in wpk.words.iter().enumerate() {
        quark.machine.mem.write_u64_le(w_addr + (i * 8) as u64, word, 8);
    }
    let rq = RqBuf::create(&mut quark, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = quark.alloc((m * n) as u64);
    let run_q =
        matmul_bitserial(&mut quark, m, k, n, 2, a_addr, &wpk, w_addr, &rq, out, true, idx);

    // Verify against the host oracle (alpha=1/beta=0 requant → clamped ACC).
    let (acc, _) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);
    let got = quark.read_u8s(out, m * n);
    for i in 0..m * n {
        assert_eq!(got[i] as i64, acc[i].clamp(0, 255), "output {i}");
    }
    println!(
        "\nQuark-4L  w2a2 : {:>9} cycles  ({:.2} MAC/cycle) — verified vs oracle",
        run_q.cycles,
        run_q.macs_per_cycle()
    );

    // --- 3. Ara baseline: int8 GEMM ----------------------------------------
    let mut ara = Sim::new(MachineConfig::ara(4));
    let a8 = ara.alloc((m * k) as u64);
    let w8 = ara.alloc((k * n) as u64);
    let rq8 = RqBuf::create(&mut ara, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out8 = ara.alloc((m * n) as u64);
    let run_a = matmul_int8(&mut ara, m, k, n, a8, w8, &rq8, out8);
    println!(
        "Ara-4L    int8 : {:>9} cycles  ({:.2} MAC/cycle)",
        run_a.cycles,
        run_a.macs_per_cycle()
    );

    println!(
        "\nspeedup (Int2 bit-serial vs Int8): {:.2}x",
        run_a.cycles as f64 / run_q.cycles as f64
    );
}
