//! End-to-end driver: quantized ResNet-18 (CIFAR variant, batch 1) inference
//! through the full system — functional + cycle simulation on every layer,
//! all paper precisions, plus the PJRT golden cross-check when artifacts are
//! present. This is the run recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example resnet18_e2e
//! ```

use quark::arch::MachineConfig;
use quark::nn::model::{ModelRunner, Precision};
use quark::nn::zoo;
use quark::sim::{Sim, SimMode};

fn run(cfg: MachineConfig, precision: Precision, full: bool) -> (Vec<quark::nn::LayerReport>, f64) {
    let net = zoo::model("resnet18-cifar@100").expect("registry entry");
    let mut sim = Sim::new(cfg);
    // `Full` executes every instruction functionally (data really flows);
    // TimingOnly produces identical cycle counts (asserted in the tests).
    sim.set_mode(if full { SimMode::Full } else { SimMode::TimingOnly });
    let t0 = std::time::Instant::now();
    let reports = ModelRunner::run(&mut sim, &net, precision);
    (reports, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("=== quantized ResNet-18 / CIFAR-100-scale input, batch 1 ===\n");
    let configs: Vec<(MachineConfig, Precision, bool)> = vec![
        // Full functional execution for the two headline configs; the rest
        // timing-only (identical cycles, ~5x faster wall-clock).
        (MachineConfig::ara(4), Precision::Int8, true),
        (MachineConfig::ara(4), Precision::Fp32, false),
        (MachineConfig::quark(4), Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true }, false),
        (MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true }, true),
        (MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: false }, false),
    ];

    let mut table: Vec<(String, String, Vec<(String, u64)>, u64, f64, f64)> = Vec::new();
    for (cfg, prec, full) in configs {
        let name = cfg.name.clone();
        let freq = cfg.freq_ghz;
        eprintln!("running {} {} ({})…", name, prec.label(), if full { "full" } else { "timing" });
        let (reports, wall) = run(cfg, prec, full);
        let total: u64 = reports.iter().map(|r| r.run.cycles).sum();
        let per_layer: Vec<(String, u64)> = reports
            .iter()
            .filter(|r| r.quantized)
            .map(|r| (r.name.clone(), r.run.cycles))
            .collect();
        let ms = total as f64 / (freq * 1e6);
        table.push((name, prec.label(), per_layer, total, ms, wall));
    }

    // Per-layer speedups vs Ara int8 (paper Fig. 3's view).
    let base = table[0].2.clone();
    println!("\nper-layer speedup over ara-4l int8:");
    println!("{:<18} {:>12} {:>8} {:>8} {:>8} {:>12}", "layer", "int8 cyc", "fp32", "w1a1", "w2a2", "w2a2-novbp");
    for (li, (lname, bcyc)) in base.iter().enumerate() {
        print!("{:<18} {:>12}", lname, bcyc);
        for entry in &table[1..] {
            let c = entry.2[li].1;
            print!(" {:>7.2}x", *bcyc as f64 / c as f64);
        }
        println!();
    }

    println!("\nend-to-end (all layers incl. stem/pool):");
    println!("{:<12} {:<12} {:>14} {:>10} {:>12}", "machine", "precision", "device cycles", "device ms", "host sim s");
    for (name, prec, _, total, ms, wall) in &table {
        println!("{name:<12} {prec:<12} {total:>14} {ms:>10.3} {wall:>12.1}");
    }
    let int8 = table[0].3 as f64;
    println!("\nnetwork speedups vs ara-4l int8 (quantized layers + glue):");
    for (name, prec, _, total, _, _) in &table[1..] {
        println!("  {name} {prec}: {:.2}x", int8 / *total as f64);
    }

    // Golden cross-check through PJRT, if the AOT artifacts exist.
    if std::path::Path::new("artifacts/qgemm.hlo.txt").exists() {
        println!("\nPJRT golden cross-check (L1 Pallas → AOT → xla crate):");
        match quark::runtime::Runtime::cpu() {
            Ok(rt) => match quark::coordinator::golden::crosscheck_qgemm(&rt, "artifacts/qgemm.hlo.txt", 7) {
                Ok(r) => println!(
                    "  {} accumulators, {} mismatches — simulator == JAX == oracle {}",
                    r.checked,
                    r.mismatches,
                    if r.mismatches == 0 { "✓" } else { "✗" }
                ),
                Err(e) => println!("  crosscheck failed: {e}"),
            },
            Err(e) => println!("  PJRT unavailable: {e}"),
        }
    } else {
        println!("\n(run `make artifacts` to enable the PJRT golden cross-check)");
    }
}
