//! Serving demo: start the coordinator (pool of simulated Quark cores +
//! dynamic batcher) and drive it with an in-process client load, reporting
//! throughput and latency percentiles — the L3 runtime in action.
//!
//! ```sh
//! cargo run --release --offline --example serve
//! ```
//! (For the TCP front-end use `repro serve` and talk to it with netcat.)

use std::time::{Duration, Instant};

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};

fn main() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 2;
    cfg.batch_size = 4;
    cfg.batch_timeout = Duration::from_millis(10);
    println!(
        "coordinator: {} workers ({}), precision {:?}, batch ≤ {}",
        cfg.workers, cfg.machine.name, cfg.precision, cfg.batch_size
    );
    let coord = Coordinator::start(cfg);

    let n = 24u64;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| coord.submit(InferenceRequest { id, input: vec![(id % 4) as u8; 32 * 32 * 3] }))
        .collect();
    let mut responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = t0.elapsed();
    responses.sort_by_key(|r| r.id);

    let mut lat: Vec<f64> =
        responses.iter().map(|r| (r.queue_time + r.service_time).as_secs_f64() * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() as f64 - 1.0) * p) as usize];
    let device_us: f64 = responses.iter().map(|r| r.device_us).sum::<f64>() / n as f64;
    let batches: std::collections::HashSet<u64> = responses.iter().map(|r| r.batch_id).collect();

    println!("\nserved {n} requests in {:.2}s → {:.1} req/s (host)", wall.as_secs_f64(), n as f64 / wall.as_secs_f64());
    println!("batches formed : {} (avg {:.1} req/batch)", batches.len(), n as f64 / batches.len() as f64);
    println!("device latency : {:.0} us/request (simulated {} @ {:.2} GHz)", device_us, coord.config().machine.name, coord.config().machine.freq_ghz);
    println!("host latency   : p50 {:.0} ms, p90 {:.0} ms, p99 {:.0} ms", pct(0.5), pct(0.9), pct(0.99));
    let per_worker: Vec<usize> = (0..coord.config().workers)
        .map(|w| responses.iter().filter(|r| r.worker == w).count())
        .collect();
    println!("per-worker load: {per_worker:?}");
    coord.shutdown();
}
