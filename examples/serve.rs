//! Serving demo: start the coordinator (pool of persistent simulated Quark
//! cores + dynamic batcher + timing cache) and drive it with an in-process
//! client load, reporting throughput, latency percentiles, cache behavior,
//! and a couple of real classifications — the L3 runtime in action.
//!
//! ```sh
//! cargo run --release --offline --example serve
//! ```
//! (For the TCP front-end use `repro serve` and talk to it with netcat.)

use std::time::{Duration, Instant};

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};

fn main() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 2;
    cfg.batch_size = 4;
    cfg.batch_timeout = Duration::from_millis(10);
    println!(
        "coordinator: {} workers ({}), schedule {}, batch ≤ {}, queue ≤ {}",
        cfg.workers, cfg.machine.name, cfg.schedule.label(), cfg.batch_size, cfg.max_queue
    );
    let coord = Coordinator::start(cfg);

    // Phase 1: timing-only load — after the first batch per worker this is
    // pure timing-cache hits, so throughput is bounded by batching overhead.
    let n = 64u64;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| {
            coord
                .submit(InferenceRequest { id, ..Default::default() })
                .expect("queue has room")
        })
        .collect();
    let mut responses: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().expect("undeadlined requests never expire"))
        .collect();
    let wall = t0.elapsed();
    responses.sort_by_key(|r| r.id);

    let mut lat: Vec<f64> =
        responses.iter().map(|r| (r.queue_time + r.service_time).as_secs_f64() * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() as f64 - 1.0) * p) as usize];
    let device_us: f64 = responses.iter().map(|r| r.device_us).sum::<f64>() / n as f64;
    let batches: std::collections::HashSet<u64> = responses.iter().map(|r| r.batch_id).collect();
    let cached = responses.iter().filter(|r| r.timing_cached).count();

    println!(
        "\nserved {n} timing requests in {:.3}s → {:.0} req/s (host)",
        wall.as_secs_f64(),
        n as f64 / wall.as_secs_f64()
    );
    println!("batches formed : {} (avg {:.1} req/batch)", batches.len(), n as f64 / batches.len() as f64);
    println!("timing cache   : {cached}/{n} responses served from cache");
    println!(
        "device latency : {:.0} us/request (simulated {} @ {:.2} GHz)",
        device_us,
        coord.config().machine.name,
        coord.config().machine.freq_ghz
    );
    println!("host latency   : p50 {:.2} ms, p90 {:.2} ms, p99 {:.2} ms", pct(0.5), pct(0.9), pct(0.99));

    // Phase 2: two real inferences — input bytes flow through the functional
    // executor and come back as logits.
    let input_a = vec![0u8; 32 * 32 * 3];
    let input_b = vec![200u8; 32 * 32 * 3];
    for (label, input) in [("zeros", input_a), ("bright", input_b)] {
        let rx = coord
            .submit(InferenceRequest { id: 1000, input: Some(input), ..Default::default() })
            .expect("queue has room");
        let r = rx.recv().unwrap().expect("undeadlined requests never expire");
        println!(
            "classify {label:>6}: argmax={} (service {:.0} ms, worker {})",
            r.argmax.unwrap(),
            r.service_time.as_secs_f64() * 1e3,
            r.worker
        );
    }

    let s = coord.stats();
    println!(
        "\nSTATS served={} rejected={} expired={} degraded={} cache_hits={} cache_misses={} \
         p50_us={} p99_us={} util={:?}",
        s.served, s.rejected, s.expired, s.degraded, s.cache_hits, s.cache_misses,
        s.p50_us, s.p99_us,
        s.utilization.iter().map(|u| (u * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    coord.shutdown();
}
