//! Differential + identity proof of the model registry
//! ([`quark::nn::zoo`]): every zoo topology is a first-class workload —
//!
//! * **bit-exact vs the naive-i128 golden**: the new topologies
//!   (`resnet34-cifar`, `quarknet`, `mlp`) run layer-by-layer bit-identical
//!   to [`quark::nn::golden::run_golden`] at uniform w2a2 and a mixed
//!   schedule, in `Full` mode, with `TimingOnly` cycle counts identical to
//!   the `Full` run (both `SimMode`s — the cycle model is
//!   data-independent);
//! * **cluster N = 1 emission identity per zoo model**: for every
//!   registered model, the 1-shard cluster program is artifact-identical to
//!   the single-core [`quark::program::compile`] output and reports exactly
//!   its cycles (zero sync);
//! * **registry identity**: `resnet18-cifar@100` through the registry is
//!   the exact paper graph (the default-path regression guard lives next to
//!   the emitter, in `nn::model`'s unit tests, where it can drive the raw
//!   layer list through the shared emission routine).
//!
//! The deep ResNet-34 runs its `Full`-mode differential on a
//! [`zoo::model_head`] prefix (stem + the first stage-1 block, i.e. the
//! residual add) — full-graph `Full` mode is debug-prohibitive, the same
//! trade `rust/tests/cluster.rs` makes — and its full graph in `TimingOnly`
//! mode.

use quark::arch::MachineConfig;
use quark::cluster::{cluster_timing, compile_cluster};
use quark::nn::golden::run_golden;
use quark::nn::model::{ModelRunner, Precision, PrecisionMap};
use quark::nn::{zoo, NetGraph};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };

fn test_input() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 23 + 19) % 251) as u8).collect()
}

/// The acceptance schedules: uniform w2a2, the registry's mixed schedule
/// (stage-1 + FC at int8 — on an all-FC graph that resolves to uniform
/// int8, still a distinct cache key), and a hand-picked boundary schedule
/// pinning one mid-graph layer to int8 so every topology exercises a real
/// 8-bit↔2-bit consumer-grid re-pack.
fn schedules(net: &NetGraph, boundary_layer: &str) -> Vec<(&'static str, PrecisionMap)> {
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("mixed", zoo::mixed_schedule(net)),
        ("boundary", PrecisionMap::uniform(W2A2).with(boundary_layer, Precision::Int8)),
    ]
}

/// Full-mode emission vs the i128 golden, layer by layer, plus the
/// TimingOnly cycle identity of the same (net, schedule).
fn run_differential(net: &NetGraph, boundary_layer: &str) {
    let input = test_input();
    for (label, sched) in schedules(net, boundary_layer) {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.set_mode(SimMode::Full);
        let run = ModelRunner::run_scheduled(&mut sim, net, &sched, Some(&input));
        let golden = run_golden(net, &sched, Some(&input));
        assert_eq!(run.reports.len(), net.len());
        for (i, r) in run.reports.iter().enumerate() {
            assert_eq!(
                sim.read_u8s(r.out_addr, r.out_elems),
                golden.maps[i + 1],
                "{}: layer {i} ({} @ {}) diverges from the i128 golden under {label}",
                net.name(),
                r.name,
                r.precision.label()
            );
        }
        // Both SimModes: TimingOnly reports the identical per-layer cycles.
        let mut tsim = Sim::new(MachineConfig::quark(4));
        tsim.set_mode(SimMode::TimingOnly);
        let trun = ModelRunner::run_scheduled(&mut tsim, net, &sched, None);
        for (f, t) in run.reports.iter().zip(trun.reports.iter()) {
            assert_eq!(
                f.run.cycles, t.run.cycles,
                "{}: Full vs TimingOnly cycle drift at {} under {label}",
                net.name(),
                f.name
            );
        }
    }
}

#[test]
fn mlp_matches_golden_both_modes() {
    // fc2 at int8 inside a w2a2 stack: 2-bit → 8-bit → 2-bit boundaries on
    // a pure-GEMM graph.
    run_differential(&zoo::model("mlp").unwrap(), "fc2");
}

#[test]
fn quarknet_matches_golden_both_modes() {
    // The 10-class variant: full graph (the plain-feedforward topology is
    // Full-mode affordable end to end); c2 pinned for the boundary leg.
    run_differential(&zoo::model("quarknet@10").unwrap(), "c2");
}

#[test]
fn resnet34_head_matches_golden_both_modes() {
    // stem + conv1_s1b1a + conv2_s1b1b: the residual add of the deep
    // variant at Full-mode-affordable scale.
    let head = zoo::model_head("resnet34-cifar@10", 3).unwrap();
    assert_eq!(head.len(), 3);
    run_differential(&head, "conv1_s1b1a");
}

#[test]
fn resnet34_full_graph_runs_timing_only() {
    // The whole [3,4,6,3] graph through the runner: every layer emits and
    // the deep net costs roughly twice the quantized work of ResNet-18.
    let net34 = zoo::model("resnet34-cifar@100").unwrap();
    let net18 = zoo::model("resnet18-cifar@100").unwrap();
    let cycles = |net: &NetGraph| -> u64 {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.set_mode(SimMode::TimingOnly);
        ModelRunner::run(&mut sim, net, W2A2).iter().map(|r| r.run.cycles).sum()
    };
    let (c34, c18) = (cycles(&net34), cycles(&net18));
    assert!(
        c34 > (c18 as f64 * 1.5) as u64 && c34 < c18 * 3,
        "ResNet-34 should cost ~2x ResNet-18: {c34} vs {c18}"
    );
}

#[test]
fn cluster_n1_emission_identity_per_zoo_model() {
    // Acceptance: for EVERY registered model (at its --fast profile, so the
    // deep nets stay affordable), the 1-shard cluster program is
    // artifact-identical to the single-core compile and its cluster timing
    // equals the single-core cycles exactly, with zero sync.
    let quark = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    for e in zoo::entries() {
        let net = zoo::model_profile(e.name, true).unwrap();
        let prog = compile(&net, &quark, &sched).unwrap();
        let mut sim = Sim::new(quark.clone());
        sim.set_mode(SimMode::TimingOnly);
        let base = sim.alloc(prog.mem_len());
        let single = sim.execute(&prog, base).cycles;

        let cluster = compile_cluster(&net, &quark, &sched, 1).unwrap();
        let shard0 = &cluster.shard_programs()[0];
        assert_eq!(shard0.trace_len(), prog.trace_len(), "{}", e.name);
        assert_eq!(shard0.image_bytes(), prog.image_bytes(), "{}", e.name);
        assert_eq!(shard0.mem_len(), prog.mem_len(), "{}", e.name);
        let t = cluster_timing(&cluster, &quark);
        assert_eq!(t.sync_cycles, 0, "{}", e.name);
        assert_eq!(
            t.total_cycles(),
            single,
            "{}: a 1-shard cluster must report exactly the single-core cycles",
            e.name
        );
    }
}

#[test]
fn zoo_models_shard_bit_exactly() {
    // The new topologies survive tensor-parallel partitioning: mlp (pure
    // GEMM stack, uneven 10-way classifier splits) and the quarknet head
    // gather to logits bit-identical to their single-core programs.
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let input = test_input();
    for (spec, shards) in [("mlp", 4usize), ("quarknet@10", 2)] {
        let net = if spec == "mlp" {
            zoo::model(spec).unwrap()
        } else {
            zoo::model_head(spec, 4).unwrap()
        };
        let prog = compile(&net, &machine, &sched).unwrap();
        let mut sim = Sim::new(machine.clone());
        let base = sim.alloc(prog.mem_len());
        let run = sim.execute_functional(&prog, base, Some(&input));
        let single = sim.read_u8s(run.out_addr, run.out_elems);

        let cluster = compile_cluster(&net, &machine, &sched, shards).unwrap();
        let mut cores = quark::cluster::ClusterCores::new(&machine, shards);
        let sharded = cores.infer(&cluster, &input).logits;
        assert_eq!(sharded, single, "{spec} at {shards} shards");
    }
}

#[test]
fn registry_resnet18_is_the_paper_graph() {
    // Identity guard: the registry's default workload is structurally the
    // exact graph the paper's reports have always used.
    let g = zoo::model("resnet18-cifar@100").unwrap();
    assert_eq!(
        quark::nn::structural_fingerprint(&g),
        quark::nn::structural_fingerprint(&quark::nn::resnet::resnet18_cifar(100)),
    );
    assert_eq!(g.num_classes(), 100);
    assert_eq!(g.out_elems(), 100);
    assert_eq!(quark::nn::resnet::quantized_layers(&g).len(), 20);
}
