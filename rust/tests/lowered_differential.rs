//! The oracle suite the decode-once lowering is judged against:
//! [`Sim::execute_lowered`] (fused micro-op replay, the warm serving path)
//! must be indistinguishable from [`Sim::execute_with_input`] (the timed
//! instruction-by-instruction interpreter) and from the naive-i128 host
//! golden model —
//!
//! * **bit-exact logits and per-layer feature maps** for every `nn::zoo`
//!   entry at {w2a2, w1a1, mixed, int8} schedules,
//! * at **relocated base addresses** (two fresh bases plus a worker-style
//!   dirty-arena replay),
//! * and under **cluster sharding** at {1, 2} shards, where every shard
//!   core replays its program through the same functional range machinery
//!   the lowering falls back to.
//!
//! Deep graphs run on `Full`-mode-affordable prefixes ([`zoo::model_head`]
//! / 10-class variants) — the same trade `rust/tests/zoo.rs` makes; the
//! lowering walk itself sees every kernel shape (bit-serial conv, int8
//! conv, FC, pool, residual re-pack) through those heads.

use quark::arch::MachineConfig;
use quark::cluster::{compile_cluster, ClusterCores};
use quark::nn::golden::run_golden;
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::{zoo, NetGraph};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

fn test_input() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + 5) % 251) as u8).collect()
}

/// Every registered model at a `Full`-mode-affordable profile: shallow
/// graphs whole (10-class variants keep the classifier small), deep ResNets
/// as a stem + first-residual-block head.
fn affordable_zoo() -> Vec<NetGraph> {
    zoo::entries()
        .iter()
        .map(|e| match e.name {
            "resnet18-cifar" => zoo::model_head("resnet18-cifar@10", 4).unwrap(),
            "resnet34-cifar" => zoo::model_head("resnet34-cifar@10", 3).unwrap(),
            name => zoo::model(&format!("{name}@10")).unwrap(),
        })
        .collect()
}

/// The acceptance schedule matrix: uniform w2a2 / w1a1 / int8 plus the
/// registry's mixed schedule for this graph.
fn schedules(net: &NetGraph) -> Vec<(&'static str, PrecisionMap)> {
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("w1a1", PrecisionMap::uniform(W1A1)),
        ("mixed", zoo::mixed_schedule(net)),
        ("int8", PrecisionMap::uniform(Precision::Int8)),
    ]
}

#[test]
fn every_zoo_model_lowered_matches_timed_and_golden() {
    let input = test_input();
    for net in affordable_zoo() {
        for (label, sched) in schedules(&net) {
            let ctx = format!("{} under {label}", net.name());
            let prog = compile(&net, &MachineConfig::quark(4), &sched)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let golden = run_golden(&net, &sched, Some(&input));

            // Timed oracle: Full-mode instruction-by-instruction replay.
            let mut timed = Sim::new(MachineConfig::quark(4));
            timed.set_mode(SimMode::Full);
            let tb = timed.alloc(prog.mem_len());
            let trun = timed.execute_with_input(&prog, tb, Some(&input));
            assert_eq!(
                timed.read_u8s(trun.out_addr, trun.out_elems),
                golden.maps[net.len()],
                "{ctx}: timed oracle diverges from the i128 golden"
            );

            // Lowered replay: fused micro-ops, same memory effects.
            let mut low = Sim::new(MachineConfig::quark(4));
            let lb = low.alloc(prog.mem_len());
            let lrun = low.execute_lowered(&prog, lb, Some(&input));
            assert_eq!(lrun.cycles, 0, "{ctx}: lowered replay accounts no cycles");
            assert_eq!(lrun.reports.len(), net.len(), "{ctx}");
            for (i, (l, t)) in lrun.reports.iter().zip(trun.reports.iter()).enumerate() {
                assert_eq!(l.name, t.name, "{ctx}");
                assert_eq!(l.out_elems, t.out_elems, "{ctx}: layer {}", t.name);
                let got = low.read_u8s(l.out_addr, l.out_elems);
                assert_eq!(
                    got,
                    timed.read_u8s(t.out_addr, t.out_elems),
                    "{ctx}: lowered layer {} diverges from the timed oracle",
                    t.name
                );
                assert_eq!(
                    got, golden.maps[i + 1],
                    "{ctx}: lowered layer {} diverges from the i128 golden",
                    t.name
                );
            }
            assert_eq!(
                low.read_u8s(lrun.out_addr, lrun.out_elems),
                golden.maps[net.len()],
                "{ctx}: lowered logits diverge from the i128 golden"
            );
        }
    }
}

#[test]
fn lowered_relocation_replays_bit_exactly_at_two_bases() {
    let net = zoo::model("tiny@10").unwrap();
    let sched = zoo::mixed_schedule(&net);
    let input = test_input();
    let prog = compile(&net, &MachineConfig::quark(4), &sched).unwrap();
    let golden = run_golden(&net, &sched, Some(&input));

    // Base A: the compile-time base (fresh sim, first allocation).
    let mut sim_a = Sim::new(MachineConfig::quark(4));
    let base_a = sim_a.alloc(prog.mem_len());
    let run_a = sim_a.execute_lowered(&prog, base_a, Some(&input));
    assert_eq!(sim_a.read_u8s(run_a.out_addr, run_a.out_elems), golden.maps[net.len()]);

    // Base B: shifted by a padding allocation — every resolved micro-op
    // address must follow the delta.
    let mut sim_b = Sim::new(MachineConfig::quark(4));
    sim_b.alloc(1 << 16);
    let base_b = sim_b.alloc(prog.mem_len());
    assert_ne!(base_a, base_b, "test must exercise a real relocation");
    let run_b = sim_b.execute_lowered(&prog, base_b, Some(&input));
    assert_eq!(
        run_b.out_addr,
        run_a.out_addr + (base_b - base_a),
        "reported addresses must follow the relocation delta"
    );
    for (a, b) in run_a.reports.iter().zip(run_b.reports.iter()) {
        assert_eq!(b.out_addr, a.out_addr + (base_b - base_a), "layer {}", a.name);
        assert_eq!(
            sim_a.read_u8s(a.out_addr, a.out_elems),
            sim_b.read_u8s(b.out_addr, b.out_elems),
            "layer {}",
            a.name
        );
    }
    assert_eq!(sim_b.read_u8s(run_b.out_addr, run_b.out_elems), golden.maps[net.len()]);

    // Worker-style reuse of a dirty arena at yet another base.
    let base_c = sim_b.alloc(prog.mem_len());
    let run_c = sim_b.execute_lowered(&prog, base_c, Some(&input));
    assert_eq!(sim_b.read_u8s(run_c.out_addr, run_c.out_elems), golden.maps[net.len()]);
}

#[test]
fn lowered_matches_cluster_inference_at_one_and_two_shards() {
    let net = zoo::model_head("quarknet@10", 4).unwrap();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let input = test_input();
    let golden = run_golden(&net, &sched, Some(&input));

    // Single-core lowered logits — the reference.
    let prog = compile(&net, &machine, &sched).unwrap();
    let mut sim = Sim::new(machine.clone());
    let base = sim.alloc(prog.mem_len());
    let run = sim.execute_lowered(&prog, base, Some(&input));
    let single = sim.read_u8s(run.out_addr, run.out_elems);
    assert_eq!(single, golden.maps[net.len()]);

    for shards in [1usize, 2] {
        let cluster = compile_cluster(&net, &machine, &sched, shards).unwrap();
        let mut cores = ClusterCores::new(&machine, shards);
        let sharded = cores.infer(&cluster, &input).logits;
        assert_eq!(
            sharded, single,
            "cluster at {shards} shard(s) must gather the single-core lowered logits"
        );
    }
}
