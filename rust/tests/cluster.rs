//! Differential proof of the cluster-sharding subsystem
//! ([`quark::cluster`]): partitioning one inference across N simulated
//! Quark cores is *functionally invisible* —
//!
//! * **bit-exact logits** at shard counts {1, 2, 4}, for w2a2, w1a1, and
//!   the SPEED-style mixed schedule, against both the single-core
//!   [`CompiledProgram`] replay and the naive-i128 host golden model;
//! * **cycle identity at N = 1**: the cluster model of a 1-shard deployment
//!   reports exactly the single-core program's cycles (and zero sync);
//! * **monotone scaling**: more shards → lower modeled latency, with a
//!   non-zero all-gather sync fraction charged against the AXI link;
//! * **uneven partitions** (channel counts not divisible by the shard
//!   count) still gather to bit-exact results.
//!
//! The functional differentials run on a ResNet-18 *head* — stem + a
//! stage-1 basic block + the stage-2 downsampling block (projection
//! shortcut + stride-2 convs) + pool + 100-way FC, i.e. every layer kind,
//! residual topology, and re-pack boundary of the full graph at
//! `Full`-mode-affordable scale (the same trade `program_replay.rs` makes).
//! The full ResNet-18 graph is covered in `TimingOnly` mode here and by
//! `benches/cluster_scaling.rs`; the `#[ignore]`d test at the bottom runs
//! the full-graph functional differential (release mode recommended:
//! `cargo test --release --test cluster -- --ignored`).

use quark::arch::MachineConfig;
use quark::cluster::{cluster_timing, compile_cluster, ClusterCores};
use quark::kernels::Conv2dParams;
use quark::nn::golden::run_golden;
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::resnet::resnet18_mixed_schedule;
use quark::nn::{zoo, ConvLayer, LayerKind, NetGraph, NetLayer};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

fn conv(
    name: &str,
    h: usize,
    c_in: usize,
    c_out: usize,
    ksz: usize,
    stride: usize,
    relu: bool,
    residual: bool,
    quantized: bool,
) -> ConvLayer {
    ConvLayer {
        name: name.into(),
        params: Conv2dParams {
            h,
            w: h,
            c_in,
            c_out,
            kh: ksz,
            kw: ksz,
            stride,
            pad: if ksz == 3 { 1 } else { 0 },
        },
        relu,
        residual,
        quantized,
    }
}

/// ResNet-18 head at 16×16: stem, one stage-1 basic block (residual add),
/// the stage-2 downsampling block (1×1 stride-2 projection + stride-2 conv
/// + residual), global pool, 100-way FC. Layer names follow the full
/// graph's convention so [`resnet18_mixed_schedule`] applies unchanged.
fn resnet_head() -> NetGraph {
    NetGraph::new(
        "resnet-head@100",
        100,
        vec![
            // map 1
            NetLayer {
                kind: LayerKind::Conv(conv("stem", 16, 3, 64, 3, 1, true, false, false)),
                input: 0,
                residual_from: None,
            },
            // map 2
            NetLayer {
                kind: LayerKind::Conv(conv("conv1_s1b1a", 16, 64, 64, 3, 1, true, false, true)),
                input: 1,
                residual_from: None,
            },
            // map 3: closes the stage-1 block (skip from the stem).
            NetLayer {
                kind: LayerKind::Conv(conv("conv2_s1b1b", 16, 64, 64, 3, 1, true, true, true)),
                input: 2,
                residual_from: Some(1),
            },
            // map 4: projection shortcut (1×1, stride 2, 64→128).
            NetLayer {
                kind: LayerKind::Conv(conv("conv3_ds_s2b1", 16, 64, 128, 1, 2, false, false, true)),
                input: 3,
                residual_from: None,
            },
            // map 5
            NetLayer {
                kind: LayerKind::Conv(conv("conv4_s2b1a", 16, 64, 128, 3, 2, true, false, true)),
                input: 3,
                residual_from: None,
            },
            // map 6: closes the stage-2 block (skip from the projection).
            NetLayer {
                kind: LayerKind::Conv(conv("conv5_s2b1b", 8, 128, 128, 3, 1, true, true, true)),
                input: 5,
                residual_from: Some(4),
            },
            // map 7
            NetLayer { kind: LayerKind::AvgPool { h: 8, w: 8, c: 128 }, input: 6, residual_from: None },
            // map 8
            NetLayer {
                kind: LayerKind::Fc { k: 128, n: 100, name: "fc".into() },
                input: 7,
                residual_from: None,
            },
        ],
    )
    .unwrap()
}

fn test_input() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + 5) % 251) as u8).collect()
}

/// The three acceptance schedules on a given graph.
fn schedules(net: &NetGraph) -> Vec<(&'static str, PrecisionMap)> {
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("w1a1", PrecisionMap::uniform(W1A1)),
        ("mixed", resnet18_mixed_schedule(net)),
    ]
}

/// Single-core reference: functional replay of the unsharded program.
fn single_core_logits(net: &NetGraph, sched: &PrecisionMap, input: &[u8]) -> Vec<u8> {
    let prog = compile(net, &MachineConfig::quark(4), sched).unwrap();
    let mut sim = Sim::new(MachineConfig::quark(4));
    let base = sim.alloc(prog.mem_len());
    let run = sim.execute_functional(&prog, base, Some(input));
    sim.read_u8s(run.out_addr, run.out_elems)
}

fn cluster_logits(net: &NetGraph, sched: &PrecisionMap, input: &[u8], shards: usize) -> Vec<u8> {
    let machine = MachineConfig::quark(4);
    let cluster = compile_cluster(net, &machine, sched, shards).unwrap();
    let mut cores = ClusterCores::new(&machine, shards);
    cores.infer(&cluster, input).logits
}

fn run_functional_differential(net: &NetGraph, shard_counts: &[usize]) {
    let input = test_input();
    for (label, sched) in schedules(net) {
        let single = single_core_logits(net, &sched, &input);
        let golden = run_golden(net, &sched, Some(&input));
        assert_eq!(
            &single,
            golden.maps.last().unwrap(),
            "single-core replay diverges from the i128 golden under {label}"
        );
        for &n in shard_counts {
            let sharded = cluster_logits(net, &sched, &input, n);
            assert_eq!(
                sharded, single,
                "{n}-shard logits diverge from the single-core program under {label}"
            );
        }
    }
}

#[test]
fn sharded_logits_bit_exact_vs_single_core_and_golden() {
    // Shard counts {1, 2, 4} × {w2a2, w1a1, mixed} on the ResNet-18 head:
    // gathered logits must equal both the single-core CompiledProgram
    // replay and the naive-i128 host golden, bit for bit.
    run_functional_differential(&resnet_head(), &[1, 2, 4]);
}

#[test]
fn uneven_channel_splits_gather_bit_exactly() {
    // A 100-class FC over the raw input plane (K = 3072 — 64-aligned for
    // the bit-plane kernels), sharded 8 ways: 100 % 8 != 0, so shards own
    // 12- and 13-channel ranges. And a 10-class head at 4 shards (2/3/2/3).
    for classes in [100usize, 10] {
        let net = NetGraph::new(
            "fc-only",
            classes,
            vec![NetLayer {
                kind: LayerKind::Fc { k: 32 * 32 * 3, n: classes, name: "fc".into() },
                input: 0,
                residual_from: None,
            }],
        )
        .unwrap();
        let input = test_input();
        let sched = PrecisionMap::uniform(W2A2);
        let single = single_core_logits(&net, &sched, &input);
        let golden = run_golden(&net, &sched, Some(&input));
        assert_eq!(&single, golden.maps.last().unwrap());
        for shards in [4usize, 8] {
            let sharded = cluster_logits(&net, &sched, &input, shards);
            assert_eq!(sharded, single, "{classes} classes over {shards} shards");
        }
    }
}

#[test]
fn one_shard_cluster_cycles_equal_single_core_exactly_full_resnet18() {
    // Acceptance: reported cluster cycles at N = 1 equal single-core cycles
    // exactly — on the full ResNet-18 graph (TimingOnly; the cycle model is
    // data-independent).
    let net = zoo::model("resnet18-cifar@100").unwrap();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);

    let prog = compile(&net, &machine, &sched).unwrap();
    let mut sim = Sim::new(machine.clone());
    sim.set_mode(SimMode::TimingOnly);
    let base = sim.alloc(prog.mem_len());
    let single = sim.execute(&prog, base).cycles;

    let cluster = compile_cluster(&net, &machine, &sched, 1).unwrap();
    let t = cluster_timing(&cluster, &machine);
    assert_eq!(t.sync_cycles, 0, "one core has no all-gather");
    assert_eq!(
        t.total_cycles(),
        single,
        "a 1-shard cluster must report exactly the single-core cycles"
    );
    assert_eq!(t.shard_cycles, vec![single]);
}

#[test]
fn one_shard_cluster_cycles_equal_single_core_all_schedules_on_head() {
    let net = resnet_head();
    let machine = MachineConfig::quark(4);
    for (label, sched) in schedules(&net) {
        let prog = compile(&net, &machine, &sched).unwrap();
        let mut sim = Sim::new(machine.clone());
        sim.set_mode(SimMode::TimingOnly);
        let base = sim.alloc(prog.mem_len());
        let single = sim.execute(&prog, base).cycles;
        let t = cluster_timing(&compile_cluster(&net, &machine, &sched, 1).unwrap(), &machine);
        assert_eq!(t.total_cycles(), single, "N=1 cycle identity under {label}");
        assert_eq!(t.sync_cycles, 0);
    }
}

#[test]
fn modeled_latency_scales_down_with_shards() {
    // Strong scaling on the head: each doubling of cores must reduce the
    // modeled latency (the MAC phase parallelizes; im2col/packing and the
    // all-gather bound the win — the full-net ≥1.6x@4 acceptance bound is
    // asserted by benches/cluster_scaling.rs in release mode).
    let net = resnet_head();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let totals: Vec<(usize, u64, u64)> = [1usize, 2, 4]
        .iter()
        .map(|&n| {
            let t = cluster_timing(&compile_cluster(&net, &machine, &sched, n).unwrap(), &machine);
            (n, t.total_cycles(), t.sync_cycles)
        })
        .collect();
    assert!(totals[1].1 < totals[0].1, "2 shards must beat 1: {totals:?}");
    assert!(totals[2].1 < totals[1].1, "4 shards must beat 2: {totals:?}");
    assert_eq!(totals[0].2, 0);
    assert!(totals[2].2 > 0, "sharded layers must charge sync cycles");
    // Sync exists but must not dominate at this scale.
    let t4 = cluster_timing(&compile_cluster(&net, &machine, &sched, 4).unwrap(), &machine);
    assert!(t4.sync_fraction() > 0.0 && t4.sync_fraction() < 0.5, "{}", t4.sync_fraction());
    // Per-layer aggregation invariants: totals are the sums of the rows.
    assert_eq!(t4.compute_cycles, t4.layers.iter().map(|l| l.compute_cycles).sum::<u64>());
    assert_eq!(t4.sync_cycles, t4.layers.iter().map(|l| l.sync_cycles).sum::<u64>());
    // Replicated layers (pool) charge no sync; sharded convs do.
    let pool = t4.layers.iter().find(|l| l.name == "avgpool").unwrap();
    assert_eq!(pool.sync_cycles, 0);
    let c1 = t4.layers.iter().find(|l| l.name == "conv1_s1b1a").unwrap();
    assert!(c1.sync_cycles > 0);
}

#[test]
fn cluster_inference_is_repeatable_on_persistent_cores() {
    // Worker-style reuse: repeat inferences on one ClusterCores pool are
    // deterministic in the input and sensitive to it.
    let net = resnet_head();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let cluster = compile_cluster(&net, &machine, &sched, 2).unwrap();
    let mut cores = ClusterCores::new(&machine, 2);
    let input = test_input();
    let a = cores.infer(&cluster, &input).logits;
    let b = cores.infer(&cluster, &input).logits;
    assert_eq!(a, b, "repeat cluster inference must be deterministic");
    let other: Vec<u8> = input.iter().map(|&v| v ^ 0x55).collect();
    let c = cores.infer(&cluster, &other).logits;
    assert_ne!(a, c, "different inputs must produce different logits");
    assert_eq!(a.len(), 100);
}

#[test]
#[ignore = "full-graph functional differential; run with --release --ignored"]
fn full_resnet18_sharded_logits_bit_exact() {
    // The unabridged acceptance run: full ResNet-18, shard counts {1, 2, 4},
    // all three schedules, vs single-core replay and the i128 golden.
    run_functional_differential(&zoo::model("resnet18-cifar@100").unwrap(), &[1, 2, 4]);
}
