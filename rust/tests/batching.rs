//! The oracle suite continuous batching is judged against:
//! [`Sim::execute_lowered_batch`] (one arena, one image application, one
//! pass of fused micro-ops per batch element) must be indistinguishable
//! from B independent [`Sim::execute_lowered`] single-request replays and
//! from the naive-i128 host golden model —
//!
//! * **bit-exact logits** for every `nn::zoo` entry at {w2a2, w1a1, mixed,
//!   int8} schedules, at batch sizes **B ∈ {1, 4, 16}**,
//! * at **relocated base addresses** (two fresh bases plus a worker-style
//!   dirty-arena replay),
//! * and against **cluster sharding** at {1, 2} shards — the tensor-
//!   parallel path must gather exactly what every batch element produced.
//!
//! Batch inputs cycle through 4 distinct images, so a B=16 run doubles as
//! a determinism check: elements 4..16 re-run earlier inputs over an arena
//! dirtied by the intervening ones and must reproduce their logits. Deep
//! graphs run on `Full`-mode-affordable prefixes ([`zoo::model_head`] /
//! 10-class variants) — the same trade `rust/tests/lowered_differential.rs`
//! makes.

use quark::arch::MachineConfig;
use quark::cluster::{compile_cluster, ClusterCores};
use quark::nn::golden::run_golden;
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::{zoo, NetGraph};
use quark::program::compile;
use quark::sim::Sim;

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

/// Batch element `k`'s input image: a distinct deterministic pattern per
/// `k` (k = 0 matches no other suite's input, so cross-suite cache effects
/// cannot mask a bug).
fn test_input(k: usize) -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + 5 + k * 37) % 251) as u8).collect()
}

/// Number of distinct images per combination; larger batches cycle.
const DISTINCT: usize = 4;

/// Every registered model at a `Full`-mode-affordable profile: shallow
/// graphs whole (10-class variants keep the classifier small), deep ResNets
/// as a stem + first-residual-block head.
fn affordable_zoo() -> Vec<NetGraph> {
    zoo::entries()
        .iter()
        .map(|e| match e.name {
            "resnet18-cifar" => zoo::model_head("resnet18-cifar@10", 4).unwrap(),
            "resnet34-cifar" => zoo::model_head("resnet34-cifar@10", 3).unwrap(),
            name => zoo::model(&format!("{name}@10")).unwrap(),
        })
        .collect()
}

/// The acceptance schedule matrix: uniform w2a2 / w1a1 / int8 plus the
/// registry's mixed schedule for this graph.
fn schedules(net: &NetGraph) -> Vec<(&'static str, PrecisionMap)> {
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("w1a1", PrecisionMap::uniform(W1A1)),
        ("mixed", zoo::mixed_schedule(net)),
        ("int8", PrecisionMap::uniform(Precision::Int8)),
    ]
}

/// Reference logits for the `DISTINCT` images: each one checked against the
/// i128 golden model through an independent single-request lowered replay.
fn reference_logits(
    net: &NetGraph,
    sched: &PrecisionMap,
    prog: &quark::program::CompiledProgram,
    ctx: &str,
) -> Vec<Vec<u8>> {
    (0..DISTINCT)
        .map(|k| {
            let input = test_input(k);
            let golden = run_golden(net, sched, Some(&input));
            let mut sim = Sim::new(MachineConfig::quark(4));
            let base = sim.alloc(prog.mem_len());
            let run = sim.execute_lowered(prog, base, Some(&input));
            let logits = sim.read_u8s(run.out_addr, run.out_elems);
            assert_eq!(
                logits,
                golden.maps[net.len()],
                "{ctx}: single-request replay diverges from the i128 golden (input {k})"
            );
            logits
        })
        .collect()
}

#[test]
fn batched_replay_matches_singles_and_golden_across_the_zoo() {
    for net in affordable_zoo() {
        for (label, sched) in schedules(&net) {
            let ctx = format!("{} under {label}", net.name());
            let prog = compile(&net, &MachineConfig::quark(4), &sched)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let refs = reference_logits(&net, &sched, &prog, &ctx);

            // One shared arena serves every batch size — later batches run
            // over memory dirtied by earlier ones, like a warm worker.
            let inputs: Vec<Vec<u8>> = (0..DISTINCT).map(test_input).collect();
            let mut sim = Sim::new(MachineConfig::quark(4));
            let base = sim.alloc(prog.mem_len());
            for b in [1usize, 4, 16] {
                let views: Vec<&[u8]> =
                    (0..b).map(|j| inputs[j % DISTINCT].as_slice()).collect();
                let batch = sim.execute_lowered_batch(&prog, base, &views);
                assert_eq!(batch.outputs.len(), b, "{ctx}: batch {b} output count");
                assert_eq!(batch.out_elems, refs[0].len(), "{ctx}: batch {b} logit width");
                for (j, out) in batch.outputs.iter().enumerate() {
                    assert_eq!(
                        out,
                        &refs[j % DISTINCT],
                        "{ctx}: batch {b} element {j} diverges from its single-request run"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_replay_relocates_bit_exactly_at_two_bases() {
    let net = zoo::model("tiny@10").unwrap();
    let sched = zoo::mixed_schedule(&net);
    let prog = compile(&net, &MachineConfig::quark(4), &sched).unwrap();
    let refs = reference_logits(&net, &sched, &prog, "tiny@10 under mixed");
    let inputs: Vec<Vec<u8>> = (0..DISTINCT).map(test_input).collect();
    let views: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();

    // Base A: the compile-time base (fresh sim, first allocation).
    let mut sim_a = Sim::new(MachineConfig::quark(4));
    let base_a = sim_a.alloc(prog.mem_len());
    let run_a = sim_a.execute_lowered_batch(&prog, base_a, &views);
    assert_eq!(run_a.outputs, refs);

    // Base B: shifted by a padding allocation — every resolved micro-op
    // address must follow the delta.
    let mut sim_b = Sim::new(MachineConfig::quark(4));
    sim_b.alloc(1 << 16);
    let base_b = sim_b.alloc(prog.mem_len());
    assert_ne!(base_a, base_b, "test must exercise a real relocation");
    let run_b = sim_b.execute_lowered_batch(&prog, base_b, &views);
    assert_eq!(
        run_b.out_addr,
        run_a.out_addr + (base_b - base_a),
        "reported output address must follow the relocation delta"
    );
    assert_eq!(run_b.outputs, refs);

    // Worker-style reuse of a dirty arena at yet another base.
    let base_c = sim_b.alloc(prog.mem_len());
    let run_c = sim_b.execute_lowered_batch(&prog, base_c, &views);
    assert_eq!(run_c.outputs, refs);
}

#[test]
fn batched_replay_matches_cluster_shards() {
    let net = zoo::model_head("quarknet@10", 4).unwrap();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let prog = compile(&net, &machine, &sched).unwrap();
    let inputs: Vec<Vec<u8>> = (0..DISTINCT).map(test_input).collect();
    let views: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();

    // Single-core batched logits — the reference.
    let mut sim = Sim::new(machine.clone());
    let base = sim.alloc(prog.mem_len());
    let batch = sim.execute_lowered_batch(&prog, base, &views);

    for shards in [1usize, 2] {
        let cluster = compile_cluster(&net, &machine, &sched, shards).unwrap();
        let mut cores = ClusterCores::new(&machine, shards);
        for (j, input) in inputs.iter().enumerate() {
            assert_eq!(
                cores.infer(&cluster, input).logits,
                batch.outputs[j],
                "cluster at {shards} shard(s) must gather batch element {j}'s logits"
            );
        }
    }
}
