//! Ablations over the timing-model design choices DESIGN.md calls out:
//! dispatch-queue depth, chaining, mask-unit throughput, AXI width. Each
//! test perturbs one structural parameter and asserts the *direction* of the
//! effect — the cycle model must respond to its knobs the way the hardware
//! argument says it should.

use quark::arch::MachineConfig;
use quark::kernels::bitpack::setup_index_vector;
use quark::kernels::conv2d::bitserial_block;
use quark::kernels::matmul::{matmul_bitserial, matmul_int8};
use quark::kernels::requantize::RqBuf;
use quark::quant::pack_weight_planes;
use quark::sim::{Sim, SimMode};

fn bitserial_cycles(cfg: MachineConfig, bits: u8, use_vbp: bool) -> u64 {
    let (m, k, n) = (16, 576, 64);
    let mut sim = Sim::with_memory(cfg, 32 << 20);
    sim.set_mode(SimMode::TimingOnly);
    let idx = setup_index_vector(&mut sim);
    let wpk = pack_weight_planes(&vec![1u8; k * n], k, n, bits, bitserial_block(sim.cfg.vlen_bits, n));
    let a = sim.alloc((m * k) as u64);
    let w = sim.alloc(wpk.byte_len() as u64);
    let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((m * n) as u64);
    matmul_bitserial(&mut sim, m, k, n, bits, a, &wpk, w, &rq, out, use_vbp, idx);
    sim.cycles()
}

fn int8_cycles(cfg: MachineConfig) -> u64 {
    let (m, k, n) = (16, 576, 64);
    let mut sim = Sim::with_memory(cfg, 32 << 20);
    sim.set_mode(SimMode::TimingOnly);
    let a = sim.alloc((m * k) as u64);
    let w = sim.alloc((k * n) as u64);
    let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((m * n) as u64);
    matmul_int8(&mut sim, m, k, n, a, w, &rq, out);
    sim.cycles()
}

#[test]
fn deeper_dispatch_queue_helps_until_it_doesnt() {
    // The scalar requant stream overlaps vector compute through the queue:
    // depth 1 serializes hard; depth 8 ≈ depth 64 (compute becomes the bound).
    let cy = |d: usize| {
        let mut cfg = MachineConfig::quark(4);
        cfg.vq_depth = d;
        bitserial_cycles(cfg, 2, true)
    };
    let d1 = cy(1);
    let d8 = cy(8);
    let d64 = cy(64);
    assert!(d1 > d8, "queue depth 1 must hurt: {d1} vs {d8}");
    let saturation = (d8 as f64 - d64 as f64) / d8 as f64;
    assert!(saturation < 0.10, "depth 8 should be near saturation ({d8} vs {d64})");
}

#[test]
fn chaining_matters() {
    // Removing chaining (consumers wait for full producer completion —
    // modeled by a huge chain latency) must slow the bit-serial inner loop.
    let mut cfg = MachineConfig::quark(4);
    let base = bitserial_cycles(cfg.clone(), 2, true);
    cfg.chain_latency = 10_000; // effectively "no chaining"
    let nochain = bitserial_cycles(cfg, 2, true);
    assert!(
        nochain as f64 > base as f64 * 1.2,
        "no-chaining should cost ≥20%: {base} → {nochain}"
    );
}

#[test]
fn mask_unit_speed_only_affects_the_novbitpack_path() {
    // The pure-RVV pack path serializes on vredsum/slow units, but neither
    // path touches the MASKU in the final kernels; a faster mask unit must
    // not change anything (guards against accidental mask-unit routing).
    let mut fast = MachineConfig::quark(4);
    fast.mask_elems_per_lane_cycle = 64.0;
    let slow_vbp = bitserial_cycles(MachineConfig::quark(4), 2, true);
    let fast_vbp = bitserial_cycles(fast.clone(), 2, true);
    assert_eq!(slow_vbp, fast_vbp, "vbitpack path must not touch the mask unit");
}

#[test]
fn int8_moves_far_more_weight_bytes_per_mac_than_bitserial() {
    // The roofline argument of Fig. 4: sub-byte weights shrink traffic per
    // MAC substantially (activation-side im2col traffic is shared, so the
    // end-to-end ratio lands near 2x rather than the raw 8x). Measure actual
    // vector-load bytes.
    let traffic = |bits: Option<u8>| -> f64 {
        let (m, k, n) = (16, 576, 64);
        let cfg = if bits.is_some() { MachineConfig::quark(4) } else { MachineConfig::ara(4) };
        let mut sim = Sim::with_memory(cfg, 32 << 20);
        sim.set_mode(SimMode::TimingOnly);
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        let before = sim.stats().clone();
        match bits {
            Some(b) => {
                let idx = setup_index_vector(&mut sim);
                let wpk = pack_weight_planes(
                    &vec![1u8; k * n], k, n, b, bitserial_block(sim.cfg.vlen_bits, n),
                );
                let a = sim.alloc((m * k) as u64);
                let w = sim.alloc(wpk.byte_len() as u64);
                matmul_bitserial(&mut sim, m, k, n, b, a, &wpk, w, &rq, out, true, idx);
            }
            None => {
                let a = sim.alloc((m * k) as u64);
                let w = sim.alloc((k * n) as u64);
                matmul_int8(&mut sim, m, k, n, a, w, &rq, out);
            }
        }
        let d = sim.stats().delta_since(&before);
        d.vload_bytes as f64 / d.effective_macs as f64
    };
    let int8 = traffic(None);
    let w1a1 = traffic(Some(1));
    assert!(
        int8 > 2.0 * w1a1,
        "int8 should stream ≫ more weight bytes/MAC: {int8:.4} vs {w1a1:.4}"
    );
}

#[test]
fn eight_lanes_speed_up_a_vector_bound_conv() {
    // On a wide, vector-bound layer, doubling lanes must pay off clearly;
    // the scalar requant bounds the gain well below 2x.
    use quark::kernels::conv2d::conv2d_bitserial;
    use quark::kernels::Conv2dParams;
    let cy = |lanes: usize| {
        let p = Conv2dParams { h: 8, w: 8, c_in: 256, c_out: 256, kh: 3, kw: 3, stride: 1, pad: 1 };
        let mut sim = Sim::with_memory(MachineConfig::quark(lanes), 64 << 20);
        sim.set_mode(SimMode::TimingOnly);
        let idx = setup_index_vector(&mut sim);
        let (k, n) = (p.k(), p.c_out);
        let wpk =
            pack_weight_planes(&vec![1u8; k * n], k, n, 2, bitserial_block(sim.cfg.vlen_bits, n));
        let fm = sim.alloc((p.h * p.w * p.c_in) as u64);
        let w = sim.alloc(wpk.byte_len() as u64);
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((p.out_h() * p.out_w() * n) as u64);
        conv2d_bitserial(&mut sim, &p, 2, fm, &wpk, w, &rq, out, None, true, idx);
        sim.cycles()
    };
    let g = cy(4) as f64 / cy(8) as f64;
    assert!(g > 1.25, "8 lanes must clearly help a 256-channel conv: {g:.2}");
    assert!(g < 2.0, "scalar requant bounds the gain below 2x: {g:.2}");
}

#[test]
fn startup_latency_hurts_short_vectors_most() {
    let cy = |startup: u64, n: usize| {
        let mut cfg = MachineConfig::quark(4);
        cfg.vstartup_latency = startup;
        let (m, k) = (4, 128);
        let mut sim = Sim::with_memory(cfg, 16 << 20);
        sim.set_mode(SimMode::TimingOnly);
        let idx = setup_index_vector(&mut sim);
        let wpk =
            pack_weight_planes(&vec![1u8; k * n], k, n, 2, bitserial_block(sim.cfg.vlen_bits, n));
        let a = sim.alloc((m * k) as u64);
        let w = sim.alloc(wpk.byte_len() as u64);
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_bitserial(&mut sim, m, k, n, 2, a, &wpk, w, &rq, out, true, idx);
        sim.cycles()
    };
    // Relative cost of +16 cycles startup must be larger for n=16 than n=64.
    let small = cy(20, 16) as f64 / cy(4, 16) as f64;
    let large = cy(20, 64) as f64 / cy(4, 64) as f64;
    assert!(small > large, "startup should tax short vectors more: {small:.3} vs {large:.3}");
}
