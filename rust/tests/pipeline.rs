//! Differential proof of the pipeline-parallel subsystem
//! ([`quark::cluster::pipeline`]): staging one model across N simulated
//! Quark cores connected by bounded activation queues is *functionally
//! invisible* —
//!
//! * **bit-exact logits** at stage counts {1, 2, 4}, for w2a2, a genuine
//!   mixed sub-byte/int8 schedule, and uniform int8, against both the
//!   single-core [`CompiledProgram`] replay and the naive-i128 host golden
//!   model — on the `attn-tiny` attention surrogate, the (fast-profile)
//!   `quarknet` conv stack, and the ResNet-18 head with its residual
//!   blocks;
//! * **streams preserve order**: several distinct requests pushed through
//!   the queues come back as each input's own single-core logits, in
//!   submission order;
//! * **identity at N = 1**: a 1-stage pipeline is emission-identical to
//!   the plain [`compile`] (same trace, image, and arena) and the timing
//!   model reports exactly the single-core replay's cycles with zero hops;
//! * **the latency law**: fill = Σ stage effective cycles, period =
//!   max stage, total(tokens) = fill + (tokens − 1) · period, and deeper
//!   pipelines never raise the period on the uniform stack.
//!
//! The graph selection mirrors `tests/cluster.rs`: full `attn-tiny` (23
//! small GEMMs — cheap), the truncated `--fast` quarknet profile, and the
//! locally-rebuilt ResNet-18 head (stem + stage-1 block + stage-2
//! downsampling block + pool + FC) so residual-indivisibility and every
//! re-pack boundary are exercised at `Full`-mode-affordable scale. The
//! full-graph functional differential is `#[ignore]`d (release mode
//! recommended: `cargo test --release --test pipeline -- --ignored`).

use quark::arch::MachineConfig;
use quark::cluster::{compile_pipeline, pipeline_timing, PipelineCores};
use quark::kernels::Conv2dParams;
use quark::nn::golden::run_golden;
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::resnet::resnet18_mixed_schedule;
use quark::nn::{zoo, ConvLayer, LayerKind, NetGraph, NetLayer};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };

const STAGE_COUNTS: [usize; 3] = [1, 2, 4];

fn conv(
    name: &str,
    h: usize,
    c_in: usize,
    c_out: usize,
    ksz: usize,
    stride: usize,
    relu: bool,
    residual: bool,
    quantized: bool,
) -> ConvLayer {
    ConvLayer {
        name: name.into(),
        params: Conv2dParams {
            h,
            w: h,
            c_in,
            c_out,
            kh: ksz,
            kw: ksz,
            stride,
            pad: if ksz == 3 { 1 } else { 0 },
        },
        relu,
        residual,
        quantized,
    }
}

/// ResNet-18 head at 16×16 — the same graph `tests/cluster.rs` builds:
/// stem, one stage-1 basic block (residual add), the stage-2 downsampling
/// block (1×1 stride-2 projection + stride-2 conv + residual), global
/// pool, 100-way FC. Both residual blocks are indivisible to the stage
/// partitioner, so 4 stages forces cuts at the only legal boundaries.
fn resnet_head() -> NetGraph {
    NetGraph::new(
        "resnet-head@100",
        100,
        vec![
            NetLayer {
                kind: LayerKind::Conv(conv("stem", 16, 3, 64, 3, 1, true, false, false)),
                input: 0,
                residual_from: None,
            },
            NetLayer {
                kind: LayerKind::Conv(conv("conv1_s1b1a", 16, 64, 64, 3, 1, true, false, true)),
                input: 1,
                residual_from: None,
            },
            NetLayer {
                kind: LayerKind::Conv(conv("conv2_s1b1b", 16, 64, 64, 3, 1, true, true, true)),
                input: 2,
                residual_from: Some(1),
            },
            NetLayer {
                kind: LayerKind::Conv(conv("conv3_ds_s2b1", 16, 64, 128, 1, 2, false, false, true)),
                input: 3,
                residual_from: None,
            },
            NetLayer {
                kind: LayerKind::Conv(conv("conv4_s2b1a", 16, 64, 128, 3, 2, true, false, true)),
                input: 3,
                residual_from: None,
            },
            NetLayer {
                kind: LayerKind::Conv(conv("conv5_s2b1b", 8, 128, 128, 3, 1, true, true, true)),
                input: 5,
                residual_from: Some(4),
            },
            NetLayer { kind: LayerKind::AvgPool { h: 8, w: 8, c: 128 }, input: 6, residual_from: None },
            NetLayer {
                kind: LayerKind::Fc { k: 128, n: 100, name: "fc".into() },
                input: 7,
                residual_from: None,
            },
        ],
    )
    .unwrap()
}

/// Deterministic distinct inputs for a streamed batch (seed 0 matches the
/// `tests/cluster.rs` single-request input).
fn stream_input(seed: usize) -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + seed * 17 + 5) % 251) as u8).collect()
}

/// The three acceptance schedules on a given graph. `mixed` must carry a
/// genuine sub-byte/int8 boundary: for conv graphs the generic zoo rule
/// (FC + stage-1/stem layers at int8) already does, but on an all-FC graph
/// that rule collapses to uniform int8, so attention-shaped nets pin their
/// embed/score/classifier GEMMs to int8 over a 2-bit default instead.
fn schedules(net: &NetGraph) -> Vec<(&'static str, PrecisionMap)> {
    let all_fc = net.iter().all(|l| matches!(l.kind, LayerKind::Fc { .. }));
    let mixed = if all_fc {
        let mut m = PrecisionMap::uniform(W2A2);
        for layer in net.iter() {
            if let LayerKind::Fc { name, .. } = &layer.kind {
                if name.as_str() == "embed" || name.as_str() == "fc" || name.ends_with("score") {
                    m.set(name, Precision::Int8);
                }
            }
        }
        m
    } else {
        resnet18_mixed_schedule(net)
    };
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("mixed", mixed),
        ("int8", PrecisionMap::uniform(Precision::Int8)),
    ]
}

/// Single-core reference: functional replay of the unstaged program.
fn single_core_logits(net: &NetGraph, sched: &PrecisionMap, input: &[u8]) -> Vec<u8> {
    let prog = compile(net, &MachineConfig::quark(4), sched).unwrap();
    let mut sim = Sim::new(MachineConfig::quark(4));
    let base = sim.alloc(prog.mem_len());
    let run = sim.execute_functional(&prog, base, Some(input));
    sim.read_u8s(run.out_addr, run.out_elems)
}

/// Stream `inputs` through an `n`-stage pipeline, returning per-request
/// logits in submission order.
fn pipeline_logits(
    net: &NetGraph,
    sched: &PrecisionMap,
    inputs: &[Vec<u8>],
    n: usize,
) -> Vec<Vec<u8>> {
    let machine = MachineConfig::quark(4);
    let pipeline = compile_pipeline(net, &machine, sched, n).unwrap();
    let mut cores = PipelineCores::new(&machine, n);
    cores.infer_stream(&pipeline, inputs).logits
}

/// The full differential: for every acceptance schedule, single-core
/// replay == i128 golden per input, and every stage count streams the
/// whole batch back bit-exactly in order.
fn run_functional_differential(net: &NetGraph, stage_counts: &[usize], stream: usize) {
    let inputs: Vec<Vec<u8>> = (0..stream).map(stream_input).collect();
    for (label, sched) in schedules(net) {
        let singles: Vec<Vec<u8>> =
            inputs.iter().map(|inp| single_core_logits(net, &sched, inp)).collect();
        for (inp, single) in inputs.iter().zip(&singles) {
            let golden = run_golden(net, &sched, Some(inp));
            assert_eq!(
                single,
                golden.maps.last().unwrap(),
                "single-core replay diverges from the i128 golden under {label}"
            );
        }
        for &n in stage_counts {
            let piped = pipeline_logits(net, &sched, &inputs, n);
            assert_eq!(
                piped, singles,
                "{n}-stage streamed logits diverge from per-request single-core \
                 replay under {label} on {}",
                net.name()
            );
        }
    }
}

#[test]
fn attn_tiny_streams_bit_exact_logits_at_every_stage_count() {
    // Full 23-GEMM stack, 3 distinct requests in flight.
    let net = zoo::model("attn-tiny").unwrap();
    run_functional_differential(&net, &STAGE_COUNTS, 3);
}

#[test]
fn quarknet_streams_bit_exact_logits_at_every_stage_count() {
    // The registry's --fast truncation (stem + 3 quantized convs) — the
    // same affordability trade the bench and `repro verify --fast` make.
    let net = zoo::model_profile("quarknet", true).unwrap();
    run_functional_differential(&net, &STAGE_COUNTS, 2);
}

#[test]
fn resnet_head_streams_bit_exact_logits_across_residual_blocks() {
    run_functional_differential(&resnet_head(), &STAGE_COUNTS, 2);
}

#[test]
fn one_stage_pipeline_is_emission_identical_and_cycle_exact() {
    let machine = MachineConfig::quark(4);
    for net in [zoo::model_profile("quarknet", true).unwrap(), resnet_head()] {
        for (label, sched) in schedules(&net) {
            let single = compile(&net, &machine, &sched).unwrap();
            let pipeline = compile_pipeline(&net, &machine, &sched, 1).unwrap();
            let stage = &pipeline.stage_programs()[0];
            assert_eq!(stage.trace_len(), single.trace_len(), "{label}: trace diverges");
            assert_eq!(stage.image_bytes(), single.image_bytes(), "{label}: image diverges");
            assert_eq!(stage.mem_len(), single.mem_len(), "{label}: arena diverges");
            assert_eq!(stage.out_elems(), single.out_elems());

            let mut sim = Sim::new(machine.clone());
            sim.set_mode(SimMode::TimingOnly);
            let base = sim.alloc(single.mem_len());
            let cycles = sim.execute(&single, base).cycles;

            let t = pipeline_timing(&pipeline, &machine, 1);
            assert_eq!(t.stages.len(), 1);
            assert_eq!(t.stages[0].hop_cycles, 0, "{label}: a 1-stage pipeline has no hop");
            assert_eq!(
                t.total_cycles(),
                cycles,
                "{label}: 1-stage pipeline timing must equal the single-core replay"
            );
            assert_eq!(t.fill_cycles(), t.period_cycles(), "one stage: fill == period");
        }
    }
}

#[test]
fn timing_model_obeys_the_fill_period_law() {
    let machine = MachineConfig::quark(4);
    let net = zoo::model("attn-tiny").unwrap();
    let sched = PrecisionMap::uniform(W2A2);
    let mut periods = Vec::new();
    for n in STAGE_COUNTS {
        let pipeline = compile_pipeline(&net, &machine, &sched, n).unwrap();
        let t1 = pipeline_timing(&pipeline, &machine, 1);
        let t16 = pipeline_timing(&pipeline, &machine, 16);
        // total(tokens) = fill + (tokens − 1) · period, exactly.
        assert_eq!(t1.total_cycles(), t1.fill_cycles());
        assert_eq!(
            t16.total_cycles(),
            t16.fill_cycles() + 15 * t16.period_cycles(),
            "{n} stages: stream total must follow the fill/period law"
        );
        assert!(t16.fill_cycles() >= t16.period_cycles(), "fill covers every stage");
        // Per-stage conservation: busy + bubble == total.
        let total = t16.total_cycles();
        for (b, i) in t16.busy_cycles().into_iter().zip(t16.bubble_cycles()) {
            assert_eq!(b + i, total, "{n} stages: busy/bubble conservation");
        }
        if n > 1 {
            let hops: u64 = t16.stages.iter().map(|s| s.hop_cycles).sum();
            assert!(hops > 0, "{n} stages: activation hand-offs are not free");
        }
        periods.push(t16.period_cycles());
    }
    // Deeper pipelines shorten the steady-state period on the uniform
    // stack (the whole point of the mode).
    assert!(
        periods.windows(2).all(|w| w[1] < w[0]),
        "period must fall as stages split the uniform stack: {periods:?}"
    );
}

/// Full-graph functional differential (multi-second in debug builds):
/// `cargo test --release --test pipeline -- --ignored`.
#[test]
#[ignore]
fn full_quarknet_streams_bit_exact_logits() {
    let net = zoo::model("quarknet").unwrap();
    run_functional_differential(&net, &STAGE_COUNTS, 2);
}
