//! Differential proof of the compile-once / run-many split: replaying a
//! [`quark::program::CompiledProgram`] is indistinguishable from fresh
//! kernel emission —
//!
//! * **bit-exact logits and feature maps** (every layer, `Full` mode),
//! * **exactly identical cycle counts and stats** (both `SimMode`s),
//! * across uniform and mixed precision schedules (incl. w1a1),
//! * at **relocated base addresses** (the artifact is position-independent),
//! * and [`Sim::execute_functional`] (the serving fast path, no timing
//!   scoreboard) produces the same memory effects as a timed replay — and
//!   the same codes as the naive-i128 host golden model.
//!
//! The net is the mixed-precision suite's ResNet basic block (stem →
//! projection + two 3×3 convs with residual → pool → FC): every layer kind,
//! every re-pack boundary, small enough for `Full`-mode runs in a test.

use quark::arch::MachineConfig;
use quark::kernels::Conv2dParams;
use quark::nn::golden::run_golden;
use quark::nn::model::{ModelRunner, Precision, PrecisionMap};
use quark::nn::{ConvLayer, LayerKind, NetGraph, NetLayer};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

const INT8: Precision = Precision::Int8;
const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

fn block_net() -> NetGraph {
    let conv = |name: &str,
                c_in: usize,
                ksz: usize,
                relu: bool,
                residual: bool,
                quantized: bool| ConvLayer {
        name: name.into(),
        params: Conv2dParams {
            h: 8,
            w: 8,
            c_in,
            c_out: 64,
            kh: ksz,
            kw: ksz,
            stride: 1,
            pad: if ksz == 3 { 1 } else { 0 },
        },
        relu,
        residual,
        quantized,
    };
    NetGraph::new(
        "replay-block@10",
        10,
        vec![
            NetLayer { kind: LayerKind::Conv(conv("stem", 3, 3, true, false, false)), input: 0, residual_from: None },
            NetLayer { kind: LayerKind::Conv(conv("proj", 64, 1, false, false, true)), input: 1, residual_from: None },
            NetLayer { kind: LayerKind::Conv(conv("c1", 64, 3, true, false, true)), input: 1, residual_from: None },
            NetLayer { kind: LayerKind::Conv(conv("c2", 64, 3, true, true, true)), input: 3, residual_from: Some(2) },
            NetLayer { kind: LayerKind::AvgPool { h: 8, w: 8, c: 64 }, input: 4, residual_from: None },
            NetLayer { kind: LayerKind::Fc { k: 64, n: 10, name: "fc".into() }, input: 5, residual_from: None },
        ],
    )
    .unwrap()
}

fn test_input() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + 5) % 251) as u8).collect()
}

/// The ≥5-schedule matrix: uniform w2a2 / w1a1 / int8 plus two mixed maps
/// covering every re-pack boundary (8→2, 2→8, 1-bit inside int8).
fn schedules() -> Vec<PrecisionMap> {
    vec![
        PrecisionMap::uniform(W2A2),
        PrecisionMap::uniform(W1A1),
        PrecisionMap::uniform(INT8),
        PrecisionMap::uniform(W2A2).with("c1", INT8),
        PrecisionMap::uniform(INT8).with("c2", W1A1),
    ]
}

#[test]
fn full_mode_replay_is_bit_and_cycle_exact_across_schedules() {
    let net = block_net();
    let input = test_input();
    for schedule in schedules() {
        // Fresh kernel emission — the reference.
        let mut fresh = Sim::new(MachineConfig::quark(4));
        fresh.set_mode(SimMode::Full);
        let want = ModelRunner::run_scheduled(&mut fresh, &net, &schedule, Some(&input));

        // Compile once, replay on a different Sim.
        let prog = compile(&net, &MachineConfig::quark(4), &schedule).unwrap();
        let mut replayed = Sim::new(MachineConfig::quark(4));
        replayed.set_mode(SimMode::Full);
        let base = replayed.alloc(prog.mem_len());
        let got = replayed.execute_with_input(&prog, base, Some(&input));

        assert_eq!(got.reports.len(), want.reports.len(), "{}", schedule.spec());
        for (g, w) in got.reports.iter().zip(want.reports.iter()) {
            let ctx = format!("layer {} under {}", w.name, schedule.spec());
            assert_eq!(g.name, w.name, "{ctx}");
            assert_eq!(g.precision, w.precision, "{ctx}");
            assert_eq!(g.run.cycles, w.run.cycles, "cycle divergence at {ctx}");
            assert_eq!(g.run.macs, w.run.macs, "{ctx}");
            assert_eq!(g.stats, w.stats, "stats divergence at {ctx}");
            assert_eq!(g.out_elems, w.out_elems, "{ctx}");
            // Bit-exact feature maps, every layer.
            assert_eq!(
                replayed.read_u8s(g.out_addr, g.out_elems),
                fresh.read_u8s(w.out_addr, w.out_elems),
                "feature-map divergence at {ctx}"
            );
        }
        assert_eq!(
            replayed.read_u8s(got.out_addr, got.out_elems),
            fresh.read_u8s(want.out_addr, want.out_elems),
            "logit divergence under {}",
            schedule.spec()
        );
    }
}

#[test]
fn timing_only_replay_matches_fresh_emission_cycles() {
    let net = block_net();
    for schedule in [PrecisionMap::uniform(W2A2), PrecisionMap::uniform(W2A2).with("fc", INT8)] {
        let mut fresh = Sim::new(MachineConfig::quark(4));
        fresh.set_mode(SimMode::TimingOnly);
        let want = ModelRunner::run_scheduled(&mut fresh, &net, &schedule, None);

        let prog = compile(&net, &MachineConfig::quark(4), &schedule).unwrap();
        let mut replayed = Sim::new(MachineConfig::quark(4));
        replayed.set_mode(SimMode::TimingOnly);
        let base = replayed.alloc(prog.mem_len());
        let got = replayed.execute(&prog, base);

        let want_total: u64 = want.reports.iter().map(|r| r.run.cycles).sum();
        assert_eq!(got.cycles, want_total, "total cycles under {}", schedule.spec());
        for (g, w) in got.reports.iter().zip(want.reports.iter()) {
            assert_eq!(g.run.cycles, w.run.cycles, "layer {} under {}", w.name, schedule.spec());
            assert_eq!(g.stats, w.stats, "layer {} under {}", w.name, schedule.spec());
        }
    }
}

#[test]
fn relocation_replays_bit_exactly_at_two_bases() {
    let net = block_net();
    let schedule = PrecisionMap::uniform(W2A2).with("c1", INT8);
    let input = test_input();
    let prog = compile(&net, &MachineConfig::quark(4), &schedule).unwrap();

    // Base A: the compile-time base (fresh sim, first allocation).
    let mut sim_a = Sim::new(MachineConfig::quark(4));
    sim_a.set_mode(SimMode::Full);
    let base_a = sim_a.alloc(prog.mem_len());
    let run_a = sim_a.execute_with_input(&prog, base_a, Some(&input));

    // Base B: shifted by a padding allocation (fresh timing state, so the
    // cycle comparison is exact, not just close).
    let mut sim_b = Sim::new(MachineConfig::quark(4));
    sim_b.set_mode(SimMode::Full);
    sim_b.alloc(1 << 16);
    let base_b = sim_b.alloc(prog.mem_len());
    assert_ne!(base_a, base_b, "test must exercise a real relocation");
    let run_b = sim_b.execute_with_input(&prog, base_b, Some(&input));

    assert_eq!(
        sim_a.read_u8s(run_a.out_addr, run_a.out_elems),
        sim_b.read_u8s(run_b.out_addr, run_b.out_elems),
        "relocated replay must produce identical logits"
    );
    for (a, b) in run_a.reports.iter().zip(run_b.reports.iter()) {
        assert_eq!(a.run.cycles, b.run.cycles, "layer {}", a.name);
        assert_eq!(
            sim_a.read_u8s(a.out_addr, a.out_elems),
            sim_b.read_u8s(b.out_addr, b.out_elems),
            "layer {}",
            a.name
        );
        assert_eq!(
            b.out_addr,
            a.out_addr + (base_b - base_a),
            "reported addresses must follow the relocation delta"
        );
    }

    // A third replay on sim_b at yet another base (worker-style reuse of a
    // dirty arena) still reproduces the same logits.
    let base_c = sim_b.alloc(prog.mem_len());
    let run_c = sim_b.execute_with_input(&prog, base_c, Some(&input));
    assert_eq!(
        sim_b.read_u8s(run_c.out_addr, run_c.out_elems),
        sim_a.read_u8s(run_a.out_addr, run_a.out_elems),
    );
}

#[test]
fn functional_replay_matches_timed_replay_and_host_golden() {
    let net = block_net();
    let schedule = PrecisionMap::uniform(W2A2).with("c1", INT8);
    let input = test_input();
    let prog = compile(&net, &MachineConfig::quark(4), &schedule).unwrap();

    // Timed Full replay — the reference values.
    let mut timed = Sim::new(MachineConfig::quark(4));
    timed.set_mode(SimMode::Full);
    let base = timed.alloc(prog.mem_len());
    let timed_run = timed.execute_with_input(&prog, base, Some(&input));

    // Functional replay (serving fast path): same memory effects, no timing.
    let mut func = Sim::new(MachineConfig::quark(4));
    let base = func.alloc(prog.mem_len());
    let func_run = func.execute_functional(&prog, base, Some(&input));
    assert_eq!(func_run.cycles, 0, "functional replay accounts no cycles");
    for (f, t) in func_run.reports.iter().zip(timed_run.reports.iter()) {
        assert_eq!(
            func.read_u8s(f.out_addr, f.out_elems),
            timed.read_u8s(t.out_addr, t.out_elems),
            "layer {}",
            t.name
        );
    }

    // And both agree with the naive-i128 host golden model, layer by layer.
    let golden = run_golden(&net, &schedule, Some(&input));
    for (i, f) in func_run.reports.iter().enumerate() {
        assert_eq!(
            func.read_u8s(f.out_addr, f.out_elems),
            golden.maps[i + 1],
            "layer {} diverges from the i128 golden model",
            f.name
        );
    }

    // Worker-style reuse: repeat replays on one dirty sim are deterministic
    // in the input, and sensitive to it.
    let again = func.execute_functional(&prog, base, Some(&input));
    assert_eq!(
        func.read_u8s(again.out_addr, again.out_elems),
        golden.maps[net.len()],
        "repeat replay must reproduce the same logits"
    );
    let other_input: Vec<u8> = input.iter().map(|&b| b ^ 0x55).collect();
    let other = func.execute_functional(&prog, base, Some(&other_input));
    assert_ne!(
        func.read_u8s(other.out_addr, other.out_elems),
        golden.maps[net.len()],
        "different inputs must produce different logits"
    );
}

#[test]
fn replay_rejects_wrong_machines_and_misaligned_bases() {
    let net = block_net();
    let schedule = PrecisionMap::uniform(W2A2);
    let prog = compile(&net, &MachineConfig::quark(4), &schedule).unwrap();

    // Wrong machine: the trace carries Quark custom ops; an Ara sim must be
    // rejected up front (fingerprint mismatch), not trap mid-replay.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sim = Sim::new(MachineConfig::ara(4));
        let base = sim.alloc(prog.mem_len());
        sim.execute(&prog, base);
    }));
    assert!(r.is_err(), "replay on the wrong machine must panic");

    // Lane-count change is also a different machine (VLEN changes vl).
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sim = Sim::new(MachineConfig::quark(8));
        let base = sim.alloc(prog.mem_len());
        sim.execute(&prog, base);
    }));
    assert!(r.is_err(), "replay on a different lane count must panic");

    // Misaligned base: allocation alignment is part of the contract.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sim = Sim::new(MachineConfig::quark(4));
        let base = sim.alloc(prog.mem_len());
        sim.execute(&prog, base + 1);
    }));
    assert!(r.is_err(), "replay at a misaligned base must panic");
}
