//! Shared test support: a small deterministic property-testing helper
//! (proptest is unavailable in this offline environment). `Gen` is a
//! splitmix64-based generator; `run_cases` reports the failing seed so
//! failures are reproducible.
//!
//! Included by several integration-test binaries; not every binary uses
//! every helper, so unused-item lints are silenced crate-locally.
#![allow(dead_code)]

pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.u64() % (hi - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Run `n` property cases with per-case seeds; panics include the seed.
pub fn run_cases(n: u64, mut f: impl FnMut(&mut Gen)) {
    for seed in 0..n {
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}
