//! Integration: whole-model execution on the simulated machines.

mod support;

use quark::arch::MachineConfig;
use quark::coordinator::demo_net;
use quark::nn::model::{ModelRunner, Precision};
use quark::nn::resnet::quantized_layers;
use quark::nn::zoo;
use quark::sim::{Sim, SimMode};

#[test]
fn demo_net_full_mode_produces_data_and_matches_timing_only() {
    let net = demo_net();
    let run = |mode: SimMode| {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.set_mode(mode);
        let reports = ModelRunner::run(&mut sim, &net, Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
        (reports.iter().map(|r| r.run.cycles).sum::<u64>(), reports.len())
    };
    let (full_cycles, n1) = run(SimMode::Full);
    let (timing_cycles, n2) = run(SimMode::TimingOnly);
    assert_eq!(n1, n2);
    assert_eq!(full_cycles, timing_cycles, "timing must be data-independent");
}

#[test]
fn resnet18_per_layer_ordering_matches_paper_shape() {
    // The Fig. 3 claims at whole-network granularity, on the real graph.
    let net = zoo::model("resnet18-cifar@100").unwrap();
    let total = |cfg: MachineConfig, prec: Precision| -> u64 {
        let mut sim = Sim::new(cfg);
        sim.set_mode(SimMode::TimingOnly);
        ModelRunner::run(&mut sim, &net, prec)
            .iter()
            .filter(|r| r.quantized)
            .map(|r| r.run.cycles)
            .sum()
    };
    let int8 = total(MachineConfig::ara(4), Precision::Int8);
    let fp32 = total(MachineConfig::ara(4), Precision::Fp32);
    let w1 = total(MachineConfig::quark(4), Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true });
    let w2 = total(MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    let w2n = total(MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: false });

    // Paper ordering: fp32 slowest, then int8; w2-no-vbitpack a bit better
    // than int8; w2 clearly better; w1 best.
    //
    // Known deviation (documented in EXPERIMENTS.md): on our Ara model both
    // int8 and fp32 sustain 2 elem/lane/cycle at SEW=32, so they land within
    // a few percent of each other instead of the paper's visible fp32 gap —
    // the sub-byte comparisons (the contribution) are unaffected.
    assert!(
        fp32 as f64 >= int8 as f64 * 0.80,
        "fp32 {fp32} should stay within ~20% of int8 {int8}"
    );
    assert!(w2n < int8, "w2a2-novbp {w2n} should edge out int8 {int8}");
    assert!(w2 < w2n, "vbitpack must help: {w2} vs {w2n}");
    assert!(w1 < w2, "1-bit must beat 2-bit: {w1} vs {w2}");
    // Magnitudes (loose): Int1 ≥ 3x, Int2 ≥ 2x over Int8.
    assert!(int8 as f64 / w1 as f64 > 3.0);
    assert!(int8 as f64 / w2 as f64 > 2.0);
}

#[test]
fn resnet18_has_twenty_quantized_kernels() {
    let net = zoo::model("resnet18-cifar@100").unwrap();
    assert_eq!(quantized_layers(&net).len(), 20);
}

#[test]
fn quark8_runs_the_full_model_faster_than_quark4() {
    let net = zoo::model("resnet18-cifar@100").unwrap();
    let total = |lanes: usize| -> u64 {
        let mut sim = Sim::new(MachineConfig::quark(lanes));
        sim.set_mode(SimMode::TimingOnly);
        ModelRunner::run(&mut sim, &net, Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true })
            .iter()
            .map(|r| r.run.cycles)
            .sum()
    };
    let q4 = total(4);
    let q8 = total(8);
    assert!(q8 < q4, "8 lanes must be faster: {q8} vs {q4}");
}
