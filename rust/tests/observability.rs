//! Observability invariants ([`quark::obs`]), in two families:
//!
//! * **Span conservation** (host clock): every request the coordinator
//!   admits leaves a complete, reconcilable lifecycle in the trace — one
//!   submit→queue→claim→reply chain per served request, one shared batch
//!   id (and one replay span) per single-core batch, terminal expire spans
//!   for dropped requests, and event counts that agree with `CoordStats`.
//! * **Attribution soundness** (simulated clock): the cycle attributor's
//!   per-layer and per-class sums equal the independent replay totals
//!   exactly — zoo-wide, across the acceptance schedules, single-core and
//!   sharded. No tolerance: timing is a pure function of the instruction
//!   stream, so any drift is a bug.

use std::sync::Arc;
use std::time::Duration;

use quark::arch::MachineConfig;
use quark::cluster::{cluster_timing, compile_cluster};
use quark::coordinator::{Coordinator, CoordinatorConfig, DegradePolicy, InferenceRequest};
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::zoo;
use quark::obs::{self, SpanKind, TraceEvent};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

fn small_cfg() -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 1;
    cfg.batch_size = 8;
    cfg.batch_timeout = Duration::from_millis(2);
    cfg
}

fn count(events: &[TraceEvent], kind: SpanKind, req: Option<u64>) -> usize {
    events.iter().filter(|e| e.kind == kind && (req.is_none() || e.req == req)).count()
}

#[test]
fn served_requests_leave_one_complete_lifecycle_chain_each() {
    let mut cfg = small_cfg();
    // A long fill window so the riders below are claimed as ONE batch.
    cfg.batch_timeout = Duration::from_millis(500);
    let coord = Coordinator::start(cfg);
    let tracer = coord.enable_tracing();

    // Occupy the single worker with a functional request so the riders
    // queue up behind it and get claimed together.
    let input = vec![7u8; 32 * 32 * 3];
    let blocker = coord
        .submit(InferenceRequest { id: 100, input: Some(input.clone()), ..Default::default() })
        .unwrap();
    while coord.stats().queue_depth > 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    let riders: Vec<_> = (0..3u64)
        .map(|id| {
            coord
                .submit(InferenceRequest { id, input: Some(input.clone()), ..Default::default() })
                .unwrap()
        })
        .collect();
    let blocker_resp =
        blocker.recv_timeout(Duration::from_secs(120)).expect("blocker answered").unwrap();
    let rider_resps: Vec<_> = riders
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(120)).expect("rider answered").unwrap())
        .collect();
    let batch_id = rider_resps[0].batch_id;
    assert!(
        rider_resps.iter().all(|r| r.batch_id == batch_id),
        "riders queued behind one blocker must be claimed as one batch"
    );
    assert_ne!(blocker_resp.batch_id, batch_id, "the blocker rode its own batch");

    let events = tracer.drain();
    // One complete chain per served request: submit → queue → claim → reply.
    for id in [100u64, 0, 1, 2] {
        for kind in [SpanKind::Submit, SpanKind::Queue, SpanKind::Claim, SpanKind::Reply] {
            assert_eq!(
                count(&events, kind, Some(id)),
                1,
                "request {id} must carry exactly one {} event",
                kind.name()
            );
        }
    }
    // Batched requests share one batch span: their queue/claim/reply events
    // all carry the shared batch id, and exactly one replay span does too.
    for e in events.iter().filter(|e| e.req.is_some_and(|id| id < 3)) {
        if e.kind != SpanKind::Submit {
            assert_eq!(e.batch, Some(batch_id), "{} of a rider", e.kind.name());
        }
    }
    let batch_replays: Vec<_> = events
        .iter()
        .filter(|e| e.kind == SpanKind::Replay && e.batch == Some(batch_id))
        .collect();
    assert_eq!(batch_replays.len(), 1, "one shared replay span per single-core batch");
    assert!(batch_replays[0].label.contains("n=3"), "{}", batch_replays[0].label);
    // Counts reconcile with the coordinator's own accounting.
    let stats = coord.stats();
    assert_eq!(count(&events, SpanKind::Reply, None) as u64, stats.served + stats.degraded);
    assert_eq!(count(&events, SpanKind::Submit, None), 4);
    assert_eq!(count(&events, SpanKind::Expire, None), 0);
    assert_eq!(stats.trace_dropped, 0, "nothing here should overflow a ring");
    // The first functional resolution of the default deployment also filled
    // the default profile (the serve trace's simulated track).
    let profiles: Vec<_> = coord.default_profiles().into_iter().flatten().collect();
    assert_eq!(profiles.len(), 1, "default-schedule timing miss captures the profile");
    assert_eq!(profiles[0].total_cycles, blocker_resp.sim_cycles, "profile == served timing");
    coord.shutdown();
}

#[test]
fn expired_and_degraded_requests_carry_matching_terminal_events() {
    let mut cfg = small_cfg();
    // depth 0: every eligible request degrades — deterministic.
    cfg.degrade = Some(DegradePolicy {
        schedule: PrecisionMap::uniform(Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true }),
        depth: 0,
    });
    let coord = Coordinator::start(cfg);
    let tracer = coord.enable_tracing();

    // deadline_ms=0 has always passed by claim time: deterministic expiry.
    let expired: Vec<_> = (0..4u64)
        .map(|id| {
            coord
                .submit(InferenceRequest { id, deadline_ms: Some(0), ..Default::default() })
                .unwrap()
        })
        .collect();
    for rx in expired {
        let res = rx.recv_timeout(Duration::from_secs(120)).expect("expiry answered");
        assert!(res.is_err(), "deadline_ms=0 must expire");
    }
    // An eligible probe degrades (nothing pinned, depth already exceeded).
    let rx = coord.submit(InferenceRequest { id: 50, ..Default::default() }).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    assert!(resp.degraded);

    let events = tracer.drain();
    for id in 0..4u64 {
        assert_eq!(count(&events, SpanKind::Expire, Some(id)), 1, "request {id}");
        assert_eq!(count(&events, SpanKind::Submit, Some(id)), 1, "request {id}");
        // Terminal means terminal: an expired request never reaches a
        // worker, so no queue/claim/reply events exist for it.
        for kind in [SpanKind::Queue, SpanKind::Claim, SpanKind::Reply] {
            assert_eq!(count(&events, kind, Some(id)), 0, "{} of expired {id}", kind.name());
        }
    }
    assert_eq!(count(&events, SpanKind::Expire, None) as u64, coord.stats().expired);
    // The degraded completion is visible end to end: degradation is decided
    // at admission, so the submit instant already carries the disposition,
    // and the reply instant confirms it.
    let submit = events
        .iter()
        .find(|e| e.kind == SpanKind::Submit && e.req == Some(50))
        .expect("degraded submit");
    assert_eq!(submit.label, "degraded");
    let reply = events
        .iter()
        .find(|e| e.kind == SpanKind::Reply && e.req == Some(50))
        .expect("degraded reply");
    assert_eq!(reply.label, "degraded");
    coord.shutdown();
}

#[test]
fn attribution_sums_equal_replay_totals_across_the_zoo() {
    let machine = MachineConfig::quark(4);
    let mut checked = 0usize;
    for entry in zoo::entries() {
        let net = zoo::model_profile(entry.name, true).expect("registry entries are valid");
        let scheds: Vec<(String, PrecisionMap)> = vec![
            ("w2a2".into(), PrecisionMap::parse("w2a2").unwrap()),
            ("w1a1".into(), PrecisionMap::parse("w1a1").unwrap()),
            ("mixed".into(), zoo::mixed_schedule(&net)),
            ("int8".into(), PrecisionMap::parse("int8").unwrap()),
        ];
        for (label, sched) in &scheds {
            // Single core: per-layer deltas must match an independent timed
            // replay layer for layer, and both class/layer sums its total.
            let Ok(prog) = compile(&net, &machine, sched) else {
                continue; // schedule not deployable on this model: skip
            };
            let profile = obs::profile_on_fresh_core(&prog, &machine);
            let mut sim = Sim::new(machine.clone());
            sim.set_mode(SimMode::TimingOnly);
            let base = sim.alloc(prog.mem_len());
            let run = sim.execute(&prog, base);
            let ctx = format!("{} · {label}", entry.name);
            assert_eq!(profile.total_cycles, run.cycles, "{ctx}: total");
            assert_eq!(profile.layers.len(), run.reports.len(), "{ctx}: layer count");
            for (l, r) in profile.layers.iter().zip(&run.reports) {
                assert_eq!(l.cycles, r.run.cycles, "{ctx}: layer {}", l.name);
                assert_eq!(l.macs, r.run.macs, "{ctx}: layer {} macs", l.name);
            }
            let layer_sum: u64 = profile.layers.iter().map(|l| l.cycles).sum();
            let class_sum: u64 = profile.class_cycles.iter().sum();
            assert_eq!(layer_sum, profile.total_cycles, "{ctx}: Σ layers");
            assert_eq!(class_sum, profile.total_cycles, "{ctx}: Σ classes");
            checked += 1;

            // Sharded: the profiled cluster fold must equal the serving
            // path's cluster timing model exactly.
            let Ok(cluster) = compile_cluster(&net, &machine, sched, 2) else {
                continue; // 2 shards not deployable here: skip
            };
            let cprofile = obs::profile_cluster(&cluster, &machine);
            let timing = cluster_timing(&cluster, &machine);
            assert_eq!(
                cprofile.timing.total_cycles(),
                timing.total_cycles(),
                "{ctx} · shards=2: total"
            );
            assert_eq!(cprofile.timing.sync_cycles, timing.sync_cycles, "{ctx} · shards=2: sync");
            let shard_class_sum: u64 = cprofile.class_cycles().iter().sum();
            let shard_total_sum: u64 = cprofile.shards.iter().map(|p| p.total_cycles).sum();
            assert_eq!(shard_class_sum, shard_total_sum, "{ctx} · shards=2: Σ classes");
            checked += 1;
        }
    }
    assert!(checked >= 8, "the sweep must actually cover deployments (got {checked})");
}

#[test]
fn batched_two_model_serve_run_exports_a_loadable_dual_domain_trace() {
    let mut cfg = small_cfg();
    cfg.models.push(Arc::new(zoo::model("mlp@10").unwrap()));
    let coord = Coordinator::start(cfg);
    let tracer = coord.enable_tracing();

    let input = vec![9u8; 32 * 32 * 3];
    let rxs: Vec<_> = (0..4u64)
        .map(|id| {
            let net = if id % 2 == 0 { None } else { Some("mlp@10".to_string()) };
            let req =
                InferenceRequest { id, net, input: Some(input.clone()), ..Default::default() };
            coord.submit(req).unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120)).expect("answered").unwrap();
    }

    let events = tracer.drain();
    let sims: Vec<_> = coord.default_profiles().into_iter().flatten().collect();
    assert_eq!(sims.len(), 2, "both deployed models resolved default timing");
    let json = obs::export::chrome_trace_json(&events, &sims);
    let n = obs::export::validate_chrome_trace(&json).expect("exported trace must parse");
    assert!(n >= events.len(), "host events all exported");
    // Both clock domains are present as separate process tracks.
    assert!(json.contains("host (wall clock"), "host process track");
    assert!(json.contains("sim (1 cycle ="), "sim process track");
    assert!(json.contains("\"cat\":\"sim-layer\""), "per-layer sim spans");
    assert!(json.contains("\"cat\":\"sim-class\""), "per-class sim spans");
    for p in &sims {
        assert!(json.contains(&format!("{} [{}] layers", p.model, p.schedule)), "{}", p.model);
    }
    // The folded view carries both domains too.
    let folded = obs::export::folded_stacks(&events, &sims);
    assert!(folded.lines().any(|l| l.starts_with("host;")), "{folded}");
    assert!(folded.lines().any(|l| l.starts_with("sim;")), "{folded}");
    coord.shutdown();
}
