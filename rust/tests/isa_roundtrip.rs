//! Property tests: encode→decode round-trips for randomly generated
//! instructions across the whole implemented ISA, including Quark's custom
//! ops in the custom-2 space.

mod support;

use quark::isa::decode::decode;
use quark::isa::encode::encode;
use quark::isa::instr::{AluOp, FAluOp, Instr, MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use quark::isa::reg::{FReg, Reg, VReg};
use quark::isa::vtype::{Lmul, Sew, VType};
use support::{run_cases, Gen};

fn reg(g: &mut Gen) -> Reg {
    Reg(g.usize(0, 31) as u8)
}

fn nz_reg(g: &mut Gen) -> Reg {
    Reg(g.usize(1, 31) as u8)
}

fn freg(g: &mut Gen) -> FReg {
    FReg(g.usize(0, 31) as u8)
}

fn vreg(g: &mut Gen) -> VReg {
    VReg(g.usize(0, 31) as u8)
}

fn imm12(g: &mut Gen) -> i64 {
    g.range(0, 4095) as i64 - 2048
}

fn sew(g: &mut Gen) -> Sew {
    *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64])
}

/// Generate an encodable scalar op (canonical form — see decode.rs docs).
fn scalar_op(g: &mut Gen) -> ScalarOp {
    match g.usize(0, 11) {
        0 => {
            // Canonical Li: nonzero rd or nonzero imm (addi x0,x0,0 is Nop).
            let rd = nz_reg(g);
            ScalarOp::Li { rd, imm: imm12(g) }
        }
        1 => {
            let op = *g.pick(&[
                AluOp::Add,
                AluOp::Sub,
                AluOp::And,
                AluOp::Or,
                AluOp::Xor,
                AluOp::Sll,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Mul,
                AluOp::Mulh,
                AluOp::Div,
                AluOp::Rem,
            ]);
            ScalarOp::Alu { op, rd: reg(g), rs1: reg(g), rs2: reg(g) }
        }
        2 => {
            // AluImm: rs1 must be nonzero (rs1=x0 is the Li alias).
            let op = *g.pick(&[AluOp::Add, AluOp::And, AluOp::Or, AluOp::Xor, AluOp::Slt, AluOp::Sltu]);
            ScalarOp::AluImm { op, rd: reg(g), rs1: nz_reg(g), imm: imm12(g) }
        }
        3 => {
            let op = *g.pick(&[AluOp::Sll, AluOp::Srl, AluOp::Sra]);
            ScalarOp::AluImm { op, rd: reg(g), rs1: nz_reg(g), imm: g.range(0, 63) as i64 }
        }
        4 => {
            let width = *g.pick(&[MemWidth::B, MemWidth::H, MemWidth::W, MemWidth::D]);
            // `ld` is canonically signed.
            let signed = if width == MemWidth::D { true } else { g.bool() };
            ScalarOp::Load { width, signed, rd: reg(g), base: reg(g), offset: imm12(g) }
        }
        5 => ScalarOp::Store {
            width: *g.pick(&[MemWidth::B, MemWidth::H, MemWidth::W, MemWidth::D]),
            rs2: reg(g),
            base: reg(g),
            offset: imm12(g),
        },
        6 => ScalarOp::Branch { taken: g.bool() },
        7 => ScalarOp::FLoad { rd: freg(g), base: reg(g), offset: imm12(g) },
        8 => ScalarOp::FStore { rs2: freg(g), base: reg(g), offset: imm12(g) },
        9 => {
            let op = *g.pick(&[FAluOp::Add, FAluOp::Sub, FAluOp::Mul, FAluOp::Div, FAluOp::Min, FAluOp::Max]);
            ScalarOp::FAlu { op, rd: freg(g), rs1: freg(g), rs2: freg(g) }
        }
        10 => ScalarOp::FMadd { rd: freg(g), rs1: freg(g), rs2: freg(g), rs3: freg(g) },
        _ => *g.pick(&[
            ScalarOp::FCvtWS { rd: Reg(3), rs1: FReg(4) },
            ScalarOp::FCvtSW { rd: FReg(5), rs1: Reg(6) },
            ScalarOp::FMvXW { rd: Reg(7), rs1: FReg(8) },
            ScalarOp::FMvWX { rd: FReg(9), rs1: Reg(10) },
            ScalarOp::CsrReadCycle { rd: Reg(11) },
            ScalarOp::Nop,
        ]),
    }
}

fn vector_op(g: &mut Gen) -> VOp {
    match g.usize(0, 13) {
        0 => VOp::Load {
            kind: if g.bool() { VMemKind::UnitStride } else { VMemKind::Strided { stride: reg(g) } },
            eew: sew(g),
            vd: vreg(g),
            base: reg(g),
        },
        1 => VOp::Store {
            kind: if g.bool() { VMemKind::UnitStride } else { VMemKind::Strided { stride: reg(g) } },
            eew: sew(g),
            vs3: vreg(g),
            base: reg(g),
        },
        2 => {
            let op = *g.pick(&[
                VIOp::Add,
                VIOp::Sub,
                VIOp::Rsub,
                VIOp::And,
                VIOp::Or,
                VIOp::Xor,
                VIOp::Sll,
                VIOp::Srl,
                VIOp::Sra,
                VIOp::Min,
                VIOp::Max,
                VIOp::Minu,
                VIOp::Maxu,
                VIOp::Mul,
                VIOp::Mulh,
            ]);
            VOp::IVV { op, vd: vreg(g), vs2: vreg(g), vs1: vreg(g) }
        }
        3 => {
            let op = *g.pick(&[VIOp::Add, VIOp::And, VIOp::Or, VIOp::Xor, VIOp::Mul, VIOp::Mulh]);
            // vs2 = v0 with funct6 010111 would alias vmv.v.x; avoid v0.
            VOp::IVX { op, vd: vreg(g), vs2: VReg(g.usize(1, 31) as u8), rs1: reg(g) }
        }
        4 => {
            let op = *g.pick(&[VIOp::Add, VIOp::Rsub, VIOp::And, VIOp::Or, VIOp::Xor]);
            VOp::IVI { op, vd: vreg(g), vs2: VReg(g.usize(1, 31) as u8), imm: g.range(0, 31) as i64 - 16 }
        }
        5 => VOp::MaccVX { vd: vreg(g), rs1: reg(g), vs2: vreg(g) },
        6 => VOp::MaccVV { vd: vreg(g), vs1: vreg(g), vs2: vreg(g) },
        7 => VOp::RedSum { vd: vreg(g), vs2: vreg(g), vs1: vreg(g) },
        8 => *g.pick(&[
            VOp::MvXS { rd: Reg(5), vs2: VReg(6) },
            VOp::MvSX { vd: VReg(7), rs1: Reg(8) },
            VOp::MvVX { vd: VReg(9), rs1: Reg(10) },
            VOp::MvVI { vd: VReg(11), imm: -3 },
        ]),
        9 => {
            let frac = *g.pick(&[2u8, 4, 8]);
            if g.bool() {
                VOp::Sext { vd: vreg(g), vs2: vreg(g), frac }
            } else {
                VOp::Zext { vd: vreg(g), vs2: vreg(g), frac }
            }
        }
        10 => {
            let imm = g.range(0, 31) as i64 - 16;
            if g.bool() {
                VOp::MseqVI { vd: vreg(g), vs2: vreg(g), imm }
            } else {
                VOp::MsneVI { vd: vreg(g), vs2: vreg(g), imm }
            }
        }
        11 => *g.pick(&[
            VOp::FMaccVF { vd: VReg(1), rs1: FReg(2), vs2: VReg(3) },
            VOp::FAddVV { vd: VReg(4), vs2: VReg(5), vs1: VReg(6) },
            VOp::FMulVF { vd: VReg(7), vs2: VReg(8), rs1: FReg(9) },
            VOp::FMaxVF { vd: VReg(10), vs2: VReg(11), rs1: FReg(12) },
            VOp::FRedSum { vd: VReg(13), vs2: VReg(14), vs1: VReg(15) },
        ]),
        12 => VOp::Popcnt { vd: vreg(g), vs2: vreg(g) },
        _ => {
            if g.bool() {
                VOp::Shacc { vd: vreg(g), vs2: vreg(g), shamt: g.range(0, 31) as u8 }
            } else {
                VOp::Bitpack { vd: vreg(g), vs2: vreg(g), bit: g.range(0, 31) as u8 }
            }
        }
    }
}

#[test]
fn scalar_roundtrip_property() {
    run_cases(2000, |g| {
        let i = Instr::Scalar(scalar_op(g));
        if let Some(word) = encode(&i) {
            assert_eq!(decode(word), Some(i), "word {word:#010x}");
        }
    });
}

#[test]
fn vector_roundtrip_property() {
    run_cases(2000, |g| {
        let i = Instr::Vector(vector_op(g));
        if let Some(word) = encode(&i) {
            assert_eq!(decode(word), Some(i), "word {word:#010x}");
        }
    });
}

#[test]
fn vsetivli_roundtrip_property() {
    run_cases(500, |g| {
        let i = Instr::VSetVli {
            rd: reg(g),
            avl: g.range(0, 31),
            vtype: VType::new(sew(g), *g.pick(&[Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8])),
        };
        let word = encode(&i).expect("vsetivli with avl<32 must encode");
        assert_eq!(decode(word), Some(i));
    });
}

#[test]
fn every_generated_instruction_is_encodable_often_enough() {
    // Encoding coverage: the generators above should produce an encodable
    // instruction nearly always (they are built to canonical forms).
    let mut total = 0u32;
    let mut encoded = 0u32;
    run_cases(1000, |g| {
        let i =
            if g.bool() { Instr::Scalar(scalar_op(g)) } else { Instr::Vector(vector_op(g)) };
        total += 1;
        if encode(&i).is_some() {
            encoded += 1;
        }
    });
    assert!(encoded as f64 / total as f64 > 0.95, "{encoded}/{total} encodable");
}

/// One canonical exemplar of every encodable instruction variant — the
/// deterministic complement of the random generators above, so a decode or
/// disassembly regression in any single opcode fails by name rather than
/// by seed.
fn exemplars() -> Vec<Instr> {
    let mut xs: Vec<Instr> = Vec::new();
    let s = Instr::Scalar;

    xs.push(s(ScalarOp::Li { rd: Reg(5), imm: -42 }));
    for op in [
        AluOp::Add,
        AluOp::Sub,
        AluOp::And,
        AluOp::Or,
        AluOp::Xor,
        AluOp::Sll,
        AluOp::Srl,
        AluOp::Sra,
        AluOp::Slt,
        AluOp::Sltu,
        AluOp::Mul,
        AluOp::Mulh,
        AluOp::Div,
        AluOp::Rem,
    ] {
        xs.push(s(ScalarOp::Alu { op, rd: Reg(1), rs1: Reg(2), rs2: Reg(3) }));
    }
    for op in [AluOp::Add, AluOp::And, AluOp::Or, AluOp::Xor, AluOp::Slt, AluOp::Sltu] {
        xs.push(s(ScalarOp::AluImm { op, rd: Reg(4), rs1: Reg(5), imm: -7 }));
    }
    for op in [AluOp::Sll, AluOp::Srl, AluOp::Sra] {
        xs.push(s(ScalarOp::AluImm { op, rd: Reg(6), rs1: Reg(7), imm: 9 }));
    }
    for width in [MemWidth::B, MemWidth::H, MemWidth::W] {
        xs.push(s(ScalarOp::Load { width, signed: true, rd: Reg(8), base: Reg(9), offset: 16 }));
        xs.push(s(ScalarOp::Load { width, signed: false, rd: Reg(8), base: Reg(9), offset: -16 }));
    }
    // `ld` is canonically signed.
    xs.push(s(ScalarOp::Load { width: MemWidth::D, signed: true, rd: Reg(10), base: Reg(11), offset: 0 }));
    for width in [MemWidth::B, MemWidth::H, MemWidth::W, MemWidth::D] {
        xs.push(s(ScalarOp::Store { width, rs2: Reg(12), base: Reg(13), offset: 24 }));
    }
    xs.push(s(ScalarOp::Branch { taken: true }));
    xs.push(s(ScalarOp::Branch { taken: false }));
    xs.push(s(ScalarOp::FLoad { rd: FReg(1), base: Reg(2), offset: 4 }));
    xs.push(s(ScalarOp::FStore { rs2: FReg(3), base: Reg(4), offset: -4 }));
    for op in [FAluOp::Add, FAluOp::Sub, FAluOp::Mul, FAluOp::Div, FAluOp::Min, FAluOp::Max] {
        xs.push(s(ScalarOp::FAlu { op, rd: FReg(5), rs1: FReg(6), rs2: FReg(7) }));
    }
    xs.push(s(ScalarOp::FMadd { rd: FReg(8), rs1: FReg(9), rs2: FReg(10), rs3: FReg(11) }));
    xs.push(s(ScalarOp::FCvtWS { rd: Reg(3), rs1: FReg(4) }));
    xs.push(s(ScalarOp::FCvtSW { rd: FReg(5), rs1: Reg(6) }));
    xs.push(s(ScalarOp::FMvXW { rd: Reg(7), rs1: FReg(8) }));
    xs.push(s(ScalarOp::FMvWX { rd: FReg(9), rs1: Reg(10) }));
    xs.push(s(ScalarOp::CsrReadCycle { rd: Reg(11) }));
    xs.push(s(ScalarOp::Nop));

    for (sew, lmul, avl) in
        [(Sew::E8, Lmul::M1, 16), (Sew::E32, Lmul::M2, 8), (Sew::E64, Lmul::M8, 31)]
    {
        xs.push(Instr::VSetVli { rd: Reg(1), avl, vtype: VType::new(sew, lmul) });
    }

    let v = Instr::Vector;
    xs.push(v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E8, vd: VReg(1), base: Reg(2) }));
    xs.push(v(VOp::Load {
        kind: VMemKind::Strided { stride: Reg(3) },
        eew: Sew::E32,
        vd: VReg(4),
        base: Reg(5),
    }));
    xs.push(v(VOp::Store { kind: VMemKind::UnitStride, eew: Sew::E8, vs3: VReg(6), base: Reg(7) }));
    xs.push(v(VOp::Store {
        kind: VMemKind::Strided { stride: Reg(8) },
        eew: Sew::E64,
        vs3: VReg(9),
        base: Reg(10),
    }));
    for op in [
        VIOp::Add,
        VIOp::Sub,
        VIOp::Rsub,
        VIOp::And,
        VIOp::Or,
        VIOp::Xor,
        VIOp::Sll,
        VIOp::Srl,
        VIOp::Sra,
        VIOp::Min,
        VIOp::Max,
        VIOp::Minu,
        VIOp::Maxu,
        VIOp::Mul,
        VIOp::Mulh,
    ] {
        xs.push(v(VOp::IVV { op, vd: VReg(1), vs2: VReg(2), vs1: VReg(3) }));
    }
    for op in [VIOp::Add, VIOp::And, VIOp::Or, VIOp::Xor, VIOp::Mul, VIOp::Mulh] {
        // vs2 = v0 would alias vmv.v.x; the canonical form keeps vs2 ≠ v0.
        xs.push(v(VOp::IVX { op, vd: VReg(4), vs2: VReg(5), rs1: Reg(6) }));
    }
    for op in [VIOp::Add, VIOp::Rsub, VIOp::And, VIOp::Or, VIOp::Xor] {
        xs.push(v(VOp::IVI { op, vd: VReg(7), vs2: VReg(8), imm: -5 }));
    }
    xs.push(v(VOp::MaccVX { vd: VReg(1), rs1: Reg(2), vs2: VReg(3) }));
    xs.push(v(VOp::MaccVV { vd: VReg(4), vs1: VReg(5), vs2: VReg(6) }));
    xs.push(v(VOp::RedSum { vd: VReg(7), vs2: VReg(8), vs1: VReg(9) }));
    xs.push(v(VOp::MvXS { rd: Reg(5), vs2: VReg(6) }));
    xs.push(v(VOp::MvSX { vd: VReg(7), rs1: Reg(8) }));
    xs.push(v(VOp::MvVX { vd: VReg(9), rs1: Reg(10) }));
    xs.push(v(VOp::MvVI { vd: VReg(11), imm: -3 }));
    for frac in [2u8, 4, 8] {
        xs.push(v(VOp::Sext { vd: VReg(1), vs2: VReg(2), frac }));
        xs.push(v(VOp::Zext { vd: VReg(3), vs2: VReg(4), frac }));
    }
    xs.push(v(VOp::MseqVI { vd: VReg(5), vs2: VReg(6), imm: 15 }));
    xs.push(v(VOp::MsneVI { vd: VReg(7), vs2: VReg(8), imm: -16 }));
    xs.push(v(VOp::FMaccVF { vd: VReg(1), rs1: FReg(2), vs2: VReg(3) }));
    xs.push(v(VOp::FAddVV { vd: VReg(4), vs2: VReg(5), vs1: VReg(6) }));
    xs.push(v(VOp::FMulVF { vd: VReg(7), vs2: VReg(8), rs1: FReg(9) }));
    xs.push(v(VOp::FMaxVF { vd: VReg(10), vs2: VReg(11), rs1: FReg(12) }));
    xs.push(v(VOp::FMvVF { vd: VReg(13), rs1: FReg(14) }));
    xs.push(v(VOp::FRedSum { vd: VReg(13), vs2: VReg(14), vs1: VReg(15) }));
    xs.push(v(VOp::Popcnt { vd: VReg(1), vs2: VReg(2) }));
    xs.push(v(VOp::Shacc { vd: VReg(3), vs2: VReg(4), shamt: 31 }));
    xs.push(v(VOp::Bitpack { vd: VReg(5), vs2: VReg(6), bit: 31 }));
    xs
}

#[test]
fn every_opcode_roundtrips_through_disasm_and_reencode() {
    for i in exemplars() {
        let word = encode(&i).unwrap_or_else(|| panic!("exemplar must encode: {i}"));
        let back =
            decode(word).unwrap_or_else(|| panic!("word {word:#010x} ({i}) must decode"));
        assert_eq!(back, i, "decode must invert encode (word {word:#010x})");
        let text = format!("{back}");
        assert!(!text.trim().is_empty(), "disassembly of {word:#010x} must be non-empty");
        assert_eq!(
            encode(&back),
            Some(word),
            "re-encoding the decoded form of {text:?} must reproduce {word:#010x}"
        );
    }
}

#[test]
fn quark_custom_ops_disassemble_and_land_in_custom2() {
    use quark::isa::quark::{F6_VBITPACK, F6_VPOPCNT, F6_VSHACC, OPC_CUSTOM2};
    let cases = [
        (Instr::Vector(VOp::Popcnt { vd: VReg(3), vs2: VReg(7) }), "vpopcnt.v v3, v7", F6_VPOPCNT),
        (
            Instr::Vector(VOp::Shacc { vd: VReg(1), vs2: VReg(2), shamt: 1 }),
            "vshacc.vi v1, v2, 1",
            F6_VSHACC,
        ),
        (
            Instr::Vector(VOp::Bitpack { vd: VReg(8), vs2: VReg(0), bit: 3 }),
            "vbitpack.vi v8, v0, 3",
            F6_VBITPACK,
        ),
    ];
    for (i, text, f6) in cases {
        assert_eq!(format!("{i}"), text);
        let word = encode(&i).expect("custom ops must encode");
        assert_eq!(word & 0x7f, OPC_CUSTOM2, "{text} must land in the custom-2 opcode space");
        assert_eq!(word >> 26, f6, "{text} funct6");
        assert_eq!(decode(word), Some(i), "{text}");
    }
}

#[test]
fn decode_rejects_garbage_mostly() {
    // Random words should usually NOT decode to valid instructions of our
    // subset; and decoding must never panic.
    let mut g = Gen::new(99);
    for _ in 0..10000 {
        let w = g.u64() as u32;
        let _ = decode(w); // no panic
    }
}
