//! Property tests over simulator invariants:
//! * TimingOnly and Full modes agree on cycle counts for any kernel
//!   invocation (the kernels are data-independent);
//! * the simulated `vbitpack`/pure-RVV packers match the host packer for
//!   random sizes and precisions;
//! * cycles are monotone in work; stats stay consistent;
//! * decode-once lowered replay ≡ functional replay ≡ i128 golden on
//!   random small `NetGraph`s under random per-layer precision schedules.

mod support;

use quark::arch::MachineConfig;
use quark::kernels::bitpack::{emit_pack_planes, setup_index_vector, PackedBuf};
use quark::kernels::matmul::{gemm_codes_golden, matmul_bitserial, matmul_int8};
use quark::kernels::requantize::{requant_host, RqBuf};
use quark::kernels::Conv2dParams;
use quark::nn::golden::run_golden;
use quark::nn::model::{Precision, PrecisionMap};
use quark::nn::{ConvLayer, LayerKind, NetGraph, NetLayer};
use quark::program::compile;
use quark::quant::{pack_bit_planes, pack_weight_planes};
use quark::sim::{Sim, SimMode};
use support::{run_cases, Gen};

fn quark_sim(mode: SimMode) -> Sim {
    let mut s = Sim::with_memory(MachineConfig::quark(4), 16 << 20);
    s.set_mode(mode);
    s
}

#[test]
fn packing_matches_host_for_random_shapes() {
    run_cases(40, |g| {
        let k = g.usize(1, 2000);
        let bits = g.usize(1, 4) as u8;
        let use_vbp = g.bool();
        let mut sim = quark_sim(SimMode::Full);
        let idx = setup_index_vector(&mut sim);
        let vals: Vec<u8> = (0..k).map(|_| (g.u64() % (1 << bits)) as u8).collect();
        let src = sim.alloc(k as u64);
        sim.write_bytes(src, &vals);
        let dst = PackedBuf::alloc(&mut sim, k, bits);
        emit_pack_planes(&mut sim, src, &dst, use_vbp, idx);
        let want = pack_bit_planes(&vals, bits);
        for p in 0..bits as usize {
            for w in 0..dst.kw() {
                assert_eq!(
                    sim.machine.mem.read_u64_le(dst.word_addr(p, w), 8),
                    want[p][w],
                    "k={k} bits={bits} vbp={use_vbp} p={p} w={w}"
                );
            }
        }
    });
}

#[test]
fn timing_only_equals_full_on_random_gemms() {
    run_cases(12, |g| {
        let m = g.usize(1, 6);
        let k = g.usize(1, 4) * 64;
        let n = g.usize(1, 96);
        let bits = g.usize(1, 2) as u8;
        let vbp = g.bool();
        let cycles = |mode: SimMode| {
            let mut sim = quark_sim(mode);
            let idx = setup_index_vector(&mut sim);
            let wpk = pack_weight_planes(&vec![1u8; k * n], k, n, bits, sim.cfg.vlen_bits / 64);
            let a = sim.alloc((m * k) as u64);
            let w = sim.alloc(wpk.byte_len() as u64);
            let rq =
                RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
            let out = sim.alloc((m * n) as u64);
            matmul_bitserial(&mut sim, m, k, n, bits, a, &wpk, w, &rq, out, vbp, idx);
            sim.cycles()
        };
        assert_eq!(
            cycles(SimMode::Full),
            cycles(SimMode::TimingOnly),
            "m={m} k={k} n={n} bits={bits} vbp={vbp}"
        );
    });
}

#[test]
fn bitserial_gemm_matches_oracle_random() {
    run_cases(10, |g| {
        let m = g.usize(1, 5);
        let k = g.usize(1, 3) * 64;
        let n = g.usize(1, 70);
        let abits = g.usize(1, 2) as u8;
        let wbits = g.usize(1, 2) as u8;
        let vbp = g.bool();
        let a_codes: Vec<u8> = (0..m * k).map(|_| (g.u64() % (1 << abits)) as u8).collect();
        let w_codes: Vec<u8> = (0..k * n).map(|_| (g.u64() % (1 << wbits)) as u8).collect();
        let mut sim = quark_sim(SimMode::Full);
        let idx = setup_index_vector(&mut sim);
        let wpk = pack_weight_planes(&w_codes, k, n, wbits, sim.cfg.vlen_bits / 64);
        let a = sim.alloc((m * k) as u64);
        sim.write_bytes(a, &a_codes);
        let w = sim.alloc(wpk.byte_len() as u64);
        for (i, &word) in wpk.words.iter().enumerate() {
            sim.machine.mem.write_u64_le(w + (i * 8) as u64, word, 8);
        }
        let alpha = 0.37f32;
        let beta = -0.11f32;
        let rq =
            RqBuf::create(&mut sim, &vec![alpha; n], &vec![beta; n], &vec![0.25; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_bitserial(&mut sim, m, k, n, abits, a, &wpk, w, &rq, out, vbp, idx);
        let (acc, asum) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want = requant_host(
                    acc[i * n + j] as i32,
                    Some(asum[i] as i32),
                    None,
                    alpha,
                    beta,
                    0.25,
                    255.0,
                    0.0,
                );
                assert_eq!(
                    sim.read_u8s(out + (i * n + j) as u64, 1)[0],
                    want,
                    "m={m} k={k} n={n} a{abits} w{wbits} ({i},{j})"
                );
            }
        }
    });
}

#[test]
fn cycles_monotone_in_problem_size() {
    let cycles = |m: usize| {
        let mut sim = quark_sim(SimMode::TimingOnly);
        let idx = setup_index_vector(&mut sim);
        let (k, n) = (128, 64);
        let wpk = pack_weight_planes(&vec![1u8; k * n], k, n, 2, sim.cfg.vlen_bits / 64);
        let a = sim.alloc((m * k) as u64);
        let w = sim.alloc(wpk.byte_len() as u64);
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_bitserial(&mut sim, m, k, n, 2, a, &wpk, w, &rq, out, true, idx);
        sim.cycles()
    };
    let mut prev = 0;
    for m in [1usize, 2, 4, 8, 16] {
        let c = cycles(m);
        assert!(c > prev, "cycles must grow with M: m={m} {c} vs {prev}");
        prev = c;
    }
}

#[test]
fn more_lanes_never_slower() {
    let cycles = |lanes: usize| {
        let mut sim = Sim::with_memory(MachineConfig::quark(lanes), 16 << 20);
        sim.set_mode(SimMode::TimingOnly);
        let idx = setup_index_vector(&mut sim);
        let (m, k, n) = (8, 576, 64);
        let wpk = pack_weight_planes(&vec![1u8; k * n], k, n, 2, sim.cfg.vlen_bits / 64);
        let a = sim.alloc((m * k) as u64);
        let w = sim.alloc(wpk.byte_len() as u64);
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_bitserial(&mut sim, m, k, n, 2, a, &wpk, w, &rq, out, true, idx);
        sim.cycles()
    };
    assert!(cycles(8) <= cycles(4), "8 lanes must not be slower than 4");
}

/// One 8×8 stride-1 conv layer with a random kernel size (1 or 3, padded
/// to preserve the spatial shape) and random relu — the building block of
/// the random graphs below. Quantized K axes stay 64-aligned because
/// `c_in ∈ {64, 128}` and `k² ∈ {1, 9}`.
fn rand_conv(
    g: &mut Gen,
    name: &str,
    c_in: usize,
    c_out: usize,
    quantized: bool,
    input: usize,
) -> NetLayer {
    let ksz = if g.bool() { 3 } else { 1 };
    NetLayer {
        kind: LayerKind::Conv(ConvLayer {
            name: name.into(),
            params: Conv2dParams {
                h: 8,
                w: 8,
                c_in,
                c_out,
                kh: ksz,
                kw: ksz,
                stride: 1,
                pad: if ksz == 3 { 1 } else { 0 },
            },
            relu: g.bool(),
            residual: false,
            quantized,
        }),
        input,
        residual_from: None,
    }
}

/// A random small valid `NetGraph`: int8 stem, 1–2 quantized convs with
/// random widths/kernels, optionally a global pool before the 10-class
/// classifier. Returns the graph plus the names of its schedulable layers.
fn random_net(g: &mut Gen) -> (NetGraph, Vec<String>) {
    let widths = [64usize, 128];
    let mut layers = Vec::new();
    let mut names = Vec::new();
    let mut c = *g.pick(&widths);
    layers.push(rand_conv(g, "stem", 3, c, false, 0));
    for i in 0..g.usize(1, 2) {
        let c_out = *g.pick(&widths);
        let name = format!("c{i}");
        layers.push(rand_conv(g, &name, c, c_out, true, layers.len()));
        names.push(name);
        c = c_out;
    }
    if g.bool() {
        layers.push(NetLayer {
            kind: LayerKind::AvgPool { h: 8, w: 8, c },
            input: layers.len(),
            residual_from: None,
        });
        layers.push(NetLayer {
            kind: LayerKind::Fc { k: c, n: 10, name: "fc".into() },
            input: layers.len(),
            residual_from: None,
        });
    } else {
        layers.push(NetLayer {
            kind: LayerKind::Fc { k: 8 * 8 * c, n: 10, name: "fc".into() },
            input: layers.len(),
            residual_from: None,
        });
    }
    names.push("fc".to_string());
    (NetGraph::new("prop-net@10", 10, layers).unwrap(), names)
}

#[test]
fn lowered_replay_matches_functional_and_golden_on_random_nets() {
    // The supported integer palette: int8 plus every 1–2-bit sub-byte
    // combination, with and without the vbitpack fast path.
    let palette = [
        Precision::Int8,
        Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true },
        Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true },
        Precision::Sub { abits: 2, wbits: 1, use_vbitpack: false },
        Precision::Sub { abits: 1, wbits: 2, use_vbitpack: true },
    ];
    run_cases(6, |g| {
        let (net, names) = random_net(g);
        let mut sched = PrecisionMap::uniform(*g.pick(&palette));
        for name in &names {
            sched = sched.with(name, *g.pick(&palette));
        }
        let input: Vec<u8> = (0..32 * 32 * 3).map(|_| (g.u64() % 251) as u8).collect();
        let ctx = format!("{} layers, schedule {}", net.len(), sched.spec());

        let prog = compile(&net, &MachineConfig::quark(4), &sched)
            .unwrap_or_else(|e| panic!("{ctx}: {e}"));
        let golden = run_golden(&net, &sched, Some(&input));

        let mut func = quark_sim(SimMode::Full);
        let fb = func.alloc(prog.mem_len());
        let frun = func.execute_functional(&prog, fb, Some(&input));

        let mut low = quark_sim(SimMode::Full);
        let lb = low.alloc(prog.mem_len());
        let lrun = low.execute_lowered(&prog, lb, Some(&input));

        for (i, (l, f)) in lrun.reports.iter().zip(frun.reports.iter()).enumerate() {
            let want = &golden.maps[i + 1];
            assert_eq!(
                &func.read_u8s(f.out_addr, f.out_elems),
                want,
                "{ctx}: functional layer {} diverges from the i128 golden",
                f.name
            );
            assert_eq!(
                &low.read_u8s(l.out_addr, l.out_elems),
                want,
                "{ctx}: lowered layer {} diverges from the i128 golden",
                l.name
            );
        }
        assert_eq!(
            low.read_u8s(lrun.out_addr, lrun.out_elems),
            golden.maps[net.len()],
            "{ctx}: lowered logits diverge from the i128 golden"
        );
    });
}

#[test]
fn int8_stats_account_memory_traffic() {
    let mut sim = Sim::with_memory(MachineConfig::ara(4), 16 << 20);
    sim.set_mode(SimMode::TimingOnly);
    let (m, k, n) = (4, 128, 64);
    let a = sim.alloc((m * k) as u64);
    let w = sim.alloc((k * n) as u64);
    let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((m * n) as u64);
    let before = sim.stats().clone();
    matmul_int8(&mut sim, m, k, n, a, w, &rq, out);
    let d = sim.stats().delta_since(&before);
    // Weights are streamed at least once: ≥ K·N bytes of vector loads.
    assert!(d.vload_bytes >= (k * n) as u64, "vload {} < {}", d.vload_bytes, k * n);
    assert!(d.effective_macs == (m * k * n) as u64);
    assert!(d.scalar_fpu_cycles > 0, "requant must use the scalar FPU");
}
