//! Negative corpus for the static program verifier
//! (`quark::program::verify`): hand-corrupted artifacts must be rejected
//! with the right finding class, while the pristine artifact passes for
//! every zoo entry × {w2a2, w1a1, mixed, int8}. The corruption helpers
//! live in `program::verify::corrupt` so this suite never needs
//! `CompiledProgram`'s internals.
//!
//! The suite also holds the batching-fallback proof: an artifact the
//! verifier rejects still replays bit-exactly through
//! `Sim::execute_lowered_batch`, because without a batch-safety proof the
//! executor keeps its per-element dynamic isolation check in every build
//! profile.
//!
//! Deep ResNets run as truncated heads for `Full`-mode affordability — the
//! same trade `rust/tests/batching.rs` makes.

use quark::arch::MachineConfig;
use quark::nn::model::{Precision, PrecisionMap, ShardPlan};
use quark::nn::{zoo, NetGraph};
use quark::program::verify::corrupt;
use quark::program::{compile, compile_shard, CompiledProgram, FindingClass};
use quark::sim::Sim;

const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

/// Input image `k`: a distinct deterministic pattern per `k` (matches the
/// batching suite, so a fallback divergence here isolates the verifier
/// gate, not the replay).
fn test_input(k: usize) -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 11 + 5 + k * 37) % 251) as u8).collect()
}

/// Every registered model at a `Full`-mode-affordable profile.
fn affordable_zoo() -> Vec<NetGraph> {
    zoo::entries()
        .iter()
        .map(|e| match e.name {
            "resnet18-cifar" => zoo::model_head("resnet18-cifar@10", 4).unwrap(),
            "resnet34-cifar" => zoo::model_head("resnet34-cifar@10", 3).unwrap(),
            name => zoo::model(&format!("{name}@10")).unwrap(),
        })
        .collect()
}

/// The acceptance schedule matrix: uniform w2a2 / w1a1 / int8 plus the
/// registry's mixed schedule for this graph.
fn schedules(net: &NetGraph) -> Vec<(&'static str, PrecisionMap)> {
    vec![
        ("w2a2", PrecisionMap::uniform(W2A2)),
        ("w1a1", PrecisionMap::uniform(W1A1)),
        ("mixed", zoo::mixed_schedule(net)),
        ("int8", PrecisionMap::uniform(Precision::Int8)),
    ]
}

#[test]
fn pristine_artifacts_pass_for_every_zoo_entry_and_schedule() {
    for net in affordable_zoo() {
        for (label, sched) in schedules(&net) {
            let ctx = format!("{} under {label}", net.name());
            let prog = compile(&net, &MachineConfig::quark(4), &sched)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let rep = prog.verify_report();
            assert!(rep.ok(), "{ctx}: pristine artifact must verify clean:\n{rep}");
            assert!(rep.batch_safe(), "{ctx}: single-core artifact must prove batch safety");
            assert!(rep.checked_instrs() > 0 && rep.checked_ops() > 0, "{ctx}: empty audit");
        }
    }
}

#[test]
fn pristine_shard_artifacts_pass_but_never_claim_batch_safety() {
    let net = zoo::model_head("quarknet@10", 4).unwrap();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let plan = ShardPlan::derive(&net, 2).unwrap();
    for shard in 0..2 {
        let prog = compile_shard(&net, &machine, &sched, &plan, shard).unwrap();
        let rep = prog.verify_report();
        assert!(rep.ok(), "shard {shard}: pristine shard must verify clean:\n{rep}");
        assert!(
            !rep.batch_safe(),
            "shard {shard}: inter-layer gathers are host effects — the proof must not extend"
        );
    }
}

#[test]
fn corruptions_are_rejected_with_the_right_class() {
    use std::collections::HashSet;
    let net = zoo::model("tiny@10").unwrap();
    let machine = MachineConfig::quark(4);
    let mut classes_hit: HashSet<&'static str> = HashSet::new();
    for (label, sched) in schedules(&net) {
        let prog = compile(&net, &machine, &sched).unwrap();
        assert!(prog.verify_report().ok(), "{label}: corpus baseline must be pristine");
        // Each corruption helper returns `None` when the schedule has no
        // instance of the construct (e.g. no PlaneMac under int8).
        let cases: Vec<(&'static str, Option<CompiledProgram>, FindingClass)> = vec![
            ("drop-reloc-entry", corrupt::drop_reloc_entry(&prog), FindingClass::Relocation),
            (
                "overlap-output-into-image",
                corrupt::overlap_output_into_image(&prog),
                FindingClass::Segments,
            ),
            ("truncate-init-image", corrupt::truncate_image(&prog), FindingClass::UninitRead),
            ("alias-planemac-acc", corrupt::alias_plane_mac_acc(&prog), FindingClass::FusedOp),
            ("skip-vsetvli", corrupt::skip_vsetvli(&prog), FindingClass::VState),
        ];
        let mut applied = 0;
        for (name, bad, class) in cases {
            let Some(bad) = bad else { continue };
            applied += 1;
            classes_hit.insert(name);
            let rep = bad.verify_report();
            assert!(!rep.ok(), "{label}/{name}: corruption must be rejected:\n{rep}");
            assert!(
                rep.has(class),
                "{label}/{name}: expected a {class} finding, got:\n{rep}"
            );
            assert!(!rep.batch_safe(), "{label}/{name}: a failing artifact is never proven");
        }
        assert!(applied >= 4, "{label}: only {applied} corruption(s) applicable");
    }
    assert_eq!(classes_hit.len(), 5, "all five corruption classes must fire: {classes_hit:?}");
}

#[test]
fn unverifiable_artifacts_still_batch_correctly_via_the_dynamic_check() {
    let net = zoo::model("tiny@10").unwrap();
    let machine = MachineConfig::quark(4);
    let sched = PrecisionMap::uniform(W2A2);
    let prog = compile(&net, &machine, &sched).unwrap();
    // Dropping a relocation entry fails verification but leaves execution
    // at the compile-time base untouched (the entry is only consulted when
    // re-basing) — exactly the shape of artifact the fallback must cover.
    let bad = corrupt::drop_reloc_entry(&prog).expect("tiny carries ≥3 relocation entries");
    assert!(!bad.verify_report().ok(), "corruption must invalidate the artifact");
    assert!(!bad.verify_report().batch_safe(), "no proof → per-element dynamic check");

    let inputs: Vec<Vec<u8>> = (0..4).map(test_input).collect();
    let views: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();

    // Reference: independent single-request replays of the pristine artifact.
    let refs: Vec<Vec<u8>> = inputs
        .iter()
        .map(|input| {
            let mut sim = Sim::new(machine.clone());
            let base = sim.alloc(prog.mem_len());
            let run = sim.execute_lowered(&prog, base, Some(input));
            sim.read_u8s(run.out_addr, run.out_elems)
        })
        .collect();

    // Batched replay of the unverifiable artifact at the compile-time base:
    // the always-on isolation check guards it, and the logits stay exact.
    let mut sim = Sim::new(machine.clone());
    let base = sim.alloc(bad.mem_len());
    let batch = sim.execute_lowered_batch(&bad, base, &views);
    assert_eq!(batch.outputs, refs, "fallback-guarded batch diverged from pristine singles");
}
