//! Differential testing of the re-quantization / rescaling path
//! (`quant/requant.rs` + `kernels/requantize.rs` + the `vshacc` plane
//! weighting) against naive i128 host models.
//!
//! Strategy (same spirit as `exec_differential.rs`): pick scale factors that
//! are exact powers of two and accumulators small enough that every f32 step
//! of the golden sequence is exact (|values| < 2²³ — the paper's real
//! accumulators are ≪ that: ACC ≤ K·3·3 ≈ 10⁴ at K = 1152). Then the whole
//! rescale collapses to an integer shift-round-clamp, which a deliberately
//! naive i128 model computes with no floating point at all. Accumulator
//! magnitudes are swept per SEW grid (E8/E16/E32) and shift amounts 0..=12.

mod support;

use quark::arch::MachineConfig;
use quark::isa::instr::{VIOp, VOp};
use quark::isa::reg::VReg;
use quark::isa::vtype::{Lmul, Sew};
use quark::kernels::requantize::{
    emit_asum_preload, emit_requant_channel_block, emit_requant_setup, RqBuf,
};
use quark::quant::{requantize_golden, RequantParams};
use quark::sim::Sim;
use support::run_cases;

/// Naive i128 round-half-even of `acc / 2^s` (no floating point).
fn round_half_even_shift(acc: i128, s: u32) -> i128 {
    if s == 0 {
        return acc;
    }
    let q = acc >> s; // floor, also for negatives
    let r = acc - (q << s); // remainder in [0, 2^s)
    let half = 1i128 << (s - 1);
    if r > half {
        q + 1
    } else if r < half {
        q
    } else if q & 1 == 1 {
        q + 1
    } else {
        q
    }
}

/// The full naive model of the rescale: `(acc - asum) / 2^s`, round to
/// nearest (ties to even), clamp onto the `[0, qmax]` output grid.
fn naive_requant_i128(acc: i128, asum: i128, s: u32, qmax: i128) -> u8 {
    let rounded = round_half_even_shift(acc - asum, s);
    rounded.clamp(0, qmax) as u8
}

/// Accumulator magnitude bound per SEW grid, capped so every f32 step stays
/// exact (see module docs).
fn acc_bound(sew: Sew) -> i64 {
    match sew.bits() {
        8 => 127,
        16 => 32_767,
        _ => (1 << 22) - 1,
    }
}

#[test]
fn requantize_golden_matches_naive_i128_model() {
    run_cases(200, |g| {
        let sew = *g.pick(&[Sew::E8, Sew::E16, Sew::E32]);
        let bound = acc_bound(sew);
        let s = g.range(0, 12) as u32;
        let out_bits = *g.pick(&[1u8, 2, 4, 8]);
        let qmax = (1i128 << out_bits) - 1;
        let acc = g.range(0, 2 * bound as u64) as i64 - bound;
        let asum = g.range(0, bound as u64) as i64;
        let p = RequantParams {
            alpha: (2f32).powi(-(s as i32)),
            beta: -(2f32).powi(-(s as i32)),
            bias: 0.0,
            qmax: qmax as f32,
            res_scale: 0.0,
        };
        let got = requantize_golden(acc, asum, 0, &p);
        let want = naive_requant_i128(acc as i128, asum as i128, s, qmax);
        assert_eq!(
            got, want,
            "acc={acc} asum={asum} shift={s} qmax={qmax} sew={}",
            sew.bits()
        );
    });
}

#[test]
fn emitted_requant_kernel_matches_naive_i128_model() {
    // The simulated scalar-FP instruction stream, the f32 host oracle, and
    // the integer model must all agree — sweeping shift per channel.
    run_cases(25, |g| {
        let mut sim = Sim::with_memory(MachineConfig::quark(4), 1 << 20);
        let n = g.usize(1, 6); // channels, each with its own shift
        let px = g.usize(1, 8); // pixels per block
        let shifts: Vec<u32> = (0..n).map(|_| g.range(0, 12) as u32).collect();
        let alphas: Vec<f32> = shifts.iter().map(|&s| (2f32).powi(-(s as i32))).collect();
        let betas: Vec<f32> = shifts.iter().map(|&s| -(2f32).powi(-(s as i32))).collect();
        let biases = vec![0.0f32; n];
        let qmax = 255.0f32;
        let rq = RqBuf::create(&mut sim, &alphas, &betas, &biases, qmax, 0.0);
        let consts = sim.alloc(16);

        let bound = (1i64 << 22) - 1;
        let accs: Vec<i32> =
            (0..px).map(|_| (g.range(0, 2 * bound as u64) as i64 - bound) as i32).collect();
        let asums: Vec<i32> = (0..px).map(|_| g.range(0, bound as u64) as i32).collect();

        let acc_buf = sim.alloc((px * 8) as u64);
        let asum_buf = sim.alloc((px * 4) as u64);
        let out_buf = sim.alloc((n * px) as u64);
        for t in 0..px {
            sim.write_i32s(acc_buf + (t * 8) as u64, &[accs[t]]);
            sim.write_i32s(asum_buf + (t * 4) as u64, &[asums[t]]);
        }

        emit_requant_setup(&mut sim, &rq, consts);
        emit_asum_preload(&mut sim, px, |t| asum_buf + (t * 4) as u64);
        for j in 0..n {
            let out_base = out_buf + (j * px) as u64;
            emit_requant_channel_block(
                &mut sim,
                &rq,
                j,
                px,
                |t| acc_buf + (t * 8) as u64,
                true,
                None,
                |t| out_base + t as u64,
            );
        }

        for j in 0..n {
            for t in 0..px {
                let got = sim.read_u8s(out_buf + (j * px + t) as u64, 1)[0];
                let want =
                    naive_requant_i128(accs[t] as i128, asums[t] as i128, shifts[j], 255);
                assert_eq!(
                    got, want,
                    "channel {j} (shift {}) pixel {t}: acc={} asum={}",
                    shifts[j], accs[t], asums[t]
                );
            }
        }
    });
}

#[test]
fn multi_plane_shacc_rescaling_matches_naive_i128() {
    // The sub-byte kernels rescale bit-plane partial products with
    // `vshacc.vi` (acc = (acc << shamt) + popcnt). Chain several planes and
    // compare the final accumulator against a naive i128 interpreter, at
    // every SEW and shift amount; at E64 (the kernels' working width,
    // where nothing wraps) additionally check the closed-form
    // Σ popcount·2^weight the quantization math assumes.
    run_cases(40, |g| {
        let sew = *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64]);
        let bits = sew.bits();
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut sim = Sim::with_memory(MachineConfig::quark(4), 1 << 20);
        let vl = g.usize(1, 4096 / bits);
        sim.vsetvli(vl as u64, sew, Lmul::M1);
        let planes = g.usize(2, 4);
        let mut avals = vec![vec![0u64; vl]; planes];
        let mut wvals = vec![vec![0u64; vl]; planes];
        let mut shifts = Vec::with_capacity(planes);
        sim.v(VOp::MvVI { vd: VReg(10), imm: 0 });
        for p in 0..planes {
            let sh = g.range(0, 3) as u8;
            shifts.push(sh);
            for i in 0..vl {
                avals[p][i] = g.u64();
                wvals[p][i] = g.u64();
                sim.machine.vset(VReg(2), i, sew.bytes(), avals[p][i]);
                sim.machine.vset(VReg(3), i, sew.bytes(), wvals[p][i]);
            }
            sim.v(VOp::IVV { op: VIOp::And, vd: VReg(4), vs2: VReg(2), vs1: VReg(3) });
            sim.v(VOp::Popcnt { vd: VReg(5), vs2: VReg(4) });
            sim.v(VOp::Shacc { vd: VReg(10), vs2: VReg(5), shamt: sh });
        }
        for i in 0..vl {
            // Naive i128 chain model with SEW wrap-around.
            let mut acc: i128 = 0;
            let mut popcounts = Vec::with_capacity(planes);
            for p in 0..planes {
                let pc = (avals[p][i] & wvals[p][i] & mask).count_ones() as i128;
                popcounts.push(pc);
                acc = (((acc << shifts[p]) & mask as i128) + pc) & mask as i128;
            }
            let got = sim.machine.vget(VReg(10), i, sew.bytes());
            assert_eq!(got, acc as u64, "elem {i} sew={bits} shifts={shifts:?}");
            if bits == 64 {
                // No wrap possible: the chain equals the weighted plane sum.
                let mut weighted: i128 = 0;
                for p in 0..planes {
                    let later: u32 = shifts[p + 1..].iter().map(|&s| s as u32).sum();
                    weighted += popcounts[p] << later;
                }
                assert_eq!(got as i128, weighted, "closed-form plane weighting, elem {i}");
            }
        }
    });
}
