//! Mixed per-layer precision, end to end:
//!
//! * a mixed-schedule ResNet basic block executed in `Full` mode must match
//!   the naive-i128 host golden model ([`quark::nn::golden`]) **layer by
//!   layer, bit-exactly**, across schedules that exercise every re-pack
//!   boundary (int8 → 2-bit, 2-bit → 2-bit with residual, 2-bit → int8,
//!   1-bit layers);
//! * a full ResNet-18 under the mixed schedule must land strictly between
//!   the uniform Int8 and uniform Int2 baselines on whole-network cycles,
//!   both through the simulator directly and through the coordinator
//!   `INFER` path (per-request schedules, separate timing-cache entries);
//! * functional inference under a mixed schedule must produce real,
//!   deterministic logits.

use std::sync::Arc;
use std::time::Duration;

use quark::arch::MachineConfig;
use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};
use quark::kernels::Conv2dParams;
use quark::nn::golden::run_golden;
use quark::nn::model::{ModelRunner, Precision, PrecisionMap};
use quark::nn::resnet::resnet18_mixed_schedule;
use quark::nn::{zoo, ConvLayer, LayerKind, NetGraph, NetLayer};
use quark::sim::{Sim, SimMode};

const INT8: Precision = Precision::Int8;
const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

/// A ResNet basic block at 8×8×64 (stem → projection + two 3×3 convs with a
/// residual add → pool → FC): small enough for `Full`-mode simulation in a
/// debug test while covering every layer kind and skip wiring.
fn block_net() -> NetGraph {
    let conv = |name: &str,
                c_in: usize,
                ksz: usize,
                relu: bool,
                residual: bool,
                quantized: bool| ConvLayer {
        name: name.into(),
        params: Conv2dParams {
            h: 8,
            w: 8,
            c_in,
            c_out: 64,
            kh: ksz,
            kw: ksz,
            stride: 1,
            pad: if ksz == 3 { 1 } else { 0 },
        },
        relu,
        residual,
        quantized,
    };
    NetGraph::new(
        "mixed-block@10",
        10,
        vec![
            // 0: unquantized stem (pinned to int8 by resolve()) — writes map 1.
            NetLayer { kind: LayerKind::Conv(conv("stem", 3, 3, true, false, false)), input: 0, residual_from: None },
            // 1: projection shortcut — map 2.
            NetLayer { kind: LayerKind::Conv(conv("proj", 64, 1, false, false, true)), input: 1, residual_from: None },
            // 2: first block conv — map 3.
            NetLayer { kind: LayerKind::Conv(conv("c1", 64, 3, true, false, true)), input: 1, residual_from: None },
            // 3: second block conv, adds the projection residual — map 4.
            NetLayer { kind: LayerKind::Conv(conv("c2", 64, 3, true, true, true)), input: 3, residual_from: Some(2) },
            // 4: global pool — map 5.
            NetLayer { kind: LayerKind::AvgPool { h: 8, w: 8, c: 64 }, input: 4, residual_from: None },
            // 5: classifier — map 6.
            NetLayer { kind: LayerKind::Fc { k: 64, n: 10, name: "fc".into() }, input: 5, residual_from: None },
        ],
    )
    .unwrap()
}

fn test_input() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 7 + 13) % 251) as u8).collect()
}

#[test]
fn mixed_block_matches_naive_i128_golden_layer_by_layer() {
    let net = block_net();
    let schedules = [
        // int8 first conv inside an otherwise 2-bit block: int8 → 2-bit and
        // 2-bit → int8 boundaries, plus the 2-bit residual add.
        PrecisionMap::uniform(W2A2).with("c1", INT8),
        // 1-bit layer inside an int8 net: 8-bit → 1-bit repack.
        PrecisionMap::uniform(INT8).with("c2", W1A1),
        // classifier at int8, everything else 2-bit (the mixed-schedule
        // shape the report uses).
        PrecisionMap::uniform(W2A2).with("fc", INT8),
        // uniform baselines stay golden too.
        PrecisionMap::uniform(INT8),
        PrecisionMap::uniform(W2A2),
    ];
    let input = test_input();
    for schedule in schedules {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.set_mode(SimMode::Full);
        let run = ModelRunner::run_scheduled(&mut sim, &net, &schedule, Some(&input));
        let golden = run_golden(&net, &schedule, Some(&input));
        assert_eq!(run.reports.len(), net.len());
        assert_eq!(golden.maps.len(), net.len() + 1);
        for (i, r) in run.reports.iter().enumerate() {
            let got = sim.read_u8s(r.out_addr, r.out_elems);
            let want = &golden.maps[i + 1];
            assert_eq!(
                &got,
                want,
                "layer {i} ({} @ {}) diverges from the i128 golden model under {}",
                r.name,
                r.precision.label(),
                schedule.spec()
            );
        }
    }
}

#[test]
fn repack_boundaries_clamp_onto_the_consumer_grid() {
    // Under `w2a2 with c1=int8`, map 1 (the stem output) feeds both the
    // 2-bit projection and the int8 c1 — its narrowest consumer is 2-bit,
    // so every stored code must sit on the [0, 3] grid, in the simulator
    // and the golden model alike.
    let net = block_net();
    let schedule = PrecisionMap::uniform(W2A2).with("c1", INT8);
    let input = test_input();
    let mut sim = Sim::new(MachineConfig::quark(4));
    sim.set_mode(SimMode::Full);
    let run = ModelRunner::run_scheduled(&mut sim, &net, &schedule, Some(&input));
    let stem = &run.reports[0];
    let codes = sim.read_u8s(stem.out_addr, stem.out_elems);
    assert!(codes.iter().all(|&v| v <= 3), "stem output escapes the 2-bit grid");
    assert!(codes.iter().any(|&v| v > 0), "clamped map still carries data");
    let golden = run_golden(&net, &schedule, Some(&input));
    assert!(golden.maps[1].iter().all(|&v| v <= 3));
    // The grid is per-map, not global: under uniform int8 the same stem
    // output keeps its full 8-bit range.
    let mut sim8 = Sim::new(MachineConfig::quark(4));
    sim8.set_mode(SimMode::Full);
    let run8 =
        ModelRunner::run_scheduled(&mut sim8, &net, &PrecisionMap::uniform(INT8), Some(&input));
    let stem8 = &run8.reports[0];
    let codes8 = sim8.read_u8s(stem8.out_addr, stem8.out_elems);
    assert!(codes8.iter().any(|&v| v > 3), "int8-consumed stem keeps the 8-bit grid");
}

#[test]
fn mixed_resnet18_serves_between_uniform_baselines_via_coordinator() {
    // The acceptance run: full ResNet-18 with a non-uniform map through the
    // coordinator INFER path; its cycle count sits strictly between the
    // uniform int8 and uniform 2-bit deployments.
    let net = zoo::model("resnet18-cifar@100").unwrap();
    let mixed_map = resnet18_mixed_schedule(&net);
    let mut cfg = CoordinatorConfig::demo();
    cfg.models = vec![Arc::new(net)];
    cfg.schedule = PrecisionMap::uniform(INT8);
    cfg.workers = 1;
    cfg.batch_size = 1;
    cfg.batch_timeout = Duration::from_millis(1);
    let coord = Coordinator::start(cfg);
    let get = |id: u64, sched: Option<PrecisionMap>| {
        let rx = coord.submit(InferenceRequest { id, schedule: sched, ..Default::default() }).unwrap();
        rx.recv_timeout(Duration::from_secs(600)).unwrap().unwrap()
    };
    let int8 = get(0, None); // deployment default: uniform int8
    let mixed = get(1, Some(mixed_map));
    let int2 = get(2, Some(PrecisionMap::uniform(W2A2)));
    assert!(
        int2.sim_cycles < mixed.sim_cycles && mixed.sim_cycles < int8.sim_cycles,
        "uniform w2a2 {} < mixed {} < uniform int8 {}",
        int2.sim_cycles,
        mixed.sim_cycles,
        int8.sim_cycles
    );
    assert!(mixed.precision.starts_with("mixed("), "{}", mixed.precision);
    // Each schedule is its own cache entry; repeats are lookups.
    let again =
        get(3, Some(resnet18_mixed_schedule(&zoo::model("resnet18-cifar@100").unwrap())));
    assert!(again.timing_cached, "equal schedules must share a cache entry");
    assert_eq!(again.sim_cycles, mixed.sim_cycles);
    coord.shutdown();
}

#[test]
fn mixed_schedule_functional_inference_produces_real_logits() {
    // Functional (input-carrying) inference under a per-request mixed
    // schedule on the demo net: real logits, deterministic, and different
    // from the uniform deployment's output.
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 1;
    cfg.batch_size = 2;
    let coord = Coordinator::start(cfg);
    let mixed = PrecisionMap::uniform(W2A2).with("c2", INT8);
    let input = vec![200u8; 32 * 32 * 3];
    let get = |id: u64, sched: Option<PrecisionMap>| {
        let rx = coord
            .submit(InferenceRequest { id, input: Some(input.clone()), schedule: sched, ..Default::default() })
            .unwrap();
        rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap()
    };
    let a = get(0, Some(mixed.clone()));
    let b = get(1, Some(mixed.clone()));
    let uni = get(2, None);
    let (la, lb, lu) = (a.logits.unwrap(), b.logits.unwrap(), uni.logits.unwrap());
    assert_eq!(la.len(), 100);
    assert!(a.argmax.unwrap() < 100);
    assert_eq!(la, lb, "mixed-schedule inference must be deterministic");
    assert_ne!(la, lu, "schedule change must change the computation");
    coord.shutdown();
}
