//! Differential testing of the functional executor: random vector integer
//! operations are run both through the simulator and through a tiny
//! independent host interpreter; element values must agree exactly at every
//! SEW. (The interpreter is deliberately written in the most naive style —
//! i128 arithmetic + masking — so a shared bug is unlikely.)

mod support;

use quark::arch::MachineConfig;
use quark::isa::instr::{VIOp, VOp};
use quark::isa::reg::VReg;
use quark::isa::vtype::{Lmul, Sew};
use quark::sim::Sim;
use support::{run_cases, Gen};

/// Naive host semantics for one element.
fn host_op(op: VIOp, a: u64, b: u64, bits: u32) -> u64 {
    let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let sx = |v: u64| -> i128 {
        let v = v & mask;
        if (v >> (bits - 1)) & 1 == 1 {
            v as i128 - (1i128 << bits)
        } else {
            v as i128
        }
    };
    let (ia, ib) = (sx(a), sx(b));
    let sh = (b & mask) % bits as u64;
    let r: i128 = match op {
        VIOp::Add => ia + ib,
        VIOp::Sub => ia - ib,
        VIOp::Rsub => ib - ia,
        VIOp::And => (a & b) as i128,
        VIOp::Or => (a | b) as i128,
        VIOp::Xor => (a ^ b) as i128,
        VIOp::Sll => ((a & mask) as i128) << sh,
        VIOp::Srl => ((a & mask) >> sh) as i128,
        VIOp::Sra => ia >> sh,
        VIOp::Min => ia.min(ib),
        VIOp::Max => ia.max(ib),
        VIOp::Minu => ((a & mask).min(b & mask)) as i128,
        VIOp::Maxu => ((a & mask).max(b & mask)) as i128,
        VIOp::Mul => ia * ib,
        VIOp::Mulh => return (((ia * ib) >> bits) as u64) & mask,
    };
    (r as u64) & mask
}

const OPS: [VIOp; 15] = [
    VIOp::Add,
    VIOp::Sub,
    VIOp::Rsub,
    VIOp::And,
    VIOp::Or,
    VIOp::Xor,
    VIOp::Sll,
    VIOp::Srl,
    VIOp::Sra,
    VIOp::Min,
    VIOp::Max,
    VIOp::Minu,
    VIOp::Maxu,
    VIOp::Mul,
    VIOp::Mulh,
];

#[test]
fn vector_integer_ops_match_naive_interpreter() {
    run_cases(60, |g| {
        let sew = *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64]);
        let bits = sew.bits() as u32;
        let op = *g.pick(&OPS);
        let mut sim = Sim::with_memory(MachineConfig::quark(4), 1 << 20);
        let vl = g.usize(1, 4096 / sew.bits());
        sim.vsetvli(vl as u64, sew, Lmul::M1);
        let mut avals = Vec::with_capacity(vl);
        let mut bvals = Vec::with_capacity(vl);
        for i in 0..vl {
            let a = g.u64();
            let b = g.u64();
            sim.machine.vset(VReg(2), i, sew.bytes(), a);
            sim.machine.vset(VReg(3), i, sew.bytes(), b);
            avals.push(a);
            bvals.push(b);
        }
        sim.v(VOp::IVV { op, vd: VReg(4), vs2: VReg(2), vs1: VReg(3) });
        for i in 0..vl {
            let got = sim.machine.vget(VReg(4), i, sew.bytes());
            let want = host_op(op, avals[i], bvals[i], bits);
            assert_eq!(got, want, "{op:?} sew={bits} elem {i}: a={:#x} b={:#x}", avals[i], bvals[i]);
        }
    });
}

#[test]
fn popcnt_shacc_match_naive_interpreter() {
    run_cases(40, |g| {
        let sew = *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64]);
        let bits = sew.bits();
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut sim = Sim::with_memory(MachineConfig::quark(4), 1 << 20);
        let vl = g.usize(1, 4096 / bits);
        sim.vsetvli(vl as u64, sew, Lmul::M1);
        let shamt = g.range(0, 3) as u8;
        let mut src = Vec::new();
        let mut acc = Vec::new();
        for i in 0..vl {
            let s = g.u64();
            let a = g.u64();
            sim.machine.vset(VReg(2), i, sew.bytes(), s);
            sim.machine.vset(VReg(4), i, sew.bytes(), a);
            src.push(s);
            acc.push(a);
        }
        sim.v(VOp::Popcnt { vd: VReg(3), vs2: VReg(2) });
        sim.v(VOp::Shacc { vd: VReg(4), vs2: VReg(3), shamt });
        for i in 0..vl {
            let pc = (src[i] & mask).count_ones() as u64;
            let want = (((acc[i] & mask) << shamt) & mask).wrapping_add(pc) & mask;
            assert_eq!(sim.machine.vget(VReg(4), i, sew.bytes()), want, "elem {i}");
            assert_eq!(sim.machine.vget(VReg(3), i, sew.bytes()), pc, "popcnt {i}");
        }
    });
}

#[test]
fn macc_and_redsum_match_naive_interpreter() {
    run_cases(30, |g| {
        let sew = *g.pick(&[Sew::E16, Sew::E32, Sew::E64]);
        let bits = sew.bits();
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut sim = Sim::with_memory(MachineConfig::quark(4), 1 << 20);
        let vl = g.usize(1, 4096 / bits);
        sim.vsetvli(vl as u64, sew, Lmul::M1);
        let scalar = g.u64();
        sim.machine.set_x(quark::isa::Reg(7), scalar);
        let mut acc = Vec::new();
        let mut m = Vec::new();
        for i in 0..vl {
            let a = g.u64();
            let v = g.u64();
            sim.machine.vset(VReg(8), i, sew.bytes(), a);
            sim.machine.vset(VReg(9), i, sew.bytes(), v);
            acc.push(a);
            m.push(v);
        }
        sim.v(VOp::MaccVX { vd: VReg(8), rs1: quark::isa::Reg(7), vs2: VReg(9) });
        let mut sum = 0u64;
        for i in 0..vl {
            let want = (acc[i].wrapping_add((scalar & mask).wrapping_mul(m[i] & mask))) & mask;
            assert_eq!(sim.machine.vget(VReg(8), i, sew.bytes()), want, "macc elem {i}");
            sum = sum.wrapping_add(want) & mask;
        }
        // vredsum with zeroed seed.
        sim.v(VOp::MvVI { vd: VReg(12), imm: 0 });
        sim.v(VOp::RedSum { vd: VReg(12), vs2: VReg(8), vs1: VReg(12) });
        assert_eq!(sim.machine.vget(VReg(12), 0, sew.bytes()), sum);
    });
}
