//! Integration: the three-layer loop — simulated Quark custom-ISA kernels vs
//! the JAX/Pallas AOT artifacts executed through the PJRT runtime.
//!
//! Requires `make artifacts`. The tests skip (with a loud message) when the
//! artifacts are missing so `cargo test` stays green on a fresh checkout.

use quark::coordinator::golden::{crosscheck_qgemm, GOLDEN_K, GOLDEN_M, GOLDEN_N};
use quark::runtime::Runtime;

fn artifact(name: &str) -> Option<String> {
    // Tests run from the crate root.
    let p = format!("artifacts/{name}");
    if std::path::Path::new(&p).exists() {
        Some(p)
    } else {
        eprintln!("SKIP: {p} missing — run `make artifacts` first");
        None
    }
}

#[test]
fn qgemm_crosscheck_simulator_vs_pjrt() {
    let Some(path) = artifact("qgemm.hlo.txt") else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    for seed in [1u64, 2, 3] {
        let r = crosscheck_qgemm(&rt, &path, seed).expect("crosscheck runs");
        assert_eq!(r.checked, GOLDEN_M * GOLDEN_N);
        assert_eq!(r.mismatches, 0, "seed {seed}: integer mismatch between sim and JAX");
    }
}

#[test]
fn qgemm_artifact_shapes_match_contract() {
    let Some(path) = artifact("qgemm.hlo.txt") else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let art = rt.load(&path).expect("compile artifact");
    let a = vec![1i32; GOLDEN_M * GOLDEN_K];
    let w = vec![1i32; GOLDEN_K * GOLDEN_N];
    let out = art.run_i32(&[(&a, &[GOLDEN_M, GOLDEN_K]), (&w, &[GOLDEN_K, GOLDEN_N])]).unwrap();
    assert_eq!(out.len(), 2, "expected (acc, asum)");
    assert_eq!(out[0].len(), GOLDEN_M * GOLDEN_N);
    assert_eq!(out[1].len(), GOLDEN_M);
    // all-ones codes: acc = K, asum = K.
    assert!(out[0].iter().all(|&v| v == GOLDEN_K as i32));
    assert!(out[1].iter().all(|&v| v == GOLDEN_K as i32));
}

#[test]
fn qnet_artifact_runs_end_to_end() {
    let Some(path) = artifact("qnet.hlo.txt") else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let art = rt.load(&path).expect("compile qnet artifact");
    let x = vec![2i32; 16 * 16 * 64];
    let logits = art.run_i32_to_f32(&[(&x, &[16, 16, 64])]).expect("qnet executes");
    assert_eq!(logits[0].len(), 10);
    assert!(logits[0].iter().all(|v| v.is_finite()));
    // Determinism: constants are baked, same input → same logits.
    let logits2 = art.run_i32_to_f32(&[(&x, &[16, 16, 64])]).unwrap();
    assert_eq!(logits[0], logits2[0]);
}
