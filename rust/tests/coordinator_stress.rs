//! Stress: many concurrent submitters against a small worker pool with a
//! small bounded queue — no deadlock, every accepted request answered,
//! `served()` consistent with the accepted-submission count, and
//! backpressure visible under load.

use std::sync::Arc;
use std::time::Duration;

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest, SubmitError};

const SUBMITTERS: usize = 32;
const PER_SUBMITTER: u64 = 8;

#[test]
fn concurrent_submitters_all_get_answers() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 3;
    cfg.batch_size = 8;
    cfg.batch_timeout = Duration::from_millis(2);
    cfg.max_queue = 16; // small on purpose: exercises the BUSY/retry path
    let coord = Arc::new(Coordinator::start(cfg));

    // Warm the timing cache so the storm measures the steady-state path.
    coord
        .submit(InferenceRequest { id: u64::MAX, input: None, net: None, schedule: None, shards: None })
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap();

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|t| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                for k in 0..PER_SUBMITTER {
                    let id = (t as u64) * PER_SUBMITTER + k;
                    // Retry on backpressure until accepted.
                    let rx = loop {
                        match coord.submit(InferenceRequest { id, input: None, net: None, schedule: None, shards: None }) {
                            Ok(rx) => break rx,
                            Err(SubmitError::Busy { .. }) => {
                                std::thread::sleep(Duration::from_millis(1))
                            }
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                    };
                    let resp = rx
                        .recv_timeout(Duration::from_secs(120))
                        .expect("response must arrive (no deadlock)");
                    assert_eq!(resp.id, id);
                    assert!(resp.sim_cycles > 0);
                    ids.push(resp.id);
                }
                ids
            })
        })
        .collect();

    let mut all_ids: Vec<u64> = Vec::new();
    for h in handles {
        all_ids.extend(h.join().expect("submitter thread must not panic"));
    }
    all_ids.sort_unstable();
    let total = (SUBMITTERS as u64) * PER_SUBMITTER;
    assert_eq!(all_ids.len() as u64, total, "every request answered exactly once");
    for (i, &id) in all_ids.iter().enumerate() {
        assert_eq!(id, i as u64, "ids cover the full range with no dupes/losses");
    }

    // served() counts exactly the accepted submissions (storm + warmup).
    assert_eq!(coord.served(), total + 1);

    let s = coord.stats();
    assert_eq!(s.queue_depth, 0, "queue drains completely");
    assert_eq!(s.cache_misses, 1, "only the warmup batch simulates timing");
    assert!(s.cache_hits >= 1, "the storm is served from the timing cache");
    assert!(s.utilization.len() == 3);

    let coord = Arc::try_unwrap(coord).ok().expect("all clients done");
    coord.shutdown();
}
