//! Stress: many concurrent submitters against a small worker pool with a
//! small bounded queue — no deadlock, every accepted request answered,
//! `served()` consistent with the accepted-submission count, and
//! backpressure visible under load.
//!
//! Admission-control stress rides along: the conservation invariant
//! (`submitted == served + rejected + expired + degraded`) under 32
//! concurrent submitters mixing deadlines with degradable traffic,
//! deadline expiry counted (never lost), degraded requests answered with
//! the fallback schedule's label, and mixed-DeployKey traffic never
//! coalesced into one batch.

use std::sync::Arc;
use std::time::Duration;

use quark::coordinator::{
    Coordinator, CoordinatorConfig, DegradePolicy, InferenceRequest, Priority, ServeError,
    SubmitError,
};
use quark::nn::model::{Precision, PrecisionMap};

const SUBMITTERS: usize = 32;
const PER_SUBMITTER: u64 = 8;

const W1A1: Precision = Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true };

#[test]
fn concurrent_submitters_all_get_answers() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 3;
    cfg.batch_size = 8;
    cfg.batch_timeout = Duration::from_millis(2);
    cfg.max_queue = 16; // small on purpose: exercises the BUSY/retry path
    let coord = Arc::new(Coordinator::start(cfg));

    // Warm the timing cache so the storm measures the steady-state path.
    coord
        .submit(InferenceRequest { id: u64::MAX, ..Default::default() })
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .unwrap();

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|t| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                for k in 0..PER_SUBMITTER {
                    let id = (t as u64) * PER_SUBMITTER + k;
                    // Retry on backpressure until accepted.
                    let rx = loop {
                        match coord.submit(InferenceRequest { id, ..Default::default() }) {
                            Ok(rx) => break rx,
                            Err(SubmitError::Busy { .. }) => {
                                std::thread::sleep(Duration::from_millis(1))
                            }
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                    };
                    let resp = rx
                        .recv_timeout(Duration::from_secs(120))
                        .expect("response must arrive (no deadlock)")
                        .expect("undeadlined requests never expire");
                    assert_eq!(resp.id, id);
                    assert!(resp.sim_cycles > 0);
                    ids.push(resp.id);
                }
                ids
            })
        })
        .collect();

    let mut all_ids: Vec<u64> = Vec::new();
    for h in handles {
        all_ids.extend(h.join().expect("submitter thread must not panic"));
    }
    all_ids.sort_unstable();
    let total = (SUBMITTERS as u64) * PER_SUBMITTER;
    assert_eq!(all_ids.len() as u64, total, "every request answered exactly once");
    for (i, &id) in all_ids.iter().enumerate() {
        assert_eq!(id, i as u64, "ids cover the full range with no dupes/losses");
    }

    // served() counts exactly the accepted submissions (storm + warmup).
    assert_eq!(coord.served(), total + 1);

    let s = coord.stats();
    assert_eq!(s.queue_depth, 0, "queue drains completely");
    assert_eq!(s.cache_misses, 1, "only the warmup batch simulates timing");
    assert!(s.cache_hits >= 1, "the storm is served from the timing cache");
    assert!(s.utilization.len() == 3);

    let coord = Arc::try_unwrap(coord).ok().expect("all clients done");
    coord.shutdown();
}

/// Conservation under admission control: every accepted submission ends in
/// exactly one of {served, expired, degraded}, every rejection is counted,
/// and client-side tallies agree with the coordinator's counters.
#[test]
fn admission_storm_conserves_every_request() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 2;
    cfg.batch_size = 4;
    cfg.batch_timeout = Duration::from_millis(2);
    cfg.max_queue = 8; // tiny: forces BUSY, deep queues, and degrade trips
    cfg.degrade = Some(DegradePolicy { schedule: PrecisionMap::uniform(W1A1), depth: 4 });
    let coord = Arc::new(Coordinator::start(cfg));

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|t| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let (mut served, mut rejected, mut expired, mut degraded) = (0u64, 0u64, 0u64, 0u64);
                for k in 0..PER_SUBMITTER {
                    let id = (t as u64) * PER_SUBMITTER + k;
                    // A third of the traffic carries an already-passed
                    // deadline (deterministic expiry); the rest is
                    // degrade-eligible default traffic. No retry loop: a
                    // BUSY is terminal for that request and tallied.
                    let deadline_ms = if k % 3 == 0 { Some(0) } else { None };
                    let req = InferenceRequest {
                        id,
                        deadline_ms,
                        prio: match k % 3 {
                            0 => Priority::High,
                            1 => Priority::Normal,
                            _ => Priority::Low,
                        },
                        ..Default::default()
                    };
                    match coord.submit(req) {
                        Err(SubmitError::Busy { .. }) => rejected += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                        Ok(rx) => {
                            match rx
                                .recv_timeout(Duration::from_secs(120))
                                .expect("response must arrive (no deadlock)")
                            {
                                Ok(resp) => {
                                    assert_eq!(resp.id, id);
                                    if resp.degraded {
                                        assert_eq!(
                                            resp.precision, "w1a1",
                                            "degraded requests run the fallback schedule"
                                        );
                                        degraded += 1;
                                    } else {
                                        served += 1;
                                    }
                                }
                                Err(ServeError::Expired { deadline_ms, .. }) => {
                                    assert_eq!(deadline_ms, 0, "only deadline_ms=0 expires here");
                                    expired += 1;
                                }
                            }
                        }
                    }
                }
                (served, rejected, expired, degraded)
            })
        })
        .collect();

    let (mut served, mut rejected, mut expired, mut degraded) = (0u64, 0u64, 0u64, 0u64);
    for h in handles {
        let (s, r, e, d) = h.join().expect("submitter thread must not panic");
        served += s;
        rejected += r;
        expired += e;
        degraded += d;
    }
    let total = (SUBMITTERS as u64) * PER_SUBMITTER;
    assert_eq!(
        served + rejected + expired + degraded,
        total,
        "every submission lands in exactly one bucket"
    );
    // The coordinator's counters agree with the client-side tallies.
    assert_eq!(coord.served(), served);
    assert_eq!(coord.rejected(), rejected);
    assert_eq!(coord.expired(), expired);
    assert_eq!(coord.degraded(), degraded);
    let s = coord.stats();
    assert_eq!(s.served + s.rejected + s.expired + s.degraded, total, "conservation");
    assert_eq!(s.queue_depth, 0, "queue drains completely");
    // Per-model counts include degraded completions but not drops.
    let by_model: u64 = s.served_by_model.iter().map(|(_, n)| n).sum();
    assert_eq!(by_model, served + degraded);
    // Every dequeue (completion or expiry) recorded its queue age.
    assert_eq!(s.queue_age_hist.iter().sum::<u64>(), served + degraded + expired);

    let coord = Arc::try_unwrap(coord).ok().expect("all clients done");
    coord.shutdown();
}

/// Deadline expiry is counted, never lost: with every request carrying an
/// already-passed deadline, nothing runs, nothing deadlocks, and the
/// expired counter accounts for all of them.
#[test]
fn expired_requests_are_counted_not_lost() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 1;
    cfg.batch_size = 4;
    cfg.batch_timeout = Duration::from_millis(1);
    let coord = Coordinator::start(cfg);
    let n = 24u64;
    let rxs: Vec<_> = (0..n)
        .map(|id| {
            coord
                .submit(InferenceRequest { id, deadline_ms: Some(0), ..Default::default() })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let res = rx.recv_timeout(Duration::from_secs(120)).expect("expiry must be answered");
        assert!(
            matches!(res, Err(ServeError::Expired { .. })),
            "deadline_ms=0 must expire, got {res:?}"
        );
    }
    assert_eq!(coord.expired(), n);
    assert_eq!(coord.served(), 0, "expired requests never run");
    assert_eq!(coord.degraded(), 0);
    // Regression: expired requests must land in the queue-age histogram
    // like served ones do — every admitted request leaves exactly one
    // age sample, so the buckets sum to n even when nothing was served.
    let hist_sum: u64 = coord.stats().queue_age_hist.iter().sum();
    assert_eq!(hist_sum, n, "each expired request contributes one queue-age sample");
    coord.shutdown();
}

/// Mixed-DeployKey traffic is never coalesced: requests claimed into one
/// worker batch are split into per-key groups, so every batch_id maps to
/// exactly one (model, schedule, shards) triple.
#[test]
fn batches_never_mix_deploy_keys() {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 1;
    cfg.batch_size = 8;
    // A long fill window so the probes below are claimed as ONE batch.
    cfg.batch_timeout = Duration::from_millis(500);
    cfg.models.push(Arc::new(quark::nn::zoo::model("mlp@10").unwrap()));
    let coord = Arc::new(Coordinator::start(cfg));

    // Occupy the single worker with a functional request so the probes
    // queue up behind it and get claimed together.
    let n = 32 * 32 * 3;
    let blocker = coord
        .submit(InferenceRequest { id: 999, input: Some(vec![7u8; n]), ..Default::default() })
        .unwrap();
    while coord.stats().queue_depth > 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    // Eight probes alternating between two deployed models (two distinct
    // DeployKeys), plus a schedule override making a third key.
    let rxs: Vec<_> = (0..8u64)
        .map(|id| {
            let req = match id % 3 {
                0 => InferenceRequest { id, ..Default::default() },
                1 => InferenceRequest { id, net: Some("mlp@10".into()), ..Default::default() },
                _ => InferenceRequest {
                    id,
                    schedule: Some(PrecisionMap::uniform(Precision::Int8)),
                    ..Default::default()
                },
            };
            coord.submit(req).unwrap()
        })
        .collect();
    blocker.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
    let resps: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap())
        .collect();

    // Same batch_id ⇒ same (model, precision, shards): groups never span keys.
    for a in &resps {
        for b in &resps {
            if a.batch_id == b.batch_id {
                assert_eq!(a.model, b.model, "batch {} mixes models", a.batch_id);
                assert_eq!(a.precision, b.precision, "batch {} mixes schedules", a.batch_id);
                assert_eq!(a.shards, b.shards, "batch {} mixes shard counts", a.batch_id);
            }
        }
    }
    // The probes really were claimed together: at least one per-key group
    // holds 2+ requests (8 probes over 3 keys cannot all be singletons
    // when claimed as one batch).
    let max_group = resps
        .iter()
        .map(|r| resps.iter().filter(|o| o.batch_id == r.batch_id).count())
        .max()
        .unwrap();
    assert!(max_group >= 2, "expected some per-key batching, got max group {max_group}");
    // And the two models never share a batch id.
    let tiny_ids: Vec<u64> =
        resps.iter().filter(|r| r.model == "tiny@100").map(|r| r.batch_id).collect();
    let mlp_ids: Vec<u64> =
        resps.iter().filter(|r| r.model == "mlp@10").map(|r| r.batch_id).collect();
    assert!(!tiny_ids.is_empty() && !mlp_ids.is_empty());
    assert!(
        tiny_ids.iter().all(|id| !mlp_ids.contains(id)),
        "models must never share a batch: tiny {tiny_ids:?} vs mlp {mlp_ids:?}"
    );

    let coord = Arc::try_unwrap(coord).ok().expect("all clients done");
    coord.shutdown();
}
