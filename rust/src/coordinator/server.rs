//! Line-protocol TCP front-end for the coordinator.
//!
//! Protocol (text, one request per line — see `docs/serving.md`):
//! ```text
//! PING                      → PONG
//! MODELS                    → MODELS n=<count> default=<name> models=<a,b,…>
//! STATS                     → STATS served=<n> rejected=<n> expired=<n>
//!                                   degraded=<n>
//!                                   by_model=<name>:<n>[,<name>:<n>…]
//!                                   queue_depth=<n>
//!                                   workers=<n> cache_hits=<n> cache_misses=<n>
//!                                   prog_hits=<n> prog_misses=<n>
//!                                   verify_fails=<n>
//!                                   compile_us=<n> replay_us=<n>
//!                                   compile_by_worker=<c0,c1,…>
//!                                   sync_cycles=<n> shard_util=<s0,…|->
//!                                   stage_util=<s0,…|-> bubble_cycles=<n>
//!                                   p50_us=<n> p95_us=<n> p99_us=<n>
//!                                   lat_min_us=<n> lat_max_us=<n>
//!                                   queue_age_hist=<c0,…,c11>
//!                                   slo=<name>:<p50>/<p95>/<p99>/<min>/<max>[,…]
//!                                   util=<u0,u1,…>
//!                                   uptime_ms=<n> trace_dropped=<n>
//!                                   class_mix=<name>:<f0/…/f5|->[,…]
//! TRACE                     → TRACE events=<n> dropped=<n> sim_tracks=<k>
//!                                   written=<path|->
//!                             drains the request-lifecycle trace rings
//!                             ([`crate::obs`]); with `serve --trace <path>`
//!                             the drained spans plus the default programs'
//!                             cycle-attribution profiles are written as
//!                             Chrome trace-event JSON at `<path>` (and
//!                             folded stacks at `<path>.folded`), else
//!                             `written=-`. `ERR tracing disabled` when the
//!                             server was started without tracing.
//! INFER <id> [net=<name>] [prec=<spec>] [mode=<tensor|pipeline>]
//!       [shards=<n>] [stages=<n>] [deadline_ms=<ms>]
//!       [prio=<low|normal|high>] [<b0,b1,...>]
//!                           → OK <id> cycles=<c> device_us=<t> worker=<w>
//!                                   batch=<b> cached=<0|1> prec=<label>
//!                                   net=<name> shards=<n> sync_cycles=<s>
//!                                   prio=<p> degraded=<0|1> mode=<m>
//!                                   stages=<n>
//!                             with input bytes: plus ` argmax=<k>
//!                             logits=<v0,v1,…>` — the bytes are run through
//!                             the functional executor and the real outputs
//!                             returned
//!                           → EXPIRED <id> waited_ms=<w> deadline_ms=<d>
//!                             when the deadline passed while queued (the
//!                             request was dropped at claim time, unrun)
//! QUIT                      → closes the connection
//! ```
//! The optional `net=` field selects a deployed model by name (`MODELS`
//! lists them; `serve --models a,b,c` deploys them); without it the
//! deployment's default (first) model serves the request, and unknown names
//! answer `ERR invalid request: unknown model …`. The optional `prec=`
//! field is a [`PrecisionMap`] spec (`default[;layer=precision…]`, e.g.
//! `prec=int8` or `prec=w2a2;c1=int8;fc=int8`) selecting a per-request
//! precision schedule; without it the deployment default applies. The
//! optional `shards=` field selects a tensor-parallel shard count
//! ([`crate::cluster`]): the inference is partitioned over that many
//! simulated cores, `cycles=` reports the cluster model (`max` shard
//! compute + all-gather sync), and the logits are bit-identical to a
//! single-core run. The optional `mode=` field selects the parallelism
//! axis: `tensor` (the default — layers split across shard cores) or
//! `pipeline` (contiguous layer ranges staged across cores,
//! [`crate::cluster::pipeline`]); `stages=` sets the pipeline depth.
//! The two axes don't compose: `mode=pipeline` with `shards=` > 1 (or
//! `stages=` > 1 without `mode=pipeline`) answers `ERR invalid request`.
//! Pipelined replies report `cycles=` as the fill latency of one request
//! through every stage and `sync_cycles=` as the Σ of inter-stage hop
//! costs; logits remain bit-identical to a single-core run. The optional
//! `deadline_ms=` field bounds how long the
//! request may wait in the queue: if the deadline passes before a worker
//! claims it, the reply is `EXPIRED` (counted in STATS `expired=`) instead
//! of a late `OK`. The optional `prio=` field (`low`/`normal`/`high`,
//! default `normal`) orders claims within the queue: higher classes are
//! claimed first, FIFO within a class. Under a deployment-configured
//! degrade policy (`serve --degrade`), requests that pin neither `prec=`
//! nor `shards=` may be rerouted to the cheaper fallback schedule when the
//! queue is deep — the reply then carries `degraded=1` and the fallback's
//! `prec=` label. Malformed requests answer `ERR <reason>`; a full queue
//! answers `BUSY <reason>`. Neither kills the connection — clients keep the
//! socket and retry. (No JSON library exists in this offline environment; a
//! line protocol keeps the wire format trivially testable with netcat.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::ClusterMode;
use crate::error::Result;
use crate::nn::model::PrecisionMap;

use super::{Coordinator, InferenceRequest, Priority, ServeError, SubmitError};

/// Hard cap on explicit input payloads: the shared CIFAR-sized input plane
/// every model reads a prefix of ([`crate::nn::INPUT_ELEMS`]). Longer
/// payloads are rejected, not truncated.
pub const MAX_INPUT_BYTES: usize = crate::nn::INPUT_ELEMS;

/// Serve until the process is killed. Binds `addr` (e.g. "127.0.0.1:7070").
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    serve_traced(coord, addr, None)
}

/// [`serve`] with request-lifecycle tracing armed when `trace` is set: the
/// coordinator records spans into its bounded rings, and every `TRACE`
/// command drains them to Chrome trace-event JSON at the given path (plus
/// folded stacks at `<path>.folded`). `None` leaves tracing off — the
/// serving path then pays only a pointer check per hook.
pub fn serve_traced(coord: Arc<Coordinator>, addr: &str, trace: Option<PathBuf>) -> Result<()> {
    let trace = trace.map(|p| {
        coord.enable_tracing();
        Arc::new(p)
    });
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "quark coordinator listening on {addr} ({} workers, machine {}, batch≤{}, queue≤{}, models [{}]{})",
        coord.config().workers,
        coord.config().machine.name,
        coord.config().batch_size,
        coord.config().max_queue,
        coord.config().models.iter().map(|m| m.name()).collect::<Vec<_>>().join(", "),
        match &trace {
            Some(p) => format!(", tracing → {}", p.display()),
            None => String::new(),
        }
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = coord.clone();
        let trace = trace.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_client(coord, stream, trace) {
                eprintln!("client error: {e}");
            }
        });
    }
    Ok(())
}

/// Parse the optional `INFER` input payload. `Ok(None)` = timing-only.
fn parse_input(csv: Option<&str>) -> std::result::Result<Option<Vec<u8>>, String> {
    let Some(csv) = csv else { return Ok(None) };
    let mut bytes = Vec::new();
    for tok in csv.split(',') {
        match tok.trim().parse::<u8>() {
            Ok(b) => bytes.push(b),
            Err(_) => return Err(format!("bad input byte {tok:?} (want comma-separated u8)")),
        }
    }
    if bytes.len() > MAX_INPUT_BYTES {
        return Err(format!("input too large ({} > {MAX_INPUT_BYTES} bytes)", bytes.len()));
    }
    Ok(Some(bytes))
}

pub(crate) fn handle_client(
    coord: Arc<Coordinator>,
    stream: TcpStream,
    trace: Option<Arc<PathBuf>>,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(300)))?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next().unwrap_or("") {
            "PING" => writeln!(writer, "PONG")?,
            "MODELS" => {
                let models = &coord.config().models;
                let names: Vec<&str> = models.iter().map(|m| m.name()).collect();
                writeln!(
                    writer,
                    "MODELS n={} default={} models={}",
                    models.len(),
                    names[0],
                    names.join(",")
                )?
            }
            "STATS" => {
                let s = coord.stats();
                let util: Vec<String> =
                    s.utilization.iter().map(|u| format!("{u:.2}")).collect();
                let by_model: Vec<String> = s
                    .served_by_model
                    .iter()
                    .map(|(name, n)| format!("{name}:{n}"))
                    .collect();
                let cbw: Vec<String> =
                    s.compile_by_worker.iter().map(|c| c.to_string()).collect();
                let util_csv = |us: &[f64]| {
                    if us.is_empty() {
                        "-".to_string()
                    } else {
                        us.iter().map(|u| format!("{u:.2}")).collect::<Vec<_>>().join(",")
                    }
                };
                let shard_util = util_csv(&s.shard_util);
                let stage_util = util_csv(&s.stage_util);
                let hist: Vec<String> =
                    s.queue_age_hist.iter().map(|c| c.to_string()).collect();
                let slo: Vec<String> = s
                    .slo_by_model
                    .iter()
                    .map(|m| {
                        format!(
                            "{}:{}/{}/{}/{}/{}",
                            m.model, m.p50_us, m.p95_us, m.p99_us, m.min_us, m.max_us
                        )
                    })
                    .collect();
                let class_mix: Vec<String> = s
                    .class_mix
                    .iter()
                    .map(|m| match &m.fractions {
                        Some(fr) => {
                            let fs: Vec<String> = fr.iter().map(|f| format!("{f:.3}")).collect();
                            format!("{}:{}", m.model, fs.join("/"))
                        }
                        None => format!("{}:-", m.model),
                    })
                    .collect();
                writeln!(
                    writer,
                    "STATS served={} rejected={} expired={} degraded={} by_model={} \
                     queue_depth={} workers={} \
                     cache_hits={} cache_misses={} prog_hits={} prog_misses={} \
                     verify_fails={} \
                     compile_us={} replay_us={} compile_by_worker={} \
                     sync_cycles={} shard_util={} stage_util={} bubble_cycles={} \
                     p50_us={} p95_us={} p99_us={} lat_min_us={} lat_max_us={} \
                     queue_age_hist={} slo={} util={} \
                     uptime_ms={} trace_dropped={} class_mix={}",
                    s.served,
                    s.rejected,
                    s.expired,
                    s.degraded,
                    by_model.join(","),
                    s.queue_depth,
                    s.workers,
                    s.cache_hits,
                    s.cache_misses,
                    s.program_hits,
                    s.program_misses,
                    s.verify_fails,
                    s.compile_us,
                    s.replay_us,
                    cbw.join(","),
                    s.sync_cycles,
                    shard_util,
                    stage_util,
                    s.bubble_cycles,
                    s.p50_us,
                    s.p95_us,
                    s.p99_us,
                    s.min_us,
                    s.max_us,
                    hist.join(","),
                    slo.join(","),
                    util.join(","),
                    s.uptime_ms,
                    s.trace_dropped,
                    class_mix.join(",")
                )?
            }
            "TRACE" => match coord.tracer() {
                None => writeln!(writer, "ERR tracing disabled (serve --trace <path> enables it)")?,
                Some(tr) => {
                    let events = tr.drain();
                    let dropped = tr.dropped();
                    let profiles: Vec<crate::obs::ProgramProfile> =
                        coord.default_profiles().into_iter().flatten().collect();
                    let written = match &trace {
                        Some(path) => {
                            let json = crate::obs::export::chrome_trace_json(&events, &profiles);
                            let folded = crate::obs::export::folded_stacks(&events, &profiles);
                            let mut folded_path = path.as_os_str().to_owned();
                            folded_path.push(".folded");
                            match std::fs::write(path.as_ref(), json)
                                .and_then(|()| std::fs::write(&folded_path, folded))
                            {
                                Ok(()) => path.display().to_string(),
                                Err(e) => {
                                    writeln!(writer, "ERR trace write failed: {e}")?;
                                    continue;
                                }
                            }
                        }
                        None => "-".to_string(),
                    };
                    writeln!(
                        writer,
                        "TRACE events={} dropped={} sim_tracks={} written={}",
                        events.len(),
                        dropped,
                        profiles.len(),
                        written
                    )?
                }
            },
            "QUIT" => break,
            "INFER" => {
                let id: u64 = match parts.next().and_then(|s| s.parse().ok()) {
                    Some(id) => id,
                    None => {
                        writeln!(writer, "ERR missing/invalid id")?;
                        continue;
                    }
                };
                // Optional model selector, per-request precision schedule,
                // parallelism mode, and shard/stage counts (any order, each
                // at most once).
                let mut next_tok = parts.next();
                let mut net = None;
                let mut schedule = None;
                let mut shards = None;
                let mut mode = None;
                let mut stages = None;
                let mut deadline_ms = None;
                let mut prio = None;
                let mut wire_err = None;
                while let Some(tok) = next_tok {
                    if let Some(name) = tok.strip_prefix("net=") {
                        if net.is_some() {
                            wire_err = Some("duplicate net= field".to_string());
                            break;
                        }
                        if name.is_empty() {
                            wire_err = Some("empty net= field".to_string());
                            break;
                        }
                        net = Some(name.to_string());
                    } else if let Some(spec) = tok.strip_prefix("prec=") {
                        if schedule.is_some() {
                            wire_err = Some("duplicate prec= field".to_string());
                            break;
                        }
                        match PrecisionMap::parse(spec) {
                            Ok(m) => schedule = Some(m),
                            Err(reason) => {
                                wire_err = Some(format!("bad precision: {reason}"));
                                break;
                            }
                        }
                    } else if let Some(spec) = tok.strip_prefix("shards=") {
                        if shards.is_some() {
                            wire_err = Some("duplicate shards= field".to_string());
                            break;
                        }
                        match spec.parse::<usize>() {
                            Ok(n) => shards = Some(n),
                            Err(_) => {
                                wire_err =
                                    Some(format!("bad shards field {spec:?} (want an integer)"));
                                break;
                            }
                        }
                    } else if let Some(spec) = tok.strip_prefix("mode=") {
                        if mode.is_some() {
                            wire_err = Some("duplicate mode= field".to_string());
                            break;
                        }
                        match ClusterMode::parse(spec) {
                            Ok(m) => mode = Some(m),
                            Err(reason) => {
                                wire_err = Some(reason);
                                break;
                            }
                        }
                    } else if let Some(spec) = tok.strip_prefix("stages=") {
                        if stages.is_some() {
                            wire_err = Some("duplicate stages= field".to_string());
                            break;
                        }
                        match spec.parse::<usize>() {
                            Ok(n) => stages = Some(n),
                            Err(_) => {
                                wire_err =
                                    Some(format!("bad stages field {spec:?} (want an integer)"));
                                break;
                            }
                        }
                    } else if let Some(spec) = tok.strip_prefix("deadline_ms=") {
                        if deadline_ms.is_some() {
                            wire_err = Some("duplicate deadline_ms= field".to_string());
                            break;
                        }
                        match spec.parse::<u64>() {
                            Ok(ms) => deadline_ms = Some(ms),
                            Err(_) => {
                                wire_err = Some(format!(
                                    "bad deadline_ms field {spec:?} (want milliseconds)"
                                ));
                                break;
                            }
                        }
                    } else if let Some(spec) = tok.strip_prefix("prio=") {
                        if prio.is_some() {
                            wire_err = Some("duplicate prio= field".to_string());
                            break;
                        }
                        match Priority::parse(spec) {
                            Some(p) => prio = Some(p),
                            None => {
                                wire_err = Some(format!(
                                    "bad prio field {spec:?} (want low|normal|high)"
                                ));
                                break;
                            }
                        }
                    } else {
                        break;
                    }
                    next_tok = parts.next();
                }
                if let Some(reason) = wire_err {
                    writeln!(writer, "ERR {reason}")?;
                    continue;
                }
                let input = match parse_input(next_tok) {
                    Ok(v) => v,
                    Err(reason) => {
                        writeln!(writer, "ERR {reason}")?;
                        continue;
                    }
                };
                if parts.next().is_some() {
                    writeln!(writer, "ERR trailing garbage after input")?;
                    continue;
                }
                let req = InferenceRequest {
                    id,
                    input,
                    net,
                    schedule,
                    shards,
                    mode,
                    stages,
                    deadline_ms,
                    prio: prio.unwrap_or_default(),
                };
                match coord.submit(req) {
                    Err(SubmitError::Busy { depth }) => {
                        writeln!(writer, "BUSY queue full (depth {depth})")?
                    }
                    Err(SubmitError::Invalid { reason }) => {
                        writeln!(writer, "ERR invalid request: {reason}")?
                    }
                    Ok(rx) => match rx.recv() {
                        Ok(Ok(r)) => {
                            let mut reply = format!(
                                "OK {} cycles={} device_us={:.1} worker={} batch={} cached={} \
                                 prec={} net={} shards={} sync_cycles={} prio={} degraded={} \
                                 mode={} stages={}",
                                r.id,
                                r.sim_cycles,
                                r.device_us,
                                r.worker,
                                r.batch_id,
                                r.timing_cached as u8,
                                r.precision,
                                r.model,
                                r.shards,
                                r.sync_cycles,
                                r.prio.label(),
                                r.degraded as u8,
                                r.mode.label(),
                                r.stages
                            );
                            if let (Some(am), Some(lg)) = (r.argmax, r.logits.as_ref()) {
                                let csv: Vec<String> =
                                    lg.iter().map(|v| format!("{v}")).collect();
                                reply.push_str(&format!(" argmax={am} logits={}", csv.join(",")));
                            }
                            writeln!(writer, "{reply}")?
                        }
                        Ok(Err(ServeError::Expired { waited_ms, deadline_ms })) => writeln!(
                            writer,
                            "EXPIRED {id} waited_ms={waited_ms} deadline_ms={deadline_ms}"
                        )?,
                        Err(_) => writeln!(writer, "ERR worker dropped")?,
                    },
                }
            }
            other => writeln!(writer, "ERR unknown command {other}")?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    /// Spawn a handler for exactly one client connection; returns its addr.
    fn one_shot_server(coord: Arc<Coordinator>) -> std::net::SocketAddr {
        one_shot_server_traced(coord, None)
    }

    /// [`one_shot_server`] with a TRACE output path wired through (the
    /// caller arms tracing on the coordinator itself).
    fn one_shot_server_traced(
        coord: Arc<Coordinator>,
        trace: Option<Arc<PathBuf>>,
    ) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = handle_client(coord, stream, trace);
        });
        addr
    }

    fn small_cfg() -> CoordinatorConfig {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 2;
        cfg.batch_timeout = Duration::from_millis(2);
        cfg
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "INFER 7").unwrap();
        writeln!(client, "STATS").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines[0], "PONG");
        assert!(lines[1].starts_with("OK 7 cycles="), "{}", lines[1]);
        assert!(lines[1].contains(" cached="), "{}", lines[1]);
        assert!(!lines[1].contains("logits="), "timing-only reply carries no logits: {}", lines[1]);
        assert!(lines[2].starts_with("STATS served="), "{}", lines[2]);
        for field in [
            "rejected=",
            "by_model=",
            "queue_depth=",
            "cache_hits=",
            "prog_hits=",
            "prog_misses=",
            "verify_fails=",
            "compile_us=",
            "replay_us=",
            "compile_by_worker=",
            "sync_cycles=",
            "shard_util=",
            "stage_util=",
            "bubble_cycles=",
            "p50_us=",
            "p99_us=",
            "lat_min_us=",
            "lat_max_us=",
            "util=",
            "uptime_ms=",
            "trace_dropped=0",
            "class_mix=",
        ] {
            assert!(lines[2].contains(field), "missing {field}: {}", lines[2]);
        }
        assert!(lines[1].contains(" shards=1 "), "single-core reply: {}", lines[1]);
    }

    #[test]
    fn infer_accepts_a_shard_count_on_the_wire() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // Timing-only probes: single-core, then the same deployment split
        // over 2 shard cores (order of prec=/shards= is free).
        writeln!(client, "INFER 1").unwrap();
        writeln!(client, "INFER 2 shards=2").unwrap();
        writeln!(client, "INFER 3 shards=2 prec=w2a2").unwrap();
        // Bad shard counts answer ERR without killing the connection.
        writeln!(client, "INFER 4 shards=zap").unwrap();
        writeln!(client, "INFER 5 shards=999").unwrap();
        writeln!(client, "INFER 6 shards=2 shards=4").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(7).map(|l| l.unwrap()).collect();
        assert!(lines[0].contains(" shards=1 sync_cycles=0"), "{}", lines[0]);
        assert!(lines[1].contains(" shards=2 "), "{}", lines[1]);
        assert!(lines[2].contains(" shards=2 "), "{}", lines[2]);
        assert!(lines[3].starts_with("ERR bad shards field"), "{}", lines[3]);
        assert!(lines[4].starts_with("ERR invalid request"), "{}", lines[4]);
        assert!(lines[5].starts_with("ERR duplicate shards= field"), "{}", lines[5]);
        assert_eq!(lines[6], "PONG", "connection survived shard errors");
        let field = |l: &str, f: &str| -> u64 {
            l.split(f).nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap()
        };
        // The cluster model charges a real sync cost, and the sharded run
        // (which also pays it) still beats one core on modeled latency.
        assert!(field(&lines[1], "sync_cycles=") > 0, "{}", lines[1]);
        assert!(
            field(&lines[1], "cycles=") < field(&lines[0], "cycles="),
            "2-shard latency must beat single-core: {} vs {}",
            lines[1],
            lines[0]
        );
        // shards=2 with the explicit default schedule is the same deployment
        // key: identical modeled cycles.
        assert_eq!(field(&lines[1], "cycles="), field(&lines[2], "cycles="));
    }

    #[test]
    fn infer_accepts_pipeline_mode_on_the_wire() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // Timing-only probes: the single-core default, then the same
        // deployment staged over 2 pipeline cores.
        writeln!(client, "INFER 1").unwrap();
        writeln!(client, "INFER 2 mode=pipeline stages=2").unwrap();
        // mode=tensor is the explicit default; stages=1 pipeline is served
        // single-core but still echoes the mode.
        writeln!(client, "INFER 3 mode=tensor").unwrap();
        writeln!(client, "INFER 4 mode=pipeline stages=1").unwrap();
        writeln!(client, "STATS").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(6).map(|l| l.unwrap()).collect();
        assert!(lines[0].contains(" mode=tensor stages=1"), "{}", lines[0]);
        assert!(lines[1].contains(" mode=pipeline stages=2"), "{}", lines[1]);
        assert!(lines[2].contains(" mode=tensor stages=1"), "{}", lines[2]);
        assert!(lines[3].contains(" mode=pipeline stages=1"), "{}", lines[3]);
        let field = |l: &str, f: &str| -> u64 {
            l.split(f).nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap()
        };
        // The pipeline model charges real hop costs; a 1-stage pipeline
        // has no hops and serves down the single-core path.
        assert!(field(&lines[1], "sync_cycles=") > 0, "{}", lines[1]);
        assert_eq!(field(&lines[3], "sync_cycles="), 0, "{}", lines[3]);
        assert_eq!(
            field(&lines[3], "cycles="),
            field(&lines[0], "cycles="),
            "a 1-stage pipeline is cycle-exact with single-core: {} vs {}",
            lines[3],
            lines[0]
        );
        // STATS: both stage cores are reported (timing-only probes replay on
        // stage cores for the timing miss, so utilization may be 0 — the
        // field just must parse), and bubble_cycles is present.
        assert!(lines[4].contains(" stage_util="), "{}", lines[4]);
        assert!(lines[4].contains(" bubble_cycles="), "{}", lines[4]);
        assert_eq!(lines[5], "PONG");
    }

    #[test]
    fn pipeline_error_paths_keep_the_connection_alive() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // Unknown mode label.
        writeln!(client, "INFER 1 mode=ring").unwrap();
        // More stages than the net has layers (or than MAX_SHARDS allows).
        writeln!(client, "INFER 2 mode=pipeline stages=999").unwrap();
        // Duplicate fields.
        writeln!(client, "INFER 3 mode=pipeline mode=tensor").unwrap();
        writeln!(client, "INFER 4 mode=pipeline stages=2 stages=4").unwrap();
        // Unparsable stage count.
        writeln!(client, "INFER 5 mode=pipeline stages=deep").unwrap();
        // Pipeline composed with tensor sharding: one axis only.
        writeln!(client, "INFER 6 mode=pipeline stages=2 shards=2").unwrap();
        // Stages without pipeline mode.
        writeln!(client, "INFER 7 stages=2").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(8).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("ERR unknown cluster mode"), "{}", lines[0]);
        assert!(lines[1].starts_with("ERR invalid request"), "{}", lines[1]);
        assert!(lines[2].starts_with("ERR duplicate mode= field"), "{}", lines[2]);
        assert!(lines[3].starts_with("ERR duplicate stages= field"), "{}", lines[3]);
        assert!(lines[4].starts_with("ERR bad stages field"), "{}", lines[4]);
        assert!(
            lines[5].starts_with("ERR invalid request") && lines[5].contains("one parallelism axis"),
            "{}",
            lines[5]
        );
        assert!(
            lines[6].starts_with("ERR invalid request") && lines[6].contains("mode=pipeline"),
            "{}",
            lines[6]
        );
        assert_eq!(lines[7], "PONG", "connection survived all pipeline error paths");
    }

    #[test]
    fn infer_with_input_returns_logits_and_argmax() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // Full-size input (3072 bytes of 200) — real functional execution.
        let csv: Vec<String> = (0..MAX_INPUT_BYTES).map(|_| "200".to_string()).collect();
        writeln!(client, "INFER 11 {}", csv.join(",")).unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let line = reader.lines().next().unwrap().unwrap();
        assert!(line.starts_with("OK 11 cycles="), "{line}");
        assert!(line.contains(" argmax="), "{line}");
        let logits_csv = line.split("logits=").nth(1).expect("logits field");
        assert_eq!(logits_csv.split(',').count(), 100, "100-class logits");
    }

    #[test]
    fn error_paths_keep_the_connection_alive() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        let oversized: Vec<String> = (0..MAX_INPUT_BYTES + 1).map(|_| "1".to_string()).collect();
        writeln!(client, "INFER nope").unwrap(); // malformed id
        writeln!(client, "INFER").unwrap(); // missing id
        writeln!(client, "INFER 1 12,xx,13").unwrap(); // garbage CSV
        writeln!(client, "INFER 2 {}", oversized.join(",")).unwrap(); // oversized
        writeln!(client, "INFER 3 1,2 junk").unwrap(); // trailing garbage
        writeln!(client, "FROB 1").unwrap(); // unknown command
        writeln!(client, "PING").unwrap(); // connection must still work
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(7).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("ERR missing/invalid id"), "{}", lines[0]);
        assert!(lines[1].starts_with("ERR missing/invalid id"), "{}", lines[1]);
        assert!(lines[2].starts_with("ERR bad input byte"), "{}", lines[2]);
        assert!(lines[3].starts_with("ERR input too large"), "{}", lines[3]);
        assert!(lines[4].starts_with("ERR trailing garbage"), "{}", lines[4]);
        assert!(lines[5].starts_with("ERR unknown command FROB"), "{}", lines[5]);
        assert_eq!(lines[6], "PONG", "connection survived all error paths");
    }

    #[test]
    fn infer_accepts_a_precision_map_on_the_wire() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // Timing-only probes under three schedules: the deployment default
        // (w2a2), uniform int8, and a mixed map pinning c1 to int8.
        writeln!(client, "INFER 1").unwrap();
        writeln!(client, "INFER 2 prec=int8").unwrap();
        writeln!(client, "INFER 3 prec=w2a2;c1=int8").unwrap();
        // Schedules compose with input payloads (functional execution).
        writeln!(client, "INFER 4 prec=w2a2;c1=int8 7,8,9").unwrap();
        // Bad schedules answer ERR without killing the connection.
        writeln!(client, "INFER 5 prec=w9a9").unwrap();
        writeln!(client, "INFER 6 prec=int8;ghost=w2a2").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(7).map(|l| l.unwrap()).collect();
        assert!(lines[0].contains(" prec=w2a2"), "{}", lines[0]);
        assert!(lines[1].contains(" prec=int8"), "{}", lines[1]);
        assert!(lines[2].contains(" prec=mixed(w2a2+1)"), "{}", lines[2]);
        assert!(lines[3].contains(" prec=mixed(w2a2+1)"), "{}", lines[3]);
        assert!(lines[3].contains(" argmax="), "{}", lines[3]);
        assert!(lines[4].starts_with("ERR bad precision"), "{}", lines[4]);
        assert!(lines[5].starts_with("ERR invalid request"), "{}", lines[5]);
        assert_eq!(lines[6], "PONG", "connection survived schedule errors");
        // The mixed schedule costs more cycles than pure w2a2 but fewer than
        // pure int8 (c1 re-runs at 8-bit, the rest stays 2-bit).
        let cycles = |l: &str| -> u64 {
            l.split("cycles=").nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap()
        };
        let (c_w2, c_i8, c_mix) = (cycles(&lines[0]), cycles(&lines[1]), cycles(&lines[2]));
        assert!(c_w2 < c_mix && c_mix < c_i8, "w2a2 {c_w2} < mixed {c_mix} < int8 {c_i8}");
    }

    #[test]
    fn models_roundtrip_and_net_selection_on_the_wire() {
        // Two-model deployment: default tiny plus the zoo mlp.
        let mut cfg = small_cfg();
        cfg.models.push(Arc::new(crate::nn::zoo::model("mlp").unwrap()));
        let coord = Arc::new(Coordinator::start(cfg));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "MODELS").unwrap();
        writeln!(client, "INFER 1").unwrap(); // default model
        writeln!(client, "INFER 2 net=mlp@10").unwrap(); // explicit selection
        writeln!(client, "INFER 3 net=mlp@10 prec=int8 shards=2").unwrap(); // composes
        // Unknown model: ERR invalid request, connection survives.
        writeln!(client, "INFER 4 net=ghost-net").unwrap();
        writeln!(client, "INFER 5 net=mlp@10 net=tiny@100").unwrap(); // duplicate field
        writeln!(client, "STATS").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(8).map(|l| l.unwrap()).collect();
        // MODELS round-trip: count, default, full list.
        assert_eq!(lines[0], "MODELS n=2 default=tiny@100 models=tiny@100,mlp@10", "{}", lines[0]);
        assert!(lines[1].contains(" net=tiny@100 "), "{}", lines[1]);
        assert!(lines[2].contains(" net=mlp@10 "), "{}", lines[2]);
        assert!(
            lines[3].contains(" net=mlp@10 ") && lines[3].contains(" prec=int8 ")
                && lines[3].contains(" shards=2 "),
            "{}",
            lines[3]
        );
        assert!(
            lines[4].starts_with("ERR invalid request:") && lines[4].contains("unknown model"),
            "{}",
            lines[4]
        );
        assert!(lines[5].starts_with("ERR duplicate net= field"), "{}", lines[5]);
        // Per-model STATS counts: 1 on tiny, 2 on mlp, in deployment order.
        assert!(lines[6].contains(" by_model=tiny@100:1,mlp@10:2 "), "{}", lines[6]);
        assert_eq!(lines[7], "PONG", "connection survived the model errors");
        // Different models must report different timings (distinct
        // DeployKeys — the mlp is far cheaper than tiny).
        let cycles = |l: &str| -> u64 {
            l.split("cycles=").nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap()
        };
        assert!(cycles(&lines[2]) < cycles(&lines[1]), "{} vs {}", lines[2], lines[1]);
    }

    #[test]
    fn net_field_composes_with_functional_input() {
        let mut cfg = small_cfg();
        cfg.models.push(Arc::new(crate::nn::zoo::model("mlp").unwrap()));
        let coord = Arc::new(Coordinator::start(cfg));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "INFER 9 net=mlp@10 5,6,7,8").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let line = reader.lines().next().unwrap().unwrap();
        assert!(line.starts_with("OK 9 cycles="), "{line}");
        assert!(line.contains(" net=mlp@10 "), "{line}");
        assert!(line.contains(" argmax="), "{line}");
        let logits_csv = line.split("logits=").nth(1).expect("logits field");
        assert_eq!(logits_csv.split(',').count(), 10, "10-class mlp logits");
    }

    #[test]
    fn deadline_and_priority_fields_on_the_wire() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        // A generous deadline and an explicit priority: served normally, the
        // reply echoes the priority class (order of fields is free).
        writeln!(client, "INFER 1 deadline_ms=600000 prio=high").unwrap();
        // deadline_ms=0 has always passed by claim time: deterministic EXPIRED.
        writeln!(client, "INFER 2 deadline_ms=0").unwrap();
        // Malformed admission fields answer ERR without killing the connection.
        writeln!(client, "INFER 3 deadline_ms=soon").unwrap();
        writeln!(client, "INFER 4 prio=urgent").unwrap();
        writeln!(client, "INFER 5 deadline_ms=1 deadline_ms=2").unwrap();
        writeln!(client, "INFER 6 prio=low prio=high").unwrap();
        writeln!(client, "STATS").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(8).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("OK 1 "), "{}", lines[0]);
        assert!(lines[0].contains(" prio=high"), "{}", lines[0]);
        assert!(lines[0].contains(" degraded=0"), "{}", lines[0]);
        assert!(lines[1].starts_with("EXPIRED 2 waited_ms="), "{}", lines[1]);
        assert!(lines[1].contains(" deadline_ms=0"), "{}", lines[1]);
        assert!(lines[2].starts_with("ERR bad deadline_ms field"), "{}", lines[2]);
        assert!(lines[3].starts_with("ERR bad prio field"), "{}", lines[3]);
        assert!(lines[3].contains("want low|normal|high"), "{}", lines[3]);
        assert!(lines[4].starts_with("ERR duplicate deadline_ms= field"), "{}", lines[4]);
        assert!(lines[5].starts_with("ERR duplicate prio= field"), "{}", lines[5]);
        // STATS counts the expiry and exposes the SLO fields.
        assert!(lines[6].contains(" expired=1 "), "{}", lines[6]);
        assert!(lines[6].contains(" degraded=0 "), "{}", lines[6]);
        assert!(lines[6].contains(" queue_age_hist="), "{}", lines[6]);
        assert!(lines[6].contains(" slo=tiny@100:"), "{}", lines[6]);
        let hist_csv = lines[6]
            .split("queue_age_hist=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        assert_eq!(
            hist_csv.split(',').count(),
            crate::coordinator::QUEUE_AGE_BUCKETS,
            "{}",
            lines[6]
        );
        assert_eq!(lines[7], "PONG", "connection survived admission errors");
    }

    #[test]
    fn degraded_requests_reply_with_the_fallback_label() {
        use crate::coordinator::DegradePolicy;
        use crate::nn::model::Precision;
        let mut cfg = small_cfg();
        // depth 0: every eligible request degrades — deterministic.
        cfg.degrade = Some(DegradePolicy {
            schedule: PrecisionMap::uniform(Precision::Sub {
                abits: 1,
                wbits: 1,
                use_vbitpack: true,
            }),
            depth: 0,
        });
        let coord = Arc::new(Coordinator::start(cfg));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "INFER 1").unwrap(); // eligible: degrades
        writeln!(client, "INFER 2 prec=int8").unwrap(); // pinned: exempt
        writeln!(client, "STATS").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert!(lines[0].contains(" prec=w1a1 "), "{}", lines[0]);
        assert!(lines[0].contains(" degraded=1"), "{}", lines[0]);
        assert!(lines[1].contains(" prec=int8 "), "{}", lines[1]);
        assert!(lines[1].contains(" degraded=0"), "{}", lines[1]);
        assert!(lines[2].contains(" served=1 "), "{}", lines[2]);
        assert!(lines[2].contains(" degraded=1 "), "{}", lines[2]);
        assert!(lines[2].contains(" by_model=tiny@100:2 "), "{}", lines[2]);
    }

    #[test]
    fn trace_answers_err_when_tracing_is_disabled() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "TRACE").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(2).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("ERR tracing disabled"), "{}", lines[0]);
        assert_eq!(lines[1], "PONG", "TRACE without tracing must not kill the connection");
    }

    #[test]
    fn trace_drains_spans_and_writes_a_loadable_chrome_trace() {
        let coord = Arc::new(Coordinator::start(small_cfg()));
        coord.enable_tracing();
        let path =
            Arc::new(std::env::temp_dir().join(format!("quark_trace_{}.json", std::process::id())));
        let addr = one_shot_server_traced(coord.clone(), Some(path.clone()));

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "INFER 1").unwrap();
        writeln!(client, "TRACE").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(2).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("OK 1 "), "{}", lines[0]);
        assert!(lines[1].starts_with("TRACE events="), "{}", lines[1]);
        let field = |f: &str| -> String {
            lines[1].split(f).nth(1).unwrap().split_whitespace().next().unwrap().to_string()
        };
        assert!(
            field("events=").parse::<u64>().unwrap() >= 4,
            "submit+queue+claim+reply at minimum: {}",
            lines[1]
        );
        assert_eq!(field("dropped="), "0", "{}", lines[1]);
        assert_eq!(
            field("sim_tracks="),
            "1",
            "the default-schedule timing miss must have profiled the model: {}",
            lines[1]
        );
        assert_eq!(field("written="), path.display().to_string(), "{}", lines[1]);
        // The written file is a loadable Chrome trace, and the folded
        // companion carries the simulated-cycle stacks.
        let json = std::fs::read_to_string(path.as_ref()).unwrap();
        let n = crate::obs::export::validate_chrome_trace(&json).unwrap();
        assert!(n > 0, "exported trace carries events");
        let mut folded = path.as_os_str().to_owned();
        folded.push(".folded");
        let folded_txt = std::fs::read_to_string(&folded).unwrap();
        assert!(folded_txt.contains("sim;tiny@100;"), "{folded_txt}");
        let _ = std::fs::remove_file(path.as_ref());
        let _ = std::fs::remove_file(&folded);
    }

    #[test]
    fn busy_reply_when_queue_full() {
        let mut cfg = small_cfg();
        cfg.max_queue = 0; // deterministic rejection
        let coord = Arc::new(Coordinator::start(cfg));
        let addr = one_shot_server(coord);

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "INFER 5").unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(2).map(|l| l.unwrap()).collect();
        assert!(lines[0].starts_with("BUSY queue full"), "{}", lines[0]);
        assert_eq!(lines[1], "PONG", "BUSY must not kill the connection");
    }
}
