//! Line-protocol TCP front-end for the coordinator.
//!
//! Protocol (text, one request per line):
//! ```text
//! PING                      → PONG
//! STATS                     → STATS served=<n>
//! INFER <id>                → OK <id> cycles=<c> device_us=<t> worker=<w> batch=<b>
//! INFER <id> <b0,b1,...>    → same, with explicit input bytes (comma-separated u8)
//! QUIT                      → closes the connection
//! ```
//! (No JSON library exists in this offline environment; a line protocol keeps
//! the wire format trivially testable with netcat.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::{Coordinator, InferenceRequest};

/// Serve until the process is killed. Binds `addr` (e.g. "127.0.0.1:7070").
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "quark coordinator listening on {addr} ({} workers, machine {}, batch≤{})",
        coord.config().workers,
        coord.config().machine.name,
        coord.config().batch_size
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = coord.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_client(coord, stream) {
                eprintln!("client error: {e}");
            }
        });
    }
    Ok(())
}

pub(crate) fn handle_client(coord: Arc<Coordinator>, stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(300)))?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next().unwrap_or("") {
            "PING" => writeln!(writer, "PONG")?,
            "STATS" => writeln!(writer, "STATS served={}", coord.served())?,
            "QUIT" => break,
            "INFER" => {
                let id: u64 = match parts.next().and_then(|s| s.parse().ok()) {
                    Some(id) => id,
                    None => {
                        writeln!(writer, "ERR missing/invalid id")?;
                        continue;
                    }
                };
                let input: Vec<u8> = parts
                    .next()
                    .map(|csv| csv.split(',').filter_map(|v| v.parse().ok()).collect())
                    .unwrap_or_else(|| vec![0u8; 32 * 32 * 3]);
                let rx = coord.submit(InferenceRequest { id, input });
                match rx.recv() {
                    Ok(r) => writeln!(
                        writer,
                        "OK {} cycles={} device_us={:.1} worker={} batch={}",
                        r.id, r.sim_cycles, r.device_us, r.worker, r.batch_id
                    )?,
                    Err(_) => writeln!(writer, "ERR worker dropped")?,
                }
            }
            other => writeln!(writer, "ERR unknown command {other}")?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    #[test]
    fn tcp_roundtrip() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::demo()));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_coord = coord.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = handle_client(server_coord, stream);
        });

        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, "PING").unwrap();
        writeln!(client, "INFER 7").unwrap();
        writeln!(client, "STATS").unwrap();
        writeln!(client, "QUIT").unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines[0], "PONG");
        assert!(lines[1].starts_with("OK 7 cycles="), "{}", lines[1]);
        assert!(lines[2].starts_with("STATS served="), "{}", lines[2]);
    }
}
