//! Batching inference coordinator — the L3 serving layer.
//!
//! The paper's contribution is the core itself, so L3 is the "thin driver
//! plus" the workspace mandates: a request router + dynamic batcher in front
//! of a pool of simulated Quark cores (std threads; the environment has no
//! async runtime available — see Cargo.toml), with an optional PJRT
//! golden-model cross-check ([`golden`]) wired into the data path.
//!
//! Flow:
//! ```text
//! clients → submit() → bounded queue → batcher (size/timeout) → worker pool
//!               │ BUSY when full                    (one persistent core each)
//!               └───────────────────────────────────────────────────────────
//! ```
//!
//! Serving-path design (vs the original per-request loop):
//!
//! * **Persistent cores.** Each worker owns one [`Sim`] for its whole
//!   lifetime (`WorkerCore`); between requests only the bump allocator is
//!   rewound, so per-request `Sim` construction (VRF + 192 MiB of simulated
//!   memory) is paid once.
//! * **Deterministic timing cache.** Cycle counts of a `TimingOnly` run are
//!   a pure function of `(net graph, precision schedule, machine config)` —
//!   the kernels are data-independent. The coordinator memoizes them in a
//!   per-coordinator map keyed by structural fingerprints plus the
//!   [`PrecisionMap`], so repeat requests against the same deployment resolve
//!   timing with a lookup instead of a multi-ms re-simulation
//!   (`benches/coordinator_throughput.rs` measures the win).
//! * **Compiled-program cache.** Next to the timing cache, and under the
//!   same key, the coordinator caches [`CompiledProgram`] artifacts
//!   ([`crate::program::compile`]): the emitted instruction trace, buffer
//!   plan, and init image of one (net, machine, schedule) deployment. The
//!   warm serving path does **zero kernel emission** — a worker writes the
//!   request's input bytes, replays the trace
//!   ([`Sim::execute_functional`]), and reads the logits
//!   (`benches/program_replay.rs` measures the win over re-emission).
//!   Timing-cache misses also replay the cached program (`Sim::execute` in
//!   `TimingOnly`) instead of re-emitting.
//! * **Per-request precision schedules.** A request may carry its own
//!   [`PrecisionMap`] (wire: the `prec=` field of `INFER`), overriding the
//!   deployment default — the schedule-space exploration the mixed-precision
//!   papers motivate, without redeploying. Schedules are validated at
//!   submission ([`SubmitError::Invalid`]) and occupy their own timing-cache
//!   entries.
//! * **Real batched inference.** Requests that carry input bytes are run
//!   through the functional executor on the worker's persistent core; the
//!   response carries the resulting logits and argmax. Requests without
//!   input are timing-only probes.
//! * **Continuous batching.** A claimed batch is partitioned into
//!   DeployKey-pure groups — same `(model, schedule, shards)` — and each
//!   single-core group's inputs ride **one** multi-input lowered replay
//!   ([`Sim::execute_lowered_batch`]): the arena is rewound and the init
//!   image applied once per group, then only the input segment is rebound
//!   per request. Logits are bit-identical to per-request replays
//!   (`rust/tests/batching.rs`); requests never share a replay across keys
//!   (`batch_id` is per group).
//! * **Admission control.** A request may carry a deadline
//!   ([`InferenceRequest::deadline_ms`]; wire `deadline_ms=`) and a
//!   [`Priority`] (wire `prio=`). Workers claim strictly by priority (FIFO
//!   within a class), and a request whose deadline passed while queued is
//!   dropped at claim time with [`ServeError::Expired`] (wire `EXPIRED`) —
//!   counted, never run, never silently lost. Under overload an optional
//!   [`DegradePolicy`] reroutes default-schedule submissions to a cheaper
//!   deployment-configured precision schedule instead of answering plain
//!   `BUSY`; degraded responses are labeled and counted separately.
//! * **Cluster sharding.** A request may ask for its inference to be
//!   partitioned across `N` simulated cores ([`crate::cluster`]; wire: the
//!   `shards=` field of `INFER`, deployment default `serve --shards`).
//!   Shard programs live as per-shard entries under the same `DeployKey`
//!   program cache; reported cycles follow the cluster model (`max` shard
//!   compute + modeled all-gather sync), and the logits are bit-identical
//!   to single-core serving.
//! * **Multi-model serving.** The coordinator deploys a *set* of
//!   [`NetGraph`]s ([`CoordinatorConfig::models`], CLI `serve --models
//!   a,b,c`) — named zoo models ([`crate::nn::zoo`]), the first being the
//!   default. A request selects its model by name (wire: the `net=` field
//!   of `INFER`; the `MODELS` command lists the deployments); unknown names
//!   are rejected at submission. Every cache key (`DeployKey`) carries the
//!   graph fingerprint, so each model owns its own timing entries and
//!   pinned default programs, and `STATS` counts served requests per model.
//! * **Backpressure + metrics.** The queue is bounded
//!   ([`CoordinatorConfig::max_queue`]); `submit` rejects with
//!   [`SubmitError::Busy`] when full. [`Coordinator::stats`] exposes queue
//!   depth, served/rejected counts (total and per model), cache hit/miss
//!   counts (with program compiles attributed per worker), cluster
//!   sync-cycle and shard-core utilization counters, latency percentiles
//!   over a sliding window, and per-worker utilization.

pub mod golden;
pub mod server;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::arch::MachineConfig;
use crate::cluster::{
    cluster_timing, pipeline_timing, stage_costs, ClusterCores, ClusterMode, ClusterProgram,
    PipelineCores, PipelineProgram,
};
use crate::nn::model::{Precision, PrecisionMap, ShardPlan, StagePlan};
use crate::nn::{zoo, NetGraph};
use crate::obs;
use crate::program::{compile, compile_shard, compile_stage, CompiledProgram};
use crate::sim::{Sim, SimMode};

/// Upper bound on per-request shard counts (the cluster runtime spawns one
/// host thread + one persistent core per shard; 8 matches the widest
/// configuration the scaling report explores). Pipeline stage counts share
/// the same bound — either way it caps cores per request.
pub const MAX_SHARDS: usize = 8;

/// One inference request (CIFAR-sized input codes).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// Input activation codes (u8, up to 32·32·3 bytes; shorter inputs are
    /// zero-padded). `None` requests timing only — no functional execution.
    pub input: Option<Vec<u8>>,
    /// Deployed model this request targets, by [`NetGraph::name`] (wire:
    /// the `net=` field of `INFER`); `None` uses the deployment's default
    /// model (the first entry of [`CoordinatorConfig::models`]). Unknown
    /// names are rejected at submission ([`SubmitError::Invalid`]).
    pub net: Option<String>,
    /// Per-request precision schedule; `None` uses the deployment default
    /// ([`CoordinatorConfig::schedule`]).
    pub schedule: Option<PrecisionMap>,
    /// Tensor-parallel shard count ([`crate::cluster`]); `None` uses the
    /// deployment default ([`CoordinatorConfig::shards`]), 1 = single core.
    pub shards: Option<usize>,
    /// Cluster parallelism mode (wire: `mode=tensor|pipeline`); `None` uses
    /// the deployment default ([`CoordinatorConfig::mode`]). Pipeline mode
    /// cannot compose with `shards > 1` — the two pick different axes.
    pub mode: Option<ClusterMode>,
    /// Pipeline stage count ([`crate::cluster::pipeline`]; wire `stages=`);
    /// `None` uses the deployment default ([`CoordinatorConfig::stages`]).
    /// Only meaningful in pipeline mode; bounded by [`MAX_SHARDS`] and the
    /// model's layer/residual structure.
    pub stages: Option<usize>,
    /// Queue-wait budget in milliseconds (wire: `deadline_ms=`). If the
    /// request is still queued this long after submission, it is dropped at
    /// claim time with [`ServeError::Expired`] instead of running late.
    /// `None` waits indefinitely; once a worker claims a request it is
    /// always served.
    pub deadline_ms: Option<u64>,
    /// Scheduling class (wire: `prio=low|normal|high`): workers claim
    /// strictly higher classes first, FIFO within a class.
    pub prio: Priority,
}

impl Default for InferenceRequest {
    /// A timing-only probe of the deployment defaults: id 0, no input, no
    /// overrides, no deadline, [`Priority::Normal`]. Construction sites
    /// name what they care about and take the rest from here.
    fn default() -> Self {
        InferenceRequest {
            id: 0,
            input: None,
            net: None,
            schedule: None,
            shards: None,
            mode: None,
            stages: None,
            deadline_ms: None,
            prio: Priority::Normal,
        }
    }
}

/// Request priority class. `Ord` follows urgency (`Low < Normal < High`):
/// workers always claim a strictly higher class before a lower one, and
/// keep FIFO order within a class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Wire label (the `prio=` field value).
    pub fn label(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a wire label; `None` on unknown values.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// Completed inference.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Simulated device cycles for the whole network.
    pub sim_cycles: u64,
    /// Simulated device latency in microseconds (cycles / freq).
    pub device_us: f64,
    /// Wall-clock time spent queued before a worker picked the batch up.
    pub queue_time: Duration,
    /// Wall-clock simulation (service) time.
    pub service_time: Duration,
    /// Which worker/core served it.
    pub worker: usize,
    /// Batch this request was grouped into.
    pub batch_id: u64,
    /// Whether `sim_cycles` came from the timing cache (vs a fresh run).
    pub timing_cached: bool,
    /// Label of the schedule this request ran under
    /// ([`PrecisionMap::label`]; wire field `prec=`).
    pub precision: String,
    /// Name of the model this request ran on ([`NetGraph::name`]; wire
    /// field `net=`).
    pub model: String,
    /// Shard cores this request's inference was partitioned across (1 =
    /// classic single-core serving; always 1 in pipeline mode).
    pub shards: usize,
    /// Modeled inter-core transfer cycles included in `sim_cycles`: the
    /// all-gather in tensor mode, the Σ of stage-hop activation transfers
    /// in pipeline mode (0 single-core).
    pub sync_cycles: u64,
    /// Cluster parallelism mode the request ran under (wire field `mode=`).
    pub mode: ClusterMode,
    /// Pipeline stage cores the model was partitioned across (1 outside
    /// pipeline mode). In pipeline mode `sim_cycles` reports the fill
    /// latency — one request through every stage, hops included.
    pub stages: usize,
    /// True when the [`DegradePolicy`] rerouted this request to the
    /// deployment's fallback schedule at admission; `precision` then labels
    /// the fallback, not the deployment default.
    pub degraded: bool,
    /// Priority class the request was scheduled under.
    pub prio: Priority,
    /// Output of the network's last layer for the submitted input (u8 codes
    /// widened to f32 at integer precisions, raw floats at fp32). `None` for
    /// timing-only requests.
    pub logits: Option<Vec<f32>>,
    /// Index of the largest logit (first wins on ties).
    pub argmax: Option<usize>,
}

/// Why a submission was not accepted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The request queue is at capacity; back off and retry (wire: `BUSY`).
    Busy { depth: usize },
    /// The request cannot run on this deployment: unknown model name, or
    /// an invalid precision schedule / shard count for the selected model
    /// (unknown layer, fp32/integer mix, unsupported by the machine, too
    /// few channels). Not retryable as-is (wire: `ERR invalid request:`).
    Invalid { reason: String },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { depth } => write!(f, "queue full (depth {depth})"),
            SubmitError::Invalid { reason } => write!(f, "invalid request: {reason}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an *accepted* request produced no inference — delivered through the
/// response channel (the receiver [`Coordinator::submit`] returns yields
/// [`ServeResult`]s).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The deadline passed while the request waited in the queue; it was
    /// dropped at claim time without running (wire: `EXPIRED`). Counted in
    /// [`CoordStats::expired`] — distinct from [`SubmitError::Busy`], which
    /// rejects before admission.
    Expired { waited_ms: u64, deadline_ms: u64 },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Expired { waited_ms, deadline_ms } => {
                write!(f, "deadline expired after {waited_ms} ms (deadline {deadline_ms} ms)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request's receiver yields: the completed inference, or
/// the reason the coordinator dropped the request after admission.
pub type ServeResult = Result<InferenceResponse, ServeError>;

/// Overload degrade policy ([`CoordinatorConfig::degrade`]). Past `depth`
/// queued requests, submissions that don't pin their own schedule or shard
/// count are admitted under the cheaper fallback `schedule` instead of
/// riding the default toward `BUSY` — graceful degradation in the
/// mixed-precision spirit: a cheaper per-layer schedule is a fallback, not
/// a failure. Degraded responses carry [`InferenceResponse::degraded`] and
/// the fallback's precision label, and count in [`CoordStats::degraded`].
#[derive(Clone)]
pub struct DegradePolicy {
    /// The fallback schedule; validated against every deployed model at
    /// [`Coordinator::start`], exactly like the deployment default.
    pub schedule: PrecisionMap,
    /// Queue depth at or above which eligible submissions degrade (0
    /// degrades every eligible request; `>= max_queue` effectively
    /// disables the policy).
    pub depth: usize,
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub machine: MachineConfig,
    /// Default precision schedule for requests that do not carry their own.
    pub schedule: PrecisionMap,
    /// Simulated cores (worker threads).
    pub workers: usize,
    /// Max requests per batch.
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch.
    pub batch_timeout: Duration,
    /// Queue bound: submissions beyond this depth are rejected with
    /// [`SubmitError::Busy`].
    pub max_queue: usize,
    /// Default tensor-parallel shard count for requests that do not carry
    /// their own (`serve --shards N`; 1 = single-core serving).
    pub shards: usize,
    /// Default cluster parallelism mode (`serve --mode tensor|pipeline`).
    /// Pipeline deployments require `shards == 1` — the two axes don't
    /// compose.
    pub mode: ClusterMode,
    /// Default pipeline stage count (`serve --stages N`; only meaningful
    /// with [`ClusterMode::Pipeline`], 1 = single-core serving).
    pub stages: usize,
    /// Deployed models, each a validated [`NetGraph`] with a unique name.
    /// The first entry is the default for requests without `net=`
    /// (`serve --models a,b,c`).
    pub models: Vec<Arc<NetGraph>>,
    /// Optional overload degrade policy (`serve --degrade`); `None` keeps
    /// plain `BUSY`-only backpressure.
    pub degrade: Option<DegradePolicy>,
}

impl CoordinatorConfig {
    /// A small default: Quark-4L, 2-bit, the zoo's `tiny` net for snappy
    /// serving.
    pub fn demo() -> Self {
        CoordinatorConfig {
            machine: MachineConfig::quark(4),
            schedule: PrecisionMap::uniform(Precision::Sub {
                abits: 2,
                wbits: 2,
                use_vbitpack: true,
            }),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            max_queue: 256,
            shards: 1,
            mode: ClusterMode::Tensor,
            stages: 1,
            models: vec![Arc::new(demo_net())],
            degrade: None,
        }
    }

    /// The deployment's default model (the first of
    /// [`CoordinatorConfig::models`]).
    pub fn default_model(&self) -> &Arc<NetGraph> {
        &self.models[0]
    }

    /// Index of the deployed model a request's `net` field selects;
    /// `Err` names the unknown model.
    fn model_index(&self, net: Option<&str>) -> Result<usize, String> {
        match net {
            None => Ok(0),
            Some(name) => self
                .models
                .iter()
                .position(|m| m.name() == name)
                .ok_or_else(|| {
                    format!(
                        "unknown model {name:?} (deployed: {})",
                        self.models.iter().map(|m| m.name()).collect::<Vec<_>>().join(", ")
                    )
                }),
        }
    }
}

/// The serving demo model: the zoo's `tiny` graph (4 convs + pool + FC —
/// full ResNet-18 per request is a multi-second simulation; this keeps the
/// serving path interactive while exercising every kernel).
pub fn demo_net() -> NetGraph {
    zoo::model("tiny").expect("the tiny zoo entry is always valid")
}

// ---- machine fingerprint (cache-key half; the network half is
//      [`NetGraph::fingerprint`]) ----

pub use crate::program::machine_fingerprint;

/// Cache key shared by the timing cache and the program cache: the
/// deployment fingerprints plus the (canonical-form) precision schedule,
/// the parallelism mode, and the shard/stage counts the request ran under.
#[derive(Clone, PartialEq, Eq, Hash)]
struct DeployKey {
    net_fp: u64,
    machine_fp: u64,
    schedule: PrecisionMap,
    shards: usize,
    mode: ClusterMode,
    stages: usize,
}

/// Program-cache key: one entry per *shard program* of a tensor deployment
/// or per *stage program* of a pipeline deployment (`shard` is the shard
/// index or the stage index; always 0 for single-core deployments).
#[derive(Clone, PartialEq, Eq, Hash)]
struct ProgKey {
    deploy: DeployKey,
    shard: usize,
}

#[derive(Clone, Copy)]
struct TimingEntry {
    /// Modeled latency of one request: cluster total in tensor mode, fill
    /// (all stages + hops) in pipeline mode.
    sim_cycles: u64,
    /// Modeled inter-core transfer cycles included in `sim_cycles`
    /// (all-gather or Σ stage hops; 0 single-core).
    sync_cycles: u64,
    /// Pipeline steady-state initiation interval (`max` stage effective
    /// cycles); 0 outside pipeline mode. With `sim_cycles` (= fill) this
    /// reconstructs the whole stream model: `total(B) = fill + (B−1)·period`.
    period_cycles: u64,
}

/// The compiled-program cache: bounded FIFO with the deployment-default
/// entries pinned. When full, the *oldest non-default* entry is evicted to
/// admit the newcomer (clients cycling throwaway `prec=`/`shards=`
/// combinations therefore churn among themselves and can never evict a
/// deployed model's own warm path). Default-schedule inserts always
/// succeed — they are at most `models · MAX_SHARDS` programs (one default
/// per deployed model), so the cache is bounded by
/// `cap + models · MAX_SHARDS` entries.
struct ProgramCache {
    entries: HashMap<ProgKey, Arc<CompiledProgram>>,
    /// Insertion order of the evictable (non-pinned) keys.
    order: VecDeque<ProgKey>,
}

impl ProgramCache {
    fn new() -> Self {
        ProgramCache { entries: HashMap::new(), order: VecDeque::new() }
    }

    fn get(&self, key: &ProgKey) -> Option<Arc<CompiledProgram>> {
        self.entries.get(key).cloned()
    }

    /// Returns whether the insert evicted a resident entry (the tracing
    /// hooks turn that into an `Evict` event).
    fn insert(
        &mut self,
        key: ProgKey,
        prog: Arc<CompiledProgram>,
        pinned: bool,
        cap: usize,
    ) -> bool {
        if self.entries.contains_key(&key) {
            return false; // concurrent miss already inserted the identical artifact
        }
        if pinned {
            self.entries.insert(key, prog);
            return false;
        }
        let mut evicted = false;
        while self.entries.len() >= cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.entries.remove(&old);
                    evicted = true;
                }
                None => return evicted, // everything resident is pinned; don't insert
            }
        }
        self.order.push_back(key.clone());
        self.entries.insert(key, prog);
        evicted
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.entries.len()
    }
}

// ---- serving-metrics plumbing ----

/// Sliding window of recent end-to-end latencies (µs) for percentiles.
struct LatWindow {
    cap: usize,
    samples: VecDeque<u64>,
}

impl LatWindow {
    fn new(cap: usize) -> Self {
        LatWindow { cap, samples: VecDeque::with_capacity(cap) }
    }

    fn push(&mut self, us: u64) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(us);
    }

    /// Each p in [0,1]; zeros when no samples yet. One sort serves all
    /// requested percentiles (this runs under the lock workers take per
    /// response, so the hold time matters).
    fn percentiles<const N: usize>(&self, ps: [f64; N]) -> [u64; N] {
        if self.samples.is_empty() {
            return [0; N];
        }
        let mut sorted: Vec<u64> = self.samples.iter().copied().collect();
        sorted.sort_unstable();
        ps.map(|p| {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx.min(sorted.len() - 1)]
        })
    }

    /// Smallest and largest sample in the window — the outliers the
    /// percentile view truncates past p99. `(0, 0)` when no samples yet.
    fn min_max(&self) -> (u64, u64) {
        let mut iter = self.samples.iter();
        let Some(&first) = iter.next() else { return (0, 0) };
        iter.fold((first, first), |(lo, hi), &s| (lo.min(s), hi.max(s)))
    }
}

/// Snapshot of serving metrics (the extended `STATS` wire reply).
#[derive(Clone, Debug)]
pub struct CoordStats {
    /// Requests completed at their requested schedule. Disjoint from
    /// `degraded`: every accepted request ends up in exactly one of
    /// `served`, `degraded`, or `expired` (the conservation invariant
    /// `rust/tests/coordinator_stress.rs` checks).
    pub served: u64,
    pub rejected: u64,
    /// Accepted requests dropped at claim time because their deadline had
    /// passed while queued ([`ServeError::Expired`]).
    pub expired: u64,
    /// Requests rerouted to the [`DegradePolicy`] fallback schedule at
    /// admission and completed under it (disjoint from `served`).
    pub degraded: u64,
    /// Completed requests per deployed model, in deployment order —
    /// degraded completions included. The total and per-model counters are
    /// separate relaxed atomics, so a snapshot taken while requests are in
    /// flight may be off by the requests currently completing;
    /// `Σ counts == served + degraded` once responses drain.
    pub served_by_model: Vec<(String, u64)>,
    pub queue_depth: usize,
    pub workers: usize,
    /// Timing-cache hit/miss counts (one resolution per request).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Program-cache hit/miss counts. A program is resolved whenever a
    /// request needs one (it carries input bytes, or its timing missed);
    /// timing-cache hits without input resolve no program.
    pub program_hits: u64,
    pub program_misses: u64,
    /// Compiled artifacts rejected by the static verifier
    /// ([`crate::program::verify`]) at program-cache insert. A rejected
    /// artifact is never cached: each request that resolves it recompiles
    /// (so this can exceed the number of distinct bad deployments), and
    /// its batched replays fall back to the per-element dynamic isolation
    /// check instead of the verifier's batch-safety proof.
    pub verify_fails: u64,
    /// Total wall-clock µs spent compiling programs (cold path) vs
    /// replaying them (warm path) — the compile-once/run-many ratio.
    pub compile_us: u64,
    pub replay_us: u64,
    /// Program compiles (cache misses) attributed per worker, so cluster
    /// and single-core miss traffic are both attributable to the core that
    /// paid for them. `Σ compile_by_worker == program_misses`.
    pub compile_by_worker: Vec<u64>,
    /// Total modeled inter-core all-gather cycles across served cluster
    /// requests (0 until a `shards > 1` request is served).
    pub sync_cycles: u64,
    /// Busy core-equivalents per shard *position*, aggregated over every
    /// worker's cluster pool (each worker owns its own shard cores, so with
    /// `W` workers serving cluster traffic a position can report up to
    /// `W`·1.0). Trailing never-used positions are trimmed (empty until a
    /// `shards > 1` request runs functionally).
    pub shard_util: Vec<f64>,
    /// Busy core-equivalents per pipeline stage *position*, aggregated over
    /// every worker's stage pool — the pipeline analogue of `shard_util`
    /// (empty until a `mode=pipeline stages > 1` request runs functionally).
    pub stage_util: Vec<f64>,
    /// Total modeled pipeline bubble (idle) cycles across streamed groups:
    /// per group of `B` requests, `Σ_s (total − B·e_s)` where
    /// `total = fill + (B−1)·period` — what non-bottleneck stages spend
    /// waiting. 0 until a pipeline group is streamed functionally.
    pub bubble_cycles: u64,
    /// Milliseconds since [`Coordinator::start`].
    pub uptime_ms: u64,
    /// Host-trace events dropped on full or contended rings
    /// ([`crate::obs::Tracer::dropped`]); 0 while tracing is off.
    pub trace_dropped: u64,
    /// End-to-end (queue + service) latency percentiles in µs over the
    /// most recent `LAT_WINDOW` responses, flanked by the window's min/max
    /// (the outliers the percentile view truncates past p99).
    pub min_us: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Log₂ histogram of queue wait over dequeued requests (served,
    /// degraded, and expired): bucket 0 counts waits under 1 ms, bucket `i`
    /// waits in `[2^(i−1), 2^i)` ms, the last of the [`QUEUE_AGE_BUCKETS`]
    /// buckets everything from ~1 s up.
    pub queue_age_hist: Vec<u64>,
    /// Per-model end-to-end latency percentiles (the SLO view next to the
    /// aggregate p50/p95/p99), in deployment order, each over that model's
    /// most recent `LAT_WINDOW` responses.
    pub slo_by_model: Vec<ModelSlo>,
    /// Per-model micro-op-class cycle mix of the deployment-default
    /// programs ([`ModelClassMix`]), in deployment order.
    pub class_mix: Vec<ModelClassMix>,
    /// Fraction of wall-clock each worker spent serving batches.
    pub utilization: Vec<f64>,
}

/// Per-model latency SLO snapshot ([`CoordStats::slo_by_model`]), µs.
/// `min_us`/`max_us` are the window extremes around the percentiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSlo {
    pub model: String,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub min_us: u64,
    pub max_us: u64,
}

/// Per-model micro-op-class cycle mix ([`CoordStats::class_mix`]): the
/// deployment-default single-core program's per-class cycle fractions, in
/// [`crate::obs::OpClass::ALL`] order. `None` until the model's default
/// timing has been resolved (first request), or when the deployment default
/// is sharded (per-shard attribution lives in `repro profile --shards`).
#[derive(Clone, Debug)]
pub struct ModelClassMix {
    pub model: String,
    pub fractions: Option<[f64; obs::N_CLASSES]>,
}

/// Buckets of [`CoordStats::queue_age_hist`]: log₂ milliseconds, <1 ms up
/// to ≥ ~1 s.
pub const QUEUE_AGE_BUCKETS: usize = 12;

/// Histogram bucket for a queue wait: 0 for waits under 1 ms, `i` for
/// `[2^(i−1), 2^i)` ms, saturating at the last bucket.
fn queue_age_bucket(wait: Duration) -> usize {
    let ms = wait.as_millis() as u64;
    let mut b = 0usize;
    let mut lim = 1u64;
    while b < QUEUE_AGE_BUCKETS - 1 && ms >= lim {
        b += 1;
        lim *= 2;
    }
    b
}

const LAT_WINDOW: usize = 4096;

/// Timing-cache size bound. Schedules are client-supplied (the `prec=` wire
/// field), so without a cap a client cycling distinct override sets could
/// grow the map without limit. Past the cap, new schedules are still served
/// (one fresh `TimingOnly` run each) but no longer memoized.
const MAX_TIMING_ENTRIES: usize = 1024;

/// Program-cache size bound — far smaller than the timing cache: a
/// [`CompiledProgram`] holds the full dynamic instruction trace (tens of MB
/// for ResNet-scale nets), so the cap bounds server *memory*, not just map
/// growth. At the cap the [`ProgramCache`] evicts the oldest non-default
/// entry (FIFO) to admit the newcomer; the deployment-default programs are
/// pinned and can never be evicted, so client-supplied `prec=`/`shards=`
/// churn only displaces other client-supplied entries. Evicted keys simply
/// recompile on next use (a program-cache miss).
const MAX_PROGRAM_ENTRIES: usize = 16;

struct Queued {
    req: InferenceRequest,
    /// Index into [`CoordinatorConfig::models`], resolved at submission.
    model_idx: usize,
    enqueued: Instant,
    /// Absolute claim-by time (`enqueued + deadline_ms`), resolved at
    /// submission; checked when a worker considers claiming the request.
    deadline: Option<Instant>,
    /// The [`DegradePolicy`] rerouted this request at admission
    /// (`req.schedule` already holds the fallback).
    degraded: bool,
    reply: mpsc::Sender<ServeResult>,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    available: Condvar,
    shutdown: AtomicBool,
    batch_counter: AtomicU64,
    served: AtomicU64,
    rejected: AtomicU64,
    /// Accepted requests dropped at claim time (deadline passed).
    expired: AtomicU64,
    /// Requests completed under the degrade-policy fallback schedule.
    degraded: AtomicU64,
    /// Completed requests per deployed model (index-aligned with
    /// [`CoordinatorConfig::models`]; degraded completions included).
    served_by_model: Vec<AtomicU64>,
    timing_cache: Mutex<HashMap<DeployKey, TimingEntry>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Compiled (net, machine, schedule, shard) artifacts, `Arc`-shared
    /// with the workers replaying them.
    program_cache: Mutex<ProgramCache>,
    program_hits: AtomicU64,
    program_misses: AtomicU64,
    /// Freshly compiled artifacts the static verifier rejected at
    /// cache-insert time. A failing artifact is never cached — every
    /// request that needs it recompiles (and recounts here), and its
    /// replays keep the always-on dynamic isolation check because the
    /// batch-safety proof is absent.
    verify_fails: AtomicU64,
    compile_ns: AtomicU64,
    replay_ns: AtomicU64,
    /// Program compiles attributed to the worker that performed them.
    compile_by_worker: Vec<AtomicU64>,
    /// Modeled all-gather cycles accumulated over served cluster requests.
    sync_cycles: AtomicU64,
    /// Per-shard-core nanoseconds spent inside cluster replays (indexed by
    /// shard position, up to [`MAX_SHARDS`]).
    shard_busy_ns: Vec<AtomicU64>,
    /// Per-stage-core nanoseconds spent inside pipeline streams (indexed by
    /// stage position, up to [`MAX_SHARDS`]).
    stage_busy_ns: Vec<AtomicU64>,
    /// Modeled pipeline bubble cycles accumulated over streamed groups.
    bubble_cycles: AtomicU64,
    latencies: Mutex<LatWindow>,
    /// Per-model latency windows (index-aligned with
    /// [`CoordinatorConfig::models`]) behind [`CoordStats::slo_by_model`].
    model_latencies: Vec<Mutex<LatWindow>>,
    /// Queue-wait histogram counters ([`QUEUE_AGE_BUCKETS`] log₂-ms
    /// buckets), bumped whenever a request leaves the queue.
    queue_age_hist: Vec<AtomicU64>,
    /// Per-worker nanoseconds spent inside batch service.
    busy_ns: Vec<AtomicU64>,
    started: Instant,
    /// Armed by [`Coordinator::enable_tracing`]; while unset (tracing off)
    /// every hook on the serving path is one pointer check, no allocation.
    tracer: OnceLock<Arc<obs::Tracer>>,
    /// Cycle-attribution profile of each model's deployment-default
    /// single-core program, captured when its timing is first resolved
    /// (index-aligned with [`CoordinatorConfig::models`]). Feeds the STATS
    /// class-mix rows and the serve trace's simulated-cycle tracks.
    profiles: Mutex<Vec<Option<obs::ProgramProfile>>>,
}

/// The coordinator: owns the batcher + worker threads.
pub struct Coordinator {
    shared: Arc<Shared>,
    cfg: CoordinatorConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start serving. Panics if the model list is empty or duplicated, or
    /// if the deployment's default schedule or shard count is invalid for
    /// any deployed model on this machine (misconfiguration, not a runtime
    /// condition).
    pub fn start(cfg: CoordinatorConfig) -> Self {
        assert!(!cfg.models.is_empty(), "a coordinator needs at least one deployed model");
        for (i, model) in cfg.models.iter().enumerate() {
            if cfg.models[..i].iter().any(|m| m.name() == model.name()) {
                panic!("duplicate deployed model {:?}", model.name());
            }
            if let Err(e) = validate_schedule(&cfg.schedule, model, &cfg.machine) {
                panic!("invalid coordinator schedule for model {:?}: {e}", model.name());
            }
            if let Err(e) = validate_parallelism(cfg.mode, cfg.shards, cfg.stages, &cfg.schedule, model)
            {
                panic!("invalid coordinator parallelism for model {:?}: {e}", model.name());
            }
            // The degrade fallback substitutes for the default at admission,
            // so it must be as universally runnable as the default itself.
            if let Some(policy) = &cfg.degrade {
                if let Err(e) = validate_schedule(&policy.schedule, model, &cfg.machine) {
                    panic!("invalid degrade schedule for model {:?}: {e}", model.name());
                }
                if let Err(e) = validate_parallelism(
                    cfg.mode,
                    cfg.shards,
                    cfg.stages,
                    &policy.schedule,
                    model,
                ) {
                    panic!("invalid degrade schedule for model {:?} at the deployment parallelism: {e}", model.name());
                }
            }
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            batch_counter: AtomicU64::new(0),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            served_by_model: (0..cfg.models.len()).map(|_| AtomicU64::new(0)).collect(),
            timing_cache: Mutex::new(HashMap::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            program_cache: Mutex::new(ProgramCache::new()),
            program_hits: AtomicU64::new(0),
            program_misses: AtomicU64::new(0),
            verify_fails: AtomicU64::new(0),
            compile_ns: AtomicU64::new(0),
            replay_ns: AtomicU64::new(0),
            compile_by_worker: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            sync_cycles: AtomicU64::new(0),
            shard_busy_ns: (0..MAX_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            stage_busy_ns: (0..MAX_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            bubble_cycles: AtomicU64::new(0),
            latencies: Mutex::new(LatWindow::new(LAT_WINDOW)),
            model_latencies: (0..cfg.models.len())
                .map(|_| Mutex::new(LatWindow::new(LAT_WINDOW)))
                .collect(),
            queue_age_hist: (0..QUEUE_AGE_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            busy_ns: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
            tracer: OnceLock::new(),
            profiles: Mutex::new(vec![None; cfg.models.len()]),
        });
        let workers = (0..cfg.workers)
            .map(|wid| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("quark-core-{wid}"))
                    .spawn(move || worker_loop(wid, shared, cfg))
                    .expect("spawn worker")
            })
            .collect();
        Coordinator { shared, cfg, workers }
    }

    /// Submit a request; returns a receiver for the [`ServeResult`],
    /// [`SubmitError::Busy`] when the queue is at capacity, or
    /// [`SubmitError::Invalid`] when the request names an unknown model or
    /// its schedule/shard count cannot run on this deployment. An accepted
    /// request always gets exactly one reply: the response, or
    /// [`ServeError::Expired`] if its deadline passes while queued. Under a
    /// configured [`DegradePolicy`], an eligible submission past the policy
    /// depth is admitted with its schedule rewritten to the fallback.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<ServeResult>, SubmitError> {
        let model_idx = match self.cfg.model_index(req.net.as_deref()) {
            Ok(i) => i,
            Err(reason) => return Err(SubmitError::Invalid { reason }),
        };
        let model = &self.cfg.models[model_idx];
        if let Some(sched) = &req.schedule {
            if let Err(reason) = validate_schedule(sched, model, &self.cfg.machine) {
                return Err(SubmitError::Invalid { reason });
            }
        }
        // Validate the *effective* (schedule, mode, shards, stages) tuple,
        // not just explicit overrides: a request overriding only the
        // schedule still runs at the deployment's shard/stage counts (e.g.
        // fp32 on a sharded fp32-capable deployment must be rejected here,
        // not panic a worker), and a `mode=pipeline` override composes with
        // whatever `shards=` rode along. All-default requests skip the
        // walk — Coordinator::start validated that tuple against every
        // deployed model.
        if req.shards.is_some()
            || req.schedule.is_some()
            || req.mode.is_some()
            || req.stages.is_some()
        {
            let mode = req.mode.unwrap_or(self.cfg.mode);
            let shards = req.shards.unwrap_or(self.cfg.shards);
            let stages = req.stages.unwrap_or(self.cfg.stages);
            let sched = req.schedule.as_ref().unwrap_or(&self.cfg.schedule);
            if let Err(reason) = validate_parallelism(mode, shards, stages, sched, model) {
                return Err(SubmitError::Invalid { reason });
            }
        }
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.cfg.max_queue {
            let depth = q.len();
            drop(q);
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Busy { depth });
        }
        // Overload degrade: past the policy depth, requests that don't pin
        // their own schedule or shard count are admitted under the cheaper
        // fallback instead of riding the default toward BUSY. Rewriting
        // `req.schedule` here means the DeployKey, precision label, and
        // batching grouping all follow naturally downstream.
        let mut req = req;
        let mut degraded = false;
        if let Some(policy) = &self.cfg.degrade {
            if req.schedule.is_none()
                && req.shards.is_none()
                && req.mode.is_none()
                && req.stages.is_none()
                && q.len() >= policy.depth
            {
                req.schedule = Some(policy.schedule.clone());
                degraded = true;
            }
        }
        let enqueued = Instant::now();
        // `checked_add` so an absurd client-supplied deadline (u64::MAX ms)
        // degenerates to "no deadline" instead of panicking on overflow.
        let deadline =
            req.deadline_ms.and_then(|ms| enqueued.checked_add(Duration::from_millis(ms)));
        let req_id = req.id;
        q.push_back(Queued { req, model_idx, enqueued, deadline, degraded, reply: tx });
        drop(q);
        self.shared.available.notify_one();
        if let Some(tr) = self.shared.tracer.get() {
            let mut ev = obs::TraceEvent::instant(obs::SpanKind::Submit, tr.us_at(enqueued))
                .with_req(req_id);
            if degraded {
                ev = ev.with_label("degraded");
            }
            tr.record(tr.admission_track(), ev);
        }
        Ok(rx)
    }

    /// Requests served at their requested schedule so far (degraded
    /// completions count separately — [`Coordinator::degraded`]).
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Requests rejected by backpressure so far.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Accepted requests dropped unserved because their deadline passed
    /// while they were queued.
    pub fn expired(&self) -> u64 {
        self.shared.expired.load(Ordering::Relaxed)
    }

    /// Requests completed under the degrade-policy fallback schedule.
    pub fn degraded(&self) -> u64 {
        self.shared.degraded.load(Ordering::Relaxed)
    }

    /// Snapshot of the serving metrics.
    pub fn stats(&self) -> CoordStats {
        let queue_depth = self.shared.queue.lock().unwrap().len();
        let ([p50_us, p95_us, p99_us], (min_us, max_us)) = {
            let w = self.shared.latencies.lock().unwrap();
            (w.percentiles([0.50, 0.95, 0.99]), w.min_max())
        };
        let elapsed_ns = self.shared.started.elapsed().as_nanos().max(1) as f64;
        CoordStats {
            served: self.shared.served.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            expired: self.shared.expired.load(Ordering::Relaxed),
            degraded: self.shared.degraded.load(Ordering::Relaxed),
            served_by_model: self
                .cfg
                .models
                .iter()
                .zip(self.shared.served_by_model.iter())
                .map(|(m, c)| (m.name().to_string(), c.load(Ordering::Relaxed)))
                .collect(),
            queue_depth,
            workers: self.cfg.workers,
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            program_hits: self.shared.program_hits.load(Ordering::Relaxed),
            program_misses: self.shared.program_misses.load(Ordering::Relaxed),
            verify_fails: self.shared.verify_fails.load(Ordering::Relaxed),
            compile_us: self.shared.compile_ns.load(Ordering::Relaxed) / 1_000,
            replay_us: self.shared.replay_ns.load(Ordering::Relaxed) / 1_000,
            compile_by_worker: self
                .shared
                .compile_by_worker
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            sync_cycles: self.shared.sync_cycles.load(Ordering::Relaxed),
            shard_util: {
                // Deliberately unclamped: the counters aggregate every
                // worker's pool, so the meaningful unit is busy
                // core-equivalents per shard position, not a 0–1 fraction.
                let mut util: Vec<f64> = self
                    .shared
                    .shard_busy_ns
                    .iter()
                    .map(|b| b.load(Ordering::Relaxed) as f64 / elapsed_ns)
                    .collect();
                while util.last() == Some(&0.0) {
                    util.pop();
                }
                util
            },
            stage_util: {
                let mut util: Vec<f64> = self
                    .shared
                    .stage_busy_ns
                    .iter()
                    .map(|b| b.load(Ordering::Relaxed) as f64 / elapsed_ns)
                    .collect();
                while util.last() == Some(&0.0) {
                    util.pop();
                }
                util
            },
            bubble_cycles: self.shared.bubble_cycles.load(Ordering::Relaxed),
            uptime_ms: self.shared.started.elapsed().as_millis() as u64,
            trace_dropped: self.shared.tracer.get().map_or(0, |t| t.dropped()),
            min_us,
            p50_us,
            p95_us,
            p99_us,
            max_us,
            queue_age_hist: self
                .shared
                .queue_age_hist
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            slo_by_model: self
                .cfg
                .models
                .iter()
                .zip(self.shared.model_latencies.iter())
                .map(|(m, w)| {
                    let w = w.lock().unwrap();
                    let [p50_us, p95_us, p99_us] = w.percentiles([0.50, 0.95, 0.99]);
                    let (min_us, max_us) = w.min_max();
                    ModelSlo {
                        model: m.name().to_string(),
                        p50_us,
                        p95_us,
                        p99_us,
                        min_us,
                        max_us,
                    }
                })
                .collect(),
            class_mix: {
                let profiles = self.shared.profiles.lock().unwrap();
                self.cfg
                    .models
                    .iter()
                    .zip(profiles.iter())
                    .map(|(m, p)| ModelClassMix {
                        model: m.name().to_string(),
                        fractions: p.as_ref().map(|p| p.class_fractions()),
                    })
                    .collect()
            },
            utilization: self
                .shared
                .busy_ns
                .iter()
                .map(|b| (b.load(Ordering::Relaxed) as f64 / elapsed_ns).min(1.0))
                .collect(),
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Arm request-lifecycle tracing ([`crate::obs`]). Idempotent: the
    /// first call installs the tracer (one bounded ring per worker plus an
    /// admission ring, [`obs::DEFAULT_RING_CAP`] events each); later calls
    /// return the same instance. Until armed, every tracing hook on the
    /// serving path is a single pointer check and allocates nothing.
    pub fn enable_tracing(&self) -> Arc<obs::Tracer> {
        self.shared
            .tracer
            .get_or_init(|| Arc::new(obs::Tracer::new(self.cfg.workers, obs::DEFAULT_RING_CAP)))
            .clone()
    }

    /// The armed tracer, if [`Coordinator::enable_tracing`] has been
    /// called; `None` means tracing is off.
    pub fn tracer(&self) -> Option<Arc<obs::Tracer>> {
        self.shared.tracer.get().cloned()
    }

    /// Cycle-attribution profiles of the deployment-default single-core
    /// programs, per model in deployment order (`None` until that model's
    /// default timing has been resolved, or when the deployment default is
    /// sharded). The serve trace exports these as its simulated-cycle
    /// tracks.
    pub fn default_profiles(&self) -> Vec<Option<obs::ProgramProfile>> {
        self.shared.profiles.lock().unwrap().clone()
    }

    /// Stop workers and join.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Full schedule validation against one deployed model: map shape +
/// machine caps.
fn validate_schedule(
    sched: &PrecisionMap,
    net: &NetGraph,
    machine: &MachineConfig,
) -> Result<(), String> {
    sched.validate(net)?;
    sched.validate_machine(net, machine)
}

/// Shard-count validation against one deployed model: bounds, channel
/// counts, and the integer-only rule ([`ShardPlan`]). The single source of
/// truth for both the submit path and the CLI's `serve --shards` check.
pub(crate) fn validate_shards(
    shards: usize,
    sched: &PrecisionMap,
    net: &NetGraph,
) -> Result<(), String> {
    if shards == 0 || shards > MAX_SHARDS {
        return Err(format!("shard count {shards} out of range (1\u{2013}{MAX_SHARDS})"));
    }
    ShardPlan::derive(net, shards)?.validate_schedule(sched)
}

/// Stage-count validation against one deployed model: bounds, cut
/// feasibility (layer count, residual-block indivisibility), and the
/// integer-only rule ([`StagePlan`]). Cut *feasibility* does not depend on
/// the cost vector, so unit costs validate cheaply here; the serving path
/// re-derives the balanced plan from real cycle estimates at compile time.
pub(crate) fn validate_stages(
    stages: usize,
    sched: &PrecisionMap,
    net: &NetGraph,
) -> Result<(), String> {
    if stages == 0 || stages > MAX_SHARDS {
        return Err(format!("stage count {stages} out of range (1\u{2013}{MAX_SHARDS})"));
    }
    StagePlan::derive_balanced(net, stages, &vec![1; net.len()])?.validate_schedule(sched)
}

/// Validate one effective `(mode, shards, stages)` parallelism tuple under
/// `sched` against one deployed model — the single source of truth for the
/// submit path, [`Coordinator::start`], and the CLI's `serve` checks. The
/// two axes never compose: tensor mode rejects `stages > 1`, pipeline mode
/// rejects `shards > 1`.
pub(crate) fn validate_parallelism(
    mode: ClusterMode,
    shards: usize,
    stages: usize,
    sched: &PrecisionMap,
    net: &NetGraph,
) -> Result<(), String> {
    match mode {
        ClusterMode::Tensor => {
            if stages > 1 {
                return Err(format!("stages={stages} requires mode=pipeline"));
            }
            validate_shards(shards, sched, net)
        }
        ClusterMode::Pipeline => {
            if shards > 1 {
                return Err(format!(
                    "pipeline mode does not compose with tensor sharding (shards={shards}); \
                     pick one parallelism axis"
                ));
            }
            validate_stages(stages, sched, net)
        }
    }
}

/// One worker's persistent simulated core. Constructed once per worker
/// thread; between model runs only the bump allocator is rewound (the Sim's
/// VRF, timing state, and 192 MiB memory arena are reused).
struct WorkerCore {
    sim: Sim,
    heap_base: u64,
}

impl WorkerCore {
    fn new(machine: MachineConfig) -> Self {
        let sim = Sim::new(machine);
        let heap_base = sim.machine.mem.brk();
        WorkerCore { sim, heap_base }
    }

    fn rewind(&mut self) {
        self.sim.machine.mem.reset_alloc_to(self.heap_base);
    }

    /// One `TimingOnly` replay of `prog` (timing-cache-miss path — still
    /// zero kernel emission when the program itself was cached), attributed
    /// per layer and per micro-op class as it runs. The profile's
    /// `total_cycles` is exactly what a plain timing replay would report
    /// (`obs::profile` asserts the conservation), so the timing cache and
    /// the attribution tables can never disagree.
    fn profile(&mut self, prog: &CompiledProgram) -> obs::ProgramProfile {
        self.rewind();
        self.sim.set_mode(SimMode::TimingOnly);
        let base = self.sim.alloc(prog.mem_len());
        obs::profile_program(&mut self.sim, prog, base)
    }

    /// Batched functional replay of `prog`: the whole group of same-key
    /// inputs rides **one** decode-once lowered replay
    /// ([`Sim::execute_lowered_batch`]) — arena rewound once, init image
    /// applied once, only the input segment rebound per element. Values
    /// only (cycles come from the timing cache), and bit-identical to B
    /// independent single-request replays — `rust/tests/batching.rs` holds
    /// the differential proof. Returns `(logits, argmax)` per input, in
    /// order.
    fn infer_batch(&mut self, prog: &CompiledProgram, inputs: &[&[u8]]) -> Vec<(Vec<f32>, usize)> {
        self.rewind();
        let base = self.sim.alloc(prog.mem_len());
        let run = self.sim.execute_lowered_batch(prog, base, inputs);
        run.outputs
            .iter()
            .map(|bytes| {
                if prog.is_fp32() {
                    let logits: Vec<f32> = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    let am = argmax_of(&logits);
                    (logits, am)
                } else {
                    widen_logits(bytes)
                }
            })
            .collect()
    }
}

/// Index of the largest logit, first max wins on ties.
fn argmax_of(logits: &[f32]) -> usize {
    let mut am = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[am] {
            am = i;
        }
    }
    am
}

/// Widen u8 logit codes to f32 and locate the argmax — one shared helper
/// for the single-core and cluster serving paths, so the tie-break rule can
/// never diverge between them.
fn widen_logits(codes: &[u8]) -> (Vec<f32>, usize) {
    let logits: Vec<f32> = codes.iter().map(|&v| v as f32).collect();
    let am = argmax_of(&logits);
    (logits, am)
}

/// Resolve one compiled (shard) program for `key`: cache hit is an `Arc`
/// clone, miss compiles once (attributed to worker `wid` in
/// `compile_by_worker`). `memoize` decides whether a miss is inserted: the
/// functional serving path memoizes — it replays per request — while
/// timing-only resolutions compile transiently, so probe-only schedules
/// never pin a trace-sized artifact in server memory. Insertions follow the
/// [`ProgramCache`] FIFO-eviction policy with every deployed model's
/// default-schedule entries pinned. Concurrent misses on one key may
/// compile twice; the first insert wins — both artifacts are identical
/// (compilation is deterministic).
fn resolve_program(
    shared: &Shared,
    cfg: &CoordinatorConfig,
    net: &NetGraph,
    wid: usize,
    key: &ProgKey,
    sched: &PrecisionMap,
    memoize: bool,
    stage_plan: &mut Option<StagePlan>,
) -> Arc<CompiledProgram> {
    if let Some(p) = shared.program_cache.lock().unwrap().get(key) {
        shared.program_hits.fetch_add(1, Ordering::Relaxed);
        return p;
    }
    shared.program_misses.fetch_add(1, Ordering::Relaxed);
    shared.compile_by_worker[wid].fetch_add(1, Ordering::Relaxed);
    let tracer = shared.tracer.get();
    let key_label = tracer.map(|_| {
        let width = match key.deploy.mode {
            ClusterMode::Pipeline => key.deploy.stages,
            ClusterMode::Tensor => key.deploy.shards,
        };
        format!("{}|{}|{}|{}", net.name(), sched.label(), key.deploy.mode.label(), width)
    });
    let t0 = Instant::now();
    let prog = Arc::new(if key.deploy.mode == ClusterMode::Pipeline && key.deploy.stages > 1 {
        // Derive the balanced stage plan once per resolution chain (the
        // caller threads `stage_plan` across the stage set — the costs
        // sweep is a full-net TimingOnly emission, deterministic, so every
        // stage of one deployment cuts identically).
        let plan = stage_plan.get_or_insert_with(|| {
            let costs = stage_costs(net, &cfg.machine, sched);
            StagePlan::derive_balanced(net, key.deploy.stages, &costs)
                .expect("stage count was validated at submission")
        });
        compile_stage(net, &cfg.machine, sched, plan, key.shard)
            .expect("schedule was validated at submission")
    } else if key.deploy.shards > 1 {
        let plan = ShardPlan::derive(net, key.deploy.shards)
            .expect("shard count was validated at submission");
        compile_shard(net, &cfg.machine, sched, &plan, key.shard)
            .expect("schedule was validated at submission")
    } else {
        compile(net, &cfg.machine, sched).expect("schedule was validated at submission")
    });
    let compile_dur = t0.elapsed();
    shared.compile_ns.fetch_add(compile_dur.as_nanos() as u64, Ordering::Relaxed);
    if let Some(tr) = tracer {
        let ev = obs::TraceEvent::span(
            obs::SpanKind::Compile,
            tr.us_at(t0),
            compile_dur.as_micros() as u64,
        )
        .with_label(key_label.clone().unwrap_or_default());
        tr.record(wid, ev);
    }
    if memoize {
        // Force the decode-once lowering before the entry becomes visible,
        // so warm replays never pay the lowering walk.
        let lower_t0 = Instant::now();
        prog.lowered();
        if let Some(tr) = tracer {
            let ev = obs::TraceEvent::span(
                obs::SpanKind::Lower,
                tr.us_at(lower_t0),
                lower_t0.elapsed().as_micros() as u64,
            )
            .with_label(key_label.clone().unwrap_or_default());
            tr.record(wid, ev);
        }
        // Gate the cache on the static verifier: a failing artifact is
        // never memoized, so no later request can hit it warm. This
        // request still runs it — with no cached `VerifyReport` claiming
        // batch safety, `execute_lowered_batch` keeps the per-element
        // dynamic isolation check, so serving stays safe even for an
        // artifact the prover rejected.
        let verify_t0 = Instant::now();
        let verified = prog.verify_report().ok();
        if let Some(tr) = tracer {
            let label = key_label.as_deref().unwrap_or_default();
            let ev = obs::TraceEvent::span(
                obs::SpanKind::VerifyGate,
                tr.us_at(verify_t0),
                verify_t0.elapsed().as_micros() as u64,
            )
            .with_label(format!("{label} {}", if verified { "pass" } else { "FAIL" }));
            tr.record(wid, ev);
        }
        if verified {
            let pinned = *sched == cfg.schedule
                && key.deploy.shards == cfg.shards
                && key.deploy.mode == cfg.mode
                && key.deploy.stages == cfg.stages;
            let evicted = shared.program_cache.lock().unwrap().insert(
                key.clone(),
                prog.clone(),
                pinned,
                MAX_PROGRAM_ENTRIES,
            );
            if evicted {
                if let Some(tr) = tracer {
                    let ev = obs::TraceEvent::instant(obs::SpanKind::Evict, tr.now_us())
                        .with_label(key_label.unwrap_or_default());
                    tr.record(wid, ev);
                }
            }
        } else {
            shared.verify_fails.fetch_add(1, Ordering::Relaxed);
        }
    }
    prog
}

/// Resolve the full shard-program set of a cluster deployment (one
/// per-shard cache entry each) and assemble the [`ClusterProgram`].
///
/// Misses compile sequentially on the serving worker, by choice: each
/// in-flight compile owns a recording-arena `Sim`, so parallelizing an
/// 8-shard cold miss would multiply transient server memory roughly
/// eightfold for a once-per-deployment event (offline callers that want
/// parallel compiles use [`crate::cluster::compile_cluster`]).
fn resolve_cluster(
    shared: &Shared,
    cfg: &CoordinatorConfig,
    net: &NetGraph,
    wid: usize,
    deploy: &DeployKey,
    sched: &PrecisionMap,
    memoize: bool,
) -> ClusterProgram {
    let progs: Vec<Arc<CompiledProgram>> = (0..deploy.shards)
        .map(|shard| {
            let key = ProgKey { deploy: deploy.clone(), shard };
            resolve_program(shared, cfg, net, wid, &key, sched, memoize, &mut None)
        })
        .collect();
    ClusterProgram::from_shards(progs).expect("per-shard cache entries form one deployment")
}

/// Resolve the full stage-program set of a pipeline deployment (one
/// per-stage cache entry each, `ProgKey.shard` doubling as the stage index)
/// and assemble the [`PipelineProgram`]. The balanced [`StagePlan`] is
/// derived at most once per resolution (lazily, on the first stage miss);
/// all-hit resolutions never pay the cost sweep. Misses compile
/// sequentially on the serving worker for the same memory-bounding reason
/// as [`resolve_cluster`].
fn resolve_pipeline(
    shared: &Shared,
    cfg: &CoordinatorConfig,
    net: &NetGraph,
    wid: usize,
    deploy: &DeployKey,
    sched: &PrecisionMap,
    memoize: bool,
) -> PipelineProgram {
    let mut plan: Option<StagePlan> = None;
    let progs: Vec<Arc<CompiledProgram>> = (0..deploy.stages)
        .map(|stage| {
            let key = ProgKey { deploy: deploy.clone(), shard: stage };
            resolve_program(shared, cfg, net, wid, &key, sched, memoize, &mut plan)
        })
        .collect();
    PipelineProgram::from_stages(progs).expect("per-stage cache entries form one pipeline")
}

/// How long `item` has waited if its deadline has passed; `None` while it
/// is still claimable.
fn expired_wait(item: &Queued) -> Option<Duration> {
    let deadline = item.deadline?;
    let now = Instant::now();
    if now > deadline {
        Some(now - item.enqueued)
    } else {
        None
    }
}

/// Answer an expired request: [`ServeError::Expired`] on its channel, the
/// `expired` counter, and a queue-age sample — dropped requests are
/// counted, never silently lost.
fn expire_item(shared: &Shared, item: Queued, waited: Duration) {
    shared.expired.fetch_add(1, Ordering::Relaxed);
    shared.queue_age_hist[queue_age_bucket(waited)].fetch_add(1, Ordering::Relaxed);
    if let Some(tr) = shared.tracer.get() {
        let ev = obs::TraceEvent::span(
            obs::SpanKind::Expire,
            tr.us_at(item.enqueued),
            waited.as_micros() as u64,
        )
        .with_req(item.req.id);
        tr.record(tr.admission_track(), ev);
    }
    let _ = item.reply.send(Err(ServeError::Expired {
        waited_ms: waited.as_millis() as u64,
        deadline_ms: item.req.deadline_ms.unwrap_or(0),
    }));
}

/// Pop the claimable request the scheduler ranks highest: a strictly higher
/// [`Priority`] always wins, FIFO within a class (the scan keeps the first
/// of equals). Deadline-expired requests encountered on the way are
/// answered via [`expire_item`] and skipped. `None` when nothing claimable
/// remains.
fn pop_ready(q: &mut VecDeque<Queued>, shared: &Shared) -> Option<Queued> {
    loop {
        if q.is_empty() {
            return None;
        }
        let mut best = 0;
        let mut best_prio = q[0].req.prio;
        for (i, item) in q.iter().enumerate().skip(1) {
            // Strict `>` keeps the first of equals — FIFO within a class.
            if item.req.prio > best_prio {
                best = i;
                best_prio = item.req.prio;
            }
        }
        let item = q.remove(best).expect("index is in bounds");
        match expired_wait(&item) {
            Some(waited) => expire_item(shared, item, waited),
            None => return Some(item),
        }
    }
}

/// Effective deployment key of a claimed request. A claimed batch
/// partitions by this before serving, so a multi-input replay only ever
/// binds same-`(model, schedule, shards)` requests — explicit overrides
/// that happen to equal the deployment defaults land in the same group as
/// default requests.
#[derive(PartialEq)]
struct GroupKey {
    model_idx: usize,
    schedule: PrecisionMap,
    shards: usize,
    mode: ClusterMode,
    stages: usize,
}

/// Worker: claims batches (size- or timeout-bounded, priority-ordered,
/// deadline-filtered), partitions each claim into [`GroupKey`]-pure groups,
/// and serves every group on its persistent core. Timing is still resolved
/// per request (requests in one batch may carry different schedules); the
/// caches make repeats free: warm timing is a map lookup, warm functional
/// inference rides the group's single multi-input lowered replay with zero
/// kernel emission. Requests with `shards > 1` run on the worker's
/// lazily-built [`ClusterCores`] pool instead of its single core (one pool
/// per worker, rebuilt when the shard count changes — bounding memory at
/// one cluster per worker).
fn worker_loop(wid: usize, shared: Arc<Shared>, cfg: CoordinatorConfig) {
    let mut core = WorkerCore::new(cfg.machine.clone());
    let mut cluster_cores: Option<ClusterCores> = None;
    let mut pipeline_cores: Option<PipelineCores> = None;
    loop {
        // Claim a batch.
        let mut batch = Vec::new();
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(item) = pop_ready(&mut q, &shared) {
                    batch.push(item);
                    break;
                }
                q = shared.available.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
            }
            // First request in hand; wait up to batch_timeout for more.
            let deadline = Instant::now() + cfg.batch_timeout;
            while batch.len() < cfg.batch_size {
                if let Some(item) = pop_ready(&mut q, &shared) {
                    batch.push(item);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (nq, timeout) =
                    shared.available.wait_timeout(q, deadline - now).unwrap();
                q = nq;
                if timeout.timed_out() && q.is_empty() {
                    break;
                }
            }
        }
        let busy_t0 = Instant::now();

        // Partition the claim into DeployKey-pure groups (claim order
        // preserved within each): requests never share a replay — or a
        // batch_id — across keys.
        let mut groups: Vec<(GroupKey, Vec<Queued>)> = Vec::new();
        for item in batch {
            let gk = GroupKey {
                model_idx: item.model_idx,
                schedule: item.req.schedule.clone().unwrap_or_else(|| cfg.schedule.clone()),
                shards: item.req.shards.unwrap_or(cfg.shards),
                mode: item.req.mode.unwrap_or(cfg.mode),
                stages: item.req.stages.unwrap_or(cfg.stages),
            };
            match groups.iter_mut().find(|(k, _)| *k == gk) {
                Some((_, g)) => g.push(item),
                None => groups.push((gk, vec![item])),
            }
        }
        for (gk, group) in groups {
            serve_group(
                wid,
                &shared,
                &cfg,
                &mut core,
                &mut cluster_cores,
                &mut pipeline_cores,
                gk,
                group,
            );
        }
        shared.busy_ns[wid].fetch_add(busy_t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Serve one [`GroupKey`]-pure group of a claimed batch under its own
/// `batch_id`. Timing and program resolution stay per request — the cache
/// counters keep their per-request semantics, and within a group the first
/// timing miss fills the entry its peers then hit. The batch axis pays off
/// in the functional phase: all of a single-core group's inputs ride one
/// multi-input lowered replay ([`WorkerCore::infer_batch`]); cluster
/// (`shards > 1`) requests keep their per-request replay — the all-gather
/// runtime owns per-shard arenas of its own.
fn serve_group(
    wid: usize,
    shared: &Shared,
    cfg: &CoordinatorConfig,
    core: &mut WorkerCore,
    cluster_cores: &mut Option<ClusterCores>,
    pipeline_cores: &mut Option<PipelineCores>,
    gk: GroupKey,
    group: Vec<Queued>,
) {
    let batch_id = shared.batch_counter.fetch_add(1, Ordering::Relaxed);
    let model = &cfg.models[gk.model_idx];
    let sched = &gk.schedule;
    let shards = gk.shards;
    let (mode, stages) = (gk.mode, gk.stages);
    // A 1-stage "pipeline" is served on the single-core path (its emission
    // is identical — `rust/tests/pipeline.rs` proves it cycle-exact).
    let pipelined = mode == ClusterMode::Pipeline && stages > 1;
    let tracer = shared.tracer.get();
    let assemble_t0 = Instant::now();
    let key_label = tracer.map(|_| {
        let width = if mode == ClusterMode::Pipeline { stages } else { shards };
        format!("{}|{}|{}|{}", model.name(), sched.label(), mode.label(), width)
    });
    let key = DeployKey {
        net_fp: model.fingerprint(),
        machine_fp: machine_fingerprint(&cfg.machine),
        schedule: sched.clone(),
        shards,
        mode,
        stages,
    };

    struct Resolved {
        item: Queued,
        sim_cycles: u64,
        sync_cycles: u64,
        period_cycles: u64,
        timing_cached: bool,
        prog: Option<Arc<CompiledProgram>>,
        cluster: Option<ClusterProgram>,
        pipe: Option<PipelineProgram>,
    }
    let mut resolved: Vec<Resolved> = Vec::with_capacity(group.len());
    for item in group {
        // Resolve the compiled program(s) when this request needs them: it
        // carries input bytes (functional replay), or its timing misses
        // below (TimingOnly replay). Warm timing-only probes touch neither
        // cache entry's payload.
        let cached = shared.timing_cache.lock().unwrap().get(&key).copied();
        let need_progs = item.req.input.is_some() || cached.is_none();
        let memoize = item.req.input.is_some();
        // Single-core requests resolve one program; cluster requests a
        // full shard set, pipeline requests a full stage set (each under
        // its own per-shard/per-stage cache entry).
        let (prog, cluster, pipe) = if !need_progs {
            (None, None, None)
        } else if pipelined {
            (None, None, Some(resolve_pipeline(shared, cfg, model, wid, &key, sched, memoize)))
        } else if shards == 1 {
            let pkey = ProgKey { deploy: key.clone(), shard: 0 };
            let p = resolve_program(shared, cfg, model, wid, &pkey, sched, memoize, &mut None);
            (Some(p), None, None)
        } else {
            (None, Some(resolve_cluster(shared, cfg, model, wid, &key, sched, memoize)), None)
        };
        // Resolve timing: cache hit is a map lookup, miss is one TimingOnly
        // replay (per shard/stage core, in parallel, for clusters and
        // pipelines) whose result every later request under the same (net,
        // machine, schedule, mode, shards, stages) key reuses — including
        // the rest of this group.
        let (sim_cycles, sync_cycles, period_cycles, timing_cached) = match cached {
            Some(e) => {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
                (e.sim_cycles, e.sync_cycles, e.period_cycles, true)
            }
            None => {
                let t0 = Instant::now();
                let (c, sync, period) = match (&cluster, &pipe) {
                    (Some(cp), _) => {
                        let t = cluster_timing(cp, &cfg.machine);
                        (t.total_cycles(), t.sync_cycles, 0)
                    }
                    (_, Some(pp)) => {
                        // One request through every stage: fill latency,
                        // with the Σ of stage hops reported like the
                        // all-gather, plus the steady-state period so the
                        // stream model reconstructs for any batch size.
                        let t = pipeline_timing(pp, &cfg.machine, 1);
                        let hops: u64 = t.stages.iter().map(|s| s.hop_cycles).sum();
                        (t.fill_cycles(), hops, t.period_cycles())
                    }
                    (None, None) => {
                        // Timing misses resolve attribution for free: the
                        // profiled replay costs the same TimingOnly pass and
                        // yields the per-layer/per-class tables. Keep the
                        // profile only for the deployment-default key —
                        // that's what STATS and the serve trace export.
                        let prog_ref = prog.as_deref().expect("timing misses resolve a program");
                        let profile = core.profile(prog_ref);
                        let c = profile.total_cycles;
                        if *sched == cfg.schedule
                            && shards == cfg.shards
                            && mode == cfg.mode
                            && stages == cfg.stages
                        {
                            shared.profiles.lock().unwrap()[gk.model_idx] = Some(profile);
                        }
                        (c, 0, 0)
                    }
                };
                shared.replay_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
                let mut cache = shared.timing_cache.lock().unwrap();
                if cache.len() < MAX_TIMING_ENTRIES {
                    cache.insert(
                        key.clone(),
                        TimingEntry { sim_cycles: c, sync_cycles: sync, period_cycles: period },
                    );
                }
                drop(cache);
                (c, sync, period, false)
            }
        };
        // Account the modeled inter-core transfers once per served cluster
        // or pipeline request (timing-only probes included — the model is
        // part of the reply).
        if shards > 1 || pipelined {
            shared.sync_cycles.fetch_add(sync_cycles, Ordering::Relaxed);
        }
        resolved.push(Resolved {
            item,
            sim_cycles,
            sync_cycles,
            period_cycles,
            timing_cached,
            prog,
            cluster,
            pipe,
        });
    }
    if let Some(tr) = tracer {
        let ev = obs::TraceEvent::span(
            obs::SpanKind::BatchAssemble,
            tr.us_at(assemble_t0),
            assemble_t0.elapsed().as_micros() as u64,
        )
        .with_batch(batch_id)
        .with_label(format!("{} n={}", key_label.as_deref().unwrap_or_default(), resolved.len()));
        tr.record(wid, ev);
    }

    // Queue time stops for the whole group here: execution begins.
    let queue_times: Vec<Duration> = resolved.iter().map(|r| r.item.enqueued.elapsed()).collect();

    // Functional phase. Single-core inputs share one batched replay (they
    // finish together, so each rider's service time is the whole pass);
    // cluster requests replay per request on the worker's shard pool;
    // pipelined requests stream together through the worker's stage pool.
    let mut outcomes: Vec<Option<(Vec<f32>, usize)>> = vec![None; resolved.len()];
    let mut services: Vec<Duration> = vec![Duration::ZERO; resolved.len()];
    if pipelined {
        let idxs: Vec<usize> = resolved
            .iter()
            .enumerate()
            .filter(|(_, r)| r.item.req.input.is_some())
            .map(|(i, _)| i)
            .collect();
        if !idxs.is_empty() {
            let pp =
                resolved[idxs[0]].pipe.clone().expect("functional pipeline requests resolve stages");
            let inputs: Vec<Vec<u8>> = idxs
                .iter()
                .map(|&i| resolved[i].item.req.input.clone().expect("filtered on input"))
                .collect();
            // (Re)build this worker's stage-core pool when the requested
            // depth changes — same single-pool-per-worker policy as the
            // tensor shard pool.
            let rebuild = pipeline_cores.as_ref().map(|pc| pc.count()) != Some(stages);
            if rebuild {
                *pipeline_cores = Some(PipelineCores::new(&cfg.machine, stages));
            }
            let cores = pipeline_cores.as_mut().expect("pool was just built");
            let t0 = Instant::now();
            let inf = cores.infer_stream(&pp, &inputs);
            let elapsed = t0.elapsed();
            shared.replay_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
            if let Some(tr) = tracer {
                let ev = obs::TraceEvent::span(
                    obs::SpanKind::Replay,
                    tr.us_at(t0),
                    elapsed.as_micros() as u64,
                )
                .with_batch(batch_id)
                .with_label(format!(
                    "{} n={}",
                    key_label.as_deref().unwrap_or_default(),
                    idxs.len()
                ));
                tr.record(wid, ev);
            }
            for (j, ns) in inf.stage_busy_ns.iter().enumerate() {
                shared.stage_busy_ns[j].fetch_add(*ns, Ordering::Relaxed);
            }
            // Modeled bubble accounting for this stream: B requests keep
            // every stage busy B·e_s of the fill + (B−1)·period total, so
            // Σ bubbles = stages·total − B·fill (per-stage busy + bubble
            // tiles the total — the conservation law `obs::profile_pipeline`
            // asserts).
            let b = idxs.len() as u64;
            let fill = resolved[idxs[0]].sim_cycles;
            let period = resolved[idxs[0]].period_cycles;
            let total = fill + (b - 1) * period;
            shared.bubble_cycles.fetch_add(stages as u64 * total - b * fill, Ordering::Relaxed);
            for (&i, logits) in idxs.iter().zip(inf.logits) {
                outcomes[i] = Some(widen_logits(&logits));
                services[i] = elapsed;
            }
        }
    } else if shards == 1 {
        let idxs: Vec<usize> = resolved
            .iter()
            .enumerate()
            .filter(|(_, r)| r.item.req.input.is_some())
            .map(|(i, _)| i)
            .collect();
        if !idxs.is_empty() {
            let prog =
                resolved[idxs[0]].prog.clone().expect("functional requests resolve a program");
            let inputs: Vec<&[u8]> = idxs
                .iter()
                .map(|&i| resolved[i].item.req.input.as_deref().expect("filtered on input"))
                .collect();
            let t0 = Instant::now();
            let outs = core.infer_batch(&prog, &inputs);
            let elapsed = t0.elapsed();
            shared.replay_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
            if let Some(tr) = tracer {
                let ev = obs::TraceEvent::span(
                    obs::SpanKind::Replay,
                    tr.us_at(t0),
                    elapsed.as_micros() as u64,
                )
                .with_batch(batch_id)
                .with_label(format!(
                    "{} n={}",
                    key_label.as_deref().unwrap_or_default(),
                    idxs.len()
                ));
                tr.record(wid, ev);
            }
            for (&i, out) in idxs.iter().zip(outs) {
                outcomes[i] = Some(out);
                services[i] = elapsed;
            }
        }
    } else {
        for (i, r) in resolved.iter().enumerate() {
            let Some(bytes) = &r.item.req.input else { continue };
            let cp = r.cluster.as_ref().expect("cluster requests resolve a shard set");
            // (Re)build this worker's shard-core pool when the requested
            // width changes. One pool per worker, by choice: caching a pool
            // per shard count would bound memory at Σ(2..=8) grown arenas
            // *per worker*; traffic alternating shard counts pays the
            // rebuild instead.
            let rebuild = cluster_cores.as_ref().map(|cc| cc.count()) != Some(shards);
            if rebuild {
                *cluster_cores = Some(ClusterCores::new(&cfg.machine, shards));
            }
            let cores = cluster_cores.as_mut().expect("pool was just built");
            let t0 = Instant::now();
            let inf = cores.infer(cp, bytes);
            services[i] = t0.elapsed();
            shared.replay_ns.fetch_add(services[i].as_nanos() as u64, Ordering::Relaxed);
            if let Some(tr) = tracer {
                let ev = obs::TraceEvent::span(
                    obs::SpanKind::Replay,
                    tr.us_at(t0),
                    services[i].as_micros() as u64,
                )
                .with_req(r.item.req.id)
                .with_batch(batch_id)
                .with_label(key_label.clone().unwrap_or_default());
                tr.record(wid, ev);
            }
            for (j, ns) in inf.shard_busy_ns.iter().enumerate() {
                shared.shard_busy_ns[j].fetch_add(*ns, Ordering::Relaxed);
            }
            outcomes[i] = Some(widen_logits(&inf.logits));
        }
    }

    // Responses + accounting. Every claimed request completes: `served` for
    // requests at their requested schedule, `degraded` for fallback-schedule
    // completions (disjoint — the conservation invariant), `served_by_model`
    // for both.
    let device_scale = cfg.machine.freq_ghz * 1e3;
    for (i, r) in resolved.into_iter().enumerate() {
        let (logits, argmax) = match outcomes[i].take() {
            Some((l, a)) => (Some(l), Some(a)),
            None => (None, None),
        };
        let resp = InferenceResponse {
            id: r.item.req.id,
            sim_cycles: r.sim_cycles,
            device_us: r.sim_cycles as f64 / device_scale,
            queue_time: queue_times[i],
            service_time: services[i],
            worker: wid,
            batch_id,
            timing_cached: r.timing_cached,
            precision: sched.label(),
            model: model.name().to_string(),
            shards,
            sync_cycles: r.sync_cycles,
            mode,
            stages,
            degraded: r.item.degraded,
            prio: r.item.req.prio,
            logits,
            argmax,
        };
        if r.item.degraded {
            shared.degraded.fetch_add(1, Ordering::Relaxed);
        } else {
            shared.served.fetch_add(1, Ordering::Relaxed);
        }
        shared.served_by_model[gk.model_idx].fetch_add(1, Ordering::Relaxed);
        shared.queue_age_hist[queue_age_bucket(queue_times[i])].fetch_add(1, Ordering::Relaxed);
        let us = (queue_times[i] + services[i]).as_micros() as u64;
        shared.latencies.lock().unwrap().push(us);
        shared.model_latencies[gk.model_idx].lock().unwrap().push(us);
        if let Some(tr) = tracer {
            let id = r.item.req.id;
            let q_start = tr.us_at(r.item.enqueued);
            let q_us = queue_times[i].as_micros() as u64;
            let queued = obs::TraceEvent::span(obs::SpanKind::Queue, q_start, q_us)
                .with_req(id)
                .with_batch(batch_id);
            tr.record(wid, queued);
            let claim = obs::TraceEvent::instant(obs::SpanKind::Claim, q_start + q_us)
                .with_req(id)
                .with_batch(batch_id)
                .with_label(key_label.clone().unwrap_or_default());
            tr.record(wid, claim);
            let reply = obs::TraceEvent::instant(obs::SpanKind::Reply, tr.now_us())
                .with_req(id)
                .with_batch(batch_id)
                .with_label(if r.item.degraded { "degraded" } else { "ok" });
            tr.record(wid, reply);
        }
        let _ = r.item.reply.send(Ok(resp));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_requests_and_batches() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 2;
        cfg.batch_size = 4;
        let coord = Coordinator::start(cfg);
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                coord
                    .submit(InferenceRequest { id: i, ..Default::default() })
                    .unwrap()
            })
            .collect();
        let mut responses: Vec<_> =
            rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.sim_cycles > 0);
            assert!(r.device_us > 0.0);
            assert_eq!(r.precision, "w2a2");
            assert!(r.logits.is_none(), "timing-only requests carry no logits");
        }
        // Batching grouped at least two requests somewhere.
        let max_batch = responses
            .iter()
            .map(|r| responses.iter().filter(|o| o.batch_id == r.batch_id).count())
            .max()
            .unwrap();
        assert!(max_batch >= 2, "expected some batching, got max batch {max_batch}");
        assert_eq!(coord.served(), 6);
        coord.shutdown();
    }

    #[test]
    fn timing_cache_converges_to_lookups() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        // Sequential submissions: every request after the first must hit.
        let mut cycles = Vec::new();
        for i in 0..5u64 {
            let rx = coord
                .submit(InferenceRequest { id: i, ..Default::default() })
                .unwrap();
            let r = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
            cycles.push((r.sim_cycles, r.timing_cached));
        }
        assert!(cycles.iter().all(|&(c, _)| c == cycles[0].0), "cached timing must be stable");
        assert!(!cycles[0].1, "first request is a miss");
        assert!(cycles[1..].iter().all(|&(_, hit)| hit), "later requests must hit");
        let s = coord.stats();
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.cache_hits, 4);
        coord.shutdown();
    }

    #[test]
    fn real_inputs_produce_logits_that_depend_on_data() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 2;
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let rx_a = coord
            .submit(InferenceRequest { id: 0, input: Some(vec![0u8; n]), ..Default::default() })
            .unwrap();
        let rx_b = coord
            .submit(InferenceRequest { id: 1, input: Some(vec![200u8; n]), ..Default::default() })
            .unwrap();
        let a = rx_a.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        let b = rx_b.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        let (la, lb) = (a.logits.unwrap(), b.logits.unwrap());
        assert_eq!(la.len(), 100, "demo net classifies over 100 classes");
        assert_eq!(lb.len(), 100);
        assert!(a.argmax.unwrap() < 100 && b.argmax.unwrap() < 100);
        assert_ne!(la, lb, "different inputs must produce different logits");
        // Determinism: same input → same logits.
        let rx_c = coord
            .submit(InferenceRequest { id: 2, input: Some(vec![200u8; n]), ..Default::default() })
            .unwrap();
        let c = rx_c.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(lb, c.logits.unwrap(), "same input must reproduce the same logits");
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.max_queue = 0; // every submission rejects deterministically
        let coord = Coordinator::start(cfg);
        let err = coord
            .submit(InferenceRequest { id: 9, ..Default::default() })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Busy { .. }));
        assert_eq!(coord.rejected(), 1);
        assert_eq!(coord.served(), 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_schedules_are_rejected_at_submission() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        let coord = Coordinator::start(cfg);
        // Unknown layer name (override must differ from the default — equal
        // ones canonicalize away).
        let err = coord
            .submit(InferenceRequest {
                id: 0,
                schedule: Some(
                    PrecisionMap::uniform(Precision::Sub {
                        abits: 2,
                        wbits: 2,
                        use_vbitpack: true,
                    })
                    .with("ghost", Precision::Int8),
                ),
                ..Default::default()
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        // fp32 needs the vector FPU the Quark machine lacks.
        let err = coord
            .submit(InferenceRequest {
                id: 1,
                schedule: Some(PrecisionMap::uniform(Precision::Fp32)),
                ..Default::default()
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        assert_eq!(coord.rejected(), 0, "Invalid is not backpressure");
        coord.shutdown();
    }

    #[test]
    fn per_request_schedules_get_separate_cache_entries() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let get = |id: u64, sched: Option<PrecisionMap>| {
            let rx = coord
                .submit(InferenceRequest { id, schedule: sched, ..Default::default() })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap()
        };
        let int2 = get(0, None); // deployment default: uniform w2a2
        let int8 = get(1, Some(PrecisionMap::uniform(Precision::Int8)));
        let mixed = get(
            2,
            Some(
                PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true })
                    .with("c1", Precision::Int8),
            ),
        );
        assert_eq!(int2.precision, "w2a2");
        assert_eq!(int8.precision, "int8");
        assert_eq!(mixed.precision, "mixed(w2a2+1)");
        assert!(!int8.timing_cached && !mixed.timing_cached, "distinct keys each miss once");
        assert!(int8.sim_cycles > int2.sim_cycles, "int8 must cost more cycles than 2-bit");
        assert!(
            mixed.sim_cycles > int2.sim_cycles && mixed.sim_cycles < int8.sim_cycles,
            "mixed ({}) must land between 2-bit ({}) and int8 ({})",
            mixed.sim_cycles,
            int2.sim_cycles,
            int8.sim_cycles
        );
        // Repeats hit their own entries.
        let again = get(3, Some(PrecisionMap::uniform(Precision::Int8)));
        assert!(again.timing_cached);
        assert_eq!(again.sim_cycles, int8.sim_cycles);
        coord.shutdown();
    }

    #[test]
    fn program_cache_compiles_once_and_replays() {
        // One deployment schedule, a mix of timing-only and functional
        // requests: exactly one compile; functional repeats are cache hits.
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let get = |id: u64, input: Option<Vec<u8>>| {
            let rx = coord.submit(InferenceRequest { id, input, ..Default::default() }).unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap()
        };
        // Timing miss: compiles a transient program (timing-only schedules
        // are not memoized — they would pin trace-sized artifacts).
        let first = get(0, None);
        assert!(!first.timing_cached);
        // Warm timing-only probe: no program resolution at all.
        let warm = get(1, None);
        assert!(warm.timing_cached);
        let s = coord.stats();
        assert_eq!(s.program_misses, 1, "one deployment schedule, one compile so far");
        assert_eq!(s.program_hits, 0, "warm timing probes never touch the program cache");
        assert!(s.compile_us > 0, "compile time must be accounted");
        // Functional requests memoize, then replay the cached program.
        let a = get(2, Some(vec![7u8; n]));
        let b = get(3, Some(vec![7u8; n]));
        assert_eq!(a.logits, b.logits, "replays of one program are deterministic");
        let s = coord.stats();
        assert_eq!(s.program_misses, 2, "first functional request compiles + memoizes");
        assert_eq!(s.program_hits, 1, "second functional request hits the cache");
        assert_eq!(s.verify_fails, 0, "compiler-produced artifacts pass the static verifier");
        assert!(s.replay_us > 0, "replay time must be accounted");
        // Every compile is attributable: the single worker paid for both.
        assert_eq!(s.compile_by_worker, vec![2], "Σ compile_by_worker == program_misses");
        coord.shutdown();
    }

    /// A 2-layer graph small enough to compile/replay in milliseconds —
    /// cache-boundary tests need dozens of distinct deployments.
    fn tiny_serving_net() -> NetGraph {
        use crate::kernels::Conv2dParams;
        use crate::nn::{ConvLayer, LayerKind, NetLayer};
        NetGraph::new(
            "serving-micro@10",
            10,
            vec![
                NetLayer {
                    kind: LayerKind::Conv(ConvLayer {
                        name: "c1".into(),
                        params: Conv2dParams {
                            h: 4,
                            w: 4,
                            c_in: 16,
                            c_out: 64,
                            kh: 1,
                            kw: 1,
                            stride: 1,
                            pad: 0,
                        },
                        relu: true,
                        residual: false,
                        quantized: true,
                    }),
                    input: 0,
                    residual_from: None,
                },
                NetLayer {
                    kind: LayerKind::Fc { k: 4 * 4 * 64, n: 10, name: "fc".into() },
                    input: 1,
                    residual_from: None,
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn program_cache_evicts_fifo_but_never_the_deployment_default() {
        // Satellite: direct test at the MAX_PROGRAM_ENTRIES boundary. Flood
        // the cache with > MAX_PROGRAM_ENTRIES distinct DeployKeys; the
        // deployment default must survive (pinned), flooded keys must evict
        // FIFO, and evicted keys must recompile (miss counter increments).
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models = vec![Arc::new(tiny_serving_net())];
        let coord = Coordinator::start(cfg);
        let input = vec![9u8; 4 * 4 * 16];
        let get = |id: u64, sched: Option<PrecisionMap>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    input: Some(input.clone()),
                    schedule: sched,
                    ..Default::default()
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap()
        };
        // Seed the pinned default entry (functional requests memoize).
        get(0, None);
        // 17 distinct non-default schedules: w2a2 with per-layer overrides.
        let precs = ["w1a1", "w1a2", "w2a1", "int8", "w1a1-novbp", "w2a2-novbp"];
        let mut floods: Vec<PrecisionMap> = Vec::new();
        'outer: for a in precs {
            for b in precs {
                let spec = format!("w2a2;c1={a};fc={b}");
                let m = PrecisionMap::parse(&spec).unwrap();
                if !floods.contains(&m) && !m.is_uniform() {
                    floods.push(m);
                }
                if floods.len() == MAX_PROGRAM_ENTRIES + 1 {
                    break 'outer;
                }
            }
        }
        assert_eq!(floods.len(), MAX_PROGRAM_ENTRIES + 1, "need 17+ distinct keys");
        for (i, m) in floods.iter().enumerate() {
            get(100 + i as u64, Some(m.clone()));
        }
        let s = coord.stats();
        // 1 default + 17 flooded = 18 distinct keys, each compiled once.
        assert_eq!(s.program_misses, 18);
        let bounded = coord.shared.program_cache.lock().unwrap().len();
        assert!(bounded <= MAX_PROGRAM_ENTRIES + 1, "cache unbounded: {bounded} entries");
        // The pinned deployment default must still be resident: a repeat is
        // a pure hit (miss counter unchanged).
        let r = get(500, None);
        assert!(r.timing_cached);
        let s = coord.stats();
        assert_eq!(s.program_misses, 18, "the default entry must never be evicted");
        assert_eq!(s.program_hits, 1);
        // The oldest flooded key was evicted by the later ones: using it
        // again recompiles (miss counter increments).
        get(501, Some(floods[0].clone()));
        let s = coord.stats();
        assert_eq!(s.program_misses, 19, "evicted keys must recompile on reuse");
        // And the whole miss history is attributed to the single worker.
        assert_eq!(s.compile_by_worker, vec![19]);
        coord.shutdown();
    }

    #[test]
    fn cluster_requests_shard_and_match_single_core_logits() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let input: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) % 251) as u8).collect();
        let get = |id: u64, shards: Option<usize>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    input: Some(input.clone()),
                    shards,
                    ..Default::default()
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap()
        };
        let single = get(0, None);
        let sharded = get(1, Some(2));
        assert_eq!(single.shards, 1);
        assert_eq!(single.sync_cycles, 0);
        assert_eq!(sharded.shards, 2);
        assert!(sharded.sync_cycles > 0, "the cluster model must charge the all-gather");
        assert_eq!(
            single.logits, sharded.logits,
            "tensor-parallel logits must be bit-identical to single-core"
        );
        assert_eq!(single.argmax, sharded.argmax);
        assert!(
            sharded.sim_cycles < single.sim_cycles,
            "2 shards must beat 1 core on modeled latency ({} vs {})",
            sharded.sim_cycles,
            single.sim_cycles
        );
        // Cluster metrics: shard utilization for both cores, sync counter.
        let s = coord.stats();
        assert_eq!(s.shard_util.len(), 2, "two shard cores ran: {:?}", s.shard_util);
        assert!(s.shard_util.iter().all(|&u| u > 0.0));
        assert_eq!(s.sync_cycles, sharded.sync_cycles);
        // Warm repeat: per-shard program entries + cluster timing all hit.
        let again = get(2, Some(2));
        assert!(again.timing_cached);
        assert_eq!(again.logits, single.logits);
        coord.shutdown();
    }

    #[test]
    fn pipeline_requests_stream_and_match_single_core_logits() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 4;
        cfg.batch_timeout = Duration::from_millis(10);
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let mk = |seed: usize| -> Vec<u8> {
            (0..n).map(|i| ((i * 11 + seed * 17 + 5) % 253) as u8).collect()
        };
        // Single-core references (their own group: the deploy key differs).
        let singles: Vec<_> = (0..3usize)
            .map(|k| {
                coord
                    .submit(InferenceRequest {
                        id: k as u64,
                        input: Some(mk(k)),
                        ..Default::default()
                    })
                    .unwrap()
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap())
            .collect();
        for s in &singles {
            assert_eq!(s.mode, ClusterMode::Tensor);
            assert_eq!(s.stages, 1);
        }
        // The same inputs as one pipelined stream across two stage cores.
        let rxs: Vec<_> = (0..3usize)
            .map(|k| {
                coord
                    .submit(InferenceRequest {
                        id: 100 + k as u64,
                        input: Some(mk(k)),
                        mode: Some(ClusterMode::Pipeline),
                        stages: Some(2),
                        ..Default::default()
                    })
                    .unwrap()
            })
            .collect();
        let mut piped: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap())
            .collect();
        piped.sort_by_key(|r| r.id);
        for (s, p) in singles.iter().zip(&piped) {
            assert_eq!(p.mode, ClusterMode::Pipeline);
            assert_eq!(p.stages, 2);
            assert!(p.sync_cycles > 0, "pipeline replies charge the stage hop");
            assert_eq!(
                s.logits, p.logits,
                "pipelined logits must be bit-identical to single-core"
            );
            assert_eq!(s.argmax, p.argmax);
        }
        // Pipeline metrics: both stage cores ran, and the stream model
        // charged fill bubbles (stages ≥ 2 always leaves some).
        let st = coord.stats();
        assert_eq!(st.stage_util.len(), 2, "two stage cores ran: {:?}", st.stage_util);
        assert!(st.stage_util.iter().all(|&u| u > 0.0));
        assert!(st.bubble_cycles > 0, "a 2-stage stream must report fill bubbles");
        // A 1-stage "pipeline" serves down the single-core path: identical
        // logits and cycles, no hop charge, but the mode echoes back.
        let rx = coord
            .submit(InferenceRequest {
                id: 200,
                input: Some(mk(0)),
                mode: Some(ClusterMode::Pipeline),
                stages: Some(1),
                ..Default::default()
            })
            .unwrap();
        let one = rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(one.mode, ClusterMode::Pipeline);
        assert_eq!(one.stages, 1);
        assert_eq!(one.sync_cycles, 0);
        assert_eq!(one.logits, singles[0].logits);
        assert_eq!(one.sim_cycles, singles[0].sim_cycles, "stages=1 is cycle-exact");
        coord.shutdown();
    }

    #[test]
    fn invalid_parallelism_overrides_are_rejected_at_submission() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        let coord = Coordinator::start(cfg);
        let bad = [
            // Stages without pipeline mode.
            InferenceRequest { id: 0, stages: Some(2), ..Default::default() },
            // Pipeline composed with tensor sharding.
            InferenceRequest {
                id: 1,
                mode: Some(ClusterMode::Pipeline),
                shards: Some(2),
                stages: Some(2),
                ..Default::default()
            },
            // Stage counts out of range.
            InferenceRequest {
                id: 2,
                mode: Some(ClusterMode::Pipeline),
                stages: Some(0),
                ..Default::default()
            },
            InferenceRequest {
                id: 3,
                mode: Some(ClusterMode::Pipeline),
                stages: Some(MAX_SHARDS + 1),
                ..Default::default()
            },
        ];
        for req in bad {
            let id = req.id;
            let err = coord.submit(req).unwrap_err();
            assert!(matches!(err, SubmitError::Invalid { .. }), "req {id}: {err}");
        }
        assert_eq!(coord.rejected(), 0, "Invalid is not backpressure");
        coord.shutdown();
    }

    #[test]
    fn invalid_shard_counts_are_rejected_at_submission() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        let coord = Coordinator::start(cfg);
        for bad in [0usize, MAX_SHARDS + 1] {
            let err = coord
                .submit(InferenceRequest {
                    id: 0,
                    shards: Some(bad),
                    ..Default::default()
                })
                .unwrap_err();
            assert!(matches!(err, SubmitError::Invalid { .. }), "shards={bad}: {err}");
        }
        assert_eq!(coord.rejected(), 0, "Invalid is not backpressure");
        coord.shutdown();
    }

    #[test]
    fn multi_model_deployments_serve_and_count_separately() {
        // Two deployed models: the default `tiny` and the micro test net.
        // Requests route by name, each model owns its own timing-cache
        // entry, and STATS counts per model.
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models.push(Arc::new(tiny_serving_net()));
        let coord = Coordinator::start(cfg);
        let get = |id: u64, net: Option<&str>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    net: net.map(|s| s.to_string()),
                    ..Default::default()
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap()
        };
        let default = get(0, None);
        assert_eq!(default.model, "tiny@100", "no net= selects the first deployment");
        let named = get(1, Some("tiny@100"));
        assert_eq!(named.model, "tiny@100");
        assert!(named.timing_cached, "explicit name shares the default's cache entry");
        assert_eq!(named.sim_cycles, default.sim_cycles);
        let micro = get(2, Some("serving-micro@10"));
        assert_eq!(micro.model, "serving-micro@10");
        assert!(!micro.timing_cached, "each model owns its own timing entry");
        assert!(
            micro.sim_cycles < default.sim_cycles,
            "the micro net must be far cheaper than tiny ({} vs {})",
            micro.sim_cycles,
            default.sim_cycles
        );
        let again = get(3, Some("serving-micro@10"));
        assert!(again.timing_cached);
        // Unknown model: rejected at submission, not backpressure.
        let err = coord
            .submit(InferenceRequest {
                id: 4,
                net: Some("ghost-net".to_string()),
                ..Default::default()
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert_eq!(coord.rejected(), 0);
        // Per-model serve counts, in deployment order.
        let s = coord.stats();
        assert_eq!(
            s.served_by_model,
            vec![("tiny@100".to_string(), 2), ("serving-micro@10".to_string(), 2)]
        );
        assert_eq!(s.served, 4, "Σ per-model counts == served");
        coord.shutdown();
    }

    #[test]
    fn start_rejects_bad_model_lists() {
        // Duplicate names are a misconfiguration.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.push(cfg.models[0].clone());
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err(),
            "duplicate model names must panic at start"
        );
        // An empty deployment list too.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.clear();
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err()
        );
        // The default schedule must validate against EVERY deployed model:
        // an override naming a layer only `tiny` has is rejected up front.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.push(Arc::new(zoo::model("mlp").unwrap()));
        cfg.schedule = PrecisionMap::uniform(Precision::Sub {
            abits: 2,
            wbits: 2,
            use_vbitpack: true,
        })
        .with("c3", Precision::Int8);
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err(),
            "schedule naming a tiny-only layer cannot deploy alongside mlp"
        );
    }

    #[test]
    fn lat_window_percentiles_edge_cases() {
        // Satellite: direct coverage of the p50/p95/p99 feed.
        // Empty window: all zeros.
        let w = LatWindow::new(4);
        assert_eq!(w.percentiles([0.50, 0.95, 0.99]), [0, 0, 0]);
        // Single sample: every percentile is that sample.
        let mut w = LatWindow::new(4);
        w.push(42);
        assert_eq!(w.percentiles([0.0, 0.50, 0.99]), [42, 42, 42]);
        // Cap overflow wraps around: only the most recent `cap` samples
        // survive (the early outlier is forgotten).
        let mut w = LatWindow::new(4);
        w.push(1_000_000);
        for v in [10, 20, 30, 40] {
            w.push(v);
        }
        assert_eq!(w.samples.len(), 4, "window must stay at cap");
        let [p0, p50, p100] = w.percentiles([0.0, 0.50, 1.0]);
        assert_eq!(p0, 10);
        assert_eq!(p100, 40, "the outlier must have been evicted");
        assert_eq!(p50, 30, "median of {{10,20,30,40}} rounds up to index 2");
        // Percentiles are order-insensitive (window sorts internally).
        let mut w = LatWindow::new(8);
        for v in [5, 1, 4, 2, 3] {
            w.push(v);
        }
        assert_eq!(w.percentiles([0.0, 1.0]), [1, 5]);
    }

    #[test]
    fn lat_window_min_max_edge_cases() {
        // Satellite: the min/max companions to the percentile feed.
        // Empty window: (0, 0), matching the percentile convention.
        let w = LatWindow::new(4);
        assert_eq!(w.min_max(), (0, 0));
        // Single sample: min == max == the sample.
        let mut w = LatWindow::new(4);
        w.push(42);
        assert_eq!(w.min_max(), (42, 42));
        // Wraparound: the evicted outlier must not linger as the max.
        let mut w = LatWindow::new(4);
        w.push(1_000_000);
        for v in [10, 20, 30, 40] {
            w.push(v);
        }
        assert_eq!(w.min_max(), (10, 40), "extremes track the surviving window only");
        // Order-insensitive, and min/max agree with p0/p100.
        let mut w = LatWindow::new(8);
        for v in [5, 1, 4, 2, 3] {
            w.push(v);
        }
        let (lo, hi) = w.min_max();
        assert_eq!([lo, hi], w.percentiles([0.0, 1.0]));
    }

    #[test]
    fn program_cache_eviction_policy_unit() {
        // Unit-level check of the FIFO + pinning policy, independent of the
        // serving path.
        let net = tiny_serving_net();
        let quark = MachineConfig::quark(4);
        let key = |spec: &str| ProgKey {
            deploy: DeployKey {
                net_fp: 1,
                machine_fp: 2,
                schedule: PrecisionMap::parse(spec).unwrap(),
                shards: 1,
                mode: ClusterMode::Tensor,
                stages: 1,
            },
            shard: 0,
        };
        let prog = Arc::new(
            compile(&net, &quark, &PrecisionMap::parse("w2a2").unwrap()).unwrap(),
        );
        let mut cache = ProgramCache::new();
        assert!(!cache.insert(key("w2a2"), prog.clone(), true, 2)); // pinned default
        assert!(!cache.insert(key("w1a1"), prog.clone(), false, 2));
        assert_eq!(cache.len(), 2);
        // At cap: the non-pinned FIFO head (w1a1) is evicted, not the
        // default — and the insert reports the eviction (the trace hook).
        assert!(cache.insert(key("int8"), prog.clone(), false, 2));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key("w2a2")).is_some(), "pinned entry survives");
        assert!(cache.get(&key("w1a1")).is_none(), "FIFO head evicted");
        assert!(cache.get(&key("int8")).is_some());
        // Re-inserting an existing key is a no-op (no double insert).
        assert!(!cache.insert(key("int8"), prog, false, 2));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn queue_age_bucket_boundaries() {
        // Power-of-two millisecond buckets: 0 = <1ms, i = [2^(i-1), 2^i) ms,
        // last = everything ≥ 2^(BUCKETS-2) ms.
        assert_eq!(queue_age_bucket(Duration::ZERO), 0);
        assert_eq!(queue_age_bucket(Duration::from_micros(999)), 0);
        assert_eq!(queue_age_bucket(Duration::from_millis(1)), 1);
        assert_eq!(queue_age_bucket(Duration::from_millis(2)), 2);
        assert_eq!(queue_age_bucket(Duration::from_millis(3)), 2);
        assert_eq!(queue_age_bucket(Duration::from_millis(4)), 3);
        assert_eq!(queue_age_bucket(Duration::from_millis(1023)), QUEUE_AGE_BUCKETS - 2);
        assert_eq!(queue_age_bucket(Duration::from_millis(1024)), QUEUE_AGE_BUCKETS - 1);
        assert_eq!(queue_age_bucket(Duration::from_secs(3600)), QUEUE_AGE_BUCKETS - 1);
    }

    #[test]
    fn priority_labels_roundtrip() {
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.label()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::High > Priority::Normal && Priority::Normal > Priority::Low);
    }

    #[test]
    fn deadline_expired_requests_are_dropped_and_counted() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 2;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models = vec![Arc::new(tiny_serving_net())];
        let coord = Coordinator::start(cfg);
        // deadline_ms=0 has always passed by claim time: deterministic
        // expiry without sleeping in the test.
        let rxs: Vec<_> = (0..4u64)
            .map(|id| {
                coord
                    .submit(InferenceRequest { id, deadline_ms: Some(0), ..Default::default() })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(120)).unwrap() {
                Err(ServeError::Expired { deadline_ms, .. }) => assert_eq!(deadline_ms, 0),
                other => panic!("expected expiry, got {other:?}"),
            }
        }
        assert_eq!(coord.expired(), 4);
        assert_eq!(coord.served(), 0, "expired requests never run");
        // The worker is still healthy: an undeadlined request is served.
        let rx = coord.submit(InferenceRequest { id: 99, ..Default::default() }).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
        assert_eq!(r.id, 99);
        let s = coord.stats();
        // Conservation: submitted == served + rejected + expired + degraded.
        assert_eq!(s.served + s.rejected + s.expired + s.degraded, 5);
        // Expired requests still record their queue age.
        assert_eq!(s.queue_age_hist.len(), QUEUE_AGE_BUCKETS);
        assert_eq!(s.queue_age_hist.iter().sum::<u64>(), 5, "4 expired + 1 served");
        coord.shutdown();
    }

    #[test]
    fn degrade_policy_reroutes_to_the_fallback_schedule() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models = vec![Arc::new(tiny_serving_net())];
        // depth 0: every eligible submission degrades — deterministic.
        cfg.degrade = Some(DegradePolicy {
            schedule: PrecisionMap::uniform(Precision::Sub {
                abits: 1,
                wbits: 1,
                use_vbitpack: true,
            }),
            depth: 0,
        });
        let coord = Coordinator::start(cfg);
        let get = |req: InferenceRequest| {
            coord
                .submit(req)
                .unwrap()
                .recv_timeout(Duration::from_secs(120))
                .unwrap()
                .unwrap()
        };
        // A default-schedule request is rerouted to the fallback.
        let d = get(InferenceRequest { id: 0, ..Default::default() });
        assert!(d.degraded, "default-schedule request must degrade at depth 0");
        assert_eq!(d.precision, "w1a1", "degraded responses carry the fallback label");
        // A request pinning its own schedule is exempt.
        let pinned = get(InferenceRequest {
            id: 1,
            schedule: Some(PrecisionMap::uniform(Precision::Int8)),
            ..Default::default()
        });
        assert!(!pinned.degraded, "explicit schedules are never rewritten");
        assert_eq!(pinned.precision, "int8");
        // Counters: served and degraded are disjoint; per-model includes both.
        assert_eq!(coord.degraded(), 1);
        assert_eq!(coord.served(), 1);
        let s = coord.stats();
        assert_eq!(s.degraded, 1);
        assert_eq!(s.served, 1);
        assert_eq!(s.served_by_model[0].1, 2, "per-model counts include degraded completions");
        coord.shutdown();
    }

    #[test]
    fn high_priority_requests_are_claimed_before_low() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        // Occupy the single worker with a functional request (a timing miss
        // plus a full replay — a wide window), then queue a low- and a
        // high-priority probe behind it. The high one must be claimed first.
        let n = 32 * 32 * 3;
        let blocker = coord
            .submit(InferenceRequest { id: 0, input: Some(vec![3u8; n]), ..Default::default() })
            .unwrap();
        while coord.stats().queue_depth > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let low = coord
            .submit(InferenceRequest { id: 1, prio: Priority::Low, ..Default::default() })
            .unwrap();
        let high = coord
            .submit(InferenceRequest { id: 2, prio: Priority::High, ..Default::default() })
            .unwrap();
        blocker.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        let l = low.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        let h = high.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(h.prio, Priority::High);
        assert_eq!(l.prio, Priority::Low);
        assert!(
            h.batch_id < l.batch_id,
            "high priority must be claimed first (batch {} vs {})",
            h.batch_id,
            l.batch_id
        );
        coord.shutdown();
    }

    #[test]
    fn stats_expose_queue_age_and_per_model_slo() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models = vec![Arc::new(tiny_serving_net())];
        let coord = Coordinator::start(cfg);
        for id in 0..3u64 {
            coord
                .submit(InferenceRequest { id, ..Default::default() })
                .unwrap()
                .recv_timeout(Duration::from_secs(120))
                .unwrap()
                .unwrap();
        }
        let s = coord.stats();
        assert_eq!(s.queue_age_hist.len(), QUEUE_AGE_BUCKETS);
        assert_eq!(s.queue_age_hist.iter().sum::<u64>(), 3, "every completion is recorded");
        assert_eq!(s.slo_by_model.len(), 1);
        assert_eq!(s.slo_by_model[0].model, "serving-micro@10");
        assert!(s.slo_by_model[0].p99_us > 0, "the first (miss) request took real time");
        assert!(s.slo_by_model[0].p99_us >= s.slo_by_model[0].p50_us);
        assert!(s.slo_by_model[0].p95_us >= s.slo_by_model[0].p50_us);
        coord.shutdown();
    }
}
