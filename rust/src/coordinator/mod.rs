//! Batching inference coordinator — the L3 serving layer.
//!
//! The paper's contribution is the core itself, so L3 is the "thin driver
//! plus" the workspace mandates: a request router + dynamic batcher in front
//! of a pool of simulated Quark cores (std threads; the environment has no
//! async runtime available — see Cargo.toml), with an optional PJRT
//! golden-model cross-check ([`golden`]) wired into the data path.
//!
//! Flow:
//! ```text
//! clients → submit() → bounded queue → batcher (size/timeout) → worker pool
//!               │ BUSY when full                    (one persistent core each)
//!               └───────────────────────────────────────────────────────────
//! ```
//!
//! Serving-path design (vs the original per-request loop):
//!
//! * **Persistent cores.** Each worker owns one [`Sim`] for its whole
//!   lifetime (`WorkerCore`); between requests only the bump allocator is
//!   rewound, so per-request `Sim` construction (VRF + 192 MiB of simulated
//!   memory) is paid once.
//! * **Deterministic timing cache.** Cycle counts of a `TimingOnly` run are
//!   a pure function of `(net graph, precision schedule, machine config)` —
//!   the kernels are data-independent. The coordinator memoizes them in a
//!   per-coordinator map keyed by structural fingerprints plus the
//!   [`PrecisionMap`], so repeat requests against the same deployment resolve
//!   timing with a lookup instead of a multi-ms re-simulation
//!   (`benches/coordinator_throughput.rs` measures the win).
//! * **Compiled-program cache.** Next to the timing cache, and under the
//!   same key, the coordinator caches [`CompiledProgram`] artifacts
//!   ([`crate::program::compile`]): the emitted instruction trace, buffer
//!   plan, and init image of one (net, machine, schedule) deployment. The
//!   warm serving path does **zero kernel emission** — a worker writes the
//!   request's input bytes, replays the trace
//!   ([`Sim::execute_functional`]), and reads the logits
//!   (`benches/program_replay.rs` measures the win over re-emission).
//!   Timing-cache misses also replay the cached program (`Sim::execute` in
//!   `TimingOnly`) instead of re-emitting.
//! * **Per-request precision schedules.** A request may carry its own
//!   [`PrecisionMap`] (wire: the `prec=` field of `INFER`), overriding the
//!   deployment default — the schedule-space exploration the mixed-precision
//!   papers motivate, without redeploying. Schedules are validated at
//!   submission ([`SubmitError::Invalid`]) and occupy their own timing-cache
//!   entries.
//! * **Real batched inference.** Requests that carry input bytes are run
//!   through the functional executor (`SimMode::Full`) on the worker's
//!   persistent core; the response carries the resulting logits and argmax.
//!   Requests without input are timing-only probes.
//! * **Cluster sharding.** A request may ask for its inference to be
//!   partitioned across `N` simulated cores ([`crate::cluster`]; wire: the
//!   `shards=` field of `INFER`, deployment default `serve --shards`).
//!   Shard programs live as per-shard entries under the same `DeployKey`
//!   program cache; reported cycles follow the cluster model (`max` shard
//!   compute + modeled all-gather sync), and the logits are bit-identical
//!   to single-core serving.
//! * **Multi-model serving.** The coordinator deploys a *set* of
//!   [`NetGraph`]s ([`CoordinatorConfig::models`], CLI `serve --models
//!   a,b,c`) — named zoo models ([`crate::nn::zoo`]), the first being the
//!   default. A request selects its model by name (wire: the `net=` field
//!   of `INFER`; the `MODELS` command lists the deployments); unknown names
//!   are rejected at submission. Every cache key (`DeployKey`) carries the
//!   graph fingerprint, so each model owns its own timing entries and
//!   pinned default programs, and `STATS` counts served requests per model.
//! * **Backpressure + metrics.** The queue is bounded
//!   ([`CoordinatorConfig::max_queue`]); `submit` rejects with
//!   [`SubmitError::Busy`] when full. [`Coordinator::stats`] exposes queue
//!   depth, served/rejected counts (total and per model), cache hit/miss
//!   counts (with program compiles attributed per worker), cluster
//!   sync-cycle and shard-core utilization counters, latency percentiles
//!   over a sliding window, and per-worker utilization.

pub mod golden;
pub mod server;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arch::MachineConfig;
use crate::cluster::{cluster_timing, ClusterCores, ClusterProgram};
use crate::nn::model::{Precision, PrecisionMap, ShardPlan};
use crate::nn::{zoo, NetGraph};
use crate::program::{compile, compile_shard, CompiledProgram};
use crate::sim::{Sim, SimMode};

/// Upper bound on per-request shard counts (the cluster runtime spawns one
/// host thread + one persistent core per shard; 8 matches the widest
/// configuration the scaling report explores).
pub const MAX_SHARDS: usize = 8;

/// One inference request (CIFAR-sized input codes).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// Input activation codes (u8, up to 32·32·3 bytes; shorter inputs are
    /// zero-padded). `None` requests timing only — no functional execution.
    pub input: Option<Vec<u8>>,
    /// Deployed model this request targets, by [`NetGraph::name`] (wire:
    /// the `net=` field of `INFER`); `None` uses the deployment's default
    /// model (the first entry of [`CoordinatorConfig::models`]). Unknown
    /// names are rejected at submission ([`SubmitError::Invalid`]).
    pub net: Option<String>,
    /// Per-request precision schedule; `None` uses the deployment default
    /// ([`CoordinatorConfig::schedule`]).
    pub schedule: Option<PrecisionMap>,
    /// Tensor-parallel shard count ([`crate::cluster`]); `None` uses the
    /// deployment default ([`CoordinatorConfig::shards`]), 1 = single core.
    pub shards: Option<usize>,
}

/// Completed inference.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Simulated device cycles for the whole network.
    pub sim_cycles: u64,
    /// Simulated device latency in microseconds (cycles / freq).
    pub device_us: f64,
    /// Wall-clock time spent queued before a worker picked the batch up.
    pub queue_time: Duration,
    /// Wall-clock simulation (service) time.
    pub service_time: Duration,
    /// Which worker/core served it.
    pub worker: usize,
    /// Batch this request was grouped into.
    pub batch_id: u64,
    /// Whether `sim_cycles` came from the timing cache (vs a fresh run).
    pub timing_cached: bool,
    /// Label of the schedule this request ran under
    /// ([`PrecisionMap::label`]; wire field `prec=`).
    pub precision: String,
    /// Name of the model this request ran on ([`NetGraph::name`]; wire
    /// field `net=`).
    pub model: String,
    /// Shard cores this request's inference was partitioned across (1 =
    /// classic single-core serving).
    pub shards: usize,
    /// Modeled inter-core all-gather cycles included in `sim_cycles`
    /// (0 when `shards == 1`).
    pub sync_cycles: u64,
    /// Output of the network's last layer for the submitted input (u8 codes
    /// widened to f32 at integer precisions, raw floats at fp32). `None` for
    /// timing-only requests.
    pub logits: Option<Vec<f32>>,
    /// Index of the largest logit (first wins on ties).
    pub argmax: Option<usize>,
}

/// Why a submission was not accepted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The request queue is at capacity; back off and retry (wire: `BUSY`).
    Busy { depth: usize },
    /// The request cannot run on this deployment: unknown model name, or
    /// an invalid precision schedule / shard count for the selected model
    /// (unknown layer, fp32/integer mix, unsupported by the machine, too
    /// few channels). Not retryable as-is (wire: `ERR invalid request:`).
    Invalid { reason: String },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { depth } => write!(f, "queue full (depth {depth})"),
            SubmitError::Invalid { reason } => write!(f, "invalid request: {reason}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub machine: MachineConfig,
    /// Default precision schedule for requests that do not carry their own.
    pub schedule: PrecisionMap,
    /// Simulated cores (worker threads).
    pub workers: usize,
    /// Max requests per batch.
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch.
    pub batch_timeout: Duration,
    /// Queue bound: submissions beyond this depth are rejected with
    /// [`SubmitError::Busy`].
    pub max_queue: usize,
    /// Default tensor-parallel shard count for requests that do not carry
    /// their own (`serve --shards N`; 1 = single-core serving).
    pub shards: usize,
    /// Deployed models, each a validated [`NetGraph`] with a unique name.
    /// The first entry is the default for requests without `net=`
    /// (`serve --models a,b,c`).
    pub models: Vec<Arc<NetGraph>>,
}

impl CoordinatorConfig {
    /// A small default: Quark-4L, 2-bit, the zoo's `tiny` net for snappy
    /// serving.
    pub fn demo() -> Self {
        CoordinatorConfig {
            machine: MachineConfig::quark(4),
            schedule: PrecisionMap::uniform(Precision::Sub {
                abits: 2,
                wbits: 2,
                use_vbitpack: true,
            }),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            max_queue: 256,
            shards: 1,
            models: vec![Arc::new(demo_net())],
        }
    }

    /// The deployment's default model (the first of
    /// [`CoordinatorConfig::models`]).
    pub fn default_model(&self) -> &Arc<NetGraph> {
        &self.models[0]
    }

    /// Index of the deployed model a request's `net` field selects;
    /// `Err` names the unknown model.
    fn model_index(&self, net: Option<&str>) -> Result<usize, String> {
        match net {
            None => Ok(0),
            Some(name) => self
                .models
                .iter()
                .position(|m| m.name() == name)
                .ok_or_else(|| {
                    format!(
                        "unknown model {name:?} (deployed: {})",
                        self.models.iter().map(|m| m.name()).collect::<Vec<_>>().join(", ")
                    )
                }),
        }
    }
}

/// The serving demo model: the zoo's `tiny` graph (4 convs + pool + FC —
/// full ResNet-18 per request is a multi-second simulation; this keeps the
/// serving path interactive while exercising every kernel).
pub fn demo_net() -> NetGraph {
    zoo::model("tiny").expect("the tiny zoo entry is always valid")
}

// ---- machine fingerprint (cache-key half; the network half is
//      [`NetGraph::fingerprint`]) ----

pub use crate::program::machine_fingerprint;

/// Cache key shared by the timing cache and the program cache: the
/// deployment fingerprints plus the (canonical-form) precision schedule and
/// the tensor-parallel shard count the request ran under.
#[derive(Clone, PartialEq, Eq, Hash)]
struct DeployKey {
    net_fp: u64,
    machine_fp: u64,
    schedule: PrecisionMap,
    shards: usize,
}

/// Program-cache key: one entry per *shard program* of a deployment
/// (`shard` is always 0 for single-core deployments).
#[derive(Clone, PartialEq, Eq, Hash)]
struct ProgKey {
    deploy: DeployKey,
    shard: usize,
}

#[derive(Clone, Copy)]
struct TimingEntry {
    sim_cycles: u64,
    /// Modeled all-gather cycles included in `sim_cycles` (0 single-core).
    sync_cycles: u64,
}

/// The compiled-program cache: bounded FIFO with the deployment-default
/// entries pinned. When full, the *oldest non-default* entry is evicted to
/// admit the newcomer (clients cycling throwaway `prec=`/`shards=`
/// combinations therefore churn among themselves and can never evict a
/// deployed model's own warm path). Default-schedule inserts always
/// succeed — they are at most `models · MAX_SHARDS` programs (one default
/// per deployed model), so the cache is bounded by
/// `cap + models · MAX_SHARDS` entries.
struct ProgramCache {
    entries: HashMap<ProgKey, Arc<CompiledProgram>>,
    /// Insertion order of the evictable (non-pinned) keys.
    order: VecDeque<ProgKey>,
}

impl ProgramCache {
    fn new() -> Self {
        ProgramCache { entries: HashMap::new(), order: VecDeque::new() }
    }

    fn get(&self, key: &ProgKey) -> Option<Arc<CompiledProgram>> {
        self.entries.get(key).cloned()
    }

    fn insert(&mut self, key: ProgKey, prog: Arc<CompiledProgram>, pinned: bool, cap: usize) {
        if self.entries.contains_key(&key) {
            return; // concurrent miss already inserted the identical artifact
        }
        if pinned {
            self.entries.insert(key, prog);
            return;
        }
        while self.entries.len() >= cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.entries.remove(&old);
                }
                None => return, // everything resident is pinned; don't insert
            }
        }
        self.order.push_back(key.clone());
        self.entries.insert(key, prog);
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.entries.len()
    }
}

// ---- serving-metrics plumbing ----

/// Sliding window of recent end-to-end latencies (µs) for percentiles.
struct LatWindow {
    cap: usize,
    samples: VecDeque<u64>,
}

impl LatWindow {
    fn new(cap: usize) -> Self {
        LatWindow { cap, samples: VecDeque::with_capacity(cap) }
    }

    fn push(&mut self, us: u64) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(us);
    }

    /// Each p in [0,1]; zeros when no samples yet. One sort serves all
    /// requested percentiles (this runs under the lock workers take per
    /// response, so the hold time matters).
    fn percentiles<const N: usize>(&self, ps: [f64; N]) -> [u64; N] {
        if self.samples.is_empty() {
            return [0; N];
        }
        let mut sorted: Vec<u64> = self.samples.iter().copied().collect();
        sorted.sort_unstable();
        ps.map(|p| {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx.min(sorted.len() - 1)]
        })
    }
}

/// Snapshot of serving metrics (the extended `STATS` wire reply).
#[derive(Clone, Debug)]
pub struct CoordStats {
    pub served: u64,
    pub rejected: u64,
    /// Served requests per deployed model, in deployment order. The total
    /// and per-model counters are separate relaxed atomics, so a snapshot
    /// taken while requests are in flight may be off by the requests
    /// currently completing; `Σ counts == served` once responses drain.
    pub served_by_model: Vec<(String, u64)>,
    pub queue_depth: usize,
    pub workers: usize,
    /// Timing-cache hit/miss counts (one resolution per request).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Program-cache hit/miss counts. A program is resolved whenever a
    /// request needs one (it carries input bytes, or its timing missed);
    /// timing-cache hits without input resolve no program.
    pub program_hits: u64,
    pub program_misses: u64,
    /// Total wall-clock µs spent compiling programs (cold path) vs
    /// replaying them (warm path) — the compile-once/run-many ratio.
    pub compile_us: u64,
    pub replay_us: u64,
    /// Program compiles (cache misses) attributed per worker, so cluster
    /// and single-core miss traffic are both attributable to the core that
    /// paid for them. `Σ compile_by_worker == program_misses`.
    pub compile_by_worker: Vec<u64>,
    /// Total modeled inter-core all-gather cycles across served cluster
    /// requests (0 until a `shards > 1` request is served).
    pub sync_cycles: u64,
    /// Busy core-equivalents per shard *position*, aggregated over every
    /// worker's cluster pool (each worker owns its own shard cores, so with
    /// `W` workers serving cluster traffic a position can report up to
    /// `W`·1.0). Trailing never-used positions are trimmed (empty until a
    /// `shards > 1` request runs functionally).
    pub shard_util: Vec<f64>,
    /// End-to-end (queue + service) latency percentiles in µs over the
    /// most recent `LAT_WINDOW` responses.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Fraction of wall-clock each worker spent serving batches.
    pub utilization: Vec<f64>,
}

const LAT_WINDOW: usize = 4096;

/// Timing-cache size bound. Schedules are client-supplied (the `prec=` wire
/// field), so without a cap a client cycling distinct override sets could
/// grow the map without limit. Past the cap, new schedules are still served
/// (one fresh `TimingOnly` run each) but no longer memoized.
const MAX_TIMING_ENTRIES: usize = 1024;

/// Program-cache size bound — far smaller than the timing cache: a
/// [`CompiledProgram`] holds the full dynamic instruction trace (tens of MB
/// for ResNet-scale nets), so the cap bounds server *memory*, not just map
/// growth. At the cap the [`ProgramCache`] evicts the oldest non-default
/// entry (FIFO) to admit the newcomer; the deployment-default programs are
/// pinned and can never be evicted, so client-supplied `prec=`/`shards=`
/// churn only displaces other client-supplied entries. Evicted keys simply
/// recompile on next use (a program-cache miss).
const MAX_PROGRAM_ENTRIES: usize = 16;

struct Queued {
    req: InferenceRequest,
    /// Index into [`CoordinatorConfig::models`], resolved at submission.
    model_idx: usize,
    enqueued: Instant,
    reply: mpsc::Sender<InferenceResponse>,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    available: Condvar,
    shutdown: AtomicBool,
    batch_counter: AtomicU64,
    served: AtomicU64,
    rejected: AtomicU64,
    /// Served requests per deployed model (index-aligned with
    /// [`CoordinatorConfig::models`]).
    served_by_model: Vec<AtomicU64>,
    timing_cache: Mutex<HashMap<DeployKey, TimingEntry>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Compiled (net, machine, schedule, shard) artifacts, `Arc`-shared
    /// with the workers replaying them.
    program_cache: Mutex<ProgramCache>,
    program_hits: AtomicU64,
    program_misses: AtomicU64,
    compile_ns: AtomicU64,
    replay_ns: AtomicU64,
    /// Program compiles attributed to the worker that performed them.
    compile_by_worker: Vec<AtomicU64>,
    /// Modeled all-gather cycles accumulated over served cluster requests.
    sync_cycles: AtomicU64,
    /// Per-shard-core nanoseconds spent inside cluster replays (indexed by
    /// shard position, up to [`MAX_SHARDS`]).
    shard_busy_ns: Vec<AtomicU64>,
    latencies: Mutex<LatWindow>,
    /// Per-worker nanoseconds spent inside batch service.
    busy_ns: Vec<AtomicU64>,
    started: Instant,
}

/// The coordinator: owns the batcher + worker threads.
pub struct Coordinator {
    shared: Arc<Shared>,
    cfg: CoordinatorConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start serving. Panics if the model list is empty or duplicated, or
    /// if the deployment's default schedule or shard count is invalid for
    /// any deployed model on this machine (misconfiguration, not a runtime
    /// condition).
    pub fn start(cfg: CoordinatorConfig) -> Self {
        assert!(!cfg.models.is_empty(), "a coordinator needs at least one deployed model");
        for (i, model) in cfg.models.iter().enumerate() {
            if cfg.models[..i].iter().any(|m| m.name() == model.name()) {
                panic!("duplicate deployed model {:?}", model.name());
            }
            if let Err(e) = validate_schedule(&cfg.schedule, model, &cfg.machine) {
                panic!("invalid coordinator schedule for model {:?}: {e}", model.name());
            }
            if let Err(e) = validate_shards(cfg.shards, &cfg.schedule, model) {
                panic!("invalid coordinator shard count for model {:?}: {e}", model.name());
            }
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            batch_counter: AtomicU64::new(0),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            served_by_model: (0..cfg.models.len()).map(|_| AtomicU64::new(0)).collect(),
            timing_cache: Mutex::new(HashMap::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            program_cache: Mutex::new(ProgramCache::new()),
            program_hits: AtomicU64::new(0),
            program_misses: AtomicU64::new(0),
            compile_ns: AtomicU64::new(0),
            replay_ns: AtomicU64::new(0),
            compile_by_worker: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            sync_cycles: AtomicU64::new(0),
            shard_busy_ns: (0..MAX_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            latencies: Mutex::new(LatWindow::new(LAT_WINDOW)),
            busy_ns: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
        });
        let workers = (0..cfg.workers)
            .map(|wid| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("quark-core-{wid}"))
                    .spawn(move || worker_loop(wid, shared, cfg))
                    .expect("spawn worker")
            })
            .collect();
        Coordinator { shared, cfg, workers }
    }

    /// Submit a request; returns a receiver for the response,
    /// [`SubmitError::Busy`] when the queue is at capacity, or
    /// [`SubmitError::Invalid`] when the request names an unknown model or
    /// its schedule/shard count cannot run on this deployment.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<InferenceResponse>, SubmitError> {
        let model_idx = match self.cfg.model_index(req.net.as_deref()) {
            Ok(i) => i,
            Err(reason) => return Err(SubmitError::Invalid { reason }),
        };
        let model = &self.cfg.models[model_idx];
        if let Some(sched) = &req.schedule {
            if let Err(reason) = validate_schedule(sched, model, &self.cfg.machine) {
                return Err(SubmitError::Invalid { reason });
            }
        }
        // Validate the *effective* (schedule, shards) pair, not just explicit
        // overrides: a request overriding only the schedule still runs at the
        // deployment's shard count (e.g. fp32 on a sharded fp32-capable
        // deployment must be rejected here, not panic a worker). All-default
        // requests skip the walk — Coordinator::start validated that pair
        // against every deployed model.
        if req.shards.is_some() || req.schedule.is_some() {
            let shards = req.shards.unwrap_or(self.cfg.shards);
            let sched = req.schedule.as_ref().unwrap_or(&self.cfg.schedule);
            if let Err(reason) = validate_shards(shards, sched, model) {
                return Err(SubmitError::Invalid { reason });
            }
        }
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.cfg.max_queue {
            let depth = q.len();
            drop(q);
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Busy { depth });
        }
        q.push_back(Queued { req, model_idx, enqueued: Instant::now(), reply: tx });
        drop(q);
        self.shared.available.notify_one();
        Ok(rx)
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Requests rejected by backpressure so far.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Snapshot of the serving metrics.
    pub fn stats(&self) -> CoordStats {
        let queue_depth = self.shared.queue.lock().unwrap().len();
        let [p50_us, p95_us, p99_us] =
            self.shared.latencies.lock().unwrap().percentiles([0.50, 0.95, 0.99]);
        let elapsed_ns = self.shared.started.elapsed().as_nanos().max(1) as f64;
        CoordStats {
            served: self.shared.served.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            served_by_model: self
                .cfg
                .models
                .iter()
                .zip(self.shared.served_by_model.iter())
                .map(|(m, c)| (m.name().to_string(), c.load(Ordering::Relaxed)))
                .collect(),
            queue_depth,
            workers: self.cfg.workers,
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            program_hits: self.shared.program_hits.load(Ordering::Relaxed),
            program_misses: self.shared.program_misses.load(Ordering::Relaxed),
            compile_us: self.shared.compile_ns.load(Ordering::Relaxed) / 1_000,
            replay_us: self.shared.replay_ns.load(Ordering::Relaxed) / 1_000,
            compile_by_worker: self
                .shared
                .compile_by_worker
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            sync_cycles: self.shared.sync_cycles.load(Ordering::Relaxed),
            shard_util: {
                // Deliberately unclamped: the counters aggregate every
                // worker's pool, so the meaningful unit is busy
                // core-equivalents per shard position, not a 0–1 fraction.
                let mut util: Vec<f64> = self
                    .shared
                    .shard_busy_ns
                    .iter()
                    .map(|b| b.load(Ordering::Relaxed) as f64 / elapsed_ns)
                    .collect();
                while util.last() == Some(&0.0) {
                    util.pop();
                }
                util
            },
            p50_us,
            p95_us,
            p99_us,
            utilization: self
                .shared
                .busy_ns
                .iter()
                .map(|b| (b.load(Ordering::Relaxed) as f64 / elapsed_ns).min(1.0))
                .collect(),
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Stop workers and join.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Full schedule validation against one deployed model: map shape +
/// machine caps.
fn validate_schedule(
    sched: &PrecisionMap,
    net: &NetGraph,
    machine: &MachineConfig,
) -> Result<(), String> {
    sched.validate(net)?;
    sched.validate_machine(net, machine)
}

/// Shard-count validation against one deployed model: bounds, channel
/// counts, and the integer-only rule ([`ShardPlan`]). The single source of
/// truth for both the submit path and the CLI's `serve --shards` check.
pub(crate) fn validate_shards(
    shards: usize,
    sched: &PrecisionMap,
    net: &NetGraph,
) -> Result<(), String> {
    if shards == 0 || shards > MAX_SHARDS {
        return Err(format!("shard count {shards} out of range (1\u{2013}{MAX_SHARDS})"));
    }
    ShardPlan::derive(net, shards)?.validate_schedule(sched)
}

/// One worker's persistent simulated core. Constructed once per worker
/// thread; between model runs only the bump allocator is rewound (the Sim's
/// VRF, timing state, and 192 MiB memory arena are reused).
struct WorkerCore {
    sim: Sim,
    heap_base: u64,
}

impl WorkerCore {
    fn new(machine: MachineConfig) -> Self {
        let sim = Sim::new(machine);
        let heap_base = sim.machine.mem.brk();
        WorkerCore { sim, heap_base }
    }

    fn rewind(&mut self) {
        self.sim.machine.mem.reset_alloc_to(self.heap_base);
    }

    /// One `TimingOnly` replay of `prog` (timing-cache-miss path — still
    /// zero kernel emission when the program itself was cached).
    fn timing_cycles(&mut self, prog: &CompiledProgram) -> u64 {
        self.rewind();
        self.sim.set_mode(SimMode::TimingOnly);
        let base = self.sim.alloc(prog.mem_len());
        self.sim.execute(prog, base).cycles
    }

    /// Functional replay of `prog` on `input`: write input bytes, replay the
    /// decode-once lowering (values only — bit-identical to
    /// [`Sim::execute_functional`], cycles come from the timing cache), read
    /// logits. Returns (logits, argmax).
    fn infer(&mut self, prog: &CompiledProgram, input: &[u8]) -> (Vec<f32>, usize) {
        self.rewind();
        let base = self.sim.alloc(prog.mem_len());
        let run = self.sim.execute_lowered(prog, base, Some(input));
        if prog.is_fp32() {
            let logits = self.sim.read_f32s(run.out_addr, run.out_elems);
            let am = argmax_of(&logits);
            (logits, am)
        } else {
            widen_logits(&self.sim.read_u8s(run.out_addr, run.out_elems))
        }
    }
}

/// Index of the largest logit, first max wins on ties.
fn argmax_of(logits: &[f32]) -> usize {
    let mut am = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[am] {
            am = i;
        }
    }
    am
}

/// Widen u8 logit codes to f32 and locate the argmax — one shared helper
/// for the single-core and cluster serving paths, so the tie-break rule can
/// never diverge between them.
fn widen_logits(codes: &[u8]) -> (Vec<f32>, usize) {
    let logits: Vec<f32> = codes.iter().map(|&v| v as f32).collect();
    let am = argmax_of(&logits);
    (logits, am)
}

/// Resolve one compiled (shard) program for `key`: cache hit is an `Arc`
/// clone, miss compiles once (attributed to worker `wid` in
/// `compile_by_worker`). `memoize` decides whether a miss is inserted: the
/// functional serving path memoizes — it replays per request — while
/// timing-only resolutions compile transiently, so probe-only schedules
/// never pin a trace-sized artifact in server memory. Insertions follow the
/// [`ProgramCache`] FIFO-eviction policy with every deployed model's
/// default-schedule entries pinned. Concurrent misses on one key may
/// compile twice; the first insert wins — both artifacts are identical
/// (compilation is deterministic).
fn resolve_program(
    shared: &Shared,
    cfg: &CoordinatorConfig,
    net: &NetGraph,
    wid: usize,
    key: &ProgKey,
    sched: &PrecisionMap,
    memoize: bool,
) -> Arc<CompiledProgram> {
    if let Some(p) = shared.program_cache.lock().unwrap().get(key) {
        shared.program_hits.fetch_add(1, Ordering::Relaxed);
        return p;
    }
    shared.program_misses.fetch_add(1, Ordering::Relaxed);
    shared.compile_by_worker[wid].fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    let prog = Arc::new(if key.deploy.shards > 1 {
        let plan = ShardPlan::derive(net, key.deploy.shards)
            .expect("shard count was validated at submission");
        compile_shard(net, &cfg.machine, sched, &plan, key.shard)
            .expect("schedule was validated at submission")
    } else {
        compile(net, &cfg.machine, sched).expect("schedule was validated at submission")
    });
    shared.compile_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if memoize {
        // Force the decode-once lowering before the entry becomes visible,
        // so warm replays never pay the lowering walk.
        prog.lowered();
        let pinned = *sched == cfg.schedule && key.deploy.shards == cfg.shards;
        shared.program_cache.lock().unwrap().insert(
            key.clone(),
            prog.clone(),
            pinned,
            MAX_PROGRAM_ENTRIES,
        );
    }
    prog
}

/// Resolve the full shard-program set of a cluster deployment (one
/// per-shard cache entry each) and assemble the [`ClusterProgram`].
///
/// Misses compile sequentially on the serving worker, by choice: each
/// in-flight compile owns a recording-arena `Sim`, so parallelizing an
/// 8-shard cold miss would multiply transient server memory roughly
/// eightfold for a once-per-deployment event (offline callers that want
/// parallel compiles use [`crate::cluster::compile_cluster`]).
fn resolve_cluster(
    shared: &Shared,
    cfg: &CoordinatorConfig,
    net: &NetGraph,
    wid: usize,
    deploy: &DeployKey,
    sched: &PrecisionMap,
    memoize: bool,
) -> ClusterProgram {
    let progs: Vec<Arc<CompiledProgram>> = (0..deploy.shards)
        .map(|shard| {
            let key = ProgKey { deploy: deploy.clone(), shard };
            resolve_program(shared, cfg, net, wid, &key, sched, memoize)
        })
        .collect();
    ClusterProgram::from_shards(progs).expect("per-shard cache entries form one deployment")
}

/// Worker: claims batches (size- or timeout-bounded) and serves them on its
/// persistent simulated core. Timing is resolved per request (requests in
/// one batch may carry different schedules); the caches make repeats free:
/// warm timing is a map lookup, warm functional inference is a program
/// replay with zero kernel emission. Requests with `shards > 1` run on the
/// worker's lazily-built [`ClusterCores`] pool instead of its single core
/// (one pool per worker, rebuilt when the shard count changes — bounding
/// memory at one cluster per worker).
fn worker_loop(wid: usize, shared: Arc<Shared>, cfg: CoordinatorConfig) {
    let mut core = WorkerCore::new(cfg.machine.clone());
    let mut cluster_cores: Option<ClusterCores> = None;
    let model_fps: Vec<u64> = cfg.models.iter().map(|m| m.fingerprint()).collect();
    let machine_fp = machine_fingerprint(&cfg.machine);
    loop {
        // Claim a batch.
        let mut batch = Vec::new();
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                q = shared.available.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
            }
            // First request in hand; wait up to batch_timeout for more.
            batch.push(q.pop_front().unwrap());
            let deadline = Instant::now() + cfg.batch_timeout;
            while batch.len() < cfg.batch_size {
                if let Some(item) = q.pop_front() {
                    batch.push(item);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (nq, timeout) =
                    shared.available.wait_timeout(q, deadline - now).unwrap();
                q = nq;
                if timeout.timed_out() && q.is_empty() {
                    break;
                }
            }
        }
        let batch_id = shared.batch_counter.fetch_add(1, Ordering::Relaxed);
        let busy_t0 = Instant::now();

        // Serve the batch on the persistent core(s).
        for item in batch {
            let model = &cfg.models[item.model_idx];
            let sched = item.req.schedule.as_ref().unwrap_or(&cfg.schedule);
            let shards = item.req.shards.unwrap_or(cfg.shards);
            let key = DeployKey {
                net_fp: model_fps[item.model_idx],
                machine_fp,
                schedule: sched.clone(),
                shards,
            };
            // Resolve the compiled program(s) when this request needs them:
            // it carries input bytes (functional replay), or its timing
            // misses below (TimingOnly replay). Warm timing-only probes
            // touch neither cache entry's payload.
            let cached = shared.timing_cache.lock().unwrap().get(&key).copied();
            let need_progs = item.req.input.is_some() || cached.is_none();
            let memoize = item.req.input.is_some();
            // Single-core requests resolve one program; cluster requests a
            // full shard set (each under its own per-shard cache entry).
            let (prog, cluster) = if !need_progs {
                (None, None)
            } else if shards == 1 {
                let pkey = ProgKey { deploy: key.clone(), shard: 0 };
                (Some(resolve_program(&shared, &cfg, model, wid, &pkey, sched, memoize)), None)
            } else {
                (None, Some(resolve_cluster(&shared, &cfg, model, wid, &key, sched, memoize)))
            };
            // Resolve timing: cache hit is a map lookup, miss is one
            // TimingOnly replay (per shard core, in parallel, for clusters)
            // whose result every later request under the same (net,
            // machine, schedule, shards) key reuses.
            let (sim_cycles, sync_cycles, timing_cached) = match cached {
                Some(e) => {
                    shared.cache_hits.fetch_add(1, Ordering::Relaxed);
                    (e.sim_cycles, e.sync_cycles, true)
                }
                None => {
                    let t0 = Instant::now();
                    let (c, sync) = match &cluster {
                        Some(cp) => {
                            let t = cluster_timing(cp, &cfg.machine);
                            (t.total_cycles(), t.sync_cycles)
                        }
                        None => (core.timing_cycles(prog.as_deref().unwrap()), 0),
                    };
                    shared.replay_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    shared.cache_misses.fetch_add(1, Ordering::Relaxed);
                    let mut cache = shared.timing_cache.lock().unwrap();
                    if cache.len() < MAX_TIMING_ENTRIES {
                        cache.insert(key, TimingEntry { sim_cycles: c, sync_cycles: sync });
                    }
                    drop(cache);
                    (c, sync, false)
                }
            };
            // Account the modeled all-gather once per served cluster request
            // (timing-only probes included — the model is part of the reply).
            if shards > 1 {
                shared.sync_cycles.fetch_add(sync_cycles, Ordering::Relaxed);
            }
            let device_us = sim_cycles as f64 / (cfg.machine.freq_ghz * 1e3);

            let queue_time = item.enqueued.elapsed();
            let t0 = Instant::now();
            let (logits, argmax) = match &item.req.input {
                Some(bytes) => {
                    let (l, a) = match &cluster {
                        Some(cp) => {
                            // (Re)build this worker's shard-core pool when
                            // the requested width changes. One pool per
                            // worker, by choice: caching a pool per shard
                            // count would bound memory at Σ(2..=8) grown
                            // arenas *per worker*; traffic alternating
                            // shard counts pays the rebuild instead.
                            let rebuild =
                                cluster_cores.as_ref().map(|cc| cc.count()) != Some(shards);
                            if rebuild {
                                cluster_cores = Some(ClusterCores::new(&cfg.machine, shards));
                            }
                            let cores = cluster_cores.as_mut().unwrap();
                            let inf = cores.infer(cp, bytes);
                            for (j, ns) in inf.shard_busy_ns.iter().enumerate() {
                                shared.shard_busy_ns[j].fetch_add(*ns, Ordering::Relaxed);
                            }
                            widen_logits(&inf.logits)
                        }
                        None => core.infer(prog.as_deref().unwrap(), bytes),
                    };
                    shared
                        .replay_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    (Some(l), Some(a))
                }
                None => (None, None),
            };
            let service_time = t0.elapsed();
            let resp = InferenceResponse {
                id: item.req.id,
                sim_cycles,
                device_us,
                queue_time,
                service_time,
                worker: wid,
                batch_id,
                timing_cached,
                precision: sched.label(),
                model: model.name().to_string(),
                shards,
                sync_cycles,
                logits,
                argmax,
            };
            shared.served.fetch_add(1, Ordering::Relaxed);
            shared.served_by_model[item.model_idx].fetch_add(1, Ordering::Relaxed);
            shared
                .latencies
                .lock()
                .unwrap()
                .push((queue_time + service_time).as_micros() as u64);
            let _ = item.reply.send(resp);
        }
        shared.busy_ns[wid].fetch_add(busy_t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_requests_and_batches() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 2;
        cfg.batch_size = 4;
        let coord = Coordinator::start(cfg);
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                coord
                    .submit(InferenceRequest { id: i, input: None, net: None, schedule: None, shards: None })
                    .unwrap()
            })
            .collect();
        let mut responses: Vec<_> =
            rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.sim_cycles > 0);
            assert!(r.device_us > 0.0);
            assert_eq!(r.precision, "w2a2");
            assert!(r.logits.is_none(), "timing-only requests carry no logits");
        }
        // Batching grouped at least two requests somewhere.
        let max_batch = responses
            .iter()
            .map(|r| responses.iter().filter(|o| o.batch_id == r.batch_id).count())
            .max()
            .unwrap();
        assert!(max_batch >= 2, "expected some batching, got max batch {max_batch}");
        assert_eq!(coord.served(), 6);
        coord.shutdown();
    }

    #[test]
    fn timing_cache_converges_to_lookups() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        // Sequential submissions: every request after the first must hit.
        let mut cycles = Vec::new();
        for i in 0..5u64 {
            let rx = coord
                .submit(InferenceRequest { id: i, input: None, net: None, schedule: None, shards: None })
                .unwrap();
            let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            cycles.push((r.sim_cycles, r.timing_cached));
        }
        assert!(cycles.iter().all(|&(c, _)| c == cycles[0].0), "cached timing must be stable");
        assert!(!cycles[0].1, "first request is a miss");
        assert!(cycles[1..].iter().all(|&(_, hit)| hit), "later requests must hit");
        let s = coord.stats();
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.cache_hits, 4);
        coord.shutdown();
    }

    #[test]
    fn real_inputs_produce_logits_that_depend_on_data() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 2;
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let rx_a = coord
            .submit(InferenceRequest { id: 0, input: Some(vec![0u8; n]), net: None, schedule: None, shards: None })
            .unwrap();
        let rx_b = coord
            .submit(InferenceRequest { id: 1, input: Some(vec![200u8; n]), net: None, schedule: None, shards: None })
            .unwrap();
        let a = rx_a.recv_timeout(Duration::from_secs(300)).unwrap();
        let b = rx_b.recv_timeout(Duration::from_secs(300)).unwrap();
        let (la, lb) = (a.logits.unwrap(), b.logits.unwrap());
        assert_eq!(la.len(), 100, "demo net classifies over 100 classes");
        assert_eq!(lb.len(), 100);
        assert!(a.argmax.unwrap() < 100 && b.argmax.unwrap() < 100);
        assert_ne!(la, lb, "different inputs must produce different logits");
        // Determinism: same input → same logits.
        let rx_c = coord
            .submit(InferenceRequest { id: 2, input: Some(vec![200u8; n]), net: None, schedule: None, shards: None })
            .unwrap();
        let c = rx_c.recv_timeout(Duration::from_secs(300)).unwrap();
        assert_eq!(lb, c.logits.unwrap(), "same input must reproduce the same logits");
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.max_queue = 0; // every submission rejects deterministically
        let coord = Coordinator::start(cfg);
        let err = coord
            .submit(InferenceRequest { id: 9, input: None, net: None, schedule: None, shards: None })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Busy { .. }));
        assert_eq!(coord.rejected(), 1);
        assert_eq!(coord.served(), 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_schedules_are_rejected_at_submission() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        let coord = Coordinator::start(cfg);
        // Unknown layer name (override must differ from the default — equal
        // ones canonicalize away).
        let err = coord
            .submit(InferenceRequest {
                id: 0,
                input: None,
                net: None,
                schedule: Some(
                    PrecisionMap::uniform(Precision::Sub {
                        abits: 2,
                        wbits: 2,
                        use_vbitpack: true,
                    })
                    .with("ghost", Precision::Int8),
                ),
                shards: None,
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        // fp32 needs the vector FPU the Quark machine lacks.
        let err = coord
            .submit(InferenceRequest {
                id: 1,
                input: None,
                net: None,
                schedule: Some(PrecisionMap::uniform(Precision::Fp32)),
                shards: None,
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        assert_eq!(coord.rejected(), 0, "Invalid is not backpressure");
        coord.shutdown();
    }

    #[test]
    fn per_request_schedules_get_separate_cache_entries() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let get = |id: u64, sched: Option<PrecisionMap>| {
            let rx = coord
                .submit(InferenceRequest { id, input: None, net: None, schedule: sched, shards: None })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(120)).unwrap()
        };
        let int2 = get(0, None); // deployment default: uniform w2a2
        let int8 = get(1, Some(PrecisionMap::uniform(Precision::Int8)));
        let mixed = get(
            2,
            Some(
                PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true })
                    .with("c1", Precision::Int8),
            ),
        );
        assert_eq!(int2.precision, "w2a2");
        assert_eq!(int8.precision, "int8");
        assert_eq!(mixed.precision, "mixed(w2a2+1)");
        assert!(!int8.timing_cached && !mixed.timing_cached, "distinct keys each miss once");
        assert!(int8.sim_cycles > int2.sim_cycles, "int8 must cost more cycles than 2-bit");
        assert!(
            mixed.sim_cycles > int2.sim_cycles && mixed.sim_cycles < int8.sim_cycles,
            "mixed ({}) must land between 2-bit ({}) and int8 ({})",
            mixed.sim_cycles,
            int2.sim_cycles,
            int8.sim_cycles
        );
        // Repeats hit their own entries.
        let again = get(3, Some(PrecisionMap::uniform(Precision::Int8)));
        assert!(again.timing_cached);
        assert_eq!(again.sim_cycles, int8.sim_cycles);
        coord.shutdown();
    }

    #[test]
    fn program_cache_compiles_once_and_replays() {
        // One deployment schedule, a mix of timing-only and functional
        // requests: exactly one compile; functional repeats are cache hits.
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let get = |id: u64, input: Option<Vec<u8>>| {
            let rx = coord.submit(InferenceRequest { id, input, net: None, schedule: None, shards: None }).unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap()
        };
        // Timing miss: compiles a transient program (timing-only schedules
        // are not memoized — they would pin trace-sized artifacts).
        let first = get(0, None);
        assert!(!first.timing_cached);
        // Warm timing-only probe: no program resolution at all.
        let warm = get(1, None);
        assert!(warm.timing_cached);
        let s = coord.stats();
        assert_eq!(s.program_misses, 1, "one deployment schedule, one compile so far");
        assert_eq!(s.program_hits, 0, "warm timing probes never touch the program cache");
        assert!(s.compile_us > 0, "compile time must be accounted");
        // Functional requests memoize, then replay the cached program.
        let a = get(2, Some(vec![7u8; n]));
        let b = get(3, Some(vec![7u8; n]));
        assert_eq!(a.logits, b.logits, "replays of one program are deterministic");
        let s = coord.stats();
        assert_eq!(s.program_misses, 2, "first functional request compiles + memoizes");
        assert_eq!(s.program_hits, 1, "second functional request hits the cache");
        assert!(s.replay_us > 0, "replay time must be accounted");
        // Every compile is attributable: the single worker paid for both.
        assert_eq!(s.compile_by_worker, vec![2], "Σ compile_by_worker == program_misses");
        coord.shutdown();
    }

    /// A 2-layer graph small enough to compile/replay in milliseconds —
    /// cache-boundary tests need dozens of distinct deployments.
    fn tiny_serving_net() -> NetGraph {
        use crate::kernels::Conv2dParams;
        use crate::nn::{ConvLayer, LayerKind, NetLayer};
        NetGraph::new(
            "serving-micro@10",
            10,
            vec![
                NetLayer {
                    kind: LayerKind::Conv(ConvLayer {
                        name: "c1".into(),
                        params: Conv2dParams {
                            h: 4,
                            w: 4,
                            c_in: 16,
                            c_out: 64,
                            kh: 1,
                            kw: 1,
                            stride: 1,
                            pad: 0,
                        },
                        relu: true,
                        residual: false,
                        quantized: true,
                    }),
                    input: 0,
                    residual_from: None,
                },
                NetLayer {
                    kind: LayerKind::Fc { k: 4 * 4 * 64, n: 10, name: "fc".into() },
                    input: 1,
                    residual_from: None,
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn program_cache_evicts_fifo_but_never_the_deployment_default() {
        // Satellite: direct test at the MAX_PROGRAM_ENTRIES boundary. Flood
        // the cache with > MAX_PROGRAM_ENTRIES distinct DeployKeys; the
        // deployment default must survive (pinned), flooded keys must evict
        // FIFO, and evicted keys must recompile (miss counter increments).
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models = vec![Arc::new(tiny_serving_net())];
        let coord = Coordinator::start(cfg);
        let input = vec![9u8; 4 * 4 * 16];
        let get = |id: u64, sched: Option<PrecisionMap>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    input: Some(input.clone()),
                    net: None,
                    schedule: sched,
                    shards: None,
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap()
        };
        // Seed the pinned default entry (functional requests memoize).
        get(0, None);
        // 17 distinct non-default schedules: w2a2 with per-layer overrides.
        let precs = ["w1a1", "w1a2", "w2a1", "int8", "w1a1-novbp", "w2a2-novbp"];
        let mut floods: Vec<PrecisionMap> = Vec::new();
        'outer: for a in precs {
            for b in precs {
                let spec = format!("w2a2;c1={a};fc={b}");
                let m = PrecisionMap::parse(&spec).unwrap();
                if !floods.contains(&m) && !m.is_uniform() {
                    floods.push(m);
                }
                if floods.len() == MAX_PROGRAM_ENTRIES + 1 {
                    break 'outer;
                }
            }
        }
        assert_eq!(floods.len(), MAX_PROGRAM_ENTRIES + 1, "need 17+ distinct keys");
        for (i, m) in floods.iter().enumerate() {
            get(100 + i as u64, Some(m.clone()));
        }
        let s = coord.stats();
        // 1 default + 17 flooded = 18 distinct keys, each compiled once.
        assert_eq!(s.program_misses, 18);
        let bounded = coord.shared.program_cache.lock().unwrap().len();
        assert!(bounded <= MAX_PROGRAM_ENTRIES + 1, "cache unbounded: {bounded} entries");
        // The pinned deployment default must still be resident: a repeat is
        // a pure hit (miss counter unchanged).
        let r = get(500, None);
        assert!(r.timing_cached);
        let s = coord.stats();
        assert_eq!(s.program_misses, 18, "the default entry must never be evicted");
        assert_eq!(s.program_hits, 1);
        // The oldest flooded key was evicted by the later ones: using it
        // again recompiles (miss counter increments).
        get(501, Some(floods[0].clone()));
        let s = coord.stats();
        assert_eq!(s.program_misses, 19, "evicted keys must recompile on reuse");
        // And the whole miss history is attributed to the single worker.
        assert_eq!(s.compile_by_worker, vec![19]);
        coord.shutdown();
    }

    #[test]
    fn cluster_requests_shard_and_match_single_core_logits() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        let coord = Coordinator::start(cfg);
        let n = 32 * 32 * 3;
        let input: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) % 251) as u8).collect();
        let get = |id: u64, shards: Option<usize>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    input: Some(input.clone()),
                    net: None,
                    schedule: None,
                    shards,
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(300)).unwrap()
        };
        let single = get(0, None);
        let sharded = get(1, Some(2));
        assert_eq!(single.shards, 1);
        assert_eq!(single.sync_cycles, 0);
        assert_eq!(sharded.shards, 2);
        assert!(sharded.sync_cycles > 0, "the cluster model must charge the all-gather");
        assert_eq!(
            single.logits, sharded.logits,
            "tensor-parallel logits must be bit-identical to single-core"
        );
        assert_eq!(single.argmax, sharded.argmax);
        assert!(
            sharded.sim_cycles < single.sim_cycles,
            "2 shards must beat 1 core on modeled latency ({} vs {})",
            sharded.sim_cycles,
            single.sim_cycles
        );
        // Cluster metrics: shard utilization for both cores, sync counter.
        let s = coord.stats();
        assert_eq!(s.shard_util.len(), 2, "two shard cores ran: {:?}", s.shard_util);
        assert!(s.shard_util.iter().all(|&u| u > 0.0));
        assert_eq!(s.sync_cycles, sharded.sync_cycles);
        // Warm repeat: per-shard program entries + cluster timing all hit.
        let again = get(2, Some(2));
        assert!(again.timing_cached);
        assert_eq!(again.logits, single.logits);
        coord.shutdown();
    }

    #[test]
    fn invalid_shard_counts_are_rejected_at_submission() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        let coord = Coordinator::start(cfg);
        for bad in [0usize, MAX_SHARDS + 1] {
            let err = coord
                .submit(InferenceRequest {
                    id: 0,
                    input: None,
                    net: None,
                    schedule: None,
                    shards: Some(bad),
                })
                .unwrap_err();
            assert!(matches!(err, SubmitError::Invalid { .. }), "shards={bad}: {err}");
        }
        assert_eq!(coord.rejected(), 0, "Invalid is not backpressure");
        coord.shutdown();
    }

    #[test]
    fn multi_model_deployments_serve_and_count_separately() {
        // Two deployed models: the default `tiny` and the micro test net.
        // Requests route by name, each model owns its own timing-cache
        // entry, and STATS counts per model.
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 1;
        cfg.batch_size = 1;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.models.push(Arc::new(tiny_serving_net()));
        let coord = Coordinator::start(cfg);
        let get = |id: u64, net: Option<&str>| {
            let rx = coord
                .submit(InferenceRequest {
                    id,
                    input: None,
                    net: net.map(|s| s.to_string()),
                    schedule: None,
                    shards: None,
                })
                .unwrap();
            rx.recv_timeout(Duration::from_secs(120)).unwrap()
        };
        let default = get(0, None);
        assert_eq!(default.model, "tiny@100", "no net= selects the first deployment");
        let named = get(1, Some("tiny@100"));
        assert_eq!(named.model, "tiny@100");
        assert!(named.timing_cached, "explicit name shares the default's cache entry");
        assert_eq!(named.sim_cycles, default.sim_cycles);
        let micro = get(2, Some("serving-micro@10"));
        assert_eq!(micro.model, "serving-micro@10");
        assert!(!micro.timing_cached, "each model owns its own timing entry");
        assert!(
            micro.sim_cycles < default.sim_cycles,
            "the micro net must be far cheaper than tiny ({} vs {})",
            micro.sim_cycles,
            default.sim_cycles
        );
        let again = get(3, Some("serving-micro@10"));
        assert!(again.timing_cached);
        // Unknown model: rejected at submission, not backpressure.
        let err = coord
            .submit(InferenceRequest {
                id: 4,
                input: None,
                net: Some("ghost-net".to_string()),
                schedule: None,
                shards: None,
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid { .. }), "{err}");
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert_eq!(coord.rejected(), 0);
        // Per-model serve counts, in deployment order.
        let s = coord.stats();
        assert_eq!(
            s.served_by_model,
            vec![("tiny@100".to_string(), 2), ("serving-micro@10".to_string(), 2)]
        );
        assert_eq!(s.served, 4, "Σ per-model counts == served");
        coord.shutdown();
    }

    #[test]
    fn start_rejects_bad_model_lists() {
        // Duplicate names are a misconfiguration.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.push(cfg.models[0].clone());
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err(),
            "duplicate model names must panic at start"
        );
        // An empty deployment list too.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.clear();
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err()
        );
        // The default schedule must validate against EVERY deployed model:
        // an override naming a layer only `tiny` has is rejected up front.
        let mut cfg = CoordinatorConfig::demo();
        cfg.models.push(Arc::new(zoo::model("mlp").unwrap()));
        cfg.schedule = PrecisionMap::uniform(Precision::Sub {
            abits: 2,
            wbits: 2,
            use_vbitpack: true,
        })
        .with("c3", Precision::Int8);
        assert!(
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| Coordinator::start(cfg)))
                .is_err(),
            "schedule naming a tiny-only layer cannot deploy alongside mlp"
        );
    }

    #[test]
    fn lat_window_percentiles_edge_cases() {
        // Satellite: direct coverage of the p50/p95/p99 feed.
        // Empty window: all zeros.
        let w = LatWindow::new(4);
        assert_eq!(w.percentiles([0.50, 0.95, 0.99]), [0, 0, 0]);
        // Single sample: every percentile is that sample.
        let mut w = LatWindow::new(4);
        w.push(42);
        assert_eq!(w.percentiles([0.0, 0.50, 0.99]), [42, 42, 42]);
        // Cap overflow wraps around: only the most recent `cap` samples
        // survive (the early outlier is forgotten).
        let mut w = LatWindow::new(4);
        w.push(1_000_000);
        for v in [10, 20, 30, 40] {
            w.push(v);
        }
        assert_eq!(w.samples.len(), 4, "window must stay at cap");
        let [p0, p50, p100] = w.percentiles([0.0, 0.50, 1.0]);
        assert_eq!(p0, 10);
        assert_eq!(p100, 40, "the outlier must have been evicted");
        assert_eq!(p50, 30, "median of {{10,20,30,40}} rounds up to index 2");
        // Percentiles are order-insensitive (window sorts internally).
        let mut w = LatWindow::new(8);
        for v in [5, 1, 4, 2, 3] {
            w.push(v);
        }
        assert_eq!(w.percentiles([0.0, 1.0]), [1, 5]);
    }

    #[test]
    fn program_cache_eviction_policy_unit() {
        // Unit-level check of the FIFO + pinning policy, independent of the
        // serving path.
        let net = tiny_serving_net();
        let quark = MachineConfig::quark(4);
        let key = |spec: &str| ProgKey {
            deploy: DeployKey {
                net_fp: 1,
                machine_fp: 2,
                schedule: PrecisionMap::parse(spec).unwrap(),
                shards: 1,
            },
            shard: 0,
        };
        let prog = Arc::new(
            compile(&net, &quark, &PrecisionMap::parse("w2a2").unwrap()).unwrap(),
        );
        let mut cache = ProgramCache::new();
        cache.insert(key("w2a2"), prog.clone(), true, 2); // pinned default
        cache.insert(key("w1a1"), prog.clone(), false, 2);
        assert_eq!(cache.len(), 2);
        // At cap: the non-pinned FIFO head (w1a1) is evicted, not the default.
        cache.insert(key("int8"), prog.clone(), false, 2);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key("w2a2")).is_some(), "pinned entry survives");
        assert!(cache.get(&key("w1a1")).is_none(), "FIFO head evicted");
        assert!(cache.get(&key("int8")).is_some());
        // Re-inserting an existing key is a no-op (no double insert).
        cache.insert(key("int8"), prog, false, 2);
        assert_eq!(cache.len(), 2);
    }
}
