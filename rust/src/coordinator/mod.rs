//! Batching inference coordinator — the L3 serving layer.
//!
//! The paper's contribution is the core itself, so L3 is the "thin driver
//! plus" the workspace mandates: a request router + dynamic batcher in front
//! of a pool of simulated Quark cores (std threads; the environment has no
//! async runtime available — see Cargo.toml), with an optional PJRT
//! golden-model cross-check ([`golden`]) wired into the data path.
//!
//! Flow:
//! ```text
//! clients → submit() → queue → batcher (size/timeout) → worker pool
//!                                                (one simulated core each)
//! ```
//! Each worker owns a [`Sim`] and runs the configured model per request,
//! reporting simulated cycles (device time at `freq_ghz`) plus host-side
//! queueing/service times.

pub mod golden;
pub mod server;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arch::MachineConfig;
use crate::nn::model::{ModelRunner, Precision};
use crate::nn::NetLayer;
use crate::sim::{Sim, SimMode};

/// One inference request (CIFAR-sized input codes).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub input: Vec<u8>,
}

/// Completed inference.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Simulated device cycles for the whole network.
    pub sim_cycles: u64,
    /// Simulated device latency in microseconds (cycles / freq).
    pub device_us: f64,
    /// Wall-clock time spent queued before a worker picked the batch up.
    pub queue_time: Duration,
    /// Wall-clock simulation (service) time.
    pub service_time: Duration,
    /// Which worker/core served it.
    pub worker: usize,
    /// Batch this request was grouped into.
    pub batch_id: u64,
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub machine: MachineConfig,
    pub precision: Precision,
    /// Simulated cores (worker threads).
    pub workers: usize,
    /// Max requests per batch.
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch.
    pub batch_timeout: Duration,
    /// Model graph to serve.
    pub net: Arc<Vec<NetLayer>>,
}

impl CoordinatorConfig {
    /// A small default: Quark-4L, 2-bit, a reduced net for snappy serving.
    pub fn demo() -> Self {
        CoordinatorConfig {
            machine: MachineConfig::quark(4),
            precision: Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true },
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            net: Arc::new(demo_net()),
        }
    }
}

/// A 4-conv CIFAR-scale classifier for serving demos (full ResNet-18 per
/// request is a multi-second simulation; this keeps the serving path
/// interactive while exercising every kernel).
pub fn demo_net() -> Vec<NetLayer> {
    use crate::kernels::Conv2dParams;
    use crate::nn::{ConvLayer, LayerKind};
    let conv = |name: &str, h: usize, cin: usize, cout: usize, stride: usize, q: bool| ConvLayer {
        name: name.into(),
        params: Conv2dParams { h, w: h, c_in: cin, c_out: cout, kh: 3, kw: 3, stride, pad: 1 },
        relu: true,
        residual: false,
        quantized: q,
    };
    vec![
        NetLayer { kind: LayerKind::Conv(conv("stem", 32, 3, 64, 1, false)), input: 0, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c1", 32, 64, 64, 2, true)), input: 1, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c2", 16, 64, 128, 2, true)), input: 2, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c3", 8, 128, 128, 2, true)), input: 3, residual_from: None },
        NetLayer { kind: LayerKind::AvgPool { h: 4, w: 4, c: 128 }, input: 4, residual_from: None },
        NetLayer { kind: LayerKind::Fc { k: 128, n: 100, name: "fc".into() }, input: 5, residual_from: None },
    ]
}

struct Queued {
    req: InferenceRequest,
    enqueued: Instant,
    reply: mpsc::Sender<InferenceResponse>,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    available: Condvar,
    shutdown: AtomicBool,
    batch_counter: AtomicU64,
    served: AtomicU64,
}

/// The coordinator: owns the batcher + worker threads.
pub struct Coordinator {
    shared: Arc<Shared>,
    cfg: CoordinatorConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            batch_counter: AtomicU64::new(0),
            served: AtomicU64::new(0),
        });
        let workers = (0..cfg.workers)
            .map(|wid| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("quark-core-{wid}"))
                    .spawn(move || worker_loop(wid, shared, cfg))
                    .expect("spawn worker")
            })
            .collect();
        Coordinator { shared, cfg, workers }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: InferenceRequest) -> mpsc::Receiver<InferenceResponse> {
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Queued { req, enqueued: Instant::now(), reply: tx });
        drop(q);
        self.shared.available.notify_one();
        rx
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Stop workers and join.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker: claims batches (size- or timeout-bounded) and simulates them on
/// its own core.
fn worker_loop(wid: usize, shared: Arc<Shared>, cfg: CoordinatorConfig) {
    loop {
        // Claim a batch.
        let mut batch = Vec::new();
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                q = shared.available.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
            }
            // First request in hand; wait up to batch_timeout for more.
            batch.push(q.pop_front().unwrap());
            let deadline = Instant::now() + cfg.batch_timeout;
            while batch.len() < cfg.batch_size {
                if let Some(item) = q.pop_front() {
                    batch.push(item);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (nq, timeout) =
                    shared.available.wait_timeout(q, deadline - now).unwrap();
                q = nq;
                if timeout.timed_out() && q.is_empty() {
                    break;
                }
            }
        }
        let batch_id = shared.batch_counter.fetch_add(1, Ordering::Relaxed);

        // Serve the batch on this worker's simulated core.
        for item in batch {
            let queue_time = item.enqueued.elapsed();
            let t0 = Instant::now();
            let mut sim = Sim::new(cfg.machine.clone());
            sim.set_mode(SimMode::TimingOnly);
            let reports = ModelRunner::run(&mut sim, &cfg.net, cfg.precision, false);
            let sim_cycles: u64 = reports.iter().map(|r| r.run.cycles).sum();
            let resp = InferenceResponse {
                id: item.req.id,
                sim_cycles,
                device_us: sim_cycles as f64 / (cfg.machine.freq_ghz * 1e3),
                queue_time,
                service_time: t0.elapsed(),
                worker: wid,
                batch_id,
            };
            shared.served.fetch_add(1, Ordering::Relaxed);
            let _ = item.reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_requests_and_batches() {
        let mut cfg = CoordinatorConfig::demo();
        cfg.workers = 2;
        cfg.batch_size = 4;
        let coord = Coordinator::start(cfg);
        let rxs: Vec<_> = (0..6)
            .map(|i| coord.submit(InferenceRequest { id: i, input: vec![0u8; 32 * 32 * 3] }))
            .collect();
        let mut responses: Vec<_> =
            rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.sim_cycles > 0);
            assert!(r.device_us > 0.0);
        }
        // Batching grouped at least two requests somewhere.
        let max_batch = responses
            .iter()
            .map(|r| responses.iter().filter(|o| o.batch_id == r.batch_id).count())
            .max()
            .unwrap();
        assert!(max_batch >= 2, "expected some batching, got max batch {max_batch}");
        assert_eq!(coord.served(), 6);
        coord.shutdown();
    }
}
