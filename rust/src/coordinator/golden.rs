//! Golden-model cross-check: simulated Quark kernels vs the AOT-compiled JAX
//! computation executed through PJRT.
//!
//! The Python build step (`make artifacts`) lowers the *same* bit-serial
//! quantized matmul (L1 Pallas kernel inside an L2 JAX function) to HLO text;
//! here we execute it on the PJRT CPU client and demand **integer equality**
//! of the accumulators with the simulated `vand`/`vpopcnt`/`vshacc` pipeline.
//! This closes the loop across all three layers of the stack.

use crate::bail;
use crate::error::{Context, Result};

use crate::arch::MachineConfig;
use crate::kernels::bitpack::setup_index_vector;
use crate::kernels::conv2d::conv2d_bitserial_ext;
use crate::kernels::matmul::gemm_codes_golden;
use crate::kernels::requantize::RqBuf;
use crate::quant::pack_weight_planes;
use crate::runtime::Runtime;
use crate::sim::Sim;

/// Shapes must match `python/compile/aot.py` (`qgemm` artifact).
pub const GOLDEN_M: usize = 8;
pub const GOLDEN_K: usize = 128;
pub const GOLDEN_N: usize = 16;
pub const GOLDEN_BITS: u8 = 2;

/// Result of one cross-check.
#[derive(Debug, Clone)]
pub struct CrossCheck {
    pub checked: usize,
    pub mismatches: usize,
    /// Simulated cycles for the kernel under check.
    pub sim_cycles: u64,
}

/// Run the cross-check: random codes → (a) simulated bit-serial GEMM on a
/// Quark core, (b) AOT JAX artifact via PJRT, (c) host oracle. All three
/// must agree exactly on the integer accumulators.
pub fn crosscheck_qgemm(runtime: &Runtime, artifact_path: &str, seed: u64) -> Result<CrossCheck> {
    let (m, k, n, bits) = (GOLDEN_M, GOLDEN_K, GOLDEN_N, GOLDEN_BITS);
    let mut s = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
    let mut lcg = move || {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        s >> 33
    };
    let a_codes: Vec<u8> = (0..m * k).map(|_| (lcg() % (1 << bits)) as u8).collect();
    let w_codes: Vec<u8> = (0..k * n).map(|_| (lcg() % (1 << bits)) as u8).collect();

    // (a) Simulated Quark core.
    let mut sim = Sim::new(MachineConfig::quark(4));
    let idx = setup_index_vector(&mut sim);
    let block = sim.cfg.vlen_bits / 64;
    let wpk = pack_weight_planes(&w_codes, k, n, bits, block);
    let a_addr = sim.alloc((m * k) as u64);
    sim.write_bytes(a_addr, &a_codes);
    let w_addr = sim.alloc(wpk.byte_len() as u64);
    for (i, &w) in wpk.words.iter().enumerate() {
        sim.machine.mem.write_u64_le(w_addr + (i * 8) as u64, w, 8);
    }
    let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((m * n) as u64);
    let n_padded = wpk.blocks() * block;
    let acc_dump = sim.alloc((m * n_padded * 8) as u64);
    let p = crate::kernels::matmul::gemm_params(m, k, n);
    let c0 = sim.cycles();
    conv2d_bitserial_ext(
        &mut sim, &p, bits, a_addr, &wpk, w_addr, &rq, out, None, true, idx,
        Some(acc_dump),
    );
    let sim_cycles = sim.cycles() - c0;
    let sim_acc: Vec<i64> = (0..m)
        .flat_map(|i| {
            let sim = &sim;
            (0..n).map(move |j| {
                sim.machine.mem.read_u64_le(acc_dump + ((i * n_padded + j) * 8) as u64, 8) as i64
            })
        })
        .collect();

    // (b) AOT JAX artifact through PJRT.
    let artifact = runtime
        .load(artifact_path)
        .with_context(|| format!("loading golden artifact {artifact_path} (run `make artifacts`)"))?;
    let a_i32: Vec<i32> = a_codes.iter().map(|&v| v as i32).collect();
    let w_i32: Vec<i32> = w_codes.iter().map(|&v| v as i32).collect();
    let outputs = artifact.run_i32(&[(&a_i32, &[m, k]), (&w_i32, &[k, n])])?;
    let jax_acc = &outputs[0];
    if jax_acc.len() != m * n {
        bail!("artifact output shape mismatch: got {} values, want {}", jax_acc.len(), m * n);
    }

    // (c) Host oracle.
    let (oracle_acc, _) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);

    let mut mismatches = 0;
    for i in 0..m * n {
        let s = sim_acc[i];
        let j = jax_acc[i] as i64;
        let o = oracle_acc[i];
        if s != j || s != o {
            mismatches += 1;
            if mismatches <= 5 {
                eprintln!("mismatch at {i}: sim={s} jax={j} oracle={o}");
            }
        }
    }
    Ok(CrossCheck { checked: m * n, mismatches, sim_cycles })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulator vs host oracle only (the PJRT leg needs `make artifacts`
    /// and is covered by the integration test + `repro crosscheck`).
    #[test]
    fn sim_acc_dump_matches_oracle() {
        let (m, k, n, bits) = (4usize, 64usize, 8usize, 2u8);
        let mut sim = Sim::new(MachineConfig::quark(4));
        let idx = setup_index_vector(&mut sim);
        let a_codes: Vec<u8> = (0..m * k).map(|i| ((i * 13 + 5) % 4) as u8).collect();
        let w_codes: Vec<u8> = (0..k * n).map(|i| ((i * 7 + 1) % 4) as u8).collect();
        let block = sim.cfg.vlen_bits / 64;
        let wpk = pack_weight_planes(&w_codes, k, n, bits, block);
        let a_addr = sim.alloc((m * k) as u64);
        sim.write_bytes(a_addr, &a_codes);
        let w_addr = sim.alloc(wpk.byte_len() as u64);
        for (i, &w) in wpk.words.iter().enumerate() {
            sim.machine.mem.write_u64_le(w_addr + (i * 8) as u64, w, 8);
        }
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        let n_padded = wpk.blocks() * block;
        let acc_dump = sim.alloc((m * n_padded * 8) as u64);
        let p = crate::kernels::matmul::gemm_params(m, k, n);
        conv2d_bitserial_ext(
            &mut sim, &p, bits, a_addr, &wpk, w_addr, &rq, out, None, true, idx,
            Some(acc_dump),
        );
        let (oracle, _) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let got =
                    sim.machine.mem.read_u64_le(acc_dump + ((i * n_padded + j) * 8) as u64, 8) as i64;
                assert_eq!(got, oracle[i * n + j], "({i},{j})");
            }
        }
    }
}
