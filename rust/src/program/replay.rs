//! Replay: [`Sim::execute`] / [`Sim::execute_functional`] over a
//! [`CompiledProgram`].
//!
//! Replay re-applies the program's host-written memory image (weights,
//! requant tables, constants, the default input), optionally overwrites the
//! input segment with per-request bytes, then re-issues the recorded
//! instruction trace. Because the trace is exactly what fresh kernel
//! emission would have produced, a timed replay is cycle- and stat-exact
//! against fresh emission, and a functional replay is bit-exact in memory
//! effects (`rust/tests/program_replay.rs` holds the differentials).
//!
//! Relocation: `base` need not equal the compile-time base. The uniform
//! delta is applied to every [`Sim::li_addr`]-marked immediate, every image
//! chunk, and the input/output segments. All other address arithmetic in
//! the trace is register-relative and needs no rewriting. That every
//! address-bearing immediate is actually *in* the relocation table (so no
//! load or store silently misses the delta at a shifted base) is not an
//! article of faith: the static verifier ([`super::verify`]) proves it per
//! artifact by tracking value provenance through the trace, alongside the
//! segment and def-before-use disciplines this replay relies on.

use crate::isa::instr::{Instr, ScalarOp};
use crate::kernels::KernelRun;
use crate::nn::model::LayerReport;
use crate::sim::mem::Memory;
use crate::sim::Sim;

use super::CompiledProgram;

/// Result of replaying a [`CompiledProgram`].
pub struct ProgramRun {
    /// Per-layer reports, mirror of a fresh-emission run. On
    /// [`Sim::execute_functional`] the cycle/stat fields are zero (no
    /// timing model runs); shapes, addresses, and MACs are always filled.
    pub reports: Vec<LayerReport>,
    /// Replay-space address of the final feature map (the logits).
    pub out_addr: u64,
    pub out_elems: usize,
    /// Total cycles the replay added (0 for functional replays).
    pub cycles: u64,
}

/// The [`ProgramRun`] of any values-only replay at relocation `delta`:
/// per-layer reports carry shapes, addresses, and recorded MACs, but no
/// cycles or stats (those come from the coordinator's timing cache). Shared
/// by [`Sim::execute_functional`] and [`Sim::execute_lowered`].
pub(crate) fn functional_run(prog: &CompiledProgram, delta: u64) -> ProgramRun {
    let reports = prog
        .layers
        .iter()
        .map(|mark| LayerReport {
            name: mark.name.clone(),
            quantized: mark.quantized,
            precision: mark.precision,
            out_addr: mark.out_addr.wrapping_add(delta),
            out_elems: mark.out_elems,
            run: KernelRun { cycles: 0, macs: mark.macs },
            stats: Default::default(),
        })
        .collect();
    ProgramRun {
        reports,
        out_addr: prog.out_addr.wrapping_add(delta),
        out_elems: prog.out_elems,
        cycles: 0,
    }
}

/// Rebase an `li` whose immediate is a simulated-memory address. Shared
/// with the cycle attributor ([`crate::obs::profile`]), which must replay
/// the exact instruction stream [`Sim::execute`] would.
#[inline]
pub(crate) fn relocate(instr: Instr, delta: u64) -> Instr {
    match instr {
        Instr::Scalar(ScalarOp::Li { rd, imm }) => {
            Instr::Scalar(ScalarOp::Li { rd, imm: (imm as u64).wrapping_add(delta) as i64 })
        }
        // Recording only marks `li_addr` sites; anything else is a builder
        // bug best surfaced loudly.
        other => panic!("relocation entry on non-li instruction {other:?}"),
    }
}

impl Sim {
    /// Replay `prog` at `base`, honoring the current [`crate::sim::SimMode`]
    /// (`Full`: values + cycles; `TimingOnly`: cycles only). Equivalent to
    /// re-running the kernel emitters, at none of the emission cost.
    ///
    /// `base` must be 64-byte aligned with `prog.mem_len()` bytes of
    /// simulated memory available (callers normally pass a fresh
    /// `sim.alloc(prog.mem_len())`).
    pub fn execute(&mut self, prog: &CompiledProgram, base: u64) -> ProgramRun {
        self.execute_with_input(prog, base, None)
    }

    /// [`Sim::execute`] with per-request input bytes written over the
    /// program's input segment (shorter inputs zero-padded, longer
    /// truncated, codes clamped onto the input consumer grid — the same
    /// rules as fresh emission).
    pub fn execute_with_input(
        &mut self,
        prog: &CompiledProgram,
        base: u64,
        input: Option<&[u8]>,
    ) -> ProgramRun {
        let delta = self.begin_replay(prog, base, input);
        let mut reports = Vec::with_capacity(prog.layers.len());
        let mut idx = 0usize;
        let mut reloc_i = 0usize;
        for mark in &prog.layers {
            let c0 = self.cycles();
            let before = self.stats().clone();
            while idx < mark.trace_end {
                let instr = prog.trace[idx];
                let instr = if reloc_i < prog.reloc.len() && prog.reloc[reloc_i] as usize == idx {
                    reloc_i += 1;
                    relocate(instr, delta)
                } else {
                    instr
                };
                self.emit(instr);
                idx += 1;
            }
            // Kernels credit effective MACs host-side; replay credits the
            // recorded amount at the same per-layer boundary (pooling
            // reports MACs but credits none — `credited_macs` preserves
            // that distinction bit-for-bit).
            self.stats_mut().effective_macs += mark.credited_macs;
            let stats = self.stats().delta_since(&before);
            reports.push(LayerReport {
                name: mark.name.clone(),
                quantized: mark.quantized,
                precision: mark.precision,
                out_addr: mark.out_addr.wrapping_add(delta),
                out_elems: mark.out_elems,
                run: KernelRun { cycles: self.cycles() - c0, macs: mark.macs },
                stats,
            });
        }
        debug_assert_eq!(idx, prog.trace.len(), "layer marks must tile the trace");
        let cycles = reports.iter().map(|r| r.run.cycles).sum();
        ProgramRun {
            reports,
            out_addr: prog.out_addr.wrapping_add(delta),
            out_elems: prog.out_elems,
            cycles,
        }
    }

    /// Values-only replay: the serving fast path. Executes the trace on the
    /// functional machine with **no timing scoreboard and no stats** —
    /// memory effects (and therefore logits) are bit-identical to
    /// [`Sim::execute`] in `Full` mode, at a fraction of the host cost.
    /// Cycle counts come from the coordinator's timing cache (they are a
    /// pure function of the program, so they never need re-deriving per
    /// request).
    pub fn execute_functional(
        &mut self,
        prog: &CompiledProgram,
        base: u64,
        input: Option<&[u8]>,
    ) -> ProgramRun {
        let delta = self.begin_replay(prog, base, input);
        if delta == 0 {
            for instr in &prog.trace {
                self.machine.execute(instr);
            }
        } else {
            self.execute_functional_range(prog, delta, 0, prog.trace.len());
        }
        functional_run(prog, delta)
    }

    /// Execute the trace range `[lo, hi)` functionally (no timing, no
    /// stats), relocating marked `li`s by `delta`. The cluster runtime
    /// ([`crate::cluster`]) steps shard programs layer by layer with this,
    /// interleaving the host-side activation all-gather at layer bounds.
    pub(crate) fn execute_functional_range(
        &mut self,
        prog: &CompiledProgram,
        delta: u64,
        lo: usize,
        hi: usize,
    ) {
        let mut reloc_i = prog.reloc.partition_point(|&r| (r as usize) < lo);
        for idx in lo..hi {
            let instr = prog.trace[idx];
            if reloc_i < prog.reloc.len() && prog.reloc[reloc_i] as usize == idx {
                reloc_i += 1;
                self.machine.execute(&relocate(instr, delta));
            } else {
                self.machine.execute(&instr);
            }
        }
    }

    /// Shared replay prologue: sanity checks, image application, input
    /// override. Returns the relocation delta.
    pub(crate) fn begin_replay(
        &mut self,
        prog: &CompiledProgram,
        base: u64,
        input: Option<&[u8]>,
    ) -> u64 {
        assert!(!self.is_recording(), "cannot replay into a recording Sim");
        assert_eq!(
            super::machine_fingerprint(&self.cfg),
            prog.machine_fp,
            "program compiled for machine {:?} cannot replay on {:?}",
            prog.machine_name,
            self.cfg.name
        );
        assert_eq!(base % 64, 0, "replay base {base:#x} must be 64-byte aligned");
        assert!(
            base >= Memory::BASE
                && (base - Memory::BASE) + prog.mem_len <= self.machine.mem.size() as u64,
            "program ({} bytes at {base:#x}) does not fit simulated memory",
            prog.mem_len
        );
        let delta = base.wrapping_sub(prog.base);
        for (addr, bytes) in &prog.image {
            self.machine.mem.write(addr.wrapping_add(delta), bytes);
        }
        if let Some(bytes) = input {
            self.write_request_input(prog, delta, bytes);
        }
        delta
    }

    /// Write one request's input bytes over the program's input segment at
    /// relocation `delta` (shorter inputs zero-padded, longer truncated,
    /// codes clamped onto the input consumer grid — the same rules as fresh
    /// emission). The per-element half of a replay, split out of
    /// [`Sim::begin_replay`] so a batched replay
    /// ([`Sim::execute_lowered_batch`]) can rebind the input for each batch
    /// element without re-applying the shared init image.
    pub(crate) fn write_request_input(&mut self, prog: &CompiledProgram, delta: u64, bytes: &[u8]) {
        let spec = &prog.input;
        let addr = spec.addr.wrapping_add(delta);
        if spec.fp32 {
            let vals: Vec<f32> = (0..spec.elems)
                .map(|i| bytes.get(i).copied().unwrap_or(0) as f32 / 255.0)
                .collect();
            self.write_f32s(addr, &vals);
        } else {
            let codes: Vec<u8> = (0..spec.elems)
                .map(|i| bytes.get(i).copied().unwrap_or(0).min(spec.qmax))
                .collect();
            self.write_bytes(addr, &codes);
        }
    }
}
