//! Static program verification: prove replay, relocation, and
//! batch-isolation safety of a [`CompiledProgram`] *before* it is served.
//!
//! The warm serving path rests on legality conditions that were previously
//! argued informally (lowered fusion side conditions, the batched-replay
//! "trace never writes image regions" contract) or checked only by
//! debug-build tripwires. This module machine-checks them once per artifact
//! with an abstract interpretation over the recorded trace plus a structural
//! audit of the decode-once lowering — the same move Quark itself
//! (arXiv 2302.05996) makes by relying on statically-known sub-byte
//! encodings instead of runtime checks.
//!
//! What the pass proves (one [`Finding`] per violation, never a panic):
//!
//! * **[`FindingClass::VState`]** — every vector instruction executes under a
//!   `vsetvli`-established `(vl, vtype)`; `vbitpack` stays inside its
//!   architectural envelope.
//! * **[`FindingClass::Relocation`]** — every scalar value used as a memory
//!   address is rooted in a relocation-marked `li` plus statically foldable
//!   arithmetic, so re-basing the program moves *every* access; the table
//!   itself is sorted, in range, and points at `li`s.
//! * **[`FindingClass::RegUninit`]** — def-before-use for scalar, FP, and
//!   vector registers on every data-bearing operand. (Scalar ALU results on
//!   undefined inputs propagate "undefined" instead of being flagged at the
//!   ALU — the emitters' trace-driven loop counters are decremented without
//!   initialization and never observed.)
//! * **[`FindingClass::UninitRead`]** — byte-granular def-before-use for
//!   memory: the init image, the input segment, host runtime writes (shard
//!   res-slice fills and all-gathers), and prior trace stores are the only
//!   legal read sources.
//! * **[`FindingClass::Segments`]** — segment discipline: input, output,
//!   image, per-layer [`ShardSeg`](super::ShardSeg) regions are in-bounds and the output (and
//!   every layer map) is fully written before harvest; the output segment
//!   never aliases read-only image bytes.
//! * **[`FindingClass::FusedOp`]** — the lowering tiles the trace exactly,
//!   reproduces deterministically from the trace (discharging `Interp`-range
//!   resume-state equivalence), and every fused op's legality side condition
//!   (`PlaneMac` `acc != w`, `RowSum` vacc-span disjointness, `vbitpack`
//!   envelope, no `x0` address registers) holds.
//!
//! The payoff beyond gating: a clean report whose trace (and modeled runtime
//! effects) never touched an image byte outside the input segment is a
//! *batch-safety proof* ([`VerifyReport::batch_safe`]) —
//! [`crate::sim::Sim::execute_lowered_batch`] can then skip its per-element
//! image scan while release builds finally get the isolation guarantee for
//! unproven programs (see `program/lowered.rs`).
//!
//! New emission backends (Sparq sparse kernels, LUT kernels — ROADMAP items
//! 3–4) extend the pass by construction: any instruction they emit is either
//! already in the vocabulary modeled here or a new `Instr` variant that
//! fails to compile until this walker learns its read/write sets.

use std::fmt;

use crate::isa::instr::{AluOp, Instr, ScalarOp, VMemKind, VOp};
use crate::isa::reg::{FReg, Reg, VReg};
use crate::isa::vtype::VType;

use super::lowered::{lower, MicroOp};
use super::CompiledProgram;

/// Cap on recorded findings; the rest are counted in
/// [`VerifyReport::suppressed`] so a pathological artifact cannot balloon
/// the report.
const MAX_FINDINGS: usize = 64;

/// Category of a verification failure — the unit negative tests assert on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FindingClass {
    /// Vector instruction without a live `vsetvli` state (or outside an
    /// architectural envelope the executor asserts).
    VState,
    /// Address not rooted in a relocation-marked `li` (or a malformed
    /// relocation table): the program would break when re-based.
    Relocation,
    /// Register read before any definition reaches it.
    RegUninit,
    /// Memory read outside image ⊎ input ⊎ host-runtime ⊎ prior stores.
    UninitRead,
    /// Segment-discipline violation (bounds, overlap, or output coverage).
    Segments,
    /// Lowered micro-op audit failure (tiling, determinism, or a fused-op
    /// legality side condition).
    FusedOp,
}

impl fmt::Display for FindingClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FindingClass::VState => "vstate",
            FindingClass::Relocation => "relocation",
            FindingClass::RegUninit => "reg-uninit",
            FindingClass::UninitRead => "uninit-read",
            FindingClass::Segments => "segments",
            FindingClass::FusedOp => "fused-op",
        })
    }
}

/// One verification failure: class, optional trace index, human detail.
#[derive(Clone, Debug)]
pub struct Finding {
    pub class: FindingClass,
    /// Trace index (or lowered-op index for [`FindingClass::FusedOp`]) the
    /// finding anchors to; `None` for whole-program findings.
    pub at: Option<usize>,
    pub detail: String,
}

/// The structured result of [`verify`]: per-class findings plus the
/// batch-safety verdict. `Display` is the one report printer shared by
/// `repro program`, `repro verify`, and the gate diagnostics.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    findings: Vec<Finding>,
    /// Findings beyond [`MAX_FINDINGS`] counted but not recorded.
    suppressed: usize,
    batch_safe: bool,
    checked_instrs: usize,
    checked_ops: usize,
}

impl VerifyReport {
    /// True when the artifact passed every check.
    pub fn ok(&self) -> bool {
        self.findings.is_empty() && self.suppressed == 0
    }

    /// Proof that one batch element's pass cannot leak into the next:
    /// no trace instruction (or modeled runtime effect) writes an image byte
    /// outside the input segment, and the program is not a multi-core shard
    /// (whose inter-layer gathers are host effects outside the trace, so the
    /// proof does not extend). Only meaningful when [`VerifyReport::ok`].
    pub fn batch_safe(&self) -> bool {
        self.batch_safe
    }

    pub fn findings(&self) -> &[Finding] {
        &self.findings
    }

    /// Total findings, including suppressed ones.
    pub fn count(&self) -> usize {
        self.findings.len() + self.suppressed
    }

    pub fn suppressed(&self) -> usize {
        self.suppressed
    }

    /// True if any recorded finding has the given class.
    pub fn has(&self, class: FindingClass) -> bool {
        self.findings.iter().any(|f| f.class == class)
    }

    /// Trace instructions walked by the abstract interpretation.
    pub fn checked_instrs(&self) -> usize {
        self.checked_instrs
    }

    /// Lowered micro-ops audited.
    pub fn checked_ops(&self) -> usize {
        self.checked_ops
    }
}

impl fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "verify: {} — {} finding(s){} | batch-safe: {} | {} instrs, {} micro-ops checked",
            if self.ok() { "PASS" } else { "FAIL" },
            self.count(),
            if self.suppressed > 0 {
                format!(" ({} suppressed)", self.suppressed)
            } else {
                String::new()
            },
            if self.batch_safe { "proven" } else { "no" },
            self.checked_instrs,
            self.checked_ops,
        )?;
        for finding in &self.findings {
            match finding.at {
                Some(i) => writeln!(f, "  [{}] @{}: {}", finding.class, i, finding.detail)?,
                None => writeln!(f, "  [{}] {}", finding.class, finding.detail)?,
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Byte-granular shadow memory
// ---------------------------------------------------------------------------

/// Dense bitmap over the program's memory footprint, one bit per byte,
/// operated on word-at-a-time.
struct ByteSet {
    words: Vec<u64>,
    len: usize,
}

impl ByteSet {
    fn new(len: usize) -> ByteSet {
        ByteSet { words: vec![0; len.div_ceil(64)], len }
    }

    /// Bit mask covering bits `[a, b)` of one word, `0 <= a <= b <= 64`.
    fn mask(a: usize, b: usize) -> u64 {
        if b - a == 64 {
            !0
        } else {
            ((1u64 << (b - a)) - 1) << a
        }
    }

    /// Visit each word overlapping `[lo, lo + n)` as `(word index, mask)`.
    fn words_of(lo: usize, n: usize) -> impl Iterator<Item = (usize, u64)> {
        let hi = lo + n;
        (lo / 64..hi.div_ceil(64)).map(move |w| {
            let a = lo.max(w * 64) - w * 64;
            let b = hi.min((w + 1) * 64) - w * 64;
            (w, ByteSet::mask(a, b))
        })
    }

    /// Mark bytes `[lo, lo + n)` (caller guarantees bounds).
    fn set(&mut self, lo: usize, n: usize) {
        debug_assert!(lo + n <= self.len);
        for (w, m) in ByteSet::words_of(lo, n) {
            self.words[w] |= m;
        }
    }

    /// First byte of `[lo, lo + n)` that is *not* marked, if any.
    fn first_missing(&self, lo: usize, n: usize) -> Option<usize> {
        debug_assert!(lo + n <= self.len);
        for (w, m) in ByteSet::words_of(lo, n) {
            let miss = !self.words[w] & m;
            if miss != 0 {
                return Some(w * 64 + miss.trailing_zeros() as usize);
            }
        }
        None
    }

    /// True if any byte of `[lo, lo + n)` is marked.
    fn any_set(&self, lo: usize, n: usize) -> bool {
        debug_assert!(lo + n <= self.len);
        ByteSet::words_of(lo, n).any(|(w, m)| self.words[w] & m != 0)
    }
}

// ---------------------------------------------------------------------------
// Scalar value lattice
// ---------------------------------------------------------------------------

/// Provenance of a scalar value: whether it is rooted in a
/// relocation-marked `li` (and therefore moves with the program when
/// re-based) or is a plain constant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Prov {
    /// Pure constant: identical at every replay base.
    Const,
    /// Relocation-rooted address (one `Addr` term ± constants).
    Addr,
    /// Anything else (e.g. `Addr + Addr`): not provably relocatable.
    Mixed,
}

impl Prov {
    fn combine(a: Prov, b: Prov) -> Prov {
        match (a, b) {
            (Prov::Const, Prov::Const) => Prov::Const,
            (Prov::Addr, Prov::Const) | (Prov::Const, Prov::Addr) => Prov::Addr,
            _ => Prov::Mixed,
        }
    }
}

/// Abstract scalar register value.
#[derive(Clone, Copy, Debug)]
struct SVal {
    /// Some definition reaches this register.
    def: bool,
    /// Statically folded value, when the def chain is foldable.
    val: Option<u64>,
    prov: Prov,
}

impl SVal {
    const UNDEF: SVal = SVal { def: false, val: None, prov: Prov::Const };

    fn known(val: u64, prov: Prov) -> SVal {
        SVal { def: true, val: Some(val), prov }
    }

    /// Defined but with a value the verifier does not track (loads, CSR
    /// reads, vector→scalar moves).
    const OPAQUE: SVal = SVal { def: true, val: None, prov: Prov::Const };
}

// ---------------------------------------------------------------------------
// The abstract interpreter
// ---------------------------------------------------------------------------

struct Walker<'a> {
    prog: &'a CompiledProgram,
    findings: Vec<Finding>,
    suppressed: usize,
    /// Scalar register lattice (`x0` is hardwired known-zero in accessors).
    x: [SVal; 32],
    /// FP register def-before-use bits.
    fdef: [bool; 32],
    /// Vector register def-before-use bits, whole-register granularity.
    vdef: [bool; 32],
    /// Statically tracked `(vl, vtype)`; `None` until the first `vsetvli`.
    vstate: Option<(u64, VType)>,
    /// Bytes a read may legally observe: image ∪ input ∪ runtime ∪ stores.
    defined: ByteSet,
    /// Bytes written by the trace or modeled runtime (output coverage).
    written: ByteSet,
    /// Image bytes outside the input segment — must stay read-only for the
    /// batch-safety proof.
    image_ro: ByteSet,
    /// A trace or runtime write landed on an `image_ro` byte.
    image_written: bool,
    is_reloc: Vec<bool>,
    vreg_bytes: usize,
}

impl<'a> Walker<'a> {
    fn new(prog: &'a CompiledProgram) -> Walker<'a> {
        let mem_len = prog.mem_len as usize;
        let mut is_reloc = vec![false; prog.trace.len()];
        for &r in &prog.reloc {
            if (r as usize) < is_reloc.len() {
                is_reloc[r as usize] = true;
            }
        }
        Walker {
            prog,
            findings: Vec::new(),
            suppressed: 0,
            x: [SVal::UNDEF; 32],
            fdef: [false; 32],
            vdef: [false; 32],
            vstate: None,
            defined: ByteSet::new(mem_len),
            written: ByteSet::new(mem_len),
            image_ro: ByteSet::new(mem_len),
            image_written: false,
            is_reloc,
            vreg_bytes: (prog.vlen_bits / 8).max(1),
        }
    }

    fn find(&mut self, class: FindingClass, at: Option<usize>, detail: String) {
        if self.findings.len() < MAX_FINDINGS {
            self.findings.push(Finding { class, at, detail });
        } else {
            self.suppressed += 1;
        }
    }

    // ---- scalar / FP / vector register lattices ----

    fn sreg(&self, r: Reg) -> SVal {
        if r.0 == 0 {
            SVal::known(0, Prov::Const)
        } else {
            self.x[r.0 as usize & 31]
        }
    }

    fn sset(&mut self, r: Reg, v: SVal) {
        if r.0 != 0 {
            self.x[r.0 as usize & 31] = v;
        }
    }

    fn need_sreg(&mut self, at: usize, r: Reg, what: &str) {
        if !self.sreg(r).def {
            self.find(
                FindingClass::RegUninit,
                Some(at),
                format!("{what} reads x{} before any definition", r.0),
            );
        }
    }

    fn need_freg(&mut self, at: usize, r: FReg, what: &str) {
        if !self.fdef[r.0 as usize & 31] {
            self.find(
                FindingClass::RegUninit,
                Some(at),
                format!("{what} reads f{} before any definition", r.0),
            );
        }
    }

    /// Register-group span of `bytes` bytes starting at `v`, clamped to the
    /// file; a group overrunning v31 is a segment finding.
    fn vspan(&mut self, at: usize, v: VReg, bytes: usize) -> std::ops::Range<usize> {
        let nregs = bytes.div_ceil(self.vreg_bytes).max(1);
        let s = v.0 as usize & 31;
        if s + nregs > 32 {
            self.find(
                FindingClass::Segments,
                Some(at),
                format!("vector group v{}..+{nregs} overruns the register file", v.0),
            );
            return s..32;
        }
        s..s + nregs
    }

    fn vread(&mut self, at: usize, v: VReg, bytes: usize, what: &str) {
        if bytes == 0 {
            return;
        }
        for r in self.vspan(at, v, bytes) {
            if !self.vdef[r] {
                self.find(
                    FindingClass::RegUninit,
                    Some(at),
                    format!("{what} reads v{r} before any definition"),
                );
                self.vdef[r] = true; // report once per register
            }
        }
    }

    fn vwrite(&mut self, at: usize, v: VReg, bytes: usize) {
        if bytes == 0 {
            return;
        }
        for r in self.vspan(at, v, bytes) {
            self.vdef[r] = true;
        }
    }

    // ---- shadow memory ----

    /// Translate a compile-space `[addr, addr + len)` range into footprint
    /// offsets, or record a segment finding.
    fn rel_range(&mut self, at: Option<usize>, addr: u64, len: usize, what: &str) -> Option<usize> {
        let base = self.prog.base;
        let end = base + self.prog.mem_len;
        if addr < base || addr > end || len as u64 > end - addr {
            self.find(
                FindingClass::Segments,
                at,
                format!(
                    "{what} at {addr:#x}+{len} outside the program footprint \
                     [{base:#x}, {end:#x})"
                ),
            );
            return None;
        }
        Some((addr - base) as usize)
    }

    fn mem_read(&mut self, at: usize, addr: u64, len: usize, what: &str) {
        if len == 0 {
            return;
        }
        if let Some(lo) = self.rel_range(Some(at), addr, len, what) {
            if let Some(miss) = self.defined.first_missing(lo, len) {
                self.find(
                    FindingClass::UninitRead,
                    Some(at),
                    format!(
                        "{what} reads uninitialized byte {:#x} (range {addr:#x}+{len})",
                        self.prog.base + miss as u64
                    ),
                );
                self.defined.set(lo, len); // report the range once
            }
        }
    }

    fn mem_write(&mut self, at: Option<usize>, addr: u64, len: usize, what: &str) {
        if len == 0 {
            return;
        }
        if let Some(lo) = self.rel_range(at, addr, len, what) {
            self.defined.set(lo, len);
            self.written.set(lo, len);
            if self.image_ro.any_set(lo, len) {
                self.image_written = true;
            }
        }
    }

    /// Resolve a memory address: base register must be defined,
    /// relocation-rooted, and statically foldable.
    fn addr_of(&mut self, at: usize, base: Reg, offset: i64, what: &str) -> Option<u64> {
        let s = self.sreg(base);
        if !s.def {
            self.find(
                FindingClass::RegUninit,
                Some(at),
                format!("{what} addresses through undefined x{}", base.0),
            );
            return None;
        }
        if s.prov != Prov::Addr {
            self.find(
                FindingClass::Relocation,
                Some(at),
                format!(
                    "{what} addresses through x{} whose value is not rooted in a \
                     relocation-marked li (provenance {:?})",
                    base.0, s.prov
                ),
            );
            return None;
        }
        match s.val {
            Some(v) => Some(v.wrapping_add(offset as u64)),
            None => {
                self.find(
                    FindingClass::Relocation,
                    Some(at),
                    format!("{what} address in x{} is not statically resolvable", base.0),
                );
                None
            }
        }
    }

    // ---- static pre-checks ----

    fn input_bytes(&self) -> usize {
        self.prog.input.elems * if self.prog.input.fp32 { 4 } else { 1 }
    }

    fn check_segments(&mut self) {
        let prog = self.prog;
        let in_len = self.input_bytes();
        let out_len = prog.output_bytes();
        // Input / output bounds.
        if let Some(lo) = self.rel_range(None, prog.input.addr, in_len, "input segment") {
            self.defined.set(lo, in_len);
        }
        self.rel_range(None, prog.out_addr, out_len, "output segment");
        // Image chunks: in-bounds; defined; read-only outside the input.
        let (in_lo, in_hi) = (prog.input.addr, prog.input.addr + in_len as u64);
        for (k, (addr, bytes)) in prog.image.iter().enumerate() {
            let what = format!("image chunk {k}");
            let Some(lo) = self.rel_range(None, *addr, bytes.len(), &what) else { continue };
            self.defined.set(lo, bytes.len());
            let (clo, chi) = (*addr, *addr + bytes.len() as u64);
            // Pieces of the chunk outside [in_lo, in_hi) are read-only.
            let left = (clo, chi.min(in_lo).max(clo));
            let right = (clo.max(in_hi).min(chi), chi);
            for (a, b) in [left, right] {
                if b > a {
                    self.image_ro.set((a - prog.base) as usize, (b - a) as usize);
                }
            }
        }
        // Input and output must be distinct segments on any real net.
        if !prog.layers.is_empty() {
            let out_hi = prog.out_addr + out_len as u64;
            if prog.input.addr < out_hi && prog.out_addr < in_hi {
                self.find(
                    FindingClass::Segments,
                    None,
                    format!(
                        "input segment {:#x}+{in_len} overlaps output segment {:#x}+{out_len}",
                        prog.input.addr, prog.out_addr
                    ),
                );
            }
        }
        // The harvest segment must not alias read-only image bytes (a batch
        // would then return stale weights as logits).
        if let Some(lo) = self.rel_range(None, prog.out_addr, out_len, "output segment") {
            if out_len > 0 && self.image_ro.any_set(lo, out_len) {
                self.find(
                    FindingClass::Segments,
                    None,
                    format!(
                        "output segment {:#x}+{out_len} overlaps read-only image bytes",
                        prog.out_addr
                    ),
                );
            }
        }
        // Layer marks tile the trace.
        let mut prev = 0usize;
        for (li, m) in prog.layers.iter().enumerate() {
            if m.trace_end <= prev || m.trace_end > prog.trace.len() {
                self.find(
                    FindingClass::Segments,
                    None,
                    format!(
                        "layer {li} ({}) trace_end {} does not advance within the \
                         {}-instruction trace",
                        m.name,
                        m.trace_end,
                        prog.trace.len()
                    ),
                );
            }
            prev = m.trace_end;
            let bytes = m.out_elems * if prog.input.fp32 { 4 } else { 1 };
            self.rel_range(None, m.out_addr, bytes, &format!("layer {li} output"));
        }
        if let Some(last) = prog.layers.last() {
            if last.trace_end != prog.trace.len() {
                self.find(
                    FindingClass::Segments,
                    None,
                    format!(
                        "layer marks cover {} of {} trace instructions",
                        last.trace_end,
                        prog.trace.len()
                    ),
                );
            }
        }
        // Relocation table: sorted, in range, pointing at `li`s.
        let mut last = None::<u32>;
        for &r in &prog.reloc {
            if last.is_some_and(|p| r <= p) {
                self.find(
                    FindingClass::Relocation,
                    None,
                    format!("relocation table not strictly sorted at entry {r}"),
                );
            }
            last = Some(r);
            match prog.trace.get(r as usize) {
                Some(Instr::Scalar(ScalarOp::Li { .. })) => {}
                _ => self.find(
                    FindingClass::Relocation,
                    None,
                    format!("relocation entry {r} does not point at an li"),
                ),
            }
        }
        // Shard segments: one per layer, regions in-bounds, scratch/gather
        // regions never alias read-only image bytes.
        if prog.shard.is_some() && prog.shard_segs.len() != prog.layers.len() {
            self.find(
                FindingClass::Segments,
                None,
                format!(
                    "shard program carries {} segments for {} layers",
                    prog.shard_segs.len(),
                    prog.layers.len()
                ),
            );
        }
        for (li, seg) in prog.shard_segs.iter().enumerate() {
            let regions = [
                (seg.part_addr, seg.part_elems(), "partial"),
                (seg.gather_addr, seg.gather_elems(), "gather"),
            ];
            for (addr, elems, kind) in regions {
                let what = format!("layer {li} shard {kind} region");
                if let Some(lo) = self.rel_range(None, addr, elems, &what) {
                    if elems > 0 && self.image_ro.any_set(lo, elems) {
                        self.find(
                            FindingClass::Segments,
                            None,
                            format!("{what} at {addr:#x}+{elems} overlaps read-only image bytes"),
                        );
                    }
                }
            }
            if let Some((_, slice_addr)) = seg.res_slice {
                self.rel_range(
                    None,
                    slice_addr,
                    seg.part_elems(),
                    &format!("layer {li} residual slice buffer"),
                );
            }
        }
    }

    // ---- runtime (cluster host) effects modeled into the walk ----

    /// The cluster runtime fills a sharded residual layer's slice buffer
    /// from the gathered source map *before* the layer's trace range runs.
    fn apply_res_slice(&mut self, li: usize) {
        let prog = self.prog;
        let seg = &prog.shard_segs[li];
        let Some((src_map, slice_addr)) = seg.res_slice else { return };
        let src_addr = if src_map == 0 {
            prog.input.addr
        } else if let Some(s) = prog.shard_segs.get(src_map - 1) {
            s.gather_addr
        } else {
            self.find(
                FindingClass::Segments,
                None,
                format!("layer {li} residual slice sources nonexistent map {src_map}"),
            );
            return;
        };
        let full = seg.positions * seg.c_full;
        if let Some(lo) = self.rel_range(None, src_addr, full, "residual slice source") {
            if full > 0 {
                if let Some(miss) = self.defined.first_missing(lo, full) {
                    self.find(
                        FindingClass::UninitRead,
                        None,
                        format!(
                            "layer {li} residual slice reads uninitialized source byte {:#x}",
                            prog.base + miss as u64
                        ),
                    );
                }
            }
        }
        self.mem_write(None, slice_addr, seg.part_elems(), "residual slice fill");
    }

    /// The cluster runtime all-gathers a partitioned layer *after* its trace
    /// range: this shard's partial slice must be fully written, then the
    /// full map materializes at `gather_addr`.
    fn apply_gather(&mut self, li: usize) {
        let prog = self.prog;
        let seg = &prog.shard_segs[li];
        let part = seg.part_elems();
        if let Some(lo) = self.rel_range(None, seg.part_addr, part, "all-gather partial slice") {
            if part > 0 {
                if let Some(miss) = self.written.first_missing(lo, part) {
                    self.find(
                        FindingClass::Segments,
                        None,
                        format!(
                            "layer {li} partial slice byte {:#x} never written before \
                             the all-gather harvests it",
                            prog.base + miss as u64
                        ),
                    );
                    self.written.set(lo, part);
                }
            }
        }
        self.mem_write(None, seg.gather_addr, seg.gather_elems(), "all-gather");
    }

    // ---- the walk ----

    fn walk_trace(&mut self) {
        let prog = self.prog;
        let gathers = prog.shard.map(|(_, n)| n).unwrap_or(1) > 1;
        let mut cur = 0usize; // layer containing instruction i
        for i in 0..prog.trace.len() {
            if cur < prog.shard_segs.len() {
                let start = if cur == 0 { 0 } else { prog.layers[cur - 1].trace_end };
                if i == start {
                    self.apply_res_slice(cur);
                }
            }
            self.step(i, prog.trace[i]);
            if cur < prog.layers.len() && i + 1 == prog.layers[cur].trace_end {
                if gathers
                    && cur < prog.shard_segs.len()
                    && prog.shard_segs[cur].channels.is_some()
                {
                    self.apply_gather(cur);
                }
                cur += 1;
            }
        }
    }

    fn step(&mut self, i: usize, instr: Instr) {
        match instr {
            Instr::Scalar(op) => self.scalar_op(i, op),
            Instr::VSetVli { rd, avl, vtype } => {
                let vl = avl.min(vtype.vlmax(self.prog.vlen_bits) as u64);
                self.vstate = Some((vl, vtype));
                self.sset(rd, SVal::known(vl, Prov::Const));
            }
            Instr::Vector(v) => self.vector_op(i, v),
        }
    }

    fn scalar_op(&mut self, i: usize, op: ScalarOp) {
        match op {
            ScalarOp::Li { rd, imm } => {
                let prov = if self.is_reloc[i] { Prov::Addr } else { Prov::Const };
                self.sset(rd, SVal::known(imm as u64, prov));
            }
            // ALU results on undefined inputs stay undefined rather than
            // being flagged: the emitters decrement trace-driven loop
            // counters that are never initialized (and never observed).
            ScalarOp::Alu { op, rd, rs1, rs2 } => {
                let (a, b) = (self.sreg(rs1), self.sreg(rs2));
                self.sset(rd, fold_alu(op, a, b));
            }
            ScalarOp::AluImm { op, rd, rs1, imm } => {
                let a = self.sreg(rs1);
                self.sset(rd, fold_alu(op, a, SVal::known(imm as u64, Prov::Const)));
            }
            ScalarOp::Load { width, rd, base, offset, .. } => {
                if let Some(addr) = self.addr_of(i, base, offset, "scalar load") {
                    self.mem_read(i, addr, width.bytes(), "scalar load");
                }
                self.sset(rd, SVal::OPAQUE);
            }
            ScalarOp::Store { width, rs2, base, offset } => {
                self.need_sreg(i, rs2, "scalar store");
                if let Some(addr) = self.addr_of(i, base, offset, "scalar store") {
                    self.mem_write(Some(i), addr, width.bytes(), "scalar store");
                }
            }
            ScalarOp::Branch { .. } | ScalarOp::Nop => {}
            ScalarOp::FLoad { rd, base, offset } => {
                if let Some(addr) = self.addr_of(i, base, offset, "f32 load") {
                    self.mem_read(i, addr, 4, "f32 load");
                }
                self.fdef[rd.0 as usize & 31] = true;
            }
            ScalarOp::FStore { rs2, base, offset } => {
                self.need_freg(i, rs2, "f32 store");
                if let Some(addr) = self.addr_of(i, base, offset, "f32 store") {
                    self.mem_write(Some(i), addr, 4, "f32 store");
                }
            }
            ScalarOp::FAlu { rd, rs1, rs2, .. } => {
                self.need_freg(i, rs1, "f32 alu");
                self.need_freg(i, rs2, "f32 alu");
                self.fdef[rd.0 as usize & 31] = true;
            }
            ScalarOp::FMadd { rd, rs1, rs2, rs3 } => {
                for r in [rs1, rs2, rs3] {
                    self.need_freg(i, r, "fmadd");
                }
                self.fdef[rd.0 as usize & 31] = true;
            }
            ScalarOp::FCvtWS { rd, rs1 } => {
                self.need_freg(i, rs1, "fcvt.w.s");
                self.sset(rd, SVal::OPAQUE);
            }
            ScalarOp::FCvtSW { rd, rs1 } => {
                self.need_sreg(i, rs1, "fcvt.s.w");
                self.fdef[rd.0 as usize & 31] = true;
            }
            ScalarOp::FMvXW { rd, rs1 } => {
                self.need_freg(i, rs1, "fmv.x.w");
                self.sset(rd, SVal::OPAQUE);
            }
            ScalarOp::FMvWX { rd, rs1 } => {
                self.need_sreg(i, rs1, "fmv.w.x");
                self.fdef[rd.0 as usize & 31] = true;
            }
            ScalarOp::CsrReadCycle { rd } => self.sset(rd, SVal::OPAQUE),
        }
    }

    fn vector_op(&mut self, i: usize, v: VOp) {
        let Some((vl64, vt)) = self.vstate else {
            self.find(
                FindingClass::VState,
                Some(i),
                "vector instruction with no vsetvli in effect".to_string(),
            );
            // Mark the destination defined to limit cascading reg findings.
            if let Some(vd) = v.vreg_write() {
                self.vdef[vd.0 as usize & 31] = true;
            }
            return;
        };
        let vl = vl64 as usize;
        let eb = vt.sew.bytes();
        let body = vl * eb; // byte span of a vl-element operand
        match v {
            VOp::Load { kind, eew, vd, base } => {
                let len = vl * eew.bytes();
                match kind {
                    VMemKind::UnitStride => {
                        if let Some(addr) = self.addr_of(i, base, 0, "vector load") {
                            self.mem_read(i, addr, len, "vector load");
                        }
                    }
                    VMemKind::Strided { stride } => {
                        self.strided(i, base, stride, eew.bytes(), vl, false);
                    }
                }
                self.vwrite(i, vd, len);
            }
            VOp::Store { kind, eew, vs3, base } => {
                let len = vl * eew.bytes();
                self.vread(i, vs3, len, "vector store");
                match kind {
                    VMemKind::UnitStride => {
                        if let Some(addr) = self.addr_of(i, base, 0, "vector store") {
                            self.mem_write(Some(i), addr, len, "vector store");
                        }
                    }
                    VMemKind::Strided { stride } => {
                        self.strided(i, base, stride, eew.bytes(), vl, true);
                    }
                }
            }
            VOp::IVV { vd, vs2, vs1, .. } => {
                self.vread(i, vs2, body, "vector op");
                self.vread(i, vs1, body, "vector op");
                self.vwrite(i, vd, body);
            }
            VOp::IVX { vd, vs2, rs1, .. } => {
                self.need_sreg(i, rs1, "vector vx op");
                self.vread(i, vs2, body, "vector op");
                self.vwrite(i, vd, body);
            }
            VOp::IVI { vd, vs2, .. } => {
                self.vread(i, vs2, body, "vector op");
                self.vwrite(i, vd, body);
            }
            VOp::MaccVX { vd, rs1, vs2 } => {
                self.need_sreg(i, rs1, "vmacc.vx");
                self.vread(i, vs2, body, "vmacc.vx");
                self.vread(i, vd, body, "vmacc.vx accumulator");
                self.vwrite(i, vd, body);
            }
            VOp::MaccVV { vd, vs1, vs2 } => {
                self.vread(i, vs2, body, "vmacc.vv");
                self.vread(i, vs1, body, "vmacc.vv");
                self.vread(i, vd, body, "vmacc.vv accumulator");
                self.vwrite(i, vd, body);
            }
            VOp::RedSum { vd, vs2, vs1 } | VOp::FRedSum { vd, vs2, vs1 } => {
                self.vread(i, vs2, body, "reduction");
                self.vread(i, vs1, eb, "reduction seed");
                self.vwrite(i, vd, eb);
            }
            VOp::MvXS { rd, vs2 } => {
                self.vread(i, vs2, eb, "vmv.x.s");
                self.sset(rd, SVal::OPAQUE);
            }
            VOp::MvSX { vd, rs1 } => {
                self.need_sreg(i, rs1, "vmv.s.x");
                self.vwrite(i, vd, eb);
            }
            VOp::MvVX { vd, rs1 } => {
                self.need_sreg(i, rs1, "vmv.v.x");
                self.vwrite(i, vd, body);
            }
            VOp::MvVI { vd, .. } => self.vwrite(i, vd, body),
            VOp::Sext { vd, vs2, frac } | VOp::Zext { vd, vs2, frac } => {
                let src = vl * (eb / (frac as usize).max(1)).max(1);
                self.vread(i, vs2, src, "vector widen");
                self.vwrite(i, vd, body);
            }
            // Mask-producing compares write the full mask register.
            VOp::MseqVI { vd, vs2, .. } | VOp::MsneVI { vd, vs2, .. } => {
                self.vread(i, vs2, body, "mask compare");
                self.vwrite(i, vd, self.vreg_bytes);
            }
            VOp::FMaccVF { vd, rs1, vs2 } => {
                self.need_freg(i, rs1, "vfmacc.vf");
                self.vread(i, vs2, body, "vfmacc.vf");
                self.vread(i, vd, body, "vfmacc.vf accumulator");
                self.vwrite(i, vd, body);
            }
            VOp::FAddVV { vd, vs2, vs1 } => {
                self.vread(i, vs2, body, "vfadd.vv");
                self.vread(i, vs1, body, "vfadd.vv");
                self.vwrite(i, vd, body);
            }
            VOp::FMulVF { vd, vs2, rs1 } | VOp::FMaxVF { vd, vs2, rs1 } => {
                self.need_freg(i, rs1, "vector vf op");
                self.vread(i, vs2, body, "vector vf op");
                self.vwrite(i, vd, body);
            }
            VOp::FMvVF { vd, rs1 } => {
                self.need_freg(i, rs1, "vfmv.v.f");
                self.vwrite(i, vd, body);
            }
            VOp::Popcnt { vd, vs2 } => {
                self.vread(i, vs2, body, "vpopcnt.v");
                self.vwrite(i, vd, body);
            }
            VOp::Shacc { vd, vs2, .. } => {
                self.vread(i, vs2, body, "vshacc.vi");
                self.vread(i, vd, body, "vshacc.vi accumulator");
                self.vwrite(i, vd, body);
            }
            VOp::Bitpack { vd, vs2, bit } => {
                // Envelope the executor asserts: the plane must fit one
                // VLEN-bit register and the sliced bit must exist at SEW.
                if vl > self.prog.vlen_bits || bit as usize >= vt.sew.bits() {
                    self.find(
                        FindingClass::VState,
                        Some(i),
                        format!(
                            "vbitpack outside its envelope (vl {vl} vs VLEN {}, bit {bit} \
                             at sew {} bits)",
                            self.prog.vlen_bits,
                            vt.sew.bits()
                        ),
                    );
                }
                self.vread(i, vs2, body, "vbitpack");
                // `vd` is deliberately *not* required to be defined: the
                // packer shifts garbage out after 64/vl calls, so the
                // emitters legally start from an uninitialized register.
                self.vwrite(i, vd, self.vreg_bytes);
            }
        }
    }

    /// Conservative per-element model of strided accesses (no current
    /// emitter uses them; kept total for future backends).
    fn strided(&mut self, i: usize, base: Reg, stride: Reg, eew: usize, vl: usize, write: bool) {
        let Some(addr) = self.addr_of(i, base, 0, "strided access") else { return };
        self.need_sreg(i, stride, "strided access");
        let Some(step) = self.sreg(stride).val else {
            self.find(
                FindingClass::Relocation,
                Some(i),
                "strided access with a statically unresolvable stride".to_string(),
            );
            return;
        };
        for k in 0..vl {
            let a = addr.wrapping_add((k as u64).wrapping_mul(step));
            if write {
                self.mem_write(Some(i), a, eew, "strided store");
            } else {
                self.mem_read(i, a, eew, "strided load");
            }
        }
    }

    // ---- post-walk checks ----

    fn check_output_coverage(&mut self) {
        let prog = self.prog;
        let out_len = prog.output_bytes();
        if let Some(lo) = self.rel_range(None, prog.out_addr, out_len, "output segment") {
            if out_len > 0 {
                if let Some(miss) = self.written.first_missing(lo, out_len) {
                    self.find(
                        FindingClass::Segments,
                        None,
                        format!(
                            "output byte {:#x} never written before harvest",
                            prog.base + miss as u64
                        ),
                    );
                }
            }
        }
        // Every layer map a replay report exposes must be fully written too.
        let esz = if prog.input.fp32 { 4 } else { 1 };
        for (li, m) in prog.layers.iter().enumerate() {
            let bytes = m.out_elems * esz;
            if let Some(lo) = self.rel_range(None, m.out_addr, bytes, "layer output") {
                if bytes > 0 && self.written.first_missing(lo, bytes).is_some() {
                    self.find(
                        FindingClass::Segments,
                        None,
                        format!("layer {li} ({}) output map is not fully written", m.name),
                    );
                }
            }
        }
    }

    /// Audit the decode-once lowering: exact trace tiling, reproducibility
    /// (which discharges `Interp`-range resume-state equivalence — `lower`
    /// is a pure function of the trace), and per-op legality conditions.
    fn check_lowered(&mut self) -> usize {
        let prog = self.prog;
        let low = prog.lowered();
        if lower(prog, prog.vlen_bits).ops != low.ops {
            self.find(
                FindingClass::FusedOp,
                None,
                "cached lowering does not reproduce from the trace".to_string(),
            );
        }
        let mut cursor = 0usize;
        for (oi, op) in low.ops.iter().enumerate() {
            let took = match op {
                MicroOp::Interp { lo, hi } => {
                    if *lo as usize != cursor || hi < lo || *hi as usize > prog.trace.len() {
                        self.find(
                            FindingClass::FusedOp,
                            Some(oi),
                            format!(
                                "interp range [{lo}, {hi}) does not continue the tiling at \
                                 {cursor}"
                            ),
                        );
                    }
                    cursor = (*hi as usize).max(cursor);
                    continue;
                }
                MicroOp::Fill { rd, addr, len, .. } => {
                    self.fused_reg(oi, *rd, "fill");
                    self.fused_bounds(oi, *addr, *len, "fill");
                    3
                }
                MicroOp::Copy { rs, src, rd, dst, len, .. } => {
                    self.fused_reg(oi, *rs, "copy");
                    self.fused_reg(oi, *rd, "copy");
                    self.fused_bounds(oi, *src, *len, "copy source");
                    self.fused_bounds(oi, *dst, *len, "copy destination");
                    4
                }
                MicroOp::LoadUnit { rd, addr, len, .. } => {
                    self.fused_reg(oi, *rd, "unit load");
                    self.fused_bounds(oi, *addr, *len, "unit load");
                    2
                }
                MicroOp::StoreUnit { rd, addr, len, .. } => {
                    self.fused_reg(oi, *rd, "unit store");
                    self.fused_bounds(oi, *addr, *len, "unit store");
                    2
                }
                MicroOp::PlaneMac { t1, tmp, taps, .. } => {
                    self.fused_reg(oi, *t1, "plane-mac");
                    for tap in taps.iter() {
                        if tap.base == *t1 {
                            self.find(
                                FindingClass::FusedOp,
                                Some(oi),
                                format!(
                                    "plane-mac tap base x{} aliases the scratch load \
                                     register",
                                    tap.base.0
                                ),
                            );
                        }
                        if tap.w == *tmp || tap.acc == *tmp {
                            self.find(
                                FindingClass::FusedOp,
                                Some(oi),
                                format!("plane-mac tap aliases scratch v{}", tmp.0),
                            );
                        }
                        if tap.acc == tap.w {
                            self.find(
                                FindingClass::FusedOp,
                                Some(oi),
                                format!(
                                    "plane-mac accumulator v{} aliases its weight plane — \
                                     the elided scratch write would be observable",
                                    tap.acc.0
                                ),
                            );
                        }
                    }
                    4 * taps.len()
                }
                MicroOp::BitpackFast { bit, vl, eb, .. } => {
                    if *vl > prog.vlen_bits
                        || (*bit as usize) >= eb * 8
                        || prog.vlen_bits / 8 > 512
                    {
                        self.find(
                            FindingClass::FusedOp,
                            Some(oi),
                            format!("bitpack-fast outside its envelope (vl {vl}, bit {bit})"),
                        );
                    }
                    1
                }
                MicroOp::MaccByte { a0, addr, .. } => {
                    self.fused_reg(oi, *a0, "macc-byte");
                    self.fused_bounds(oi, *addr, 1, "macc-byte operand");
                    3
                }
                MicroOp::RowSum(rs) => {
                    if rs.n > 1024 {
                        self.find(
                            FindingClass::FusedOp,
                            Some(oi),
                            format!("row-sum n {} exceeds the 1024-byte kernel buffer", rs.n),
                        );
                    }
                    self.fused_reg(oi, rs.a0, "row-sum");
                    self.fused_reg(oi, rs.t1, "row-sum");
                    self.fused_bounds(oi, rs.src, rs.n, "row-sum source");
                    self.fused_bounds(oi, rs.dst, 4, "row-sum destination");
                    // The fused kernel elides vacc's zero-write: element 0 of
                    // vacc must overlap neither the loaded bytes nor the
                    // widened u32 span.
                    let vb = self.vreg_bytes;
                    let (l0, z0, av) =
                        (rs.vload.0 as usize * vb, rs.vz.0 as usize * vb, rs.vacc.0 as usize * vb);
                    let disjoint = |lo: usize, len: usize| av + 4 <= lo || lo + len <= av;
                    if !(disjoint(l0, rs.n) && disjoint(z0, 4 * rs.n)) {
                        self.find(
                            FindingClass::FusedOp,
                            Some(oi),
                            format!(
                                "row-sum accumulator v{} span overlaps its operand spans",
                                rs.vacc.0
                            ),
                        );
                    }
                    10
                }
            };
            cursor += took;
        }
        if cursor != prog.trace.len() {
            self.find(
                FindingClass::FusedOp,
                None,
                format!(
                    "lowering tiles {cursor} of {} trace instructions",
                    prog.trace.len()
                ),
            );
        }
        low.ops.len()
    }

    fn fused_reg(&mut self, oi: usize, r: Reg, what: &str) {
        if r.0 == 0 {
            self.find(
                FindingClass::FusedOp,
                Some(oi),
                format!("{what} micro-op addresses through x0"),
            );
        }
    }

    fn fused_bounds(&mut self, oi: usize, addr: u64, len: usize, what: &str) {
        let base = self.prog.base;
        let end = base + self.prog.mem_len;
        if addr < base || addr > end || len as u64 > end - addr {
            self.find(
                FindingClass::FusedOp,
                Some(oi),
                format!("{what} at {addr:#x}+{len} outside the program footprint"),
            );
        }
    }
}

/// Statically fold a scalar ALU op over abstract values. Only the ops the
/// emitters use for address-free arithmetic fold; everything else yields an
/// opaque (but defined, when both inputs are) result.
fn fold_alu(op: AluOp, a: SVal, b: SVal) -> SVal {
    let def = a.def && b.def;
    let prov = Prov::combine(a.prov, b.prov);
    let val = match (a.val, b.val) {
        (Some(x), Some(y)) => match op {
            AluOp::Add => Some(x.wrapping_add(y)),
            AluOp::Sub => Some(x.wrapping_sub(y)),
            AluOp::And => Some(x & y),
            AluOp::Or => Some(x | y),
            AluOp::Xor => Some(x ^ y),
            AluOp::Mul => Some(x.wrapping_mul(y)),
            _ => None,
        },
        _ => None,
    };
    SVal { def, val, prov }
}

/// Run the full verification pass over `prog`. Never panics: every check
/// lands in the report as a [`Finding`]. Deterministic — a pure function of
/// the artifact.
pub fn verify(prog: &CompiledProgram) -> VerifyReport {
    let mut w = Walker::new(prog);
    w.check_segments();
    w.walk_trace();
    w.check_output_coverage();
    let checked_ops = w.check_lowered();
    let single_core = match prog.shard {
        Some((_, n)) => n == 1,
        None => true,
    };
    let batch_safe = w.findings.is_empty() && w.suppressed == 0 && !w.image_written && single_core;
    VerifyReport {
        findings: w.findings,
        suppressed: w.suppressed,
        batch_safe,
        checked_instrs: prog.trace.len(),
        checked_ops,
    }
}

/// Hand-corruption helpers for the negative-case test corpus
/// (`rust/tests/verify_negative.rs`). Hidden from docs: these construct
/// deliberately broken artifacts and exist only so tests outside the crate
/// can build them without exposing `CompiledProgram`'s internals.
#[doc(hidden)]
pub mod corrupt {
    use super::super::lowered::{lower, MicroOp};
    use super::super::CompiledProgram;
    use crate::isa::instr::Instr;

    /// Field-by-field duplicate with fresh lazy caches (`CompiledProgram`
    /// deliberately does not implement `Clone`; corruption needs a scratch
    /// copy the pristine artifact never sees).
    pub fn dup(p: &CompiledProgram) -> CompiledProgram {
        CompiledProgram {
            net_fp: p.net_fp,
            machine_fp: p.machine_fp,
            model_name: p.model_name.clone(),
            machine_name: p.machine_name.clone(),
            schedule: p.schedule.clone(),
            base: p.base,
            mem_len: p.mem_len,
            trace: p.trace.clone(),
            reloc: p.reloc.clone(),
            image: p.image.clone(),
            input: p.input.clone(),
            out_addr: p.out_addr,
            out_elems: p.out_elems,
            layers: p.layers.clone(),
            shard: p.shard,
            stage: p.stage,
            shard_segs: p.shard_segs.clone(),
            vlen_bits: p.vlen_bits,
            lowered: std::sync::OnceLock::new(),
            verify: std::sync::OnceLock::new(),
        }
    }

    /// Drop a middle relocation-table entry: the `li` it covered still holds
    /// an address, but the verifier can no longer prove it re-bases →
    /// `Relocation`.
    pub fn drop_reloc_entry(p: &CompiledProgram) -> Option<CompiledProgram> {
        if p.reloc.len() < 3 {
            return None;
        }
        let mut c = dup(p);
        c.reloc.remove(c.reloc.len() / 2);
        Some(c)
    }

    /// Point the output segment into the largest read-only image chunk
    /// (weights): harvest would return image bytes → `Segments`.
    pub fn overlap_output_into_image(p: &CompiledProgram) -> Option<CompiledProgram> {
        let (addr, _) = ro_image_chunk(p)?;
        let mut c = dup(p);
        c.out_addr = addr;
        Some(c)
    }

    /// Truncate the largest read-only image chunk to half: the trace now
    /// reads weight bytes the image never defined → `UninitRead`.
    pub fn truncate_image(p: &CompiledProgram) -> Option<CompiledProgram> {
        let (addr, len) = ro_image_chunk(p)?;
        if len < 2 {
            return None;
        }
        let mut c = dup(p);
        for (a, bytes) in &mut c.image {
            if *a == addr && bytes.len() == len {
                bytes.truncate(len / 2);
                break;
            }
        }
        Some(c)
    }

    /// Alias a lowered `PlaneMac` tap's accumulator onto its weight plane —
    /// the fusion side condition the lowering matcher enforces → `FusedOp`.
    /// `None` when the schedule emits no bit-serial MAC (int8, fp32).
    pub fn alias_plane_mac_acc(p: &CompiledProgram) -> Option<CompiledProgram> {
        let c = dup(p);
        let mut fresh = lower(&c, c.vlen_bits);
        let mac = fresh.ops.iter_mut().find_map(|op| match op {
            MicroOp::PlaneMac { taps, .. } => Some(taps),
            _ => None,
        })?;
        mac[0].w = mac[0].acc;
        let _ = c.lowered.set(fresh);
        Some(c)
    }

    /// Remove the first `vsetvli` that governs at least one vector
    /// instruction: that instruction now executes with no vector state →
    /// `VState`. Relocation indices and layer trace ends shift down with the
    /// removed instruction so the rest of the artifact stays consistent.
    pub fn skip_vsetvli(p: &CompiledProgram) -> Option<CompiledProgram> {
        let mut idx = None;
        'outer: for (i, instr) in p.trace.iter().enumerate() {
            if !matches!(instr, Instr::VSetVli { .. }) {
                continue;
            }
            for later in &p.trace[i + 1..] {
                match later {
                    Instr::VSetVli { .. } => continue 'outer,
                    Instr::Vector(_) => {
                        idx = Some(i);
                        break 'outer;
                    }
                    _ => {}
                }
            }
        }
        let idx = idx?;
        let mut c = dup(p);
        c.trace.remove(idx);
        for r in &mut c.reloc {
            if *r as usize > idx {
                *r -= 1;
            }
        }
        for m in &mut c.layers {
            if m.trace_end > idx {
                m.trace_end -= 1;
            }
        }
        Some(c)
    }

    /// Largest image chunk fully outside the input segment (weights or
    /// requant tables — bytes the trace reads but never writes).
    fn ro_image_chunk(p: &CompiledProgram) -> Option<(u64, usize)> {
        let in_lo = p.input.addr;
        let in_hi = in_lo + p.input.elems as u64 * if p.input.fp32 { 4 } else { 1 };
        p.image
            .iter()
            .filter(|(a, b)| *a + b.len() as u64 <= in_lo || *a >= in_hi)
            .max_by_key(|(_, b)| b.len())
            .map(|(a, b)| (*a, b.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::coordinator::demo_net;
    use crate::nn::model::{Precision, PrecisionMap, ShardPlan};
    use crate::program::{compile, compile_shard};

    fn w2a2() -> PrecisionMap {
        PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true })
    }

    #[test]
    fn pristine_program_verifies_clean_and_batch_safe() {
        let prog = compile(&demo_net(), &MachineConfig::quark(4), &w2a2()).unwrap();
        let rep = verify(&prog);
        assert!(rep.ok(), "pristine demo-net program must verify:\n{rep}");
        assert!(rep.batch_safe(), "single-core program must prove batch safety");
        assert_eq!(rep.checked_instrs(), prog.trace_len());
        assert!(rep.checked_ops() > 0);
        assert!(format!("{rep}").contains("PASS"));
    }

    #[test]
    fn verify_report_is_cached_on_the_program() {
        let prog = compile(&demo_net(), &MachineConfig::quark(4), &w2a2()).unwrap();
        let a: *const VerifyReport = prog.verify_report();
        let b: *const VerifyReport = prog.verify_report();
        assert_eq!(a, b, "OnceLock must cache the report");
        assert!(prog.verify_report().ok());
    }

    #[test]
    fn shard_programs_verify_but_do_not_claim_batch_safety() {
        let net = demo_net();
        let machine = MachineConfig::quark(4);
        let sched = w2a2();
        let plan = ShardPlan::derive(&net, 2).unwrap();
        for shard in 0..2 {
            let prog = compile_shard(&net, &machine, &sched, &plan, shard).unwrap();
            let rep = verify(&prog);
            assert!(rep.ok(), "shard {shard} must verify:\n{rep}");
            assert!(
                !rep.batch_safe(),
                "inter-layer gathers are host effects; the batch proof must not extend"
            );
        }
    }

    #[test]
    fn corruptions_are_rejected_with_the_right_class() {
        let prog = compile(&demo_net(), &MachineConfig::quark(4), &w2a2()).unwrap();
        let cases: [(&str, Option<CompiledProgram>, FindingClass); 5] = [
            ("drop-reloc", corrupt::drop_reloc_entry(&prog), FindingClass::Relocation),
            (
                "overlap-output",
                corrupt::overlap_output_into_image(&prog),
                FindingClass::Segments,
            ),
            ("alias-plane-mac", corrupt::alias_plane_mac_acc(&prog), FindingClass::FusedOp),
            ("truncate-image", corrupt::truncate_image(&prog), FindingClass::UninitRead),
            ("skip-vsetvli", corrupt::skip_vsetvli(&prog), FindingClass::VState),
        ];
        for (name, corrupted, class) in cases {
            let c = corrupted.unwrap_or_else(|| panic!("{name}: corruption not applicable"));
            let rep = verify(&c);
            assert!(!rep.ok(), "{name}: corruption must be rejected");
            assert!(rep.has(class), "{name}: expected a {class} finding, got:\n{rep}");
            assert!(!rep.batch_safe(), "{name}: a failing artifact is never batch-safe");
        }
    }

    #[test]
    fn byte_set_word_operations() {
        let mut s = ByteSet::new(200);
        assert_eq!(s.first_missing(0, 200), Some(0));
        s.set(3, 70); // crosses a word boundary
        assert!(s.any_set(0, 10));
        assert!(!s.any_set(0, 3));
        assert_eq!(s.first_missing(3, 70), None);
        assert_eq!(s.first_missing(0, 200), Some(0));
        assert_eq!(s.first_missing(3, 100), Some(73));
        s.set(0, 200); // full-range, exercises the 64-bit mask path
        assert_eq!(s.first_missing(0, 200), None);
        assert!(s.any_set(199, 1));
    }
}
