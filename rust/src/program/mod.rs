//! Compile-once / run-many: the [`CompiledProgram`] artifact.
//!
//! Quark's serving workloads are static DNN graphs: for a fixed
//! (network, machine, precision schedule) the emitted vector instruction
//! stream is *identical on every inference* — the kernels are shape-driven
//! and data-independent. SPEED (arXiv 2409.14017) and the mixed-precision
//! RISC-V work of Ottavi et al. (arXiv 2010.04073) both treat the per-layer
//! instruction schedule as a compiled artifact reused across inferences;
//! this module adopts that split.
//!
//! ```text
//!            compile (once)                      execute (per request)
//! net ──┐                                  ┌── apply image (weights, rq, …)
//! machine ─► ProgramBuilder ─► CompiledProgram ─► write input bytes
//! schedule ─┘   (recording Sim:      │          ├── replay trace (± reloc)
//!                kernels emit,       │          └── read logits at out_addr
//!                nothing simulates)  │
//!                                    ├ trace   — dynamic instruction stream
//!                                    ├ reloc   — indices of address `li`s
//!                                    ├ image   — host-written init bytes
//!                                    ├ input   — segment + clamp grid
//!                                    ├ layers  — per-layer marks (ranges,
//!                                    │           shapes, MACs)
//!                                    └ out     — logits segment
//! ```
//!
//! [`compile`] drives the single model-emission routine (`emit_model` in
//! [`builder`] — also the live path behind
//! [`crate::nn::model::ModelRunner`]) into a recording
//! [`Sim`](crate::sim::Sim), capturing the trace, the relocation table
//! ([`crate::sim::Sim::li_addr`] call sites), and the host-written memory
//! image. [`crate::sim::Sim::execute`] replays the artifact with full
//! timing + functional fidelity (bit-exact logits, cycle-exact timing —
//! `rust/tests/program_replay.rs` is the differential proof);
//! [`crate::sim::Sim::execute_functional`] is the serving fast path: values
//! only, no timing scoreboard, for requests whose cycle counts come from
//! the coordinator's timing cache.
//!
//! Programs are *relocatable*: every buffer address materialized by a
//! kernel goes through `li_addr`, so replaying at `base ≠ compile base`
//! just re-bases those immediates (and the image/input/output segments) by
//! the same delta.
//!
//! Programs also carry a *batch axis*: because the input and output
//! segments are isolated from the (read-only) image regions,
//! [`crate::sim::Sim::execute_lowered_batch`] binds B request inputs in
//! turn against one arena — image applied once — and one pass of the fused
//! micro-ops per element yields B logit vectors, each bit-identical to an
//! independent single-request replay (`rust/tests/batching.rs`).

pub mod builder;
pub mod lowered;
mod replay;
pub mod verify;

pub use builder::ProgramBuilder;
pub use lowered::{BatchRun, LoweredProgram};
pub(crate) use replay::relocate;
pub use replay::ProgramRun;
pub use verify::{Finding, FindingClass, VerifyReport};

use crate::arch::MachineConfig;
use crate::isa::instr::Instr;
use crate::nn::graph::{fnv, fnv_str};
use crate::nn::model::{Precision, PrecisionMap};
use crate::nn::NetGraph;

// ---- machine fingerprint (cache-key partner of NetGraph::fingerprint) ----
//
// The network-side identity moved into [`NetGraph::fingerprint`]
// (`crate::nn::graph`), which subsumes the structural `net_fingerprint`
// hash this module used to own.

/// Structural identity of a machine configuration: every timing-model knob.
pub fn machine_fingerprint(cfg: &MachineConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv_str(&mut h, &cfg.name);
    for v in [
        cfg.lanes as u64,
        cfg.vlen_bits as u64,
        cfg.has_vfpu as u64,
        cfg.has_quark_isa as u64,
        cfg.freq_ghz.to_bits(),
        cfg.axi_bytes_per_cycle as u64,
        cfg.mem_latency,
        cfg.dispatch_latency,
        cfg.vstartup_latency,
        cfg.chain_latency,
        cfg.mask_elems_per_lane_cycle.to_bits(),
        cfg.scalar_fp_latency,
        cfg.scalar_mul_latency,
        cfg.scalar_load_latency,
        cfg.vq_depth as u64,
    ] {
        fnv(&mut h, v);
    }
    h
}

/// Per-layer marker inside a [`CompiledProgram`]: the trace range that
/// implements the layer plus everything a replay needs to rebuild the
/// layer's [`crate::nn::model::LayerReport`] without re-emitting.
#[derive(Clone, Debug)]
pub struct LayerMark {
    pub name: String,
    /// Resolved execution precision of the layer.
    pub precision: Precision,
    /// Member of the paper's quantized-layer set (Fig. 3 filtering).
    pub quantized: bool,
    /// Compile-space address of the layer's output feature map (re-based by
    /// the relocation delta on replay).
    pub out_addr: u64,
    pub out_elems: usize,
    /// Effective MACs the layer's kernel reports
    /// ([`crate::kernels::KernelRun::macs`]).
    pub macs: u64,
    /// MACs the kernel *credits into* [`crate::sim::Stats`] — equal to
    /// `macs` for the conv/GEMM kernels, 0 for pooling (which reports but
    /// does not credit). Replay re-credits exactly this amount so stats
    /// stay identical to fresh emission.
    pub(crate) credited_macs: u64,
    /// Exclusive end index of the layer's instructions in the trace (layer
    /// `i` spans `layers[i-1].trace_end .. layers[i].trace_end`).
    pub(crate) trace_end: usize,
}

/// Per-layer shard segment of a tensor-parallel shard program
/// ([`compile_shard`]): where the kernel wrote its partial output-channel
/// slice, and where the cluster runtime must deposit the full gathered map
/// before the next layer reads it. All addresses are compile-space (re-based
/// by the relocation delta on replay, like every other program address).
#[derive(Clone, Debug)]
pub struct ShardSeg {
    /// Output-channel range `[c0, c1)` this shard computes; `None` when the
    /// layer runs replicated (pooling, or every layer of a 1-shard plan).
    pub channels: Option<(usize, usize)>,
    /// Full output channel count of the layer.
    pub c_full: usize,
    /// Spatial positions of the output map (`out_h · out_w`; 1 for FC and
    /// pooling).
    pub positions: usize,
    /// Kernel-written partial output (packed layout, channel stride
    /// `c1 − c0`). Equals `gather_addr` when the layer is replicated.
    pub part_addr: u64,
    /// Full gathered map every consumer of this layer reads (`positions ·
    /// c_full` u8 codes). The cluster runtime writes it after the all-gather.
    pub gather_addr: u64,
    /// Residual feed of a sharded residual layer: `(source feature-map
    /// index, slice-buffer address)`. The runtime fills the buffer with this
    /// shard's `[c0, c1)` channel slice of the (already gathered) source map
    /// before the layer's trace range executes — the kernels index residual
    /// maps at their own (narrowed) channel stride.
    pub res_slice: Option<(usize, u64)>,
}

impl ShardSeg {
    /// The identity segment of a replicated (or unpartitioned) layer: the
    /// kernel output *is* the full map, nothing to gather or slice.
    pub(crate) fn replicated(addr: u64, c_full: usize, positions: usize) -> ShardSeg {
        ShardSeg {
            channels: None,
            c_full,
            positions,
            part_addr: addr,
            gather_addr: addr,
            res_slice: None,
        }
    }

    /// Elements of the kernel-written partial slice.
    pub fn part_elems(&self) -> usize {
        match self.channels {
            Some((c0, c1)) => self.positions * (c1 - c0),
            None => self.positions * self.c_full,
        }
    }

    /// Elements of the full gathered map.
    pub fn gather_elems(&self) -> usize {
        self.positions * self.c_full
    }
}

/// Pipeline-stage identity of a [`compile_stage`] artifact: which contiguous
/// layer range of the source net this program executes, and where it sits in
/// the stage sequence. The pipeline runtime ([`crate::cluster::pipeline`])
/// validates a stage set against these before streaming requests through it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageInfo {
    /// Stage index in `0..count`.
    pub index: usize,
    /// Total stages of the plan this program was compiled under.
    pub count: usize,
    /// First layer (inclusive) of the stage's range in the source net.
    pub lo: usize,
    /// Last layer (exclusive) of the stage's range in the source net.
    pub hi: usize,
}

/// The network-input segment of a program: where replay writes per-request
/// input bytes, and how they are encoded.
#[derive(Clone, Debug)]
pub(crate) struct InputSpec {
    /// Compile-space address of feature map 0.
    pub(crate) addr: u64,
    pub(crate) elems: usize,
    /// Input clamp grid (`2^bits − 1` of the narrowest consumer) — the
    /// mixed-precision re-pack rule applied to map 0.
    pub(crate) qmax: u8,
    /// fp32 schedules store the input as `code / 255.0` floats.
    pub(crate) fp32: bool,
}

/// A compiled, relocatable inference program: everything needed to replay
/// one (net, machine, schedule) emission against fresh input bytes, with
/// zero kernel re-emission. Produced by [`compile`] / [`ProgramBuilder`];
/// consumed by [`crate::sim::Sim::execute`] and
/// [`crate::sim::Sim::execute_functional`].
pub struct CompiledProgram {
    pub(crate) net_fp: u64,
    pub(crate) machine_fp: u64,
    /// Name of the [`NetGraph`] this program was compiled from.
    pub(crate) model_name: String,
    pub(crate) machine_name: String,
    pub(crate) schedule: PrecisionMap,
    /// Compile-time heap base: the program's addresses are valid as-is when
    /// replayed at this base; any other base applies a uniform delta.
    pub(crate) base: u64,
    /// Bytes of simulated memory the program occupies from `base`.
    pub(crate) mem_len: u64,
    pub(crate) trace: Vec<Instr>,
    /// Sorted trace indices of relocatable `li` address immediates.
    pub(crate) reloc: Vec<u32>,
    /// Host-written initial memory (weights, requant tables, constants,
    /// index vectors, the synthetic default input), in program order.
    pub(crate) image: Vec<(u64, Vec<u8>)>,
    pub(crate) input: InputSpec,
    /// Compile-space address/length of the final feature map (the logits).
    pub(crate) out_addr: u64,
    pub(crate) out_elems: usize,
    pub(crate) layers: Vec<LayerMark>,
    /// `(shard index, shard count)` for tensor-parallel shard programs
    /// ([`compile_shard`]); `None` for single-core programs.
    pub(crate) shard: Option<(usize, usize)>,
    /// Stage identity for pipeline-stage programs ([`compile_stage`]);
    /// `None` for single-core and tensor-shard programs.
    pub(crate) stage: Option<StageInfo>,
    /// One [`ShardSeg`] per layer on shard programs; empty otherwise.
    pub(crate) shard_segs: Vec<ShardSeg>,
    /// VLEN the program was compiled for — the lowering pass needs it to
    /// resolve `vsetvli` results statically.
    pub(crate) vlen_bits: usize,
    /// Lazily built decode-once lowering of the trace ([`lowered::lower`]).
    /// The coordinator forces it at cache-insert time so warm replays never
    /// pay the lowering cost.
    pub(crate) lowered: std::sync::OnceLock<LoweredProgram>,
    /// Lazily built static-verification report ([`verify::verify`]). Forced
    /// alongside the lowering at cache-insert time; a failing artifact is
    /// never served from the warm path.
    pub(crate) verify: std::sync::OnceLock<VerifyReport>,
}

impl CompiledProgram {
    /// Simulated-memory footprint: a replay target must have this many bytes
    /// free at the chosen base.
    pub fn mem_len(&self) -> u64 {
        self.mem_len
    }

    /// Dynamic instructions in the trace.
    pub fn trace_len(&self) -> usize {
        self.trace.len()
    }

    /// Bytes of host-written initial memory re-applied per replay.
    pub fn image_bytes(&self) -> usize {
        self.image.iter().map(|(_, b)| b.len()).sum()
    }

    /// The schedule this program was compiled under (canonical form).
    pub fn schedule(&self) -> &PrecisionMap {
        &self.schedule
    }

    /// Fingerprint of the model graph ([`NetGraph::fingerprint`]).
    pub fn net_fingerprint(&self) -> u64 {
        self.net_fp
    }

    /// Name of the model graph this program was compiled from
    /// ([`NetGraph::name`]).
    pub fn model(&self) -> &str {
        &self.model_name
    }

    /// Fingerprint of the machine ([`machine_fingerprint`]).
    pub fn machine_fingerprint(&self) -> u64 {
        self.machine_fp
    }

    /// Per-layer marks, in network order.
    pub fn layers(&self) -> &[LayerMark] {
        &self.layers
    }

    /// Element count of the final feature map (class count for classifiers).
    pub fn out_elems(&self) -> usize {
        self.out_elems
    }

    /// Element count of the network-input segment.
    pub fn input_elems(&self) -> usize {
        self.input.elems
    }

    /// True for uniform-fp32 programs (logits are raw f32, input is
    /// normalized to `[0, 1]`).
    pub fn is_fp32(&self) -> bool {
        self.input.fp32
    }

    /// Bytes of the output segment a replay harvests per inference: one u8
    /// activation code per element at integer precisions, four bytes per
    /// element (little-endian f32) when [`CompiledProgram::is_fp32`].
    pub fn output_bytes(&self) -> usize {
        self.out_elems * if self.is_fp32() { 4 } else { 1 }
    }

    /// `(shard index, shard count)` of a tensor-parallel shard program;
    /// `None` for single-core programs ([`compile`]).
    pub fn shard(&self) -> Option<(usize, usize)> {
        self.shard
    }

    /// Per-layer shard segments (empty on single-core programs).
    pub fn shard_segs(&self) -> &[ShardSeg] {
        &self.shard_segs
    }

    /// Stage identity of a pipeline-stage program ([`compile_stage`]);
    /// `None` for single-core and tensor-shard programs.
    pub fn stage(&self) -> Option<StageInfo> {
        self.stage
    }

    /// The decode-once lowering of this program's trace, built on first use
    /// and cached for the program's lifetime. [`crate::sim::Sim::execute_lowered`]
    /// replays it; [`crate::sim::Sim::execute_functional`] stays the
    /// instruction-by-instruction oracle.
    pub fn lowered(&self) -> &LoweredProgram {
        self.lowered.get_or_init(|| lowered::lower(self, self.vlen_bits))
    }

    /// The static-verification report for this artifact, built on first use
    /// and cached for the program's lifetime ([`verify::verify`]): replay /
    /// relocation / segment / fused-op safety findings plus the
    /// batch-isolation proof [`VerifyReport::batch_safe`] that lets
    /// [`crate::sim::Sim::execute_lowered_batch`] skip its per-element image
    /// scan.
    pub fn verify_report(&self) -> &VerifyReport {
        self.verify.get_or_init(|| verify::verify(self))
    }
}

/// Compile `net` for `machine` under `schedule` into a reusable
/// [`CompiledProgram`]. Validates the schedule against the net and the
/// machine first ([`PrecisionMap::validate`] /
/// [`PrecisionMap::validate_machine`]); the error is the human-readable
/// reason. Compilation runs the kernel emitters exactly once, into a
/// recording [`Sim`](crate::sim::Sim) — no cycles are simulated and no
/// vector data flows.
pub fn compile(
    net: &NetGraph,
    machine: &MachineConfig,
    schedule: &PrecisionMap,
) -> Result<CompiledProgram, String> {
    schedule.validate(net)?;
    schedule.validate_machine(net, machine)?;
    let prog = ProgramBuilder::new(machine.clone()).build(net, schedule);
    // Debug builds verify every freshly compiled artifact; release serving
    // relies on the coordinator's cache-insert gate instead.
    #[cfg(debug_assertions)]
    debug_assert!(
        prog.verify_report().ok(),
        "compile produced an unverifiable artifact:\n{}",
        prog.verify_report()
    );
    Ok(prog)
}

/// Compile shard `shard` of a tensor-parallel cluster deployment: the same
/// validated emission as [`compile`], but every Conv/FC layer computes only
/// its [`crate::nn::model::ShardPlan::range`] of output channels (reading
/// the full input map), writing into a partial buffer; a full-size gather
/// buffer per layer receives the inter-core all-gather at replay
/// ([`crate::cluster`]). Weights and requant parameters are drawn from the
/// *full* deterministic stream and column-sliced, so every channel's
/// arithmetic — and therefore the gathered feature maps — is bit-identical
/// to the single-core program. At `plan.shards() == 1` the emission is
/// instruction- and image-identical to [`compile`].
pub fn compile_shard(
    net: &NetGraph,
    machine: &MachineConfig,
    schedule: &PrecisionMap,
    plan: &crate::nn::model::ShardPlan,
    shard: usize,
) -> Result<CompiledProgram, String> {
    schedule.validate(net)?;
    schedule.validate_machine(net, machine)?;
    plan.validate_schedule(schedule)?;
    if plan.layers() != net.len() {
        return Err(format!(
            "shard plan covers {} layers but the net has {}",
            plan.layers(),
            net.len()
        ));
    }
    if shard >= plan.shards() {
        return Err(format!("shard {shard} out of range (plan has {})", plan.shards()));
    }
    let prog = ProgramBuilder::new(machine.clone()).build_sharded(net, schedule, plan, shard);
    #[cfg(debug_assertions)]
    debug_assert!(
        prog.verify_report().ok(),
        "compile_shard produced an unverifiable artifact:\n{}",
        prog.verify_report()
    );
    Ok(prog)
}

/// Compile stage `stage` of a pipeline-parallel cluster deployment: the same
/// validated emission as [`compile`], restricted to the plan's contiguous
/// layer range — the stage's *input segment* is the hand-off activation map
/// written per request by the pipeline runtime ([`crate::cluster::pipeline`]).
/// The deterministic parameter stream is advanced over the skipped prefix
/// layers and requant grids come from the *full* net, so chained stage
/// programs produce logits bit-identical to the single-core program. At
/// `plan.stages() == 1` the emission is instruction- and image-identical to
/// [`compile`].
pub fn compile_stage(
    net: &NetGraph,
    machine: &MachineConfig,
    schedule: &PrecisionMap,
    plan: &crate::nn::model::StagePlan,
    stage: usize,
) -> Result<CompiledProgram, String> {
    schedule.validate(net)?;
    schedule.validate_machine(net, machine)?;
    plan.validate_schedule(schedule)?;
    if plan.layers() != net.len() {
        return Err(format!(
            "stage plan covers {} layers but the net has {}",
            plan.layers(),
            net.len()
        ));
    }
    if stage >= plan.stages() {
        return Err(format!("stage {stage} out of range (plan has {})", plan.stages()));
    }
    let prog = ProgramBuilder::new(machine.clone()).build_staged(net, schedule, plan, stage);
    #[cfg(debug_assertions)]
    debug_assert!(
        prog.verify_report().ok(),
        "compile_stage produced an unverifiable artifact:\n{}",
        prog.verify_report()
    );
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::demo_net;

    #[test]
    fn fingerprints_separate_deployments() {
        let net = demo_net();
        let fp = net.fingerprint();
        assert_eq!(fp, demo_net().fingerprint(), "fingerprint must be deterministic");
        // A different classifier width is a different model identity.
        let other = crate::nn::zoo::model("tiny@10").unwrap();
        assert_ne!(fp, other.fingerprint(), "shape change must change the key");
        // So is a different topology under the same class count.
        let quarknet = crate::nn::zoo::model("quarknet@100").unwrap();
        assert_ne!(fp, quarknet.fingerprint());
        assert_ne!(
            machine_fingerprint(&MachineConfig::quark(4)),
            machine_fingerprint(&MachineConfig::quark(8)),
        );
        assert_ne!(
            machine_fingerprint(&MachineConfig::quark(4)),
            machine_fingerprint(&MachineConfig::ara(4)),
        );
    }

    #[test]
    fn compile_rejects_invalid_schedules() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        // Unknown layer name.
        let bad = PrecisionMap::uniform(Precision::Int8).with("ghost", Precision::Int8);
        // `with` canonicalizes equal-to-default overrides away; force a
        // distinct one instead.
        let bad2 = PrecisionMap::uniform(Precision::Int8)
            .with("ghost", Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
        assert!(bad.is_uniform(), "redundant override canonicalizes away");
        assert!(compile(&net, &quark, &bad2).is_err());
        // fp32 needs the vector FPU Quark lacks.
        assert!(compile(&net, &quark, &PrecisionMap::uniform(Precision::Fp32)).is_err());
    }

    #[test]
    fn compile_produces_a_plausible_artifact() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(Precision::Sub {
            abits: 2,
            wbits: 2,
            use_vbitpack: true,
        });
        let prog = compile(&net, &quark, &sched).unwrap();
        assert_eq!(prog.layers().len(), net.len());
        assert!(prog.trace_len() > 0);
        assert!(prog.mem_len() > 0);
        assert!(prog.image_bytes() > 0, "weights + rq tables must be captured");
        assert_eq!(prog.out_elems(), 100, "demo net classifies over 100 classes");
        assert_eq!(prog.input_elems(), 32 * 32 * 3);
        assert!(!prog.is_fp32());
        // Layer marks tile the trace.
        assert_eq!(prog.layers().last().unwrap().trace_end, prog.trace_len());
        let mut prev = 0;
        for m in prog.layers() {
            assert!(m.trace_end > prev, "layer {} spans no instructions", m.name);
            prev = m.trace_end;
        }
        // Relocation entries are sorted, in range, and all point at `li`s.
        let mut last = 0u32;
        for (i, &r) in prog.reloc.iter().enumerate() {
            assert!((r as usize) < prog.trace_len());
            assert!(i == 0 || r > last, "reloc table must be strictly sorted");
            last = r;
            assert!(
                matches!(
                    prog.trace[r as usize],
                    crate::isa::instr::Instr::Scalar(crate::isa::instr::ScalarOp::Li { .. })
                ),
                "relocation entry {r} is not an li"
            );
        }
        // Determinism: compiling twice yields the identical artifact.
        let again = compile(&demo_net(), &quark, &sched).unwrap();
        assert_eq!(prog.trace, again.trace);
        assert_eq!(prog.reloc, again.reloc);
        assert_eq!(prog.image, again.image);
        assert_eq!(prog.mem_len, again.mem_len);
    }
}
