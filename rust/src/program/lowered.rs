//! Decode-once lowering: [`CompiledProgram`] → [`LoweredProgram`].
//!
//! Warm serving replays the same trace thousands of times, and the
//! instruction-by-instruction interpreter re-pays decode + dispatch on every
//! element of every loop iteration — the overhead class SPEED
//! (arXiv 2409.14017) attacks with decode/dispatch separation. This pass
//! walks the trace **once**, statically resolving `vsetvli` results (AVL and
//! vtype are trace literals, so `vl` is a compile-time constant at every
//! point), and collapses the hot emitted shapes into host micro-ops:
//!
//! * `li`+`vle`/`vse` unit-stride transfers → one bounds-checked memcpy
//!   ([`MicroOp::LoadUnit`] / [`MicroOp::StoreUnit`] / [`MicroOp::Copy`]);
//! * `vmv.v.i 0`+`li`+`vse` splat-fills → one zero-fill ([`MicroOp::Fill`]);
//! * the bit-serial MAC inner loop — runs of `ld`/`vand.vx`/`vpopcnt.v`/
//!   `vadd.vv` quads — → one tight AND-popcount-accumulate kernel
//!   ([`MicroOp::PlaneMac`]);
//! * `vbitpack.vi` → an allocation-free host packer
//!   ([`MicroOp::BitpackFast`]);
//! * the int8 conv tap `li`+`lbu`+`vmacc.vx` → [`MicroOp::MaccByte`];
//! * the 10-instruction activation row-sum shape → [`MicroOp::RowSum`].
//!
//! Everything else stays as [`MicroOp::Interp`] ranges executed by the
//! unchanged functional interpreter. **Fusion legality**: a sequence is
//! fused only when the micro-op reproduces *every* architectural effect of
//! the replaced instructions — destination vector registers (including the
//! final values of scratch intermediates), scalar registers, vl/vtype, and
//! memory — so machine state at every micro-op boundary is bit-identical to
//! plain interpretation, and any prefix/suffix mix of fused and interpreted
//! execution is exact. Matchers reject the rare register-aliasing shapes
//! where eliding an intermediate write would be observable (conditions
//! documented per matcher below).
//!
//! Addresses are fully resolved at lowering time: every fused address comes
//! from a relocation-marked `li`, stored in compile space and re-based by
//! the replay delta — the same rule as interpreted relocation.
//!
//! [`crate::sim::Sim::execute`] (timed) and
//! [`crate::sim::Sim::execute_functional`] (values-only) are untouched and
//! serve as the differential oracles; `rust/tests/lowered_differential.rs`
//! and the randomized sweep in `rust/tests/sim_properties.rs` hold the
//! proofs.

use crate::isa::instr::{Instr, MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use crate::isa::reg::{Reg, VReg};
use crate::isa::vtype::{Sew, VType};
use crate::sim::exec::{trunc, MacTap, RowSumOp};
use crate::sim::Sim;

use super::replay::functional_run;
use super::{CompiledProgram, ProgramRun};

/// One pre-decoded replay step. Address fields are compile-space; the
/// executor adds the relocation delta.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum MicroOp {
    /// Fallback: interpret the trace range `[lo, hi)` unchanged.
    Interp { lo: u32, hi: u32 },
    /// `vmv.v.i vd, 0` + reloc-`li rd` + unit-stride `vse`.
    Fill { vd: VReg, rd: Reg, addr: u64, len: usize },
    /// `li`+`vle`+`li`+`vse`: memory-to-memory copy staged through `vd`.
    Copy { rs: Reg, src: u64, rd: Reg, dst: u64, vd: VReg, len: usize },
    /// Reloc-`li rd` + unit-stride `vle`.
    LoadUnit { rd: Reg, addr: u64, vd: VReg, len: usize },
    /// Reloc-`li rd` + unit-stride `vse`.
    StoreUnit { rd: Reg, addr: u64, vs3: VReg, len: usize },
    /// A run of bit-serial MAC quads at SEW=64 sharing scratch `t1`/`tmp`.
    PlaneMac { vl: usize, t1: Reg, tmp: VReg, taps: Box<[MacTap]> },
    /// One `vbitpack.vi` through the allocation-free host packer.
    BitpackFast { vd: VReg, vs2: VReg, bit: u8, vl: usize, eb: usize },
    /// Int8 conv tap: reloc-`li a0` + `lbu t1, 0(a0)` + `vmacc.vx`.
    MaccByte { a0: Reg, addr: u64, t1: Reg, vd: VReg, vs2: VReg, vl: usize, eb: usize },
    /// The fused 10-instruction activation row-sum shape.
    RowSum(Box<RowSumOp>),
}

/// A [`CompiledProgram`] trace lowered into dense pre-decoded micro-ops.
/// Built once per cached program ([`CompiledProgram::lowered`]); replayed by
/// [`Sim::execute_lowered`].
pub struct LoweredProgram {
    pub(crate) ops: Vec<MicroOp>,
    /// Trace range `[lo, hi)` each micro-op covers, parallel to `ops`.
    /// Ranges are non-empty and tile the trace in order — the cycle
    /// attributor ([`crate::obs::profile`]) samples the timing model at
    /// exactly these boundaries to split the total by micro-op class.
    pub(crate) spans: Vec<(u32, u32)>,
    fused_instrs: usize,
    interp_instrs: usize,
}

impl LoweredProgram {
    /// Number of replay steps (fused kernels + interpreter ranges).
    pub fn micro_ops(&self) -> usize {
        self.ops.len()
    }

    /// Trace instructions covered by fused host kernels.
    pub fn fused_instrs(&self) -> usize {
        self.fused_instrs
    }

    /// Trace instructions still executed by the interpreter.
    pub fn interp_instrs(&self) -> usize {
        self.interp_instrs
    }

    /// Fraction of trace instructions covered by fused host kernels.
    pub fn fused_fraction(&self) -> f64 {
        let total = self.fused_instrs + self.interp_instrs;
        if total == 0 {
            0.0
        } else {
            self.fused_instrs as f64 / total as f64
        }
    }
}

/// Lower `prog`'s trace. Pure function of (trace, reloc table, VLEN).
pub(crate) fn lower(prog: &CompiledProgram, vlen_bits: usize) -> LoweredProgram {
    let trace = &prog.trace;
    let mut is_reloc = vec![false; trace.len()];
    for &r in &prog.reloc {
        is_reloc[r as usize] = true;
    }
    let mut ops = Vec::new();
    let mut spans: Vec<(u32, u32)> = Vec::new();
    let mut fused_instrs = 0usize;
    // Start of the currently open Interp range, if any.
    let mut pend: Option<u32> = None;
    // Statically tracked (vl, vtype); fusion requires both known.
    let mut st: Option<(u64, VType)> = None;
    let mut i = 0usize;
    while i < trace.len() {
        if let Some(st_now) = st {
            if let Some((op, took)) = match_at(trace, &is_reloc, i, st_now, vlen_bits) {
                if let Some(lo) = pend.take() {
                    ops.push(MicroOp::Interp { lo, hi: i as u32 });
                    spans.push((lo, i as u32));
                }
                // RowSum embeds two vsetvli's; carry their result forward.
                if let MicroOp::RowSum(rs) = &op {
                    st = Some((rs.vl_after, rs.vtype_after));
                }
                fused_instrs += took;
                ops.push(op);
                spans.push((i as u32, (i + took) as u32));
                i += took;
                continue;
            }
        }
        if let Instr::VSetVli { avl, vtype, .. } = trace[i] {
            st = Some((avl.min(vtype.vlmax(vlen_bits) as u64), vtype));
        }
        if pend.is_none() {
            pend = Some(i as u32);
        }
        i += 1;
    }
    if let Some(lo) = pend {
        ops.push(MicroOp::Interp { lo, hi: trace.len() as u32 });
        spans.push((lo, trace.len() as u32));
    }
    debug_assert_eq!(spans.len(), ops.len());
    debug_assert!(spans.windows(2).all(|w| w[0].1 == w[1].0), "spans must tile the trace");
    let interp_instrs = trace.len() - fused_instrs;
    LoweredProgram { ops, spans, fused_instrs, interp_instrs }
}

/// Try every matcher at trace position `i` under statically known
/// `(vl, vtype)`. Returns the micro-op and how many instructions it covers.
fn match_at(
    trace: &[Instr],
    is_reloc: &[bool],
    i: usize,
    (vl, vt): (u64, VType),
    vlen_bits: usize,
) -> Option<(MicroOp, usize)> {
    let eb = vt.sew.bytes();
    match trace[i] {
        // Splat-zero fill: vmv.v.i vd,0 ; li rd,addr ; vse vd,(rd).
        Instr::Vector(VOp::MvVI { vd, imm }) if trunc(imm as u64, vt.sew.bits()) == 0 => {
            let (rd, addr) = reloc_li(trace, is_reloc, i + 1)?;
            let (eew, vs3, base) = unit_store(trace, i + 2)?;
            if eew.bytes() != eb || vs3 != vd || base != rd {
                return None;
            }
            Some((MicroOp::Fill { vd, rd, addr, len: vl as usize * eb }, 3))
        }
        // Address materialization: row-sum first (li+vle is its prefix),
        // then copy (li+vle+li+vse), then the int8 tap, then bare transfers.
        Instr::Scalar(ScalarOp::Li { .. }) => match_row_sum(trace, is_reloc, i, vl, vt, vlen_bits)
            .or_else(|| match_copy(trace, is_reloc, i, vl))
            .or_else(|| match_macc_byte(trace, is_reloc, i, vl, eb))
            .or_else(|| match_load_store(trace, is_reloc, i, vl)),
        Instr::Scalar(ScalarOp::Load { width: MemWidth::D, signed: false, .. }) => {
            match_plane_mac(trace, i, vl, vt)
        }
        Instr::Vector(VOp::Bitpack { vd, vs2, bit }) => {
            // The host packer mirrors the interpreted semantics only within
            // the asserted envelope (plane fits one register) and uses a
            // fixed 512-byte stack buffer.
            let ok = vl as usize <= vlen_bits
                && (bit as usize) < vt.sew.bits()
                && vlen_bits / 8 <= 512;
            ok.then_some((MicroOp::BitpackFast { vd, vs2, bit, vl: vl as usize, eb }, 1))
        }
        _ => None,
    }
}

/// A relocation-marked `li rd, addr` with `rd != x0` (fused ops must write
/// the register; `li x0` would be a no-op the executors don't model).
fn reloc_li(trace: &[Instr], is_reloc: &[bool], i: usize) -> Option<(Reg, u64)> {
    if i >= trace.len() || !is_reloc[i] {
        return None;
    }
    match trace[i] {
        Instr::Scalar(ScalarOp::Li { rd, imm }) if rd.0 != 0 => Some((rd, imm as u64)),
        _ => None,
    }
}

fn unit_load(trace: &[Instr], i: usize) -> Option<(Sew, VReg, Reg)> {
    match trace.get(i)? {
        Instr::Vector(VOp::Load { kind: VMemKind::UnitStride, eew, vd, base }) => {
            Some((*eew, *vd, *base))
        }
        _ => None,
    }
}

fn unit_store(trace: &[Instr], i: usize) -> Option<(Sew, VReg, Reg)> {
    match trace.get(i)? {
        Instr::Vector(VOp::Store { kind: VMemKind::UnitStride, eew, vs3, base }) => {
            Some((*eew, *vs3, *base))
        }
        _ => None,
    }
}

/// `li rs,src ; vle vd,(rs) ; li rd,dst ; vse vd,(rd)` with equal element
/// widths. Load-before-store execution makes overlap and `rs == rd` exact.
fn match_copy(trace: &[Instr], is_reloc: &[bool], i: usize, vl: u64) -> Option<(MicroOp, usize)> {
    let (rs, src) = reloc_li(trace, is_reloc, i)?;
    let (eew1, vd, b1) = unit_load(trace, i + 1)?;
    let (rd, dst) = reloc_li(trace, is_reloc, i + 2)?;
    let (eew2, vs3, b2) = unit_store(trace, i + 3)?;
    if b1 != rs || b2 != rd || vs3 != vd || eew1.bytes() != eew2.bytes() {
        return None;
    }
    Some((MicroOp::Copy { rs, src, rd, dst, vd, len: vl as usize * eew1.bytes() }, 4))
}

/// `li rd,addr` + a single unit-stride transfer based on `rd`.
fn match_load_store(
    trace: &[Instr],
    is_reloc: &[bool],
    i: usize,
    vl: u64,
) -> Option<(MicroOp, usize)> {
    let (rd, addr) = reloc_li(trace, is_reloc, i)?;
    if let Some((eew, vd, base)) = unit_load(trace, i + 1) {
        if base == rd {
            return Some((MicroOp::LoadUnit { rd, addr, vd, len: vl as usize * eew.bytes() }, 2));
        }
    }
    if let Some((eew, vs3, base)) = unit_store(trace, i + 1) {
        if base == rd {
            return Some((MicroOp::StoreUnit { rd, addr, vs3, len: vl as usize * eew.bytes() }, 2));
        }
    }
    None
}

/// `li a0,addr ; lbu t1, 0(a0) ; vmacc.vx vd, t1, vs2` — the int8 conv tap.
/// `t1 == x0` is legal (both the interpreter and the fused kernel then
/// multiply by zero).
fn match_macc_byte(
    trace: &[Instr],
    is_reloc: &[bool],
    i: usize,
    vl: u64,
    eb: usize,
) -> Option<(MicroOp, usize)> {
    let (a0, addr) = reloc_li(trace, is_reloc, i)?;
    let Instr::Scalar(ScalarOp::Load {
        width: MemWidth::B,
        signed: false,
        rd: t1,
        base,
        offset: 0,
    }) = *trace.get(i + 1)?
    else {
        return None;
    };
    if base != a0 {
        return None;
    }
    let Instr::Vector(VOp::MaccVX { vd, rs1, vs2 }) = *trace.get(i + 2)? else {
        return None;
    };
    if rs1 != t1 {
        return None;
    }
    Some((MicroOp::MaccByte { a0, addr, t1, vd, vs2, vl: vl as usize, eb }, 3))
}

/// A maximal run of bit-serial MAC quads at SEW=64:
/// `ld t1, off(base) ; vand.vx tmp, w, t1 ; vpopcnt.v tmp, tmp ;
///  vadd.vv acc, acc, tmp`, all quads sharing `t1`/`tmp`.
///
/// Legality: `t1 != x0` (else the AND reads zero, not the loaded word);
/// `base != t1` per tap (base registers stay stable across the run — it
/// writes no memory and no scalar but `t1`, which also licenses hoisting
/// the loads per chunk); `w != tmp` (the AND would read stale scratch);
/// `acc != tmp`; `acc != w` within a tap (the elided intermediate `tmp`
/// would otherwise be computed from a pre-accumulate `w` the fused kernel
/// no longer sees). Cross-tap aliasing (e.g. one tap's `acc` as a later
/// tap's `w`) is exact by tap-major ordering.
fn match_plane_mac(trace: &[Instr], i: usize, vl: u64, vt: VType) -> Option<(MicroOp, usize)> {
    if vt.sew != Sew::E64 {
        return None;
    }
    let Instr::Scalar(ScalarOp::Load { rd: t1, .. }) = trace[i] else {
        return None;
    };
    if t1.0 == 0 {
        return None;
    }
    let Instr::Vector(VOp::IVX { op: VIOp::And, vd: tmp, .. }) = *trace.get(i + 1)? else {
        return None;
    };
    let mut taps = Vec::new();
    let mut j = i;
    while let Some(&Instr::Scalar(ScalarOp::Load {
        width: MemWidth::D,
        signed: false,
        rd,
        base,
        offset,
    })) = trace.get(j)
    {
        if rd != t1 || base == t1 {
            break;
        }
        let Some(&Instr::Vector(VOp::IVX { op: VIOp::And, vd, vs2: w, rs1 })) = trace.get(j + 1)
        else {
            break;
        };
        if vd != tmp || rs1 != t1 || w == tmp {
            break;
        }
        let Some(&Instr::Vector(VOp::Popcnt { vd: pd, vs2: ps })) = trace.get(j + 2) else {
            break;
        };
        if pd != tmp || ps != tmp {
            break;
        }
        let Some(&Instr::Vector(VOp::IVV { op: VIOp::Add, vd: acc, vs2, vs1 })) = trace.get(j + 3)
        else {
            break;
        };
        if vs2 != acc || vs1 != tmp || acc == tmp || acc == w {
            break;
        }
        taps.push(MacTap { base, offset, w, acc });
        j += 4;
    }
    if taps.is_empty() {
        return None;
    }
    let took = taps.len() * 4;
    Some((MicroOp::PlaneMac { vl: vl as usize, t1, tmp, taps: taps.into_boxed_slice() }, took))
}

/// The single-chunk row-sum shape `kernels::matmul::emit_row_sum_u8` emits:
///
/// ```text
/// li a0, src ; vle8 vload,(a0) ; vzext vz, vload            (n bytes → u32)
/// vsetvli x0, 1, e32 ; vmv.v.i vacc, 0 ; vsetvli x0, n, e32
/// vredsum vacc, vz, vacc ; vmv.x.s t0, vacc
/// li t1, dst ; sw t0, 0(t1)
/// ```
///
/// Legality: current SEW=32 (the widen reads bytes), `n <= 1024` (fixed
/// stack buffer; the emitter's chunk bound), both embedded `vsetvli`s write
/// `x0`, the second resolves back to exactly `n`, and `vacc`'s first
/// element overlaps neither the loaded bytes nor the widened u32 span (the
/// fused kernel elides the intermediate `vacc` zero-write).
fn match_row_sum(
    trace: &[Instr],
    is_reloc: &[bool],
    i: usize,
    vl: u64,
    vt: VType,
    vlen_bits: usize,
) -> Option<(MicroOp, usize)> {
    let n = vl as usize;
    if vt.sew != Sew::E32 || n > 1024 {
        return None;
    }
    let (a0, src) = reloc_li(trace, is_reloc, i)?;
    let (eew, vload, b1) = unit_load(trace, i + 1)?;
    if eew != Sew::E8 || b1 != a0 {
        return None;
    }
    let Instr::Vector(VOp::Zext { vd: vz, vs2, frac: 4 }) = *trace.get(i + 2)? else {
        return None;
    };
    if vs2 != vload {
        return None;
    }
    let Instr::VSetVli { rd: r1, avl: 1, vtype: vt1 } = *trace.get(i + 3)? else {
        return None;
    };
    if r1.0 != 0 || vt1.sew != Sew::E32 {
        return None;
    }
    let Instr::Vector(VOp::MvVI { vd: vacc, imm }) = *trace.get(i + 4)? else {
        return None;
    };
    if trunc(imm as u64, 32) != 0 {
        return None;
    }
    let Instr::VSetVli { rd: r2, avl: a2, vtype: vt2 } = *trace.get(i + 5)? else {
        return None;
    };
    if r2.0 != 0 || vt2.sew != Sew::E32 || a2.min(vt2.vlmax(vlen_bits) as u64) != vl {
        return None;
    }
    let Instr::Vector(VOp::RedSum { vd, vs2, vs1 }) = *trace.get(i + 6)? else {
        return None;
    };
    if vd != vacc || vs2 != vz || vs1 != vacc {
        return None;
    }
    let Instr::Vector(VOp::MvXS { rd: t0, vs2: ms }) = *trace.get(i + 7)? else {
        return None;
    };
    if ms != vacc {
        return None;
    }
    let (t1, dst) = reloc_li(trace, is_reloc, i + 8)?;
    let Instr::Scalar(ScalarOp::Store { width: MemWidth::W, rs2, base, offset: 0 }) =
        *trace.get(i + 9)?
    else {
        return None;
    };
    if rs2 != t0 || base != t1 {
        return None;
    }
    let vreg_bytes = vlen_bits / 8;
    let l0 = vload.0 as usize * vreg_bytes;
    let z0 = vz.0 as usize * vreg_bytes;
    let av = vacc.0 as usize * vreg_bytes;
    let acc_disjoint = |lo: usize, len: usize| av + 4 <= lo || lo + len <= av;
    if !(acc_disjoint(l0, n) && acc_disjoint(z0, 4 * n)) {
        return None;
    }
    Some((
        MicroOp::RowSum(Box::new(RowSumOp {
            src,
            dst,
            n,
            a0,
            t0,
            t1,
            vload,
            vz,
            vacc,
            vl_after: vl,
            vtype_after: vt2,
        })),
        10,
    ))
}

impl Sim {
    /// Values-only replay through the decode-once lowering
    /// ([`CompiledProgram::lowered`]): the warm-serving fast path. Memory
    /// effects — and therefore logits and per-layer maps — are bit-identical
    /// to [`Sim::execute_functional`] (and to [`Sim::execute`] in `Full`
    /// mode), which remain the differential oracles. Like the functional
    /// path, no timing scoreboard runs and reported cycles are zero.
    pub fn execute_lowered(
        &mut self,
        prog: &CompiledProgram,
        base: u64,
        input: Option<&[u8]>,
    ) -> ProgramRun {
        let delta = self.begin_replay(prog, base, input);
        self.run_lowered_ops(prog, delta);
        functional_run(prog, delta)
    }

    /// One pass over the fused micro-ops at relocation `delta`: the body of
    /// a lowered replay, after [`Sim::begin_replay`] has prepared the arena.
    /// Split out so [`Sim::execute_lowered_batch`] can re-run the pass per
    /// batch element on one shared arena.
    fn run_lowered_ops(&mut self, prog: &CompiledProgram, delta: u64) {
        let low = prog.lowered();
        for op in &low.ops {
            match op {
                MicroOp::Interp { lo, hi } => {
                    self.execute_functional_range(prog, delta, *lo as usize, *hi as usize)
                }
                MicroOp::Fill { vd, rd, addr, len } => {
                    self.machine.exec_fill(*vd, *rd, addr.wrapping_add(delta), *len)
                }
                MicroOp::Copy { rs, src, rd, dst, vd, len } => self.machine.exec_copy(
                    *rs,
                    src.wrapping_add(delta),
                    *rd,
                    dst.wrapping_add(delta),
                    *vd,
                    *len,
                ),
                MicroOp::LoadUnit { rd, addr, vd, len } => {
                    self.machine.exec_load_unit(*rd, addr.wrapping_add(delta), *vd, *len)
                }
                MicroOp::StoreUnit { rd, addr, vs3, len } => {
                    self.machine.exec_store_unit(*rd, addr.wrapping_add(delta), *vs3, *len)
                }
                MicroOp::PlaneMac { vl, t1, tmp, taps } => {
                    self.machine.exec_plane_mac(*vl, *t1, *tmp, taps)
                }
                MicroOp::BitpackFast { vd, vs2, bit, vl, eb } => {
                    self.machine.exec_bitpack_host(*vd, *vs2, *bit, *vl, *eb)
                }
                MicroOp::MaccByte { a0, addr, t1, vd, vs2, vl, eb } => self.machine.exec_macc_byte(
                    *a0,
                    addr.wrapping_add(delta),
                    *t1,
                    *vd,
                    *vs2,
                    *vl,
                    *eb,
                ),
                MicroOp::RowSum(rs) => self.machine.exec_row_sum(rs, delta),
            }
        }
    }

    /// Replay the decode-once lowering for a whole batch of inputs: the
    /// serving batch axis. The arena is prepared **once** — one
    /// [`Sim::begin_replay`] applies the init image (weights, requant
    /// tables, constants) once for all elements — then per element the
    /// input segment is rebound, the fused micro-ops run, and the output
    /// segment is harvested before the next element's pass overwrites the
    /// shared scratch.
    ///
    /// Legality rests on the compiled program's structure (see
    /// `docs/architecture.md`, "Batched replay" and "Static verification"):
    /// the trace never writes image regions, the input segment is fully
    /// rewritten per element, and scratch is written before read within one
    /// pass — so element `k`'s leftovers are invisible to element `k + 1`,
    /// and every element's output is bit-identical to a standalone
    /// [`Sim::execute_lowered`] call. `rust/tests/batching.rs` holds the
    /// differential proof across the model zoo.
    ///
    /// Cross-request isolation is enforced in **every** build profile: when
    /// the static verifier proved the read-only-image property
    /// ([`CompiledProgram::verify_report`],
    /// [`crate::program::VerifyReport::batch_safe`]) the per-element image
    /// scan is skipped in release (debug builds keep it as an oracle for the
    /// proof itself); an unproven program pays the always-on scan instead of
    /// silently losing the guarantee.
    ///
    /// Like `execute_lowered`, no timing scoreboard runs — per-request
    /// cycles come from the serving layer's timing cache.
    pub fn execute_lowered_batch(
        &mut self,
        prog: &CompiledProgram,
        base: u64,
        inputs: &[&[u8]],
    ) -> BatchRun {
        let delta = self.begin_replay(prog, base, None);
        let out_addr = prog.out_addr.wrapping_add(delta);
        let out_len = prog.output_bytes();
        let proven = prog.verify_report().batch_safe();
        let mut outputs = Vec::with_capacity(inputs.len());
        for input in inputs {
            self.write_request_input(prog, delta, input);
            self.run_lowered_ops(prog, delta);
            outputs.push(self.machine.copy_region(out_addr, out_len));
            if cfg!(debug_assertions) || !proven {
                self.assert_image_intact(prog, delta);
            }
        }
        BatchRun { out_addr, out_elems: prog.out_elems, outputs }
    }

    /// Guard for the batched-replay contract: after an element's pass,
    /// every image chunk outside the input segment must still hold its
    /// image bytes (the trace treats weights/requant/constants as
    /// read-only, so one image application serves the whole batch). Runs
    /// per element in debug builds as the oracle for the verifier's
    /// batch-safety proof, and in release builds whenever the proof is
    /// absent.
    fn assert_image_intact(&self, prog: &CompiledProgram, delta: u64) {
        let in_lo = prog.input.addr;
        let in_hi = in_lo + prog.input.elems as u64 * if prog.input.fp32 { 4 } else { 1 };
        for (addr, bytes) in &prog.image {
            let (lo, hi) = (*addr, *addr + bytes.len() as u64);
            if lo < in_hi && in_lo < hi {
                continue; // the input segment is rebound per element
            }
            assert_eq!(
                self.machine.mem.read(addr.wrapping_add(delta), bytes.len()),
                &bytes[..],
                "batched replay contract violated: trace overwrote image bytes at {addr:#x}"
            );
        }
    }
}

/// One batched lowered replay: what [`Sim::execute_lowered_batch`] returns.
/// Output bytes are harvested per element because the batch shares one
/// arena — element `k + 1`'s pass overwrites the scratch and output
/// segments element `k` wrote.
pub struct BatchRun {
    /// Replay-space address of the output segment (compile-space `out_addr`
    /// plus the relocation delta).
    pub out_addr: u64,
    /// Elements in the output segment (the class count for classifiers).
    pub out_elems: usize,
    /// Raw output-segment bytes per batch element, in input order: one u8
    /// activation code per element for integer programs, four little-endian
    /// f32 bytes per element when [`CompiledProgram::is_fp32`].
    pub outputs: Vec<Vec<u8>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::coordinator::demo_net;
    use crate::nn::golden::run_golden;
    use crate::nn::model::{Precision, PrecisionMap};
    use crate::program::compile;

    fn w2a2() -> PrecisionMap {
        PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true })
    }

    #[test]
    fn lowered_matches_functional_and_golden_on_demo_net() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let prog = compile(&net, &quark, &w2a2()).unwrap();
        let input: Vec<u8> =
            (0..prog.input_elems()).map(|i| ((i * 7 + 3) % 251) as u8).collect();
        let golden = run_golden(&net, prog.schedule(), Some(&input));
        let mut f = Sim::with_memory(quark.clone(), 64 << 20);
        let fb = f.alloc(prog.mem_len());
        let fr = f.execute_functional(&prog, fb, Some(&input));
        let mut l = Sim::with_memory(quark.clone(), 64 << 20);
        let lb = l.alloc(prog.mem_len());
        let lr = l.execute_lowered(&prog, lb, Some(&input));
        assert_eq!(
            l.read_u8s(lr.out_addr, lr.out_elems),
            f.read_u8s(fr.out_addr, fr.out_elems),
            "lowered vs functional logits"
        );
        assert_eq!(l.read_u8s(lr.out_addr, lr.out_elems), golden.maps[net.len()]);
        for (i, r) in lr.reports.iter().enumerate() {
            assert_eq!(
                l.read_u8s(r.out_addr, r.out_elems),
                golden.maps[i + 1],
                "layer {} map",
                r.name
            );
        }
        // Stronger than logits: identical scalar state, vl/vtype, and the
        // entire program memory footprint.
        assert_eq!(l.machine.x, f.machine.x, "scalar register file");
        assert_eq!(l.machine.vl, f.machine.vl);
        assert_eq!(l.machine.vtype, f.machine.vtype);
        assert_eq!(
            l.machine.mem.read(lb, prog.mem_len() as usize),
            f.machine.mem.read(fb, prog.mem_len() as usize),
            "program memory footprint"
        );
    }

    #[test]
    fn batched_replay_matches_independent_singles() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let prog = compile(&net, &quark, &w2a2()).unwrap();
        let inputs: Vec<Vec<u8>> = (0..2)
            .map(|k| {
                (0..prog.input_elems()).map(|i| ((i * 7 + 3 + k * 53) % 251) as u8).collect()
            })
            .collect();
        let refs: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut b = Sim::with_memory(quark.clone(), 64 << 20);
        let bb = b.alloc(prog.mem_len());
        let run = b.execute_lowered_batch(&prog, bb, &refs);
        assert_eq!(run.outputs.len(), 2);
        assert_eq!(run.out_elems, prog.out_elems());
        for (k, input) in inputs.iter().enumerate() {
            let mut s = Sim::with_memory(quark.clone(), 64 << 20);
            let sb = s.alloc(prog.mem_len());
            let sr = s.execute_lowered(&prog, sb, Some(input));
            assert_eq!(
                run.outputs[k],
                s.read_u8s(sr.out_addr, sr.out_elems),
                "batch element {k} vs an independent single-request replay"
            );
        }
    }

    #[test]
    fn lowering_covers_the_hot_trace() {
        let net = demo_net();
        let prog = compile(&net, &MachineConfig::quark(4), &w2a2()).unwrap();
        let low = prog.lowered();
        assert_eq!(low.fused_instrs() + low.interp_instrs(), prog.trace_len());
        assert!(
            low.fused_fraction() > 0.5,
            "w2a2 trace should lower mostly into fused kernels, got {:.3}",
            low.fused_fraction()
        );
        assert!(
            low.micro_ops() < prog.trace_len() / 2,
            "lowering should shrink the step count ({} steps for {} instrs)",
            low.micro_ops(),
            prog.trace_len()
        );
    }

    #[test]
    fn lowering_is_deterministic_and_cached() {
        let net = demo_net();
        let prog = compile(&net, &MachineConfig::quark(4), &w2a2()).unwrap();
        let a = lower(&prog, prog.vlen_bits);
        let b = lower(&prog, prog.vlen_bits);
        assert_eq!(a.ops, b.ops, "lowering must be deterministic");
        assert_eq!(a.fused_instrs, b.fused_instrs);
        let p1: *const LoweredProgram = prog.lowered();
        let p2: *const LoweredProgram = prog.lowered();
        assert_eq!(p1, p2, "OnceLock must cache the lowering");
    }
}
