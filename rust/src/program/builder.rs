//! [`ProgramBuilder`] and the single model-emission routine.
//!
//! `emit_model` (crate-internal) is the one place in the codebase that
//! walks a network graph and drives the kernels — the emission loop
//! previously duplicated inside `ModelRunner`. It has exactly two
//! consumers:
//!
//! * the **live path** ([`crate::nn::model::ModelRunner`]): kernels emit
//!   into a normal [`Sim`], which simulates (functionally and/or in time)
//!   as it always has;
//! * the **compile path** ([`ProgramBuilder`], via
//!   [`super::compile`]): kernels emit into a *recording* `Sim`, which
//!   captures the trace/relocations/image instead of simulating, producing
//!   a [`CompiledProgram`] for later replay.
//!
//! Synthetic parameters are drawn from one deterministic stream (a function
//! of the schedule family only) — the same stream the naive-i128 host
//! golden model ([`crate::nn::golden`]) draws, which is what makes the
//! layer-by-layer bit-exact differentials possible.
//!
//! ## Tensor-parallel shard emission
//!
//! With a [`ShardPlan`] slice active ([`super::compile_shard`]), every
//! Conv/FC layer computes only its shard's output-channel range: the kernel
//! runs with a *narrowed* `c_out`, writing a packed partial map, and a
//! full-size gather buffer is allocated for the inter-core all-gather the
//! cluster runtime performs between layers ([`crate::cluster`]). Two rules
//! keep shard programs bit-identical to the single-core emission:
//!
//! * **full-stream draw** — synthetic weights and requant parameters are
//!   always drawn at the layer's *full* channel count and column-sliced, so
//!   shard `k`'s channel `c` sees exactly the values the single-core run
//!   gives channel `c` (and the deterministic seed advances identically);
//! * **consumers read gathers** — the feature-map list advances with the
//!   *gather* address, so every downstream layer (including residual
//!   sources) is emitted against full maps, exactly as on one core.

use crate::arch::MachineConfig;
use crate::kernels::bitpack::setup_index_vector;
use crate::kernels::conv2d::{bitserial_block, conv2d_bitserial, conv2d_f32, conv2d_int8};
use crate::kernels::matmul::{matmul_bitserial, matmul_f32, matmul_int8};
use crate::kernels::pool::{global_avgpool_f32, global_avgpool_u8};
use crate::kernels::requantize::RqBuf;
use crate::kernels::Conv2dParams;
use crate::nn::graph::INPUT_ELEMS;
use crate::nn::model::{
    grid_qmax, map_consumer_bits, synth_codes, synth_f32, synth_i8, synth_input, synth_rq_params,
    LayerReport, Precision, PrecisionMap, ShardPlan, StagePlan,
};
use crate::nn::{LayerKind, NetGraph, NetLayer};
use crate::quant::pack_weight_planes;
use crate::sim::Sim;

use super::{CompiledProgram, InputSpec, LayerMark, ShardSeg};

/// Everything [`emit_model`] reports back about one emission pass.
pub(crate) struct EmittedModel {
    pub reports: Vec<LayerReport>,
    /// Per-layer exclusive trace end indices (all zero on a live, i.e.
    /// non-recording, `Sim`).
    pub trace_ends: Vec<usize>,
    /// Address/size of feature map 0 (the network input).
    pub in_addr: u64,
    pub input_elems: usize,
    /// Clamp grid applied to input codes (narrowest-consumer re-pack rule).
    pub in_qmax: u8,
    /// Uniform-fp32 schedule (input stored as normalized floats).
    pub fp32: bool,
    /// Address/size of the final feature map (the logits).
    pub out_addr: u64,
    pub out_elems: usize,
    /// Per-layer shard segments; populated iff a shard slice was active.
    pub shard_segs: Vec<ShardSeg>,
}

/// Builds [`CompiledProgram`]s: owns a recording [`Sim`] sized like a
/// serving core and funnels the shared `emit_model` routine through it.
pub struct ProgramBuilder {
    sim: Sim,
}

impl ProgramBuilder {
    /// A builder for `machine`. Allocates its own simulated memory arena
    /// (the default serving-core size) — compilation is a cold-path,
    /// once-per-deployment operation.
    pub fn new(machine: MachineConfig) -> Self {
        let mut sim = Sim::with_memory(machine, Sim::DEFAULT_MEM);
        sim.start_recording();
        ProgramBuilder { sim }
    }

    /// Emit `net` under `schedule` and package the recording. The schedule
    /// must already be validated (see [`super::compile`], which is the
    /// checked entry point); invalid schedules panic exactly like the live
    /// runner.
    pub fn build(self, net: &NetGraph, schedule: &PrecisionMap) -> CompiledProgram {
        self.build_inner(net, schedule, None, None)
    }

    /// Emit one shard of a tensor-parallel deployment (see
    /// [`super::compile_shard`], the checked entry point).
    pub(crate) fn build_sharded(
        self,
        net: &NetGraph,
        schedule: &PrecisionMap,
        plan: &ShardPlan,
        shard: usize,
    ) -> CompiledProgram {
        self.build_inner(net, schedule, Some((plan, shard)), None)
    }

    /// Emit one stage of a pipeline-parallel deployment (see
    /// [`super::compile_stage`], the checked entry point).
    pub(crate) fn build_staged(
        self,
        net: &NetGraph,
        schedule: &PrecisionMap,
        plan: &StagePlan,
        stage: usize,
    ) -> CompiledProgram {
        self.build_inner(net, schedule, None, Some((plan, stage)))
    }

    fn build_inner(
        mut self,
        net: &NetGraph,
        schedule: &PrecisionMap,
        shard: Option<(&ShardPlan, usize)>,
        stage: Option<(&StagePlan, usize)>,
    ) -> CompiledProgram {
        let base = self.sim.machine.mem.brk();
        let emitted = emit_model(&mut self.sim, net, schedule, None, shard, stage);
        let mem_len = self.sim.machine.mem.brk() - base;
        let rec = self.sim.take_recording();
        let layers = emitted
            .reports
            .iter()
            .zip(emitted.trace_ends.iter())
            .map(|(r, &trace_end)| LayerMark {
                name: r.name.clone(),
                precision: r.precision,
                quantized: r.quantized,
                out_addr: r.out_addr,
                out_elems: r.out_elems,
                macs: r.run.macs,
                // During recording no timing runs, so the only stat a layer
                // accrues is what its kernel credited host-side — exactly
                // the amount a replay must re-credit.
                credited_macs: r.stats.effective_macs,
                trace_end,
            })
            .collect();
        CompiledProgram {
            net_fp: net.fingerprint(),
            machine_fp: super::machine_fingerprint(&self.sim.cfg),
            model_name: net.name().to_string(),
            machine_name: self.sim.cfg.name.clone(),
            schedule: schedule.clone(),
            base,
            mem_len,
            trace: rec.trace,
            reloc: rec.reloc,
            image: rec.image,
            input: InputSpec {
                addr: emitted.in_addr,
                elems: emitted.input_elems,
                qmax: emitted.in_qmax,
                fp32: emitted.fp32,
            },
            out_addr: emitted.out_addr,
            out_elems: emitted.out_elems,
            layers,
            shard: shard.map(|(plan, idx)| (idx, plan.shards())),
            stage: stage.map(|(plan, idx)| {
                let (lo, hi) = plan.range(idx);
                super::StageInfo { index: idx, count: plan.stages(), lo, hi }
            }),
            shard_segs: emitted.shard_segs,
            vlen_bits: self.sim.cfg.vlen_bits,
            lowered: std::sync::OnceLock::new(),
            verify: std::sync::OnceLock::new(),
        }
    }
}

/// Select output-channel columns `[c0, c1)` of a row-major `[K][N]` matrix —
/// the tensor-parallel weight split. Values are *identical* to the
/// single-core draw for the same channels, by construction.
fn slice_cols<T: Copy>(w: &[T], n: usize, c0: usize, c1: usize) -> Vec<T> {
    w.chunks(n).flat_map(|row| row[c0..c1].iter().copied()).collect()
}

/// THE model-emission routine: materialize `net` in simulated memory and
/// emit every layer through the kernel matching its resolved [`Precision`].
/// Synthetic weights/requant parameters come from the deterministic stream;
/// `input` (CIFAR-sized u8 codes; shorter zero-padded, longer truncated)
/// overrides the synthetic network input when given. On a live
/// `TimingOnly` sim, tensor data is neither synthesized nor written (the
/// cycle model is data-independent — the historical fast path for timing
/// sweeps); recording and `Full`-mode sims always materialize it.
///
/// `shard` activates tensor-parallel shard emission (recording sims only —
/// a live sim could not perform the inter-layer all-gather).
///
/// `stage` activates pipeline-parallel stage emission (also recording sims
/// only, and mutually exclusive with `shard`): only the plan's layer range
/// `[lo, hi)` is emitted, with the stage's *input segment* standing in for
/// feature map `lo` (the previous stage's output, written per request by
/// the pipeline runtime — [`crate::cluster::pipeline`]). Bit-exactness
/// against the single-core emission rests on two rules: the deterministic
/// parameter stream is advanced over the *skipped* prefix layers exactly as
/// if they had been emitted (so in-range layers draw identical weights),
/// and requant grids come from [`map_consumer_bits`] over the *full* net
/// (so the upstream stage already clamped the hand-off activation onto this
/// stage's consumer grid — the input-segment clamp is a no-op).
///
/// Panics on schedules that fail [`PrecisionMap::validate`] /
/// [`PrecisionMap::validate_machine`] — the serving layer pre-validates at
/// submission, and [`super::compile`] validates before building.
pub(crate) fn emit_model(
    sim: &mut Sim,
    net: &[NetLayer],
    schedule: &PrecisionMap,
    input: Option<&[u8]>,
    shard: Option<(&ShardPlan, usize)>,
    stage: Option<(&StagePlan, usize)>,
) -> EmittedModel {
    if let Err(e) = schedule.validate(net) {
        panic!("invalid schedule: {e}");
    }
    if let Err(e) = schedule.validate_machine(net, &sim.cfg) {
        panic!("{e}");
    }
    if let Some((plan, _)) = shard {
        assert!(
            plan.shards() == 1 || sim.is_recording(),
            "sharded emission requires a recording Sim (the gather is host-driven)"
        );
    }
    if let Some((plan, idx)) = stage {
        assert!(
            shard.is_none(),
            "tensor sharding and pipeline staging cannot combine in one emission"
        );
        assert!(
            sim.is_recording(),
            "staged emission requires a recording Sim (stage programs exist to be replayed)"
        );
        assert!(idx < plan.stages(), "stage {idx} out of range (plan has {})", plan.stages());
        assert_eq!(plan.layers(), net.len(), "stage plan derived for a different net");
    }
    let (stage_lo, stage_hi) = stage.map(|(p, i)| p.range(i)).unwrap_or((0, net.len()));
    let resolved = schedule.resolve(net);
    let consumer_bits = map_consumer_bits(net, &resolved);
    let fp32 = schedule.default_precision() == Precision::Fp32;
    let esz = if fp32 { 4usize } else { 1 };
    // Whether tensor data must actually be materialized: always when
    // recording (the program's init image) or executing functionally; a
    // live `TimingOnly` sweep skips the synthesis and writes — the cycle
    // model is data-independent, so timing-only callers (reports, cache
    // baselines) keep their historical cost.
    let write_data = sim.is_recording() || sim.mode() == crate::sim::SimMode::Full;
    let idx_vec = setup_index_vector(sim);
    let mut seed = 0xC0FFEE ^ schedule.seed_tag();

    // Feature-map addresses; map 0 is the shared CIFAR-sized input plane
    // every model reads a prefix of ([`crate::nn::graph::INPUT_ELEMS`]).
    // A stage program starting at layer `lo > 0` substitutes map `lo` (the
    // hand-off activation) as its input segment instead.
    let input_elems = if stage_lo == 0 { INPUT_ELEMS } else { map_elems(net, stage_lo) };
    let in_qmax = grid_qmax(consumer_bits[stage_lo]) as u8;
    let in_addr = sim.alloc((input_elems * esz) as u64);
    if write_data {
        // Draw the synthetic input even when an explicit one overrides it
        // (or, for a non-first stage, replaces it entirely), so the weight
        // streams below are identical either way.
        let mut codes = synth_input(&mut seed, INPUT_ELEMS);
        if stage_lo == 0 {
            if let Some(bytes) = input {
                for (i, c) in codes.iter_mut().enumerate() {
                    *c = bytes.get(i).copied().unwrap_or(0);
                }
            }
            if fp32 {
                let vals: Vec<f32> = codes.iter().map(|&c| c as f32 / 255.0).collect();
                sim.write_f32s(in_addr, &vals);
            } else {
                for c in codes.iter_mut() {
                    *c = (*c).min(in_qmax);
                }
                sim.write_bytes(in_addr, &codes);
            }
        } else {
            // The stage input is runtime-provided (the previous stage's
            // output); record a zeroed segment so replay starts defined.
            sim.write_bytes(in_addr, &vec![0u8; input_elems]);
            // Skip-ahead: draw and discard the weights of every layer
            // before `lo`, keeping the deterministic stream aligned with
            // the single-core emission.
            for (li, layer) in net.iter().enumerate().take(stage_lo) {
                skip_layer_draw(&mut seed, layer, resolved[li]);
            }
        }
    }
    // maps[0..stage_lo] are owned by upstream stages and never read here
    // (the stage-plan cut rule guarantees it); poison them so a violation
    // fails loudly.
    let mut maps: Vec<u64> = vec![u64::MAX; stage_lo];
    maps.push(in_addr);
    let mut reports = Vec::new();
    let mut trace_ends = Vec::new();
    let mut shard_segs = Vec::new();

    for (li, layer) in net.iter().enumerate().take(stage_hi).skip(stage_lo) {
        let input_addr = maps[layer.input];
        debug_assert_ne!(input_addr, u64::MAX, "stage reads a map owned by an upstream stage");
        let residual = layer.residual_from.map(|i| maps[i]);
        debug_assert_ne!(
            residual.unwrap_or(0),
            u64::MAX,
            "stage residual reads a map owned by an upstream stage"
        );
        let lp = resolved[li];
        let out_qmax = grid_qmax(consumer_bits[li + 1]) as f32;
        // Tensor-parallel slice of this layer, when a plan is active.
        let srange = shard.and_then(|(plan, idx)| plan.range(li, idx));
        let before = sim.stats().clone();
        let (out_addr, out_elems, name, run, quantized, seg) = match &layer.kind {
            LayerKind::Conv(c) => {
                let pf = c.params;
                let positions = pf.out_h() * pf.out_w();
                let n_full = pf.c_out;
                let (c0, c1) = srange.unwrap_or((0, n_full));
                let nk = c1 - c0;
                let p = Conv2dParams { c_out: nk, ..pf };
                let out = sim.alloc((positions * nk * esz) as u64);
                // Residual source maps are full gathered maps; a sharded
                // layer reads its channel slice through a runtime-filled
                // slice buffer (kernels index residuals at their own,
                // narrowed, channel stride).
                let mut res_slice = None;
                let res_addr = if c.residual {
                    match (residual, srange) {
                        (Some(_), Some(_)) => {
                            let buf = sim.alloc((positions * nk) as u64);
                            res_slice = Some((layer.residual_from.unwrap(), buf));
                            Some(buf)
                        }
                        (r, _) => r,
                    }
                } else {
                    None
                };
                let k = p.k();
                let run = match lp {
                    Precision::Fp32 => {
                        debug_assert!(srange.is_none(), "fp32 schedules cannot shard");
                        let w = sim.alloc((k * n_full * 4) as u64);
                        let b = sim.alloc((n_full * 4) as u64);
                        if write_data {
                            let wv = synth_f32(&mut seed, k * n_full);
                            sim.write_f32s(w, &wv);
                            sim.write_f32s(b, &vec![0.01; n_full]);
                        }
                        conv2d_f32(sim, &p, input_addr, w, b, out, c.relu, res_addr)
                    }
                    Precision::Int8 => {
                        // Also the unquantized stem under every integer
                        // schedule (PrecisionMap::resolve pins it).
                        let w = sim.alloc((k * nk) as u64);
                        if write_data {
                            let wv = synth_i8(&mut seed, k * n_full);
                            sim.write_i8(w, &slice_cols(&wv, n_full, c0, c1));
                        }
                        let rq = rqbuf(sim, n_full, k, out_qmax, (c0, c1));
                        conv2d_int8(sim, &p, input_addr, w, &rq, out, res_addr)
                    }
                    Precision::Sub { abits, wbits, use_vbitpack } => {
                        let codes: Vec<u8> = if write_data {
                            let full = synth_codes(&mut seed, k * n_full, wbits);
                            slice_cols(&full, n_full, c0, c1)
                        } else {
                            vec![0u8; k * nk]
                        };
                        let block = bitserial_block(sim.cfg.vlen_bits, nk);
                        let wpk = pack_weight_planes(&codes, k, nk, wbits, block);
                        let w = sim.alloc(wpk.byte_len() as u64);
                        if write_data {
                            sim.write_u64s(w, &wpk.words);
                        }
                        let rq = rqbuf(sim, n_full, k, out_qmax, (c0, c1));
                        conv2d_bitserial(
                            sim, &p, abits, input_addr, &wpk, w, &rq, out, res_addr,
                            use_vbitpack, idx_vec,
                        )
                    }
                };
                // Consumers (and residual readers) see the full map: the
                // gather buffer on sharded layers, the kernel output itself
                // otherwise.
                let (full_addr, seg) = match srange {
                    Some(_) => {
                        let gather = sim.alloc((positions * n_full * esz) as u64);
                        let seg = ShardSeg {
                            channels: srange,
                            c_full: n_full,
                            positions,
                            part_addr: out,
                            gather_addr: gather,
                            res_slice,
                        };
                        (gather, seg)
                    }
                    None => (out, ShardSeg::replicated(out, n_full, positions)),
                };
                (full_addr, positions * n_full, c.name.clone(), run, c.quantized, seg)
            }
            LayerKind::AvgPool { h, w, c } => {
                // Pooling runs replicated on every shard: its input is a
                // full gathered map, so each core derives the identical
                // pooled vector with no exchange.
                let out = sim.alloc((c * esz) as u64);
                let run = if fp32 {
                    global_avgpool_f32(sim, *h, *w, *c, input_addr, out)
                } else {
                    let alpha = 1.0 / (*h * *w) as f32;
                    let rq = RqBuf::create(
                        sim,
                        &vec![alpha; *c],
                        &vec![0.0; *c],
                        &vec![0.0; *c],
                        out_qmax,
                        0.0,
                    );
                    global_avgpool_u8(sim, *h, *w, *c, input_addr, &rq, out)
                };
                (out, *c, "avgpool".to_string(), run, false, ShardSeg::replicated(out, *c, 1))
            }
            LayerKind::Fc { k, n, name } => {
                let (c0, c1) = srange.unwrap_or((0, *n));
                let nk = c1 - c0;
                let out = sim.alloc((nk.max(64) * esz) as u64);
                let run = match lp {
                    Precision::Fp32 => {
                        debug_assert!(srange.is_none(), "fp32 schedules cannot shard");
                        let w = sim.alloc((k * n * 4) as u64);
                        let b = sim.alloc((n * 4) as u64);
                        if write_data {
                            let wv = synth_f32(&mut seed, k * n);
                            sim.write_f32s(w, &wv);
                            sim.write_f32s(b, &vec![0.01; *n]);
                        }
                        matmul_f32(sim, 1, *k, *n, input_addr, w, b, out, false)
                    }
                    Precision::Int8 => {
                        let w = sim.alloc((k * nk) as u64);
                        if write_data {
                            let wv = synth_i8(&mut seed, k * n);
                            sim.write_i8(w, &slice_cols(&wv, *n, c0, c1));
                        }
                        let rq = rqbuf(sim, *n, *k, out_qmax, (c0, c1));
                        matmul_int8(sim, 1, *k, nk, input_addr, w, &rq, out)
                    }
                    Precision::Sub { abits, wbits, use_vbitpack } => {
                        let codes: Vec<u8> = if write_data {
                            let full = synth_codes(&mut seed, k * n, wbits);
                            slice_cols(&full, *n, c0, c1)
                        } else {
                            vec![0u8; k * nk]
                        };
                        let block = bitserial_block(sim.cfg.vlen_bits, nk);
                        let wpk = pack_weight_planes(&codes, *k, nk, wbits, block);
                        let w = sim.alloc(wpk.byte_len() as u64);
                        if write_data {
                            sim.write_u64s(w, &wpk.words);
                        }
                        let rq = rqbuf(sim, *n, *k, out_qmax, (c0, c1));
                        matmul_bitserial(
                            sim, 1, *k, nk, abits, input_addr, &wpk, w, &rq, out,
                            use_vbitpack, idx_vec,
                        )
                    }
                };
                let (full_addr, seg) = match srange {
                    Some(_) => {
                        let gather = sim.alloc((*n * esz) as u64);
                        let seg = ShardSeg {
                            channels: srange,
                            c_full: *n,
                            positions: 1,
                            part_addr: out,
                            gather_addr: gather,
                            res_slice: None,
                        };
                        (gather, seg)
                    }
                    None => (out, ShardSeg::replicated(out, *n, 1)),
                };
                (full_addr, *n, name.clone(), run, true, seg)
            }
        };
        maps.push(out_addr);
        let stats = sim.stats().delta_since(&before);
        reports.push(LayerReport {
            name,
            quantized,
            precision: lp,
            out_addr,
            out_elems,
            run,
            stats,
        });
        trace_ends.push(sim.trace_len());
        if shard.is_some() {
            shard_segs.push(seg);
        }
    }
    let (final_addr, final_elems) = reports
        .last()
        .map(|r| (r.out_addr, r.out_elems))
        .unwrap_or((in_addr, input_elems));
    EmittedModel {
        reports,
        trace_ends,
        in_addr,
        input_elems,
        in_qmax,
        fp32,
        out_addr: final_addr,
        out_elems: final_elems,
        shard_segs,
    }
}

/// Allocate the synthetic requant parameter block
/// ([`synth_rq_params`]) with the consumer-grid clamp `qmax` (the re-pack
/// rule). Parameters are synthesized at the layer's *full* channel count and
/// sliced to `[c0, c1)`, so shard programs see exactly the single-core
/// per-channel scales.
fn rqbuf(sim: &mut Sim, n_full: usize, k: usize, qmax: f32, (c0, c1): (usize, usize)) -> RqBuf {
    let (alphas, betas, biases) = synth_rq_params(n_full, k);
    RqBuf::create(sim, &alphas[c0..c1], &betas[c0..c1], &biases[c0..c1], qmax, 0.0)
}

/// Logical element count of feature map `idx` (map 0 is the network input;
/// layer `i` writes map `i + 1`) — the size of a pipeline stage's hand-off
/// activation.
fn map_elems(net: &[NetLayer], idx: usize) -> usize {
    if idx == 0 {
        return INPUT_ELEMS;
    }
    match &net[idx - 1].kind {
        LayerKind::Conv(c) => c.params.out_h() * c.params.out_w() * c.params.c_out,
        LayerKind::AvgPool { c, .. } => *c,
        LayerKind::Fc { n, .. } => *n,
    }
}

/// Advance the deterministic parameter stream over one *skipped* layer of a
/// stage emission: draw (and discard) exactly the values the layer's kernel
/// path would have drawn, so downstream layers see the single-core stream.
/// Pooling draws nothing; requant parameters ([`synth_rq_params`]) are
/// seedless and need no skip.
fn skip_layer_draw(seed: &mut u64, layer: &NetLayer, precision: Precision) {
    let (k, n) = match &layer.kind {
        LayerKind::Conv(c) => (c.params.k(), c.params.c_out),
        LayerKind::Fc { k, n, .. } => (*k, *n),
        LayerKind::AvgPool { .. } => return,
    };
    match precision {
        Precision::Fp32 => {
            let _ = synth_f32(seed, k * n);
        }
        Precision::Int8 => {
            let _ = synth_i8(seed, k * n);
        }
        Precision::Sub { wbits, .. } => {
            let _ = synth_codes(seed, k * n, wbits);
        }
    }
}
