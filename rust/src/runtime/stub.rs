//! Offline stand-in for the PJRT runtime (built when the `pjrt` feature is
//! off). Same API as `executable.rs`; every entry point reports that the
//! golden-model backend is unavailable in this build. Integration tests
//! already skip when `artifacts/` is missing, so a fresh offline checkout
//! stays green.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::Result;

const UNAVAILABLE: &str = "PJRT runtime not built: enable the `pjrt` cargo feature \
     (requires a vendored `xla` crate) to run golden-model cross-checks";

/// A compiled AOT artifact (one HLO module → one PJRT executable).
pub struct Artifact {
    /// Path the HLO text was loaded from (for diagnostics).
    pub path: PathBuf,
}

impl Artifact {
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn run_i32(&self, _inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn run_i32_to_f32(&self, _inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

/// PJRT runtime stub: construction always fails with a clear message.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load(&self, _path: impl AsRef<Path>) -> Result<Arc<Artifact>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = Runtime::cpu().err().expect("stub must not construct");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
