//! PJRT golden-model runtime.
//!
//! Loads AOT artifacts produced by `python/compile/aot.py` (HLO **text**, the
//! interchange format that round-trips through xla_extension 0.5.1 — see
//! DESIGN.md) and executes them on the PJRT CPU client via the `xla` crate.
//!
//! This is the only place Python-produced bits enter the Rust process, and it
//! happens at load time: the request path never touches Python.

mod executable;

pub use executable::{Artifact, Runtime};
