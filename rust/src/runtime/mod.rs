//! PJRT golden-model runtime.
//!
//! Loads AOT artifacts produced by `python/compile/aot.py` (HLO **text**, the
//! interchange format that round-trips through xla_extension 0.5.1 — see
//! DESIGN.md) and executes them on the PJRT CPU client via the `xla` crate.
//!
//! This is the only place Python-produced bits enter the Rust process, and it
//! happens at load time: the request path never touches Python.
//!
//! The `xla` crate is not vendorable in the offline build environment, so the
//! real implementation is gated behind the `pjrt` cargo feature (which also
//! requires adding `xla` to `[dependencies]`). Without it, [`stub`] provides
//! the same API surface: `Runtime::cpu()` returns an error explaining the
//! situation, and every golden-artifact consumer (tests, `repro crosscheck`)
//! degrades to a skip/diagnostic instead of a build failure.

#[cfg(feature = "pjrt")]
mod executable;
#[cfg(feature = "pjrt")]
pub use executable::{Artifact, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Artifact, Runtime};
