//! Thin safe wrapper over the `xla` crate's PJRT client.
//!
//! One [`Runtime`] owns the PJRT CPU client; each [`Artifact`] is a compiled
//! executable loaded from an HLO text file. Executables are compiled once and
//! cached by path, so the coordinator's hot path only pays `execute`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::anyhow;
use crate::error::{Context, Result};

/// A compiled AOT artifact (one HLO module → one PJRT executable).
pub struct Artifact {
    /// Path the HLO text was loaded from (for diagnostics).
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with `f32` inputs (each tensor given as flat data + dims) and
    /// return all outputs flattened to `f32` vectors.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the PJRT output is a
    /// single tuple literal which we unpack here.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims64)
                    .context("reshape input literal")
            })
            .collect::<Result<Vec<_>>>()?;
        let tuple = self.execute(&lits)?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("decompose output tuple: {e}"))?
            .into_iter()
            .map(|l| {
                let l = l.convert(xla::PrimitiveType::F32).map_err(|e| anyhow!("{e}"))?;
                l.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
            })
            .collect()
    }

    /// Execute with `i32` inputs, returning `i32` outputs. Used for the
    /// integer-exact cross-check between the simulated bit-serial kernels and
    /// the JAX golden model.
    pub fn run_i32(&self, inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims64)
                    .context("reshape input literal")
            })
            .collect::<Result<Vec<_>>>()?;
        let tuple = self.execute(&lits)?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("decompose output tuple: {e}"))?
            .into_iter()
            .map(|l| {
                let l = l.convert(xla::PrimitiveType::S32).map_err(|e| anyhow!("{e}"))?;
                l.to_vec::<i32>().map_err(|e| anyhow!("{e}"))
            })
            .collect()
    }

    /// Execute with `i32` inputs, returning `f32` outputs (e.g. the qnet
    /// artifact: integer activation codes in, logits out).
    pub fn run_i32_to_f32(&self, inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims64)
                    .context("reshape input literal")
            })
            .collect::<Result<Vec<_>>>()?;
        let tuple = self.execute(&lits)?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("decompose output tuple: {e}"))?
            .into_iter()
            .map(|l| {
                let l = l.convert(xla::PrimitiveType::F32).map_err(|e| anyhow!("{e}"))?;
                l.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
            })
            .collect()
    }

    fn execute(&self, lits: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(lits)
            .with_context(|| format!("execute artifact {}", self.path.display()))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetch output literal")?;
        Ok(lit)
    }
}

/// PJRT runtime: owns the CPU client and a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Artifact>>>,
}

impl Runtime {
    /// Create a PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Human-readable platform string (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact, memoized by path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Artifact>> {
        let path = path.as_ref().to_path_buf();
        if let Some(a) = self.cache.lock().unwrap().get(&path) {
            return Ok(a.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        let artifact = std::sync::Arc::new(Artifact { path: path.clone(), exe });
        self.cache.lock().unwrap().insert(path, artifact.clone());
        Ok(artifact)
    }
}
