//! Regenerators for every table and figure in the paper's evaluation:
//!
//! | paper artifact | module | regenerates |
//! |---|---|---|
//! | Fig. 3 | [`fig3`] | per-layer ResNet-18 speedups, Quark Int1/Int2 (±vbitpack) vs Ara Int8/FP32 |
//! | Fig. 4 | [`fig4`] | conv2d 3×3 roofline, Quark-8L vs Ara-4L |
//! | Table I | [`table1`] | LSQ accuracy/size table (consumes the Python run's TSV) |
//! | Table II | [`table2`] | physical implementation table from the tech model |
//! | Fig. 5 | [`table2`] (`fig5_markdown`) | per-lane area breakdown |
//! | headline claims | [`summary`] | 5.7×/3.5× speedups, 2.3×/1.9× lane ratios |
//! | — (beyond the paper) | [`mixed`] | per-layer precision schedule sweep: uniform int8 vs uniform 2-bit vs mixed |
//! | — (beyond the paper) | [`cluster`] | tensor-parallel strong scaling: ResNet-18 latency at 1/2/4/8 shard cores, with the all-gather sync fraction |
//! | — (beyond the paper) | [`profile`] | cycle attribution: per-layer and per-micro-op-class tables from [`crate::obs`] profiles |
//!
//! Every generator returns its data structure (for tests and benches) and can
//! render markdown + CSV under `artifacts/reports/`.

pub mod cluster;
pub mod fig3;
pub mod fig4;
pub mod mixed;
pub mod profile;
pub mod summary;
pub mod table1;
pub mod table2;

use std::io::Write;
use std::path::Path;

/// Write a report file under `artifacts/reports/`, creating the directory.
pub fn write_report(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = Path::new("artifacts/reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(contents.as_bytes())?;
    Ok(path)
}

/// Render a markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}|\n", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Render CSV.
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = headers.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    s
}
