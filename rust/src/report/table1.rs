//! Table I: LSQ quantization of ResNet-18 — accuracy and model size.
//!
//! Accuracy comes from the Python side (`python/compile/train_lsq.py`, run
//! via `make table1`), which trains the model at FP32 / W8A8 / W2A2 / W1A1 on
//! a synthetic CIFAR-scale dataset (the substitution for the paper's full
//! CIFAR-100 training — see DESIGN.md) and writes
//! `artifacts/table1.tsv`. The **size column is exact arithmetic** on the
//! real ResNet-18 parameter counts and is computed here.

use std::path::Path;

/// ResNet-18 (CIFAR-100 head) parameter count, matching the paper's 42.80 MB
/// FP32 size: 42.80 MB / 4 B ≈ 11.22 M parameters.
pub const RESNET18_CIFAR100_PARAMS: u64 = 11_220_132;

/// One Table I row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub precision: String,
    /// Accuracy (%), `None` until the Python run has produced it.
    pub accuracy: Option<f64>,
    /// Paper-reported accuracy for comparison.
    pub paper_accuracy: f64,
    /// Model size in MB.
    pub size_mb: f64,
    /// Paper-reported size.
    pub paper_size_mb: f64,
}

/// Model size in MB at `bits` per weight (FP32 = 32). Sub-byte checkpoints
/// also carry one FP scale per channel — negligible, as in the paper.
pub fn model_size_mb(bits: u32) -> f64 {
    RESNET18_CIFAR100_PARAMS as f64 * bits as f64 / 8.0 / 1e6
}

/// Parse the accuracy TSV produced by `train_lsq.py`
/// (lines: `precision<TAB>accuracy`).
pub fn parse_accuracy_tsv(contents: &str) -> Vec<(String, f64)> {
    contents
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut it = l.split('\t');
            let p = it.next()?.trim().to_string();
            let a: f64 = it.next()?.trim().parse().ok()?;
            Some((p, a))
        })
        .collect()
}

/// Build the table, merging measured accuracy if available.
pub fn generate(tsv_path: &Path) -> Vec<Table1Row> {
    let measured = std::fs::read_to_string(tsv_path)
        .map(|s| parse_accuracy_tsv(&s))
        .unwrap_or_default();
    let acc = |p: &str| measured.iter().find(|(k, _)| k == p).map(|(_, a)| *a);
    vec![
        Table1Row {
            precision: "LSQ(1/1)".into(),
            accuracy: acc("w1a1"),
            paper_accuracy: 57.32,
            size_mb: model_size_mb(1),
            paper_size_mb: 1.45,
        },
        Table1Row {
            precision: "LSQ(2/2)".into(),
            accuracy: acc("w2a2"),
            paper_accuracy: 76.81,
            size_mb: model_size_mb(2),
            paper_size_mb: 2.89,
        },
        Table1Row {
            precision: "LSQ(8/8)".into(),
            accuracy: acc("w8a8"),
            paper_accuracy: 78.45,
            size_mb: model_size_mb(8),
            paper_size_mb: 10.87,
        },
        Table1Row {
            precision: "FP32".into(),
            accuracy: acc("fp32"),
            paper_accuracy: 76.82,
            size_mb: model_size_mb(32),
            paper_size_mb: 42.80,
        },
    ]
}

pub fn markdown(rows: &[Table1Row]) -> String {
    let mut out = String::from(
        "# Table I — LSQ quantization of ResNet-18\n\n\
         Accuracy: measured on the synthetic CIFAR-scale task (see DESIGN.md \
         substitution); paper values are CIFAR-100.\n\n",
    );
    let trows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.precision.clone(),
                r.accuracy.map_or("run `make table1`".into(), |a| format!("{a:.2}")),
                format!("{:.2}", r.paper_accuracy),
                format!("{:.2}", r.size_mb),
                format!("{:.2}", r.paper_size_mb),
            ]
        })
        .collect();
    out.push_str(&super::md_table(
        &["precision (W/A)", "accuracy % (ours)", "accuracy % (paper)", "size MB (ours)", "size MB (paper)"],
        &trows,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_column_matches_paper_within_5pct() {
        // The size column is arithmetic on the parameter count. The paper's
        // own rows are not exactly `params·bits/8` of a single count (full-
        // precision stem/head and per-channel scales skew each row), so we
        // check each against the true CIFAR-ResNet18 parameter count at ≤6%.
        for (bits, paper) in [(1u32, 1.45), (2, 2.89), (8, 10.87), (32, 42.80)] {
            let ours = model_size_mb(bits);
            assert!(
                (ours - paper).abs() / paper < 0.06,
                "{bits}-bit: {ours:.2} MB vs paper {paper} MB"
            );
        }
    }

    #[test]
    fn tsv_parses() {
        let rows = parse_accuracy_tsv("# comment\nw1a1\t55.2\nw2a2\t74.0\nfp32\t75.1\n");
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, "w1a1");
        assert!((rows[0].1 - 55.2).abs() < 1e-9);
    }

    #[test]
    fn generate_without_tsv_keeps_paper_columns() {
        let rows = generate(Path::new("/nonexistent/table1.tsv"));
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.accuracy.is_none()));
        assert!(markdown(&rows).contains("LSQ(2/2)"));
    }
}
