//! Fig. 4: roofline for conv2d with a 3×3 kernel — Quark-8-lane (sub-byte)
//! vs Ara-4-lane (int8), the iso-area/iso-power comparison (both dies are
//! 1.09 mm², Table II).

use crate::arch::MachineConfig;
use crate::kernels::conv2d::{conv2d_bitserial, conv2d_int8};
use crate::kernels::bitpack::setup_index_vector;
use crate::kernels::requantize::RqBuf;
use crate::kernels::Conv2dParams;
use crate::phys::{roofline_curve, Roofline, RooflinePoint};
use crate::quant::pack_weight_planes;
use crate::sim::{Sim, SimMode};

/// The figure: machine rooflines + measured conv2d points over input sizes.
#[derive(Clone, Debug)]
pub struct Fig4 {
    pub roofs: Vec<Roofline>,
    pub points: Vec<RooflinePoint>,
    /// (size, quark8 gops, ara4 gops) summary per swept input size.
    pub sweep: Vec<(usize, f64, f64)>,
}

fn conv_params(hw: usize, c: usize) -> Conv2dParams {
    Conv2dParams { h: hw, w: hw, c_in: c, c_out: c, kh: 3, kw: 3, stride: 1, pad: 1 }
}

/// Measure one bit-serial conv on a machine; returns (cycles, stats delta).
fn run_bitserial(cfg: &MachineConfig, p: &Conv2dParams, bits: u8) -> (u64, crate::sim::Stats) {
    let mut sim = Sim::new(cfg.clone());
    sim.set_mode(SimMode::TimingOnly);
    let idx = setup_index_vector(&mut sim);
    let k = p.k();
    let n = p.c_out;
    let block = crate::kernels::conv2d::bitserial_block(cfg.vlen_bits, n);
    let wpk = pack_weight_planes(&vec![0u8; k * n], k, n, bits, block);
    let fm_in = sim.alloc((p.h * p.w * p.c_in) as u64);
    let w = sim.alloc(wpk.byte_len() as u64);
    let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((p.out_h() * p.out_w() * n) as u64);
    let before = sim.stats().clone();
    let c0 = sim.cycles();
    conv2d_bitserial(&mut sim, p, bits, fm_in, &wpk, w, &rq, out, None, true, idx);
    (sim.cycles() - c0, sim.stats().delta_since(&before))
}

fn run_int8(cfg: &MachineConfig, p: &Conv2dParams) -> (u64, crate::sim::Stats) {
    let mut sim = Sim::new(cfg.clone());
    sim.set_mode(SimMode::TimingOnly);
    let k = p.k();
    let n = p.c_out;
    let fm_in = sim.alloc((p.h * p.w * p.c_in) as u64);
    let w = sim.alloc((k * n) as u64);
    let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
    let out = sim.alloc((p.out_h() * p.out_w() * n) as u64);
    let before = sim.stats().clone();
    let c0 = sim.cycles();
    conv2d_int8(&mut sim, p, fm_in, w, &rq, out, None);
    (sim.cycles() - c0, sim.stats().delta_since(&before))
}

/// Generate with custom sweep sizes (channel count 64, the paper's kernel).
pub fn generate(sizes: &[usize]) -> Fig4 {
    let ara = MachineConfig::ara(4);
    let q8 = MachineConfig::quark(8);
    let roof_ara = Roofline::for_machine(&ara, "int8");
    let roof_q8 = Roofline::for_machine(&q8, "w2a2");
    let mut points = Vec::new();
    let mut sweep = Vec::new();
    for &hw in sizes {
        let p = conv_params(hw, 64);
        let (qc, qs) = run_bitserial(&q8, &p, 2);
        let qpt = RooflinePoint::from_stats(format!("quark8-w2a2 {hw}x{hw}"), &roof_q8, &q8, qc, &qs);
        let (ac, as_) = run_int8(&ara, &p);
        let apt = RooflinePoint::from_stats(format!("ara4-int8 {hw}x{hw}"), &roof_ara, &ara, ac, &as_);
        sweep.push((hw, qpt.gops, apt.gops));
        points.push(qpt);
        points.push(apt);
    }
    Fig4 { roofs: vec![roof_q8, roof_ara], points, sweep }
}

/// The paper's sweep (input tensor sizes for a 3×3, 64-channel conv).
pub fn generate_default() -> Fig4 {
    generate(&[4, 8, 16, 32, 56])
}

impl Fig4 {
    pub fn markdown(&self) -> String {
        let mut out =
            String::from("# Fig. 4 — roofline, conv2d 3×3 (C=64): Quark-8L (2-bit) vs Ara-4L (int8)\n\n");
        out.push_str("## Machine roofs\n\n");
        let rows: Vec<Vec<String>> = self
            .roofs
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    format!("{:.0}", r.peak_gops),
                    format!("{:.1}", r.mem_gbs),
                    format!("{:.2}", r.ridge()),
                ]
            })
            .collect();
        out.push_str(&super::md_table(&["roof", "peak GOPS", "BW GB/s", "ridge ops/B"], &rows));
        out.push_str("\n## Measured points\n\n");
        let rows: Vec<Vec<String>> = self
            .points
            .iter()
            .map(|p| {
                vec![
                    p.label.clone(),
                    format!("{:.2}", p.ai),
                    format!("{:.1}", p.gops),
                    format!("{:.0}%", p.efficiency * 100.0),
                ]
            })
            .collect();
        out.push_str(&super::md_table(&["kernel", "AI ops/B", "GOPS", "roof eff."], &rows));
        out.push_str("\n## Quark-8L vs Ara-4L per input size (iso area/power)\n\n");
        let rows: Vec<Vec<String>> = self
            .sweep
            .iter()
            .map(|(hw, q, a)| {
                vec![
                    format!("{hw}x{hw}x64"),
                    format!("{q:.1}"),
                    format!("{a:.1}"),
                    format!("{:.2}x", q / a),
                ]
            })
            .collect();
        out.push_str(&super::md_table(&["input", "Quark-8L GOPS", "Ara-4L GOPS", "ratio"], &rows));
        out
    }

    pub fn csv(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .points
            .iter()
            .map(|p| {
                vec![p.label.clone(), format!("{:.4}", p.ai), format!("{:.3}", p.gops), format!("{:.4}", p.efficiency)]
            })
            .collect();
        let mut s = super::csv(&["label", "ai_ops_per_byte", "gops", "efficiency"], &rows);
        s.push('\n');
        for r in &self.roofs {
            for (ai, g) in roofline_curve(r, 0.05, 200.0, 64) {
                s.push_str(&format!("curve:{},{:.4},{:.3},\n", r.name, ai, g));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quark8_wins_at_every_size() {
        // Small sweep keeps the test quick; the paper's claim is "Quark
        // outperforms Ara in all the input tensor sizes".
        let fig = generate(&[4, 8]);
        for (hw, q, a) in &fig.sweep {
            assert!(q > a, "{hw}: quark {q} vs ara {a}");
        }
        assert!(fig.markdown().contains("roof"));
    }
}
