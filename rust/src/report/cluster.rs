//! Cluster strong-scaling report (beyond the paper's single-core runs):
//! modeled ResNet-18 latency when one inference is tensor-parallel-sharded
//! across 1/2/4/8 simulated Quark cores ([`crate::cluster`]), at uniform
//! w2a2, uniform w1a1, and the SPEED-style mixed schedule.
//!
//! Per (schedule, shard count) the row reports the cluster cycle model —
//! `Σ max(shard compute) + all-gather sync` — the speedup over the 1-core
//! run of the same schedule, and the Amdahl-style sync fraction. Sub-linear
//! scaling has two sources the table separates: the replicated per-pixel
//! work (im2col + activation packing runs on every shard — the serial
//! fraction) and the modeled inter-core all-gather (the sync fraction).

use crate::arch::MachineConfig;
use crate::cluster::{
    cluster_timing, compile_cluster, compile_pipeline, pipeline_timing, ClusterTiming,
};
use crate::nn::model::{Precision, PrecisionMap};
use crate::nn::resnet::resnet18_mixed_schedule;
use crate::nn::{zoo, NetGraph};

/// One (schedule, shard count) point of the scaling sweep.
#[derive(Clone, Debug)]
pub struct ClusterRow {
    pub schedule: String,
    pub shards: usize,
    /// Modeled end-to-end latency in cycles (compute critical path + sync).
    pub total_cycles: u64,
    /// Modeled inter-core all-gather cycles included in `total_cycles`.
    pub sync_cycles: u64,
    /// `total_cycles(1 shard) / total_cycles` for the same schedule.
    pub speedup: f64,
    /// `sync_cycles / total_cycles`.
    pub sync_fraction: f64,
    /// Mean modeled shard-core utilization (busy cycles over the compute
    /// critical path; 1.0 = perfectly balanced).
    pub mean_shard_util: f64,
}

/// The full sweep.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub machine: String,
    pub rows: Vec<ClusterRow>,
}

/// Default shard counts of the strong-scaling sweep.
pub const DEFAULT_SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Run the sweep on `net` (Quark-4L; schedule differences are then
/// schedule-only, like the mixed report).
pub fn generate(net: &NetGraph, shard_counts: &[usize]) -> ClusterReport {
    let machine = MachineConfig::quark(4);
    let w2a2 = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    let w1a1 = PrecisionMap::uniform(Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true });
    let mixed = resnet18_mixed_schedule(net);
    let mut rows = Vec::new();
    for (label, sched) in [("w2a2", &w2a2), ("w1a1", &w1a1), ("mixed", &mixed)] {
        let time_at = |n: usize| {
            let cluster = compile_cluster(net, &machine, sched, n)
                .unwrap_or_else(|e| panic!("compile {label} at {n} shards: {e}"));
            cluster_timing(&cluster, &machine)
        };
        let timings: Vec<(usize, ClusterTiming)> =
            shard_counts.iter().map(|&n| (n, time_at(n))).collect();
        // Speedup is always vs the true 1-shard run: reuse it from the sweep
        // when present, derive it otherwise (so `--shards 4,8` stays honest).
        let base_cycles = timings
            .iter()
            .find(|(n, _)| *n == 1)
            .map(|(_, t)| t.total_cycles())
            .unwrap_or_else(|| time_at(1).total_cycles());
        for (n, t) in timings {
            let total = t.total_cycles();
            let util = t.shard_utilization();
            rows.push(ClusterRow {
                schedule: label.to_string(),
                shards: n,
                total_cycles: total,
                sync_cycles: t.sync_cycles,
                speedup: base_cycles as f64 / total.max(1) as f64,
                sync_fraction: t.sync_fraction(),
                mean_shard_util: util.iter().sum::<f64>() / util.len().max(1) as f64,
            });
        }
    }
    ClusterReport { machine: machine.name.clone(), rows }
}

/// Full-size sweep (the paper's ResNet-18/CIFAR-100 workload) at the
/// default shard counts.
pub fn generate_default() -> ClusterReport {
    generate(&zoo::model("resnet18-cifar@100").expect("registry entry"), &DEFAULT_SHARD_COUNTS)
}

impl ClusterReport {
    fn cells(&self) -> Vec<Vec<String>> {
        self.rows
            .iter()
            .map(|r| {
                vec![
                    r.schedule.clone(),
                    r.shards.to_string(),
                    r.total_cycles.to_string(),
                    r.sync_cycles.to_string(),
                    format!("{:.2}", r.speedup),
                    format!("{:.4}", r.sync_fraction),
                    format!("{:.2}", r.mean_shard_util),
                ]
            })
            .collect()
    }

    pub fn markdown(&self) -> String {
        let mut out = format!(
            "# Cluster sharding — ResNet-18 strong scaling ({} shard cores)\n\n",
            self.machine
        );
        out.push_str(&super::md_table(
            &["schedule", "shards", "total cycles", "sync cycles", "speedup", "sync frac", "shard util"],
            &self.cells(),
        ));
        out.push_str(
            "\nSpeedup is vs the 1-shard run of the same schedule. Sub-linear scaling \
             separates into the replicated per-pixel work (im2col + activation packing \
             runs on every shard) and the modeled all-gather (`sync frac`, charged \
             against the per-core AXI link).\n",
        );
        out
    }

    pub fn csv(&self) -> String {
        super::csv(
            &[
                "schedule",
                "shards",
                "total_cycles",
                "sync_cycles",
                "speedup",
                "sync_fraction",
                "mean_shard_util",
            ],
            &self.cells(),
        )
    }
}

/// One (schedule, core count) point comparing the two parallelism axes on
/// the same workload at the same core budget: tensor sharding's per-request
/// latency (which bounds its sustained throughput — one request occupies
/// every shard core end to end) vs the pipeline's steady-state period (one
/// request completes per period once the pipe is full).
#[derive(Clone, Debug)]
pub struct ModeRow {
    pub schedule: String,
    pub cores: usize,
    /// Tensor-parallel modeled latency at `cores` shards (= cycles between
    /// completions under back-to-back requests).
    pub tensor_cycles: u64,
    /// Pipeline fill latency at `cores` stages (first-request latency).
    pub pipeline_fill: u64,
    /// Pipeline steady-state period (cycles between completions).
    pub pipeline_period: u64,
    /// Σ inter-stage hop cycles (charged like the all-gather, per request).
    pub pipeline_hops: u64,
    /// `tensor_cycles / pipeline_period` — above 1.0 the pipeline sustains
    /// more requests per second than tensor sharding on the same cores.
    pub sustained_ratio: f64,
    /// Mean modeled stage utilization over a [`STREAM_TOKENS`]-deep stream.
    pub mean_stage_util: f64,
}

/// The tensor-vs-pipeline sweep.
#[derive(Clone, Debug)]
pub struct ModeReport {
    pub machine: String,
    pub net: String,
    pub rows: Vec<ModeRow>,
}

/// Stream depth used for the mode sweep's stage-utilization column (deep
/// enough that fill bubbles stop dominating, small enough to model a
/// realistic burst).
pub const STREAM_TOKENS: u64 = 16;

/// Run the tensor-vs-pipeline comparison on `net` at `core_counts`
/// (Quark-4L, uniform w2a2 and int8 — schedules every zoo model deploys).
pub fn generate_modes(net: &NetGraph, core_counts: &[usize]) -> ModeReport {
    let machine = MachineConfig::quark(4);
    let w2a2 = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    let int8 = PrecisionMap::uniform(Precision::Int8);
    let mut rows = Vec::new();
    for (label, sched) in [("w2a2", &w2a2), ("int8", &int8)] {
        for &n in core_counts {
            let cluster = compile_cluster(net, &machine, sched, n)
                .unwrap_or_else(|e| panic!("tensor compile {label} at {n} cores: {e}"));
            let tensor = cluster_timing(&cluster, &machine);
            let pipeline = compile_pipeline(net, &machine, sched, n)
                .unwrap_or_else(|e| panic!("pipeline compile {label} at {n} cores: {e}"));
            let pt = pipeline_timing(&pipeline, &machine, STREAM_TOKENS);
            let util = pt.stage_utilization();
            let period = pt.period_cycles();
            rows.push(ModeRow {
                schedule: label.to_string(),
                cores: n,
                tensor_cycles: tensor.total_cycles(),
                pipeline_fill: pt.fill_cycles(),
                pipeline_period: period,
                pipeline_hops: pt.stages.iter().map(|s| s.hop_cycles).sum(),
                sustained_ratio: tensor.total_cycles() as f64 / period.max(1) as f64,
                mean_stage_util: util.iter().sum::<f64>() / util.len().max(1) as f64,
            });
        }
    }
    ModeReport { machine: machine.name.clone(), net: net.name().to_string(), rows }
}

impl ModeReport {
    fn cells(&self) -> Vec<Vec<String>> {
        self.rows
            .iter()
            .map(|r| {
                vec![
                    r.schedule.clone(),
                    r.cores.to_string(),
                    r.tensor_cycles.to_string(),
                    r.pipeline_fill.to_string(),
                    r.pipeline_period.to_string(),
                    r.pipeline_hops.to_string(),
                    format!("{:.2}", r.sustained_ratio),
                    format!("{:.2}", r.mean_stage_util),
                ]
            })
            .collect()
    }

    pub fn markdown(&self) -> String {
        let mut out = format!(
            "# Tensor vs pipeline parallelism — {} sustained throughput ({})\n\n",
            self.net, self.machine
        );
        out.push_str(&super::md_table(
            &[
                "schedule",
                "cores",
                "tensor cycles",
                "pipe fill",
                "pipe period",
                "pipe hops",
                "sustained ratio",
                "stage util",
            ],
            &self.cells(),
        ));
        out.push_str(
            "\nTensor sharding optimizes per-request latency but replicates the \
             per-request input packing on every shard and pays an all-gather per \
             layer; its sustained throughput is 1/latency. The pipeline keeps each \
             request on one core per stage — under a steady stream a request \
             completes every `period = max(stage)` cycles, so `sustained ratio = \
             tensor cycles / pipe period` above 1.0 means the pipeline serves more \
             requests per second on the same cores (at the cost of fill latency).\n",
        );
        out
    }

    pub fn csv(&self) -> String {
        super::csv(
            &[
                "schedule",
                "cores",
                "tensor_cycles",
                "pipeline_fill",
                "pipeline_period",
                "pipeline_hops",
                "sustained_ratio",
                "mean_stage_util",
            ],
            &self.cells(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::demo_net;

    #[test]
    fn scaling_rows_improve_with_shards_on_the_demo_net() {
        let rep = generate(&demo_net(), &[1, 2, 4]);
        assert_eq!(rep.rows.len(), 9, "3 schedules × 3 shard counts");
        for chunk in rep.rows.chunks(3) {
            assert_eq!(chunk[0].shards, 1);
            assert!((chunk[0].speedup - 1.0).abs() < 1e-12, "1-shard speedup is 1.0");
            assert_eq!(chunk[0].sync_cycles, 0, "no all-gather on one core");
            assert!(
                chunk[1].total_cycles < chunk[0].total_cycles,
                "{}: 2 shards must beat 1 ({} vs {})",
                chunk[1].schedule,
                chunk[1].total_cycles,
                chunk[0].total_cycles
            );
            assert!(
                chunk[2].total_cycles < chunk[1].total_cycles,
                "{}: 4 shards must beat 2 ({} vs {})",
                chunk[2].schedule,
                chunk[2].total_cycles,
                chunk[1].total_cycles
            );
            assert!(chunk[2].sync_fraction > 0.0 && chunk[2].sync_fraction < 0.5);
        }
        let md = rep.markdown();
        assert!(md.contains("strong scaling"));
        assert!(rep.csv().lines().count() == 10);
    }

    #[test]
    fn mode_comparison_rows_are_consistent() {
        let rep = generate_modes(&demo_net(), &[1, 2]);
        assert_eq!(rep.rows.len(), 4, "2 schedules × 2 core counts");
        for r in &rep.rows {
            assert!(r.pipeline_fill >= r.pipeline_period, "fill covers every stage");
            assert!(r.pipeline_period > 0);
            if r.cores == 1 {
                assert_eq!(r.pipeline_hops, 0, "one stage has no hand-offs");
                assert_eq!(
                    r.tensor_cycles, r.pipeline_fill,
                    "{}: at one core both axes are the same single-core run",
                    r.schedule
                );
                assert!((r.sustained_ratio - 1.0).abs() < 1e-9);
            } else {
                assert!(r.pipeline_hops > 0, "stage hand-offs are charged");
                assert!(r.mean_stage_util > 0.0 && r.mean_stage_util <= 1.0);
            }
        }
        let md = rep.markdown();
        assert!(md.contains("sustained ratio"), "{md}");
        assert_eq!(rep.csv().lines().count(), 5, "header + 4 rows");
    }
}
