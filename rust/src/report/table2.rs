//! Table II (physical implementation) and Fig. 5 (area breakdown), from the
//! analytical tech model.

use crate::arch::MachineConfig;
use crate::phys::{PhysReport, TechModel};

/// Paper values for side-by-side comparison in the rendered table.
pub const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    // (name, lane mm², die mm², freq GHz, lane power mW)
    ("ara-4l", 0.120, 1.09, 1.05, 229.0),
    ("quark-4l", 0.051, 0.69, 1.05, 119.0),
    ("quark-8l", 0.046, 1.09, 1.00, 97.0),
];

pub fn generate() -> Vec<PhysReport> {
    let m = TechModel::default();
    MachineConfig::paper_configs().iter().map(|c| m.report(c)).collect()
}

pub fn markdown(reports: &[PhysReport]) -> String {
    let mut out = String::from("# Table II — physical implementation (GF22FDX, analytical model)\n\n");
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            let paper = PAPER.iter().find(|p| p.0 == r.name);
            vec![
                r.name.clone(),
                r.lanes.to_string(),
                r.vrf_kib.to_string(),
                format!("{:.3} ({})", r.lane_area_mm2, paper.map_or("-".into(), |p| format!("{:.3}", p.1))),
                format!("{:.2} ({})", r.die_area_mm2, paper.map_or("-".into(), |p| format!("{:.2}", p.2))),
                format!("{:.2} ({})", r.freq_ghz, paper.map_or("-".into(), |p| format!("{:.2}", p.3))),
                format!("{:.0} ({})", r.lane_power_mw, paper.map_or("-".into(), |p| format!("{:.0}", p.4))),
            ]
        })
        .collect();
    out.push_str(&super::md_table(
        &[
            "config",
            "lanes",
            "VRF KiB",
            "lane mm² (paper)",
            "die mm² (paper)",
            "TT GHz (paper)",
            "power/lane mW (paper)",
        ],
        &rows,
    ));
    out
}

/// Fig. 5 equivalent: per-lane area breakdown per configuration.
pub fn fig5_markdown(reports: &[PhysReport]) -> String {
    let mut out = String::from("# Fig. 5 — per-lane area breakdown (mm²)\n\n");
    for r in reports {
        out.push_str(&format!("## {} (lane = {:.3} mm²)\n\n", r.name, r.lane_area_mm2));
        let rows: Vec<Vec<String>> = r
            .breakdown
            .iter()
            .map(|(name, a)| {
                vec![
                    name.to_string(),
                    format!("{a:.4}"),
                    format!("{:.0}%", 100.0 * a / r.lane_area_mm2),
                ]
            })
            .collect();
        out.push_str(&super::md_table(&["component", "mm²", "share"], &rows));
        out.push('\n');
    }
    out.push_str(
        "The vector FPU + FP operand queues dominate the Ara lane — removing \
         them is what makes the Quark lane ≈2.3× smaller (paper Fig. 5: the \
         FPU blocks visibly occupy most of each Ara lane).\n",
    );
    out
}

pub fn csv(reports: &[PhysReport]) -> String {
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.lanes.to_string(),
                format!("{:.4}", r.lane_area_mm2),
                format!("{:.3}", r.die_area_mm2),
                format!("{:.2}", r.freq_ghz),
                format!("{:.1}", r.lane_power_mw),
            ]
        })
        .collect();
    super::csv(&["config", "lanes", "lane_mm2", "die_mm2", "freq_ghz", "lane_power_mw"], &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_within_six_percent_of_paper() {
        for r in generate() {
            let p = PAPER.iter().find(|p| p.0 == r.name).unwrap();
            for (got, want) in [
                (r.lane_area_mm2, p.1),
                (r.die_area_mm2, p.2),
                (r.freq_ghz, p.3),
                (r.lane_power_mw, p.4),
            ] {
                assert!(
                    (got - want).abs() / want < 0.06,
                    "{}: {got} vs paper {want}",
                    r.name
                );
            }
        }
    }

    #[test]
    fn renders() {
        let reports = generate();
        assert!(markdown(&reports).contains("quark-8l"));
        assert!(fig5_markdown(&reports).contains("vector FPU"));
    }
}
