//! Fig. 3: per-layer relative speedup, ResNet-18 on CIFAR-100 (batch 1),
//! Quark Int1 / Int2 (with and without `vbitpack`) over Ara Int8, plus the
//! Ara FP32 reference.

use crate::arch::MachineConfig;
use crate::nn::model::{ModelRunner, Precision};
use crate::nn::{zoo, NetGraph};
use crate::sim::{Sim, SimMode};

/// One Fig. 3 series: per-quantized-layer cycle counts for a configuration.
#[derive(Clone, Debug)]
pub struct Fig3Series {
    pub label: String,
    pub machine: String,
    /// (layer name, cycles) for the quantized layers, in network order.
    pub layer_cycles: Vec<(String, u64)>,
}

/// The full figure: baseline (Ara Int8) plus comparison series.
#[derive(Clone, Debug)]
pub struct Fig3 {
    pub baseline: Fig3Series,
    pub series: Vec<Fig3Series>,
}

fn run_series(cfg: MachineConfig, precision: Precision, net: &NetGraph) -> Fig3Series {
    let mut sim = Sim::new(cfg.clone());
    sim.set_mode(SimMode::TimingOnly);
    let reports = ModelRunner::run(&mut sim, net, precision);
    Fig3Series {
        label: precision.label(),
        machine: cfg.name,
        layer_cycles: reports
            .into_iter()
            .filter(|r| r.quantized)
            .map(|r| (r.name, r.run.cycles))
            .collect(),
    }
}

/// Generate the figure data on the paper's configurations.
pub fn generate(net: &NetGraph) -> Fig3 {
    let baseline = run_series(MachineConfig::ara(4), Precision::Int8, net);
    let series = vec![
        run_series(MachineConfig::ara(4), Precision::Fp32, net),
        run_series(
            MachineConfig::quark(4),
            Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true },
            net,
        ),
        run_series(
            MachineConfig::quark(4),
            Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true },
            net,
        ),
        run_series(
            MachineConfig::quark(4),
            Precision::Sub { abits: 2, wbits: 2, use_vbitpack: false },
            net,
        ),
    ];
    Fig3 { baseline, series }
}

/// Full-size figure (the paper's workload).
pub fn generate_default() -> Fig3 {
    generate(&zoo::model("resnet18-cifar@100").expect("registry entry"))
}

impl Fig3 {
    /// Per-layer speedup of `series[i]` over the Int8 baseline.
    pub fn speedups(&self, i: usize) -> Vec<(String, f64)> {
        self.series[i]
            .layer_cycles
            .iter()
            .zip(self.baseline.layer_cycles.iter())
            .map(|((name, c), (_, b))| (name.clone(), *b as f64 / *c as f64))
            .collect()
    }

    /// Geometric-mean speedup of a series over Int8 (the paper quotes
    /// arithmetic "average"; we report both).
    pub fn mean_speedup(&self, i: usize) -> (f64, f64) {
        let sp = self.speedups(i);
        let n = sp.len() as f64;
        let arith = sp.iter().map(|(_, s)| s).sum::<f64>() / n;
        let geo = (sp.iter().map(|(_, s)| s.ln()).sum::<f64>() / n).exp();
        (arith, geo)
    }

    pub fn markdown(&self) -> String {
        let mut headers = vec!["layer".to_string(), format!("{} cycles", self.baseline.label)];
        for s in &self.series {
            headers.push(format!("{} ({})", s.label, s.machine));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut rows = Vec::new();
        for (li, (name, base)) in self.baseline.layer_cycles.iter().enumerate() {
            let mut row = vec![name.clone(), base.to_string()];
            for s in &self.series {
                let c = s.layer_cycles[li].1;
                row.push(format!("{:.2}x", *base as f64 / c as f64));
            }
            rows.push(row);
        }
        let mut out = String::from(
            "# Fig. 3 — per-layer speedup over Ara Int8 (ResNet-18/CIFAR-100, batch 1)\n\n",
        );
        out.push_str(&super::md_table(&hdr_refs, &rows));
        out.push_str("\n**Averages (arith / geo):**\n\n");
        for (i, s) in self.series.iter().enumerate() {
            let (a, g) = self.mean_speedup(i);
            out.push_str(&format!("* {}: {:.2}x / {:.2}x\n", s.label, a, g));
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut headers = vec!["layer".to_string(), "int8_cycles".to_string()];
        for s in &self.series {
            headers.push(format!("{}_cycles", s.label));
            headers.push(format!("{}_speedup", s.label));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut rows = Vec::new();
        for (li, (name, base)) in self.baseline.layer_cycles.iter().enumerate() {
            let mut row = vec![name.clone(), base.to_string()];
            for s in &self.series {
                let c = s.layer_cycles[li].1;
                row.push(c.to_string());
                row.push(format!("{:.4}", *base as f64 / c as f64));
            }
            rows.push(row);
        }
        super::csv(&hdr_refs, &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Conv2dParams;
    use crate::nn::{ConvLayer, LayerKind, NetLayer};

    /// A stem + two quantized convs — keeps the test fast while exercising
    /// the whole generator pipeline.
    fn mini_net() -> NetGraph {
        let conv = |name: &str, c_in: usize, quantized: bool| ConvLayer {
            name: name.into(),
            params: Conv2dParams {
                h: 8,
                w: 8,
                c_in,
                c_out: 64,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            relu: true,
            residual: false,
            quantized,
        };
        NetGraph::new(
            "fig3-mini",
            0,
            vec![
                NetLayer { kind: LayerKind::Conv(conv("stem", 3, false)), input: 0, residual_from: None },
                NetLayer { kind: LayerKind::Conv(conv("c1", 64, true)), input: 1, residual_from: None },
                NetLayer { kind: LayerKind::Conv(conv("c2", 64, true)), input: 2, residual_from: None },
            ],
        )
        .unwrap()
    }

    #[test]
    fn fig3_shape_holds_on_mini_net() {
        let fig = generate(&mini_net());
        assert_eq!(fig.series.len(), 4);
        // Series order: fp32, w1a1, w2a2, w2a2-novbp.
        let (int1_avg, _) = fig.mean_speedup(1);
        let (int2_avg, _) = fig.mean_speedup(2);
        let (int2_novbp_avg, _) = fig.mean_speedup(3);
        // Int1 beats Int8 on EVERY layer (the paper's claim).
        for (name, s) in fig.speedups(1) {
            assert!(s > 1.0, "Int1 must beat Int8 on {name}: {s:.2}");
        }
        // Ordering: Int1 > Int2 > Int2-no-vbitpack.
        assert!(int1_avg > int2_avg, "{int1_avg} vs {int2_avg}");
        assert!(int2_avg > int2_novbp_avg, "{int2_avg} vs {int2_novbp_avg}");
        // FP32 is slower than Int8.
        let (fp32_avg, _) = fig.mean_speedup(0);
        assert!(fp32_avg < 1.15, "fp32 should be ≈int8 or slower: {fp32_avg}");
        // Rendering works.
        assert!(fig.markdown().contains("c1"));
        assert!(fig.csv().lines().count() >= 3);
    }
}
