//! Headline-claims summary (paper abstract + conclusion):
//!
//! * Int1 ≈5.7× and Int2 ≈3.5× faster than Ara Int8 on ResNet-18 (average);
//! * Int2 *without* `vbitpack` barely beats Int8;
//! * Quark lane ≈2.3× smaller, ≈1.9× lower power than Ara's;
//! * Quark-8L beats Ara-4L at iso-area/power on conv2d for all sizes.

use crate::arch::MachineConfig;
use crate::phys::TechModel;

use super::fig3::Fig3;
use super::fig4::Fig4;

#[derive(Clone, Debug)]
pub struct Summary {
    pub int1_avg_speedup: f64,
    pub int2_avg_speedup: f64,
    pub int2_novbp_avg_speedup: f64,
    pub lane_area_ratio: f64,
    pub lane_power_ratio: f64,
    pub quark8_wins_all_sizes: bool,
}

pub fn generate(fig3: &Fig3, fig4: &Fig4) -> Summary {
    // Series order in fig3::generate: fp32, w1a1, w2a2, w2a2-novbp.
    let m = TechModel::default();
    let ara = m.report(&MachineConfig::ara(4));
    let quark = m.report(&MachineConfig::quark(4));
    Summary {
        int1_avg_speedup: fig3.mean_speedup(1).0,
        int2_avg_speedup: fig3.mean_speedup(2).0,
        int2_novbp_avg_speedup: fig3.mean_speedup(3).0,
        lane_area_ratio: ara.lane_area_mm2 / quark.lane_area_mm2,
        lane_power_ratio: ara.lane_power_mw / quark.lane_power_mw,
        quark8_wins_all_sizes: fig4.sweep.iter().all(|(_, q, a)| q > a),
    }
}

pub fn markdown(s: &Summary) -> String {
    format!(
        "# Headline claims — paper vs reproduction\n\n\
         | claim | paper | measured |\n|---|---|---|\n\
         | Int1 avg speedup over Ara Int8 | 5.7x | {:.2}x |\n\
         | Int2 avg speedup over Ara Int8 | 3.5x | {:.2}x |\n\
         | Int2 w/o vbitpack | \"not significant\" vs Int8 | {:.2}x |\n\
         | Quark lane area vs Ara | 2.3x smaller | {:.2}x |\n\
         | Quark lane power vs Ara | 1.9x lower | {:.2}x |\n\
         | Quark-8L > Ara-4L at iso budget, all conv sizes | yes | {} |\n",
        s.int1_avg_speedup,
        s.int2_avg_speedup,
        s.int2_novbp_avg_speedup,
        s.lane_area_ratio,
        s.lane_power_ratio,
        if s.quark8_wins_all_sizes { "yes" } else { "no" },
    )
}
