//! Layer-wise schedule-space comparison (beyond the paper's uniform runs):
//! per-layer and whole-network cycles for uniform Int8, uniform Int2
//! (w2a2), and the mixed per-layer schedule
//! ([`crate::nn::resnet::resnet18_mixed_schedule`]: first-stage convs + the
//! classifier at 8-bit, everything else 2-bit bit-serial), all on the same
//! Quark-4L machine so differences are schedule-only.
//!
//! The acceptance property — a mixed schedule lands strictly between the
//! uniform baselines on total cycles — is asserted by
//! `rust/tests/mixed_precision.rs` and `benches/mixed_precision.rs`.

use crate::arch::MachineConfig;
use crate::nn::model::{ModelRunner, Precision, PrecisionMap};
use crate::nn::resnet::resnet18_mixed_schedule;
use crate::nn::{zoo, NetGraph};
use crate::sim::{Sim, SimMode};

/// Per-layer cycles under the three schedules.
#[derive(Clone, Debug)]
pub struct MixedRow {
    pub layer: String,
    /// The layer's resolved precision under the mixed schedule.
    pub mixed_precision: String,
    pub int8_cycles: u64,
    pub int2_cycles: u64,
    pub mixed_cycles: u64,
}

/// The full comparison: per-layer rows plus whole-network totals.
#[derive(Clone, Debug)]
pub struct MixedReport {
    pub machine: String,
    pub rows: Vec<MixedRow>,
    pub int8_total: u64,
    pub int2_total: u64,
    pub mixed_total: u64,
}

fn run_cycles(
    machine: &MachineConfig,
    net: &NetGraph,
    schedule: &PrecisionMap,
) -> Vec<(String, String, u64)> {
    let mut sim = Sim::new(machine.clone());
    sim.set_mode(SimMode::TimingOnly);
    let run = ModelRunner::run_scheduled(&mut sim, net, schedule, None);
    run.reports
        .into_iter()
        .map(|r| (r.name, r.precision.label(), r.run.cycles))
        .collect()
}

/// Generate the comparison on Quark-4L (int8 is integer-only, so all three
/// schedules run on the same machine).
pub fn generate(net: &NetGraph) -> MixedReport {
    let machine = MachineConfig::quark(4);
    let int2_prec = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };
    let int8 = run_cycles(&machine, net, &PrecisionMap::uniform(Precision::Int8));
    let int2 = run_cycles(&machine, net, &PrecisionMap::uniform(int2_prec));
    let mixed = run_cycles(&machine, net, &resnet18_mixed_schedule(net));
    let rows: Vec<MixedRow> = int8
        .iter()
        .zip(int2.iter())
        .zip(mixed.iter())
        .map(|((a, b), m)| MixedRow {
            layer: a.0.clone(),
            mixed_precision: m.1.clone(),
            int8_cycles: a.2,
            int2_cycles: b.2,
            mixed_cycles: m.2,
        })
        .collect();
    MixedReport {
        machine: machine.name.clone(),
        int8_total: rows.iter().map(|r| r.int8_cycles).sum(),
        int2_total: rows.iter().map(|r| r.int2_cycles).sum(),
        mixed_total: rows.iter().map(|r| r.mixed_cycles).sum(),
        rows,
    }
}

/// Full-size comparison (the paper's ResNet-18/CIFAR-100 workload).
pub fn generate_default() -> MixedReport {
    generate(&zoo::model("resnet18-cifar@100").expect("registry entry"))
}

impl MixedReport {
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.layer.clone(),
                    r.mixed_precision.clone(),
                    r.int8_cycles.to_string(),
                    r.int2_cycles.to_string(),
                    r.mixed_cycles.to_string(),
                ]
            })
            .collect();
        let mut out = format!(
            "# Mixed per-layer precision — ResNet-18 schedule sweep ({})\n\n",
            self.machine
        );
        out.push_str(&super::md_table(
            &["layer", "mixed prec", "int8 cycles", "w2a2 cycles", "mixed cycles"],
            &rows,
        ));
        out.push_str(&format!(
            "\n**Totals:** int8 {} | mixed {} ({:.2}x vs int8) | w2a2 {} ({:.2}x vs int8)\n",
            self.int8_total,
            self.mixed_total,
            self.int8_total as f64 / self.mixed_total.max(1) as f64,
            self.int2_total,
            self.int8_total as f64 / self.int2_total.max(1) as f64,
        ));
        out
    }

    pub fn csv(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.layer.clone(),
                    r.mixed_precision.clone(),
                    r.int8_cycles.to_string(),
                    r.int2_cycles.to_string(),
                    r.mixed_cycles.to_string(),
                ]
            })
            .collect();
        super::csv(
            &["layer", "mixed_precision", "int8_cycles", "w2a2_cycles", "mixed_cycles"],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Conv2dParams;
    use crate::nn::{ConvLayer, LayerKind, NetLayer};

    /// Two stages' worth of names on a small net: the mixed schedule keeps
    /// `_s1` at int8 and drops `_s2` to 2-bit.
    fn mini_net() -> NetGraph {
        let conv = |name: &str, c_in: usize, quantized: bool| ConvLayer {
            name: name.into(),
            params: Conv2dParams {
                h: 8,
                w: 8,
                c_in,
                c_out: 64,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            relu: true,
            residual: false,
            quantized,
        };
        NetGraph::new(
            "mixed-mini",
            0,
            vec![
                NetLayer { kind: LayerKind::Conv(conv("stem", 3, false)), input: 0, residual_from: None },
                NetLayer { kind: LayerKind::Conv(conv("conv1_s1b1a", 64, true)), input: 1, residual_from: None },
                NetLayer { kind: LayerKind::Conv(conv("conv2_s2b1a", 64, true)), input: 2, residual_from: None },
            ],
        )
        .unwrap()
    }

    #[test]
    fn mixed_total_lands_between_uniforms_on_mini_net() {
        let rep = generate(&mini_net());
        assert_eq!(rep.rows.len(), 3);
        assert_eq!(rep.rows[0].mixed_precision, "int8", "the stem is pinned");
        assert_eq!(rep.rows[1].mixed_precision, "int8");
        assert_eq!(rep.rows[2].mixed_precision, "w2a2");
        assert!(
            rep.int2_total < rep.mixed_total && rep.mixed_total < rep.int8_total,
            "w2a2 {} < mixed {} < int8 {}",
            rep.int2_total,
            rep.mixed_total,
            rep.int8_total
        );
        assert!(rep.markdown().contains("conv1_s1b1a"));
        assert!(rep.csv().lines().count() >= 3);
    }
}
