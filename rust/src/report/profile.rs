//! Markdown/CSV renderers for cycle-attribution profiles
//! ([`crate::obs::profile`]): the per-layer and per-micro-op-class tables
//! `repro profile` prints, in the same `md_table` idiom as the paper
//! regenerators.

use crate::obs::{ClusterProfile, OpClass, PipelineProfile, ProgramProfile};

use super::{csv, md_table};

/// Per-layer table: name, scheduled precision, MACs, cycles, share of the
/// replay total.
pub fn layers_markdown(p: &ProgramProfile) -> String {
    let total = p.total_cycles.max(1);
    let mut rows: Vec<Vec<String>> = p
        .layers
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                l.precision.clone(),
                l.macs.to_string(),
                l.cycles.to_string(),
                format!("{:.1}%", 100.0 * l.cycles as f64 / total as f64),
            ]
        })
        .collect();
    rows.push(vec![
        "**total**".to_string(),
        p.schedule.clone(),
        p.layers.iter().map(|l| l.macs).sum::<u64>().to_string(),
        p.total_cycles.to_string(),
        "100.0%".to_string(),
    ]);
    format!(
        "### {} · {} — per-layer cycles\n\n{}",
        p.model,
        p.schedule,
        md_table(&["layer", "precision", "MACs", "cycles", "share"], &rows)
    )
}

/// Per-class table over one core's cycles (the [`OpClass::ALL`] order).
/// Zero-cycle classes are kept — a vanished class is itself information.
pub fn classes_markdown(label: &str, class_cycles: &[u64], total: u64) -> String {
    let denom = total.max(1);
    let rows: Vec<Vec<String>> = OpClass::ALL
        .iter()
        .map(|cls| {
            let c = class_cycles[cls.index()];
            vec![
                cls.name().to_string(),
                c.to_string(),
                format!("{:.1}%", 100.0 * c as f64 / denom as f64),
            ]
        })
        .collect();
    format!(
        "### {label} — per-micro-op-class cycles\n\n{}",
        md_table(&["class", "cycles", "share"], &rows)
    )
}

/// Full single-core report: per-layer then per-class tables.
pub fn markdown(p: &ProgramProfile) -> String {
    format!(
        "{}\n{}",
        layers_markdown(p),
        classes_markdown(
            &format!("{} · {}", p.model, p.schedule),
            &p.class_cycles,
            p.total_cycles
        )
    )
}

/// CSV of the per-layer rows (one line per layer, plus the total).
pub fn layers_csv(p: &ProgramProfile) -> String {
    let mut rows: Vec<Vec<String>> = p
        .layers
        .iter()
        .map(|l| {
            vec![l.name.clone(), l.precision.clone(), l.macs.to_string(), l.cycles.to_string()]
        })
        .collect();
    rows.push(vec![
        "total".to_string(),
        p.schedule.clone(),
        p.layers.iter().map(|l| l.macs).sum::<u64>().to_string(),
        p.total_cycles.to_string(),
    ]);
    csv(&["layer", "precision", "macs", "cycles"], &rows)
}

/// Sharded report: the aggregated cluster timeline (per-layer
/// `max(shard) + sync`), per-shard totals, and the summed per-class mix
/// (core-cycles — shards overlap in time, so these sum across cores).
pub fn cluster_markdown(c: &ClusterProfile) -> String {
    let model = c.shards.first().map(|p| p.model.as_str()).unwrap_or("-");
    let schedule = c.shards.first().map(|p| p.schedule.as_str()).unwrap_or("-");
    let total = c.timing.total_cycles().max(1);
    let mut rows: Vec<Vec<String>> = c
        .timing
        .layers
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                l.compute_cycles.to_string(),
                l.sync_cycles.to_string(),
                format!(
                    "{:.1}%",
                    100.0 * (l.compute_cycles + l.sync_cycles) as f64 / total as f64
                ),
            ]
        })
        .collect();
    rows.push(vec![
        "**total**".to_string(),
        c.timing.compute_cycles.to_string(),
        c.timing.sync_cycles.to_string(),
        "100.0%".to_string(),
    ]);
    let shard_rows: Vec<Vec<String>> = c
        .shards
        .iter()
        .enumerate()
        .map(|(i, p)| vec![format!("shard {i}"), p.total_cycles.to_string()])
        .collect();
    let class_total: u64 = c.class_cycles().iter().sum();
    format!(
        "### {model} · {schedule} · {} shards — cluster timeline\n\n{}\n{}\n{}",
        c.shards.len(),
        md_table(&["layer", "max-shard cycles", "sync cycles", "share"], &rows),
        md_table(&["core", "compute cycles"], &shard_rows),
        classes_markdown(
            &format!("{model} · {schedule} · all shard cores"),
            &c.class_cycles(),
            class_total
        )
    )
}

/// Staged report: per-stage timeline (layer range, compute, hop cost, busy
/// / bubble split over the profiled stream) and the summed per-class mix
/// (core-cycles — stages overlap in time, so these sum across cores).
pub fn pipeline_markdown(p: &PipelineProfile) -> String {
    let model = p.stages.first().map(|s| s.model.as_str()).unwrap_or("-");
    let schedule = p.stages.first().map(|s| s.schedule.as_str()).unwrap_or("-");
    let total = p.timing.total_cycles();
    let busy = p.timing.busy_cycles();
    let bubbles = p.timing.bubble_cycles();
    let util = p.timing.stage_utilization();
    let stage_rows: Vec<Vec<String>> = p
        .timing
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            vec![
                format!("stage {i}"),
                format!("{}..{}", s.range.0, s.range.1),
                s.compute_cycles.to_string(),
                s.hop_cycles.to_string(),
                busy[i].to_string(),
                bubbles[i].to_string(),
                format!("{:.2}", util[i]),
            ]
        })
        .collect();
    let class_total: u64 = p.class_cycles().iter().sum();
    format!(
        "### {model} · {schedule} · {} stages — pipeline timeline \
         ({} requests streamed: fill {}, period {}, total {total})\n\n{}\n{}",
        p.stages.len(),
        p.timing.tokens,
        p.timing.fill_cycles(),
        p.timing.period_cycles(),
        md_table(
            &["stage", "layers", "compute cycles", "hop cycles", "busy", "bubble", "util"],
            &stage_rows
        ),
        classes_markdown(
            &format!("{model} · {schedule} · all stage cores"),
            &p.class_cycles(),
            class_total
        )
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{LayerCycles, N_CLASSES};

    fn profile() -> ProgramProfile {
        ProgramProfile {
            model: "tiny@2".to_string(),
            schedule: "w2a2".to_string(),
            layers: vec![
                LayerCycles {
                    name: "c1".to_string(),
                    precision: "w2a2".to_string(),
                    macs: 100,
                    cycles: 60,
                },
                LayerCycles {
                    name: "fc".to_string(),
                    precision: "int8".to_string(),
                    macs: 50,
                    cycles: 40,
                },
            ],
            class_cycles: {
                let mut c = [0u64; N_CLASSES];
                c[0] = 70;
                c[5] = 30;
                c
            },
            total_cycles: 100,
        }
    }

    #[test]
    fn tables_carry_every_layer_and_class_with_an_exact_total() {
        let md = markdown(&profile());
        for needle in ["| c1 |", "| fc |", "| **total** |", "| 100 |", "60.0%", "plane_mac"] {
            assert!(md.contains(needle), "missing {needle:?} in:\n{md}");
        }
        // Every class renders, including the zero-cycle ones.
        for cls in crate::obs::OpClass::ALL {
            assert!(md.contains(cls.name()), "missing class {} in:\n{md}", cls.name());
        }
        let csv = layers_csv(&profile());
        assert_eq!(csv.lines().count(), 1 + 2 + 1, "header + layers + total");
        assert!(csv.ends_with("total,w2a2,150,100\n"), "{csv}");
    }
}
