//! Disassembler: render instructions in standard RISC-V / RVV assembly
//! syntax (Quark custom ops use their paper mnemonics). Used by the
//! simulator's trace mode (`Sim::set_trace`) and handy in test failures.

use std::fmt;

use super::instr::{AluOp, FAluOp, Instr, MemWidth, ScalarOp, VIOp, VMemKind, VOp};

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::And => "and",
        AluOp::Or => "or",
        AluOp::Xor => "xor",
        AluOp::Sll => "sll",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::Slt => "slt",
        AluOp::Sltu => "sltu",
        AluOp::Mul => "mul",
        AluOp::Mulh => "mulh",
        AluOp::Div => "div",
        AluOp::Rem => "rem",
    }
}

fn falu_name(op: FAluOp) -> &'static str {
    match op {
        FAluOp::Add => "fadd.s",
        FAluOp::Sub => "fsub.s",
        FAluOp::Mul => "fmul.s",
        FAluOp::Div => "fdiv.s",
        FAluOp::Min => "fmin.s",
        FAluOp::Max => "fmax.s",
    }
}

fn load_name(w: MemWidth, signed: bool) -> &'static str {
    match (w, signed) {
        (MemWidth::B, true) => "lb",
        (MemWidth::B, false) => "lbu",
        (MemWidth::H, true) => "lh",
        (MemWidth::H, false) => "lhu",
        (MemWidth::W, true) => "lw",
        (MemWidth::W, false) => "lwu",
        (MemWidth::D, _) => "ld",
    }
}

fn store_name(w: MemWidth) -> &'static str {
    match w {
        MemWidth::B => "sb",
        MemWidth::H => "sh",
        MemWidth::W => "sw",
        MemWidth::D => "sd",
    }
}

fn viop_name(op: VIOp) -> &'static str {
    match op {
        VIOp::Add => "vadd",
        VIOp::Sub => "vsub",
        VIOp::Rsub => "vrsub",
        VIOp::And => "vand",
        VIOp::Or => "vor",
        VIOp::Xor => "vxor",
        VIOp::Sll => "vsll",
        VIOp::Srl => "vsrl",
        VIOp::Sra => "vsra",
        VIOp::Min => "vmin",
        VIOp::Max => "vmax",
        VIOp::Minu => "vminu",
        VIOp::Maxu => "vmaxu",
        VIOp::Mul => "vmul",
        VIOp::Mulh => "vmulh",
    }
}

impl fmt::Display for ScalarOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use ScalarOp::*;
        match *self {
            Li { rd, imm } => write!(f, "li {rd}, {imm}"),
            Alu { op, rd, rs1, rs2 } => write!(f, "{} {rd}, {rs1}, {rs2}", alu_name(op)),
            AluImm { op, rd, rs1, imm } => write!(f, "{}i {rd}, {rs1}, {imm}", alu_name(op)),
            Load { width, signed, rd, base, offset } => {
                write!(f, "{} {rd}, {offset}({base})", load_name(width, signed))
            }
            Store { width, rs2, base, offset } => {
                write!(f, "{} {rs2}, {offset}({base})", store_name(width))
            }
            Branch { taken } => write!(f, "bne <loop>  # {}", if taken { "taken" } else { "fall-through" }),
            FLoad { rd, base, offset } => write!(f, "flw {rd}, {offset}({base})"),
            FStore { rs2, base, offset } => write!(f, "fsw {rs2}, {offset}({base})"),
            FAlu { op, rd, rs1, rs2 } => write!(f, "{} {rd}, {rs1}, {rs2}", falu_name(op)),
            FMadd { rd, rs1, rs2, rs3 } => write!(f, "fmadd.s {rd}, {rs1}, {rs2}, {rs3}"),
            FCvtWS { rd, rs1 } => write!(f, "fcvt.w.s {rd}, {rs1}"),
            FCvtSW { rd, rs1 } => write!(f, "fcvt.s.w {rd}, {rs1}"),
            FMvXW { rd, rs1 } => write!(f, "fmv.x.w {rd}, {rs1}"),
            FMvWX { rd, rs1 } => write!(f, "fmv.w.x {rd}, {rs1}"),
            CsrReadCycle { rd } => write!(f, "csrr {rd}, cycle"),
            Nop => write!(f, "nop"),
        }
    }
}

impl fmt::Display for VOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use VOp::*;
        match *self {
            Load { kind, eew, vd, base } => match kind {
                VMemKind::UnitStride => write!(f, "vle{}.v {vd}, ({base})", eew.bits()),
                VMemKind::Strided { stride } => {
                    write!(f, "vlse{}.v {vd}, ({base}), {stride}", eew.bits())
                }
            },
            Store { kind, eew, vs3, base } => match kind {
                VMemKind::UnitStride => write!(f, "vse{}.v {vs3}, ({base})", eew.bits()),
                VMemKind::Strided { stride } => {
                    write!(f, "vsse{}.v {vs3}, ({base}), {stride}", eew.bits())
                }
            },
            IVV { op, vd, vs2, vs1 } => write!(f, "{}.vv {vd}, {vs2}, {vs1}", viop_name(op)),
            IVX { op, vd, vs2, rs1 } => write!(f, "{}.vx {vd}, {vs2}, {rs1}", viop_name(op)),
            IVI { op, vd, vs2, imm } => write!(f, "{}.vi {vd}, {vs2}, {imm}", viop_name(op)),
            MaccVX { vd, rs1, vs2 } => write!(f, "vmacc.vx {vd}, {rs1}, {vs2}"),
            MaccVV { vd, vs1, vs2 } => write!(f, "vmacc.vv {vd}, {vs1}, {vs2}"),
            RedSum { vd, vs2, vs1 } => write!(f, "vredsum.vs {vd}, {vs2}, {vs1}"),
            MvXS { rd, vs2 } => write!(f, "vmv.x.s {rd}, {vs2}"),
            MvSX { vd, rs1 } => write!(f, "vmv.s.x {vd}, {rs1}"),
            MvVX { vd, rs1 } => write!(f, "vmv.v.x {vd}, {rs1}"),
            MvVI { vd, imm } => write!(f, "vmv.v.i {vd}, {imm}"),
            Sext { vd, vs2, frac } => write!(f, "vsext.vf{frac} {vd}, {vs2}"),
            Zext { vd, vs2, frac } => write!(f, "vzext.vf{frac} {vd}, {vs2}"),
            MseqVI { vd, vs2, imm } => write!(f, "vmseq.vi {vd}, {vs2}, {imm}"),
            MsneVI { vd, vs2, imm } => write!(f, "vmsne.vi {vd}, {vs2}, {imm}"),
            FMaccVF { vd, rs1, vs2 } => write!(f, "vfmacc.vf {vd}, {rs1}, {vs2}"),
            FAddVV { vd, vs2, vs1 } => write!(f, "vfadd.vv {vd}, {vs2}, {vs1}"),
            FMulVF { vd, vs2, rs1 } => write!(f, "vfmul.vf {vd}, {vs2}, {rs1}"),
            FMaxVF { vd, vs2, rs1 } => write!(f, "vfmax.vf {vd}, {vs2}, {rs1}"),
            FMvVF { vd, rs1 } => write!(f, "vfmv.v.f {vd}, {rs1}"),
            FRedSum { vd, vs2, vs1 } => write!(f, "vfredusum.vs {vd}, {vs2}, {vs1}"),
            Popcnt { vd, vs2 } => write!(f, "vpopcnt.v {vd}, {vs2}"),
            Shacc { vd, vs2, shamt } => write!(f, "vshacc.vi {vd}, {vs2}, {shamt}"),
            Bitpack { vd, vs2, bit } => write!(f, "vbitpack.vi {vd}, {vs2}, {bit}"),
        }
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Instr::Scalar(op) => write!(f, "{op}"),
            Instr::VSetVli { rd, avl, vtype } => write!(f, "vsetvli {rd}, {avl}, {vtype}"),
            Instr::Vector(op) => write!(f, "{op}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::instr::*;
    use super::super::reg::{FReg, Reg, VReg};
    use super::super::vtype::{Lmul, Sew, VType};

    #[test]
    fn custom_op_mnemonics() {
        assert_eq!(
            Instr::Vector(VOp::Popcnt { vd: VReg(3), vs2: VReg(7) }).to_string(),
            "vpopcnt.v v3, v7"
        );
        assert_eq!(
            Instr::Vector(VOp::Shacc { vd: VReg(1), vs2: VReg(2), shamt: 1 }).to_string(),
            "vshacc.vi v1, v2, 1"
        );
        assert_eq!(
            Instr::Vector(VOp::Bitpack { vd: VReg(8), vs2: VReg(0), bit: 3 }).to_string(),
            "vbitpack.vi v8, v0, 3"
        );
    }

    #[test]
    fn standard_syntax() {
        assert_eq!(
            Instr::Scalar(ScalarOp::Load {
                width: MemWidth::B,
                signed: false,
                rd: Reg(6),
                base: Reg(18),
                offset: 24
            })
            .to_string(),
            "lbu x6, 24(x18)"
        );
        assert_eq!(
            Instr::Vector(VOp::IVX { op: VIOp::And, vd: VReg(12), vs2: VReg(4), rs1: Reg(6) })
                .to_string(),
            "vand.vx v12, v4, x6"
        );
        assert_eq!(
            Instr::Scalar(ScalarOp::FMadd { rd: FReg(5), rs1: FReg(1), rs2: FReg(24), rs3: FReg(3) })
                .to_string(),
            "fmadd.s f5, f1, f24, f3"
        );
        assert_eq!(
            Instr::VSetVli { rd: Reg(0), avl: 64, vtype: VType::new(Sew::E64, Lmul::M1) }
                .to_string(),
            "vsetvli x0, 64, e64,m1"
        );
    }

    #[test]
    fn every_roundtrippable_word_disassembles_nonempty() {
        // Cross-check with the decoder: decoding any valid encoding must
        // produce something the disassembler renders.
        use super::super::{decode::decode, encode::encode};
        let i = Instr::Vector(VOp::MaccVX { vd: VReg(8), rs1: Reg(11), vs2: VReg(16) });
        let w = encode(&i).unwrap();
        let d = decode(w).unwrap();
        assert_eq!(d.to_string(), "vmacc.vx v8, x11, v16");
    }
}
