//! Decoder: inverse of [`crate::isa::encode`].
//!
//! Canonicalization notes (real assembly aliases):
//! * `addi rd, x0, imm` decodes to [`ScalarOp::Li`] (the canonical form the
//!   kernels emit); `addi x0, x0, 0` decodes to [`ScalarOp::Nop`].
//! * `vsetivli` decodes to [`Instr::VSetVli`] with the immediate AVL.

use super::encode::{
    fld, freg_at, reg_at, vreg_at, OPCFG, OPC_BRANCH, OPC_LOAD, OPC_LOAD_FP, OPC_MADD, OPC_OP,
    OPC_OP_FP, OPC_OP_IMM, OPC_OP_V, OPC_STORE, OPC_STORE_FP, OPC_SYSTEM, OPFVF, OPFVV, OPIVI,
    OPIVV, OPIVX, OPMVV, OPMVX,
};
use super::instr::{AluOp, FAluOp, Instr, MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use super::quark::{F6_VBITPACK, F6_VPOPCNT, F6_VSHACC, OPC_CUSTOM2};
use super::vtype::{Sew, VType};

fn sext(v: u32, bits: u32) -> i64 {
    let shift = 64 - bits;
    (((v as i64) << shift) >> shift) as i64
}

fn i_imm(w: u32) -> i64 {
    sext(fld(w, 20, 12), 12)
}

fn s_imm(w: u32) -> i64 {
    sext((fld(w, 25, 7) << 5) | fld(w, 7, 5), 12)
}

fn viop_from_funct6_i(f6: u32) -> Option<VIOp> {
    Some(match f6 {
        0b000000 => VIOp::Add,
        0b000010 => VIOp::Sub,
        0b000011 => VIOp::Rsub,
        0b000100 => VIOp::Minu,
        0b000101 => VIOp::Min,
        0b000110 => VIOp::Maxu,
        0b000111 => VIOp::Max,
        0b001001 => VIOp::And,
        0b001010 => VIOp::Or,
        0b001011 => VIOp::Xor,
        0b100101 => VIOp::Sll,
        0b101000 => VIOp::Srl,
        0b101001 => VIOp::Sra,
        _ => return None,
    })
}

fn mem_eew(f3: u32) -> Option<Sew> {
    Some(match f3 {
        0b000 => Sew::E8,
        0b101 => Sew::E16,
        0b110 => Sew::E32,
        0b111 => Sew::E64,
        _ => return None,
    })
}

/// Decode one 32-bit word. Returns `None` for words outside the implemented
/// subset (a real core would trap with an illegal-instruction exception).
pub fn decode(w: u32) -> Option<Instr> {
    let opc = fld(w, 0, 7);
    let f3 = fld(w, 12, 3);
    match opc {
        OPC_OP_IMM => {
            let rd = reg_at(w, 7);
            let rs1 = reg_at(w, 15);
            match f3 {
                0b000 => {
                    let imm = i_imm(w);
                    if rd.0 == 0 && rs1.0 == 0 && imm == 0 {
                        Some(Instr::Scalar(ScalarOp::Nop))
                    } else if rs1.0 == 0 {
                        Some(Instr::Scalar(ScalarOp::Li { rd, imm }))
                    } else {
                        Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::Add, rd, rs1, imm }))
                    }
                }
                0b001 => Some(Instr::Scalar(ScalarOp::AluImm {
                    op: AluOp::Sll,
                    rd,
                    rs1,
                    imm: fld(w, 20, 6) as i64,
                })),
                0b101 => {
                    let op = if fld(w, 26, 6) == 0b010000 { AluOp::Sra } else { AluOp::Srl };
                    Some(Instr::Scalar(ScalarOp::AluImm { op, rd, rs1, imm: fld(w, 20, 6) as i64 }))
                }
                0b010 => Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::Slt, rd, rs1, imm: i_imm(w) })),
                0b011 => Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::Sltu, rd, rs1, imm: i_imm(w) })),
                0b100 => Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::Xor, rd, rs1, imm: i_imm(w) })),
                0b110 => Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::Or, rd, rs1, imm: i_imm(w) })),
                0b111 => Some(Instr::Scalar(ScalarOp::AluImm { op: AluOp::And, rd, rs1, imm: i_imm(w) })),
                _ => None,
            }
        }
        OPC_OP => {
            let rd = reg_at(w, 7);
            let rs1 = reg_at(w, 15);
            let rs2 = reg_at(w, 20);
            let f7 = fld(w, 25, 7);
            let op = match (f3, f7) {
                (0b000, 0b0000000) => AluOp::Add,
                (0b000, 0b0100000) => AluOp::Sub,
                (0b001, 0b0000000) => AluOp::Sll,
                (0b010, 0b0000000) => AluOp::Slt,
                (0b011, 0b0000000) => AluOp::Sltu,
                (0b100, 0b0000000) => AluOp::Xor,
                (0b101, 0b0000000) => AluOp::Srl,
                (0b101, 0b0100000) => AluOp::Sra,
                (0b110, 0b0000000) => AluOp::Or,
                (0b111, 0b0000000) => AluOp::And,
                (0b000, 0b0000001) => AluOp::Mul,
                (0b001, 0b0000001) => AluOp::Mulh,
                (0b100, 0b0000001) => AluOp::Div,
                (0b110, 0b0000001) => AluOp::Rem,
                _ => return None,
            };
            Some(Instr::Scalar(ScalarOp::Alu { op, rd, rs1, rs2 }))
        }
        OPC_LOAD => {
            let (width, signed) = match f3 {
                0b000 => (MemWidth::B, true),
                0b001 => (MemWidth::H, true),
                0b010 => (MemWidth::W, true),
                0b011 => (MemWidth::D, true),
                0b100 => (MemWidth::B, false),
                0b101 => (MemWidth::H, false),
                0b110 => (MemWidth::W, false),
                _ => return None,
            };
            Some(Instr::Scalar(ScalarOp::Load {
                width,
                signed,
                rd: reg_at(w, 7),
                base: reg_at(w, 15),
                offset: i_imm(w),
            }))
        }
        OPC_STORE => {
            let width = match f3 {
                0b000 => MemWidth::B,
                0b001 => MemWidth::H,
                0b010 => MemWidth::W,
                0b011 => MemWidth::D,
                _ => return None,
            };
            Some(Instr::Scalar(ScalarOp::Store {
                width,
                rs2: reg_at(w, 20),
                base: reg_at(w, 15),
                offset: s_imm(w),
            }))
        }
        OPC_BRANCH => Some(Instr::Scalar(ScalarOp::Branch { taken: fld(w, 20, 5) != 0 })),
        OPC_LOAD_FP => {
            // Scalar flw (f3=010 with no vector width meaning) vs vector load.
            if f3 == 0b010 {
                return Some(Instr::Scalar(ScalarOp::FLoad {
                    rd: freg_at(w, 7),
                    base: reg_at(w, 15),
                    offset: i_imm(w),
                }));
            }
            let eew = mem_eew(f3)?;
            let mop = fld(w, 26, 2);
            let kind = match mop {
                0b00 => VMemKind::UnitStride,
                0b10 => VMemKind::Strided { stride: reg_at(w, 20) },
                _ => return None,
            };
            Some(Instr::Vector(VOp::Load { kind, eew, vd: vreg_at(w, 7), base: reg_at(w, 15) }))
        }
        OPC_STORE_FP => {
            if f3 == 0b010 {
                return Some(Instr::Scalar(ScalarOp::FStore {
                    rs2: freg_at(w, 20),
                    base: reg_at(w, 15),
                    offset: s_imm(w),
                }));
            }
            let eew = mem_eew(f3)?;
            let mop = fld(w, 26, 2);
            let kind = match mop {
                0b00 => VMemKind::UnitStride,
                0b10 => VMemKind::Strided { stride: reg_at(w, 20) },
                _ => return None,
            };
            Some(Instr::Vector(VOp::Store { kind, eew, vs3: vreg_at(w, 7), base: reg_at(w, 15) }))
        }
        OPC_OP_FP => {
            let f7 = fld(w, 25, 7);
            match f7 {
                0b1100000 => Some(Instr::Scalar(ScalarOp::FCvtWS { rd: reg_at(w, 7), rs1: freg_at(w, 15) })),
                0b1101000 => Some(Instr::Scalar(ScalarOp::FCvtSW { rd: freg_at(w, 7), rs1: reg_at(w, 15) })),
                0b1110000 => Some(Instr::Scalar(ScalarOp::FMvXW { rd: reg_at(w, 7), rs1: freg_at(w, 15) })),
                0b1111000 => Some(Instr::Scalar(ScalarOp::FMvWX { rd: freg_at(w, 7), rs1: reg_at(w, 15) })),
                _ => {
                    let op = match (f7, f3) {
                        (0b0000000, _) => FAluOp::Add,
                        (0b0000100, _) => FAluOp::Sub,
                        (0b0001000, _) => FAluOp::Mul,
                        (0b0001100, _) => FAluOp::Div,
                        (0b0010100, 0b000) => FAluOp::Min,
                        (0b0010100, 0b001) => FAluOp::Max,
                        _ => return None,
                    };
                    Some(Instr::Scalar(ScalarOp::FAlu {
                        op,
                        rd: freg_at(w, 7),
                        rs1: freg_at(w, 15),
                        rs2: freg_at(w, 20),
                    }))
                }
            }
        }
        OPC_MADD => Some(Instr::Scalar(ScalarOp::FMadd {
            rd: freg_at(w, 7),
            rs1: freg_at(w, 15),
            rs2: freg_at(w, 20),
            rs3: freg_at(w, 27),
        })),
        OPC_SYSTEM => {
            if f3 == 0b010 && fld(w, 20, 12) == 0xC00 {
                Some(Instr::Scalar(ScalarOp::CsrReadCycle { rd: reg_at(w, 7) }))
            } else {
                None
            }
        }
        OPC_OP_V => decode_opv(w, f3),
        OPC_CUSTOM2 => decode_custom(w, f3),
        _ => None,
    }
}

fn decode_opv(w: u32, f3: u32) -> Option<Instr> {
    let f6 = fld(w, 26, 6);
    let vd = vreg_at(w, 7);
    let vs1 = vreg_at(w, 15);
    let vs2 = vreg_at(w, 20);
    let rs1 = reg_at(w, 15);
    let fs1 = freg_at(w, 15);
    let imm = sext(fld(w, 15, 5), 5);
    match f3 {
        OPCFG => {
            // Only vsetivli (bits 31:30 == 11) is in the subset.
            if fld(w, 30, 2) != 0b11 {
                return None;
            }
            let vtype = VType::from_encoding(fld(w, 20, 10))?;
            Some(Instr::VSetVli { rd: reg_at(w, 7), avl: fld(w, 15, 5) as u64, vtype })
        }
        OPIVV => Some(Instr::Vector(VOp::IVV { op: viop_from_funct6_i(f6)?, vd, vs2, vs1 })),
        OPIVX => {
            if f6 == 0b010111 && vs2.0 == 0 {
                return Some(Instr::Vector(VOp::MvVX { vd, rs1 }));
            }
            Some(Instr::Vector(VOp::IVX { op: viop_from_funct6_i(f6)?, vd, vs2, rs1 }))
        }
        OPIVI => match f6 {
            0b010111 if vs2.0 == 0 => Some(Instr::Vector(VOp::MvVI { vd, imm })),
            0b011000 => Some(Instr::Vector(VOp::MseqVI { vd, vs2, imm })),
            0b011001 => Some(Instr::Vector(VOp::MsneVI { vd, vs2, imm })),
            _ => {
                let op = viop_from_funct6_i(f6)?;
                let imm = if matches!(op, VIOp::Sll | VIOp::Srl | VIOp::Sra) {
                    fld(w, 15, 5) as i64
                } else {
                    imm
                };
                Some(Instr::Vector(VOp::IVI { op, vd, vs2, imm }))
            }
        },
        OPMVV => match f6 {
            0b000000 => Some(Instr::Vector(VOp::RedSum { vd, vs2, vs1 })),
            0b010000 if vs1.0 == 0 => Some(Instr::Vector(VOp::MvXS { rd: reg_at(w, 7), vs2 })),
            0b010010 => match vs1.0 {
                0b00010 => Some(Instr::Vector(VOp::Zext { vd, vs2, frac: 8 })),
                0b00011 => Some(Instr::Vector(VOp::Sext { vd, vs2, frac: 8 })),
                0b00100 => Some(Instr::Vector(VOp::Zext { vd, vs2, frac: 4 })),
                0b00101 => Some(Instr::Vector(VOp::Sext { vd, vs2, frac: 4 })),
                0b00110 => Some(Instr::Vector(VOp::Zext { vd, vs2, frac: 2 })),
                0b00111 => Some(Instr::Vector(VOp::Sext { vd, vs2, frac: 2 })),
                _ => None,
            },
            0b100101 => Some(Instr::Vector(VOp::IVV { op: VIOp::Mul, vd, vs2, vs1 })),
            0b100111 => Some(Instr::Vector(VOp::IVV { op: VIOp::Mulh, vd, vs2, vs1 })),
            0b101101 => Some(Instr::Vector(VOp::MaccVV { vd, vs1, vs2 })),
            _ => None,
        },
        OPMVX => match f6 {
            0b010000 if vs2.0 == 0 => Some(Instr::Vector(VOp::MvSX { vd, rs1 })),
            0b100101 => Some(Instr::Vector(VOp::IVX { op: VIOp::Mul, vd, vs2, rs1 })),
            0b100111 => Some(Instr::Vector(VOp::IVX { op: VIOp::Mulh, vd, vs2, rs1 })),
            0b101101 => Some(Instr::Vector(VOp::MaccVX { vd, rs1, vs2 })),
            _ => None,
        },
        OPFVV => match f6 {
            0b000000 => Some(Instr::Vector(VOp::FAddVV { vd, vs2, vs1 })),
            0b000001 => Some(Instr::Vector(VOp::FRedSum { vd, vs2, vs1 })),
            _ => None,
        },
        OPFVF => match f6 {
            0b101100 => Some(Instr::Vector(VOp::FMaccVF { vd, rs1: fs1, vs2 })),
            0b100100 => Some(Instr::Vector(VOp::FMulVF { vd, vs2, rs1: fs1 })),
            0b000110 => Some(Instr::Vector(VOp::FMaxVF { vd, vs2, rs1: fs1 })),
            0b010111 if vs2.0 == 0 => Some(Instr::Vector(VOp::FMvVF { vd, rs1: fs1 })),
            _ => None,
        },
        _ => None,
    }
}

fn decode_custom(w: u32, f3: u32) -> Option<Instr> {
    let f6 = fld(w, 26, 6);
    let vd = vreg_at(w, 7);
    let vs2 = vreg_at(w, 20);
    let uimm = fld(w, 15, 5) as u8;
    match (f6, f3) {
        (F6_VPOPCNT, OPIVV) => Some(Instr::Vector(VOp::Popcnt { vd, vs2 })),
        (F6_VSHACC, OPIVI) => Some(Instr::Vector(VOp::Shacc { vd, vs2, shamt: uimm })),
        (F6_VBITPACK, OPIVI) => Some(Instr::Vector(VOp::Bitpack { vd, vs2, bit: uimm })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::*;
    use crate::isa::reg::{FReg, Reg, VReg};

    fn rt(i: Instr) {
        let w = encode(&i).unwrap_or_else(|| panic!("{i:?} should encode"));
        assert_eq!(decode(w), Some(i), "roundtrip failed for {i:?} (word {w:#010x})");
    }

    #[test]
    fn custom_instruction_roundtrip() {
        rt(Instr::Vector(VOp::Popcnt { vd: VReg(3), vs2: VReg(7) }));
        rt(Instr::Vector(VOp::Shacc { vd: VReg(1), vs2: VReg(2), shamt: 1 }));
        rt(Instr::Vector(VOp::Bitpack { vd: VReg(31), vs2: VReg(30), bit: 7 }));
    }

    #[test]
    fn scalar_roundtrip_spotchecks() {
        rt(Instr::Scalar(ScalarOp::Li { rd: Reg(5), imm: -7 }));
        rt(Instr::Scalar(ScalarOp::Alu { op: AluOp::Mul, rd: Reg(1), rs1: Reg(2), rs2: Reg(3) }));
        rt(Instr::Scalar(ScalarOp::Load {
            width: MemWidth::B,
            signed: false,
            rd: Reg(9),
            base: Reg(10),
            offset: 33,
        }));
        rt(Instr::Scalar(ScalarOp::Store { width: MemWidth::D, rs2: Reg(4), base: Reg(2), offset: -8 }));
        rt(Instr::Scalar(ScalarOp::FMadd { rd: FReg(1), rs1: FReg(2), rs2: FReg(3), rs3: FReg(4) }));
        rt(Instr::Scalar(ScalarOp::CsrReadCycle { rd: Reg(14) }));
    }

    #[test]
    fn vector_roundtrip_spotchecks() {
        rt(Instr::Vector(VOp::IVV { op: VIOp::And, vd: VReg(1), vs2: VReg(2), vs1: VReg(3) }));
        rt(Instr::Vector(VOp::IVX { op: VIOp::Mul, vd: VReg(1), vs2: VReg(2), rs1: Reg(3) }));
        rt(Instr::Vector(VOp::MaccVX { vd: VReg(8), rs1: Reg(11), vs2: VReg(16) }));
        rt(Instr::Vector(VOp::Load {
            kind: VMemKind::Strided { stride: Reg(6) },
            eew: Sew::E8,
            vd: VReg(2),
            base: Reg(10),
        }));
        rt(Instr::VSetVli {
            rd: Reg(1),
            avl: 16,
            vtype: VType::new(Sew::E64, crate::isa::vtype::Lmul::M1),
        });
    }

    #[test]
    fn illegal_words_decode_to_none() {
        assert_eq!(decode(0xFFFF_FFFF), None);
        assert_eq!(decode(0), None);
    }
}
