//! 32-bit instruction encodings.
//!
//! Scalar instructions use the real RV64IMF formats; vector instructions use
//! the real RVV 1.0 OP-V layouts (funct6 / vm / vs2 / vs1 / funct3 / vd);
//! Quark's custom instructions use the custom-2 major opcode with an
//! OP-V-like layout (see [`crate::isa::quark`]).
//!
//! `encode` returns `None` for dynamic-form instructions that have no
//! single-word encoding (e.g. `li` with a >12-bit immediate, which a real
//! assembler expands to `lui+addi`, or `vsetvli` with AVL ≥ 32, which takes
//! AVL from a register the trace no longer names). Round-trip
//! (`decode(encode(i)) == i`) holds for everything encodable — see the
//! proptest suite in `rust/tests/isa_roundtrip.rs`.

use super::instr::{AluOp, FAluOp, Instr, MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use super::quark::{F6_VBITPACK, F6_VPOPCNT, F6_VSHACC, OPC_CUSTOM2};
use super::reg::{FReg, Reg, VReg};
use super::vtype::Sew;

// Major opcodes.
pub(crate) const OPC_OP: u32 = 0x33;
pub(crate) const OPC_OP_IMM: u32 = 0x13;
pub(crate) const OPC_LOAD: u32 = 0x03;
pub(crate) const OPC_STORE: u32 = 0x23;
pub(crate) const OPC_BRANCH: u32 = 0x63;
pub(crate) const OPC_LOAD_FP: u32 = 0x07;
pub(crate) const OPC_STORE_FP: u32 = 0x27;
pub(crate) const OPC_OP_FP: u32 = 0x53;
pub(crate) const OPC_MADD: u32 = 0x43;
pub(crate) const OPC_SYSTEM: u32 = 0x73;
pub(crate) const OPC_OP_V: u32 = 0x57;

fn r_type(funct7: u32, rs2: u32, rs1: u32, funct3: u32, rd: u32, opc: u32) -> u32 {
    (funct7 << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opc
}

fn i_type(imm: i64, rs1: u32, funct3: u32, rd: u32, opc: u32) -> Option<u32> {
    if !(-2048..=2047).contains(&imm) {
        return None;
    }
    let imm12 = (imm as u32) & 0xFFF;
    Some((imm12 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opc)
}

fn s_type(imm: i64, rs2: u32, rs1: u32, funct3: u32, opc: u32) -> Option<u32> {
    if !(-2048..=2047).contains(&imm) {
        return None;
    }
    let imm = (imm as u32) & 0xFFF;
    Some(((imm >> 5) << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | ((imm & 0x1F) << 7) | opc)
}

fn alu_f3f7(op: AluOp) -> (u32, u32) {
    match op {
        AluOp::Add => (0b000, 0b0000000),
        AluOp::Sub => (0b000, 0b0100000),
        AluOp::Sll => (0b001, 0b0000000),
        AluOp::Slt => (0b010, 0b0000000),
        AluOp::Sltu => (0b011, 0b0000000),
        AluOp::Xor => (0b100, 0b0000000),
        AluOp::Srl => (0b101, 0b0000000),
        AluOp::Sra => (0b101, 0b0100000),
        AluOp::Or => (0b110, 0b0000000),
        AluOp::And => (0b111, 0b0000000),
        AluOp::Mul => (0b000, 0b0000001),
        AluOp::Mulh => (0b001, 0b0000001),
        AluOp::Div => (0b100, 0b0000001),
        AluOp::Rem => (0b110, 0b0000001),
    }
}

fn load_f3(width: MemWidth, signed: bool) -> u32 {
    match (width, signed) {
        (MemWidth::B, true) => 0b000,
        (MemWidth::H, true) => 0b001,
        (MemWidth::W, true) => 0b010,
        (MemWidth::D, _) => 0b011,
        (MemWidth::B, false) => 0b100,
        (MemWidth::H, false) => 0b101,
        (MemWidth::W, false) => 0b110,
    }
}

fn store_f3(width: MemWidth) -> u32 {
    match width {
        MemWidth::B => 0b000,
        MemWidth::H => 0b001,
        MemWidth::W => 0b010,
        MemWidth::D => 0b011,
    }
}

fn falu_f7f3(op: FAluOp) -> (u32, u32) {
    // rm=dyn (0b111) for arithmetic; fmin/fmax use funct3 as the selector.
    match op {
        FAluOp::Add => (0b0000000, 0b111),
        FAluOp::Sub => (0b0000100, 0b111),
        FAluOp::Mul => (0b0001000, 0b111),
        FAluOp::Div => (0b0001100, 0b111),
        FAluOp::Min => (0b0010100, 0b000),
        FAluOp::Max => (0b0010100, 0b001),
    }
}

// RVV funct3 (instruction class within OP-V).
pub(crate) const OPIVV: u32 = 0b000;
pub(crate) const OPFVV: u32 = 0b001;
pub(crate) const OPMVV: u32 = 0b010;
pub(crate) const OPIVI: u32 = 0b011;
pub(crate) const OPIVX: u32 = 0b100;
pub(crate) const OPFVF: u32 = 0b101;
pub(crate) const OPMVX: u32 = 0b110;
pub(crate) const OPCFG: u32 = 0b111;

pub(crate) fn viop_funct6(op: VIOp) -> u32 {
    match op {
        VIOp::Add => 0b000000,
        VIOp::Sub => 0b000010,
        VIOp::Rsub => 0b000011,
        VIOp::Minu => 0b000100,
        VIOp::Min => 0b000101,
        VIOp::Maxu => 0b000110,
        VIOp::Max => 0b000111,
        VIOp::And => 0b001001,
        VIOp::Or => 0b001010,
        VIOp::Xor => 0b001011,
        VIOp::Sll => 0b100101,
        VIOp::Srl => 0b101000,
        VIOp::Sra => 0b101001,
        // vmul/vmulh live in the OPMVV/OPMVX space.
        VIOp::Mul => 0b100101,
        VIOp::Mulh => 0b100111,
    }
}

fn vop_v(funct6: u32, vm: u32, vs2: u32, vs1: u32, funct3: u32, vd: u32, opc: u32) -> u32 {
    (funct6 << 26) | (vm << 25) | (vs2 << 20) | (vs1 << 15) | (funct3 << 12) | (vd << 7) | opc
}

fn imm5(imm: i64) -> Option<u32> {
    if !(-16..=15).contains(&imm) {
        return None;
    }
    Some((imm as u32) & 0x1F)
}

fn vmem_width_f3(eew: Sew) -> u32 {
    match eew {
        Sew::E8 => 0b000,
        Sew::E16 => 0b101,
        Sew::E32 => 0b110,
        Sew::E64 => 0b111,
    }
}

/// Encode one instruction to its 32-bit word, or `None` if this dynamic form
/// has no single-word encoding (see module docs).
pub fn encode(instr: &Instr) -> Option<u32> {
    match *instr {
        Instr::Scalar(op) => encode_scalar(op),
        Instr::VSetVli { rd, avl, vtype } => {
            // vsetivli: bits 31:30 = 11, zimm10 = vtype, uimm5 (AVL) in rs1.
            if avl >= 32 {
                return None;
            }
            let zimm = vtype.encoding() & 0x3FF;
            Some(
                (0b11 << 30)
                    | (zimm << 20)
                    | ((avl as u32) << 15)
                    | (OPCFG << 12)
                    | ((rd.0 as u32) << 7)
                    | OPC_OP_V,
            )
        }
        Instr::Vector(v) => encode_vector(v),
    }
}

fn encode_scalar(op: ScalarOp) -> Option<u32> {
    use ScalarOp::*;
    match op {
        Li { rd, imm } => i_type(imm, 0, 0b000, rd.0 as u32, OPC_OP_IMM),
        Alu { op, rd, rs1, rs2 } => {
            let (f3, f7) = alu_f3f7(op);
            Some(r_type(f7, rs2.0 as u32, rs1.0 as u32, f3, rd.0 as u32, OPC_OP))
        }
        AluImm { op, rd, rs1, imm } => {
            let (f3, f7) = alu_f3f7(op);
            match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                    // RV64 shifts: 6-bit shamt, funct7[6:1] selects the op.
                    if !(0..64).contains(&imm) {
                        return None;
                    }
                    Some(
                        ((f7 >> 1) << 26)
                            | ((imm as u32) << 20)
                            | ((rs1.0 as u32) << 15)
                            | (f3 << 12)
                            | ((rd.0 as u32) << 7)
                            | OPC_OP_IMM,
                    )
                }
                AluOp::Add | AluOp::And | AluOp::Or | AluOp::Xor | AluOp::Slt | AluOp::Sltu => {
                    i_type(imm, rs1.0 as u32, f3, rd.0 as u32, OPC_OP_IMM)
                }
                // No immediate forms exist.
                _ => None,
            }
        }
        Load { width, signed, rd, base, offset } => {
            // `ld`/`lwu` etc.; unsigned `ld` is canonicalized to signed.
            let signed = signed || width == MemWidth::D;
            i_type(offset, base.0 as u32, load_f3(width, signed), rd.0 as u32, OPC_LOAD)
        }
        Store { width, rs2, base, offset } => {
            s_type(offset, rs2.0 as u32, base.0 as u32, store_f3(width), OPC_STORE)
        }
        // Pseudo-marker: beq/bne x0,x0 with `taken` carried in rs2.
        Branch { taken } => Some(r_type(0, taken as u32, 0, 0b000, 0, OPC_BRANCH)),
        FLoad { rd, base, offset } => i_type(offset, base.0 as u32, 0b010, rd.0 as u32, OPC_LOAD_FP),
        FStore { rs2, base, offset } => {
            s_type(offset, rs2.0 as u32, base.0 as u32, 0b010, OPC_STORE_FP)
        }
        FAlu { op, rd, rs1, rs2 } => {
            let (f7, f3) = falu_f7f3(op);
            Some(r_type(f7, rs2.0 as u32, rs1.0 as u32, f3, rd.0 as u32, OPC_OP_FP))
        }
        FMadd { rd, rs1, rs2, rs3 } => Some(
            ((rs3.0 as u32) << 27)
                | ((rs2.0 as u32) << 20)
                | ((rs1.0 as u32) << 15)
                | (0b111 << 12)
                | ((rd.0 as u32) << 7)
                | OPC_MADD,
        ),
        FCvtWS { rd, rs1 } => Some(r_type(0b1100000, 0, rs1.0 as u32, 0b111, rd.0 as u32, OPC_OP_FP)),
        FCvtSW { rd, rs1 } => Some(r_type(0b1101000, 0, rs1.0 as u32, 0b111, rd.0 as u32, OPC_OP_FP)),
        FMvXW { rd, rs1 } => Some(r_type(0b1110000, 0, rs1.0 as u32, 0b000, rd.0 as u32, OPC_OP_FP)),
        FMvWX { rd, rs1 } => Some(r_type(0b1111000, 0, rs1.0 as u32, 0b000, rd.0 as u32, OPC_OP_FP)),
        // csrrs rd, cycle(0xC00), x0
        CsrReadCycle { rd } => i_type(-1024, 0, 0b010, rd.0 as u32, OPC_SYSTEM),
        Nop => i_type(0, 0, 0b000, 0, OPC_OP_IMM),
    }
}

fn encode_vector(v: VOp) -> Option<u32> {
    use VOp::*;
    let vm = 1; // kernels run unmasked
    match v {
        Load { kind, eew, vd, base } => {
            let w = vmem_width_f3(eew);
            let (mop, rs2) = match kind {
                VMemKind::UnitStride => (0b00u32, 0u32),
                VMemKind::Strided { stride } => (0b10, stride.0 as u32),
            };
            Some(
                (mop << 26)
                    | (vm << 25)
                    | (rs2 << 20)
                    | ((base.0 as u32) << 15)
                    | (w << 12)
                    | ((vd.0 as u32) << 7)
                    | OPC_LOAD_FP,
            )
        }
        Store { kind, eew, vs3, base } => {
            let w = vmem_width_f3(eew);
            let (mop, rs2) = match kind {
                VMemKind::UnitStride => (0b00u32, 0u32),
                VMemKind::Strided { stride } => (0b10, stride.0 as u32),
            };
            Some(
                (mop << 26)
                    | (vm << 25)
                    | (rs2 << 20)
                    | ((base.0 as u32) << 15)
                    | (w << 12)
                    | ((vs3.0 as u32) << 7)
                    | OPC_STORE_FP,
            )
        }
        IVV { op, vd, vs2, vs1 } => {
            let (f6, f3) = match op {
                VIOp::Mul => (0b100101, OPMVV),
                VIOp::Mulh => (0b100111, OPMVV),
                _ => (viop_funct6(op), OPIVV),
            };
            Some(vop_v(f6, vm, vs2.0 as u32, vs1.0 as u32, f3, vd.0 as u32, OPC_OP_V))
        }
        IVX { op, vd, vs2, rs1 } => {
            let (f6, f3) = match op {
                VIOp::Mul => (0b100101, OPMVX),
                VIOp::Mulh => (0b100111, OPMVX),
                _ => (viop_funct6(op), OPIVX),
            };
            Some(vop_v(f6, vm, vs2.0 as u32, rs1.0 as u32, f3, vd.0 as u32, OPC_OP_V))
        }
        IVI { op, vd, vs2, imm } => {
            // No vi forms for sub/min/max/mul families we use them with.
            let ok = matches!(
                op,
                VIOp::Add | VIOp::Rsub | VIOp::And | VIOp::Or | VIOp::Xor | VIOp::Sll
                    | VIOp::Srl | VIOp::Sra
            );
            if !ok {
                return None;
            }
            let imm = if matches!(op, VIOp::Sll | VIOp::Srl | VIOp::Sra) {
                if !(0..32).contains(&imm) {
                    return None;
                }
                (imm as u32) & 0x1F
            } else {
                imm5(imm)?
            };
            Some(vop_v(viop_funct6(op), vm, vs2.0 as u32, imm, OPIVI, vd.0 as u32, OPC_OP_V))
        }
        MaccVX { vd, rs1, vs2 } => {
            Some(vop_v(0b101101, vm, vs2.0 as u32, rs1.0 as u32, OPMVX, vd.0 as u32, OPC_OP_V))
        }
        MaccVV { vd, vs1, vs2 } => {
            Some(vop_v(0b101101, vm, vs2.0 as u32, vs1.0 as u32, OPMVV, vd.0 as u32, OPC_OP_V))
        }
        RedSum { vd, vs2, vs1 } => {
            Some(vop_v(0b000000, vm, vs2.0 as u32, vs1.0 as u32, OPMVV, vd.0 as u32, OPC_OP_V))
        }
        MvXS { rd, vs2 } => {
            Some(vop_v(0b010000, vm, vs2.0 as u32, 0, OPMVV, rd.0 as u32, OPC_OP_V))
        }
        MvSX { vd, rs1 } => {
            Some(vop_v(0b010000, vm, 0, rs1.0 as u32, OPMVX, vd.0 as u32, OPC_OP_V))
        }
        MvVX { vd, rs1 } => {
            Some(vop_v(0b010111, vm, 0, rs1.0 as u32, OPIVX, vd.0 as u32, OPC_OP_V))
        }
        MvVI { vd, imm } => {
            Some(vop_v(0b010111, vm, 0, imm5(imm)?, OPIVI, vd.0 as u32, OPC_OP_V))
        }
        Sext { vd, vs2, frac } => {
            let vs1 = match frac {
                8 => 0b00011,
                4 => 0b00101,
                2 => 0b00111,
                _ => return None,
            };
            Some(vop_v(0b010010, vm, vs2.0 as u32, vs1, OPMVV, vd.0 as u32, OPC_OP_V))
        }
        Zext { vd, vs2, frac } => {
            let vs1 = match frac {
                8 => 0b00010,
                4 => 0b00100,
                2 => 0b00110,
                _ => return None,
            };
            Some(vop_v(0b010010, vm, vs2.0 as u32, vs1, OPMVV, vd.0 as u32, OPC_OP_V))
        }
        MseqVI { vd, vs2, imm } => {
            Some(vop_v(0b011000, vm, vs2.0 as u32, imm5(imm)?, OPIVI, vd.0 as u32, OPC_OP_V))
        }
        MsneVI { vd, vs2, imm } => {
            Some(vop_v(0b011001, vm, vs2.0 as u32, imm5(imm)?, OPIVI, vd.0 as u32, OPC_OP_V))
        }
        FMaccVF { vd, rs1, vs2 } => {
            Some(vop_v(0b101100, vm, vs2.0 as u32, rs1.0 as u32, OPFVF, vd.0 as u32, OPC_OP_V))
        }
        FAddVV { vd, vs2, vs1 } => {
            Some(vop_v(0b000000, vm, vs2.0 as u32, vs1.0 as u32, OPFVV, vd.0 as u32, OPC_OP_V))
        }
        FMulVF { vd, vs2, rs1 } => {
            Some(vop_v(0b100100, vm, vs2.0 as u32, rs1.0 as u32, OPFVF, vd.0 as u32, OPC_OP_V))
        }
        FMaxVF { vd, vs2, rs1 } => {
            Some(vop_v(0b000110, vm, vs2.0 as u32, rs1.0 as u32, OPFVF, vd.0 as u32, OPC_OP_V))
        }
        FMvVF { vd, rs1 } => {
            Some(vop_v(0b010111, vm, 0, rs1.0 as u32, OPFVF, vd.0 as u32, OPC_OP_V))
        }
        FRedSum { vd, vs2, vs1 } => {
            Some(vop_v(0b000001, vm, vs2.0 as u32, vs1.0 as u32, OPFVV, vd.0 as u32, OPC_OP_V))
        }
        Popcnt { vd, vs2 } => {
            Some(vop_v(F6_VPOPCNT, vm, vs2.0 as u32, 0, OPIVV, vd.0 as u32, OPC_CUSTOM2))
        }
        Shacc { vd, vs2, shamt } => {
            if shamt >= 32 {
                return None;
            }
            Some(vop_v(F6_VSHACC, vm, vs2.0 as u32, shamt as u32, OPIVI, vd.0 as u32, OPC_CUSTOM2))
        }
        Bitpack { vd, vs2, bit } => {
            if bit >= 32 {
                return None;
            }
            Some(vop_v(F6_VBITPACK, vm, vs2.0 as u32, bit as u32, OPIVI, vd.0 as u32, OPC_CUSTOM2))
        }
    }
}

// Re-exported field helpers for the decoder.
pub(crate) fn fld(word: u32, lo: u32, len: u32) -> u32 {
    (word >> lo) & ((1 << len) - 1)
}

pub(crate) fn reg_at(word: u32, lo: u32) -> Reg {
    Reg(fld(word, lo, 5) as u8)
}

pub(crate) fn freg_at(word: u32, lo: u32) -> FReg {
    FReg(fld(word, lo, 5) as u8)
}

pub(crate) fn vreg_at(word: u32, lo: u32) -> VReg {
    VReg(fld(word, lo, 5) as u8)
}
