//! RVV `vtype` state: selected element width (SEW) and register grouping
//! (LMUL). Quark/Ara use VLEN = 4096 bits (16 KiB VRF for 4 lanes — paper
//! Table II), so a single vector register holds e.g. 512 bytes.

use std::fmt;

/// Selected element width.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Sew {
    E8,
    E16,
    E32,
    E64,
}

impl Sew {
    /// Element width in bits.
    pub fn bits(self) -> usize {
        match self {
            Sew::E8 => 8,
            Sew::E16 => 16,
            Sew::E32 => 32,
            Sew::E64 => 64,
        }
    }

    /// Element width in bytes.
    pub fn bytes(self) -> usize {
        self.bits() / 8
    }

    /// `vsew` encoding per RVV 1.0.
    pub fn encoding(self) -> u32 {
        match self {
            Sew::E8 => 0,
            Sew::E16 => 1,
            Sew::E32 => 2,
            Sew::E64 => 3,
        }
    }

    pub fn from_encoding(v: u32) -> Option<Self> {
        Some(match v {
            0 => Sew::E8,
            1 => Sew::E16,
            2 => Sew::E32,
            3 => Sew::E64,
            _ => return None,
        })
    }
}

/// Register group multiplier (integral LMUL only — the kernels never need
/// fractional grouping).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum Lmul {
    M1,
    M2,
    M4,
    M8,
}

impl Lmul {
    pub fn factor(self) -> usize {
        match self {
            Lmul::M1 => 1,
            Lmul::M2 => 2,
            Lmul::M4 => 4,
            Lmul::M8 => 8,
        }
    }

    /// `vlmul` encoding per RVV 1.0.
    pub fn encoding(self) -> u32 {
        match self {
            Lmul::M1 => 0,
            Lmul::M2 => 1,
            Lmul::M4 => 2,
            Lmul::M8 => 3,
        }
    }

    pub fn from_encoding(v: u32) -> Option<Self> {
        Some(match v {
            0 => Lmul::M1,
            1 => Lmul::M2,
            2 => Lmul::M4,
            3 => Lmul::M8,
            _ => return None,
        })
    }
}

/// The dynamic vector-type configuration set by `vsetvli`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct VType {
    pub sew: Sew,
    pub lmul: Lmul,
}

impl VType {
    pub fn new(sew: Sew, lmul: Lmul) -> Self {
        VType { sew, lmul }
    }

    /// VLMAX for a given VLEN (bits): `LMUL * VLEN / SEW`.
    pub fn vlmax(&self, vlen_bits: usize) -> usize {
        self.lmul.factor() * vlen_bits / self.sew.bits()
    }

    /// Raw `vtype` CSR encoding (ta/ma assumed set, as Ara's runtime does).
    pub fn encoding(&self) -> u32 {
        (1 << 7) | (1 << 6) | (self.sew.encoding() << 3) | self.lmul.encoding()
    }

    pub fn from_encoding(v: u32) -> Option<Self> {
        Some(VType {
            sew: Sew::from_encoding((v >> 3) & 0x7)?,
            lmul: Lmul::from_encoding(v & 0x7)?,
        })
    }
}

impl fmt::Display for VType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "e{},m{}", self.sew.bits(), self.lmul.factor())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vlmax_matches_vlen4096() {
        // VLEN=4096: one register holds 512 int8 / 64 int64 elements.
        assert_eq!(VType::new(Sew::E8, Lmul::M1).vlmax(4096), 512);
        assert_eq!(VType::new(Sew::E64, Lmul::M1).vlmax(4096), 64);
        assert_eq!(VType::new(Sew::E32, Lmul::M8).vlmax(4096), 1024);
    }

    #[test]
    fn vtype_encoding_roundtrip() {
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            for lmul in [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8] {
                let vt = VType::new(sew, lmul);
                assert_eq!(VType::from_encoding(vt.encoding()), Some(vt));
            }
        }
    }
}
