//! Architectural register names.

use std::fmt;

/// Scalar integer register `x0..x31` (`x0` is hard-wired zero).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct Reg(pub u8);

/// Scalar floating-point register `f0..f31` (CVA6's FPU — used by the
/// re-scaling step of quantized inference, which Quark keeps on the scalar
/// core precisely so the *vector* FPU can be dropped).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct FReg(pub u8);

/// Vector register `v0..v31`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct VReg(pub u8);

impl Reg {
    pub const ZERO: Reg = Reg(0);

    /// Panics on out-of-range register numbers.
    pub fn new(n: u8) -> Self {
        assert!(n < 32, "x{n} out of range");
        Reg(n)
    }
}

impl FReg {
    pub fn new(n: u8) -> Self {
        assert!(n < 32, "f{n} out of range");
        FReg(n)
    }
}

impl VReg {
    pub fn new(n: u8) -> Self {
        assert!(n < 32, "v{n} out of range");
        VReg(n)
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "x{}", self.0)
    }
}

impl fmt::Display for FReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "f{}", self.0)
    }
}

impl fmt::Display for VReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "v{}", self.0)
    }
}

/// Conventional ABI aliases used by the kernel emitters for readability.
pub mod abi {
    use super::{FReg, Reg};

    pub const ZERO: Reg = Reg(0);
    pub const RA: Reg = Reg(1);
    pub const SP: Reg = Reg(2);
    /// Temporaries t0..t6.
    pub const T0: Reg = Reg(5);
    pub const T1: Reg = Reg(6);
    pub const T2: Reg = Reg(7);
    pub const T3: Reg = Reg(28);
    pub const T4: Reg = Reg(29);
    pub const T5: Reg = Reg(30);
    pub const T6: Reg = Reg(31);
    /// Argument registers a0..a7.
    pub const A0: Reg = Reg(10);
    pub const A1: Reg = Reg(11);
    pub const A2: Reg = Reg(12);
    pub const A3: Reg = Reg(13);
    pub const A4: Reg = Reg(14);
    pub const A5: Reg = Reg(15);
    pub const A6: Reg = Reg(16);
    pub const A7: Reg = Reg(17);
    /// Saved registers s2..s11 (s0/s1 reserved for frame in real ABIs).
    pub const S2: Reg = Reg(18);
    pub const S3: Reg = Reg(19);
    pub const S4: Reg = Reg(20);
    pub const S5: Reg = Reg(21);
    pub const S6: Reg = Reg(22);
    pub const S7: Reg = Reg(23);
    pub const S8: Reg = Reg(24);
    pub const S9: Reg = Reg(25);
    pub const S10: Reg = Reg(26);
    pub const S11: Reg = Reg(27);

    pub const FT0: FReg = FReg(0);
    pub const FT1: FReg = FReg(1);
    pub const FT2: FReg = FReg(2);
    pub const FT3: FReg = FReg(3);
    pub const FA0: FReg = FReg(10);
    pub const FA1: FReg = FReg(11);
}
