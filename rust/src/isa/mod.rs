//! Instruction-set definitions: the RV64 scalar subset CVA6 executes, the
//! RVV 1.0 vector subset Ara implements, and Quark's three custom vector
//! instructions (`vpopcnt.v`, `vshacc.vi`, `vbitpack.vi`).
//!
//! The simulator is *trace-driven*: kernels (see [`crate::kernels`]) emit the
//! dynamic instruction stream straight into the simulator, with loop control
//! represented by explicit [`instr::ScalarOp::Branch`] markers so control-flow overhead
//! is still charged. Encodings ([`encode`]/[`decode`]) exist so the custom
//! instructions have concrete, testable 32-bit formats (they occupy the
//! custom-2 major opcode, as a real Ara-derived design would).

pub mod decode;
pub mod disasm;
pub mod encode;
pub mod instr;
pub mod quark;
pub mod reg;
pub mod vtype;

pub use instr::{FUnit, Instr, MemWidth, ScalarOp, VMemKind, VOp};
pub use reg::{FReg, Reg, VReg};
pub use vtype::{Lmul, Sew, VType};
