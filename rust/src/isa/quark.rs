//! Quark's custom-instruction definitions and interpretation notes.
//!
//! A prose reference for these three instructions (encodings, semantics,
//! rationale, worked examples) lives in `docs/isa.md`; this module is the
//! authoritative in-code source it cross-links.
//!
//! The paper (§III-A) adds three instructions to the RVV 1.0 ISA:
//!
//! | mnemonic       | semantics                                                        |
//! |----------------|------------------------------------------------------------------|
//! | `vpopcnt.v`    | per-element population count                                     |
//! | `vshacc.vi`    | fused shift-accumulate: `vd[i] = (vd[i] << shamt) + vs2[i]`      |
//! | `vbitpack.vi`  | `vd = (vd << vl) \| plane(vs2, b)` — bit-slice + pack            |
//!
//! ## Why each exists
//!
//! The bit-serial inner product (paper Eq. 1)
//!
//! ```text
//! w · a = Σₘ Σₙ 2^(n+m) · popcount(wₘ AND aₙ)
//! ```
//!
//! needs three operators beyond the base ISA:
//!
//! * **per-element popcount** — base RVV only has `vcpop.m`, a *whole-register*
//!   count over a mask; bit-serial needs one count per packed word.
//! * **shift-and-accumulate** — the `2^(n+m)` weights become a Horner
//!   recurrence over bit planes (MSB→LSB): `acc = (acc << 1) + partial`.
//!   Fusing saves one instruction and one VRF round-trip per plane.
//! * **bit-packing** — activations arrive element-per-byte from the previous
//!   layer and must be transposed to bit-plane (bit-stream) layout *at every
//!   layer*; without hardware support this runs on the mask unit and eats the
//!   entire bit-serial advantage (paper Fig. 3, "Int2 w/o vbitpack").
//!
//! ## `vbitpack` interpretation
//!
//! Paper Fig. 1 shows consecutive `vbitpack` calls accumulating bit slices of
//! `v1` into `v2`, "shift\[ing\] the target register to the left and then
//! perform\[ing\] the packing". The figure is 8 elements wide and leaves the
//! exact shift amount implicit. We pin down the semantics as:
//!
//! ```text
//! vd = (vd << vl) | plane(vs2, b)        (vd viewed as a VLEN-bit vector,
//!                                         plane bit i = bit b of vs2[i])
//! ```
//!
//! i.e. the register shifts left by one *plane width* so that `n` consecutive
//! calls with `b = n-1 … 0` leave `n` bit planes packed plane-major in `vd`.
//! This matches the figure (two colored slices sitting side by side after two
//! calls at 2-bit precision) and is what the bit-serial kernels want: each
//! plane is a contiguous `vl`-bit stream. One call into a zeroed register
//! extracts a single plane.
//!
//! ## Encodings
//!
//! The three instructions occupy the *custom-2* major opcode (`0x5B`), which
//! RISC-V reserves for vendor extensions, with an OP-V-like layout:
//! `funct6 | vm=1 | vs2 | rs1/imm5 | funct3 | vd | opcode`. See
//! [`crate::isa::encode`].

/// Major opcode used by the custom instructions (RISC-V custom-2 space).
pub const OPC_CUSTOM2: u32 = 0x5B;

/// funct6 assignments within custom-2.
pub const F6_VPOPCNT: u32 = 0b000001;
pub const F6_VSHACC: u32 = 0b000010;
pub const F6_VBITPACK: u32 = 0b000011;

/// Cost model notes (used by `sim::timing`):
///
/// * `vshacc.vi` executes on the lane ALUs at the full 64 bit/lane/cycle
///   rate; `vpopcnt.v` has its own popcount tree in the lane slot freed by
///   the FPU removal (Fig. 5's "bit-serial units"), so the AND→popcount→
///   accumulate triple overlaps across two units via chaining. Both are
///   single-cycle at 22FDX/1 GHz (the paper reports no frequency loss: both
///   designs close at 1.05 GHz TT).
/// * `vbitpack.vi` is a cross-lane bit permutation and runs on the slide unit
///   at `lanes × 64` input bits per cycle.
pub const _COST_MODEL_DOC: () = ();
