//! The instruction vocabulary shared by the assembler-style kernel emitters,
//! the functional executor, and the timing model.
//!
//! This is the *dynamic* form: the simulator is trace-driven, so loop control
//! appears as explicit [`ScalarOp::Branch`] markers (still charged cycles by
//! the timing model) rather than as resolved PC arithmetic. Everything else
//! has full architectural semantics.

use super::reg::{FReg, Reg, VReg};
use super::vtype::{Sew, VType};

/// Memory access width for scalar loads/stores.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MemWidth {
    B,
    H,
    W,
    D,
}

impl MemWidth {
    pub fn bytes(self) -> usize {
        match self {
            MemWidth::B => 1,
            MemWidth::H => 2,
            MemWidth::W => 4,
            MemWidth::D => 8,
        }
    }
}

/// Scalar integer ALU operations (RV64IM subset used by the kernels).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AluOp {
    Add,
    Sub,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    Slt,
    Sltu,
    Mul,
    Mulh,
    Div,
    Rem,
}

/// Scalar FP ALU operations (CVA6's scalar FPU — this is where quantized
/// re-scaling runs, per the paper's architecture).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FAluOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

/// Scalar-side instructions (executed by the CVA6 model).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScalarOp {
    /// `li rd, imm` pseudo-instruction (lui+addi pair; charged 1 cycle, as
    /// CVA6 fuses or the common case is addi).
    Li { rd: Reg, imm: i64 },
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i64 },
    Load { width: MemWidth, signed: bool, rd: Reg, base: Reg, offset: i64 },
    Store { width: MemWidth, rs2: Reg, base: Reg, offset: i64 },
    /// Control-flow marker emitted once per dynamic branch; `taken` feeds the
    /// (static) branch-cost model.
    Branch { taken: bool },
    /// f32 load/store.
    FLoad { rd: FReg, base: Reg, offset: i64 },
    FStore { rs2: FReg, base: Reg, offset: i64 },
    FAlu { op: FAluOp, rd: FReg, rs1: FReg, rs2: FReg },
    /// `fmadd.s rd, rs1, rs2, rs3` → rd = rs1*rs2 + rs3.
    FMadd { rd: FReg, rs1: FReg, rs2: FReg, rs3: FReg },
    /// `fcvt.w.s` (f32 → i32, round-to-nearest-even) — the quantizing cast.
    FCvtWS { rd: Reg, rs1: FReg },
    /// `fcvt.s.w` (i32 → f32) — dequantizing cast for accumulator re-scale.
    FCvtSW { rd: FReg, rs1: Reg },
    /// `fmv.x.w` — move f32 bits to integer register.
    FMvXW { rd: Reg, rs1: FReg },
    /// `fmv.w.x` — move integer bits to f32 register.
    FMvWX { rd: FReg, rs1: Reg },
    /// `csrrs rd, cycle, x0` — read the cycle CSR (how the paper measures).
    CsrReadCycle { rd: Reg },
    Nop,
}

/// Vector memory addressing kinds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VMemKind {
    /// `vle<eew>.v` / `vse<eew>.v`
    UnitStride,
    /// `vlse<eew>.v` / `vsse<eew>.v` with byte stride in a scalar register.
    Strided { stride: Reg },
}

/// Vector integer two-source ops (element-wise).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VIOp {
    Add,
    Sub,
    Rsub,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    Min,
    Max,
    Minu,
    Maxu,
    Mul,
    Mulh,
}

/// Vector-side instructions (dispatched by CVA6 to the Ara/Quark unit).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VOp {
    /// Unit-stride / strided vector load.
    Load { kind: VMemKind, eew: Sew, vd: VReg, base: Reg },
    /// Unit-stride / strided vector store.
    Store { kind: VMemKind, eew: Sew, vs3: VReg, base: Reg },
    /// vv-form integer op: `vd = vs2 op vs1`.
    IVV { op: VIOp, vd: VReg, vs2: VReg, vs1: VReg },
    /// vx-form integer op: `vd = vs2 op x[rs1]`.
    IVX { op: VIOp, vd: VReg, vs2: VReg, rs1: Reg },
    /// vi-form integer op: `vd = vs2 op imm`.
    IVI { op: VIOp, vd: VReg, vs2: VReg, imm: i64 },
    /// `vmacc.vx vd, rs1, vs2` → `vd += x[rs1] * vs2`.
    MaccVX { vd: VReg, rs1: Reg, vs2: VReg },
    /// `vmacc.vv vd, vs1, vs2` → vd += vs1 * vs2.
    MaccVV { vd: VReg, vs1: VReg, vs2: VReg },
    /// `vredsum.vs vd, vs2, vs1` → vd[0] = vs1[0] + Σ vs2[0..vl].
    RedSum { vd: VReg, vs2: VReg, vs1: VReg },
    /// `vmv.x.s rd, vs2` — element 0 to scalar (synchronizes scalar on vector).
    MvXS { rd: Reg, vs2: VReg },
    /// `vmv.s.x vd, rs1` — scalar into element 0.
    MvSX { vd: VReg, rs1: Reg },
    /// `vmv.v.x vd, rs1` — broadcast splat.
    MvVX { vd: VReg, rs1: Reg },
    /// `vmv.v.i vd, imm` — immediate splat.
    MvVI { vd: VReg, imm: i64 },
    /// `vsext.vf{2,4,8}` — sign-extend from SEW/frac to SEW.
    Sext { vd: VReg, vs2: VReg, frac: u8 },
    /// `vzext.vf{2,4,8}`.
    Zext { vd: VReg, vs2: VReg, frac: u8 },
    /// `vmseq.vi vd, vs2, imm` — mask-producing compare (result in mask
    /// layout: bit i of vd = `(vs2[i] == imm)`). Used by the pure-RVV bitpack
    /// fallback; runs on the (slow) mask unit.
    MseqVI { vd: VReg, vs2: VReg, imm: i64 },
    /// `vmsne.vi vd, vs2, imm` — mask-producing compare (≠).
    MsneVI { vd: VReg, vs2: VReg, imm: i64 },
    /// `vfmacc.vf vd, rs1, vs2` → `vd += f[rs1] * vs2` (f32; Ara only).
    FMaccVF { vd: VReg, rs1: FReg, vs2: VReg },
    /// `vfadd.vv` (f32; Ara only).
    FAddVV { vd: VReg, vs2: VReg, vs1: VReg },
    /// `vfmul.vf` (f32; Ara only).
    FMulVF { vd: VReg, vs2: VReg, rs1: FReg },
    /// `vfmax.vf` (f32 relu; Ara only).
    FMaxVF { vd: VReg, vs2: VReg, rs1: FReg },
    /// `vfmv.v.f` splat (f32; Ara only).
    FMvVF { vd: VReg, rs1: FReg },
    /// `vfredsum.vs` (f32; Ara only).
    FRedSum { vd: VReg, vs2: VReg, vs1: VReg },

    // ---- Quark custom instructions (paper §III-A) ----
    /// `vpopcnt.v vd, vs2` — per-element popcount. The base RVV `vcpop.m`
    /// only counts bits over the whole mask register; bit-serial dot products
    /// need a per-element count, which this supplies.
    Popcnt { vd: VReg, vs2: VReg },
    /// `vshacc.vi vd, vs2, shamt` — fused shift-accumulate:
    /// `vd[i] = (vd[i] << shamt) + vs2[i]`. Implements the `2^(n+m)` weighting
    /// of Eq. (1) as a Horner recurrence over bit planes.
    Shacc { vd: VReg, vs2: VReg, shamt: u8 },
    /// `vbitpack.vi vd, vs2, b` — slice bit `b` out of each of the `vl`
    /// elements of `vs2` and pack the resulting `vl`-bit plane into the low
    /// bits of `vd` (viewed as a VLEN-bit vector), after shifting `vd` left by
    /// `vl` bits: `vd = (vd << vl) | plane(vs2, b)`. Consecutive calls
    /// accumulate bit slices exactly as paper Fig. 1 describes. See
    /// [`crate::isa::quark`] for the interpretation notes.
    Bitpack { vd: VReg, vs2: VReg, bit: u8 },
}

/// One dynamic instruction.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Instr {
    Scalar(ScalarOp),
    /// `vsetvli rd, avl, e<sew>,m<lmul>` — trace-driven, so the requested AVL
    /// is carried as a value; the executor computes `vl = min(avl, VLMAX)`.
    VSetVli { rd: Reg, avl: u64, vtype: VType },
    Vector(VOp),
}

/// Functional unit that executes an instruction — the timing model's routing
/// key (one busy-until clock per unit; see [`crate::sim::timing`]).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FUnit {
    ScalarAlu,
    ScalarMul,
    ScalarMem,
    ScalarFpu,
    ScalarCtl,
    /// Vector config (vsetvli) — handled in the dispatcher.
    VCfg,
    VAlu,
    VMul,
    VFpu,
    /// Mask unit (mask-producing compares) — deliberately slow in Ara.
    VMask,
    /// Reductions (inter-lane tree).
    VRed,
    VLsu,
    /// Slide/permute unit; Quark's `vbitpack` lives here (cross-lane bit
    /// permutation network).
    VSld,
}

impl VOp {
    /// Which functional unit executes this op.
    pub fn unit(&self) -> FUnit {
        use VOp::*;
        match self {
            Load { .. } | Store { .. } => FUnit::VLsu,
            IVV { op, .. } | IVX { op, .. } | IVI { op, .. } => match op {
                VIOp::Mul | VIOp::Mulh => FUnit::VMul,
                _ => FUnit::VAlu,
            },
            MaccVX { .. } | MaccVV { .. } => FUnit::VMul,
            RedSum { .. } | FRedSum { .. } => FUnit::VRed,
            MvXS { .. } | MvSX { .. } | MvVX { .. } | MvVI { .. } => FUnit::VAlu,
            Sext { .. } | Zext { .. } => FUnit::VAlu,
            MseqVI { .. } | MsneVI { .. } => FUnit::VMask,
            FMaccVF { .. } | FAddVV { .. } | FMulVF { .. } | FMaxVF { .. } | FMvVF { .. } => {
                FUnit::VFpu
            }
            // Quark's dedicated popcount tree sits in the ex-multiplier/FPU
            // slot of the lane (the area Fig. 5 labels "bit-serial units"),
            // so AND/accumulate (VALU) and popcount overlap via chaining.
            Popcnt { .. } => FUnit::VMul,
            Shacc { .. } => FUnit::VAlu,
            Bitpack { .. } => FUnit::VSld,
        }
    }

    /// True if this op requires the vector FPU (absent in Quark).
    pub fn needs_vfpu(&self) -> bool {
        self.unit() == FUnit::VFpu
    }

    /// True if this op is one of Quark's custom instructions (absent in Ara).
    pub fn is_quark_custom(&self) -> bool {
        matches!(self, VOp::Popcnt { .. } | VOp::Shacc { .. } | VOp::Bitpack { .. })
    }

    /// Destination vector register, if any.
    pub fn vreg_write(&self) -> Option<VReg> {
        use VOp::*;
        match *self {
            Load { vd, .. } => Some(vd),
            Store { .. } => None,
            IVV { vd, .. } | IVX { vd, .. } | IVI { vd, .. } => Some(vd),
            MaccVX { vd, .. } | MaccVV { vd, .. } => Some(vd),
            RedSum { vd, .. } | FRedSum { vd, .. } => Some(vd),
            MvXS { .. } => None,
            MvSX { vd, .. } | MvVX { vd, .. } | MvVI { vd, .. } => Some(vd),
            Sext { vd, .. } | Zext { vd, .. } => Some(vd),
            MseqVI { vd, .. } | MsneVI { vd, .. } => Some(vd),
            FMaccVF { vd, .. } | FAddVV { vd, .. } | FMulVF { vd, .. } | FMaxVF { vd, .. }
            | FMvVF { vd, .. } => Some(vd),
            Popcnt { vd, .. } | Shacc { vd, .. } | Bitpack { vd, .. } => Some(vd),
        }
    }

    /// Source vector registers (up to 3: vs1, vs2, and vd-as-accumulator).
    pub fn vreg_reads(&self) -> [Option<VReg>; 3] {
        use VOp::*;
        match *self {
            Load { .. } => [None; 3],
            Store { vs3, .. } => [Some(vs3), None, None],
            IVV { vs2, vs1, .. } => [Some(vs2), Some(vs1), None],
            IVX { vs2, .. } | IVI { vs2, .. } => [Some(vs2), None, None],
            MaccVX { vd, vs2, .. } => [Some(vs2), Some(vd), None],
            MaccVV { vd, vs1, vs2 } => [Some(vs2), Some(vs1), Some(vd)],
            RedSum { vs2, vs1, .. } | FRedSum { vs2, vs1, .. } => [Some(vs2), Some(vs1), None],
            MvXS { vs2, .. } => [Some(vs2), None, None],
            MvSX { .. } | MvVX { .. } | MvVI { .. } | FMvVF { .. } => [None; 3],
            Sext { vs2, .. } | Zext { vs2, .. } => [Some(vs2), None, None],
            MseqVI { vs2, .. } | MsneVI { vs2, .. } => [Some(vs2), None, None],
            FMaccVF { vd, vs2, .. } => [Some(vs2), Some(vd), None],
            FAddVV { vs2, vs1, .. } => [Some(vs2), Some(vs1), None],
            FMulVF { vs2, .. } | FMaxVF { vs2, .. } => [Some(vs2), None, None],
            Popcnt { vs2, .. } => [Some(vs2), None, None],
            Shacc { vd, vs2, .. } => [Some(vs2), Some(vd), None],
            Bitpack { vd, vs2, .. } => [Some(vs2), Some(vd), None],
        }
    }

    /// Scalar register consumed (address base, stride, or vx operand), if any.
    pub fn sreg_read(&self) -> Option<Reg> {
        use VOp::*;
        match *self {
            Load { base, .. } | Store { base, .. } => Some(base),
            IVX { rs1, .. } | MaccVX { rs1, .. } | MvSX { rs1, .. } | MvVX { rs1, .. } => {
                Some(rs1)
            }
            _ => None,
        }
    }

    /// Scalar register produced (vector → scalar sync point), if any.
    pub fn sreg_write(&self) -> Option<Reg> {
        match *self {
            VOp::MvXS { rd, .. } => Some(rd),
            _ => None,
        }
    }
}

impl Instr {
    pub fn is_vector(&self) -> bool {
        matches!(self, Instr::Vector(_) | Instr::VSetVli { .. })
    }

    /// Functional unit routing for the timing model.
    pub fn unit(&self) -> FUnit {
        match self {
            Instr::Scalar(op) => {
                use ScalarOp::*;
                match op {
                    Li { .. } | Alu { .. } | AluImm { .. } | Nop => FUnit::ScalarAlu,
                    Load { .. } | Store { .. } | FLoad { .. } | FStore { .. } => FUnit::ScalarMem,
                    Branch { .. } => FUnit::ScalarCtl,
                    FAlu { .. } | FMadd { .. } | FCvtWS { .. } | FCvtSW { .. } | FMvXW { .. }
                    | FMvWX { .. } => FUnit::ScalarFpu,
                    CsrReadCycle { .. } => FUnit::ScalarCtl,
                }
            }
            Instr::VSetVli { .. } => FUnit::VCfg,
            Instr::Vector(v) => v.unit(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quark_custom_ops_are_flagged() {
        let v = VOp::Popcnt { vd: VReg(1), vs2: VReg(2) };
        assert!(v.is_quark_custom());
        assert!(!v.needs_vfpu());
        let v = VOp::FMaccVF { vd: VReg(1), rs1: FReg(0), vs2: VReg(2) };
        assert!(v.needs_vfpu());
        assert!(!v.is_quark_custom());
    }

    #[test]
    fn macc_reads_its_accumulator() {
        let v = VOp::MaccVX { vd: VReg(4), rs1: Reg(5), vs2: VReg(6) };
        let reads = v.vreg_reads();
        assert!(reads.contains(&Some(VReg(4))));
        assert!(reads.contains(&Some(VReg(6))));
        assert_eq!(v.vreg_write(), Some(VReg(4)));
        assert_eq!(v.sreg_read(), Some(Reg(5)));
    }

    #[test]
    fn unit_routing() {
        assert_eq!(
            Instr::Vector(VOp::Bitpack { vd: VReg(0), vs2: VReg(1), bit: 0 }).unit(),
            FUnit::VSld
        );
        assert_eq!(
            Instr::Vector(VOp::MseqVI { vd: VReg(0), vs2: VReg(1), imm: 0 }).unit(),
            FUnit::VMask
        );
        assert_eq!(
            Instr::Scalar(ScalarOp::FAlu {
                op: FAluOp::Mul,
                rd: FReg(0),
                rs1: FReg(1),
                rs2: FReg(2)
            })
            .unit(),
            FUnit::ScalarFpu
        );
    }
}
