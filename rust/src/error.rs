//! Minimal error type for the offline build (no `anyhow` in this
//! environment — see Cargo.toml).
//!
//! API mirrors the `anyhow` subset the crate uses: [`Result`], the
//! [`crate::anyhow!`] / [`crate::bail!`] macros, and a [`Context`] extension
//! trait for `Result` and `Option`. Context strings are folded into the
//! message front-to-back, so `load(p).context("loading artifact")?` renders
//! as `loading artifact: <cause>`.

use std::fmt;

/// Boxed dynamic error with a flattened message chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`, which keeps the blanket conversion below coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Attach context to an error (drop-in for `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        r?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
        assert_eq!(f(false).unwrap(), 1);
        let e: Error = crate::anyhow!("x={}", 3);
        assert_eq!(e.to_string(), "x=3");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }
}
