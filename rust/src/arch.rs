//! Machine configurations for the simulated systems.
//!
//! The paper evaluates three configurations (Table II):
//!
//! | config    | lanes | VRF    | vector FPU | Quark ISA | TT freq  |
//! |-----------|-------|--------|------------|-----------|----------|
//! | Ara-4L    | 4     | 16 KiB | yes        | no        | 1.05 GHz |
//! | Quark-4L  | 4     | 16 KiB | no         | yes       | 1.05 GHz |
//! | Quark-8L  | 8     | 32 KiB | no         | yes       | 1.00 GHz |
//!
//! VLEN is VRF/32 registers: 4096 bits for the 4-lane configs (16 KiB / 32)
//! and 8192 bits for Quark-8L. All structural timing parameters live here so
//! the simulator, the physical model, and the roofline analytics agree on the
//! machine they are describing.


/// One simulated CVA6 + vector-unit system.
#[derive(Clone, Debug)]
pub struct MachineConfig {
    /// Human-readable name ("ara-4l", "quark-4l", "quark-8l").
    pub name: String,
    /// Number of vector lanes (each with a 64-bit datapath per unit).
    pub lanes: usize,
    /// Vector register length in bits (VRF = 32 × VLEN).
    pub vlen_bits: usize,
    /// Whether the lanes contain a vector FPU (Ara yes, Quark no).
    pub has_vfpu: bool,
    /// Whether the Quark custom instructions decode (`vpopcnt`, `vshacc`,
    /// `vbitpack`).
    pub has_quark_isa: bool,
    /// Typical-corner clock frequency in GHz (for GOPS/roofline conversion;
    /// the cycle model itself is frequency-independent).
    pub freq_ghz: f64,
    /// AXI data-bus width between the vector unit and L2, in bytes per cycle
    /// (Ara uses a 32B/cycle bus for 4 lanes: 64 bit/lane memory interface).
    pub axi_bytes_per_cycle: usize,
    /// Flat memory latency for the first beat of a vector memory operation
    /// (L2-hit-ish; the paper's workloads stream from L2/SPM).
    pub mem_latency: u64,
    /// CVA6 → vector-unit dispatch + acknowledge overhead per instruction.
    pub dispatch_latency: u64,
    /// Start-up latency of a vector instruction on its functional unit
    /// (sequencer + operand-requester pipeline fill).
    pub vstartup_latency: u64,
    /// Extra latency before a chained consumer may start after its producer
    /// (operand-queue depth worth of slack).
    pub chain_latency: u64,
    /// Mask-unit throughput in *elements* per lane per cycle. Mask-producing
    /// compares on Ara serialize on the MASKU; 1 elem/lane/cycle models that
    /// (vs 64/SEW elem/lane/cycle on the main ALU datapath).
    pub mask_elems_per_lane_cycle: f64,
    /// Scalar FP latency (CVA6 FPU, cycles) — re-scaling cost lives here.
    pub scalar_fp_latency: u64,
    /// Scalar integer multiply latency.
    pub scalar_mul_latency: u64,
    /// Scalar load-to-use latency (L1 D-cache hit).
    pub scalar_load_latency: u64,
    /// CVA6→Ara dispatch-queue depth: the scalar core can run at most this
    /// many undispatched vector instructions ahead (bounds the decoupling).
    pub vq_depth: usize,
}

impl MachineConfig {
    /// Bytes per vector register.
    pub fn vreg_bytes(&self) -> usize {
        self.vlen_bits / 8
    }

    /// Total VRF capacity in KiB (32 registers).
    pub fn vrf_kib(&self) -> usize {
        32 * self.vreg_bytes() / 1024
    }

    /// Peak element throughput for a vector op at `sew_bits`:
    /// `lanes × 64 / SEW` elements per cycle.
    pub fn elems_per_cycle(&self, sew_bits: usize) -> f64 {
        (self.lanes * 64) as f64 / sew_bits as f64
    }

    /// Peak int8 MAC/cycle (MACs with 32-bit accumulation: the datapath
    /// processes 64/32 = 2 accumulator elements per lane per cycle).
    pub fn peak_int8_macs_per_cycle(&self) -> f64 {
        self.elems_per_cycle(32)
    }

    /// Peak 1-bit "MAC"/cycle via AND+popcount+shacc (3 ALU ops per 64-bit
    /// word, each word holding 64 bit-products).
    pub fn peak_bitserial_macs_per_cycle(&self) -> f64 {
        self.elems_per_cycle(64) * 64.0 / 3.0
    }

    /// Ara: the baseline, RVV 1.0 with vector FPU, no custom ISA.
    pub fn ara(lanes: usize) -> Self {
        MachineConfig {
            name: format!("ara-{lanes}l"),
            lanes,
            vlen_bits: 1024 * lanes,
            has_vfpu: true,
            has_quark_isa: false,
            freq_ghz: 1.05,
            axi_bytes_per_cycle: 8 * lanes,
            mem_latency: 20,
            dispatch_latency: 3,
            vstartup_latency: 4,
            chain_latency: 2,
            mask_elems_per_lane_cycle: 1.0,
            scalar_fp_latency: 4,
            scalar_mul_latency: 2,
            scalar_load_latency: 2,
            vq_depth: 8,
        }
    }

    /// Quark: integer-only lanes + custom sub-byte ISA.
    pub fn quark(lanes: usize) -> Self {
        let freq_ghz = if lanes >= 8 { 1.00 } else { 1.05 };
        MachineConfig {
            name: format!("quark-{lanes}l"),
            lanes,
            vlen_bits: 1024 * lanes,
            has_vfpu: false,
            has_quark_isa: true,
            freq_ghz,
            axi_bytes_per_cycle: 8 * lanes,
            mem_latency: 20,
            dispatch_latency: 3,
            vstartup_latency: 4,
            chain_latency: 2,
            mask_elems_per_lane_cycle: 1.0,
            scalar_fp_latency: 4,
            scalar_mul_latency: 2,
            scalar_load_latency: 2,
            vq_depth: 8,
        }
    }

    /// The paper's three evaluated configurations.
    pub fn paper_configs() -> Vec<MachineConfig> {
        vec![Self::ara(4), Self::quark(4), Self::quark(8)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_structural_parameters() {
        let ara = MachineConfig::ara(4);
        assert_eq!(ara.vrf_kib(), 16);
        assert_eq!(ara.vlen_bits, 4096);
        let q8 = MachineConfig::quark(8);
        assert_eq!(q8.vrf_kib(), 32);
        assert!((q8.freq_ghz - 1.0).abs() < 1e-9);
        assert!(!q8.has_vfpu && q8.has_quark_isa);
    }

    #[test]
    fn peak_rates() {
        let q = MachineConfig::quark(4);
        // 4 lanes × 64 bit = 4 elem/cycle at SEW=64.
        assert!((q.elems_per_cycle(64) - 4.0).abs() < 1e-9);
        // int8 MACs at 8/cycle; 1-bit MACs at 85.3/cycle → the raw bit-serial
        // advantage the paper exploits.
        assert!((q.peak_int8_macs_per_cycle() - 8.0).abs() < 1e-9);
        assert!(q.peak_bitserial_macs_per_cycle() > 80.0);
    }
}
