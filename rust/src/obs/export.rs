//! One writer, two artifacts.
//!
//! * [`chrome_trace_json`] — Chrome `trace_event` JSON (the Perfetto /
//!   `chrome://tracing` interchange format). Host spans land on process 1
//!   with one thread per tracer ring; simulated cycles land on process 2
//!   with two threads per profiled program (per-layer timeline, per-class
//!   timeline), rendering one cycle as one microsecond. The two clock
//!   domains share a file but never a track, so wall time and simulated
//!   time cannot be confused for one another.
//! * [`folded_stacks`] — `stack;frames count` text, one line per aggregated
//!   stack, directly consumable by flamegraph tooling. Host frames count
//!   µs; sim frames count cycles.
//!
//! [`validate_chrome_trace`] is a dependency-free JSON syntax check (the
//! repo bakes in no serde and CI has no `jq`): it parses the full document
//! and confirms the `traceEvents` array of objects is present.

use std::collections::BTreeMap;

use super::profile::{OpClass, ProgramProfile};
use super::TraceEvent;

/// Escape `s` as JSON string contents (without the surrounding quotes).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The admission ring's track index, recognized by its events (submit and
/// expire are only ever recorded there) — keeps the exporters free of any
/// out-of-band knowledge about the tracer's geometry.
fn admission_track(host: &[TraceEvent]) -> Option<usize> {
    use super::SpanKind;
    host.iter()
        .find(|e| matches!(e.kind, SpanKind::Submit | SpanKind::Expire))
        .map(|e| e.track)
}

fn host_track_name(track: usize, admission: Option<usize>) -> String {
    if Some(track) == admission {
        "admission".to_string()
    } else {
        format!("worker-{track}")
    }
}

fn meta_event(pid: usize, tid: usize, key: &str, name: &str) -> String {
    format!(
        "{{\"name\":\"{key}\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
         \"args\":{{\"name\":\"{}\"}}}}",
        esc(name)
    )
}

const HOST_PID: usize = 1;
const SIM_PID: usize = 2;

/// Render host spans and simulated-cycle profiles as one Chrome
/// `trace_event` JSON document. `sims` carries one profile per simulated
/// track (typically the pinned default program of each served model).
pub fn chrome_trace_json(host: &[TraceEvent], sims: &[ProgramProfile]) -> String {
    let mut events: Vec<String> = Vec::new();
    events.push(meta_event(HOST_PID, 0, "process_name", "host (wall clock, \u{3bc}s)"));
    if !sims.is_empty() {
        events.push(meta_event(SIM_PID, 0, "process_name", "sim (1 cycle = 1\u{3bc}s)"));
    }

    let admission = admission_track(host);
    let mut tracks: Vec<usize> = host.iter().map(|e| e.track).collect();
    tracks.sort_unstable();
    tracks.dedup();
    for &t in &tracks {
        events.push(meta_event(HOST_PID, t, "thread_name", &host_track_name(t, admission)));
    }
    for e in host {
        let mut args = String::new();
        if let Some(id) = e.req {
            args.push_str(&format!("\"req\":{id},"));
        }
        if let Some(id) = e.batch {
            args.push_str(&format!("\"batch\":{id},"));
        }
        if !e.label.is_empty() {
            args.push_str(&format!("\"key\":\"{}\",", esc(&e.label)));
        }
        args.pop(); // trailing comma, if any
        let phase = if e.dur_us > 0 {
            format!("\"ph\":\"X\",\"dur\":{}", e.dur_us)
        } else {
            "\"ph\":\"i\",\"s\":\"t\"".to_string()
        };
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"host\",{phase},\"pid\":{HOST_PID},\"tid\":{},\
             \"ts\":{},\"args\":{{{args}}}}}",
            e.kind.name(),
            e.track,
            e.ts_us,
        ));
    }

    for (mi, p) in sims.iter().enumerate() {
        let (tid_layers, tid_classes) = (mi * 2, mi * 2 + 1);
        let title = format!("{} [{}]", p.model, p.schedule);
        events.push(meta_event(SIM_PID, tid_layers, "thread_name", &format!("{title} layers")));
        events.push(meta_event(SIM_PID, tid_classes, "thread_name", &format!("{title} classes")));
        let mut ts = 0u64;
        for l in &p.layers {
            events.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"sim-layer\",\"ph\":\"X\",\"pid\":{SIM_PID},\
                 \"tid\":{tid_layers},\"ts\":{ts},\"dur\":{},\
                 \"args\":{{\"precision\":\"{}\",\"macs\":{}}}}}",
                esc(&l.name),
                l.cycles,
                esc(&l.precision),
                l.macs,
            ));
            ts += l.cycles;
        }
        let mut ts = 0u64;
        for (cls, &cycles) in OpClass::ALL.iter().zip(&p.class_cycles) {
            if cycles == 0 {
                continue;
            }
            events.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"sim-class\",\"ph\":\"X\",\"pid\":{SIM_PID},\
                 \"tid\":{tid_classes},\"ts\":{ts},\"dur\":{cycles},\"args\":{{}}}}",
                cls.name(),
            ));
            ts += cycles;
        }
    }

    format!("{{\"traceEvents\":[{}],\"displayTimeUnit\":\"ms\"}}", events.join(","))
}

/// Render both domains as folded stacks (`stack;frames count`), aggregated
/// and deterministically ordered. Host counts are µs of span time; sim
/// counts are cycles.
pub fn folded_stacks(host: &[TraceEvent], sims: &[ProgramProfile]) -> String {
    let admission = admission_track(host);
    let mut agg: BTreeMap<String, u64> = BTreeMap::new();
    for e in host {
        if e.dur_us == 0 {
            continue;
        }
        let track = host_track_name(e.track, admission);
        *agg.entry(format!("host;{track};{}", e.kind.name())).or_default() += e.dur_us;
    }
    for p in sims {
        for l in &p.layers {
            *agg.entry(format!("sim;{};{}", p.model, l.name)).or_default() += l.cycles;
        }
        for (cls, &cycles) in OpClass::ALL.iter().zip(&p.class_cycles) {
            if cycles > 0 {
                *agg.entry(format!("sim;{};classes;{}", p.model, cls.name())).or_default() +=
                    cycles;
            }
        }
    }
    let mut out = String::new();
    for (stack, count) in agg {
        out.push_str(&stack);
        out.push(' ');
        out.push_str(&count.to_string());
        out.push('\n');
    }
    out
}

/// A parsed JSON value — only as much structure as the validator needs.
enum Json {
    Null,
    Bool,
    Num,
    Str,
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.i)
    }

    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(|_| Json::Str),
            Some(b't') => self.literal("true").map(|_| Json::Bool),
            Some(b'f') => self.literal("false").map(|_| Json::Bool),
            Some(b'n') => self.literal("null").map(|_| Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number().map(|_| Json::Num),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<(), String> {
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        let digits0 = self.i;
        while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.i == digits0 {
            return Err(self.err("expected digits"));
        }
        if self.b.get(self.i) == Some(&b'.') {
            self.i += 1;
            let frac0 = self.i;
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
            if self.i == frac0 {
                return Err(self.err("expected fraction digits"));
            }
        }
        if matches!(self.b.get(self.i), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.b.get(self.i), Some(b'+' | b'-')) {
                self.i += 1;
            }
            let exp0 = self.i;
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
            if self.i == exp0 {
                return Err(self.err("expected exponent digits"));
            }
        }
        Ok(())
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b' | b'f' | b'n' | b'r' | b't') => out.push(' '),
                        Some(b'u') => {
                            for k in 1..=4 {
                                if !self
                                    .b
                                    .get(self.i + k)
                                    .is_some_and(|c| c.is_ascii_hexdigit())
                                {
                                    return Err(self.err("bad \\u escape"));
                                }
                            }
                            self.i += 4;
                            out.push(' ');
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(&c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => {
                    // Multi-byte UTF-8 is fine: consume the whole char.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse `json` as a full JSON document and confirm it is an object whose
/// `traceEvents` member is an array of objects (the Chrome `trace_event`
/// envelope Perfetto loads). Returns the event count.
pub fn validate_chrome_trace(json: &str) -> Result<usize, String> {
    let mut p = Parser { b: json.as_bytes(), i: 0 };
    let doc = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing garbage after document"));
    }
    let Json::Obj(fields) = doc else {
        return Err("top level is not an object".to_string());
    };
    let Some((_, events)) = fields.iter().find(|(k, _)| k == "traceEvents") else {
        return Err("missing traceEvents member".to_string());
    };
    let Json::Arr(items) = events else {
        return Err("traceEvents is not an array".to_string());
    };
    for (i, it) in items.iter().enumerate() {
        if !matches!(it, Json::Obj(_)) {
            return Err(format!("traceEvents[{i}] is not an object"));
        }
    }
    Ok(items.len())
}

#[cfg(test)]
mod tests {
    use super::super::{SpanKind, TraceEvent};
    use super::*;
    use crate::obs::profile::N_CLASSES;

    fn layer(name: &str, cycles: u64) -> crate::obs::profile::LayerCycles {
        crate::obs::profile::LayerCycles {
            name: name.to_string(),
            precision: "w2a2".to_string(),
            macs: 8,
            cycles,
        }
    }

    fn sample_profile() -> ProgramProfile {
        let mut class_cycles = [0u64; N_CLASSES];
        class_cycles[OpClass::PlaneMac.index()] = 70;
        class_cycles[OpClass::Interp.index()] = 30;
        ProgramProfile {
            model: "tiny".to_string(),
            schedule: "w2a2".to_string(),
            layers: vec![layer("conv1 \"odd\"", 60), layer("fc", 40)],
            class_cycles,
            total_cycles: 100,
        }
    }

    #[test]
    fn chrome_trace_round_trips_through_the_validator() {
        let host = vec![
            TraceEvent::instant(SpanKind::Submit, 5).with_req(1),
            TraceEvent::span(SpanKind::Replay, 10, 42).with_batch(3).with_label("tiny|w2a2|1"),
        ];
        let json = chrome_trace_json(&host, &[sample_profile()]);
        let n = validate_chrome_trace(&json).expect("exported trace must parse");
        // 2 host events + 2 sim layers + 2 sim classes + metadata.
        assert!(n >= 6, "expected at least 6 events, got {n}");
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("admission"));
        assert!(json.contains("worker-0"));
        assert!(json.contains("tiny [w2a2] layers"));
        assert!(json.contains("plane_mac"));
    }

    #[test]
    fn folded_stacks_aggregate_spans_and_skip_instants() {
        let host = vec![
            TraceEvent::instant(SpanKind::Reply, 1),
            TraceEvent::span(SpanKind::Replay, 0, 10),
            TraceEvent::span(SpanKind::Replay, 20, 5),
        ];
        let folded = folded_stacks(&host, &[sample_profile()]);
        assert!(folded.contains("host;worker-0;replay 15\n"));
        assert!(!folded.contains(";reply"));
        assert!(folded.contains("sim;tiny;fc 40\n"));
        assert!(folded.contains("sim;tiny;classes;plane_mac 70\n"));
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("").is_err());
        assert!(validate_chrome_trace("[]").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":{}}").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":[1]}").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":[{}]} x").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":[{\"a\":1}").is_err());
        assert_eq!(validate_chrome_trace("{\"traceEvents\":[]}"), Ok(0));
        assert_eq!(
            validate_chrome_trace(
                "{\"traceEvents\":[{\"name\":\"\\u00e9 \\n\",\"ts\":1.5e-3,\"ok\":true}]}"
            ),
            Ok(1)
        );
    }
}
