//! Simulated-clock cycle attribution.
//!
//! Timing is a pure function of the instruction stream ([`Sim::execute`]'s
//! contract), so attribution does not need sampling: one `TimingOnly` walk
//! of the trace, reading [`Sim::cycles`] at every layer mark
//! (`CompiledProgram`'s per-layer boundaries) **and** at every lowered
//! micro-op span boundary (`LoweredProgram::spans`), yields telescoping
//! deltas that tile the total exactly. [`profile_program`] asserts both
//! invariants — Σ(per-layer) == Σ(per-class) == total — rather than trusting
//! them, and the replayed instruction stream is byte-for-byte the one
//! [`Sim::execute_with_input`] emits, so the totals match serving's cached
//! timings exactly (asserted across the zoo in
//! `rust/tests/observability.rs`).

use crate::arch::MachineConfig;
use crate::cluster::{
    aggregate_timing, hop_cost, shard_mem_bytes, ClusterProgram, ClusterTiming, PipelineProgram,
    PipelineTiming, StageTiming,
};
use crate::program::lowered::MicroOp;
use crate::program::{relocate, CompiledProgram};
use crate::sim::{Sim, SimMode};

/// Attribution classes for lowered micro-ops. `Fill`/`Copy`/`LoadUnit`/
/// `StoreUnit` — the pure data-movement fusions — fold into one
/// [`OpClass::HostSlice`] bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    /// Bit-serial AND–popcount–accumulate runs (`MicroOp::PlaneMac`).
    PlaneMac,
    /// Fused activation row-sums (`MicroOp::RowSum`).
    RowSum,
    /// Int8 conv taps (`MicroOp::MaccByte`).
    MaccByte,
    /// `vbitpack.vi` through the host fast path (`MicroOp::BitpackFast`).
    Bitpack,
    /// Trace ranges still run by the plain interpreter (`MicroOp::Interp`).
    Interp,
    /// Host-side data movement: fills, copies, unit-stride loads/stores.
    HostSlice,
}

/// Number of attribution classes (the length of [`OpClass::ALL`]).
pub const N_CLASSES: usize = 6;

impl OpClass {
    /// Every class, in the order of the `class_cycles` arrays.
    pub const ALL: [OpClass; N_CLASSES] = [
        OpClass::PlaneMac,
        OpClass::RowSum,
        OpClass::MaccByte,
        OpClass::Bitpack,
        OpClass::Interp,
        OpClass::HostSlice,
    ];

    /// Stable snake_case name used in exports and STATS rows.
    pub fn name(self) -> &'static str {
        match self {
            OpClass::PlaneMac => "plane_mac",
            OpClass::RowSum => "row_sum",
            OpClass::MaccByte => "macc_byte",
            OpClass::Bitpack => "bitpack",
            OpClass::Interp => "interp",
            OpClass::HostSlice => "host_slice",
        }
    }

    /// Index into the `class_cycles` arrays (the [`OpClass::ALL`] order).
    pub fn index(self) -> usize {
        self as usize
    }

    fn of(op: &MicroOp) -> OpClass {
        match op {
            MicroOp::PlaneMac { .. } => OpClass::PlaneMac,
            MicroOp::RowSum(_) => OpClass::RowSum,
            MicroOp::MaccByte { .. } => OpClass::MaccByte,
            MicroOp::BitpackFast { .. } => OpClass::Bitpack,
            MicroOp::Interp { .. } => OpClass::Interp,
            MicroOp::Fill { .. }
            | MicroOp::Copy { .. }
            | MicroOp::LoadUnit { .. }
            | MicroOp::StoreUnit { .. } => OpClass::HostSlice,
        }
    }
}

/// One layer's share of a timed replay.
#[derive(Clone, Debug)]
pub struct LayerCycles {
    pub name: String,
    /// The layer's scheduled precision label (e.g. `w2a2`, `int8`, `fp32`).
    pub precision: String,
    /// MACs the layer reports (same figure as `LayerReport`).
    pub macs: u64,
    pub cycles: u64,
}

/// Cycle attribution for one compiled program on one core.
#[derive(Clone, Debug)]
pub struct ProgramProfile {
    pub model: String,
    /// The deployment schedule's label (`PrecisionMap::label`).
    pub schedule: String,
    /// Per-layer cycles, in layer order; sums to `total_cycles`.
    pub layers: Vec<LayerCycles>,
    /// Per-class cycles in [`OpClass::ALL`] order; sums to `total_cycles`.
    pub class_cycles: [u64; N_CLASSES],
    /// Cycles of the whole timed replay — identical to what
    /// [`Sim::execute`] reports for this program.
    pub total_cycles: u64,
}

impl ProgramProfile {
    /// Per-class fractions of the total (all zero for an empty program).
    pub fn class_fractions(&self) -> [f64; N_CLASSES] {
        let mut fracs = [0.0; N_CLASSES];
        if self.total_cycles > 0 {
            for (slot, &c) in fracs.iter_mut().zip(&self.class_cycles) {
                *slot = c as f64 / self.total_cycles as f64;
            }
        }
        fracs
    }
}

/// Profile one timed replay of `prog` at `base` on `sim` (honoring the
/// sim's current mode — callers normally set `TimingOnly`). Emits exactly
/// the instruction stream of [`Sim::execute`], so cycles, per-layer deltas,
/// and stats are identical to a plain timed replay; panics if the per-layer
/// or per-class sums fail to tile the total.
pub fn profile_program(sim: &mut Sim, prog: &CompiledProgram, base: u64) -> ProgramProfile {
    let lowered = prog.lowered();
    let classes: Vec<OpClass> = lowered.ops.iter().map(OpClass::of).collect();
    let spans = &lowered.spans;
    debug_assert_eq!(spans.len(), classes.len(), "spans parallel the micro-ops");

    let delta = sim.begin_replay(prog, base, None);
    let start = sim.cycles();
    let mut layers = Vec::with_capacity(prog.layers.len());
    let mut class_cycles = [0u64; N_CLASSES];
    let (mut reloc_i, mut span_i, mut layer_i) = (0usize, 0usize, 0usize);
    let (mut c_span, mut c_layer) = (start, start);
    // Degenerate zero-instruction layers at the very front.
    while layer_i < prog.layers.len() && prog.layers[layer_i].trace_end == 0 {
        let mark = &prog.layers[layer_i];
        layers.push(LayerCycles {
            name: mark.name.clone(),
            precision: mark.precision.label(),
            macs: mark.macs,
            cycles: 0,
        });
        layer_i += 1;
    }
    for idx in 0..prog.trace.len() {
        let instr = prog.trace[idx];
        let instr = if reloc_i < prog.reloc.len() && prog.reloc[reloc_i] as usize == idx {
            reloc_i += 1;
            relocate(instr, delta)
        } else {
            instr
        };
        sim.emit(instr);
        let here = (idx + 1) as u32;
        while span_i < spans.len() && spans[span_i].1 == here {
            let c = sim.cycles();
            class_cycles[classes[span_i].index()] += c - c_span;
            c_span = c;
            span_i += 1;
        }
        while layer_i < prog.layers.len() && prog.layers[layer_i].trace_end == idx + 1 {
            let mark = &prog.layers[layer_i];
            // Same boundary-credited MACs as `Sim::execute_with_input`.
            sim.stats_mut().effective_macs += mark.credited_macs;
            let c = sim.cycles();
            layers.push(LayerCycles {
                name: mark.name.clone(),
                precision: mark.precision.label(),
                macs: mark.macs,
                cycles: c - c_layer,
            });
            c_layer = c;
            layer_i += 1;
        }
    }
    debug_assert_eq!(layer_i, prog.layers.len(), "layer marks must tile the trace");
    debug_assert_eq!(span_i, spans.len(), "micro-op spans must tile the trace");

    let total_cycles = sim.cycles() - start;
    let layer_sum: u64 = layers.iter().map(|l| l.cycles).sum();
    let class_sum: u64 = class_cycles.iter().sum();
    assert_eq!(layer_sum, total_cycles, "Σ per-layer cycles must equal the replay total");
    assert_eq!(class_sum, total_cycles, "Σ per-class cycles must equal the replay total");
    ProgramProfile {
        model: prog.model().to_string(),
        schedule: prog.schedule().label(),
        layers,
        class_cycles,
        total_cycles,
    }
}

/// Compile-free convenience: profile `prog` on a fresh `TimingOnly` core of
/// `machine` (the shape `repro profile` and the test suites use).
pub fn profile_on_fresh_core(prog: &CompiledProgram, machine: &MachineConfig) -> ProgramProfile {
    let mut sim = Sim::with_memory(machine.clone(), shard_mem_bytes(prog));
    sim.set_mode(SimMode::TimingOnly);
    let base = sim.alloc(prog.mem_len());
    profile_program(&mut sim, prog, base)
}

/// Cycle attribution for a sharded deployment: one [`ProgramProfile`] per
/// shard core plus the aggregated cluster timeline — built by the same fold
/// as [`crate::cluster::cluster_timing`], so `timing.total_cycles()` equals
/// the coordinator's cached figure exactly.
#[derive(Clone, Debug)]
pub struct ClusterProfile {
    /// Per-shard profiles, in shard order.
    pub shards: Vec<ProgramProfile>,
    /// The aggregated per-layer `max(shard) + sync` cycle model.
    pub timing: ClusterTiming,
}

impl ClusterProfile {
    /// Element-wise sum of the shard cores' per-class cycles (core-cycles,
    /// not latency — shards overlap in time).
    pub fn class_cycles(&self) -> [u64; N_CLASSES] {
        let mut sum = [0u64; N_CLASSES];
        for p in &self.shards {
            for (slot, &c) in sum.iter_mut().zip(&p.class_cycles) {
                *slot += c;
            }
        }
        sum
    }
}

/// Profile every shard of `cluster` on fresh `TimingOnly` cores and fold
/// the per-layer cycles into the cluster model.
pub fn profile_cluster(cluster: &ClusterProgram, machine: &MachineConfig) -> ClusterProfile {
    let shards: Vec<ProgramProfile> = cluster
        .shard_programs()
        .iter()
        .map(|prog| profile_on_fresh_core(prog, machine))
        .collect();
    let per_shard: Vec<Vec<u64>> =
        shards.iter().map(|p| p.layers.iter().map(|l| l.cycles).collect()).collect();
    let timing = aggregate_timing(cluster, machine, &per_shard);
    ClusterProfile { shards, timing }
}

/// Cycle attribution for a pipeline-parallel deployment: one
/// [`ProgramProfile`] per stage core plus the fill/period/bubble model
/// ([`PipelineTiming`]) rebuilt from the profiled compute cycles — the same
/// figures [`crate::cluster::pipeline_timing`] measures, so the coordinator's
/// cached timing and the profiler agree exactly.
#[derive(Clone, Debug)]
pub struct PipelineProfile {
    /// Per-stage profiles, in stage order.
    pub stages: Vec<ProgramProfile>,
    /// The fill + (tokens − 1) · period cycle model over those stages.
    pub timing: PipelineTiming,
}

impl PipelineProfile {
    /// Element-wise sum of the stage cores' per-class cycles (core-cycles,
    /// not latency — stages overlap in time once the pipeline fills).
    pub fn class_cycles(&self) -> [u64; N_CLASSES] {
        let mut sum = [0u64; N_CLASSES];
        for p in &self.stages {
            for (slot, &c) in sum.iter_mut().zip(&p.class_cycles) {
                *slot += c;
            }
        }
        sum
    }
}

/// Profile every stage of `pipeline` on fresh `TimingOnly` cores and fold
/// the totals into the pipeline model for a stream of `tokens` requests.
///
/// Each stage's idle share is attributed explicitly: panics unless, for
/// every stage, `busy + bubble == total_cycles` — the conservation law the
/// [`PipelineTiming::bubble_cycles`] docs promise. This is what lets
/// `repro profile` explain pipeline efficiency (a stage's bubble is exactly
/// the time it waits on the stream's bottleneck stage plus fill/drain).
pub fn profile_pipeline(
    pipeline: &PipelineProgram,
    machine: &MachineConfig,
    tokens: u64,
) -> PipelineProfile {
    assert!(tokens >= 1, "a pipeline stream needs at least one request");
    let stages: Vec<ProgramProfile> = pipeline
        .stage_programs()
        .iter()
        .map(|prog| profile_on_fresh_core(prog, machine))
        .collect();
    let n = stages.len();
    let timing = PipelineTiming {
        stages: pipeline
            .stage_programs()
            .iter()
            .zip(&stages)
            .enumerate()
            .map(|(i, (prog, prof))| {
                let info = prog.stage().expect("pipeline programs carry stage info");
                StageTiming {
                    range: (info.lo, info.hi),
                    compute_cycles: prof.total_cycles,
                    hop_cycles: if i + 1 < n {
                        hop_cost(machine, prog.output_bytes() as u64)
                    } else {
                        0
                    },
                }
            })
            .collect(),
        tokens,
    };
    let total = timing.total_cycles();
    let (busy, bubbles) = (timing.busy_cycles(), timing.bubble_cycles());
    for s in 0..n {
        assert_eq!(
            busy[s] + bubbles[s],
            total,
            "stage {s}: busy + bubble cycles must tile the modeled total"
        );
    }
    assert_eq!(
        busy.iter().sum::<u64>() + bubbles.iter().sum::<u64>(),
        total * n as u64,
        "Σ stage busy + bubbles must equal the modeled total across all cores"
    );
    PipelineProfile { stages, timing }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{compile_pipeline, pipeline_timing};
    use crate::coordinator::demo_net;
    use crate::nn::model::{Precision, PrecisionMap};

    #[test]
    fn pipeline_profile_agrees_with_the_timing_model() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched =
            PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
        let p = compile_pipeline(&net, &quark, &sched, 2).unwrap();
        let prof = profile_pipeline(&p, &quark, 8);
        let timing = pipeline_timing(&p, &quark, 8);
        // The profiler replays the exact instruction stream `Sim::execute`
        // emits, so its per-stage totals match the timing model's.
        for (s, (got, want)) in prof.timing.stages.iter().zip(timing.stages.iter()).enumerate() {
            assert_eq!(got.compute_cycles, want.compute_cycles, "stage {s}");
            assert_eq!(got.hop_cycles, want.hop_cycles, "stage {s}");
        }
        assert_eq!(prof.timing.total_cycles(), timing.total_cycles());
        // Per-stage attribution still tiles each stage's own total.
        for p in &prof.stages {
            assert_eq!(p.layers.iter().map(|l| l.cycles).sum::<u64>(), p.total_cycles);
        }
        // Class cycles aggregate across stages.
        let sum: u64 = prof.class_cycles().iter().sum();
        assert_eq!(sum, prof.stages.iter().map(|p| p.total_cycles).sum::<u64>());
    }
}
