//! Observability: dual-clock tracing and cycle attribution.
//!
//! Two clock domains, one artifact:
//!
//! * **Host domain** — request-lifecycle spans in the coordinator
//!   (submit → queue → claim → batch-assemble → verify-gate → replay →
//!   reply, plus compile/lower/evict events), recorded by [`Tracer`] into a
//!   bounded ring per worker. Recording never blocks the serving path: a
//!   contended or full ring drops the event and bumps `trace_dropped`
//!   instead of waiting.
//! * **Simulated domain** — [`profile::profile_program`] attributes a timed
//!   replay's cycles to the program's layers ([`crate::program`]'s layer
//!   marks) and to the lowered micro-op classes
//!   ([`profile::OpClass`]: PlaneMac / RowSum / MaccByte / Bitpack / Interp
//!   / host-slice), with Σ(per-layer) == Σ(per-class) == total cycles
//!   enforced, not sampled.
//!
//! [`export`] writes both domains through one writer: Chrome `trace_event`
//! JSON (loadable in Perfetto / `chrome://tracing`, host spans and simulated
//! cycles as separate process tracks) and folded-stacks text for flamegraph
//! tooling. See `docs/observability.md`.
//!
//! Zero-cost-when-off: the coordinator holds the tracer in a `OnceLock`;
//! until `serve --trace` arms it, every hook is a single relaxed
//! pointer-load-and-branch and no event is ever allocated.

pub mod export;
pub mod profile;

pub use profile::{
    profile_cluster, profile_on_fresh_core, profile_pipeline, profile_program, ClusterProfile,
    LayerCycles, OpClass, PipelineProfile, ProgramProfile, N_CLASSES,
};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default per-ring event capacity ([`Tracer::new`]'s `cap`). At ~9 events
/// per served request this absorbs well over a thousand in-flight requests
/// per worker between `TRACE` drains.
pub const DEFAULT_RING_CAP: usize = 16_384;

/// What a host-domain [`TraceEvent`] marks. Span kinds (`dur_us > 0`) cover
/// the request lifecycle; the rest are instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Request admitted into the queue (instant, admission track).
    Submit,
    /// Time from enqueue to claim (span; ends when a worker claims it).
    Queue,
    /// Worker claimed the request into a batch (instant, carries batch id).
    Claim,
    /// Group resolution: program + timing caches, per batch (span).
    BatchAssemble,
    /// Cold `program::compile` for a cache miss (span).
    Compile,
    /// Decode-once lowering of a freshly compiled program (span).
    Lower,
    /// Static verifier gate on the insert path (span).
    VerifyGate,
    /// Functional replay — batched lowered replay or one cluster inference
    /// (span; batched requests share one event via the batch id).
    Replay,
    /// Response handed to the reply channel (instant; label carries the
    /// `ok` / `degraded` disposition).
    Reply,
    /// Request expired in queue — terminal, no reply span follows
    /// (recorded as a span covering the time waited).
    Expire,
    /// Program-cache eviction caused by this insert (instant).
    Evict,
}

impl SpanKind {
    /// Stable lowercase name used by both exporters.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Queue => "queue",
            SpanKind::Claim => "claim",
            SpanKind::BatchAssemble => "batch-assemble",
            SpanKind::Compile => "compile",
            SpanKind::Lower => "lower",
            SpanKind::VerifyGate => "verify-gate",
            SpanKind::Replay => "replay",
            SpanKind::Reply => "reply",
            SpanKind::Expire => "expire",
            SpanKind::Evict => "evict",
        }
    }
}

/// One host-domain event. Timestamps are microseconds since the tracer's
/// epoch ([`Tracer::now_us`] / [`Tracer::us_at`]).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub kind: SpanKind,
    /// Start (spans) or occurrence (instants) time, µs since epoch.
    pub ts_us: u64,
    /// Span length in µs; 0 marks an instant event.
    pub dur_us: u64,
    /// Ring the event was recorded on: worker id, or the admission track
    /// ([`Tracer::admission_track`]) for submit/expire. Set by
    /// [`Tracer::record`].
    pub track: usize,
    /// Client-chosen request id, when the event belongs to one request.
    pub req: Option<u64>,
    /// Coordinator batch id — batched requests share it, tying their
    /// queue/claim/reply events to one replay span.
    pub batch: Option<u64>,
    /// Free-form detail: the DeployKey label (`model|schedule|shards`), the
    /// reply disposition, etc. Empty when the kind says it all.
    pub label: String,
}

impl TraceEvent {
    /// A span of `dur_us` starting at `ts_us`.
    pub fn span(kind: SpanKind, ts_us: u64, dur_us: u64) -> TraceEvent {
        TraceEvent { kind, ts_us, dur_us, track: 0, req: None, batch: None, label: String::new() }
    }

    /// An instant event at `ts_us`.
    pub fn instant(kind: SpanKind, ts_us: u64) -> TraceEvent {
        TraceEvent::span(kind, ts_us, 0)
    }

    /// Attach the request id.
    pub fn with_req(mut self, id: u64) -> TraceEvent {
        self.req = Some(id);
        self
    }

    /// Attach the batch id.
    pub fn with_batch(mut self, id: u64) -> TraceEvent {
        self.batch = Some(id);
        self
    }

    /// Attach a detail label.
    pub fn with_label(mut self, label: impl Into<String>) -> TraceEvent {
        self.label = label.into();
        self
    }
}

/// Bounded multi-ring event sink: one ring per worker plus an admission
/// ring for events raised outside any worker (submit, expire).
///
/// The recording path is wait-free with respect to the serving path: it
/// takes a ring's lock only via `try_lock`, so a concurrent drain (or an
/// unlucky collision) costs a dropped event — counted in
/// [`Tracer::dropped`] — never a stall.
pub struct Tracer {
    epoch: Instant,
    rings: Vec<Mutex<VecDeque<TraceEvent>>>,
    cap: usize,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

impl Tracer {
    /// A tracer with `workers + 1` rings (one per worker, one admission
    /// ring), each holding at most `cap` events between drains.
    pub fn new(workers: usize, cap: usize) -> Tracer {
        let rings = (0..workers + 1).map(|_| Mutex::new(VecDeque::new())).collect();
        Tracer {
            epoch: Instant::now(),
            rings,
            cap: cap.max(1),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Ring index for events raised outside any worker (submit, expire).
    pub fn admission_track(&self) -> usize {
        self.rings.len() - 1
    }

    /// Microseconds since the tracer's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// µs-since-epoch of an `Instant` captured elsewhere (0 if it predates
    /// the epoch — e.g. a request enqueued before tracing was armed).
    pub fn us_at(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record `ev` on `track`'s ring (clamped to the admission ring).
    /// Never blocks: a contended or full ring drops the event and bumps the
    /// drop counter instead.
    pub fn record(&self, track: usize, mut ev: TraceEvent) {
        let track = track.min(self.rings.len() - 1);
        match self.rings[track].try_lock() {
            Ok(mut ring) if ring.len() < self.cap => {
                ev.track = track;
                ring.push_back(ev);
                self.recorded.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Events successfully recorded since construction (drains included).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Events dropped on full or contended rings since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drain every ring, returning all buffered events sorted by start
    /// timestamp. Drains block-lock each ring in turn (the recording side
    /// stays non-blocking — it just drops into the counter meanwhile).
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut all = Vec::new();
        for ring in &self.rings {
            let mut ring = ring.lock().unwrap();
            all.extend(ring.drain(..));
        }
        all.sort_by_key(|e| (e.ts_us, e.dur_us));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_capacity_drops_and_counts_instead_of_blocking() {
        let tr = Tracer::new(1, 4);
        for i in 0..10 {
            tr.record(0, TraceEvent::instant(SpanKind::Submit, i));
        }
        assert_eq!(tr.recorded(), 4);
        assert_eq!(tr.dropped(), 6);
        assert_eq!(tr.drain().len(), 4);
        // Drained rings accept events again.
        tr.record(0, TraceEvent::instant(SpanKind::Submit, 99));
        assert_eq!(tr.drain().len(), 1);
        assert_eq!(tr.dropped(), 6);
    }

    #[test]
    fn drain_merges_rings_sorted_by_timestamp() {
        let tr = Tracer::new(2, 16);
        tr.record(1, TraceEvent::instant(SpanKind::Reply, 30));
        tr.record(0, TraceEvent::span(SpanKind::Queue, 10, 5).with_req(7).with_batch(3));
        tr.record(tr.admission_track(), TraceEvent::instant(SpanKind::Submit, 20));
        let evs = tr.drain();
        let ts: Vec<u64> = evs.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![10, 20, 30]);
        assert_eq!(evs[0].track, 0);
        assert_eq!(evs[0].req, Some(7));
        assert_eq!(evs[0].batch, Some(3));
        assert_eq!(evs[1].track, tr.admission_track());
        assert!(tr.drain().is_empty());
    }

    #[test]
    fn out_of_range_tracks_clamp_to_the_admission_ring() {
        let tr = Tracer::new(1, 16);
        tr.record(usize::MAX, TraceEvent::instant(SpanKind::Expire, 1));
        let evs = tr.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].track, tr.admission_track());
    }

    #[test]
    fn instants_before_the_epoch_saturate_to_zero() {
        let earlier = Instant::now();
        let tr = Tracer::new(1, 16);
        assert_eq!(tr.us_at(earlier), 0);
    }
}
