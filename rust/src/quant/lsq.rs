//! LSQ-style static quantizers (inference side).
//!
//! LSQ [Esser et al., ICLR'20] *learns* the step size during training; that
//! happens in `python/compile/train_lsq.py`. At inference time a quantizer is
//! just a step size + grid, which is what these helpers produce for the
//! simulator-side kernels and tests. The formulas here mirror
//! `python/compile/quantize.py` exactly — the cross-check in the coordinator
//! depends on both sides agreeing bit-for-bit on the integer codes.

/// Unsigned activation quantizer: `a_real = scale · a_u`, `a_u ∈ [0, 2ⁿ−1]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuant {
    pub bits: u8,
    pub scale: f32,
}

impl ActQuant {
    pub fn qmax(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Quantize one real activation to its unsigned code.
    pub fn quantize(&self, x: f32) -> u8 {
        let q = (x / self.scale).round_ties_even();
        q.clamp(0.0, self.qmax() as f32) as u8
    }

    pub fn dequantize(&self, q: u8) -> f32 {
        self.scale * q as f32
    }
}

/// Affine unsigned weight quantizer: `w_real = alpha · w_u + beta`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightQuant {
    pub bits: u8,
    pub alpha: f32,
    pub beta: f32,
}

impl WeightQuant {
    pub fn qmax(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Build from a symmetric signed step size (the LSQ parameter).
    ///
    /// * `bits == 1`: binary weights `{−s, +s}` → `α = 2s`, `β = −s`.
    /// * `bits ≥ 2`: offset-binary → `α = s`, `β = −s·2^(bits−1)`.
    pub fn from_symmetric_scale(bits: u8, s: f32) -> Self {
        if bits == 1 {
            WeightQuant { bits, alpha: 2.0 * s, beta: -s }
        } else {
            WeightQuant { bits, alpha: s, beta: -s * (1u32 << (bits - 1)) as f32 }
        }
    }

    pub fn dequantize(&self, q: u8) -> f32 {
        self.alpha * q as f32 + self.beta
    }
}

/// Quantize a weight tensor to unsigned codes with a symmetric LSQ-style
/// scale derived from the data (inference-time equivalent of a trained step).
///
/// Returns `(codes, quantizer)`.
pub fn quantize_weights_unsigned(w: &[f32], bits: u8) -> (Vec<u8>, WeightQuant) {
    assert!((1..=8).contains(&bits));
    let absmax = w.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    if bits == 1 {
        // {-s, +s} with s = E[|w|] (XNOR-Net / BinaryNet style scaling).
        let s = (w.iter().map(|&x| x.abs() as f64).sum::<f64>() / w.len().max(1) as f64) as f32;
        let s = s.max(1e-8);
        let wq = WeightQuant::from_symmetric_scale(1, s);
        let codes = w.iter().map(|&x| if x >= 0.0 { 1u8 } else { 0u8 }).collect();
        (codes, wq)
    } else {
        let qmax_side = (1i32 << (bits - 1)) - 1; // e.g. 127 for 8-bit
        let s = absmax / qmax_side as f32;
        let wq = WeightQuant::from_symmetric_scale(bits, s);
        let offset = 1i32 << (bits - 1);
        let codes = w
            .iter()
            .map(|&x| {
                let q = (x / s).round_ties_even() as i32;
                let q = q.clamp(-offset, qmax_side);
                (q + offset) as u8
            })
            .collect();
        (codes, wq)
    }
}

/// Quantize weights to *signed* int8 codes (the Ara baseline's format).
/// Returns `(codes, scale)` with `w_real = scale · w_s`.
pub fn quantize_weights_signed(w: &[f32], bits: u8) -> (Vec<i8>, f32) {
    assert!((2..=8).contains(&bits));
    let absmax = w.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    let qmax = (1i32 << (bits - 1)) - 1;
    let s = absmax / qmax as f32;
    let codes = w
        .iter()
        .map(|&x| (x / s).round_ties_even().clamp(-(qmax as f32) - 1.0, qmax as f32) as i8)
        .collect();
    (codes, s)
}

/// Quantize an activation tensor to unsigned codes with a data-derived scale
/// (max-based; the trained model carries its own scales).
pub fn quantize_activations(a: &[f32], bits: u8) -> (Vec<u8>, ActQuant) {
    assert!((1..=8).contains(&bits));
    let maxv = a.iter().fold(0f32, |m, &x| m.max(x)).max(1e-8);
    let qmax = (1u32 << bits) - 1;
    let aq = ActQuant { bits, scale: maxv / qmax as f32 };
    let codes = a.iter().map(|&x| aq.quantize(x)).collect();
    (codes, aq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsigned_weight_codes_dequantize_close() {
        let w: Vec<f32> = (-8..8).map(|i| i as f32 / 5.0).collect();
        for bits in [2u8, 4, 8] {
            let (codes, wq) = quantize_weights_unsigned(&w, bits);
            let max_err = w
                .iter()
                .zip(codes.iter())
                .map(|(&x, &q)| (x - wq.dequantize(q)).abs())
                .fold(0f32, f32::max);
            // Error bounded by one step.
            assert!(max_err <= wq.alpha * 0.5 + 1e-6, "bits={bits} err={max_err}");
        }
    }

    #[test]
    fn binary_weights_are_sign_codes() {
        let w = [0.5f32, -0.25, 0.75, -1.0];
        let (codes, wq) = quantize_weights_unsigned(&w, 1);
        assert_eq!(codes, vec![1, 0, 1, 0]);
        // Dequantized values are ±s with s = mean |w| = 0.625.
        assert!((wq.dequantize(1) - 0.625).abs() < 1e-6);
        assert!((wq.dequantize(0) + 0.625).abs() < 1e-6);
    }

    #[test]
    fn activation_codes_are_unsigned_and_bounded() {
        let a = [0.0f32, 0.1, 0.5, 1.0, 2.0];
        for bits in [1u8, 2, 8] {
            let (codes, aq) = quantize_activations(&a, bits);
            assert!(codes.iter().all(|&c| (c as u32) <= aq.qmax()));
            assert_eq!(codes[0], 0);
            assert_eq!(codes[4] as u32, aq.qmax()); // max maps to qmax
        }
    }

    #[test]
    fn affine_identity_acc_asum() {
        // Σ w_real·a_real == s_a·(α·ACC + β·ASUM): the identity the whole
        // bit-serial pipeline rests on.
        let w = [0.4f32, -0.3, 0.9, -0.7];
        let a = [0.2f32, 0.8, 0.5, 0.1];
        let (wc, wq) = quantize_weights_unsigned(&w, 2);
        let (ac, aq) = quantize_activations(&a, 2);
        let acc: u32 = wc.iter().zip(ac.iter()).map(|(&x, &y)| x as u32 * y as u32).sum();
        let asum: u32 = ac.iter().map(|&y| y as u32).sum();
        let via_codes = aq.scale * (wq.alpha * acc as f32 + wq.beta * asum as f32);
        let direct: f32 = wc
            .iter()
            .zip(ac.iter())
            .map(|(&x, &y)| wq.dequantize(x) * aq.dequantize(y))
            .sum();
        assert!((via_codes - direct).abs() < 1e-4, "{via_codes} vs {direct}");
    }
}
