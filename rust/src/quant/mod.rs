//! Quantization substrate: the integer semantics shared by the simulated
//! kernels, the host-side golden references, and (mirrored exactly) the JAX
//! model in `python/compile/quantize.py`.
//!
//! Scheme (matches the paper's LSQ-style inference pipeline, Fig. 2):
//!
//! * **Activations** are *unsigned* `n`-bit codes (post-ReLU):
//!   `a_real = s_a · a_u`, `a_u ∈ [0, 2ⁿ−1]`, zero-point 0.
//! * **Weights** are affine in an unsigned code so the bit-serial AND/popcount
//!   product (paper Eq. 1) applies directly: `w_real = α · w_u + β`.
//!   - `m ≥ 2`: offset-binary symmetric, `w_u = w_s + 2^(m−1)`,
//!     `α = s_w`, `β = −s_w · 2^(m−1)`.
//!   - `m = 1`: binary weights `{−s_w, +s_w}`, `w_u ∈ {0,1}`,
//!     `α = 2·s_w`, `β = −s_w`.
//! * A convolution therefore needs two integer results:
//!   `ACC = Σ w_u·a_u` (the bit-serial kernel, Eq. 1) and `ASUM = Σ a_u`
//!   (a per-patch activation sum), combined in *floating point on the scalar
//!   core* — exactly the paper's "re-scaling on CVA6" step:
//!
//!   `out_real = s_a·(α·ACC + β·ASUM) + bias`, then requantized onto the next
//!   layer's unsigned grid.
//!
//! Under a mixed per-layer schedule ([`crate::nn::model::PrecisionMap`])
//! "the next layer's unsigned grid" is literal: the requant clamp of each
//! layer targets `2^b − 1` for the narrowest consumer's activation width
//! `b` ([`crate::nn::model::map_consumer_bits`]), so an 8-bit layer feeding
//! a 2-bit one emits valid 2-bit codes and no separate repack pass is
//! needed.

pub mod lsq;
pub mod pack;
pub mod requant;

pub use lsq::{
    quantize_activations, quantize_weights_signed, quantize_weights_unsigned, ActQuant, WeightQuant,
};
pub use pack::{pack_bit_planes, pack_weight_planes, planes_words, PackedWeights};
pub use requant::{requantize_golden, RequantParams};
