//! Bit-plane packing (host side).
//!
//! The bit-serial kernels consume data in *bit-stream* (plane-major) layout:
//! plane `p` of a K-element unsigned tensor is a K-bit vector whose bit `i`
//! is bit `p` of element `i`, packed LSB-first into 64-bit words.
//!
//! * **Weights** are packed *offline* (here, on the host) — the paper does
//!   the same: weight layout is a compile-time decision.
//! * **Activations** must be packed *at runtime*, every layer; that is what
//!   `vbitpack` accelerates (see `kernels/bitpack.rs` for both the custom-
//!   instruction path and the pure-RVV fallback). The functions here serve as
//!   the golden reference those kernels are tested against.
//!
//! Under a mixed per-layer schedule each layer's weights are packed at *its
//! own* `weight_bits` (the `bits` argument below; the model runner passes
//! the per-layer value from [`crate::nn::model::PrecisionMap`]) — the
//! plane-major layout is width-agnostic, so 1-, 2-, and 8-bit layers can
//! coexist in one network with no layout changes.

/// Number of 64-bit words per plane for a K-element tensor.
pub fn planes_words(k: usize) -> usize {
    k.div_ceil(64)
}

/// Pack `values[0..k]` (unsigned codes) into `bits` planes, plane-major:
/// returns `planes[p][kw]` with bit `i % 64` of word `kw = i / 64` equal to
/// bit `p` of `values[i]`. Values beyond `values.len()` (zero padding up to a
/// word boundary) pack as 0 — consistent with zero-padded convolution edges.
pub fn pack_bit_planes(values: &[u8], bits: u8) -> Vec<Vec<u64>> {
    let kw = planes_words(values.len());
    let mut planes = vec![vec![0u64; kw]; bits as usize];
    for (i, &v) in values.iter().enumerate() {
        for (p, plane) in planes.iter_mut().enumerate() {
            if (v >> p) & 1 == 1 {
                plane[i / 64] |= 1 << (i % 64);
            }
        }
    }
    planes
}

/// Weights packed for the channel-vectorized bit-serial kernel.
///
/// Layout: `words[jb][q][kw][j]` flattened in that order, where
/// * `jb` — output-channel block (blocks of `block` channels, the kernel's
///   `vl` at SEW=64),
/// * `q`  — weight bit plane,
/// * `kw` — 64-bit word index along the reduction (K) axis,
/// * `j`  — channel within the block (vector element index).
///
/// One `vle64.v` with `vl = block` loads the per-channel words for a given
/// `(q, kw)` — the quantity `vand.vx`-ed against a broadcast activation word.
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub words: Vec<u64>,
    pub n: usize,
    pub k: usize,
    pub bits: u8,
    pub block: usize,
}

impl PackedWeights {
    pub fn kw(&self) -> usize {
        planes_words(self.k)
    }

    pub fn blocks(&self) -> usize {
        self.n.div_ceil(self.block)
    }

    /// Flat word index of `(jb, q, kw, j)`.
    pub fn index(&self, jb: usize, q: usize, kw: usize, j: usize) -> usize {
        ((jb * self.bits as usize + q) * self.kw() + kw) * self.block + j
    }

    /// Byte offset of the `(jb, q, kw)` channel-vector within the flat buffer.
    pub fn vec_byte_offset(&self, jb: usize, q: usize, kw: usize) -> u64 {
        (self.index(jb, q, kw, 0) * 8) as u64
    }

    pub fn byte_len(&self) -> usize {
        self.words.len() * 8
    }
}

/// Pack a `[K][N]` unsigned weight matrix (row-major, `w[k * n + j]`) for the
/// channel-vectorized kernel. `block` is the output-channel vector length
/// (64 on the 4-lane configs at SEW=64); N is zero-padded to a multiple.
pub fn pack_weight_planes(w: &[u8], k: usize, n: usize, bits: u8, block: usize) -> PackedWeights {
    assert_eq!(w.len(), k * n, "weight matrix shape mismatch");
    let kw = planes_words(k);
    let blocks = n.div_ceil(block);
    let mut words = vec![0u64; blocks * bits as usize * kw * block];
    for jb in 0..blocks {
        for j in 0..block {
            let ch = jb * block + j;
            if ch >= n {
                continue; // zero padding
            }
            for kk in 0..k {
                let v = w[kk * n + ch];
                for q in 0..bits as usize {
                    if (v >> q) & 1 == 1 {
                        let idx = ((jb * bits as usize + q) * kw + kk / 64) * block + j;
                        words[idx] |= 1 << (kk % 64);
                    }
                }
            }
        }
    }
    PackedWeights { words, n, k, bits, block }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_packing_roundtrips() {
        let vals: Vec<u8> = (0..130).map(|i| (i * 7 % 16) as u8).collect();
        let planes = pack_bit_planes(&vals, 4);
        assert_eq!(planes.len(), 4);
        assert_eq!(planes[0].len(), 3); // ceil(130/64)
        // Reconstruct.
        for (i, &v) in vals.iter().enumerate() {
            let mut r = 0u8;
            for (p, plane) in planes.iter().enumerate() {
                r |= (((plane[i / 64] >> (i % 64)) & 1) as u8) << p;
            }
            assert_eq!(r, v, "element {i}");
        }
    }

    #[test]
    fn weight_packing_reconstructs_dot_products() {
        // The packed layout must preserve Eq. 1: for every channel j,
        // Σ_q 2^q popcount(Wq[j] & Aplane) == Σ_k w[k][j]·a_bit[k].
        let k = 96;
        let n = 5;
        let bits = 2u8;
        let w: Vec<u8> = (0..k * n).map(|i| (i % 4) as u8).collect();
        let a_bits: Vec<u8> = (0..k).map(|i| ((i * 3) % 2) as u8).collect();
        let pw = pack_weight_planes(&w, k, n, bits, 4);
        let aplanes = pack_bit_planes(&a_bits, 1);
        for ch in 0..n {
            let jb = ch / 4;
            let j = ch % 4;
            let mut acc = 0u64;
            for q in 0..bits as usize {
                for kw in 0..pw.kw() {
                    let wword = pw.words[pw.index(jb, q, kw, j)];
                    acc += (1 << q) * (wword & aplanes[0][kw]).count_ones() as u64;
                }
            }
            let direct: u64 = (0..k).map(|kk| (w[kk * n + ch] * a_bits[kk]) as u64).sum();
            assert_eq!(acc, direct, "channel {ch}");
        }
    }

    #[test]
    fn padded_channels_are_zero() {
        let pw = pack_weight_planes(&[3u8; 64 * 3], 64, 3, 2, 4);
        // Channel 3 (padding) contributes zero words everywhere.
        for q in 0..2 {
            assert_eq!(pw.words[pw.index(0, q, 0, 3)], 0);
        }
    }
}
