//! Re-quantization math (the "Div/Mul + Clip + Round" box of paper Fig. 2).
//!
//! The paper keeps this step on the CVA6 scalar FPU — it is the only
//! floating-point work left after the FPU was stripped from the vector lanes.
//! `requantize_golden` is the host-side oracle; `kernels/requantize.rs` emits
//! the *identical* operation sequence as scalar FP instructions so the
//! simulated result matches bit-for-bit:
//!
//! ```text
//! t    = fmadd(beta,  ASUM, fmadd(alpha, ACC, c))   ; c = bias'/residual acc.
//! t    = fmax(t, 0)  ; fmin(t, qmax)                ; clamp
//! code = fcvt.w.s(t)                                ; round-to-nearest-even
//! ```

/// Per-output-channel requantization parameters, pre-folded on the host
/// (weights' α/β, the input/output activation scales, BN fold, and bias).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequantParams {
    /// Multiplier of the integer accumulator: `s_a · α / s_out`.
    pub alpha: f32,
    /// Multiplier of the patch activation sum: `s_a · β / s_out`.
    pub beta: f32,
    /// Constant term: `bias / s_out`.
    pub bias: f32,
    /// Output grid max: `2ⁿ − 1`.
    pub qmax: f32,
    /// Residual-add multiplier (`s_res / s_out`), 0 when no skip connection.
    pub res_scale: f32,
}

impl RequantParams {
    pub fn new(
        act_scale: f32,
        w_alpha: f32,
        w_beta: f32,
        bias: f32,
        out_scale: f32,
        out_bits: u8,
    ) -> Self {
        RequantParams {
            alpha: act_scale * w_alpha / out_scale,
            beta: act_scale * w_beta / out_scale,
            bias: bias / out_scale,
            qmax: ((1u32 << out_bits) - 1) as f32,
            res_scale: 0.0,
        }
    }

    pub fn with_residual(mut self, res_scale: f32, out_scale: f32) -> Self {
        self.res_scale = res_scale / out_scale;
        self
    }
}

/// Golden requantization — must mirror the scalar-FP instruction sequence in
/// `kernels/requantize.rs` operation-for-operation (f32, fused multiply-add).
pub fn requantize_golden(acc: i64, asum: i64, residual: u8, p: &RequantParams) -> u8 {
    let c = if p.res_scale != 0.0 {
        p.res_scale.mul_add(residual as f32, p.bias)
    } else {
        p.bias
    };
    let t = p.alpha.mul_add(acc as f32, c);
    let t = p.beta.mul_add(asum as f32, t);
    let t = t.max(0.0).min(p.qmax);
    t.round_ties_even() as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_to_grid() {
        let p = RequantParams { alpha: 1.0, beta: 0.0, bias: 0.0, qmax: 3.0, res_scale: 0.0 };
        assert_eq!(requantize_golden(-5, 0, 0, &p), 0);
        assert_eq!(requantize_golden(2, 0, 0, &p), 2);
        assert_eq!(requantize_golden(99, 0, 0, &p), 3);
    }

    #[test]
    fn asum_correction_applies() {
        // alpha·ACC + beta·ASUM with alpha=1, beta=-0.5: ACC=10, ASUM=8 → 6.
        let p = RequantParams { alpha: 1.0, beta: -0.5, bias: 0.0, qmax: 255.0, res_scale: 0.0 };
        assert_eq!(requantize_golden(10, 8, 0, &p), 6);
    }

    #[test]
    fn residual_folds_in() {
        let p = RequantParams { alpha: 0.0, beta: 0.0, bias: 1.0, qmax: 255.0, res_scale: 2.0 };
        assert_eq!(requantize_golden(0, 0, 3, &p), 7); // 2·3 + 1
    }

    #[test]
    fn rounds_ties_to_even() {
        let p = RequantParams { alpha: 0.5, beta: 0.0, bias: 0.0, qmax: 255.0, res_scale: 0.0 };
        assert_eq!(requantize_golden(5, 0, 0, &p), 2); // 2.5 → 2
        assert_eq!(requantize_golden(7, 0, 0, &p), 4); // 3.5 → 4
    }
}
