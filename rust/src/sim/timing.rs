//! Structural timing model: the "how many cycles" half of the simulator.
//!
//! The model is a scoreboard over architectural registers plus per-unit
//! busy-until clocks, with Ara-style chaining between vector instructions:
//!
//! * CVA6 issues at most one instruction per cycle, in order (it is an
//!   in-order issue/commit core with a scoreboard — paper §III).
//! * Vector instructions pay a dispatch/acknowledge handshake
//!   (`dispatch_latency`) and a sequencer start-up (`vstartup_latency`), then
//!   occupy one functional unit for `ceil(vl / throughput)` cycles.
//! * A dependent vector instruction *chains*: it may start
//!   `chain_latency` cycles after its producer started (element-wise
//!   forwarding through the operand queues), but cannot finish before the
//!   producer finishes.
//! * Mask-producing ops run on the mask unit at `mask_elems_per_lane_cycle`
//!   elements/lane/cycle — the structural reason `vbitpack` wins (paper
//!   Fig. 3): packing without it serializes on this unit.
//! * Vector memory ops additionally occupy the shared AXI bus at
//!   `axi_bytes_per_cycle`, so compute and memory contend the way the
//!   paper's roofline (Fig. 4) assumes.
//! * Scalar reads of vector state (`vmv.x.s`) wait for full completion —
//!   the scalar-vector synchronization cost of bit-serial reductions.

use crate::arch::MachineConfig;
use crate::isa::instr::{FUnit, Instr, ScalarOp, VMemKind, VOp};
use crate::isa::vtype::Sew;

use super::stats::Stats;

const N_UNITS: usize = 13;

fn unit_idx(u: FUnit) -> usize {
    match u {
        FUnit::ScalarAlu => 0,
        FUnit::ScalarMul => 1,
        FUnit::ScalarMem => 2,
        FUnit::ScalarFpu => 3,
        FUnit::ScalarCtl => 4,
        FUnit::VCfg => 5,
        FUnit::VAlu => 6,
        FUnit::VMul => 7,
        FUnit::VFpu => 8,
        FUnit::VMask => 9,
        FUnit::VRed => 10,
        FUnit::VLsu => 11,
        FUnit::VSld => 12,
    }
}

/// Scoreboard timing state.
pub struct Timing {
    cfg: MachineConfig,
    /// Next cycle at which CVA6 can issue (1 IPC in-order front end).
    scalar_clock: u64,
    /// Ready times for scalar / fp / vector registers.
    x_ready: [u64; 32],
    f_ready: [u64; 32],
    v_ready: [u64; 32],
    /// Start time of the most recent producer of each vector register (for
    /// chaining).
    v_start: [u64; 32],
    unit_busy: [u64; N_UNITS],
    /// Shared AXI bus availability.
    bus_free: u64,
    /// Program-order monotonicity of vector issue (the sequencer issues in
    /// order even across different units).
    last_vissue: u64,
    /// Ring of the last `vq_depth` vector-instruction start times: CVA6 may
    /// only run `vq_depth` undispatched vector instructions ahead.
    vq_ring: Vec<u64>,
    vq_count: usize,
    /// High-water mark: completion time of everything issued so far.
    horizon: u64,
}

impl Timing {
    pub fn new(cfg: &MachineConfig) -> Self {
        Timing {
            cfg: cfg.clone(),
            scalar_clock: 0,
            x_ready: [0; 32],
            f_ready: [0; 32],
            v_ready: [0; 32],
            v_start: [0; 32],
            unit_busy: [0; N_UNITS],
            bus_free: 0,
            last_vissue: 0,
            vq_ring: vec![0; cfg.vq_depth.max(1)],
            vq_count: 0,
            horizon: 0,
        }
    }

    /// Current cycle count (everything issued so far has completed).
    pub fn cycles(&self) -> u64 {
        self.horizon
    }

    /// Cycle at which the next scalar instruction would issue (used for the
    /// `cycle` CSR, which reads the *committed* count like the paper's
    /// measurements do).
    pub fn now(&self) -> u64 {
        self.scalar_clock
    }

    /// Advance the model by one instruction; `vl`/`sew` are the vector state
    /// *at issue* (captured by `Sim` before functional execution).
    pub fn step(&mut self, instr: &Instr, vl: u64, sew: Sew, stats: &mut Stats) {
        match instr {
            Instr::Scalar(op) => self.step_scalar(op, stats),
            Instr::VSetVli { rd, .. } => {
                // Handled in CVA6 + Ara dispatcher back-to-back; one issue slot.
                let issue = self.scalar_clock;
                let done = issue + 1;
                self.x_ready[rd.0 as usize] = done;
                self.scalar_clock = issue + 1;
                self.horizon = self.horizon.max(done);
                stats.vcfg_instrs += 1;
            }
            Instr::Vector(op) => self.step_vector(op, vl, sew, stats),
        }
    }

    fn reg_ready(&self, r: crate::isa::Reg) -> u64 {
        self.x_ready[r.0 as usize]
    }

    fn step_scalar(&mut self, op: &ScalarOp, stats: &mut Stats) {
        use ScalarOp::*;
        stats.scalar_instrs += 1;
        // Operand readiness.
        let mut ready = self.scalar_clock;
        let track = |r: crate::isa::Reg, ready: &mut u64| {
            *ready = (*ready).max(self.x_ready[r.0 as usize]);
        };
        let ftrack = |r: crate::isa::reg::FReg, ready: &mut u64| {
            *ready = (*ready).max(self.f_ready[r.0 as usize]);
        };
        match *op {
            Li { .. } | Branch { .. } | Nop | CsrReadCycle { .. } => {}
            Alu { rs1, rs2, .. } => {
                track(rs1, &mut ready);
                track(rs2, &mut ready);
            }
            AluImm { rs1, .. } => track(rs1, &mut ready),
            Load { base, .. } => track(base, &mut ready),
            Store { rs2, base, .. } => {
                track(rs2, &mut ready);
                track(base, &mut ready);
            }
            FLoad { base, .. } => track(base, &mut ready),
            FStore { rs2, base, .. } => {
                ftrack(rs2, &mut ready);
                track(base, &mut ready);
            }
            FAlu { rs1, rs2, .. } => {
                ftrack(rs1, &mut ready);
                ftrack(rs2, &mut ready);
            }
            FMadd { rs1, rs2, rs3, .. } => {
                ftrack(rs1, &mut ready);
                ftrack(rs2, &mut ready);
                ftrack(rs3, &mut ready);
            }
            FCvtWS { rs1, .. } => ftrack(rs1, &mut ready),
            FCvtSW { rs1, .. } => track(rs1, &mut ready),
            FMvXW { rs1, .. } => ftrack(rs1, &mut ready),
            FMvWX { rs1, .. } => track(rs1, &mut ready),
        }
        let issue = ready;
        let lat = match op {
            Load { .. } | FLoad { .. } => self.cfg.scalar_load_latency,
            Store { .. } | FStore { .. } => 1,
            Alu { op: crate::isa::instr::AluOp::Mul, .. }
            | Alu { op: crate::isa::instr::AluOp::Mulh, .. } => self.cfg.scalar_mul_latency,
            Alu { op: crate::isa::instr::AluOp::Div, .. }
            | Alu { op: crate::isa::instr::AluOp::Rem, .. } => 20,
            FAlu { .. } | FMadd { .. } => self.cfg.scalar_fp_latency,
            // Converts are short ops on FPnew.
            FCvtWS { .. } | FCvtSW { .. } => 2,
            Branch { taken } => {
                // Not-taken predicted correctly most of the time; taken
                // back-edges cost a small redirect on CVA6.
                if *taken {
                    2
                } else {
                    1
                }
            }
            _ => 1,
        };
        if matches!(op, FAlu { .. } | FMadd { .. } | FCvtWS { .. } | FCvtSW { .. }) {
            stats.scalar_fpu_cycles += lat;
        }
        if let Load { width, .. } = op {
            stats.scalar_mem_bytes += width.bytes() as u64;
        }
        if let Store { width, .. } = op {
            stats.scalar_mem_bytes += width.bytes() as u64;
        }
        if matches!(op, FLoad { .. } | FStore { .. }) {
            stats.scalar_mem_bytes += 4;
        }
        let done = issue + lat;
        // Writeback.
        match *op {
            Li { rd, .. } | Alu { rd, .. } | AluImm { rd, .. } | Load { rd, .. }
            | FCvtWS { rd, .. } | FMvXW { rd, .. } | CsrReadCycle { rd } => {
                if rd.0 != 0 {
                    self.x_ready[rd.0 as usize] = done;
                }
            }
            FLoad { rd, .. } | FAlu { rd, .. } | FMadd { rd, .. } | FCvtSW { rd, .. }
            | FMvWX { rd, .. } => {
                self.f_ready[rd.0 as usize] = done;
            }
            _ => {}
        }
        // 1 IPC front end: next instruction issues one cycle later at the
        // earliest (fully pipelined units; latency only gates dependents).
        self.scalar_clock = issue + 1;
        self.horizon = self.horizon.max(done);
    }

    /// Duration (occupancy cycles) of a vector op on its unit.
    fn vduration(&self, op: &VOp, vl: u64, sew: Sew) -> u64 {
        let lanes = self.cfg.lanes as f64;
        match op.unit() {
            FUnit::VMask => {
                // Mask unit: element-serial across lanes.
                (vl as f64 / (lanes * self.cfg.mask_elems_per_lane_cycle)).ceil() as u64
            }
            FUnit::VRed => {
                // Element accumulation at full rate + inter-lane tree.
                let epc = self.cfg.elems_per_cycle(sew.bits());
                (vl as f64 / epc).ceil() as u64 + (self.cfg.lanes as f64).log2().ceil() as u64 + 3
            }
            FUnit::VSld => {
                // vbitpack: consumes vl elements of sew bits through the
                // permutation network at lanes×64 input bits/cycle.
                ((vl * sew.bits() as u64) as f64 / (lanes * 64.0)).ceil() as u64
            }
            FUnit::VLsu => {
                let bytes = self.vmem_bytes(op, vl);
                match op {
                    VOp::Load { kind: VMemKind::Strided { .. }, .. }
                    | VOp::Store { kind: VMemKind::Strided { .. }, .. } => {
                        // Strided access degrades to ~1 element per cycle.
                        vl.max(1)
                    }
                    _ => (bytes as f64 / self.cfg.axi_bytes_per_cycle as f64).ceil() as u64,
                }
            }
            _ => {
                let epc = self.cfg.elems_per_cycle(sew.bits());
                (vl as f64 / epc).ceil() as u64
            }
        }
        .max(1)
    }

    fn vmem_bytes(&self, op: &VOp, vl: u64) -> u64 {
        match op {
            VOp::Load { eew, .. } | VOp::Store { eew, .. } => vl * eew.bytes() as u64,
            _ => 0,
        }
    }

    fn step_vector(&mut self, op: &VOp, vl: u64, sew: Sew, stats: &mut Stats) {
        stats.vector_instrs += 1;
        // CVA6 occupies one issue slot dispatching, then fire-and-forgets —
        // but the dispatch queue is finite: if `vq_depth` earlier vector
        // instructions have not started yet, the scalar core stalls here.
        let qi = self.vq_count % self.vq_ring.len();
        let mut dispatch = self.scalar_clock.max(self.vq_ring[qi]);
        if let Some(r) = op.sreg_read() {
            dispatch = dispatch.max(self.reg_ready(r));
        }
        if let VOp::Load { kind: VMemKind::Strided { stride }, .. }
        | VOp::Store { kind: VMemKind::Strided { stride }, .. } = op
        {
            dispatch = dispatch.max(self.reg_ready(*stride));
        }
        self.scalar_clock = dispatch + 1;
        let dispatch = dispatch + self.cfg.dispatch_latency;

        // Sequencer: in-order issue, chaining on vector operands.
        let mut start = dispatch.max(self.last_vissue);
        let unit = unit_idx(op.unit());
        start = start.max(self.unit_busy[unit]);
        let mut min_end = 0u64;
        for r in op.vreg_reads().iter().flatten() {
            let i = r.0 as usize;
            // Chain: start after producer's first elements are available...
            start = start.max(self.v_start[i] + self.cfg.chain_latency);
            // ...but never finish before the producer finishes.
            min_end = min_end.max(self.v_ready[i]);
        }
        // Memory ops also arbitrate for the AXI bus.
        let is_mem = matches!(op, VOp::Load { .. } | VOp::Store { .. });
        if is_mem {
            start = start.max(self.bus_free);
            if matches!(op, VOp::Load { .. }) {
                start += self.cfg.mem_latency; // first-beat latency
            }
        }

        let dur = self.vduration(op, vl, sew) + self.cfg.vstartup_latency;
        let end = (start + dur).max(min_end + 1);

        // Occupancy + stats.
        self.unit_busy[unit] = end;
        self.last_vissue = start;
        self.vq_ring[qi] = start;
        self.vq_count += 1;
        if is_mem {
            let bytes = self.vmem_bytes(op, vl);
            self.bus_free = start + (bytes as f64 / self.cfg.axi_bytes_per_cycle as f64).ceil() as u64;
            stats.vlsu_cycles += end - start;
            match op {
                VOp::Load { .. } => stats.vload_bytes += bytes,
                VOp::Store { .. } => stats.vstore_bytes += bytes,
                _ => {}
            }
        }
        if op.unit() == FUnit::VMask {
            stats.mask_unit_cycles += end - start;
        }
        if !is_mem {
            stats.vector_elem_ops += vl;
        }

        // Writebacks.
        if let Some(vd) = op.vreg_write() {
            let i = vd.0 as usize;
            self.v_start[i] = start;
            self.v_ready[i] = end;
        }
        if let Some(rd) = op.sreg_write() {
            // Scalar sees the value only after full vector completion plus the
            // return handshake.
            self.x_ready[rd.0 as usize] = end + self.cfg.dispatch_latency;
        }
        self.horizon = self.horizon.max(end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::VIOp;
    use crate::isa::reg::{Reg, VReg};

    fn t() -> (Timing, Stats) {
        (Timing::new(&MachineConfig::quark(4)), Stats::default())
    }

    fn vadd(vd: u8, vs2: u8, vs1: u8) -> Instr {
        Instr::Vector(VOp::IVV { op: VIOp::Add, vd: VReg(vd), vs2: VReg(vs2), vs1: VReg(vs1) })
    }

    #[test]
    fn independent_vector_ops_on_one_unit_serialize() {
        let (mut tm, mut st) = t();
        // SEW=64, vl=64: 16 cycles occupancy on the VALU @ 4 lanes.
        tm.step(&vadd(1, 2, 3), 64, Sew::E64, &mut st);
        let c1 = tm.cycles();
        tm.step(&vadd(4, 5, 6), 64, Sew::E64, &mut st);
        let c2 = tm.cycles();
        assert!(c2 >= c1 + 16, "second op must wait for the VALU: {c1} -> {c2}");
    }

    #[test]
    fn chaining_overlaps_dependent_ops_on_different_units() {
        let (mut tm, mut st) = t();
        // Producer on VALU, consumer (popcnt is VALU too) vs store (VLSU).
        tm.step(&vadd(1, 2, 3), 512, Sew::E8, &mut st);
        let c1 = tm.cycles();
        // Dependent store chains: total should be far less than 2x serial.
        tm.step(
            &Instr::Vector(VOp::Store {
                kind: crate::isa::VMemKind::UnitStride,
                eew: Sew::E8,
                vs3: VReg(1),
                base: Reg(10),
            }),
            512,
            Sew::E8,
            &mut st,
        );
        let c2 = tm.cycles();
        // Serial would be ~2*(16+4); chained must at most add a few cycles.
        assert!(c2 < c1 + 24, "store should chain behind the add: {c1} -> {c2}");
    }

    #[test]
    fn mask_unit_is_slow() {
        let (mut tm, mut st) = t();
        tm.step(
            &Instr::Vector(VOp::MseqVI { vd: VReg(1), vs2: VReg(2), imm: 0 }),
            512,
            Sew::E8,
            &mut st,
        );
        // 512 elements / (4 lanes × 1 elem/lane/cycle) = 128 cycles ≫ the 16
        // an ALU op takes — packing without vbitpack pays this per plane.
        assert!(tm.cycles() >= 128);
        assert!(st.mask_unit_cycles >= 128);
    }

    #[test]
    fn vector_load_charges_bus_and_latency() {
        let (mut tm, mut st) = t();
        tm.step(
            &Instr::Vector(VOp::Load {
                kind: crate::isa::VMemKind::UnitStride,
                eew: Sew::E8,
                vd: VReg(1),
                base: Reg(10),
            }),
            512,
            Sew::E8,
            &mut st,
        );
        // 512B / 32B-per-cycle = 16 beats + 20 latency + startup.
        assert!(tm.cycles() >= 36);
        assert_eq!(st.vload_bytes, 512);
    }

    #[test]
    fn scalar_read_of_vector_waits_for_completion() {
        let (mut tm, mut st) = t();
        tm.step(&vadd(1, 2, 3), 512, Sew::E8, &mut st);
        tm.step(&Instr::Vector(VOp::MvXS { rd: Reg(5), vs2: VReg(1) }), 1, Sew::E8, &mut st);
        let after_mv = tm.cycles();
        // A scalar consumer of x5 must see a ready time ≥ the vector end.
        tm.step(
            &Instr::Scalar(ScalarOp::AluImm {
                op: crate::isa::instr::AluOp::Add,
                rd: Reg(6),
                rs1: Reg(5),
                imm: 1,
            }),
            0,
            Sew::E8,
            &mut st,
        );
        assert!(tm.cycles() >= after_mv);
        assert_eq!(st.scalar_instrs, 1);
        assert_eq!(st.vector_instrs, 2);
    }
}
