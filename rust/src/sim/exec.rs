//! Functional executor: full architectural semantics for the ISA subset.
//!
//! This is the "does the hardware compute the right numbers" half of the
//! simulator; `timing.rs` is the "how many cycles" half. Both consume the
//! same dynamic instruction stream via [`crate::sim::Sim`].
//!
//! The static program verifier (`crate::program::verify`) mirrors this
//! executor's read/write semantics instruction by instruction — `vsetvli`'s
//! `vl = min(avl, vlmax)`, whole-register vs `vl`-bounded vector writes,
//! `vbitpack`'s define-on-use of its destination, the byte extents of
//! unit-stride and strided memory ops. A semantic change here (a new
//! instruction, a widened write set) must land in the verifier's walker
//! too, or zoo artifacts will stop verifying — `repro verify` and
//! `rust/tests/verify_negative.rs` are the tripwires.

use crate::arch::MachineConfig;
use crate::isa::instr::{AluOp, FAluOp, Instr, ScalarOp, VIOp, VMemKind, VOp};
use crate::isa::reg::{Reg, VReg};
use crate::isa::vtype::{Lmul, Sew, VType};

use super::mem::Memory;

/// Architectural state.
pub struct Machine {
    pub x: [u64; 32],
    pub f: [f32; 32],
    /// Vector register file: 32 × VLEN/8 bytes, contiguous (register groups
    /// under LMUL are naturally contiguous slices).
    v: Vec<u8>,
    vreg_bytes: usize,
    pub vl: u64,
    pub vtype: VType,
    pub vlen_bits: usize,
    pub mem: Memory,
    /// Value returned by `csrr cycle` — kept current by the owning `Sim`.
    pub cycle_csr: u64,
}

#[inline]
pub(crate) fn sext_to_u64(v: u64, bits: usize) -> u64 {
    let shift = 64 - bits;
    (((v << shift) as i64) >> shift) as u64
}

#[inline]
pub(crate) fn trunc(v: u64, bits: usize) -> u64 {
    if bits == 64 {
        v
    } else {
        v & ((1u64 << bits) - 1)
    }
}

impl Machine {
    pub fn new(cfg: &MachineConfig, mem_bytes: usize) -> Self {
        let vreg_bytes = cfg.vlen_bits / 8;
        Machine {
            x: [0; 32],
            f: [0.0; 32],
            v: vec![0u8; 32 * vreg_bytes],
            vreg_bytes,
            vl: 0,
            vtype: VType::new(Sew::E8, Lmul::M1),
            vlen_bits: cfg.vlen_bits,
            mem: Memory::new(mem_bytes),
            cycle_csr: 0,
        }
    }

    // ---- register helpers ----

    #[inline]
    pub fn get_x(&self, r: Reg) -> u64 {
        if r.0 == 0 {
            0
        } else {
            self.x[r.0 as usize]
        }
    }

    #[inline]
    pub fn set_x(&mut self, r: Reg, v: u64) {
        if r.0 != 0 {
            self.x[r.0 as usize] = v;
        }
    }

    /// Read vector element `idx` of width `bytes` starting at register `vr`
    /// (indices may run past one register under LMUL grouping).
    #[inline]
    pub fn vget(&self, vr: VReg, idx: usize, bytes: usize) -> u64 {
        let off = vr.0 as usize * self.vreg_bytes + idx * bytes;
        debug_assert!(off + bytes <= self.v.len(), "vector register file overrun");
        let mut buf = [0u8; 8];
        buf[..bytes].copy_from_slice(&self.v[off..off + bytes]);
        u64::from_le_bytes(buf)
    }

    #[inline]
    pub fn vset(&mut self, vr: VReg, idx: usize, bytes: usize, val: u64) {
        let off = vr.0 as usize * self.vreg_bytes + idx * bytes;
        debug_assert!(off + bytes <= self.v.len(), "vector register file overrun");
        let le = val.to_le_bytes();
        self.v[off..off + bytes].copy_from_slice(&le[..bytes]);
    }

    /// Whole-register view (test / `vbitpack` use).
    pub fn vreg_slice(&self, vr: VReg) -> &[u8] {
        let off = vr.0 as usize * self.vreg_bytes;
        &self.v[off..off + self.vreg_bytes]
    }

    pub fn vreg_slice_mut(&mut self, vr: VReg) -> &mut [u8] {
        let off = vr.0 as usize * self.vreg_bytes;
        &mut self.v[off..off + self.vreg_bytes]
    }

    /// Read mask bit `i` of register `vr` (mask layout: bit i = element i).
    pub fn vmask_bit(&self, vr: VReg, i: usize) -> bool {
        let byte = self.vreg_slice(vr)[i / 8];
        (byte >> (i % 8)) & 1 == 1
    }

    // ---- execution ----

    /// Execute one instruction. Panics on semantic violations (the simulator
    /// equivalent of a hardware assertion); ISA-availability checks (vector
    /// FPU on Quark, custom ops on Ara) are enforced by `Sim::emit`.
    pub fn execute(&mut self, instr: &Instr) {
        match instr {
            Instr::Scalar(op) => self.exec_scalar(op),
            Instr::VSetVli { rd, avl, vtype } => {
                self.vtype = *vtype;
                let vlmax = vtype.vlmax(self.vlen_bits) as u64;
                self.vl = (*avl).min(vlmax);
                self.set_x(*rd, self.vl);
            }
            Instr::Vector(op) => self.exec_vector(op),
        }
    }

    fn exec_scalar(&mut self, op: &ScalarOp) {
        use ScalarOp::*;
        match *op {
            Li { rd, imm } => self.set_x(rd, imm as u64),
            Alu { op, rd, rs1, rs2 } => {
                let a = self.get_x(rs1);
                let b = self.get_x(rs2);
                self.set_x(rd, alu(op, a, b));
            }
            AluImm { op, rd, rs1, imm } => {
                let a = self.get_x(rs1);
                self.set_x(rd, alu(op, a, imm as u64));
            }
            Load { width, signed, rd, base, offset } => {
                let addr = self.get_x(base).wrapping_add(offset as u64);
                let raw = self.mem.read_u64_le(addr, width.bytes());
                let v = if signed { sext_to_u64(raw, width.bytes() * 8) } else { raw };
                self.set_x(rd, v);
            }
            Store { width, rs2, base, offset } => {
                let addr = self.get_x(base).wrapping_add(offset as u64);
                let v = self.get_x(rs2);
                self.mem.write_u64_le(addr, v, width.bytes());
            }
            Branch { .. } | Nop => {}
            FLoad { rd, base, offset } => {
                let addr = self.get_x(base).wrapping_add(offset as u64);
                let raw = self.mem.read_u64_le(addr, 4) as u32;
                self.f[rd.0 as usize] = f32::from_bits(raw);
            }
            FStore { rs2, base, offset } => {
                let addr = self.get_x(base).wrapping_add(offset as u64);
                self.mem.write_u64_le(addr, self.f[rs2.0 as usize].to_bits() as u64, 4);
            }
            FAlu { op, rd, rs1, rs2 } => {
                let a = self.f[rs1.0 as usize];
                let b = self.f[rs2.0 as usize];
                self.f[rd.0 as usize] = match op {
                    FAluOp::Add => a + b,
                    FAluOp::Sub => a - b,
                    FAluOp::Mul => a * b,
                    FAluOp::Div => a / b,
                    FAluOp::Min => a.min(b),
                    FAluOp::Max => a.max(b),
                };
            }
            FMadd { rd, rs1, rs2, rs3 } => {
                self.f[rd.0 as usize] =
                    self.f[rs1.0 as usize].mul_add(self.f[rs2.0 as usize], self.f[rs3.0 as usize]);
            }
            FCvtWS { rd, rs1 } => {
                // Round-to-nearest-even, saturating to i32 (RISC-V semantics).
                let v = self.f[rs1.0 as usize].round_ties_even();
                let clamped = v.clamp(i32::MIN as f32, i32::MAX as f32) as i32;
                self.set_x(rd, clamped as i64 as u64);
            }
            FCvtSW { rd, rs1 } => {
                self.f[rd.0 as usize] = (self.get_x(rs1) as i64 as i32) as f32;
            }
            FMvXW { rd, rs1 } => {
                self.set_x(rd, sext_to_u64(self.f[rs1.0 as usize].to_bits() as u64, 32));
            }
            FMvWX { rd, rs1 } => {
                self.f[rd.0 as usize] = f32::from_bits(self.get_x(rs1) as u32);
            }
            CsrReadCycle { rd } => self.set_x(rd, self.cycle_csr),
        }
    }

    fn exec_vector(&mut self, op: &VOp) {
        use VOp::*;
        let vl = self.vl as usize;
        let sew = self.vtype.sew;
        let eb = sew.bytes();
        let bits = sew.bits();
        match *op {
            Load { kind, eew, vd, base } => {
                let ebytes = eew.bytes();
                let base_addr = self.get_x(base);
                match kind {
                    VMemKind::UnitStride => {
                        // Element-by-element little-endian reads of a
                        // contiguous range are exactly one byte copy into the
                        // (contiguous, LMUL-grouped) register file. vl == 0
                        // touches nothing (not even the base address).
                        let len = vl * ebytes;
                        let off = vd.0 as usize * self.vreg_bytes;
                        debug_assert!(off + len <= self.v.len(), "vector register file overrun");
                        if len > 0 {
                            self.v[off..off + len].copy_from_slice(self.mem.read(base_addr, len));
                        }
                    }
                    VMemKind::Strided { stride } => {
                        let s = self.get_x(stride);
                        for i in 0..vl {
                            let v = self
                                .mem
                                .read_u64_le(base_addr.wrapping_add(s.wrapping_mul(i as u64)), ebytes);
                            self.vset(vd, i, ebytes, v);
                        }
                    }
                }
            }
            Store { kind, eew, vs3, base } => {
                let ebytes = eew.bytes();
                let base_addr = self.get_x(base);
                match kind {
                    VMemKind::UnitStride => {
                        // Mirror of the unit-stride load: one byte copy.
                        let len = vl * ebytes;
                        let off = vs3.0 as usize * self.vreg_bytes;
                        debug_assert!(off + len <= self.v.len(), "vector register file overrun");
                        if len > 0 {
                            self.mem.write(base_addr, &self.v[off..off + len]);
                        }
                    }
                    VMemKind::Strided { stride } => {
                        let s = self.get_x(stride);
                        for i in 0..vl {
                            let v = self.vget(vs3, i, ebytes);
                            self.mem
                                .write_u64_le(base_addr.wrapping_add(s.wrapping_mul(i as u64)), v, ebytes);
                        }
                    }
                }
            }
            IVV { op, vd, vs2, vs1 } => {
                for i in 0..vl {
                    let a = self.vget(vs2, i, eb);
                    let b = self.vget(vs1, i, eb);
                    self.vset(vd, i, eb, vint(op, a, b, bits));
                }
            }
            IVX { op, vd, vs2, rs1 } => {
                let b = trunc(self.get_x(rs1), bits);
                for i in 0..vl {
                    let a = self.vget(vs2, i, eb);
                    self.vset(vd, i, eb, vint(op, a, b, bits));
                }
            }
            IVI { op, vd, vs2, imm } => {
                let b = trunc(imm as u64, bits);
                for i in 0..vl {
                    let a = self.vget(vs2, i, eb);
                    self.vset(vd, i, eb, vint(op, a, b, bits));
                }
            }
            MaccVX { vd, rs1, vs2 } => {
                let s = trunc(self.get_x(rs1), bits);
                for i in 0..vl {
                    let acc = self.vget(vd, i, eb);
                    let m = self.vget(vs2, i, eb);
                    self.vset(vd, i, eb, trunc(acc.wrapping_add(s.wrapping_mul(m)), bits));
                }
            }
            MaccVV { vd, vs1, vs2 } => {
                for i in 0..vl {
                    let acc = self.vget(vd, i, eb);
                    let a = self.vget(vs1, i, eb);
                    let b = self.vget(vs2, i, eb);
                    self.vset(vd, i, eb, trunc(acc.wrapping_add(a.wrapping_mul(b)), bits));
                }
            }
            RedSum { vd, vs2, vs1 } => {
                let mut acc = self.vget(vs1, 0, eb);
                for i in 0..vl {
                    acc = trunc(acc.wrapping_add(self.vget(vs2, i, eb)), bits);
                }
                self.vset(vd, 0, eb, acc);
            }
            MvXS { rd, vs2 } => {
                let v = self.vget(vs2, 0, eb);
                self.set_x(rd, sext_to_u64(v, bits));
            }
            MvSX { vd, rs1 } => {
                let v = trunc(self.get_x(rs1), bits);
                self.vset(vd, 0, eb, v);
            }
            MvVX { vd, rs1 } => {
                let v = trunc(self.get_x(rs1), bits);
                for i in 0..vl {
                    self.vset(vd, i, eb, v);
                }
            }
            MvVI { vd, imm } => {
                let v = trunc(imm as u64, bits);
                if v == 0 {
                    // Splat-zero (accumulator/plane clearing — the hot case)
                    // is a byte fill over the LMUL group.
                    let off = vd.0 as usize * self.vreg_bytes;
                    let len = vl * eb;
                    debug_assert!(off + len <= self.v.len(), "vector register file overrun");
                    self.v[off..off + len].fill(0);
                } else {
                    for i in 0..vl {
                        self.vset(vd, i, eb, v);
                    }
                }
            }
            Sext { vd, vs2, frac } => {
                let src_bits = bits / frac as usize;
                let src_bytes = src_bits / 8;
                assert!(src_bytes >= 1, "vsext source narrower than one byte");
                // Read all sources first: vd may overlap vs2 in the kernels'
                // register allocation only when reading backwards is safe;
                // we buffer to stay overlap-agnostic.
                let src: Vec<u64> = (0..vl).map(|i| self.vget(vs2, i, src_bytes)).collect();
                for (i, s) in src.into_iter().enumerate() {
                    self.vset(vd, i, eb, trunc(sext_to_u64(s, src_bits), bits));
                }
            }
            Zext { vd, vs2, frac } => {
                let src_bits = bits / frac as usize;
                let src_bytes = src_bits / 8;
                assert!(src_bytes >= 1, "vzext source narrower than one byte");
                let src: Vec<u64> = (0..vl).map(|i| self.vget(vs2, i, src_bytes)).collect();
                for (i, s) in src.into_iter().enumerate() {
                    self.vset(vd, i, eb, s);
                }
            }
            MseqVI { vd, vs2, imm } => {
                let b = trunc(imm as u64, bits);
                let mut maskbits = vec![0u8; self.vreg_bytes];
                for (i, mb) in (0..vl).map(|i| (i, self.vget(vs2, i, eb) == b)) {
                    if mb {
                        maskbits[i / 8] |= 1 << (i % 8);
                    }
                }
                self.vreg_slice_mut(vd).copy_from_slice(&maskbits);
            }
            MsneVI { vd, vs2, imm } => {
                let b = trunc(imm as u64, bits);
                let mut maskbits = vec![0u8; self.vreg_bytes];
                for (i, mb) in (0..vl).map(|i| (i, self.vget(vs2, i, eb) != b)) {
                    if mb {
                        maskbits[i / 8] |= 1 << (i % 8);
                    }
                }
                self.vreg_slice_mut(vd).copy_from_slice(&maskbits);
            }
            FMaccVF { vd, rs1, vs2 } => {
                assert_eq!(sew, Sew::E32, "vector f32 ops require SEW=32");
                let s = self.f[rs1.0 as usize];
                for i in 0..vl {
                    let acc = f32::from_bits(self.vget(vd, i, 4) as u32);
                    let m = f32::from_bits(self.vget(vs2, i, 4) as u32);
                    self.vset(vd, i, 4, s.mul_add(m, acc).to_bits() as u64);
                }
            }
            FAddVV { vd, vs2, vs1 } => {
                assert_eq!(sew, Sew::E32);
                for i in 0..vl {
                    let a = f32::from_bits(self.vget(vs2, i, 4) as u32);
                    let b = f32::from_bits(self.vget(vs1, i, 4) as u32);
                    self.vset(vd, i, 4, (a + b).to_bits() as u64);
                }
            }
            FMulVF { vd, vs2, rs1 } => {
                assert_eq!(sew, Sew::E32);
                let s = self.f[rs1.0 as usize];
                for i in 0..vl {
                    let a = f32::from_bits(self.vget(vs2, i, 4) as u32);
                    self.vset(vd, i, 4, (a * s).to_bits() as u64);
                }
            }
            FMaxVF { vd, vs2, rs1 } => {
                assert_eq!(sew, Sew::E32);
                let s = self.f[rs1.0 as usize];
                for i in 0..vl {
                    let a = f32::from_bits(self.vget(vs2, i, 4) as u32);
                    self.vset(vd, i, 4, a.max(s).to_bits() as u64);
                }
            }
            FMvVF { vd, rs1 } => {
                assert_eq!(sew, Sew::E32);
                let s = self.f[rs1.0 as usize].to_bits() as u64;
                for i in 0..vl {
                    self.vset(vd, i, 4, s);
                }
            }
            FRedSum { vd, vs2, vs1 } => {
                assert_eq!(sew, Sew::E32);
                let mut acc = f32::from_bits(self.vget(vs1, 0, 4) as u32);
                for i in 0..vl {
                    acc += f32::from_bits(self.vget(vs2, i, 4) as u32);
                }
                self.vset(vd, 0, 4, acc.to_bits() as u64);
            }
            Popcnt { vd, vs2 } => {
                for i in 0..vl {
                    let a = self.vget(vs2, i, eb);
                    self.vset(vd, i, eb, a.count_ones() as u64);
                }
            }
            Shacc { vd, vs2, shamt } => {
                for i in 0..vl {
                    let acc = self.vget(vd, i, eb);
                    let add = self.vget(vs2, i, eb);
                    let v = trunc(acc << shamt, bits).wrapping_add(add);
                    self.vset(vd, i, eb, trunc(v, bits));
                }
            }
            Bitpack { vd, vs2, bit } => {
                assert!(
                    vl <= self.vlen_bits,
                    "vbitpack: vl ({vl}) exceeds VLEN ({}) — plane must fit one register",
                    self.vlen_bits
                );
                assert!((bit as usize) < bits, "vbitpack: bit index {bit} out of SEW range");
                // Extract plane: bit `bit` of each element.
                let mut plane = vec![0u8; self.vreg_bytes];
                for i in 0..vl {
                    if (self.vget(vs2, i, eb) >> bit) & 1 == 1 {
                        plane[i / 8] |= 1 << (i % 8);
                    }
                }
                // vd = (vd << vl) | plane, as a VLEN-bit little-endian value.
                let dst = self.vreg_slice(vd).to_vec();
                let shifted = shl_bitvec(&dst, vl);
                let out = self.vreg_slice_mut(vd);
                for (o, (s, p)) in out.iter_mut().zip(shifted.iter().zip(plane.iter())) {
                    *o = s | p;
                }
            }
        }
    }
}

// ---- fused host kernels for lowered replay ----
//
// `crate::program::lowered` statically matches short instruction sequences in
// a compiled trace and replaces them with one call into the methods below.
// Each method replicates EVERY architectural effect of the sequence it
// stands in for — destination vector registers (including the final values
// of scratch intermediates), scalar registers, vl/vtype, and memory — so
// machine state at every fused-op boundary is bit-identical to plain
// interpretation. The static legality conditions each method relies on are
// checked by the lowering pass and documented there.

/// One AND→popcount→accumulate quad of the bit-serial MAC inner loop:
/// `acc[i] += popcount(w[i] & mem64[x[base] + offset])` for `i < vl`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct MacTap {
    pub base: Reg,
    pub offset: i64,
    pub w: VReg,
    pub acc: VReg,
}

/// The single-chunk row-sum shape (`kernels::matmul::emit_row_sum_u8`):
/// byte-load `n` activation codes, widen to u32, reduce-sum, store the sum.
/// `src`/`dst` are compile-space addresses; the executor adds the
/// relocation delta.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct RowSumOp {
    pub src: u64,
    pub dst: u64,
    pub n: usize,
    pub a0: Reg,
    pub t0: Reg,
    pub t1: Reg,
    pub vload: VReg,
    pub vz: VReg,
    pub vacc: VReg,
    /// vl/vtype left behind by the second embedded `vsetvli`.
    pub vl_after: u64,
    pub vtype_after: VType,
}

impl Machine {
    /// Copy a region of simulated memory out of the arena. The batched
    /// lowered replay harvests each element's output segment through this
    /// before the next element's pass overwrites the shared scratch.
    pub(crate) fn copy_region(&self, addr: u64, len: usize) -> Vec<u8> {
        self.mem.read(addr, len).to_vec()
    }

    /// `vmv.v.i vd, 0` + reloc-`li rd` + unit-stride `vse`: zero `len` bytes
    /// of `vd` and of memory at `addr` (already delta-resolved).
    pub(crate) fn exec_fill(&mut self, vd: VReg, rd: Reg, addr: u64, len: usize) {
        let off = vd.0 as usize * self.vreg_bytes;
        self.v[off..off + len].fill(0);
        self.set_x(rd, addr);
        if len > 0 {
            self.mem.write(addr, &self.v[off..off + len]);
        }
    }

    /// Reloc-`li rd` + unit-stride `vle`: one memcpy into the register file.
    pub(crate) fn exec_load_unit(&mut self, rd: Reg, addr: u64, vd: VReg, len: usize) {
        self.set_x(rd, addr);
        let off = vd.0 as usize * self.vreg_bytes;
        if len > 0 {
            self.v[off..off + len].copy_from_slice(self.mem.read(addr, len));
        }
    }

    /// Reloc-`li rd` + unit-stride `vse`: one memcpy out of the register file.
    pub(crate) fn exec_store_unit(&mut self, rd: Reg, addr: u64, vs3: VReg, len: usize) {
        self.set_x(rd, addr);
        let off = vs3.0 as usize * self.vreg_bytes;
        if len > 0 {
            self.mem.write(addr, &self.v[off..off + len]);
        }
    }

    /// `li`+`vle`+`li`+`vse` memory-to-memory copy staged through `vd`.
    /// Load-before-store ordering makes overlapping src/dst ranges and
    /// `rs == rd` behave exactly as the four interpreted instructions.
    pub(crate) fn exec_copy(&mut self, rs: Reg, src: u64, rd: Reg, dst: u64, vd: VReg, len: usize) {
        self.exec_load_unit(rs, src, vd, len);
        self.exec_store_unit(rd, dst, vd, len);
    }

    /// A run of `taps.len()` bit-plane MAC quads
    /// (`ld t1` / `vand.vx tmp` / `vpopcnt.v tmp` / `vadd.vv acc`) sharing
    /// one scalar temporary `t1` and one vector temporary `tmp`, at SEW=64.
    ///
    /// Executes tap-major, which equals the interpreted quad order with the
    /// intermediate `tmp` writes elided; only the last quad's `tmp`/`t1`
    /// values are architecturally visible afterwards and are materialized at
    /// the end. Hoisting the scalar loads per 64-tap chunk is exact because
    /// the run writes no memory and no base register (the matcher rejects
    /// `base == t1`).
    pub(crate) fn exec_plane_mac(&mut self, vl: usize, t1: Reg, tmp: VReg, taps: &[MacTap]) {
        debug_assert!(!taps.is_empty());
        let mut aw = [0u64; 64];
        let mut last_aw = 0u64;
        for chunk in taps.chunks(64) {
            for (slot, tap) in chunk.iter().enumerate() {
                let addr = self.get_x(tap.base).wrapping_add(tap.offset as u64);
                aw[slot] = self.mem.read_u64_le(addr, 8);
            }
            for (slot, tap) in chunk.iter().enumerate() {
                let m = aw[slot];
                let w0 = tap.w.0 as usize * self.vreg_bytes;
                let a0 = tap.acc.0 as usize * self.vreg_bytes;
                for i in 0..vl {
                    let wi =
                        u64::from_le_bytes(self.v[w0 + 8 * i..w0 + 8 * i + 8].try_into().unwrap());
                    let acc =
                        u64::from_le_bytes(self.v[a0 + 8 * i..a0 + 8 * i + 8].try_into().unwrap());
                    let r = acc.wrapping_add((wi & m).count_ones() as u64);
                    self.v[a0 + 8 * i..a0 + 8 * i + 8].copy_from_slice(&r.to_le_bytes());
                }
            }
            last_aw = aw[chunk.len() - 1];
        }
        // Final architectural values of the scratch registers: the last
        // quad's loaded word and its popcount vector.
        let last = taps[taps.len() - 1];
        let w0 = last.w.0 as usize * self.vreg_bytes;
        let t0 = tmp.0 as usize * self.vreg_bytes;
        for i in 0..vl {
            let wi = u64::from_le_bytes(self.v[w0 + 8 * i..w0 + 8 * i + 8].try_into().unwrap());
            let p = (wi & last_aw).count_ones() as u64;
            self.v[t0 + 8 * i..t0 + 8 * i + 8].copy_from_slice(&p.to_le_bytes());
        }
        self.set_x(t1, last_aw);
    }

    /// Allocation-free `vbitpack.vi vd, vs2, bit` (the interpreted form heap-
    /// allocates three temporaries per call). Caller guarantees
    /// `vl <= vlen_bits`, `bit < SEW bits` and `vreg_bytes <= 512`.
    pub(crate) fn exec_bitpack_host(&mut self, vd: VReg, vs2: VReg, bit: u8, vl: usize, eb: usize) {
        let nb = self.vreg_bytes;
        debug_assert!(nb <= 512 && vl <= self.vlen_bits && (bit as usize) < eb * 8);
        // Extract the plane first (vd may equal vs2).
        let mut plane = [0u8; 512];
        let s0 = vs2.0 as usize * nb;
        let (src_byte, src_mask) = ((bit / 8) as usize, 1u8 << (bit % 8));
        for i in 0..vl {
            if self.v[s0 + i * eb + src_byte] & src_mask != 0 {
                plane[i / 8] |= 1 << (i % 8);
            }
        }
        // vd = (vd << vl) | plane, in place. The descending walk only reads
        // source bytes at indices <= the write index, so no buffering needed.
        let d0 = vd.0 as usize * nb;
        let byte_shift = vl / 8;
        let bit_shift = vl % 8;
        for i in (0..nb).rev() {
            let shifted = if i < byte_shift {
                0
            } else {
                let lo = (self.v[d0 + i - byte_shift] as u16) << bit_shift;
                let carry = if bit_shift > 0 && i > byte_shift {
                    (self.v[d0 + i - byte_shift - 1] as u16) >> (8 - bit_shift)
                } else {
                    0
                };
                ((lo | carry) & 0xFF) as u8
            };
            self.v[d0 + i] = shifted | plane[i];
        }
    }

    /// Reloc-`li a0` + `lbu t1, 0(a0)` + `vmacc.vx vd, t1, vs2`: the
    /// per-tap inner step of the int8 conv path.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn exec_macc_byte(
        &mut self,
        a0: Reg,
        addr: u64,
        t1: Reg,
        vd: VReg,
        vs2: VReg,
        vl: usize,
        eb: usize,
    ) {
        self.set_x(a0, addr);
        let raw = self.mem.read_u64_le(addr, 1);
        self.set_x(t1, raw);
        let bits = eb * 8;
        let s = trunc(self.get_x(t1), bits);
        if eb == 1 {
            // SEW=8 (the int8 conv case): mod-256 arithmetic is plain u8
            // wrapping, and reading/writing elements before advancing makes
            // vd == vs2 exact.
            let d0 = vd.0 as usize * self.vreg_bytes;
            let s0 = vs2.0 as usize * self.vreg_bytes;
            let sb = s as u8;
            for i in 0..vl {
                let m = self.v[s0 + i];
                self.v[d0 + i] = self.v[d0 + i].wrapping_add(sb.wrapping_mul(m));
            }
        } else {
            for i in 0..vl {
                let acc = self.vget(vd, i, eb);
                let m = self.vget(vs2, i, eb);
                self.vset(vd, i, eb, trunc(acc.wrapping_add(s.wrapping_mul(m)), bits));
            }
        }
    }

    /// The fused 10-instruction row-sum shape. The reduction is a u32
    /// wrapping byte sum from zero (the embedded `vmv.v.i vacc, 0` under
    /// `vl = 1` provides the zero start the `vredsum` folds onto). Caller
    /// guarantees `n <= 1024` and that `vacc`'s first element overlaps
    /// neither the loaded bytes nor the widened u32 span.
    pub(crate) fn exec_row_sum(&mut self, op: &RowSumOp, delta: u64) {
        let src = op.src.wrapping_add(delta);
        let n = op.n;
        debug_assert!(n <= 1024);
        self.set_x(op.a0, src);
        let mut buf = [0u8; 1024];
        if n > 0 {
            buf[..n].copy_from_slice(self.mem.read(src, n));
        }
        let l0 = op.vload.0 as usize * self.vreg_bytes;
        self.v[l0..l0 + n].copy_from_slice(&buf[..n]);
        let z0 = op.vz.0 as usize * self.vreg_bytes;
        let mut sum = 0u32;
        for (i, &b) in buf[..n].iter().enumerate() {
            sum = sum.wrapping_add(b as u32);
            self.v[z0 + 4 * i..z0 + 4 * i + 4].copy_from_slice(&(b as u32).to_le_bytes());
        }
        let a0v = op.vacc.0 as usize * self.vreg_bytes;
        self.v[a0v..a0v + 4].copy_from_slice(&sum.to_le_bytes());
        self.vl = op.vl_after;
        self.vtype = op.vtype_after;
        self.set_x(op.t0, sext_to_u64(sum as u64, 32));
        self.set_x(op.t1, op.dst.wrapping_add(delta));
        self.mem.write_u64_le(self.get_x(op.t1), self.get_x(op.t0), 4);
    }
}

/// Scalar integer ALU semantics (RV64: 64-bit operations).
fn alu(op: AluOp, a: u64, b: u64) -> u64 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Sll => a << (b & 63),
        AluOp::Srl => a >> (b & 63),
        AluOp::Sra => ((a as i64) >> (b & 63)) as u64,
        AluOp::Slt => ((a as i64) < (b as i64)) as u64,
        AluOp::Sltu => (a < b) as u64,
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Mulh => (((a as i64 as i128) * (b as i64 as i128)) >> 64) as u64,
        AluOp::Div => {
            if b == 0 {
                u64::MAX
            } else {
                ((a as i64).wrapping_div(b as i64)) as u64
            }
        }
        AluOp::Rem => {
            if b == 0 {
                a
            } else {
                ((a as i64).wrapping_rem(b as i64)) as u64
            }
        }
    }
}

/// Vector integer element semantics at `bits` element width.
fn vint(op: VIOp, a: u64, b: u64, bits: usize) -> u64 {
    let sa = sext_to_u64(a, bits) as i64;
    let sb = sext_to_u64(b, bits) as i64;
    let shmask = (bits - 1) as u64;
    let r = match op {
        VIOp::Add => a.wrapping_add(b),
        VIOp::Sub => a.wrapping_sub(b),
        VIOp::Rsub => b.wrapping_sub(a),
        VIOp::And => a & b,
        VIOp::Or => a | b,
        VIOp::Xor => a ^ b,
        VIOp::Sll => a << (b & shmask),
        VIOp::Srl => trunc(a, bits) >> (b & shmask),
        VIOp::Sra => (sa >> (b & shmask)) as u64,
        VIOp::Min => {
            if sa < sb {
                a
            } else {
                b
            }
        }
        VIOp::Max => {
            if sa > sb {
                a
            } else {
                b
            }
        }
        VIOp::Minu => {
            if trunc(a, bits) < trunc(b, bits) {
                a
            } else {
                b
            }
        }
        VIOp::Maxu => {
            if trunc(a, bits) > trunc(b, bits) {
                a
            } else {
                b
            }
        }
        VIOp::Mul => a.wrapping_mul(b),
        VIOp::Mulh => ((sa as i128 * sb as i128) >> bits) as u64,
    };
    trunc(r, bits)
}

/// Shift a little-endian bitvector left by `n` bits (VLEN-sized).
fn shl_bitvec(v: &[u8], n: usize) -> Vec<u8> {
    let len = v.len();
    let mut out = vec![0u8; len];
    let byte_shift = n / 8;
    let bit_shift = n % 8;
    for i in (0..len).rev() {
        if i < byte_shift {
            continue;
        }
        let lo = v[i - byte_shift] as u16;
        let carry = if bit_shift > 0 && i > byte_shift {
            (v[i - byte_shift - 1] as u16) >> (8 - bit_shift)
        } else {
            0
        };
        out[i] = (((lo << bit_shift) | carry) & 0xFF) as u8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::{abi, FReg};

    fn machine() -> Machine {
        Machine::new(&MachineConfig::quark(4), 1 << 20)
    }

    fn setvl(m: &mut Machine, avl: u64, sew: Sew, lmul: Lmul) {
        m.execute(&Instr::VSetVli { rd: Reg(0), avl, vtype: VType::new(sew, lmul) });
    }

    #[test]
    fn scalar_alu_and_memory() {
        let mut m = machine();
        let a = m.mem.alloc(64);
        m.execute(&Instr::Scalar(ScalarOp::Li { rd: abi::T0, imm: a as i64 }));
        m.execute(&Instr::Scalar(ScalarOp::Li { rd: abi::T1, imm: -5 }));
        m.execute(&Instr::Scalar(ScalarOp::Store {
            width: crate::isa::MemWidth::D,
            rs2: abi::T1,
            base: abi::T0,
            offset: 0,
        }));
        m.execute(&Instr::Scalar(ScalarOp::Load {
            width: crate::isa::MemWidth::D,
            signed: true,
            rd: abi::T2,
            base: abi::T0,
            offset: 0,
        }));
        assert_eq!(m.get_x(abi::T2) as i64, -5);
        // x0 is hard-wired zero.
        m.execute(&Instr::Scalar(ScalarOp::Li { rd: Reg(0), imm: 42 }));
        assert_eq!(m.get_x(Reg(0)), 0);
    }

    #[test]
    fn vpopcnt_counts_per_element() {
        let mut m = machine();
        setvl(&mut m, 4, Sew::E64, Lmul::M1);
        for (i, v) in [0u64, 1, 0xFF, u64::MAX].iter().enumerate() {
            m.vset(VReg(2), i, 8, *v);
        }
        m.execute(&Instr::Vector(VOp::Popcnt { vd: VReg(4), vs2: VReg(2) }));
        assert_eq!(m.vget(VReg(4), 0, 8), 0);
        assert_eq!(m.vget(VReg(4), 1, 8), 1);
        assert_eq!(m.vget(VReg(4), 2, 8), 8);
        assert_eq!(m.vget(VReg(4), 3, 8), 64);
    }

    #[test]
    fn vshacc_is_horner_step() {
        let mut m = machine();
        setvl(&mut m, 2, Sew::E64, Lmul::M1);
        m.vset(VReg(1), 0, 8, 3); // acc
        m.vset(VReg(1), 1, 8, 1);
        m.vset(VReg(2), 0, 8, 5); // addend
        m.vset(VReg(2), 1, 8, 7);
        m.execute(&Instr::Vector(VOp::Shacc { vd: VReg(1), vs2: VReg(2), shamt: 1 }));
        assert_eq!(m.vget(VReg(1), 0, 8), 2 * 3 + 5);
        assert_eq!(m.vget(VReg(1), 1, 8), 2 * 1 + 7);
    }

    #[test]
    fn vbitpack_packs_planes_plane_major() {
        let mut m = machine();
        // 8 elements of SEW=8 holding 2-bit values; pack plane 1 then plane 0.
        setvl(&mut m, 8, Sew::E8, Lmul::M1);
        let vals = [0b00u64, 0b01, 0b10, 0b11, 0b01, 0b01, 0b10, 0b11];
        for (i, v) in vals.iter().enumerate() {
            m.vset(VReg(1), i, 1, *v);
        }
        // Zero the destination.
        m.execute(&Instr::Vector(VOp::MvVI { vd: VReg(3), imm: 0 }));
        m.execute(&Instr::Vector(VOp::Bitpack { vd: VReg(3), vs2: VReg(1), bit: 1 }));
        m.execute(&Instr::Vector(VOp::Bitpack { vd: VReg(3), vs2: VReg(1), bit: 0 }));
        // After two calls: bits [0..8) = plane0 (bit 0 of each elem),
        // bits [8..16) = plane1.
        let plane0_expect: u8 = vals
            .iter()
            .enumerate()
            .fold(0, |acc, (i, v)| acc | ((((*v >> 0) & 1) as u8) << i));
        let plane1_expect: u8 = vals
            .iter()
            .enumerate()
            .fold(0, |acc, (i, v)| acc | ((((*v >> 1) & 1) as u8) << i));
        let reg = m.vreg_slice(VReg(3));
        assert_eq!(reg[0], plane0_expect);
        assert_eq!(reg[1], plane1_expect);
    }

    #[test]
    fn bitserial_triple_matches_dot_product() {
        // AND + popcount + shacc over bit planes == integer dot product
        // (paper Eq. 1), for 2-bit unsigned weights and activations.
        let mut m = machine();
        let w = [3u64, 1, 2, 0]; // four 2-bit weights packed as bit-planes below
        let a = [2u64, 3, 1, 1];
        let expect: u64 = w.iter().zip(a.iter()).map(|(x, y)| x * y).sum();

        // Pack planes manually into 4-bit planes (one u64 word each).
        let plane = |vals: &[u64], b: u64| -> u64 {
            vals.iter().enumerate().fold(0u64, |acc, (i, v)| acc | (((v >> b) & 1) << i))
        };
        setvl(&mut m, 1, Sew::E64, Lmul::M1);
        // acc (v10) = 0
        m.execute(&Instr::Vector(VOp::MvVI { vd: VReg(10), imm: 0 }));
        for wp in [1u64, 0] {
            // partial (v11) = 0
            m.execute(&Instr::Vector(VOp::MvVI { vd: VReg(11), imm: 0 }));
            for ap in [1u64, 0] {
                m.vset(VReg(1), 0, 8, plane(&w, wp));
                m.vset(VReg(2), 0, 8, plane(&a, ap));
                m.execute(&Instr::Vector(VOp::IVV {
                    op: VIOp::And,
                    vd: VReg(3),
                    vs2: VReg(1),
                    vs1: VReg(2),
                }));
                m.execute(&Instr::Vector(VOp::Popcnt { vd: VReg(3), vs2: VReg(3) }));
                m.execute(&Instr::Vector(VOp::Shacc { vd: VReg(11), vs2: VReg(3), shamt: 1 }));
            }
            m.execute(&Instr::Vector(VOp::Shacc { vd: VReg(10), vs2: VReg(11), shamt: 1 }));
        }
        // Horner over (wp, ap) MSB→LSB computes Σ 2^(wp+ap) popcount(w&a)...
        // but the outer shacc shifts the *whole* inner sum once per weight
        // plane, so the weighting is 2^wp · (2^ap) — exactly Eq. (1) when the
        // inner partial is rebuilt per weight plane.
        assert_eq!(m.vget(VReg(10), 0, 8), expect);
    }

    #[test]
    fn fcvt_rounds_to_nearest_even() {
        let mut m = machine();
        m.f[1] = 2.5;
        m.execute(&Instr::Scalar(ScalarOp::FCvtWS { rd: Reg(5), rs1: FReg(1) }));
        assert_eq!(m.get_x(Reg(5)), 2);
        m.f[1] = 3.5;
        m.execute(&Instr::Scalar(ScalarOp::FCvtWS { rd: Reg(5), rs1: FReg(1) }));
        assert_eq!(m.get_x(Reg(5)), 4);
    }

    #[test]
    fn vector_load_store_roundtrip() {
        let mut m = machine();
        let src = m.mem.alloc(64);
        let dst = m.mem.alloc(64);
        for i in 0..16u64 {
            m.mem.write_u64_le(src + i * 4, i * 3 + 1, 4);
        }
        setvl(&mut m, 16, Sew::E32, Lmul::M1);
        m.set_x(abi::A0, src);
        m.set_x(abi::A1, dst);
        m.execute(&Instr::Vector(VOp::Load {
            kind: VMemKind::UnitStride,
            eew: Sew::E32,
            vd: VReg(8),
            base: abi::A0,
        }));
        m.execute(&Instr::Vector(VOp::Store {
            kind: VMemKind::UnitStride,
            eew: Sew::E32,
            vs3: VReg(8),
            base: abi::A1,
        }));
        for i in 0..16u64 {
            assert_eq!(m.mem.read_u64_le(dst + i * 4, 4), i * 3 + 1);
        }
    }

    #[test]
    fn outer_horner_weighting_note() {
        // Validate the double-Horner weighting explicitly for 2x2-bit:
        // value = Σ_wp Σ_ap 2^(wp+ap) pc(wp,ap).
        // Inner loop (ap = 1,0): partial = 2*pc(wp,1) + pc(wp,0).
        // Outer (wp = 1,0): acc = 2*(2*pc(1,1)+pc(1,0)) + (2*pc(0,1)+pc(0,0))
        //                      = 4·pc(1,1) + 2·pc(1,0) + 2·pc(0,1) + pc(0,0). ✓
        // (This is what `bitserial_triple_matches_dot_product` exercises.)
    }
}
