//! Cycle-approximate simulator of the CVA6 + Ara/Quark system.
//!
//! [`Sim`] couples the functional executor ([`exec::Machine`]) with the
//! structural timing model ([`timing::Timing`]) behind a single
//! [`Sim::emit`] call: kernels in [`crate::kernels`] *are* the programs; they
//! emit the dynamic instruction stream exactly as the paper's hand-written
//! RVV assembly would execute it, and the simulator accounts both values and
//! cycles.
//!
//! [`SimMode::TimingOnly`] skips functional execution for large sweeps whose
//! numerics were already validated at small scale (the values cannot change
//! the cycle count for the data-independent kernels used here — dispatch,
//! durations, and dependencies are all shape-driven).

pub mod exec;
pub mod mem;
pub mod stats;
pub mod timing;

pub use exec::Machine;
pub use stats::Stats;

use crate::arch::MachineConfig;
use crate::isa::instr::{Instr, ScalarOp, VOp};
use crate::isa::vtype::{Lmul, Sew, VType};

/// Simulation fidelity.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimMode {
    /// Execute functionally *and* account cycles (default).
    Full,
    /// Account cycles only; vector/scalar data paths are not evaluated.
    /// `vsetvli` and scalar address arithmetic still execute so that `vl`
    /// and memory footprints stay correct.
    TimingOnly,
}

/// Error returned by [`Sim::try_emit`] when an instruction is not available
/// on the configured machine (illegal-instruction trap in hardware).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Vector-FP instruction on a machine without a vector FPU (Quark).
    NoVectorFpu(&'static str),
    /// Quark custom instruction on a machine without the extension (Ara).
    NoQuarkIsa(&'static str),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::NoVectorFpu(m) => {
                write!(f, "illegal instruction: {m} requires a vector FPU (removed in Quark)")
            }
            SimError::NoQuarkIsa(m) => {
                write!(f, "illegal instruction: {m} is a Quark custom op (not present in Ara)")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Compile-time capture of everything a [`crate::program::CompiledProgram`]
/// needs to replay a kernel emission: the dynamic instruction trace, the
/// trace indices of relocatable address materializations
/// ([`Sim::li_addr`]), and the host-written memory image (weights, requant
/// tables, constants — every [`Sim::write_bytes`]-family call).
///
/// Recording is armed by [`crate::program::ProgramBuilder`]; while armed,
/// [`Sim::try_emit`] appends to the trace instead of simulating (scalar and
/// `vsetvli` instructions still execute so emission-time address/`vl` state
/// stays live, exactly as in [`SimMode::TimingOnly`] — but no cycles are
/// accounted).
#[derive(Default)]
pub(crate) struct Recording {
    /// Dynamic instruction trace, in emission order.
    pub(crate) trace: Vec<Instr>,
    /// Indices into `trace` of `li` instructions whose immediate is a
    /// simulated-memory address (re-based on relocated replay). Sorted by
    /// construction (recorded in emission order).
    pub(crate) reloc: Vec<u32>,
    /// Host-side memory writes `(address, bytes)`, in program order.
    pub(crate) image: Vec<(u64, Vec<u8>)>,
}

/// The simulated system: one CVA6 scalar core + one Ara/Quark vector unit.
pub struct Sim {
    pub cfg: MachineConfig,
    pub machine: Machine,
    timing: timing::Timing,
    stats: Stats,
    mode: SimMode,
    /// When armed, emitted instructions are recorded instead of simulated
    /// (see [`Recording`]).
    recording: Option<Box<Recording>>,
}

impl Sim {
    /// Default simulated memory: 192 MiB (fits FP32 ResNet-18 weights plus
    /// activations and im2col scratch).
    pub const DEFAULT_MEM: usize = 192 << 20;

    pub fn new(cfg: MachineConfig) -> Self {
        Self::with_memory(cfg, Self::DEFAULT_MEM)
    }

    pub fn with_memory(cfg: MachineConfig, mem_bytes: usize) -> Self {
        Sim {
            machine: Machine::new(&cfg, mem_bytes),
            timing: timing::Timing::new(&cfg),
            stats: Stats::default(),
            cfg,
            mode: SimMode::Full,
            recording: None,
        }
    }

    // ---- trace recording (the compile half of compile-once / run-many) ----

    /// Arm trace recording: every subsequent emit is captured instead of
    /// simulated. Used by [`crate::program::ProgramBuilder`] only.
    pub(crate) fn start_recording(&mut self) {
        self.recording = Some(Box::default());
    }

    /// Disarm recording and return the capture. Panics if recording was
    /// never armed (a `ProgramBuilder` bug, not a runtime condition).
    pub(crate) fn take_recording(&mut self) -> Recording {
        *self.recording.take().expect("Sim::take_recording without start_recording")
    }

    /// Number of instructions recorded so far (0 when not recording) — the
    /// layer-marker cursor for [`crate::program::ProgramBuilder`].
    pub(crate) fn trace_len(&self) -> usize {
        self.recording.as_ref().map_or(0, |r| r.trace.len())
    }

    /// True while a recording is armed (replay into a recording `Sim` is a
    /// logic error and asserts against this).
    pub(crate) fn is_recording(&self) -> bool {
        self.recording.is_some()
    }

    pub fn set_mode(&mut self, mode: SimMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> SimMode {
        self.mode
    }

    /// Total cycles elapsed (completion of everything emitted so far).
    pub fn cycles(&self) -> u64 {
        self.timing.cycles()
    }

    pub fn stats(&self) -> &Stats {
        &self.stats
    }

    pub fn stats_mut(&mut self) -> &mut Stats {
        &mut self.stats
    }

    /// Allocate simulated memory (64-byte aligned).
    pub fn alloc(&mut self, bytes: u64) -> u64 {
        self.machine.mem.alloc(bytes)
    }

    /// Emit one instruction; panics on illegal-instruction for this config.
    #[inline]
    pub fn emit(&mut self, instr: Instr) {
        if let Err(e) = self.try_emit(instr) {
            panic!("{e} (machine: {})", self.cfg.name);
        }
    }

    /// Emit one instruction, reporting ISA-availability violations.
    #[inline]
    pub fn try_emit(&mut self, instr: Instr) -> Result<(), SimError> {
        if let Instr::Vector(v) = &instr {
            if v.needs_vfpu() && !self.cfg.has_vfpu {
                return Err(SimError::NoVectorFpu(vop_name(v)));
            }
            if v.is_quark_custom() && !self.cfg.has_quark_isa {
                return Err(SimError::NoQuarkIsa(vop_name(v)));
            }
        }
        if let Some(rec) = self.recording.as_mut() {
            rec.trace.push(instr);
            // Scalar and config instructions still execute so emission-time
            // state (addresses, vl) stays live — the TimingOnly rule, minus
            // the cycle accounting. Vector data paths are not evaluated.
            if matches!(instr, Instr::VSetVli { .. } | Instr::Scalar(_)) {
                self.machine.execute(&instr);
            }
            return Ok(());
        }
        // Capture vector state *before* execution (vsetvli changes it).
        let (vl, sew) = (self.machine.vl, self.machine.vtype.sew);
        self.timing.step(&instr, vl, sew, &mut self.stats);
        match self.mode {
            SimMode::Full => {
                self.machine.cycle_csr = self.timing.now();
                self.machine.execute(&instr);
            }
            SimMode::TimingOnly => {
                // Config + scalar ops still execute so addresses/vl track.
                match &instr {
                    Instr::VSetVli { .. } | Instr::Scalar(_) => {
                        self.machine.cycle_csr = self.timing.now();
                        self.machine.execute(&instr);
                    }
                    Instr::Vector(_) => {}
                }
            }
        }
        Ok(())
    }

    // ---- emit helpers (a tiny macro-assembler; kernels read much closer
    //      to the paper's hand-written RVV assembly with these) ----

    pub fn vsetvli(&mut self, avl: u64, sew: Sew, lmul: Lmul) -> u64 {
        self.emit(Instr::VSetVli {
            rd: crate::isa::Reg(0),
            avl,
            vtype: VType::new(sew, lmul),
        });
        self.machine.vl
    }

    pub fn li(&mut self, rd: crate::isa::Reg, imm: i64) {
        self.emit(Instr::Scalar(ScalarOp::Li { rd, imm }));
    }

    /// `li rd, addr` for a *simulated-memory address*. Identical to
    /// [`Sim::li`] at emission time, but when a trace is being recorded the
    /// instruction is marked relocatable, so [`Sim::execute`] can re-base
    /// the whole program at a different address. Kernels must use this (not
    /// `li`) for every buffer address they materialize.
    pub fn li_addr(&mut self, rd: crate::isa::Reg, addr: u64) {
        if let Some(rec) = self.recording.as_mut() {
            rec.reloc.push(rec.trace.len() as u32);
        }
        self.emit(Instr::Scalar(ScalarOp::Li { rd, imm: addr as i64 }));
    }

    pub fn v(&mut self, op: VOp) {
        self.emit(Instr::Vector(op));
    }

    pub fn s(&mut self, op: ScalarOp) {
        self.emit(Instr::Scalar(op));
    }

    /// Emit a loop back-edge marker (taken branch + induction update).
    pub fn loop_edge(&mut self, counter: crate::isa::Reg) {
        self.emit(Instr::Scalar(ScalarOp::AluImm {
            op: crate::isa::instr::AluOp::Add,
            rd: counter,
            rs1: counter,
            imm: -1,
        }));
        self.emit(Instr::Scalar(ScalarOp::Branch { taken: true }));
    }

    // ---- host-side data access (model setup, test fixtures, golden
    //      comparisons). Writes are captured by an armed recording: they are
    //      the initial-memory image a compiled program re-applies on replay.

    pub fn write_bytes(&mut self, addr: u64, data: &[u8]) {
        if let Some(rec) = self.recording.as_mut() {
            rec.image.push((addr, data.to_vec()));
        }
        self.machine.mem.write(addr, data);
    }

    pub fn read_bytes(&self, addr: u64, len: usize) -> Vec<u8> {
        self.machine.mem.read(addr, len).to_vec()
    }

    pub fn write_i8(&mut self, addr: u64, data: &[i8]) {
        let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
        self.write_bytes(addr, &bytes);
    }

    pub fn read_i32s(&self, addr: u64, n: usize) -> Vec<i32> {
        (0..n)
            .map(|i| self.machine.mem.read_u64_le(addr + (i * 4) as u64, 4) as u32 as i32)
            .collect()
    }

    pub fn write_i32s(&mut self, addr: u64, data: &[i32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|&v| (v as u32).to_le_bytes()).collect();
        self.write_bytes(addr, &bytes);
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| f32::from_bits(self.machine.mem.read_u64_le(addr + (i * 4) as u64, 4) as u32))
            .collect()
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|&v| v.to_bits().to_le_bytes()).collect();
        self.write_bytes(addr, &bytes);
    }

    /// Write a dense little-endian u64 array (packed weight planes, index
    /// vectors). One recorded image chunk, vs one per word with
    /// `machine.mem.write_u64_le` — which recordings do not see.
    pub fn write_u64s(&mut self, addr: u64, data: &[u64]) {
        let bytes: Vec<u8> = data.iter().flat_map(|&v| v.to_le_bytes()).collect();
        self.write_bytes(addr, &bytes);
    }

    pub fn read_u8s(&self, addr: u64, n: usize) -> Vec<u8> {
        self.machine.mem.read(addr, n).to_vec()
    }
}

fn vop_name(v: &VOp) -> &'static str {
    match v {
        VOp::FMaccVF { .. } => "vfmacc.vf",
        VOp::FAddVV { .. } => "vfadd.vv",
        VOp::FMulVF { .. } => "vfmul.vf",
        VOp::FMaxVF { .. } => "vfmax.vf",
        VOp::FMvVF { .. } => "vfmv.v.f",
        VOp::FRedSum { .. } => "vfredusum.vs",
        VOp::Popcnt { .. } => "vpopcnt.v",
        VOp::Shacc { .. } => "vshacc.vi",
        VOp::Bitpack { .. } => "vbitpack.vi",
        _ => "vector op",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::VReg;

    #[test]
    fn quark_rejects_vector_fp() {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.vsetvli(16, Sew::E32, Lmul::M1);
        let err = sim.try_emit(Instr::Vector(VOp::FMvVF {
            vd: VReg(1),
            rs1: crate::isa::FReg(0),
        }));
        assert!(matches!(err, Err(SimError::NoVectorFpu(_))));
    }

    #[test]
    fn ara_rejects_quark_custom_ops() {
        let mut sim = Sim::new(MachineConfig::ara(4));
        sim.vsetvli(16, Sew::E64, Lmul::M1);
        let err = sim.try_emit(Instr::Vector(VOp::Popcnt { vd: VReg(1), vs2: VReg(2) }));
        assert!(matches!(err, Err(SimError::NoQuarkIsa(_))));
    }

    #[test]
    fn timing_only_matches_full_cycle_count() {
        // The kernels are data-independent: TimingOnly must produce identical
        // cycle counts to Full on the same instruction stream.
        let run = |mode: SimMode| {
            let mut sim = Sim::new(MachineConfig::quark(4));
            sim.set_mode(mode);
            let buf = sim.alloc(4096);
            sim.li(crate::isa::reg::abi::A0, buf as i64);
            sim.vsetvli(512, Sew::E8, Lmul::M1);
            for _ in 0..4 {
                sim.v(VOp::Load {
                    kind: crate::isa::VMemKind::UnitStride,
                    eew: Sew::E8,
                    vd: VReg(1),
                    base: crate::isa::reg::abi::A0,
                });
                sim.v(VOp::IVI { op: crate::isa::instr::VIOp::Add, vd: VReg(2), vs2: VReg(1), imm: 3 });
                sim.v(VOp::Store {
                    kind: crate::isa::VMemKind::UnitStride,
                    eew: Sew::E8,
                    vs3: VReg(2),
                    base: crate::isa::reg::abi::A0,
                });
                sim.loop_edge(crate::isa::reg::abi::T0);
            }
            sim.cycles()
        };
        assert_eq!(run(SimMode::Full), run(SimMode::TimingOnly));
    }

    #[test]
    fn cycle_csr_tracks_timing() {
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.vsetvli(64, Sew::E64, Lmul::M1);
        sim.v(VOp::MvVI { vd: VReg(1), imm: 1 });
        sim.s(ScalarOp::CsrReadCycle { rd: crate::isa::reg::abi::T0 });
        let t0 = sim.machine.get_x(crate::isa::reg::abi::T0);
        for _ in 0..10 {
            sim.v(VOp::IVV {
                op: crate::isa::instr::VIOp::Add,
                vd: VReg(2),
                vs2: VReg(1),
                vs1: VReg(1),
            });
        }
        sim.s(ScalarOp::CsrReadCycle { rd: crate::isa::reg::abi::T1 });
        let t1 = sim.machine.get_x(crate::isa::reg::abi::T1);
        assert!(t1 > t0, "cycle CSR must advance: {t0} -> {t1}");
    }
}
