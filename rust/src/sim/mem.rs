//! Flat simulated memory with a bump allocator.
//!
//! The Ara/Quark testbed streams tensors from an L2/scratchpad; we model a
//! single flat address space (base [`Memory::BASE`]) whose *bandwidth* is
//! charged by the timing model (`timing.rs`), not here. The allocator hands
//! out 64-byte-aligned regions, mirroring how the paper's kernels lay out
//! tensors for unit-stride vector access.

/// Flat byte-addressable memory.
pub struct Memory {
    base: u64,
    data: Vec<u8>,
    brk: u64,
}

impl Memory {
    /// Lowest valid address (catches null-ish pointer bugs in kernels).
    pub const BASE: u64 = 0x1000;

    pub fn new(size_bytes: usize) -> Self {
        Memory { base: Self::BASE, data: vec![0u8; size_bytes], brk: Self::BASE }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// Allocate `bytes` with 64-byte alignment; returns the address.
    /// Panics on exhaustion (simulated workloads are sized up front).
    pub fn alloc(&mut self, bytes: u64) -> u64 {
        let addr = (self.brk + 63) & !63;
        let end = addr + bytes;
        assert!(
            (end - self.base) as usize <= self.data.len(),
            "simulated memory exhausted: need {} KiB, have {} KiB",
            (end - self.base) / 1024,
            self.data.len() / 1024
        );
        self.brk = end;
        addr
    }

    /// Reset the allocator (used between layers when buffers are dead).
    pub fn reset_alloc_to(&mut self, addr: u64) {
        assert!(addr >= self.base && addr <= self.brk);
        self.brk = addr;
    }

    pub fn brk(&self) -> u64 {
        self.brk
    }

    #[inline]
    fn idx(&self, addr: u64, len: usize) -> usize {
        let off = addr.checked_sub(self.base).unwrap_or_else(|| {
            panic!("address {addr:#x} below memory base {:#x}", self.base)
        }) as usize;
        assert!(
            off + len <= self.data.len(),
            "address {addr:#x}+{len} out of bounds (size {:#x})",
            self.data.len()
        );
        off
    }

    #[inline]
    pub fn read(&self, addr: u64, len: usize) -> &[u8] {
        let i = self.idx(addr, len);
        &self.data[i..i + len]
    }

    #[inline]
    pub fn write(&mut self, addr: u64, bytes: &[u8]) {
        let i = self.idx(addr, bytes.len());
        self.data[i..i + bytes.len()].copy_from_slice(bytes);
    }

    #[inline]
    pub fn read_u64_le(&self, addr: u64, bytes: usize) -> u64 {
        let s = self.read(addr, bytes);
        let mut buf = [0u8; 8];
        buf[..bytes].copy_from_slice(s);
        u64::from_le_bytes(buf)
    }

    #[inline]
    pub fn write_u64_le(&mut self, addr: u64, value: u64, bytes: usize) {
        let le = value.to_le_bytes();
        self.write(addr, &le[..bytes]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_aligned_and_monotonic() {
        let mut m = Memory::new(1 << 16);
        let a = m.alloc(10);
        let b = m.alloc(1);
        assert_eq!(a % 64, 0);
        assert_eq!(b % 64, 0);
        assert!(b >= a + 10);
    }

    #[test]
    fn rw_roundtrip() {
        let mut m = Memory::new(1 << 16);
        let a = m.alloc(64);
        m.write_u64_le(a, 0xDEAD_BEEF_0BAD_F00D, 8);
        assert_eq!(m.read_u64_le(a, 8), 0xDEAD_BEEF_0BAD_F00D);
        m.write_u64_le(a + 8, 0x7F, 1);
        assert_eq!(m.read_u64_le(a + 8, 1), 0x7F);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_read_panics() {
        let m = Memory::new(4096);
        let _ = m.read(Memory::BASE + 4096, 1);
    }
}
