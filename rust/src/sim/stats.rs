//! Execution statistics: the quantities every report and roofline needs.


/// Counters accumulated by the simulator while a kernel runs.
///
/// `PartialEq`/`Eq` so differential suites (replay vs fresh emission) can
/// compare whole snapshots.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Stats {
    /// Dynamic scalar instructions (CVA6-executed).
    pub scalar_instrs: u64,
    /// Dynamic vector instructions (dispatched to Ara/Quark).
    pub vector_instrs: u64,
    /// `vsetvli` count.
    pub vcfg_instrs: u64,
    /// Total vector element operations (Σ vl over vector arithmetic ops).
    pub vector_elem_ops: u64,
    /// Bytes moved by vector loads.
    pub vload_bytes: u64,
    /// Bytes moved by vector stores.
    pub vstore_bytes: u64,
    /// Bytes moved by scalar loads/stores.
    pub scalar_mem_bytes: u64,
    /// Effective multiply-accumulates, credited by the *kernels* (a bit-serial
    /// kernel processing 64 bit-products counts the MACs it implements, so
    /// GOPS are comparable across precisions, as the paper plots them).
    pub effective_macs: u64,
    /// Cycles spent with the mask unit busy (packing-path diagnosis).
    pub mask_unit_cycles: u64,
    /// Cycles spent with the vector LSU busy.
    pub vlsu_cycles: u64,
    /// Cycles the scalar FPU was busy (re-scaling cost, CVA6-side).
    pub scalar_fpu_cycles: u64,
}

impl Stats {
    /// Total bytes moved to/from memory (roofline x-axis denominator).
    pub fn total_bytes(&self) -> u64 {
        self.vload_bytes + self.vstore_bytes + self.scalar_mem_bytes
    }

    /// Arithmetic intensity in effective ops/byte (1 MAC = 2 ops).
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.total_bytes() == 0 {
            return 0.0;
        }
        (2 * self.effective_macs) as f64 / self.total_bytes() as f64
    }

    /// Difference of two snapshots (`later - earlier`): per-kernel deltas.
    pub fn delta_since(&self, earlier: &Stats) -> Stats {
        Stats {
            scalar_instrs: self.scalar_instrs - earlier.scalar_instrs,
            vector_instrs: self.vector_instrs - earlier.vector_instrs,
            vcfg_instrs: self.vcfg_instrs - earlier.vcfg_instrs,
            vector_elem_ops: self.vector_elem_ops - earlier.vector_elem_ops,
            vload_bytes: self.vload_bytes - earlier.vload_bytes,
            vstore_bytes: self.vstore_bytes - earlier.vstore_bytes,
            scalar_mem_bytes: self.scalar_mem_bytes - earlier.scalar_mem_bytes,
            effective_macs: self.effective_macs - earlier.effective_macs,
            mask_unit_cycles: self.mask_unit_cycles - earlier.mask_unit_cycles,
            vlsu_cycles: self.vlsu_cycles - earlier.vlsu_cycles,
            scalar_fpu_cycles: self.scalar_fpu_cycles - earlier.scalar_fpu_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity_counts_macs_as_two_ops() {
        let s = Stats { effective_macs: 100, vload_bytes: 40, ..Default::default() };
        assert!((s.arithmetic_intensity() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn delta() {
        let a = Stats { scalar_instrs: 10, vector_instrs: 5, ..Default::default() };
        let b = Stats { scalar_instrs: 25, vector_instrs: 9, ..Default::default() };
        let d = b.delta_since(&a);
        assert_eq!(d.scalar_instrs, 15);
        assert_eq!(d.vector_instrs, 4);
    }
}
