//! `repro` — CLI entrypoint for the Quark reproduction.
//!
//! Subcommands (see `repro --help`):
//!   * `simulate`  — run one kernel/model on a simulated machine, print cycles
//!   * `report`    — regenerate a paper table/figure (fig3, fig4, mixed, table1, table2, fig5, summary)
//!   * `serve`     — start the batching inference coordinator
//!   * `crosscheck`— simulator vs PJRT golden-model numeric check

fn main() -> quark::error::Result<()> {
    quark::cli::main()
}
