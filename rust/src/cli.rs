//! Hand-rolled CLI (no clap in this offline environment).
//!
//! ```text
//! repro report <fig3|fig4|mixed|cluster|table1|table2|fig5|summary|all>
//!              [--net <spec>] [--fast]
//! repro simulate --kernel <conv2d|gemm> --precision <fp32|int8|w1a1|w2a2|w2a2-novbp>
//!                [--machine <ara-4l|quark-4l|quark-8l>] [--size N] [--channels C]
//! repro program [--net <spec>] [--precision <spec>]
//!               [--machine <ara-4l|quark-4l|quark-8l>] [--fast]
//! repro verify [--net <spec>] [--prec <spec>] [--shards N]
//!              [--machine <ara-4l|quark-4l|quark-8l>] [--fast]
//! repro cluster [--net <spec>] [--shards 1,2,4,8] [--pipeline] [--fast]
//! repro profile [--net <spec>] [--prec <spec|mixed>] [--shards N]
//!               [--stages N]
//!               [--machine <ara-4l|quark-4l|quark-8l>] [--fast] [--out <path>]
//! repro models
//! repro crosscheck [--artifact artifacts/qgemm.hlo.txt] [--seed S]
//! repro serve [--addr 127.0.0.1:7070] [--workers N] [--batch B] [--queue Q]
//!             [--machine <ara-4l|quark-4l|quark-8l>] [--shards N]
//!             [--mode <tensor|pipeline>] [--stages N]
//!             [--models <spec,spec,…>] [--fast]
//!             [--precision <spec>]      e.g. --precision "w2a2;c1=int8;fc=int8"
//!             [--degrade <spec>] [--degrade-depth N]
//!             [--trace <path>]
//! repro phys
//! ```
//!
//! Workloads are **zoo model specs** (`name[@classes]` — see
//! [`crate::nn::zoo`]; `repro models` lists the registry). `--net` selects
//! the graph a report/program/cluster run uses (default
//! `resnet18-cifar@100`, the paper's workload), and `--fast` applies the
//! registry's per-model truncation profile — one implementation here,
//! replacing the per-command `.take(8)` copies this file used to carry.
//!
//! `repro program` demonstrates the compile-once / run-many split: it
//! compiles a [`crate::program::CompiledProgram`], prints the artifact's
//! vital signs (trace length, image size, memory footprint), then
//! cross-checks a timed replay against one fresh kernel emission — cycle
//! counts must agree exactly — and reports the wall-clock ratio.
//!
//! `repro verify` runs the static program verifier
//! ([`crate::program::verify`]) across deployments: every zoo model ×
//! {w2a2, w1a1, mixed, int8} × shard counts {1, 2, 4} by default, or one
//! combination pinned with `--net` / `--prec` / `--shards`. Combinations a
//! model cannot deploy (e.g. too few layers for the shard count) are
//! reported `n/a` and skipped; every compiled artifact's `VerifyReport` is
//! printed through the same printer `repro program` uses, and the command
//! fails if any deployment produces findings.
//!
//! `repro cluster` (alias `repro report cluster`) runs the tensor-parallel
//! strong-scaling sweep ([`crate::report::cluster`]): modeled latency at
//! 1/2/4/8 shard cores for w2a2 / w1a1 / mixed, with the all-gather sync
//! fraction. `serve --shards N` makes the coordinator partition every
//! default inference across N simulated cores (clients can override per
//! request with the `shards=` wire field). `repro cluster --pipeline` adds
//! the tensor-vs-pipeline sustained-throughput comparison
//! ([`crate::report::cluster::generate_modes`]), and `serve
//! --mode pipeline --stages N` deploys the coordinator in pipeline-parallel
//! mode instead: contiguous layer ranges staged across N cores, requests
//! streamed through bounded activation queues (clients override per request
//! with the `mode=` / `stages=` wire fields; the two axes don't compose).
//!
//! `serve --models a,b,c` deploys several zoo models behind one
//! coordinator — the first is the default; clients pick per request with
//! the `net=` wire field and list deployments with `MODELS`. The serve
//! `--precision` spec sets the deployment's default precision schedule
//! (`default[;layer=precision…]` — see
//! [`crate::nn::model::PrecisionMap::parse`]); clients can still override
//! it per request with the `prec=` wire field (`docs/serving.md`).
//!
//! `serve --degrade <spec>` arms the overload degrade policy: once the
//! queue holds `--degrade-depth` requests (default half of `--queue`),
//! submissions that pin neither `prec=` nor `shards=` are admitted under
//! the cheaper fallback schedule instead of answering `BUSY` — their
//! replies carry `degraded=1` and STATS counts them separately.
//!
//! `repro profile` is the cycle-attribution profiler ([`crate::obs`]): one
//! timed replay of the chosen deployment, attributed per layer and per
//! lowered micro-op class, cross-checked against an independent replay
//! (totals must agree exactly), printed as tables and optionally exported
//! as Chrome trace-event JSON with `--out` (load in Perfetto or
//! `chrome://tracing`). `serve --trace <path>` arms the host-side
//! counterpart: request-lifecycle spans recorded per worker, drained to
//! `<path>` by the `TRACE` wire command (`docs/observability.md`).

use std::collections::HashMap;
use std::sync::Arc;

use crate::bail;
use crate::error::{Context, Result};

use crate::arch::MachineConfig;
use crate::coordinator::{server, Coordinator, CoordinatorConfig, DegradePolicy};
use crate::nn::model::{Precision, PrecisionMap};
use crate::nn::{zoo, NetGraph};
use crate::report;

/// Resolve the workload of a report/program/cluster command: the `--net`
/// model spec (default: the paper's ResNet-18/CIFAR-100) under the
/// registry's `--fast` truncation profile when requested.
fn net_from_flags(flags: &HashMap<String, String>) -> Result<NetGraph> {
    let spec = flags.get("net").map(|s| s.as_str()).unwrap_or("resnet18-cifar@100");
    match zoo::model_profile(spec, flags.contains_key("fast")) {
        Ok(net) => Ok(net),
        Err(e) => bail!("bad --net: {e}"),
    }
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn machine_by_name(name: &str) -> Result<MachineConfig> {
    Ok(match name {
        "ara-4l" => MachineConfig::ara(4),
        "quark-4l" => MachineConfig::quark(4),
        "quark-8l" => MachineConfig::quark(8),
        other => bail!("unknown machine {other} (ara-4l, quark-4l, quark-8l)"),
    })
}

pub fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(|s| s.as_str()) {
        Some("report") => cmd_report(pos.get(1).map(|s| s.as_str()).unwrap_or("all"), &flags),
        Some("simulate") => cmd_simulate(&flags),
        Some("program") => cmd_program(&flags),
        Some("verify") => cmd_verify(&flags),
        Some("cluster") => cmd_cluster(&flags),
        Some("models") => {
            println!("{:<16} {:>8} {:>7} {:>6}  about", "name", "classes", "layers", "fast");
            for e in zoo::entries() {
                let full = zoo::model(e.name).expect("registry entries are valid");
                println!(
                    "{:<16} {:>8} {:>7} {:>6}  {}",
                    e.name,
                    e.default_classes,
                    full.layers().len(),
                    e.fast_layers,
                    e.about
                );
            }
            println!("\nspec syntax: name[@classes]   (e.g. resnet18-cifar@10)");
            Ok(())
        }
        Some("crosscheck") => cmd_crosscheck(&flags),
        Some("profile") => cmd_profile(&flags),
        Some("serve") => cmd_serve(&flags),
        Some("phys") => {
            let reports = report::table2::generate();
            println!("{}", report::table2::markdown(&reports));
            println!("{}", report::table2::fig5_markdown(&reports));
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: repro <report|simulate|program|verify|cluster|profile|models|crosscheck|serve|phys> …\n\
                 see rust/src/cli.rs or README.md for full syntax"
            );
            Ok(())
        }
    }
}

fn cmd_report(which: &str, flags: &HashMap<String, String>) -> Result<()> {
    let fast = flags.contains_key("fast");
    let net = net_from_flags(flags)?;
    // Kernel-level / physical reports have no model graph: say so rather
    // than silently ignoring an explicit --net.
    if flags.contains_key("net") && matches!(which, "fig4" | "table1" | "table2" | "fig5") {
        eprintln!("note: report {which} is model-independent; --net is ignored");
    }
    let run_fig3 = || {
        eprintln!(
            "[fig3] simulating {} at 5 precisions (this is the long one)…",
            net.name()
        );
        report::fig3::generate(&net)
    };
    let run_fig4 = || {
        eprintln!("[fig4] conv2d 3x3 roofline sweep…");
        if fast {
            report::fig4::generate(&[4, 8])
        } else {
            report::fig4::generate_default()
        }
    };
    let run_mixed = || {
        eprintln!(
            "[mixed] {} schedule sweep: uniform int8 / uniform w2a2 / mixed…",
            net.name()
        );
        report::mixed::generate(&net)
    };
    match which {
        // One implementation for both spellings (`repro report cluster` ≡
        // `repro cluster`): cmd_cluster handles --fast and --shards itself.
        "cluster" => return cmd_cluster(flags),
        "mixed" => {
            let rep = run_mixed();
            println!("{}", rep.markdown());
            report::write_report("mixed.md", &rep.markdown())?;
            report::write_report("mixed.csv", &rep.csv())?;
        }
        "fig3" => {
            let fig = run_fig3();
            println!("{}", fig.markdown());
            report::write_report("fig3.md", &fig.markdown())?;
            report::write_report("fig3.csv", &fig.csv())?;
        }
        "fig4" => {
            let fig = run_fig4();
            println!("{}", fig.markdown());
            report::write_report("fig4.md", &fig.markdown())?;
            report::write_report("fig4.csv", &fig.csv())?;
        }
        "table1" => {
            let rows = report::table1::generate(std::path::Path::new("artifacts/table1.tsv"));
            println!("{}", report::table1::markdown(&rows));
            report::write_report("table1.md", &report::table1::markdown(&rows))?;
        }
        "table2" => {
            let reports = report::table2::generate();
            println!("{}", report::table2::markdown(&reports));
            report::write_report("table2.md", &report::table2::markdown(&reports))?;
            report::write_report("table2.csv", &report::table2::csv(&reports))?;
        }
        "fig5" => {
            let reports = report::table2::generate();
            println!("{}", report::table2::fig5_markdown(&reports));
            report::write_report("fig5.md", &report::table2::fig5_markdown(&reports))?;
        }
        "summary" | "all" => {
            let fig3 = run_fig3();
            let fig4 = run_fig4();
            let phys = report::table2::generate();
            let rows = report::table1::generate(std::path::Path::new("artifacts/table1.tsv"));
            let s = report::summary::generate(&fig3, &fig4);
            if which == "all" {
                let mixed = run_mixed();
                println!("{}", fig3.markdown());
                println!("{}", fig4.markdown());
                println!("{}", mixed.markdown());
                report::write_report("mixed.md", &mixed.markdown())?;
                report::write_report("mixed.csv", &mixed.csv())?;
                println!("{}", report::table1::markdown(&rows));
                println!("{}", report::table2::markdown(&phys));
                println!("{}", report::table2::fig5_markdown(&phys));
                report::write_report("fig3.md", &fig3.markdown())?;
                report::write_report("fig3.csv", &fig3.csv())?;
                report::write_report("fig4.md", &fig4.markdown())?;
                report::write_report("fig4.csv", &fig4.csv())?;
                report::write_report("table1.md", &report::table1::markdown(&rows))?;
                report::write_report("table2.md", &report::table2::markdown(&phys))?;
                report::write_report("fig5.md", &report::table2::fig5_markdown(&phys))?;
            }
            println!("{}", report::summary::markdown(&s));
            report::write_report("summary.md", &report::summary::markdown(&s))?;
        }
        other => bail!("unknown report {other}"),
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    use crate::kernels::bitpack::setup_index_vector;
    use crate::kernels::conv2d::{conv2d_bitserial, conv2d_f32, conv2d_int8};
    use crate::kernels::requantize::RqBuf;
    use crate::kernels::Conv2dParams;
    use crate::quant::pack_weight_planes;
    use crate::sim::{Sim, SimMode};

    let precision = flags.get("precision").map(|s| s.as_str()).unwrap_or("w2a2");
    let default_machine = if precision == "fp32" || precision == "int8" { "ara-4l" } else { "quark-4l" };
    let machine = machine_by_name(flags.get("machine").map(|s| s.as_str()).unwrap_or(default_machine))?;
    let hw: usize = flags.get("size").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let c: usize = flags.get("channels").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("conv2d");
    let p = match kernel {
        "conv2d" => Conv2dParams { h: hw, w: hw, c_in: c, c_out: c, kh: 3, kw: 3, stride: 1, pad: 1 },
        "gemm" => crate::kernels::matmul::gemm_params(hw, c * 9, c),
        other => bail!("unknown kernel {other}"),
    };

    let mut sim = Sim::new(machine.clone());
    sim.set_mode(SimMode::TimingOnly);
    let idx = setup_index_vector(&mut sim);
    let (k, n) = (p.k(), p.c_out);
    let fm_in = sim.alloc((p.h * p.w * p.c_in * 4) as u64);
    let out = sim.alloc((p.out_h() * p.out_w() * n * 4) as u64);
    let before = sim.stats().clone();
    let c0 = sim.cycles();
    let run = match precision {
        "fp32" => {
            let w = sim.alloc((k * n * 4) as u64);
            let b = sim.alloc((n * 4) as u64);
            conv2d_f32(&mut sim, &p, fm_in, w, b, out, true, None)
        }
        "int8" => {
            let w = sim.alloc((k * n) as u64);
            let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
            conv2d_int8(&mut sim, &p, fm_in, w, &rq, out, None)
        }
        spec => {
            let (abits, wbits, vbp) = match Precision::parse(spec) {
                Ok(Precision::Sub { abits, wbits, use_vbitpack }) => (abits, wbits, use_vbitpack),
                _ => bail!("unknown precision {spec} (fp32, int8, or wNaM[-novbp])"),
            };
            let block = crate::kernels::conv2d::bitserial_block(machine.vlen_bits, n);
            let wpk = pack_weight_planes(&vec![0u8; k * n], k, n, wbits, block);
            let w = sim.alloc(wpk.byte_len() as u64);
            let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
            conv2d_bitserial(&mut sim, &p, abits, fm_in, &wpk, w, &rq, out, None, vbp, idx)
        }
    };
    let stats = sim.stats().delta_since(&before);
    let cycles = sim.cycles() - c0;
    let secs = cycles as f64 / (machine.freq_ghz * 1e9);
    println!("machine       : {}", machine.name);
    println!("kernel        : {kernel} {}x{}x{} k={}", p.h, p.w, p.c_in, p.k());
    println!("precision     : {precision}");
    println!("cycles        : {cycles}");
    println!("device time   : {:.1} us", secs * 1e6);
    println!("effective MACs: {}", run.macs);
    println!("MAC/cycle     : {:.2}", run.macs_per_cycle());
    println!("GOPS          : {:.1}", 2.0 * run.macs as f64 / secs / 1e9);
    println!("AI            : {:.2} ops/byte", stats.arithmetic_intensity());
    println!(
        "instrs        : {} scalar, {} vector ({} vcfg)",
        stats.scalar_instrs, stats.vector_instrs, stats.vcfg_instrs
    );
    Ok(())
}

/// Compile-once / run-many demo: compile the deployment once, show what the
/// artifact contains, and prove a replay is cycle-exact against one fresh
/// emission (while timing both paths).
fn cmd_program(flags: &HashMap<String, String>) -> Result<()> {
    use crate::nn::model::ModelRunner;
    use crate::sim::{Sim, SimMode};
    use std::time::Instant;

    let spec = flags.get("precision").map(|s| s.as_str()).unwrap_or("w2a2");
    let schedule = match PrecisionMap::parse(spec) {
        Ok(m) => m,
        Err(e) => bail!("bad --precision: {e}"),
    };
    let default_machine =
        if schedule.default_precision() == Precision::Fp32 { "ara-4l" } else { "quark-4l" };
    let machine =
        machine_by_name(flags.get("machine").map(|s| s.as_str()).unwrap_or(default_machine))?;
    let net = net_from_flags(flags)?;

    let t0 = Instant::now();
    let prog = match crate::program::compile(&net, &machine, &schedule) {
        Ok(p) => p,
        Err(e) => bail!("cannot compile schedule for this deployment: {e}"),
    };
    let compile_s = t0.elapsed().as_secs_f64();
    println!("model          : {}", prog.model());
    println!("machine        : {}", machine.name);
    println!("schedule       : {}", schedule.spec());
    println!("layers         : {}", prog.layers().len());
    println!("trace          : {} instructions", prog.trace_len());
    println!("init image     : {:.1} KiB", prog.image_bytes() as f64 / 1024.0);
    println!("memory footprint: {:.1} KiB", prog.mem_len() as f64 / 1024.0);
    println!("compile time   : {:.3} s (once per deployment)", compile_s);
    // Verifier vitals through the shared `VerifyReport` printer (`repro
    // verify` prints the same report across the zoo).
    println!("{}", prog.verify_report());
    if !prog.verify_report().ok() {
        bail!("the compiler produced an artifact the static verifier rejects");
    }

    // Fresh emission (the run-every-request baseline) …
    let mut fresh_sim = Sim::new(machine.clone());
    fresh_sim.set_mode(SimMode::TimingOnly);
    let t0 = Instant::now();
    let fresh: u64 = ModelRunner::run_scheduled(&mut fresh_sim, &net, &schedule, None)
        .reports
        .iter()
        .map(|r| r.run.cycles)
        .sum();
    let fresh_s = t0.elapsed().as_secs_f64();
    // … vs a timed replay of the artifact.
    let mut replay_sim = Sim::new(machine.clone());
    replay_sim.set_mode(SimMode::TimingOnly);
    let base = replay_sim.alloc(prog.mem_len());
    let t0 = Instant::now();
    let replay = replay_sim.execute(&prog, base).cycles;
    let replay_s = t0.elapsed().as_secs_f64();
    if fresh != replay {
        bail!("replay diverged: fresh emission {fresh} cycles, replay {replay} cycles");
    }
    println!("device cycles  : {replay} (replay == fresh emission ✓)");
    println!("fresh emission : {fresh_s:.3} s host wall-clock per run");
    println!("timed replay   : {replay_s:.3} s host wall-clock per run ({:.2}x)", fresh_s / replay_s.max(1e-9));
    Ok(())
}

/// Static-verifier sweep: compile every requested (model, schedule, shard)
/// deployment and print its [`crate::program::VerifyReport`] through the
/// shared printer. Exits non-zero if any artifact produces findings — the
/// CI gate over the whole zoo.
fn cmd_verify(flags: &HashMap<String, String>) -> Result<()> {
    use crate::nn::model::ShardPlan;
    use crate::program::{compile, compile_shard};

    let machine =
        machine_by_name(flags.get("machine").map(|s| s.as_str()).unwrap_or("quark-4l"))?;
    let fast = flags.contains_key("fast");
    // Workload set: one model under --net, else the full zoo registry.
    let nets: Vec<NetGraph> = match flags.get("net") {
        Some(spec) => match zoo::model_profile(spec, fast) {
            Ok(n) => vec![n],
            Err(e) => bail!("bad --net: {e}"),
        },
        None => zoo::entries()
            .iter()
            .map(|e| zoo::model_profile(e.name, fast).expect("registry entries are valid"))
            .collect(),
    };
    let shard_counts: Vec<usize> = match flags.get("shards") {
        Some(s) => vec![s.parse().with_context(|| format!("bad --shards {s:?}"))?],
        None => vec![1, 2, 4],
    };
    let (mut passed, mut failed, mut skipped) = (0usize, 0usize, 0usize);
    for net in &nets {
        // Schedule matrix: one spec under --prec, else the acceptance set
        // ("mixed" = the registry's per-model mixed schedule).
        let scheds: Vec<(String, PrecisionMap)> = match flags.get("prec").map(|s| s.as_str()) {
            Some("mixed") => vec![("mixed".to_string(), zoo::mixed_schedule(net))],
            Some(spec) => match PrecisionMap::parse(spec) {
                Ok(m) => vec![(spec.to_string(), m)],
                Err(e) => bail!("bad --prec: {e}"),
            },
            None => vec![
                ("w2a2".to_string(), PrecisionMap::parse("w2a2").expect("known spec")),
                ("w1a1".to_string(), PrecisionMap::parse("w1a1").expect("known spec")),
                ("mixed".to_string(), zoo::mixed_schedule(net)),
                ("int8".to_string(), PrecisionMap::parse("int8").expect("known spec")),
            ],
        };
        for (label, sched) in &scheds {
            for &n in &shard_counts {
                let ctx = format!("{} · {label} · shards={n}", net.name());
                if let Err(e) = sched
                    .validate(net)
                    .and_then(|_| sched.validate_machine(net, &machine))
                    .and_then(|_| crate::coordinator::validate_shards(n, sched, net))
                {
                    println!("{ctx}: n/a ({e})");
                    skipped += 1;
                    continue;
                }
                let mut ok = true;
                if n == 1 {
                    let prog = match compile(net, &machine, sched) {
                        Ok(p) => p,
                        Err(e) => bail!("{ctx}: compile failed: {e}"),
                    };
                    println!("{ctx}\n{}", prog.verify_report());
                    ok = prog.verify_report().ok();
                } else {
                    let plan = match ShardPlan::derive(net, n) {
                        Ok(p) => p,
                        Err(e) => bail!("{ctx}: shard plan failed: {e}"),
                    };
                    println!("{ctx}");
                    for shard in 0..n {
                        let prog = match compile_shard(net, &machine, sched, &plan, shard) {
                            Ok(p) => p,
                            Err(e) => bail!("{ctx}: shard {shard} compile failed: {e}"),
                        };
                        println!("shard {shard}: {}", prog.verify_report());
                        ok &= prog.verify_report().ok();
                    }
                }
                if ok {
                    passed += 1;
                } else {
                    failed += 1;
                }
            }
        }
    }
    println!(
        "\nverified {} deployment(s): {passed} passed, {failed} failed, {skipped} n/a",
        passed + failed
    );
    if failed > 0 {
        bail!("{failed} deployment(s) failed static verification");
    }
    Ok(())
}

/// Tensor-parallel strong-scaling demo: modeled ResNet-18 latency at the
/// requested shard counts, per schedule, with the Amdahl-style sync
/// fraction (see [`crate::report::cluster`]).
fn cmd_cluster(flags: &HashMap<String, String>) -> Result<()> {
    let counts: Vec<usize> = match flags.get("shards") {
        Some(spec) => {
            let mut v = Vec::new();
            for tok in spec.split(',') {
                v.push(
                    tok.trim()
                        .parse()
                        .with_context(|| format!("bad --shards entry {tok:?}"))?,
                );
            }
            v
        }
        None => crate::report::cluster::DEFAULT_SHARD_COUNTS.to_vec(),
    };
    let net = net_from_flags(flags)?;
    eprintln!("[cluster] {} strong-scaling sweep at {counts:?} shard cores…", net.name());
    let rep = report::cluster::generate(&net, &counts);
    println!("{}", rep.markdown());
    report::write_report("cluster.md", &rep.markdown())?;
    report::write_report("cluster.csv", &rep.csv())?;
    if flags.contains_key("pipeline") {
        // Stage counts the net cannot form (residual blocks are indivisible)
        // are reported and skipped, not fatal — cut feasibility is
        // cost-independent, so unit costs suffice to probe it.
        use crate::nn::model::StagePlan;
        let feasible: Vec<usize> = counts
            .iter()
            .copied()
            .filter(|&n| match StagePlan::derive_balanced(&net, n, &vec![1; net.len()]) {
                Ok(_) => true,
                Err(e) => {
                    eprintln!("[cluster] skipping {n} stages: {e}");
                    false
                }
            })
            .collect();
        eprintln!(
            "[cluster] {} tensor-vs-pipeline comparison at {feasible:?} cores…",
            net.name()
        );
        let modes = report::cluster::generate_modes(&net, &feasible);
        println!("{}", modes.markdown());
        report::write_report("cluster_modes.md", &modes.markdown())?;
        report::write_report("cluster_modes.csv", &modes.csv())?;
    }
    Ok(())
}

/// Cycle-attribution profiler: compile one deployment, attribute one timed
/// replay per layer and per lowered micro-op class ([`crate::obs`]),
/// cross-check the attribution against an independent replay (exact
/// equality, layer for layer), print the tables, and optionally export a
/// Chrome trace (`--out`).
fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    use crate::cluster::{
        cluster_timing, compile_cluster, compile_pipeline, pipeline_timing, ClusterMode,
    };
    use crate::obs;
    use crate::sim::{Sim, SimMode};

    let machine =
        machine_by_name(flags.get("machine").map(|s| s.as_str()).unwrap_or("quark-4l"))?;
    let net = net_from_flags(flags)?;
    let (label, schedule) = match flags.get("prec").map(|s| s.as_str()).unwrap_or("w2a2") {
        "mixed" => ("mixed".to_string(), zoo::mixed_schedule(&net)),
        spec => match PrecisionMap::parse(spec) {
            Ok(m) => (spec.to_string(), m),
            Err(e) => bail!("bad --prec: {e}"),
        },
    };
    let shards: usize = match flags.get("shards") {
        Some(s) => s.parse().with_context(|| format!("bad --shards {s:?}"))?,
        None => 1,
    };
    // `--stages N` (N > 1) profiles the pipeline-parallel deployment; the
    // two axes don't compose, which validate_parallelism enforces below.
    let stages: usize = match flags.get("stages") {
        Some(s) => s.parse().with_context(|| format!("bad --stages {s:?}"))?,
        None => 1,
    };
    let mode = if stages > 1 { ClusterMode::Pipeline } else { ClusterMode::Tensor };
    if let Err(e) = schedule
        .validate(&net)
        .and_then(|_| schedule.validate_machine(&net, &machine))
        .and_then(|_| crate::coordinator::validate_parallelism(mode, shards, stages, &schedule, &net))
    {
        bail!("cannot deploy {} · {label} · shards={shards} · stages={stages}: {e}", net.name());
    }
    eprintln!(
        "[profile] {} · {label} · shards={shards} · stages={stages} on {}…",
        net.name(),
        machine.name
    );

    let (md, sims) = if stages > 1 {
        // Stream depth for the profiled pipeline's busy/bubble split.
        const STREAM_TOKENS: u64 = 16;
        let pipeline = match compile_pipeline(&net, &machine, &schedule, stages) {
            Ok(p) => p,
            Err(e) => bail!("pipeline compile failed: {e}"),
        };
        let profile = obs::profile_pipeline(&pipeline, &machine, STREAM_TOKENS);
        // Independent cross-check against the serving-path pipeline model.
        let timing = pipeline_timing(&pipeline, &machine, STREAM_TOKENS);
        if timing.total_cycles() != profile.timing.total_cycles() {
            bail!(
                "pipeline attribution diverged: timing model {} cycles, profile {}",
                timing.total_cycles(),
                profile.timing.total_cycles()
            );
        }
        println!("pipeline attribution == pipeline timing model ✓");
        let sims = profile.stages.clone();
        (report::profile::pipeline_markdown(&profile), sims)
    } else if shards == 1 {
        let prog = match crate::program::compile(&net, &machine, &schedule) {
            Ok(p) => p,
            Err(e) => bail!("compile failed: {e}"),
        };
        let profile = obs::profile_on_fresh_core(&prog, &machine);
        // Independent cross-check: a plain timed replay must agree with the
        // attribution layer for layer (and therefore in total).
        let mut sim = Sim::new(machine.clone());
        sim.set_mode(SimMode::TimingOnly);
        let base = sim.alloc(prog.mem_len());
        let run = sim.execute(&prog, base);
        if run.cycles != profile.total_cycles {
            bail!(
                "attribution diverged: replay {} cycles, profile {}",
                run.cycles,
                profile.total_cycles
            );
        }
        for (r, l) in run.reports.iter().zip(&profile.layers) {
            if r.run.cycles != l.cycles {
                bail!(
                    "attribution diverged at layer {}: replay {} cycles, profile {}",
                    r.name,
                    r.run.cycles,
                    l.cycles
                );
            }
        }
        println!("per-layer attribution == timed replay, layer for layer ✓");
        report::write_report("profile.csv", &report::profile::layers_csv(&profile))?;
        (report::profile::markdown(&profile), vec![profile])
    } else {
        let cluster = match compile_cluster(&net, &machine, &schedule, shards) {
            Ok(c) => c,
            Err(e) => bail!("cluster compile failed: {e}"),
        };
        let profile = obs::profile_cluster(&cluster, &machine);
        // Independent cross-check against the serving-path cluster model.
        let timing = cluster_timing(&cluster, &machine);
        if timing.total_cycles() != profile.timing.total_cycles() {
            bail!(
                "cluster attribution diverged: timing model {} cycles, profile {}",
                timing.total_cycles(),
                profile.timing.total_cycles()
            );
        }
        println!("cluster attribution == cluster timing model ✓");
        let sims = profile.shards.clone();
        (report::profile::cluster_markdown(&profile), sims)
    };
    println!("{md}");
    report::write_report("profile.md", &md)?;
    if let Some(path) = flags.get("out") {
        let json = obs::export::chrome_trace_json(&[], &sims);
        if let Err(e) = obs::export::validate_chrome_trace(&json) {
            bail!("internal: exported trace failed validation: {e}");
        }
        std::fs::write(path, &json)?;
        println!("chrome trace → {path}");
    }
    Ok(())
}

fn cmd_crosscheck(flags: &HashMap<String, String>) -> Result<()> {
    let artifact = flags
        .get("artifact")
        .cloned()
        .unwrap_or_else(|| "artifacts/qgemm.hlo.txt".to_string());
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let runtime = crate::runtime::Runtime::cpu().context("creating PJRT CPU client")?;
    println!("PJRT platform: {}", runtime.platform());
    let r = crate::coordinator::golden::crosscheck_qgemm(&runtime, &artifact, seed)?;
    println!(
        "crosscheck: {} accumulators checked, {} mismatches (sim cycles {})",
        r.checked, r.mismatches, r.sim_cycles
    );
    if r.mismatches > 0 {
        bail!("{} mismatches between simulator / JAX-AOT / oracle", r.mismatches);
    }
    println!("simulator == JAX(Pallas)-AOT-PJRT == host oracle ✓");
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7070".to_string());
    let mut cfg = CoordinatorConfig::demo();
    if let Some(w) = flags.get("workers") {
        cfg.workers = w.parse()?;
    }
    if let Some(b) = flags.get("batch") {
        cfg.batch_size = b.parse()?;
    }
    if let Some(q) = flags.get("queue") {
        cfg.max_queue = q.parse()?;
    }
    if let Some(m) = flags.get("machine") {
        cfg.machine = machine_by_name(m)?;
    }
    if let Some(spec) = flags.get("precision") {
        match PrecisionMap::parse(spec) {
            Ok(map) => cfg.schedule = map,
            Err(e) => bail!("bad --precision: {e}"),
        }
    }
    if let Some(s) = flags.get("shards") {
        cfg.shards = s.parse().with_context(|| format!("bad --shards {s:?}"))?;
    }
    if let Some(m) = flags.get("mode") {
        match crate::cluster::ClusterMode::parse(m) {
            Ok(mode) => cfg.mode = mode,
            Err(e) => bail!("bad --mode: {e}"),
        }
    }
    if let Some(s) = flags.get("stages") {
        cfg.stages = s.parse().with_context(|| format!("bad --stages {s:?}"))?;
    }
    // Overload degrade policy: fallback schedule + optional trip depth.
    let degrade = match flags.get("degrade") {
        Some(spec) => match PrecisionMap::parse(spec) {
            Ok(map) => Some(map),
            Err(e) => bail!("bad --degrade: {e}"),
        },
        None => None,
    };
    if flags.contains_key("degrade-depth") && degrade.is_none() {
        bail!("--degrade-depth requires --degrade");
    }
    let degrade_depth = match flags.get("degrade-depth") {
        Some(d) => d.parse().with_context(|| format!("bad --degrade-depth {d:?}"))?,
        None => cfg.max_queue / 2,
    };
    // Deployed model set: comma-separated zoo specs, first = default. The
    // registry --fast profile applies to every deployed model.
    let fast = flags.contains_key("fast");
    if let Some(list) = flags.get("models") {
        let mut models: Vec<Arc<NetGraph>> = Vec::new();
        for spec in list.split(',') {
            let g = match zoo::model_profile(spec, fast) {
                Ok(g) => g,
                Err(e) => bail!("bad --models entry {spec:?}: {e}"),
            };
            if models.iter().any(|m| m.name() == g.name()) {
                bail!("duplicate model {:?} in --models", g.name());
            }
            models.push(Arc::new(g));
        }
        cfg.models = models;
    }
    for model in &cfg.models {
        if let Err(e) = cfg
            .schedule
            .validate(model)
            .and_then(|_| cfg.schedule.validate_machine(model, &cfg.machine))
        {
            bail!("bad --precision for model {:?}: {e}", model.name());
        }
        if let Err(e) = crate::coordinator::validate_parallelism(
            cfg.mode,
            cfg.shards,
            cfg.stages,
            &cfg.schedule,
            model,
        ) {
            bail!("bad --mode/--shards/--stages for model {:?}: {e}", model.name());
        }
        // The degrade fallback must be deployable everywhere the default is.
        if let Some(map) = &degrade {
            if let Err(e) =
                map.validate(model).and_then(|_| map.validate_machine(model, &cfg.machine))
            {
                bail!("bad --degrade for model {:?}: {e}", model.name());
            }
            if let Err(e) = crate::coordinator::validate_parallelism(
                cfg.mode,
                cfg.shards,
                cfg.stages,
                map,
                model,
            ) {
                bail!("bad --degrade for model {:?}: {e}", model.name());
            }
        }
    }
    cfg.degrade = degrade.map(|schedule| DegradePolicy { schedule, depth: degrade_depth });
    let trace = flags.get("trace").map(std::path::PathBuf::from);
    let coord = Arc::new(Coordinator::start(cfg));
    server::serve_traced(coord, &addr, trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let args: Vec<String> =
            ["report", "fig3", "--fast", "--machine", "quark-4l"].iter().map(|s| s.to_string()).collect();
        let (pos, flags) = parse_flags(&args);
        assert_eq!(pos, vec!["report", "fig3"]);
        assert_eq!(flags.get("fast").map(|s| s.as_str()), Some("true"));
        assert_eq!(flags.get("machine").map(|s| s.as_str()), Some("quark-4l"));
    }

    #[test]
    fn machine_lookup() {
        assert!(machine_by_name("quark-8l").is_ok());
        assert!(machine_by_name("bogus").is_err());
    }
}
