//! GEMM kernels: `C[M,N] = A[M,K] × W[K,N]` at the three precisions.
//!
//! A matmul is exactly a 1×1 convolution over an `M×1` "feature map" with
//! `c_in = K`, `c_out = N` (the im2col row of each output "pixel" *is* the
//! A row, already contiguous), so these are thin wrappers over the conv2d
//! kernels — the same code path the FC layer of ResNet-18 uses. The paper
//! benchmarks both conv2d and matmul; sharing the schedule is what its vector
//! runtime does too.

use crate::quant::PackedWeights;
use crate::sim::Sim;

use super::conv2d::{conv2d_bitserial, conv2d_f32, conv2d_int8};
use super::requantize::RqBuf;
use super::{Conv2dParams, KernelRun};

/// Geometry helper: the `Conv2dParams` a GEMM maps onto.
pub fn gemm_params(m: usize, k: usize, n: usize) -> Conv2dParams {
    Conv2dParams { h: m, w: 1, c_in: k, c_out: n, kh: 1, kw: 1, stride: 1, pad: 0 }
}

/// Bit-serial sub-byte GEMM (Quark): u8 activation codes at `a` (row-major
/// `[M][K]`), offline-packed weights, u8 output codes at `out`.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bitserial(
    sim: &mut Sim,
    m: usize,
    k: usize,
    n: usize,
    abits: u8,
    a: u64,
    wpk: &PackedWeights,
    wbuf: u64,
    rq: &RqBuf,
    out: u64,
    use_vbitpack: bool,
    idx_vec: u64,
) -> KernelRun {
    let p = gemm_params(m, k, n);
    conv2d_bitserial(sim, &p, abits, a, wpk, wbuf, rq, out, None, use_vbitpack, idx_vec)
}

/// Int8 GEMM (Ara baseline): u8 codes × i8 weights (`[K][N]` row-major).
pub fn matmul_int8(
    sim: &mut Sim,
    m: usize,
    k: usize,
    n: usize,
    a: u64,
    wbuf: u64,
    rq: &RqBuf,
    out: u64,
) -> KernelRun {
    let p = gemm_params(m, k, n);
    conv2d_int8(sim, &p, a, wbuf, rq, out, None)
}

/// FP32 GEMM (Ara only), with fused bias (+ optional ReLU).
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32(
    sim: &mut Sim,
    m: usize,
    k: usize,
    n: usize,
    a: u64,
    wbuf: u64,
    bias: u64,
    out: u64,
    relu: bool,
) -> KernelRun {
    let p = gemm_params(m, k, n);
    conv2d_f32(sim, &p, a, wbuf, bias, out, relu, None)
}

/// Host-side golden GEMM over unsigned codes (oracle for the integer paths):
/// returns `(ACC[M][N], ASUM[M])`.
pub fn gemm_codes_golden(a: &[u8], w: &[u8], m: usize, k: usize, n: usize) -> (Vec<i64>, Vec<i64>) {
    let mut acc = vec![0i64; m * n];
    let mut asum = vec![0i64; m];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i64;
            asum[i] += av;
            if av != 0 {
                for j in 0..n {
                    acc[i * n + j] += av * w[kk * n + j] as i64;
                }
            }
        }
    }
    (acc, asum)
}

/// Host-side golden int8 GEMM: u8 activations × i8 weights.
pub fn gemm_int8_golden(a: &[u8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i64> {
    let mut acc = vec![0i64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i64;
            if av != 0 {
                for j in 0..n {
                    acc[i * n + j] += av * w[kk * n + j] as i64;
                }
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::kernels::bitpack::setup_index_vector;
    use crate::kernels::requantize::requant_host;
    use crate::quant::pack_weight_planes;

    fn lcg(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *seed >> 33
    }

    #[test]
    fn bitserial_matmul_matches_golden_end_to_end() {
        // Full pipeline: codes → packed planes → simulated Eq. 1 → simulated
        // scalar-FPU requant, vs the host oracle.
        let (m, k, n) = (5, 128, 7);
        let (abits, wbits) = (2u8, 2u8);
        let mut seed = 42u64;
        let a_codes: Vec<u8> = (0..m * k).map(|_| (lcg(&mut seed) % 4) as u8).collect();
        let w_codes: Vec<u8> = (0..k * n).map(|_| (lcg(&mut seed) % 4) as u8).collect();

        let mut sim = Sim::new(MachineConfig::quark(4));
        let idx = setup_index_vector(&mut sim);
        let block = sim.cfg.vlen_bits / 64;
        let wpk = pack_weight_planes(&w_codes, k, n, wbits, block);
        let a_addr = sim.alloc((m * k) as u64);
        sim.write_bytes(a_addr, &a_codes);
        let w_addr = sim.alloc(wpk.byte_len() as u64);
        for (i, &w) in wpk.words.iter().enumerate() {
            sim.machine.mem.write_u64_le(w_addr + (i * 8) as u64, w, 8);
        }
        let alphas: Vec<f32> = (0..n).map(|j| 0.01 + j as f32 * 0.002).collect();
        let betas: Vec<f32> = (0..n).map(|j| -0.005 - j as f32 * 0.001).collect();
        let biases: Vec<f32> = (0..n).map(|j| 0.1 * j as f32).collect();
        let rq = RqBuf::create(&mut sim, &alphas, &betas, &biases, 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);

        matmul_bitserial(&mut sim, m, k, n, abits, a_addr, &wpk, w_addr, &rq, out, true, idx);

        let (acc, asum) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want = requant_host(
                    acc[i * n + j] as i32,
                    Some(asum[i] as i32),
                    None,
                    alphas[j],
                    betas[j],
                    biases[j],
                    255.0,
                    0.0,
                );
                let got = sim.read_u8s(out + (i * n + j) as u64, 1)[0];
                assert_eq!(got, want, "({i},{j}) acc={} asum={}", acc[i * n + j], asum[i]);
            }
        }
    }

    #[test]
    fn bitserial_1bit_matches_golden() {
        let (m, k, n) = (3, 64, 4);
        let mut seed = 7u64;
        let a_codes: Vec<u8> = (0..m * k).map(|_| (lcg(&mut seed) % 2) as u8).collect();
        let w_codes: Vec<u8> = (0..k * n).map(|_| (lcg(&mut seed) % 2) as u8).collect();
        let mut sim = Sim::new(MachineConfig::quark(4));
        let idx = setup_index_vector(&mut sim);
        let block = sim.cfg.vlen_bits / 64;
        let wpk = pack_weight_planes(&w_codes, k, n, 1, block);
        let a_addr = sim.alloc((m * k) as u64);
        sim.write_bytes(a_addr, &a_codes);
        let w_addr = sim.alloc(wpk.byte_len() as u64);
        for (i, &w) in wpk.words.iter().enumerate() {
            sim.machine.mem.write_u64_le(w_addr + (i * 8) as u64, w, 8);
        }
        let rq = RqBuf::create(&mut sim, &[1.0; 4], &[0.0; 4], &[0.0; 4], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_bitserial(&mut sim, m, k, n, 1, a_addr, &wpk, w_addr, &rq, out, true, idx);
        let (acc, _) = gemm_codes_golden(&a_codes, &w_codes, m, k, n);
        for i in 0..m {
            for j in 0..n {
                // alpha=1, beta=0: output code == clamped ACC.
                let want = acc[i * n + j].clamp(0, 255) as u8;
                assert_eq!(sim.read_u8s(out + (i * n + j) as u64, 1)[0], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn int8_matmul_matches_golden() {
        let (m, k, n) = (4, 96, 9);
        let mut seed = 99u64;
        let a_codes: Vec<u8> = (0..m * k).map(|_| (lcg(&mut seed) % 256) as u8).collect();
        let w_codes: Vec<i8> = (0..k * n).map(|_| (lcg(&mut seed) % 256) as i8).collect();
        let mut sim = Sim::new(MachineConfig::ara(4));
        let a_addr = sim.alloc((m * k) as u64);
        sim.write_bytes(a_addr, &a_codes);
        let w_addr = sim.alloc((k * n) as u64);
        sim.write_i8(w_addr, &w_codes);
        let alphas = vec![0.001f32; n];
        let rq = RqBuf::create(&mut sim, &alphas, &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim.alloc((m * n) as u64);
        matmul_int8(&mut sim, m, k, n, a_addr, w_addr, &rq, out);
        let acc = gemm_int8_golden(&a_codes, &w_codes, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want = requant_host(acc[i * n + j] as i32, None, None, 0.001, 0.0, 0.0, 255.0, 0.0);
                assert_eq!(sim.read_u8s(out + (i * n + j) as u64, 1)[0], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn f32_matmul_matches_golden() {
        let (m, k, n) = (3, 40, 6);
        let mut seed = 5u64;
        let a: Vec<f32> = (0..m * k).map(|_| (lcg(&mut seed) % 100) as f32 / 50.0 - 1.0).collect();
        let w: Vec<f32> = (0..k * n).map(|_| (lcg(&mut seed) % 100) as f32 / 50.0 - 1.0).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.1).collect();
        let mut sim = Sim::new(MachineConfig::ara(4));
        let a_addr = sim.alloc((m * k * 4) as u64);
        sim.write_f32s(a_addr, &a);
        let w_addr = sim.alloc((k * n * 4) as u64);
        sim.write_f32s(w_addr, &w);
        let b_addr = sim.alloc((n * 4) as u64);
        sim.write_f32s(b_addr, &bias);
        let out = sim.alloc((m * n * 4) as u64);
        matmul_f32(&mut sim, m, k, n, a_addr, w_addr, b_addr, out, false);
        let got = sim.read_f32s(out, m * n);
        for i in 0..m {
            for j in 0..n {
                let mut want = bias[j];
                for kk in 0..k {
                    want = a[i * k + kk].mul_add(w[kk * n + j], want);
                }
                let g = got[i * n + j];
                assert!((g - want).abs() < 1e-3 * want.abs().max(1.0), "({i},{j}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn bitserial_beats_int8_on_cycles() {
        // The headline claim at GEMM level: 2-bit bit-serial with vbitpack
        // should beat int8 clearly on the same-size problem.
        let (m, k, n) = (32, 576, 64);
        let mut sim_q = Sim::new(MachineConfig::quark(4));
        sim_q.set_mode(crate::sim::SimMode::TimingOnly);
        let idx = setup_index_vector(&mut sim_q);
        let w_codes = vec![1u8; k * n];
        let wpk = pack_weight_planes(&w_codes, k, n, 2, sim_q.cfg.vlen_bits / 64);
        let a_addr = sim_q.alloc((m * k) as u64);
        let w_addr = sim_q.alloc(wpk.byte_len() as u64);
        let rq = RqBuf::create(&mut sim_q, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out = sim_q.alloc((m * n) as u64);
        let r2 = matmul_bitserial(&mut sim_q, m, k, n, 2, a_addr, &wpk, w_addr, &rq, out, true, idx);

        let mut sim_a = Sim::new(MachineConfig::ara(4));
        sim_a.set_mode(crate::sim::SimMode::TimingOnly);
        let a8 = sim_a.alloc((m * k) as u64);
        let w8 = sim_a.alloc((k * n) as u64);
        let rq8 = RqBuf::create(&mut sim_a, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let out8 = sim_a.alloc((m * n) as u64);
        let r8 = matmul_int8(&mut sim_a, m, k, n, a8, w8, &rq8, out8);

        let speedup = r8.cycles as f64 / r2.cycles as f64;
        assert!(speedup > 1.5, "Int2+vbitpack vs Int8 speedup {speedup:.2} too small");
    }
}
