//! Direct convolution kernels, NHWC, three precisions:
//!
//! * [`conv2d_bitserial`] — sub-byte weights × sub-byte activations via
//!   AND + `vpopcnt` + `vshacc` over bit planes (paper Eq. 1). Quark only.
//!   Activations are packed per im2col patch (with `vbitpack` or the pure-RVV
//!   fallback — the Fig. 3 ablation), weights are packed offline
//!   ([`crate::quant::pack_weight_planes`]).
//! * [`conv2d_int8`] — the Ara baseline: u8 activations × i8 weights with
//!   SEW=32 `vmacc.vx` accumulation (also runs on Quark — it is integer).
//! * [`conv2d_f32`] — the FP32 baseline (Ara only; Quark traps on vector FP).
//!
//! All three share the same structure: per output pixel, gather the zero-
//! padded patch into a scratch row (the im2col copy the paper's runtime
//! performs), then reduce against the weight matrix vectorized over output
//! channels, then re-quantize on the scalar FPU ([`super::requantize`]).
//!
//! ## Bit-serial schedule (§Perf-tuned)
//!
//! Channel blocks are `wpk.block = 64·LMUL` wide (LMUL ∈ {1,2,4} picked by
//! [`bitserial_block`] from `c_out`) — wider blocks amortize the per-block
//! zero/combine/store overhead that dominates small (1×1) convs. Weight
//! vectors stay *resident* in v0–v11 across the whole pixel loop when
//! `planes × K-words × LMUL ≤ 12` registers (always true for the 1×1
//! projection shortcuts and 1-bit 3×3 layers); otherwise they stream through
//! v0–v7 in grouped `vle64` chunks. Broadcast activation words use offset
//! addressing off per-plane base registers. Register map (phase 2):
//!
//! ```text
//! v0–v11   weight vectors (resident or streaming chunks)
//! v12–v15  AND/popcount temporary (LMUL regs)
//! v16+4i   plane-pair accumulators acc[p·pw+q] (LMUL regs each)
//! ```

use crate::isa::instr::{MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use crate::isa::reg::{abi, FReg, VReg};
use crate::isa::vtype::{Lmul, Sew};
use crate::quant::PackedWeights;
use crate::sim::Sim;

use super::bitpack::{emit_pack_planes, emit_row_sum_u8, PackedBuf};
use super::requantize::{
    emit_asum_preload, emit_requant_channel_block, emit_requant_setup, RqBuf,
};
use super::{Conv2dParams, KernelRun};

/// Pixels processed per requant block (accumulators buffered in memory).
const PIXEL_BLOCK: usize = 8;

/// Weight registers available for residency / streaming chunks.
const W_REGS: usize = 12;

/// Channel-vector width for the bit-serial kernel on a machine: 64·LMUL with
/// LMUL ∈ {1,2,4}, sized to cover `c_out` in as few blocks as possible.
pub fn bitserial_block(vlen_bits: usize, c_out: usize) -> usize {
    let base = vlen_bits / 64;
    for lmul in [1usize, 2, 4] {
        if base * lmul >= c_out {
            return base * lmul;
        }
    }
    base * 4
}

fn lmul_of(factor: usize) -> Lmul {
    match factor {
        1 => Lmul::M1,
        2 => Lmul::M2,
        4 => Lmul::M4,
        8 => Lmul::M8,
        _ => panic!("unsupported LMUL {factor}"),
    }
}

/// Copy the zero-padded im2col patch for output pixel `(oy, ox)` from the
/// NHWC feature map at `fm` (element size `esz` bytes) into `patch`
/// (`k·esz` bytes). Interior pixels copy `kh` contiguous row segments;
/// edge pixels zero the out-of-bounds parts first.
fn emit_im2col_patch(
    sim: &mut Sim,
    p: &Conv2dParams,
    fm: u64,
    esz: usize,
    oy: usize,
    ox: usize,
    patch: u64,
) {
    let eew = match esz {
        1 => Sew::E8,
        4 => Sew::E32,
        _ => panic!("unsupported element size"),
    };
    let row_len = p.kw * p.c_in; // elements per kernel row
    let full_edge = p.valid_taps(oy, ox).len() != p.kh * p.kw;
    if full_edge {
        // Zero the whole patch, then overwrite the valid spans.
        let k = p.k();
        let per_reg = sim.cfg.vlen_bits / (8 * esz);
        let mut off = 0usize;
        while off < k {
            let chunk = (k - off).min(per_reg * 8);
            sim.vsetvli(chunk as u64, eew, lmul_for(chunk, per_reg));
            sim.v(VOp::MvVI { vd: VReg(0), imm: 0 });
            sim.li_addr(abi::A1, patch + (off * esz) as u64);
            sim.v(VOp::Store { kind: VMemKind::UnitStride, eew, vs3: VReg(0), base: abi::A1 });
            off += chunk;
        }
    }
    for dy in 0..p.kh {
        let iy = (oy * p.stride + dy) as isize - p.pad as isize;
        if iy < 0 || iy >= p.h as isize {
            continue;
        }
        // Valid dx range for this row.
        let mut dx0 = 0usize;
        while dx0 < p.kw && (ox * p.stride + dx0) as isize - (p.pad as isize) < 0 {
            dx0 += 1;
        }
        let mut dx1 = p.kw;
        while dx1 > dx0 && (ox * p.stride + dx1 - 1) as isize - (p.pad as isize) >= p.w as isize {
            dx1 -= 1;
        }
        if dx1 <= dx0 {
            continue;
        }
        let ix0 = (ox * p.stride + dx0) - p.pad;
        let span = (dx1 - dx0) * p.c_in; // contiguous elements in NHWC
        let src = fm + (((iy as usize) * p.w + ix0) * p.c_in * esz) as u64;
        let dst = patch + ((dy * row_len + dx0 * p.c_in) * esz) as u64;
        let per_reg = sim.cfg.vlen_bits / (8 * esz);
        let mut off = 0usize;
        while off < span {
            let chunk = (span - off).min(per_reg * 8);
            sim.vsetvli(chunk as u64, eew, lmul_for(chunk, per_reg));
            sim.li_addr(abi::A0, src + (off * esz) as u64);
            sim.v(VOp::Load { kind: VMemKind::UnitStride, eew, vd: VReg(0), base: abi::A0 });
            sim.li_addr(abi::A1, dst + (off * esz) as u64);
            sim.v(VOp::Store { kind: VMemKind::UnitStride, eew, vs3: VReg(0), base: abi::A1 });
            off += chunk;
        }
    }
    sim.loop_edge(abi::T4);
}

fn lmul_for(elems: usize, per_reg: usize) -> Lmul {
    match elems.div_ceil(per_reg) {
        0 | 1 => Lmul::M1,
        2 => Lmul::M2,
        3 | 4 => Lmul::M4,
        _ => Lmul::M8,
    }
}

/// Bit-serial sub-byte convolution (Quark). `abits` = activation precision,
/// weight precision comes from `wpk`. Both must be ≤ 2 (the paper's range;
/// the accumulator-register schedule holds pa·pw ≤ 4 plane pairs).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bitserial(
    sim: &mut Sim,
    p: &Conv2dParams,
    abits: u8,
    fm_in: u64,
    wpk: &PackedWeights,
    wbuf: u64,
    rq: &RqBuf,
    fm_out: u64,
    residual: Option<u64>,
    use_vbitpack: bool,
    idx_vec: u64,
) -> KernelRun {
    conv2d_bitserial_ext(
        sim, p, abits, fm_in, wpk, wbuf, rq, fm_out, residual, use_vbitpack, idx_vec, None,
    )
}

/// [`conv2d_bitserial`] with an optional accumulator dump: when `acc_dump` is
/// `Some(addr)`, every output's integer ACC (Eq. 1 result, pre-requant) is
/// written as an i64 at `addr + (pixel·c_out_padded + channel)·8`, where
/// `c_out_padded = ceil(c_out/block)·block`. The coordinator's golden-model
/// cross-check reads these for integer-exact comparison against the AOT JAX
/// kernel.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bitserial_ext(
    sim: &mut Sim,
    p: &Conv2dParams,
    abits: u8,
    fm_in: u64,
    wpk: &PackedWeights,
    wbuf: u64,
    rq: &RqBuf,
    fm_out: u64,
    residual: Option<u64>,
    use_vbitpack: bool,
    idx_vec: u64,
    acc_dump: Option<u64>,
) -> KernelRun {
    assert!(sim.cfg.has_quark_isa, "bit-serial conv requires the Quark ISA");
    assert!(abits <= 2 && wpk.bits <= 2, "plane-pair schedule supports ≤2-bit");
    assert_eq!(wpk.k, p.k(), "packed weights must match conv K");
    let k = p.k();
    let kw_words = wpk.kw();
    let nb = wpk.block; // output-channel vector length (SEW=64 × LMUL)
    let lmul = nb / (sim.cfg.vlen_bits / 64);
    assert!(
        matches!(lmul, 1 | 2 | 4),
        "channel block {nb} must be 1/2/4 vregs at SEW=64 (VLEN {})",
        sim.cfg.vlen_bits
    );
    let vl_lmul = lmul_of(lmul);
    let pa = abits as usize;
    let pw = wpk.bits as usize;
    let (oh, ow) = (p.out_h(), p.out_w());
    let c0 = sim.cycles();

    // Weight residency: all pw×Kw channel-vectors in v0..v11, loaded once per
    // channel block; otherwise stream chunks of `chunk_kw` words per plane
    // through v0..v7.
    let resident = pw * kw_words * lmul <= W_REGS;
    let chunk_kw = (8 / lmul).min(kw_words.max(1));
    let w_reg = |q: usize, kw_i: usize| -> VReg {
        if resident {
            VReg((lmul * (q * kw_words + kw_i)) as u8)
        } else {
            VReg((lmul * (kw_i % chunk_kw)) as u8)
        }
    };
    let tmp = VReg(12);
    let acc_reg = |pq: usize| VReg(16 + 4 * pq as u8);

    // Scratch: patch rows, packed patches, row sums, accumulators, consts.
    let patch = sim.alloc((PIXEL_BLOCK * k) as u64);
    let packed: Vec<PackedBuf> =
        (0..PIXEL_BLOCK).map(|_| PackedBuf::alloc(sim, k, abits)).collect();
    let asumbuf = sim.alloc((PIXEL_BLOCK * 4) as u64);
    let accbuf = sim.alloc((PIXEL_BLOCK * nb * 8) as u64);
    let consts = sim.alloc(16);
    emit_requant_setup(sim, rq, consts);

    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();
    let c_out_padded = wpk.blocks() * nb;
    // Where pixel t of the current block stores its ACC vector for channel
    // block jb: the rotating scratch buffer, or the caller's dump region.
    let acc_addr = |blk: &[(usize, usize)], t: usize, jb: usize| -> u64 {
        match acc_dump {
            Some(dump) => {
                let (oy, ox) = blk[t];
                dump + (((oy * ow + ox) * c_out_padded + jb * nb) * 8) as u64
            }
            None => accbuf + (t * nb * 8) as u64,
        }
    };

    for blk in pixels.chunks(PIXEL_BLOCK) {
        // Phase 1: im2col + pack + row-sum for each pixel of the block.
        for (t, &(oy, ox)) in blk.iter().enumerate() {
            let patch_t = patch + (t * k) as u64;
            emit_im2col_patch(sim, p, fm_in, 1, oy, ox, patch_t);
            emit_pack_planes(sim, patch_t, &packed[t], use_vbitpack, idx_vec);
            emit_row_sum_u8(sim, patch_t, k, asumbuf + (t * 4) as u64);
        }
        // ASUMs → f32 registers, reused across all channel blocks.
        emit_asum_preload(sim, blk.len(), |t| asumbuf + (t * 4) as u64);

        // Phase 2: per channel block, per pixel: ACC via Eq. 1.
        for jb in 0..wpk.blocks() {
            sim.vsetvli(nb as u64, Sew::E64, vl_lmul);
            if resident {
                // Load all weight vectors for this channel block once.
                for q in 0..pw {
                    for kw_i in 0..kw_words {
                        sim.li_addr(abi::A0, wbuf + wpk.vec_byte_offset(jb, q, kw_i));
                        sim.v(VOp::Load {
                            kind: VMemKind::UnitStride,
                            eew: Sew::E64,
                            vd: w_reg(q, kw_i),
                            base: abi::A0,
                        });
                    }
                }
            }
            for t in 0..blk.len() {
                // acc_pq := 0
                for i in 0..(pa * pw) {
                    sim.v(VOp::MvVI { vd: acc_reg(i), imm: 0 });
                }
                // Per-plane base registers for offset-addressed a-word loads.
                let abase = [abi::S2, abi::S3];
                for (pl, &reg) in abase.iter().enumerate().take(pa) {
                    sim.li_addr(reg, packed[t].plane_addr(pl));
                }
                for q in 0..pw {
                    let mut kw_i = 0;
                    while kw_i < kw_words {
                        if !resident && kw_i % chunk_kw == 0 {
                            // Stream the next chunk of weight vectors with one
                            // grouped load (contiguous kw range per plane).
                            let words = chunk_kw.min(kw_words - kw_i);
                            sim.vsetvli((words * nb) as u64, Sew::E64, lmul_for(words * nb, sim.cfg.vlen_bits / 64));
                            sim.li_addr(abi::A0, wbuf + wpk.vec_byte_offset(jb, q, kw_i));
                            sim.v(VOp::Load {
                                kind: VMemKind::UnitStride,
                                eew: Sew::E64,
                                vd: VReg(0),
                                base: abi::A0,
                            });
                            sim.vsetvli(nb as u64, Sew::E64, vl_lmul);
                        }
                        for pl in 0..pa {
                            // Broadcast activation word (p, kw) of pixel t.
                            sim.s(ScalarOp::Load {
                                width: MemWidth::D,
                                signed: false,
                                rd: abi::T1,
                                base: abase[pl],
                                offset: (kw_i * 8) as i64,
                            });
                            // AND + per-element popcount + accumulate.
                            sim.v(VOp::IVX {
                                op: VIOp::And,
                                vd: tmp,
                                vs2: w_reg(q, kw_i),
                                rs1: abi::T1,
                            });
                            sim.v(VOp::Popcnt { vd: tmp, vs2: tmp });
                            let acc = acc_reg(pl * pw + q);
                            sim.v(VOp::IVV { op: VIOp::Add, vd: acc, vs2: acc, vs1: tmp });
                        }
                        kw_i += 1;
                    }
                    sim.loop_edge(abi::T2);
                }
                // Combine plane pairs: ACC = Σ 2^(p+q)·acc_pq via vshacc
                // (the fused shift-accumulate the paper adds).
                let acc_final = match (pa, pw) {
                    (1, 1) => acc_reg(0),
                    (1, 2) | (2, 1) => {
                        // ACC = 2·acc_hi + acc_lo.
                        let (hi, lo) = (acc_reg(1), acc_reg(0));
                        sim.v(VOp::Shacc { vd: hi, vs2: lo, shamt: 1 });
                        hi
                    }
                    (2, 2) => {
                        // acc[p·2+q]: 0=00, 1=01, 2=10, 3=11.
                        // ACC = 4·a11 + 2·(a01 + a10) + a00.
                        let (a00, a01, a10, a11) = (acc_reg(0), acc_reg(1), acc_reg(2), acc_reg(3));
                        sim.v(VOp::IVV { op: VIOp::Add, vd: a01, vs2: a01, vs1: a10 });
                        sim.v(VOp::Shacc { vd: a11, vs2: a01, shamt: 1 });
                        sim.v(VOp::Shacc { vd: a11, vs2: a00, shamt: 1 });
                        a11
                    }
                    _ => unreachable!(),
                };
                sim.li_addr(abi::A1, acc_addr(blk, t, jb));
                sim.v(VOp::Store {
                    kind: VMemKind::UnitStride,
                    eew: Sew::E64,
                    vs3: acc_final,
                    base: abi::A1,
                });
            }
            // Phase 3: re-quantize this channel block on the scalar FPU.
            let n_here = nb.min(p.c_out - jb * nb);
            let blk_coords: Vec<(usize, usize)> = blk.to_vec();
            let c_out = p.c_out;
            for j in 0..n_here {
                let ch = jb * nb + j;
                emit_requant_channel_block(
                    sim,
                    rq,
                    ch,
                    blk.len(),
                    |t| acc_addr(blk, t, jb) + (j * 8) as u64,
                    true,
                    residual
                        .map(|r| {
                            let bc = blk_coords.clone();
                            move |t: usize| {
                                let (oy, ox) = bc[t];
                                r + ((oy * ow + ox) * c_out + ch) as u64
                            }
                        })
                        .as_ref()
                        .map(|f| f as &dyn Fn(usize) -> u64),
                    |t| {
                        let (oy, ox) = blk_coords[t];
                        fm_out + ((oy * ow + ox) * c_out + ch) as u64
                    },
                );
            }
        }
    }

    let macs = p.macs();
    sim.stats_mut().effective_macs += macs;
    KernelRun { cycles: sim.cycles() - c0, macs }
}

/// Int8 convolution (the Ara baseline; integer-only, so Quark runs it too).
/// u8 activation codes × i8 weights, SEW=32 accumulation.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int8(
    sim: &mut Sim,
    p: &Conv2dParams,
    fm_in: u64,
    wbuf: u64, // i8 weights, [K][N] row-major
    rq: &RqBuf,
    fm_out: u64,
    residual: Option<u64>,
) -> KernelRun {
    let k = p.k();
    let nb = p.c_out.min(sim.cfg.vlen_bits / 32);
    let blocks = p.c_out.div_ceil(nb);
    let (oh, ow) = (p.out_h(), p.out_w());
    let c0 = sim.cycles();

    let patch = sim.alloc((PIXEL_BLOCK * k) as u64);
    let accbuf = sim.alloc((PIXEL_BLOCK * nb * 4) as u64);
    let consts = sim.alloc(16);
    emit_requant_setup(sim, rq, consts);

    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();

    for blk in pixels.chunks(PIXEL_BLOCK) {
        for (t, &(oy, ox)) in blk.iter().enumerate() {
            emit_im2col_patch(sim, p, fm_in, 1, oy, ox, patch + (t * k) as u64);
        }
        for jb in 0..blocks {
            let n_here = nb.min(p.c_out - jb * nb);
            sim.vsetvli(n_here as u64, Sew::E32, Lmul::M1);
            // acc_t := 0 (v16 + t)
            for t in 0..blk.len() {
                sim.v(VOp::MvVI { vd: VReg(16 + t as u8), imm: 0 });
            }
            for kk in 0..k {
                // Load + widen one weight row for this channel block.
                sim.li_addr(abi::A0, wbuf + (kk * p.c_out + jb * nb) as u64);
                sim.v(VOp::Load {
                    kind: VMemKind::UnitStride,
                    eew: Sew::E8,
                    vd: VReg(8),
                    base: abi::A0,
                });
                sim.v(VOp::Sext { vd: VReg(9), vs2: VReg(8), frac: 4 });
                for t in 0..blk.len() {
                    sim.li_addr(abi::T0, patch + (t * k + kk) as u64);
                    sim.s(ScalarOp::Load {
                        width: MemWidth::B,
                        signed: false,
                        rd: abi::T1,
                        base: abi::T0,
                        offset: 0,
                    });
                    sim.v(VOp::MaccVX { vd: VReg(16 + t as u8), rs1: abi::T1, vs2: VReg(9) });
                }
                sim.loop_edge(abi::T2);
            }
            for t in 0..blk.len() {
                sim.li_addr(abi::A1, accbuf + (t * nb * 4) as u64);
                sim.v(VOp::Store {
                    kind: VMemKind::UnitStride,
                    eew: Sew::E32,
                    vs3: VReg(16 + t as u8),
                    base: abi::A1,
                });
            }
            let blk_coords: Vec<(usize, usize)> = blk.to_vec();
            let c_out = p.c_out;
            for j in 0..n_here {
                let ch = jb * nb + j;
                emit_requant_channel_block(
                    sim,
                    rq,
                    ch,
                    blk.len(),
                    |t| accbuf + ((t * nb + j) * 4) as u64,
                    false,
                    residual
                        .map(|r| {
                            let bc = blk_coords.clone();
                            move |t: usize| {
                                let (oy, ox) = bc[t];
                                r + ((oy * ow + ox) * c_out + ch) as u64
                            }
                        })
                        .as_ref()
                        .map(|f| f as &dyn Fn(usize) -> u64),
                    |t| {
                        let (oy, ox) = blk_coords[t];
                        fm_out + ((oy * ow + ox) * c_out + ch) as u64
                    },
                );
            }
        }
    }

    let macs = p.macs();
    sim.stats_mut().effective_macs += macs;
    KernelRun { cycles: sim.cycles() - c0, macs }
}

/// FP32 convolution (Ara only): f32 NHWC activations × f32 `[K][N]` weights,
/// `vfmacc.vf` accumulation, optional fused bias + ReLU, f32 output.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32(
    sim: &mut Sim,
    p: &Conv2dParams,
    fm_in: u64,
    wbuf: u64,
    bias: u64, // f32[c_out]
    fm_out: u64,
    relu: bool,
    residual: Option<u64>, // f32 NHWC map added before ReLU
) -> KernelRun {
    assert!(sim.cfg.has_vfpu, "fp32 conv requires the vector FPU (Ara)");
    let k = p.k();
    let nb = p.c_out.min(sim.cfg.vlen_bits / 32);
    let blocks = p.c_out.div_ceil(nb);
    let (oh, ow) = (p.out_h(), p.out_w());
    let c0 = sim.cycles();

    let patch = sim.alloc((PIXEL_BLOCK * k * 4) as u64);
    let fzero_addr = sim.alloc(4);
    sim.write_f32s(fzero_addr, &[0.0]);
    sim.li_addr(abi::T6, fzero_addr);
    sim.s(ScalarOp::FLoad { rd: FReg(6), base: abi::T6, offset: 0 });

    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();

    for blk in pixels.chunks(PIXEL_BLOCK) {
        for (t, &(oy, ox)) in blk.iter().enumerate() {
            emit_im2col_patch(sim, p, fm_in, 4, oy, ox, patch + (t * k * 4) as u64);
        }
        for jb in 0..blocks {
            let n_here = nb.min(p.c_out - jb * nb);
            sim.vsetvli(n_here as u64, Sew::E32, Lmul::M1);
            for t in 0..blk.len() {
                sim.v(VOp::MvVI { vd: VReg(16 + t as u8), imm: 0 });
            }
            for kk in 0..k {
                sim.li_addr(abi::A0, wbuf + ((kk * p.c_out + jb * nb) * 4) as u64);
                sim.v(VOp::Load {
                    kind: VMemKind::UnitStride,
                    eew: Sew::E32,
                    vd: VReg(9),
                    base: abi::A0,
                });
                for t in 0..blk.len() {
                    sim.li_addr(abi::T0, patch + ((t * k + kk) * 4) as u64);
                    sim.s(ScalarOp::FLoad { rd: FReg(1), base: abi::T0, offset: 0 });
                    sim.v(VOp::FMaccVF { vd: VReg(16 + t as u8), rs1: FReg(1), vs2: VReg(9) });
                }
                sim.loop_edge(abi::T2);
            }
            // Bias + residual + ReLU + store.
            sim.li_addr(abi::A0, bias + (jb * nb * 4) as u64);
            sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E32, vd: VReg(10), base: abi::A0 });
            for (t, &(oy, ox)) in blk.iter().enumerate() {
                let acc = VReg(16 + t as u8);
                sim.v(VOp::FAddVV { vd: acc, vs2: acc, vs1: VReg(10) });
                if let Some(r) = residual {
                    sim.li_addr(abi::A2, r + (((oy * ow + ox) * p.c_out + jb * nb) * 4) as u64);
                    sim.v(VOp::Load {
                        kind: VMemKind::UnitStride,
                        eew: Sew::E32,
                        vd: VReg(11),
                        base: abi::A2,
                    });
                    sim.v(VOp::FAddVV { vd: acc, vs2: acc, vs1: VReg(11) });
                }
                if relu {
                    sim.v(VOp::FMaxVF { vd: acc, vs2: acc, rs1: FReg(6) });
                }
                sim.li_addr(abi::A1, fm_out + (((oy * ow + ox) * p.c_out + jb * nb) * 4) as u64);
                sim.v(VOp::Store { kind: VMemKind::UnitStride, eew: Sew::E32, vs3: acc, base: abi::A1 });
            }
        }
    }

    let macs = p.macs();
    sim.stats_mut().effective_macs += macs;
    KernelRun { cycles: sim.cycles() - c0, macs }
}
