//! Re-scaling on the CVA6 scalar FPU (paper Fig. 2, §III).
//!
//! Quark removed the *vector* FPU; the price is that the per-layer re-scale
//! (the only FP step of quantized inference) runs on the scalar core. This
//! module emits that scalar code. The instruction sequence mirrors
//! [`crate::quant::requantize_golden`] operation-for-operation so the
//! simulated result is bit-identical to the host oracle.
//!
//! Because CVA6 is a 1-IPC core, this loop is the scalar-side budget of
//! every quantized kernel; at 1-bit precision it is the bottleneck (the
//! vector side finishes first), so the emission is tuned (§Perf):
//! per-channel constants hoisted out of the pixel loop, per-pixel ASUM
//! converted to f32 once per pixel *block* (`emit_asum_preload`), and
//! offset addressing instead of per-access `li` materialization.
//!
//! Layout of the per-channel parameter block (written by the host / model
//! setup, read by the emitted code): three f32 arrays of length `n`:
//! `alpha[n] | beta[n] | bias[n]`, starting at `rq_addr`.

use crate::isa::instr::{MemWidth, FAluOp, ScalarOp};
use crate::isa::reg::{abi, FReg};
use crate::sim::Sim;

/// Addresses of the per-channel requant parameter arrays in simulated memory.
#[derive(Clone, Copy, Debug)]
pub struct RqBuf {
    pub addr: u64,
    pub n: usize,
    /// Output grid max (2ⁿ−1) as f32.
    pub qmax: f32,
    /// Residual multiplier (0.0 = no skip connection).
    pub res_scale: f32,
}

impl RqBuf {
    pub fn alpha_addr(&self, j: usize) -> u64 {
        self.addr + (j * 4) as u64
    }
    pub fn beta_addr(&self, j: usize) -> u64 {
        self.addr + ((self.n + j) * 4) as u64
    }
    pub fn bias_addr(&self, j: usize) -> u64 {
        self.addr + ((2 * self.n + j) * 4) as u64
    }
    pub fn byte_len(n: usize) -> u64 {
        (3 * n * 4) as u64
    }

    /// Allocate and fill a parameter block from host-side per-channel values.
    pub fn create(
        sim: &mut Sim,
        alpha: &[f32],
        beta: &[f32],
        bias: &[f32],
        qmax: f32,
        res_scale: f32,
    ) -> RqBuf {
        let n = alpha.len();
        assert_eq!(beta.len(), n);
        assert_eq!(bias.len(), n);
        let addr = sim.alloc(Self::byte_len(n));
        sim.write_f32s(addr, alpha);
        sim.write_f32s(addr + (n * 4) as u64, beta);
        sim.write_f32s(addr + (2 * n * 4) as u64, bias);
        RqBuf { addr, n, qmax, res_scale }
    }
}

// Fixed scalar/fp register roles for the requant sequences.
const F_ALPHA: FReg = FReg(1);
const F_BETA: FReg = FReg(2);
const F_BIAS: FReg = FReg(3);
const F_ZERO: FReg = FReg(6);
const F_QMAX: FReg = FReg(7);
const F_RESS: FReg = FReg(10);
/// f16..f23 hold the pixel block's ASUMs as f32 (preloaded once per block).
const F_ASUM_BASE: u8 = 16;
/// Maximum pixels per preloaded block (f16..f23).
pub const MAX_ASUM_PIXELS: usize = 8;

/// Emit the per-kernel constant setup (zero, qmax, residual scale). Call once
/// before a batch of `emit_requant_channel_block` calls.
pub fn emit_requant_setup(sim: &mut Sim, rq: &RqBuf, consts_addr: u64) {
    // consts_addr: f32 slots the host fills with [0.0, qmax, res_scale].
    sim.write_f32s(consts_addr, &[0.0, rq.qmax, rq.res_scale]);
    sim.li_addr(abi::T6, consts_addr);
    sim.s(ScalarOp::FLoad { rd: F_ZERO, base: abi::T6, offset: 0 });
    sim.s(ScalarOp::FLoad { rd: F_QMAX, base: abi::T6, offset: 4 });
    sim.s(ScalarOp::FLoad { rd: F_RESS, base: abi::T6, offset: 8 });
}

/// Preload a pixel block's ASUM values (i32 at `asum_addr(t)`) into the f16+
/// registers as f32. Call once per pixel block, before the per-channel loops
/// of *all* channel blocks (the values are reused `c_out` times).
pub fn emit_asum_preload(sim: &mut Sim, px: usize, asum_addr: impl Fn(usize) -> u64) {
    assert!(px <= MAX_ASUM_PIXELS);
    for t in 0..px {
        sim.li_addr(abi::T0, asum_addr(t));
        sim.s(ScalarOp::Load { width: MemWidth::W, signed: true, rd: abi::T1, base: abi::T0, offset: 0 });
        sim.s(ScalarOp::FCvtSW { rd: FReg(F_ASUM_BASE + t as u8), rs1: abi::T1 });
    }
}

/// Software-pipelining width of the requant loop: 4 pixels in flight with
/// disjoint register sets, so FPnew's 2–4-cycle latencies hide behind the
/// interleaved issue stream (CVA6 is in-order single-issue — dependent
/// back-to-back FP ops stall, interleaved ones do not).
const UNROLL: usize = 4;
// Per-slot register sets.
const F_ACC_SLOT: [FReg; UNROLL] = [FReg(24), FReg(25), FReg(26), FReg(27)];
const F_T_SLOT: [FReg; UNROLL] = [FReg(28), FReg(29), FReg(30), FReg(31)];
const F_RES_SLOT: [FReg; UNROLL] = [FReg(9), FReg(11), FReg(12), FReg(13)];
const X_SLOT: [(crate::isa::Reg, crate::isa::Reg); UNROLL] =
    [(abi::T0, abi::T1), (abi::A2, abi::A3), (abi::A4, abi::A5), (abi::A6, abi::A7)];

/// Requantize a block of `px` pixels for channel `j`.
///
/// * `acc_addr(t)`  — address of pixel `t`'s i32 accumulator for channel `j`
///   (stored as the low word of the SEW=64 accumulator, little-endian).
/// * `use_asum`     — apply the β·ASUM correction with the preloaded f16+t
///   registers (call [`emit_asum_preload`] first).
/// * `res_addr(t)`  — residual input code (u8) for pixel `t`, channel `j`.
/// * `out_addr(t)`  — destination u8 code.
#[allow(clippy::too_many_arguments)]
pub fn emit_requant_channel_block(
    sim: &mut Sim,
    rq: &RqBuf,
    j: usize,
    px: usize,
    acc_addr: impl Fn(usize) -> u64,
    use_asum: bool,
    res_addr: Option<&dyn Fn(usize) -> u64>,
    out_addr: impl Fn(usize) -> u64,
) {
    // Per-channel constants (hoisted out of the pixel loop).
    sim.li_addr(abi::T5, rq.alpha_addr(j));
    sim.s(ScalarOp::FLoad { rd: F_ALPHA, base: abi::T5, offset: 0 });
    sim.s(ScalarOp::FLoad { rd: F_BETA, base: abi::T5, offset: (rq.n * 4) as i64 });
    sim.s(ScalarOp::FLoad { rd: F_BIAS, base: abi::T5, offset: (2 * rq.n * 4) as i64 });
    let mut t0 = 0usize;
    while t0 < px {
        let lanes = UNROLL.min(px - t0);
        let ts: Vec<usize> = (t0..t0 + lanes).collect();
        // Stage 1: accumulator loads + convert (interleaved across slots).
        for (s, &t) in ts.iter().enumerate() {
            let (xa, xd) = X_SLOT[s];
            sim.li_addr(xa, acc_addr(t));
            sim.s(ScalarOp::Load { width: MemWidth::W, signed: true, rd: xd, base: xa, offset: 0 });
        }
        for s in 0..ts.len() {
            let (_, xd) = X_SLOT[s];
            sim.s(ScalarOp::FCvtSW { rd: F_ACC_SLOT[s], rs1: xd });
        }
        // Stage 2: t = alpha·acc + bias.
        for s in 0..ts.len() {
            sim.s(ScalarOp::FMadd { rd: F_T_SLOT[s], rs1: F_ALPHA, rs2: F_ACC_SLOT[s], rs3: F_BIAS });
        }
        if use_asum {
            // t += beta·asum_t (asum preloaded per pixel block in f16+t).
            for (s, &t) in ts.iter().enumerate() {
                sim.s(ScalarOp::FMadd {
                    rd: F_T_SLOT[s],
                    rs1: F_BETA,
                    rs2: FReg(F_ASUM_BASE + t as u8),
                    rs3: F_T_SLOT[s],
                });
            }
        }
        if let Some(res) = res_addr {
            for (s, &t) in ts.iter().enumerate() {
                let (xa, xd) = X_SLOT[s];
                sim.li_addr(xa, res(t));
                sim.s(ScalarOp::Load { width: MemWidth::B, signed: false, rd: xd, base: xa, offset: 0 });
            }
            for s in 0..ts.len() {
                let (_, xd) = X_SLOT[s];
                sim.s(ScalarOp::FCvtSW { rd: F_RES_SLOT[s], rs1: xd });
            }
            for s in 0..ts.len() {
                sim.s(ScalarOp::FMadd {
                    rd: F_T_SLOT[s],
                    rs1: F_RESS,
                    rs2: F_RES_SLOT[s],
                    rs3: F_T_SLOT[s],
                });
            }
        }
        // Stage 3: clamp, round, store.
        for s in 0..ts.len() {
            sim.s(ScalarOp::FAlu { op: FAluOp::Max, rd: F_T_SLOT[s], rs1: F_T_SLOT[s], rs2: F_ZERO });
        }
        for s in 0..ts.len() {
            sim.s(ScalarOp::FAlu { op: FAluOp::Min, rd: F_T_SLOT[s], rs1: F_T_SLOT[s], rs2: F_QMAX });
        }
        for s in 0..ts.len() {
            let (_, xd) = X_SLOT[s];
            sim.s(ScalarOp::FCvtWS { rd: xd, rs1: F_T_SLOT[s] });
        }
        for (s, &t) in ts.iter().enumerate() {
            let (xa, xd) = X_SLOT[s];
            sim.li_addr(xa, out_addr(t));
            sim.s(ScalarOp::Store { width: MemWidth::B, rs2: xd, base: xa, offset: 0 });
        }
        t0 += lanes;
    }
    sim.loop_edge(abi::T3);
}

/// Host-side mirror of the emitted sequence, for direct use by golden paths.
/// Identical to [`crate::quant::requantize_golden`] but taking the RqBuf view.
#[allow(clippy::too_many_arguments)]
pub fn requant_host(
    acc: i32,
    asum: Option<i32>,
    res: Option<u8>,
    alpha: f32,
    beta: f32,
    bias: f32,
    qmax: f32,
    res_scale: f32,
) -> u8 {
    let mut t = alpha.mul_add(acc as f32, bias);
    if let Some(s) = asum {
        t = beta.mul_add(s as f32, t);
    }
    if let Some(r) = res {
        t = res_scale.mul_add(r as f32, t);
    }
    let t = t.max(0.0).min(qmax);
    t.round_ties_even() as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;

    #[test]
    fn emitted_requant_matches_host_oracle() {
        let mut sim = Sim::new(MachineConfig::quark(4));
        let n = 4;
        let alphas = [0.02f32, 0.3, -0.1, 1.5];
        let betas = [-0.01f32, 0.0, 0.25, -0.6];
        let biases = [0.5f32, -2.0, 0.0, 3.0];
        let rq = RqBuf::create(&mut sim, &alphas, &betas, &biases, 3.0, 0.5);
        let consts = sim.alloc(16);

        let px = 3;
        let acc = sim.alloc((px * 8) as u64);
        let asm = sim.alloc((px * 4) as u64);
        let res = sim.alloc(px as u64);
        let out = sim.alloc((n * px) as u64);
        let accs = [100i32, -7, 55];
        let asums = [30i32, 12, 0];
        let ress = [2u8, 0, 3];
        for t in 0..px {
            sim.write_i32s(acc + (t * 8) as u64, &[accs[t]]);
            sim.write_i32s(asm + (t * 4) as u64, &[asums[t]]);
        }
        sim.write_bytes(res, &ress);

        emit_requant_setup(&mut sim, &rq, consts);
        emit_asum_preload(&mut sim, px, |t| asm + (t * 4) as u64);
        for j in 0..n {
            let out_base = out + (j * px) as u64;
            emit_requant_channel_block(
                &mut sim,
                &rq,
                j,
                px,
                |t| acc + (t * 8) as u64,
                true,
                Some(&|t| res + t as u64),
                |t| out_base + t as u64,
            );
        }
        for j in 0..n {
            for t in 0..px {
                let got = sim.read_u8s(out + (j * px + t) as u64, 1)[0];
                let want = requant_host(
                    accs[t],
                    Some(asums[t]),
                    Some(ress[t]),
                    alphas[j],
                    betas[j],
                    biases[j],
                    3.0,
                    0.5,
                );
                assert_eq!(got, want, "j={j} t={t}");
            }
        }
        // It really ran on the scalar FPU.
        assert!(sim.stats().scalar_fpu_cycles > 0);
    }

    #[test]
    fn per_pixel_instruction_budget() {
        // §Perf regression guard: the requant loop must stay ≤ 12 scalar
        // instructions per (channel, pixel) without residual.
        let mut sim = Sim::new(MachineConfig::quark(4));
        let n = 16;
        let rq = RqBuf::create(&mut sim, &vec![1.0; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
        let consts = sim.alloc(16);
        let px = 8;
        let acc = sim.alloc((px * 8) as u64);
        let asm = sim.alloc((px * 4) as u64);
        let out = sim.alloc((n * px) as u64);
        emit_requant_setup(&mut sim, &rq, consts);
        emit_asum_preload(&mut sim, px, |t| asm + (t * 4) as u64);
        let before = sim.stats().scalar_instrs;
        for j in 0..n {
            emit_requant_channel_block(
                &mut sim,
                &rq,
                j,
                px,
                |t| acc + (t * 8) as u64,
                true,
                None,
                |t| out + (j * px + t) as u64,
            );
        }
        let per = (sim.stats().scalar_instrs - before) as f64 / (n * px) as f64;
        assert!(per <= 12.0, "requant budget regressed: {per:.1} instrs/(ch·px)");
    }
}
