//! Activation bit-plane packing — the operation `vbitpack` exists for.
//!
//! Quantized codes arrive element-per-byte from the previous layer's
//! re-quantization; the bit-serial kernels need them in bit-stream layout
//! (paper §III-A: "this data transformation should be fast to avoid making it
//! a bottleneck"). Two implementations:
//!
//! * [`emit_pack_planes`] with `use_vbitpack = true` — one `vbitpack.vi` per
//!   plane per source group, running on the slide/permute unit at full rate.
//! * `use_vbitpack = false` — the best pure-RVV 1.0 sequence we could write
//!   (the paper's "Int2 without vbitpack" ablation): extract the plane bit
//!   with shift/and, then assemble each 64-bit word via a zext → `vsll.vv`
//!   (by a constant index vector) → `vredsum` reduction and a scalar store.
//!   The per-word reduction + scalar round-trip is what eats the bit-serial
//!   advantage — reproducing Fig. 3's "w/o vbitpack ≈ Int8" result.

use crate::isa::instr::{MemWidth, ScalarOp, VIOp, VMemKind, VOp};
use crate::isa::reg::{abi, VReg};
use crate::isa::vtype::{Lmul, Sew};
use crate::sim::Sim;

/// Plane-major packed buffer descriptor: plane `p` occupies
/// `kw = ceil(k/64)` u64 words at `addr + p·kw·8`.
#[derive(Clone, Copy, Debug)]
pub struct PackedBuf {
    pub addr: u64,
    pub k: usize,
    pub bits: u8,
}

impl PackedBuf {
    pub fn kw(&self) -> usize {
        self.k.div_ceil(64)
    }

    pub fn plane_addr(&self, p: usize) -> u64 {
        self.addr + (p * self.kw() * 8) as u64
    }

    pub fn word_addr(&self, p: usize, w: usize) -> u64 {
        self.plane_addr(p) + (w * 8) as u64
    }

    pub fn byte_len(k: usize, bits: u8) -> u64 {
        (k.div_ceil(64) * 8 * bits as usize) as u64
    }

    pub fn alloc(sim: &mut Sim, k: usize, bits: u8) -> PackedBuf {
        let addr = sim.alloc(Self::byte_len(k, bits));
        PackedBuf { addr, k, bits }
    }
}

fn lmul_for(elems: usize, per_reg: usize) -> Lmul {
    match elems.div_ceil(per_reg) {
        0 | 1 => Lmul::M1,
        2 => Lmul::M2,
        3 | 4 => Lmul::M4,
        _ => Lmul::M8,
    }
}

/// Write the constant `[0, 1, …, 63]` u64 index vector the RVV fallback needs
/// for its `vsll.vv`; call once per simulation, pass the address around.
pub fn setup_index_vector(sim: &mut Sim) -> u64 {
    let addr = sim.alloc(64 * 8);
    let idx: Vec<u64> = (0..64u64).collect();
    sim.write_u64s(addr, &idx);
    addr
}

/// Pack `k` unsigned codes (u8, one per byte) at `src` into `bits` planes at
/// `dst` (layout per [`PackedBuf`]). Tensors larger than VLEN (one `vbitpack`
/// plane must fit a register) are packed in VLEN-bit chunks — each chunk
/// lands at its word offset inside every plane.
pub fn emit_pack_planes(
    sim: &mut Sim,
    src: u64,
    dst: &PackedBuf,
    use_vbitpack: bool,
    idx_vec_addr: u64,
) {
    let vlen = sim.cfg.vlen_bits;
    if dst.k > vlen {
        let full_kw = dst.kw();
        let mut off = 0usize;
        while off < dst.k {
            let chunk = (dst.k - off).min(vlen);
            debug_assert_eq!(off % 64, 0);
            // A chunk-sized view whose plane stride is the *full* buffer's:
            // pack into a temp descriptor, then the word addressing below
            // needs the real stride, so offset per plane manually.
            emit_pack_planes_chunk(sim, src + off as u64, dst, off / 64, chunk, full_kw, use_vbitpack, idx_vec_addr);
            off += chunk;
        }
        return;
    }
    emit_pack_planes_chunk(sim, src, dst, 0, dst.k, dst.kw(), use_vbitpack, idx_vec_addr);
}

/// Pack one ≤VLEN chunk of `k_chunk` codes at `src` into every plane of
/// `dst`, starting at word offset `word_off` (plane stride `full_kw` words).
#[allow(clippy::too_many_arguments)]
fn emit_pack_planes_chunk(
    sim: &mut Sim,
    src: u64,
    dst: &PackedBuf,
    word_off: usize,
    k_chunk: usize,
    full_kw: usize,
    use_vbitpack: bool,
    idx_vec_addr: u64,
) {
    let k = k_chunk;
    let bits = dst.bits;
    let kw = k.div_ceil(64);
    let plane_addr =
        |p: usize| dst.addr + ((p * full_kw + word_off) * 8) as u64;
    let word_addr = |p: usize, w: usize| plane_addr(p) + (w * 8) as u64;
    assert!(k <= sim.cfg.vlen_bits, "plane chunk of {k} bits must fit VLEN");
    assert!(bits <= 8);

    if use_vbitpack {
        // Zero the low kw words of each destination register so the tail of a
        // non-multiple-of-64 plane stays clean after the register-wide shift.
        if k % 64 != 0 {
            sim.vsetvli(kw as u64, Sew::E64, Lmul::M1);
            for p in 0..bits {
                sim.v(VOp::MvVI { vd: VReg(8 + p), imm: 0 });
            }
        }
        // Load the source group (SEW=8).
        let vreg_elems = sim.cfg.vlen_bits / 8;
        sim.vsetvli(k as u64, Sew::E8, lmul_for(k, vreg_elems));
        sim.li_addr(abi::A0, src);
        sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E8, vd: VReg(0), base: abi::A0 });
        // One vbitpack per plane: vd = (vd << vl) | plane(vs2, p).
        for p in 0..bits {
            sim.v(VOp::Bitpack { vd: VReg(8 + p), vs2: VReg(0), bit: p });
        }
        // Store each plane (kw words).
        sim.vsetvli(kw as u64, Sew::E64, Lmul::M1);
        for p in 0..bits {
            sim.li_addr(abi::A1, plane_addr(p as usize));
            sim.v(VOp::Store {
                kind: VMemKind::UnitStride,
                eew: Sew::E64,
                vs3: VReg(8 + p),
                base: abi::A1,
            });
        }
    } else {
        // Pure-RVV fallback. Scratch buffer for the extracted 0/1 bytes.
        let scratch = sim.alloc(k.next_multiple_of(64) as u64);
        // Index vector for vsll.vv, loaded once per call.
        sim.vsetvli(64, Sew::E64, Lmul::M1);
        sim.li_addr(abi::A3, idx_vec_addr);
        sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E64, vd: VReg(28), base: abi::A3 });
        let vreg_elems = sim.cfg.vlen_bits / 8;
        for p in 0..bits {
            // Extract bit p of every element: (src >> p) & 1.
            sim.vsetvli(k as u64, Sew::E8, lmul_for(k, vreg_elems));
            sim.li_addr(abi::A0, src);
            sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E8, vd: VReg(0), base: abi::A0 });
            sim.v(VOp::IVI { op: VIOp::Srl, vd: VReg(8), vs2: VReg(0), imm: p as i64 });
            sim.v(VOp::IVI { op: VIOp::And, vd: VReg(8), vs2: VReg(8), imm: 1 });
            sim.li_addr(abi::A1, scratch);
            sim.v(VOp::Store { kind: VMemKind::UnitStride, eew: Sew::E8, vs3: VReg(8), base: abi::A1 });
            // Assemble each 64-bit word: zext → shift by index → or-reduce
            // (vredsum of distinct powers of two), then a scalar store.
            for w in 0..kw {
                let elems = 64.min(k - w * 64) as u64;
                sim.vsetvli(elems, Sew::E64, Lmul::M1);
                sim.li_addr(abi::A2, scratch + (w * 64) as u64);
                sim.v(VOp::Load {
                    kind: VMemKind::UnitStride,
                    eew: Sew::E8,
                    vd: VReg(16),
                    base: abi::A2,
                });
                sim.v(VOp::Zext { vd: VReg(17), vs2: VReg(16), frac: 8 });
                sim.v(VOp::IVV { op: VIOp::Sll, vd: VReg(18), vs2: VReg(17), vs1: VReg(28) });
                sim.v(VOp::MvVI { vd: VReg(19), imm: 0 });
                sim.v(VOp::RedSum { vd: VReg(19), vs2: VReg(18), vs1: VReg(19) });
                sim.v(VOp::MvXS { rd: abi::T0, vs2: VReg(19) });
                sim.li_addr(abi::T1, word_addr(p as usize, w));
                sim.s(ScalarOp::Store { width: MemWidth::D, rs2: abi::T0, base: abi::T1, offset: 0 });
                sim.loop_edge(abi::T2);
            }
        }
    }
}

/// Emit the patch activation sum: `out[i32 at out_addr] = Σ src[0..k]`
/// (u8 codes). Used for the β·ASUM correction of the affine weight scheme.
pub fn emit_row_sum_u8(sim: &mut Sim, src: u64, k: usize, out_addr: u64) {
    let per_reg_e32 = sim.cfg.vlen_bits / 32;
    let max_chunk = per_reg_e32 * 8; // LMUL=8
    let mut remaining = k;
    let mut src_off = src;
    let mut first = true;
    while remaining > 0 {
        let chunk = remaining.min(max_chunk);
        sim.vsetvli(chunk as u64, Sew::E32, lmul_for(chunk, per_reg_e32));
        sim.li_addr(abi::A0, src_off);
        sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E8, vd: VReg(0), base: abi::A0 });
        sim.v(VOp::Zext { vd: VReg(8), vs2: VReg(0), frac: 4 });
        if first {
            sim.vsetvli(1, Sew::E32, Lmul::M1);
            sim.v(VOp::MvVI { vd: VReg(24), imm: 0 });
            sim.vsetvli(chunk as u64, Sew::E32, lmul_for(chunk, per_reg_e32));
            first = false;
        }
        sim.v(VOp::RedSum { vd: VReg(24), vs2: VReg(8), vs1: VReg(24) });
        remaining -= chunk;
        src_off += chunk as u64;
    }
    sim.v(VOp::MvXS { rd: abi::T0, vs2: VReg(24) });
    sim.li_addr(abi::T1, out_addr);
    sim.s(ScalarOp::Store { width: MemWidth::W, rs2: abi::T0, base: abi::T1, offset: 0 });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::quant::pack_bit_planes;

    fn check_pack(k: usize, bits: u8, use_vbitpack: bool) {
        let mut sim = Sim::new(MachineConfig::quark(4));
        let idx = setup_index_vector(&mut sim);
        let vals: Vec<u8> = (0..k).map(|i| ((i * 37 + 11) % (1 << bits)) as u8).collect();
        let src = sim.alloc(k as u64);
        sim.write_bytes(src, &vals);
        let dst = PackedBuf::alloc(&mut sim, k, bits);
        emit_pack_planes(&mut sim, src, &dst, use_vbitpack, idx);
        let want = pack_bit_planes(&vals, bits);
        for p in 0..bits as usize {
            for w in 0..dst.kw() {
                let got = sim.machine.mem.read_u64_le(dst.word_addr(p, w), 8);
                assert_eq!(
                    got, want[p][w],
                    "k={k} bits={bits} vbitpack={use_vbitpack} plane={p} word={w}"
                );
            }
        }
    }

    #[test]
    fn vbitpack_path_matches_golden() {
        check_pack(576, 2, true);
        check_pack(64, 1, true);
        check_pack(100, 3, true); // non-multiple-of-64 tail
        check_pack(4096, 2, true); // full VLEN
    }

    #[test]
    fn rvv_fallback_matches_golden() {
        check_pack(576, 2, false);
        check_pack(64, 1, false);
        check_pack(100, 2, false);
    }

    #[test]
    fn rvv_fallback_is_much_slower() {
        let cycles = |use_vb: bool| {
            let mut sim = Sim::new(MachineConfig::quark(4));
            let idx = setup_index_vector(&mut sim);
            let src = sim.alloc(576);
            let dst = PackedBuf::alloc(&mut sim, 576, 2);
            let c0 = sim.cycles();
            emit_pack_planes(&mut sim, src, &dst, use_vb, idx);
            sim.cycles() - c0
        };
        let fast = cycles(true);
        let slow = cycles(false);
        assert!(
            slow > 8 * fast,
            "pure-RVV packing should be ≫ slower: vbitpack={fast}, rvv={slow}"
        );
    }

    #[test]
    fn row_sum_matches() {
        let mut sim = Sim::new(MachineConfig::quark(4));
        let k = 576;
        let vals: Vec<u8> = (0..k).map(|i| (i % 4) as u8).collect();
        let src = sim.alloc(k as u64);
        sim.write_bytes(src, &vals);
        let out = sim.alloc(4);
        emit_row_sum_u8(&mut sim, src, k, out);
        let want: i32 = vals.iter().map(|&v| v as i32).sum();
        assert_eq!(sim.read_i32s(out, 1)[0], want);
    }

    #[test]
    fn row_sum_chunked_large_k() {
        let mut sim = Sim::new(MachineConfig::quark(4));
        let k = 2500; // forces multiple chunks at SEW=32
        let vals: Vec<u8> = (0..k).map(|i| (i % 7) as u8).collect();
        let src = sim.alloc(k as u64);
        sim.write_bytes(src, &vals);
        let out = sim.alloc(4);
        emit_row_sum_u8(&mut sim, src, k, out);
        let want: i32 = vals.iter().map(|&v| v as i32).sum();
        assert_eq!(sim.read_i32s(out, 1)[0], want);
    }
}
