//! Pooling kernels (integer-only, so they run on both Ara and Quark).

use crate::isa::instr::{VMemKind, VOp};
use crate::isa::reg::{abi, VReg};
use crate::isa::vtype::{Lmul, Sew};
use crate::sim::Sim;

use super::requantize::{emit_requant_channel_block, emit_requant_setup, RqBuf};
use super::KernelRun;

fn lmul_for(elems: usize, per_reg: usize) -> Lmul {
    match elems.div_ceil(per_reg) {
        0 | 1 => Lmul::M1,
        2 => Lmul::M2,
        3 | 4 => Lmul::M4,
        _ => Lmul::M8,
    }
}

/// Global average pooling over an `h×w×c` NHWC map of u8 codes, producing
/// `c` u8 codes. The division by `h·w` folds into the requant scale
/// (`rq.alpha` should be `s_in / (h·w · s_out)`).
pub fn global_avgpool_u8(
    sim: &mut Sim,
    h: usize,
    w: usize,
    c: usize,
    fm_in: u64,
    rq: &RqBuf,
    out: u64,
) -> KernelRun {
    let c0 = sim.cycles();
    let per_reg = sim.cfg.vlen_bits / 32;
    assert!(c <= per_reg * 4, "channel count must fit an LMUL=4 group at SEW=32");
    let consts = sim.alloc(16);
    emit_requant_setup(sim, rq, consts);

    // Accumulate all positions: acc (v8 group) += zext(fm[pos]).
    sim.vsetvli(c as u64, Sew::E32, lmul_for(c, per_reg));
    sim.v(VOp::MvVI { vd: VReg(8), imm: 0 });
    for pos in 0..h * w {
        sim.li_addr(abi::A0, fm_in + (pos * c) as u64);
        sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E8, vd: VReg(0), base: abi::A0 });
        sim.v(VOp::Zext { vd: VReg(4), vs2: VReg(0), frac: 4 });
        sim.v(VOp::IVV { op: crate::isa::instr::VIOp::Add, vd: VReg(8), vs2: VReg(8), vs1: VReg(4) });
        sim.loop_edge(abi::T2);
    }
    // Spill the accumulator and requantize per channel on the scalar FPU.
    let accbuf = sim.alloc((c * 4) as u64);
    sim.li_addr(abi::A1, accbuf);
    sim.v(VOp::Store { kind: VMemKind::UnitStride, eew: Sew::E32, vs3: VReg(8), base: abi::A1 });
    for j in 0..c {
        emit_requant_channel_block(
            sim,
            rq,
            j,
            1,
            |_| accbuf + (j * 4) as u64,
            false,
            None,
            |_| out + j as u64,
        );
    }
    KernelRun { cycles: sim.cycles() - c0, macs: (h * w * c) as u64 }
}

/// Global average pooling over an f32 NHWC map (Ara FP32 baseline).
pub fn global_avgpool_f32(
    sim: &mut Sim,
    h: usize,
    w: usize,
    c: usize,
    fm_in: u64,
    out: u64,
) -> KernelRun {
    assert!(sim.cfg.has_vfpu, "f32 pooling requires the vector FPU");
    let c0 = sim.cycles();
    let per_reg = sim.cfg.vlen_bits / 32;
    assert!(c <= per_reg * 4);
    let inv = sim.alloc(4);
    sim.write_f32s(inv, &[1.0 / (h * w) as f32]);
    sim.li_addr(abi::T6, inv);
    sim.s(crate::isa::instr::ScalarOp::FLoad { rd: crate::isa::FReg(1), base: abi::T6, offset: 0 });

    sim.vsetvli(c as u64, Sew::E32, lmul_for(c, per_reg));
    sim.v(VOp::MvVI { vd: VReg(8), imm: 0 });
    for pos in 0..h * w {
        sim.li_addr(abi::A0, fm_in + (pos * c * 4) as u64);
        sim.v(VOp::Load { kind: VMemKind::UnitStride, eew: Sew::E32, vd: VReg(4), base: abi::A0 });
        sim.v(VOp::FAddVV { vd: VReg(8), vs2: VReg(8), vs1: VReg(4) });
        sim.loop_edge(abi::T2);
    }
    sim.v(VOp::FMulVF { vd: VReg(8), vs2: VReg(8), rs1: crate::isa::FReg(1) });
    sim.li_addr(abi::A1, out);
    sim.v(VOp::Store { kind: VMemKind::UnitStride, eew: Sew::E32, vs3: VReg(8), base: abi::A1 });
    KernelRun { cycles: sim.cycles() - c0, macs: (h * w * c) as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::kernels::requantize::requant_host;

    #[test]
    fn avgpool_matches_golden() {
        let (h, w, c) = (4, 4, 96);
        let vals: Vec<u8> = (0..h * w * c).map(|i| (i % 11) as u8).collect();
        let mut sim = Sim::new(MachineConfig::quark(4));
        let fm = sim.alloc((h * w * c) as u64);
        sim.write_bytes(fm, &vals);
        // alpha = 1/(h·w) so the output is the rounded mean.
        let alpha = 1.0f32 / (h * w) as f32;
        let rq = RqBuf::create(&mut sim, &vec![alpha; c], &vec![0.0; c], &vec![0.0; c], 255.0, 0.0);
        let out = sim.alloc(c as u64);
        global_avgpool_u8(&mut sim, h, w, c, fm, &rq, out);
        for j in 0..c {
            let sum: i32 = (0..h * w).map(|p| vals[p * c + j] as i32).sum();
            let want = requant_host(sum, None, None, alpha, 0.0, 0.0, 255.0, 0.0);
            assert_eq!(sim.read_u8s(out + j as u64, 1)[0], want, "channel {j}");
        }
    }

    #[test]
    fn avgpool_runs_on_ara_too() {
        let mut sim = Sim::new(MachineConfig::ara(4));
        let fm = sim.alloc(4 * 4 * 64);
        let rq = RqBuf::create(&mut sim, &[0.1; 64], &[0.0; 64], &[0.0; 64], 255.0, 0.0);
        let out = sim.alloc(64);
        let r = global_avgpool_u8(&mut sim, 4, 4, 64, fm, &rq, out);
        assert!(r.cycles > 0);
    }
}
