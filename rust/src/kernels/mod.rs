//! The vector DNN runtime: hand-written kernels emitting RVV (+ Quark custom)
//! instruction streams into the simulator — the software the paper describes
//! in §IV-A ("customized bit-serial programs for conv2d, matrix
//! multiplication, and other common kernels").
//!
//! Every kernel follows the same contract:
//! * tensors live in *simulated* memory (allocated via [`crate::sim::Sim`]),
//! * the kernel emits the dynamic instruction stream a hand-written assembly
//!   implementation would execute (loop overhead included as branch markers),
//! * cycle accounting happens in the simulator; kernels credit
//!   `effective_macs` so GOPS are comparable across precisions.
//!
//! Kernels:
//! * [`bitpack`] — activation bit-plane packing, both with `vbitpack` and
//!   with base RVV only (the Fig. 3 ablation).
//! * [`conv2d`] — direct convolution, three precisions: bit-serial sub-byte
//!   (Quark), int8 (Ara baseline), fp32 (Ara baseline).
//! * [`matmul`] — the same three precisions as plain GEMM (FC layers,
//!   microbenchmarks).
//! * [`requantize`] — the scalar-FPU re-scaling block shared by all of the
//!   integer kernels (paper Fig. 2's "Div/Mul + Clip + Round" on CVA6).
//! * [`pool`] — global average pooling.
//!
//! Kernels are precision-agnostic building blocks: each call takes its own
//! operand widths (`abits`, packed weight `bits`) and requant clamp, which
//! is what lets [`crate::nn::model::ModelRunner::run_scheduled`] dispatch a
//! *different* kernel/width per layer under a mixed
//! [`crate::nn::model::PrecisionMap`] schedule.

pub mod bitpack;
pub mod conv2d;
pub mod matmul;
pub mod pool;
pub mod requantize;

/// Convolution geometry (NHWC feature maps).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dParams {
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Reduction length K = kh·kw·c_in (the im2col row length).
    pub fn k(&self) -> usize {
        self.kh * self.kw * self.c_in
    }

    /// Total MACs for the full output (padding taps included as the paper's
    /// GOPS accounting does — the hardware computes them as zeros).
    pub fn macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.c_out) as u64 * self.k() as u64
    }

    /// Enumerate valid kernel taps `(kh, kw)` for output pixel `(oy, ox)`,
    /// with the corresponding input row/col. Out-of-bounds taps (zero
    /// padding) are skipped — they contribute nothing to ACC or ASUM.
    pub fn valid_taps(&self, oy: usize, ox: usize) -> Vec<(usize, usize, usize, usize)> {
        let mut taps = Vec::with_capacity(self.kh * self.kw);
        for dy in 0..self.kh {
            let iy = (oy * self.stride + dy) as isize - self.pad as isize;
            if iy < 0 || iy >= self.h as isize {
                continue;
            }
            for dx in 0..self.kw {
                let ix = (ox * self.stride + dx) as isize - self.pad as isize;
                if ix < 0 || ix >= self.w as isize {
                    continue;
                }
                taps.push((dy, dx, iy as usize, ix as usize));
            }
        }
        taps
    }
}

/// What a kernel invocation reports back.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelRun {
    /// Cycles from first to last instruction of this kernel (delta).
    pub cycles: u64,
    /// Effective MACs credited.
    pub macs: u64,
}

impl KernelRun {
    /// Effective MACs per cycle — the paper's headline per-kernel metric.
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        let p = Conv2dParams { h: 32, w: 32, c_in: 64, c_out: 64, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert_eq!(p.out_h(), 32);
        assert_eq!(p.out_w(), 32);
        assert_eq!(p.k(), 576);
        // Interior pixel has all 9 taps, corner has 4.
        assert_eq!(p.valid_taps(16, 16).len(), 9);
        assert_eq!(p.valid_taps(0, 0).len(), 4);
    }

    #[test]
    fn strided_geometry() {
        let p = Conv2dParams { h: 32, w: 32, c_in: 64, c_out: 128, kh: 3, kw: 3, stride: 2, pad: 1 };
        assert_eq!(p.out_h(), 16);
        assert_eq!(p.out_w(), 16);
        let p1 = Conv2dParams { h: 32, w: 32, c_in: 64, c_out: 128, kh: 1, kw: 1, stride: 2, pad: 0 };
        assert_eq!(p1.out_h(), 16);
        assert_eq!(p1.k(), 64);
    }
}
