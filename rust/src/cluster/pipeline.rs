//! Pipeline parallelism — staged inference across multiple simulated Quark
//! cores.
//!
//! Where tensor sharding ([`crate::cluster`]) puts every core on the *same*
//! layer and pays an all-gather per layer, pipeline parallelism assigns each
//! core a contiguous *stage* of layers ([`StagePlan`]) and streams
//! activations stage-to-stage, so N requests are in flight at once — the
//! staged-execution regime SPEED (arXiv 2409.14017) argues FC-heavy
//! multi-precision transformer stacks belong in:
//!
//! ```text
//!             stage 0            stage 1            stage 2
//! req 0 ─► [layers 0..a] ─q─► [layers a..b] ─q─► [layers b..n] ─► logits 0
//! req 1 ─►      …        ─q─►      …        ─q─►       …        ─► logits 1
//!               (bounded activation queues between persistent Sims)
//! ```
//!
//! **Bit-exactness.** Each stage is compiled through the same single-source
//! `emit_model` routine as every other artifact ([`compile_stage`]): the
//! deterministic parameter stream is advanced over the stage's skipped
//! prefix, so in-range layers draw exactly the single-core weights; and
//! requant grids come from the narrowest-consumer rule over the *full* net,
//! so the upstream stage's last layer already clamped the hand-off
//! activation onto the downstream consumer grid — the hand-off is a pure
//! byte copy that never re-quantizes, exactly like the tensor-mode gather.
//! Streamed logits are therefore bit-identical to the single-core program
//! and the naive-i128 host golden model (`rust/tests/pipeline.rs`).
//!
//! **Cost model.** Let `e_s = stage_cycles[s] + hop_cycles[s]`, where
//! [`hop_cost`] charges the stage's output activation over the per-core AXI
//! link exactly like one step of the tensor-mode ring all-gather
//! ([`super::sync_cost`]; the last stage has no hop). Then for `N` streamed
//! requests:
//!
//! * fill (first-token latency) = `Σ e_s`,
//! * steady-state period = `max e_s`,
//! * total = `fill + (N − 1) · period`,
//! * per-stage busy = `N · e_s`, bubble = `total − busy` (≥ 0 because
//!   `total ≥ N · e_s` for every `s`) — [`PipelineTiming`] carries the
//!   conservation law Σ-checked by [`crate::obs::profile_pipeline`].
//!
//! **Host execution.** [`PipelineCores::infer_stream`] runs one persistent
//! [`Sim`] per stage on its own host thread, connected by *bounded*
//! activation queues ([`ACT_QUEUE_DEPTH`]-deep [`sync_channel`]s), so
//! upstream stages naturally back-pressure instead of buffering the whole
//! request stream.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use crate::arch::MachineConfig;
use crate::nn::model::{ModelRunner, PrecisionMap, StagePlan};
use crate::nn::NetGraph;
use crate::program::{compile_stage, CompiledProgram};
use crate::sim::{Sim, SimMode};

use super::{shard_mem_bytes, sync_cost};

/// Depth of each bounded inter-stage activation queue: enough to decouple
/// neighbouring stages' jitter, small enough that back-pressure (not
/// buffering) governs a long stream.
pub const ACT_QUEUE_DEPTH: usize = 2;

/// A compiled pipeline-parallel deployment: one [`CompiledProgram`] per
/// stage core, all over the same (net, machine, schedule), whose layer
/// ranges tile the source net in order. `Clone` is cheap: the stage
/// programs are `Arc`-shared (the coordinator clones per request).
#[derive(Clone)]
pub struct PipelineProgram {
    stages: Vec<Arc<CompiledProgram>>,
}

impl PipelineProgram {
    /// Assemble from per-stage programs (e.g. the coordinator's per-stage
    /// cache entries). Programs must be a complete, consistent stage chain:
    /// contiguous ranges tiling the net from layer 0, one deployment
    /// identity, and each stage's input segment sized to its predecessor's
    /// output.
    pub fn from_stages(stages: Vec<Arc<CompiledProgram>>) -> Result<PipelineProgram, String> {
        if stages.is_empty() {
            return Err("a pipeline needs at least one stage program".to_string());
        }
        let n = stages.len();
        let mut expect_lo = 0usize;
        for (i, p) in stages.iter().enumerate() {
            let info = p
                .stage()
                .ok_or_else(|| format!("program {i} is not a pipeline-stage program"))?;
            if info.index != i || info.count != n {
                return Err(format!(
                    "program {i} is stage {}/{}, expected {i}/{n}",
                    info.index, info.count
                ));
            }
            if info.lo != expect_lo {
                return Err(format!(
                    "stage {i} starts at layer {} but the previous stage ended at {expect_lo}",
                    info.lo
                ));
            }
            expect_lo = info.hi;
            if p.net_fingerprint() != stages[0].net_fingerprint()
                || p.machine_fingerprint() != stages[0].machine_fingerprint()
                || p.schedule() != stages[0].schedule()
            {
                return Err(format!("program {i} belongs to a different deployment"));
            }
            if i > 0 && p.input_elems() != stages[i - 1].out_elems() {
                return Err(format!(
                    "stage {i} expects {} input elements but stage {} produces {}",
                    p.input_elems(),
                    i - 1,
                    stages[i - 1].out_elems()
                ));
            }
        }
        Ok(PipelineProgram { stages })
    }

    /// Number of stage cores.
    pub fn stages(&self) -> usize {
        self.stages.len()
    }

    /// The per-stage programs, in stage order.
    pub fn stage_programs(&self) -> &[Arc<CompiledProgram>] {
        &self.stages
    }

    /// Total layers of the source net (the stages tile it).
    pub fn layers(&self) -> usize {
        self.stages.last().and_then(|p| p.stage()).map(|s| s.hi).unwrap_or(0)
    }

    /// Element count of the final feature map (the logits).
    pub fn out_elems(&self) -> usize {
        self.stages.last().expect("non-empty pipeline").out_elems()
    }

    /// The schedule the pipeline was compiled under.
    pub fn schedule(&self) -> &PrecisionMap {
        self.stages[0].schedule()
    }
}

/// Per-layer cycle estimates for [`StagePlan::derive_balanced`]: one live
/// `TimingOnly` emission of `net` under `schedule` (data-independent — no
/// tensor data is synthesized, the historical cost of a timing sweep).
pub fn stage_costs(net: &NetGraph, machine: &MachineConfig, schedule: &PrecisionMap) -> Vec<u64> {
    let mut sim = Sim::new(machine.clone());
    sim.set_mode(SimMode::TimingOnly);
    let run = ModelRunner::run_scheduled(&mut sim, net, schedule, None);
    run.reports.iter().map(|r| r.run.cycles).collect()
}

/// Compile `net` for `machine` under `schedule`, partitioned into `stages`
/// pipeline stages balanced on the timing model's per-layer cycle estimates
/// ([`stage_costs`]). Validates the schedule (like
/// [`crate::program::compile`]) plus the stage plan (cut validity,
/// integer-only schedules at > 1 stage). Stage programs are independent, so
/// they compile on parallel host threads, like [`super::compile_cluster`].
pub fn compile_pipeline(
    net: &NetGraph,
    machine: &MachineConfig,
    schedule: &PrecisionMap,
    stages: usize,
) -> Result<PipelineProgram, String> {
    schedule.validate(net)?;
    schedule.validate_machine(net, machine)?;
    let costs = stage_costs(net, machine, schedule);
    let plan = StagePlan::derive_balanced(net, stages, &costs)?;
    plan.validate_schedule(schedule)?;
    let progs = std::thread::scope(|s| {
        let handles: Vec<_> = (0..stages)
            .map(|i| {
                let plan = &plan;
                s.spawn(move || compile_stage(net, machine, schedule, plan, i).map(Arc::new))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("stage compile thread panicked"))
            .collect::<Result<Vec<_>, _>>()
    })?;
    PipelineProgram::from_stages(progs)
}

/// Modeled cycles to move one stage's output activation (`bytes`) to the
/// next core: exactly one step of the tensor-mode ring all-gather
/// ([`sync_cost`] at 2 cores) — the slice crosses the AXI link at
/// `axi_bytes_per_cycle` after a `mem_latency` start-up. 0 when there is no
/// next stage.
pub fn hop_cost(cfg: &MachineConfig, bytes: u64) -> u64 {
    sync_cost(cfg, 2, bytes)
}

/// One stage of the pipeline cycle model.
#[derive(Clone, Debug)]
pub struct StageTiming {
    /// Layer range `[lo, hi)` the stage executes.
    pub range: (usize, usize),
    /// Σ of the stage's per-layer compute cycles.
    pub compute_cycles: u64,
    /// Modeled activation-transfer cycles to the next stage ([`hop_cost`];
    /// 0 for the last stage).
    pub hop_cycles: u64,
}

impl StageTiming {
    /// The stage's contribution to fill and to the steady-state period:
    /// compute plus its outbound hop.
    pub fn effective_cycles(&self) -> u64 {
        self.compute_cycles + self.hop_cycles
    }
}

/// The pipeline cycle model for a stream of `tokens` requests — see the
/// module docs for the fill/period/bubble law.
#[derive(Clone, Debug)]
pub struct PipelineTiming {
    pub stages: Vec<StageTiming>,
    /// Requests modeled streaming through the pipeline (≥ 1).
    pub tokens: u64,
}

impl PipelineTiming {
    /// First-token latency: Σ per-stage effective cycles.
    pub fn fill_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.effective_cycles()).sum()
    }

    /// Steady-state initiation interval: max per-stage effective cycles.
    pub fn period_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.effective_cycles()).max().unwrap_or(0)
    }

    /// Modeled end-to-end latency of the whole stream:
    /// `fill + (tokens − 1) · period`.
    pub fn total_cycles(&self) -> u64 {
        self.fill_cycles() + (self.tokens - 1) * self.period_cycles()
    }

    /// Cycles each stage spends working: `tokens · effective(s)`.
    pub fn busy_cycles(&self) -> Vec<u64> {
        self.stages.iter().map(|s| self.tokens * s.effective_cycles()).collect()
    }

    /// Idle (bubble) cycles each stage spends waiting on the stream:
    /// `total − busy(s)`, non-negative by construction (`total ≥ tokens ·
    /// effective(s)` for every stage). Per stage, `busy + bubble == total`
    /// exactly — the conservation law [`crate::obs::profile_pipeline`]
    /// asserts.
    pub fn bubble_cycles(&self) -> Vec<u64> {
        let total = self.total_cycles();
        self.busy_cycles().into_iter().map(|b| total - b).collect()
    }

    /// Modeled utilization of each stage core: busy over total.
    pub fn stage_utilization(&self) -> Vec<f64> {
        let total = self.total_cycles().max(1) as f64;
        self.busy_cycles().into_iter().map(|b| b as f64 / total).collect()
    }
}

/// Derive the pipeline cycle model for `pipeline` streaming `tokens`
/// requests: one `TimingOnly` replay per stage program on parallel host
/// threads (fresh cores — the cache-miss path, run once per deployment),
/// hop costs charged per the module cost model.
pub fn pipeline_timing(
    pipeline: &PipelineProgram,
    machine: &MachineConfig,
    tokens: u64,
) -> PipelineTiming {
    assert!(tokens >= 1, "a pipeline stream needs at least one request");
    let per_stage: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = pipeline
            .stages
            .iter()
            .map(|prog| {
                s.spawn(move || {
                    let mut sim = Sim::with_memory(machine.clone(), shard_mem_bytes(prog));
                    sim.set_mode(SimMode::TimingOnly);
                    let base = sim.alloc(prog.mem_len());
                    sim.execute(prog, base).cycles
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("stage timing thread panicked")).collect()
    });
    let n = pipeline.stages();
    let stages = pipeline
        .stages
        .iter()
        .enumerate()
        .map(|(i, prog)| {
            let info = prog.stage().expect("pipeline programs carry stage info");
            StageTiming {
                range: (info.lo, info.hi),
                compute_cycles: per_stage[i],
                hop_cycles: if i + 1 < n {
                    hop_cost(machine, prog.output_bytes() as u64)
                } else {
                    0
                },
            }
        })
        .collect();
    PipelineTiming { stages, tokens }
}

/// Result of one functional pipeline stream.
pub struct PipelineInference {
    /// Per-request logits (u8 codes; pipeline schedules are integer-only),
    /// in submission order.
    pub logits: Vec<Vec<u8>>,
    /// Host wall-clock nanoseconds each stage core spent inside the stream
    /// (incl. queue waits) — the serving layer's stage-utilization feed.
    pub stage_busy_ns: Vec<u64>,
}

struct StageCore {
    sim: Sim,
    heap: u64,
}

/// A pool of persistent stage cores (one [`Sim`] each, bump allocator
/// rewound between requests — the pipeline analogue of [`super::ClusterCores`]).
pub struct PipelineCores {
    machine: MachineConfig,
    cores: Vec<StageCore>,
}

impl PipelineCores {
    /// `count` persistent cores for `machine`. Arenas start minimal and grow
    /// to fit the first program replayed on them.
    pub fn new(machine: &MachineConfig, count: usize) -> Self {
        assert!(count >= 1, "a pipeline needs at least one core");
        let cores = (0..count)
            .map(|_| {
                let sim = Sim::with_memory(machine.clone(), 16 << 20);
                let heap = sim.machine.mem.brk();
                StageCore { sim, heap }
            })
            .collect();
        PipelineCores { machine: machine.clone(), cores }
    }

    pub fn count(&self) -> usize {
        self.cores.len()
    }

    /// Functional pipeline inference: stream `inputs` through the stage
    /// cores, one host thread per stage, neighbouring stages connected by
    /// bounded activation queues. Request order is preserved (queues are
    /// FIFO and each stage is serial), and every logit vector is
    /// bit-identical to a single-core [`Sim::execute_functional`] of the
    /// unstaged program.
    ///
    /// Replay preconditions (stage count, machine identity) are checked on
    /// the caller's thread before any stage thread launches, mirroring
    /// [`super::ClusterCores::infer`] — a panic inside a stage thread would
    /// otherwise strand its neighbours on the queues.
    pub fn infer_stream(
        &mut self,
        pipeline: &PipelineProgram,
        inputs: &[Vec<u8>],
    ) -> PipelineInference {
        let n = self.cores.len();
        assert_eq!(
            pipeline.stages(),
            n,
            "pipeline program has {} stages but this pool has {n} cores",
            pipeline.stages()
        );
        for (core, prog) in self.cores.iter_mut().zip(pipeline.stages.iter()) {
            assert_eq!(
                crate::program::machine_fingerprint(&core.sim.cfg),
                prog.machine_fingerprint(),
                "stage program compiled for a different machine than this pool"
            );
            let need = shard_mem_bytes(prog);
            if core.sim.machine.mem.size() < need {
                core.sim = Sim::with_memory(self.machine.clone(), need);
                core.heap = core.sim.machine.mem.brk();
            }
        }
        if inputs.is_empty() {
            return PipelineInference { logits: Vec::new(), stage_busy_ns: vec![0; n] };
        }
        // Stage s receives from links[s].0 (None for stage 0, which reads
        // `inputs` directly) and sends into links[s].1 (None for the last
        // stage, which collects logits).
        type Link = (Option<Receiver<Vec<u8>>>, Option<SyncSender<Vec<u8>>>);
        let mut links: Vec<Link> = (0..n).map(|_| (None, None)).collect();
        for k in 0..n - 1 {
            let (tx, rx) = sync_channel::<Vec<u8>>(ACT_QUEUE_DEPTH);
            links[k].1 = Some(tx);
            links[k + 1].0 = Some(rx);
        }
        let results: Vec<(Vec<Vec<u8>>, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .cores
                .iter_mut()
                .zip(pipeline.stages.iter())
                .zip(links.into_iter())
                .map(|((core, prog), (rx, tx))| {
                    s.spawn(move || {
                        let t0 = Instant::now();
                        let mut collected = Vec::new();
                        for req in inputs {
                            let bytes: Vec<u8> = match &rx {
                                None => req.clone(),
                                Some(rx) => rx.recv().expect("upstream stage hung up early"),
                            };
                            core.sim.machine.mem.reset_alloc_to(core.heap);
                            let base = core.sim.alloc(prog.mem_len());
                            let run = core.sim.execute_functional(prog, base, Some(&bytes));
                            let act = core.sim.read_u8s(run.out_addr, run.out_elems);
                            match &tx {
                                Some(tx) => {
                                    tx.send(act).expect("downstream stage hung up early")
                                }
                                None => collected.push(act),
                            }
                        }
                        (collected, t0.elapsed().as_nanos() as u64)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("stage replay thread panicked"))
                .collect()
        });
        let stage_busy_ns = results.iter().map(|(_, ns)| *ns).collect();
        let logits = results.into_iter().last().expect("at least one stage").0;
        PipelineInference { logits, stage_busy_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::demo_net;
    use crate::nn::model::Precision;

    const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };

    #[test]
    fn compile_pipeline_validates() {
        let net = demo_net(); // tiny zoo net: 4 convs + pool + fc
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(W2A2);
        assert!(compile_pipeline(&net, &quark, &sched, 0).is_err());
        assert!(compile_pipeline(&net, &quark, &sched, 64).is_err(), "more stages than layers");
        let p = compile_pipeline(&net, &quark, &sched, 2).unwrap();
        assert_eq!(p.stages(), 2);
        assert_eq!(p.layers(), net.len());
        assert_eq!(p.out_elems(), net.out_elems());
        // Stage ranges tile the net and chain their activation segments.
        let infos: Vec<_> = p.stage_programs().iter().map(|q| q.stage().unwrap()).collect();
        assert_eq!(infos[0].lo, 0);
        assert_eq!(infos[0].hi, infos[1].lo);
        assert_eq!(infos[1].hi, net.len());
        assert_eq!(p.stage_programs()[1].input_elems(), p.stage_programs()[0].out_elems());
        // fp32 cannot pipeline at > 1 stage, even on a machine with a vFPU.
        assert!(compile_pipeline(
            &net,
            &MachineConfig::ara(4),
            &PrecisionMap::uniform(Precision::Fp32),
            2
        )
        .is_err());
    }

    #[test]
    fn from_stages_rejects_mismatched_chains() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(W2A2);
        let p2 = compile_pipeline(&net, &quark, &sched, 2).unwrap();
        // Wrong order.
        let mut progs = p2.stage_programs().to_vec();
        progs.swap(0, 1);
        assert!(PipelineProgram::from_stages(progs).is_err());
        // Incomplete chain.
        assert!(PipelineProgram::from_stages(p2.stage_programs()[..1].to_vec()).is_err());
        // Non-stage program.
        let single = Arc::new(crate::program::compile(&net, &quark, &sched).unwrap());
        assert!(PipelineProgram::from_stages(vec![single]).is_err());
    }

    #[test]
    fn timing_model_fill_period_and_bubbles_conserve() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let p = compile_pipeline(&net, &quark, &PrecisionMap::uniform(W2A2), 2).unwrap();
        let t = pipeline_timing(&p, &quark, 8);
        assert_eq!(t.stages.len(), 2);
        assert!(t.stages.iter().all(|s| s.compute_cycles > 0));
        assert!(t.stages[0].hop_cycles > 0, "non-final stage pays its hop");
        assert_eq!(t.stages[1].hop_cycles, 0, "final stage has no hop");
        assert_eq!(
            t.fill_cycles(),
            t.stages.iter().map(|s| s.effective_cycles()).sum::<u64>()
        );
        assert_eq!(t.total_cycles(), t.fill_cycles() + 7 * t.period_cycles());
        // Conservation: per stage, busy + bubble == total.
        let (busy, bubbles) = (t.busy_cycles(), t.bubble_cycles());
        for s in 0..2 {
            assert_eq!(busy[s] + bubbles[s], t.total_cycles(), "stage {s}");
        }
        // The bottleneck stage runs bubble-free in steady state apart from
        // fill/drain: its bubble is exactly fill − its own effective cycles.
        let max_s = (0..2).max_by_key(|&s| t.stages[s].effective_cycles()).unwrap();
        assert_eq!(bubbles[max_s], t.fill_cycles() - t.stages[max_s].effective_cycles());
        // Sustained throughput beats one-request-at-a-time latency.
        assert!(t.period_cycles() < t.fill_cycles());
    }

    #[test]
    fn single_stage_pipeline_is_the_identity() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(W2A2);
        let p = compile_pipeline(&net, &quark, &sched, 1).unwrap();
        let single = crate::program::compile(&net, &quark, &sched).unwrap();
        let sp = &p.stage_programs()[0];
        assert_eq!(sp.trace_len(), single.trace_len());
        assert_eq!(sp.mem_len(), single.mem_len());
        assert_eq!(sp.image_bytes(), single.image_bytes());
        // And the timing model degenerates to the single-core latency.
        let t = pipeline_timing(&p, &quark, 4);
        assert_eq!(t.stages[0].hop_cycles, 0);
        assert_eq!(t.fill_cycles(), t.period_cycles());
        assert_eq!(t.total_cycles(), 4 * t.fill_cycles());
    }

    #[test]
    fn streamed_logits_match_single_core_replay() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(W2A2);
        let p = compile_pipeline(&net, &quark, &sched, 3).unwrap();
        let single = crate::program::compile(&net, &quark, &sched).unwrap();
        let inputs: Vec<Vec<u8>> = (0..4u8)
            .map(|r| (0..crate::nn::graph::INPUT_ELEMS).map(|i| (i as u8).wrapping_mul(r + 1)).collect())
            .collect();
        let mut cores = PipelineCores::new(&quark, 3);
        let out = cores.infer_stream(&p, &inputs);
        assert_eq!(out.logits.len(), 4);
        assert_eq!(out.stage_busy_ns.len(), 3);
        let mut sim = Sim::with_memory(quark.clone(), shard_mem_bytes(&single));
        let heap = sim.machine.mem.brk();
        for (req, got) in inputs.iter().zip(out.logits.iter()) {
            sim.machine.mem.reset_alloc_to(heap);
            let base = sim.alloc(single.mem_len());
            let run = sim.execute_functional(&single, base, Some(req));
            let want = sim.read_u8s(run.out_addr, run.out_elems);
            assert_eq!(got, &want, "pipeline diverged from single-core replay");
        }
    }
}
